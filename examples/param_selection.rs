//! Appendix-J parameter selection, end to end:
//!
//! 1. calibrate the load→runtime slope α (Fig. 16),
//! 2. capture a `T_probe`-round uncoded reference delay profile,
//! 3. grid-search (B, W, λ) / s by replaying the load-adjusted profile
//!    through the real master logic,
//! 4. print the per-scheme winners (Table 1 "Parameters" column).
//!
//! ```text
//! cargo run --release --example param_selection [--n 128 --t-probe 40]
//! ```

use sgc::cluster::SimCluster;
use sgc::probe::{grid_search, DelayProfile, SearchSpace};
use sgc::straggler::GilbertElliot;
use sgc::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let n = args.get_parse("n", 128usize);
    let t_probe = args.get_parse("t-probe", 40usize);
    let jobs = args.get_parse("jobs", 80usize);

    // Step 1: Fig-16 calibration — mean worker time at a few loads.
    let mut cal = SimCluster::from_gilbert_elliot(n, GilbertElliot::default_fit(n, 5), 17);
    let mut points = Vec::new();
    for load in [0.0, 0.1, 0.25, 0.5, 0.75, 1.0] {
        let profile = DelayProfile::capture(&mut cal, 5, load);
        points.push((load, profile.mean_time()));
    }
    let alpha = DelayProfile::fit_alpha(&points);
    println!("fitted load slope α = {alpha:.2} s/unit-load (true: {:.2})", cal.latency.alpha_s_per_load);

    // Step 2: reference (uncoded) delay profile.
    let mut cluster = SimCluster::from_gilbert_elliot(n, GilbertElliot::default_fit(n, 5), 29);
    let profile = DelayProfile::capture(&mut cluster, t_probe, 1.0 / n as f64);
    println!("captured T_probe = {t_probe} rounds of reference delays\n");

    // Steps 3-4: grid search per scheme family.
    let space = SearchSpace::paper_default(n);
    println!(
        "{:<10} {:<18} {:>10} {:>14} {:>12}",
        "family", "best params", "load", "est. runtime", "candidates"
    );
    for (name, cands) in [
        ("GC", space.gc_candidates()),
        ("SR-SGC", space.sr_sgc_candidates()),
        ("M-SGC", space.m_sgc_candidates()),
    ] {
        let ranked = grid_search(&cands, &profile, alpha, jobs);
        let best = &ranked[0];
        println!(
            "{:<10} {:<18} {:>10.4} {:>12.1}s {:>12}",
            name,
            best.config.label(),
            best.load,
            best.estimated_runtime_s,
            ranked.len()
        );
    }
    println!("\n(expected shape: M-SGC wins with ~8x lower load than GC — Table 1)");
}
