//! END-TO-END driver (DESIGN.md §6): concurrently train M = 4 MLP
//! classifiers on the synthetic corpus with **real gradient computation**
//! through the AOT-compiled PJRT artifacts, under each coding scheme,
//! on a simulated straggling serverless cluster. Logs per-model loss
//! curves and the completed-jobs-vs-time curve (Fig. 2), and saves JSON
//! to `target/experiments/multi_model_training.json`.
//!
//! Requires `make artifacts` first.
//!
//! ```text
//! cargo run --release --example multi_model_training [--n 16 --iters 30]
//! ```

use sgc::cluster::SimCluster;
use sgc::coding::SchemeConfig;
use sgc::runtime::{artifacts_dir, ComputePool};
use sgc::straggler::GilbertElliot;
use sgc::train::{Dataset, DatasetConfig, MultiModelTrainer, TrainConfig};
use sgc::util::cli::Args;
use sgc::util::json::Json;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.get_parse("n", 16usize);
    let iters = args.get_parse("iters", 30usize);
    let models = args.get_parse("models", 4usize);
    let batch = args.get_parse("batch", 256usize);
    let lanes = args.get_parse("lanes", 4usize);

    if !artifacts_dir().join("model.hlo.txt").exists() {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    }
    let pool = Arc::new(ComputePool::new(artifacts_dir(), lanes)?);
    let dims = pool.dims();
    println!(
        "model: {}-{}-{}-{} MLP ({} params), chunk capacity {}",
        dims.input,
        dims.hidden1,
        dims.hidden2,
        dims.classes,
        dims.param_count(),
        dims.chunk
    );
    let dataset = Dataset::generate(DatasetConfig::default());
    println!(
        "dataset: {} synthetic samples, {} classes | cluster: n={n}, GE stragglers\n",
        dataset.len(),
        dataset.cfg.classes
    );

    let mut out = Json::obj();
    // λ ≈ n/4 scaled from the paper's 27/256; s ≈ n/16 scaled from 15/256.
    let schemes = [
        format!("m-sgc:1,2,{}", (n / 4).max(1)),
        format!("sr-sgc:2,3,{}", (n / 4).max(2)),
        format!("gc:{}", (n / 8).max(1)),
        "uncoded".to_string(),
    ];
    for spec in &schemes {
        let scheme = SchemeConfig::parse(n, spec)?;
        let cfg = TrainConfig {
            models,
            iterations: iters,
            batch,
            lr: 2e-3,
            seed: 7,
            ..Default::default()
        };
        let mut trainer =
            MultiModelTrainer::new(scheme.clone(), cfg, Arc::clone(&pool), dataset.clone())?;
        let mut cluster =
            SimCluster::from_gilbert_elliot(n, GilbertElliot::default_fit(n, 7), 31);
        let report = trainer.run(&mut cluster)?;
        println!(
            "{:<16} load={:.4} | {} jobs | sim {:>7.1}s | wall {:>6.1}s | violations {}",
            report.scheme,
            scheme.load(),
            report.jobs_completed,
            report.sim_runtime_s,
            report.wall_runtime_s,
            report.deadline_violations
        );
        for (m, curve) in report.losses.iter().enumerate() {
            if let (Some(f), Some(l)) = (curve.first(), curve.last()) {
                println!(
                    "    model {m}: loss {:.4} → {:.4} ({} iters)",
                    f.loss, l.loss, l.iteration
                );
            }
        }
        let mut j = Json::obj();
        j.set("load", scheme.load())
            .set("sim_runtime_s", report.sim_runtime_s)
            .set("jobs", report.jobs_completed)
            .set(
                "completion_curve",
                Json::Arr(
                    report
                        .completion_curve
                        .iter()
                        .map(|&(t, c)| {
                            let mut o = Json::obj();
                            o.set("t", t).set("jobs", c);
                            o
                        })
                        .collect(),
                ),
            )
            .set(
                "loss_curves",
                Json::Arr(
                    report
                        .losses
                        .iter()
                        .map(|curve| {
                            Json::Arr(
                                curve
                                    .iter()
                                    .map(|p| {
                                        let mut o = Json::obj();
                                        o.set("iter", p.iteration)
                                            .set("t", p.sim_time_s)
                                            .set("loss", p.loss);
                                        o
                                    })
                                    .collect(),
                            )
                        })
                        .collect(),
                ),
            );
        out.set(&scheme.label(), j);
    }
    let path = "target/experiments/multi_model_training.json";
    out.save(path)?;
    println!("\nsaved {path}");
    println!("expected shape (Fig. 2): all curves reach the same loss; M-SGC reaches it fastest.");
    Ok(())
}
