//! Quickstart: simulate a few hundred jobs under each coding scheme on a
//! 64-worker cluster with naturally bursty (Gilbert-Elliot) stragglers,
//! and compare total runtimes.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use sgc::cluster::SimCluster;
use sgc::coding::SchemeConfig;
use sgc::coordinator::{Master, RunConfig};
use sgc::straggler::GilbertElliot;

fn main() {
    let n = 64;
    let jobs = 120;
    println!("sequential gradient coding quickstart — n={n}, J={jobs}\n");
    println!(
        "{:<16} {:>8} {:>4} {:>12} {:>10} {:>10}",
        "scheme", "load", "T", "runtime (s)", "waitouts", "violations"
    );
    for spec in ["m-sgc:1,2,7", "sr-sgc:2,3,6", "gc:4", "uncoded"] {
        let scheme = SchemeConfig::parse(n, spec).expect("valid scheme spec");
        let mut master = Master::new(scheme.clone(), RunConfig { jobs, ..Default::default() });
        let mut cluster =
            SimCluster::from_gilbert_elliot(n, GilbertElliot::default_fit(n, 7), 99);
        let report = master.run(&mut cluster).expect("matching cluster size");
        println!(
            "{:<16} {:>8.4} {:>4} {:>12.1} {:>10} {:>10}",
            report.scheme,
            report.load,
            report.delay,
            report.total_runtime_s,
            report.waitout_rounds(),
            report.deadline_violations
        );
    }
    println!("\nM-SGC should finish first at a fraction of GC's per-worker load —");
    println!("the paper's Table-1 effect, reproduced by `cargo bench --bench table1`.");
}
