//! Fig.-1-style straggler analysis: run 100 rounds on the simulated
//! 256-worker cluster and report (a) the straggler map density, (b) the
//! burst-length histogram and (c) the completion-time CDF.
//!
//! ```text
//! cargo run --release --example straggler_analysis [--n 256 --rounds 100]
//! ```

use sgc::cluster::SimCluster;
use sgc::straggler::GilbertElliot;
use sgc::util::cli::Args;
use sgc::util::stats;

fn main() {
    let args = Args::from_env();
    let n = args.get_parse("n", 256usize);
    let rounds = args.get_parse("rounds", 100usize);
    let mu = args.get_parse("mu", 1.0f64);
    let load = args.get_parse("load", 1.0 / n as f64);

    let mut cluster = SimCluster::from_gilbert_elliot(n, GilbertElliot::default_fit(n, 7), 13);
    let mut detected = sgc::straggler::Pattern::new(n);
    let mut all_times: Vec<f64> = Vec::new();
    for _ in 0..rounds {
        let s = cluster.sample_round(&vec![load; n]);
        let kappa = s.finish.iter().cloned().fold(f64::INFINITY, f64::min);
        detected.push_round(s.finish.iter().map(|&f| f > (1.0 + mu) * kappa).collect());
        all_times.extend_from_slice(&s.finish);
    }

    println!("== Fig 1(a): straggler map ==");
    println!(
        "cells: {} workers x {} rounds, straggling fraction {:.2}% (white cells)",
        n,
        rounds,
        100.0 * detected.straggle_fraction()
    );
    let per_round: Vec<f64> =
        (1..=rounds).map(|r| detected.count_in_round(r) as f64).collect();
    println!(
        "stragglers/round: mean {:.1}, min {:.0}, max {:.0}",
        stats::mean(&per_round),
        stats::min(&per_round),
        stats::max(&per_round)
    );

    println!("\n== Fig 1(b): burst-length histogram ==");
    let bursts = detected.burst_lengths();
    let max_b = bursts.iter().cloned().max().unwrap_or(1);
    for len in 1..=max_b {
        let count = bursts.iter().filter(|&&b| b == len).count();
        if count > 0 {
            println!("  length {len:>2}: {count:>5} {}", "#".repeat((count / 5).max(1).min(60)));
        }
    }

    println!("\n== Fig 1(c): completion-time CDF ==");
    for q in [10.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 99.9] {
        println!("  p{q:<5}: {:>8.2}s", stats::percentile(&all_times, q));
    }
    println!(
        "  tail ratio p99/p50 = {:.2} (long tail ⇒ stragglers exist)",
        stats::percentile(&all_times, 99.0) / stats::percentile(&all_times, 50.0)
    );
}
