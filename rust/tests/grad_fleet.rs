//! End-to-end tests of the real gradient data plane over a loopback TCP
//! fleet: partitions ship to workers, workers compute coded partial
//! gradients with the real MLP, the master β-decodes and steps Adam —
//! and the result must match the plain uncoded gradient sum, survive
//! worker loss with re-placement onto a late-joining spare, and reject
//! incompatible (v1) peers with a clear error frame.

use sgc::cluster::EventCluster;
use sgc::coding::SchemeConfig;
use sgc::fleet::wire::{read_frame, ERR_BAD_VERSION};
use sgc::fleet::{Frame, LoopbackFleet, MembershipConfig, WireError, WorkerConfig};
use sgc::grad::{DataPlane, GradConfig, GradPump};
use sgc::obs::{EventKind, Obs};
use sgc::sched::{JobScheduler, JobSpec, JobStatus};
use sgc::session::SessionConfig;
use std::io::Write;
use std::sync::Arc;
use std::time::Duration;

/// The small-but-real training config the tests share: 64-sample fixed
/// batch over a 4-chunk shard keeps each worker's forward/backward well
/// under the loopback round budget.
fn grad_cfg(seed: u64) -> GradConfig {
    GradConfig { seed, batch: 64, train_size: 512, ..Default::default() }
}

/// Relative loss-trajectory comparison against the uncoded reference.
fn assert_losses_match(fleet_losses: &[f64], reference: &[f64]) {
    assert_eq!(fleet_losses.len(), reference.len(), "trajectory lengths differ");
    for (i, (a, b)) in fleet_losses.iter().zip(reference).enumerate() {
        assert!(
            (a - b).abs() <= 1e-5 * (1.0 + b.abs()),
            "step {i}: fleet loss {a} vs uncoded reference {b}"
        );
    }
}

#[test]
fn decoded_coded_sums_match_the_uncoded_reference() {
    // gc(4, 1): every round's gradient reaches the master only as coded
    // payloads (β-decoded from 3-of-4 responders). The resulting loss
    // trajectory must match exact full-batch GD — the plain per-chunk
    // sum with no coding — within float noise.
    let n = 4;
    let scheme = SchemeConfig::gc(n, 1);
    let cfg = grad_cfg(0x9e2e);
    let mut fleet = LoopbackFleet::spawn(n, None).expect("spawn fleet");
    let mut pump = GradPump::new(DataPlane::shared(), cfg.clone());
    fleet.cluster.set_dataplane(pump.dataplane());
    let out = {
        let mut sched = JobScheduler::new(&mut fleet.cluster);
        sched.set_dataplane(pump.dataplane());
        let spec = JobSpec {
            scheme: scheme.clone(),
            session: SessionConfig { jobs: 4, ..Default::default() },
        };
        let j = sched.admit(&spec).expect("admit");
        pump.configure_job(j, &scheme).expect("configure");
        sched.run_observed(&mut pump).expect("fleet run")
    };
    let _ = fleet.cluster.finish_trace(Duration::from_secs(5), 1.0);
    fleet.shutdown().expect("clean shutdown");

    assert!(out.outcomes.iter().all(|o| o.status == JobStatus::Completed), "{:?}", out.outcomes);
    let sums = pump.summary();
    assert_eq!(sums.len(), 1);
    let s = &sums[0];
    assert_eq!(s.steps, 4, "every paper job must decode into an optimizer step");
    assert_eq!(s.fallback_decodes, 0, "the wire payloads must carry the decode, not the fallback");
    assert_eq!(s.audits, 0, "healthy workers must not trip the redundancy audit");
    let reference = GradPump::reference_losses(&cfg, s.job, &scheme, s.steps);
    assert_losses_match(&s.losses, &reference);
}

#[test]
fn loss_strictly_decreases_over_twenty_rounds() {
    let n = 4;
    let scheme = SchemeConfig::gc(n, 1);
    let cfg = grad_cfg(0x10_55);
    let mut fleet = LoopbackFleet::spawn(n, None).expect("spawn fleet");
    let mut pump = GradPump::new(DataPlane::shared(), cfg);
    fleet.cluster.set_dataplane(pump.dataplane());
    let out = {
        let mut sched = JobScheduler::new(&mut fleet.cluster);
        sched.set_dataplane(pump.dataplane());
        let spec = JobSpec {
            scheme: scheme.clone(),
            session: SessionConfig { jobs: 20, ..Default::default() },
        };
        let j = sched.admit(&spec).expect("admit");
        pump.configure_job(j, &scheme).expect("configure");
        sched.run_observed(&mut pump).expect("fleet run")
    };
    let _ = fleet.cluster.finish_trace(Duration::from_secs(5), 1.0);
    fleet.shutdown().expect("clean shutdown");

    assert!(out.outcomes.iter().all(|o| o.status == JobStatus::Completed), "{:?}", out.outcomes);
    let sums = pump.summary();
    let s = &sums[0];
    assert_eq!(s.steps, 20);
    assert_eq!(s.losses.len(), 21, "20 steps = 21 losses including init");
    for w in s.losses.windows(2) {
        assert!(
            w[1] < w[0],
            "full-batch GD at this lr must descend strictly: {:?}",
            s.losses
        );
    }
}

#[test]
fn replacement_spare_fetches_partitions_and_the_decode_is_unchanged() {
    // Worker 2 dies after three served rounds; a late-joined spare
    // (id 4) takes over its logical seat. The master must ship the
    // spare the job spec, the missing partitions and the *current*
    // params before its first GradAssign — and the decoded trajectory
    // must stay byte-for-byte on the uncoded reference, crash and all.
    let n = 4;
    let scheme = SchemeConfig::gc(n, 1);
    let cfg = grad_cfg(0x51a2e);
    let mut fleet = LoopbackFleet::spawn_with(n, |id, addr| {
        let mut c = WorkerConfig::loopback(id, addr.to_string(), None);
        if id == 2 {
            c.fail_after_rounds = Some(3);
        }
        c
    })
    .expect("spawn fleet");
    fleet.cluster.set_membership(MembershipConfig {
        reap_after: Duration::from_secs(1),
        ..Default::default()
    });
    fleet.join_worker(WorkerConfig::loopback(n as u32, String::new(), None));
    let obs = Arc::new(Obs::new());
    fleet.cluster.set_obs(obs.clone());
    let mut pump = GradPump::new(DataPlane::shared(), cfg.clone());
    fleet.cluster.set_dataplane(pump.dataplane());
    let out = {
        let mut sched = JobScheduler::new(&mut fleet.cluster);
        sched.set_obs(obs.clone());
        sched.set_dataplane(pump.dataplane());
        let spec = JobSpec {
            scheme: scheme.clone(),
            session: SessionConfig { jobs: 12, ..Default::default() },
        };
        let j = sched.admit(&spec).expect("admit");
        pump.configure_job(j, &scheme).expect("configure");
        sched.run_observed(&mut pump).expect("fleet run")
    };
    let _ = fleet.cluster.finish_trace(Duration::from_secs(5), 1.0);
    fleet.shutdown().expect("clean shutdown");

    assert!(out.outcomes.iter().all(|o| o.status == JobStatus::Completed), "{:?}", out.outcomes);
    assert!(out.utilization.worker_retired_events >= 1, "{}", out.utilization);
    let events = obs.journal.snapshot();
    assert!(
        events.iter().any(|e| e.kind == EventKind::Replacement),
        "the dead seat must be re-placed onto the spare"
    );
    assert!(
        events.iter().any(|e| e.kind == EventKind::PartitionSent && e.worker == n as i64),
        "the spare (worker {n}) must be shipped the partitions it lacks"
    );
    assert!(
        events.iter().any(|e| e.kind == EventKind::ParamBroadcast && e.worker == n as i64),
        "the spare (worker {n}) must be shipped the current params"
    );
    assert!(
        events.iter().any(|e| e.kind == EventKind::GradientDecoded),
        "real-gradient decodes must be journaled"
    );
    let sums = pump.summary();
    let s = &sums[0];
    assert_eq!(s.steps, 12);
    assert_eq!(s.fallback_decodes, 0, "re-placement must not force the master-side fallback");
    let reference = GradPump::reference_losses(&cfg, s.job, &scheme, s.steps);
    assert_losses_match(&s.losses, &reference);
}

#[test]
fn master_rejects_a_v1_hello_with_a_clear_error_frame() {
    // An old (v1) worker dialing a v2 master must receive a readable
    // Error frame — never a panic, never a silent hangup.
    let n = 2;
    let mut fleet = LoopbackFleet::spawn(n, None).expect("spawn fleet");
    let addr = fleet.cluster.addr().to_string();
    let mut stream = std::net::TcpStream::connect(&addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_millis(50)))
        .expect("set timeout");
    // a v1 Hello: identical layout, version byte 1
    let mut bytes = Frame::Hello { worker_id: 9 }.encode();
    bytes[4] = 1;
    stream.write_all(&bytes).expect("send v1 hello");
    stream.flush().expect("flush");
    // single-threaded reactor: pump it until the farewell arrives
    let mut reply = None;
    for _ in 0..100 {
        let now = fleet.cluster.now_s();
        let _ = fleet.cluster.poll(now + 0.02);
        match read_frame(&mut stream) {
            Ok(f) => {
                reply = Some(f);
                break;
            }
            Err(WireError::Io(_)) => continue, // timeout: not processed yet
            Err(e) => panic!("expected an Error frame, got {e}"),
        }
    }
    match reply {
        Some(Frame::Error { code, msg }) => {
            assert_eq!(code, ERR_BAD_VERSION);
            assert!(msg.contains("version"), "unhelpful rejection: {msg:?}");
        }
        other => panic!("expected an Error frame, got {other:?}"),
    }
    // …and the master then hangs up on us (possibly after a last poll)
    let mut closed = false;
    for _ in 0..100 {
        let now = fleet.cluster.now_s();
        let _ = fleet.cluster.poll(now + 0.02);
        match read_frame(&mut stream) {
            Err(WireError::Closed) => {
                closed = true;
                break;
            }
            Err(WireError::Io(_)) => continue,
            other => panic!("expected the connection to close, got {other:?}"),
        }
    }
    assert!(closed, "master kept the incompatible connection open");
    fleet.shutdown().expect("healthy workers still shut down");
}
