//! Serving-loop soak test (ISSUE 10 satellite): a scripted admission
//! source streams 40 short jobs — mixed priorities, mixed schemes, the
//! first few on the real-gradient data plane — into a live sim-backed
//! `JobScheduler::serve` loop while a chaos plan crashes one worker and
//! shrinks the fleet mid-stream. Asserts:
//!
//! 1. every non-quarantined job completes (exactly or degraded, with a
//!    report for every admitted job);
//! 2. priority inversion never exceeds one round: within one admission
//!    wave, a higher-priority job activates no more than one committed
//!    round after any lower-priority job;
//! 3. same-seed runs produce byte-identical per-job reports;
//! 4. the admission queue drains back to zero by the end of the run.

use sgc::chaos::ChaosPlan;
use sgc::cluster::SimCluster;
use sgc::coding::SchemeConfig;
use sgc::grad::{DataPlane, GradConfig, GradPump};
use sgc::obs::{EventKind, Obs};
use sgc::sched::{
    ArrivalAt, JobScheduler, JobSpec, JobStatus, ScheduleReport, ScriptedSource, ServeConfig,
};
use sgc::session::SessionConfig;
use sgc::straggler::GilbertElliot;
use std::sync::Arc;

const N: usize = 8;
const WAVES: usize = 5;
const PER_WAVE: usize = 8;

/// Wave `w`, slot `i` → (priority, spec). Schemes rotate through three
/// straggler tolerances; priorities cycle 0/3/6 so every wave mixes
/// background and urgent jobs.
fn job_shape(w: usize, i: usize) -> (u8, JobSpec) {
    let tolerance = 1 + (w + i) % 3; // gc:1 | gc:2 | gc:3
    let spec = JobSpec {
        scheme: SchemeConfig::gc(N, tolerance),
        session: SessionConfig { jobs: 2, ..Default::default() },
    };
    (((i % 3) * 3) as u8, spec)
}

/// One full soak run: 5 waves × 8 jobs, 25 s apart on the virtual
/// clock, `max_active 3` so waves overlap and queue, chaos mid-stream,
/// and the first three jobs riding the gradient data plane (the sim
/// returns no payloads, so their decodes exercise the master-side
/// fallback path — still fully deterministic).
fn soak(seed: u64) -> (ScheduleReport, ScriptedSource, Arc<Obs>, Vec<u8>) {
    let mut sim = SimCluster::from_gilbert_elliot(
        N,
        GilbertElliot::default_fit(N, seed),
        seed ^ 0xc1,
    );
    sim.set_chaos(
        ChaosPlan::parse("crash@r10:w2,shrink@r30:1", seed ^ 0x50a4)
            .expect("chaos spec parses")
            .resolve(N),
    );
    let obs = Arc::new(Obs::new());
    sim.set_obs(obs.clone());

    let mut src = ScriptedSource::new();
    let mut priorities = Vec::with_capacity(WAVES * PER_WAVE);
    for w in 0..WAVES {
        for i in 0..PER_WAVE {
            let (pri, spec) = job_shape(w, i);
            src.submit_at(
                ArrivalAt::Time(w as f64 * 25.0),
                &format!("soak-w{w}-{i}"),
                pri,
                spec,
            );
            priorities.push(pri);
        }
    }

    // Real-grad subset: co-timed arrivals admit in submission order, so
    // the first wave's first three submissions become jobs 0, 1, 2.
    let mut pump = GradPump::new(
        DataPlane::shared(),
        GradConfig { seed, batch: 32, train_size: 128, ..Default::default() },
    );
    for j in 0..3 {
        let (_, spec) = job_shape(0, j);
        pump.configure_job(j, &spec.scheme).expect("configure grad job");
    }

    let cfg = ServeConfig { max_active: 3, max_queue: 64, ..Default::default() };
    let out = {
        let mut sched = JobScheduler::new(&mut sim);
        sched.set_obs(obs.clone());
        sched.set_dataplane(pump.dataplane());
        sched.serve(&mut src, &cfg, &mut pump).expect("soak run survives chaos")
    };
    // every configured grad job decoded its full session ledger
    for s in pump.summary() {
        assert_eq!(s.steps, 2, "grad job {} missed decodes", s.job);
        assert!(s.last_loss.is_finite());
    }
    (out, src, obs, priorities)
}

#[test]
fn soak_forty_jobs_under_chaos_all_complete_and_queue_drains() {
    let (out, src, obs, priorities) = soak(0x50ab);
    let total = WAVES * PER_WAVE;
    assert_eq!(out.reports.len(), total);
    assert_eq!(src.accepted(), total);
    assert_eq!(src.rejected(), 0);

    // 1. every non-quarantined job completes, exactly or degraded
    assert!(!out.all_failed());
    for o in &out.outcomes {
        if o.status == JobStatus::Quarantined {
            continue; // chaos victims may legitimately quarantine
        }
        assert!(
            matches!(o.status, JobStatus::Completed | JobStatus::Degraded),
            "job {}: {o:?}",
            o.job
        );
        if o.status == JobStatus::Completed {
            assert!(
                out.reports[o.job].job_completion_s.iter().all(|t| t.is_finite()),
                "job {} completed with undecoded paper-jobs",
                o.job
            );
        }
    }

    // 2. priority inversion ≤ one round: within a wave, a higher-
    //    priority job's first activation trails any lower-priority
    //    job's by at most one committed round.
    let events = obs.journal.snapshot();
    let mut act_round: Vec<Option<u64>> = vec![None; total];
    let mut closed = 0u64;
    for e in &events {
        match e.kind {
            EventKind::RoundClose => closed += 1,
            EventKind::RoundAssign => {
                let j = e.job as usize;
                if e.job >= 0 && j < total && act_round[j].is_none() {
                    act_round[j] = Some(closed);
                }
            }
            _ => {}
        }
    }
    for w in 0..WAVES {
        let wave = w * PER_WAVE..(w + 1) * PER_WAVE;
        for a in wave.clone() {
            for b in wave.clone() {
                let (Some(ra), Some(rb)) = (act_round[a], act_round[b]) else {
                    continue;
                };
                if priorities[a] > priorities[b] {
                    assert!(
                        ra <= rb + 1,
                        "priority inversion: job {a} (pri {}) activated at round {ra}, \
                         after job {b} (pri {}) at round {rb}",
                        priorities[a],
                        priorities[b]
                    );
                }
            }
        }
    }

    // 4. the admission queue is empty again at the end of the run
    let rendered = obs.metrics.render_prometheus();
    assert!(
        rendered.contains("sgc_admission_queue_depth 0"),
        "queue depth did not return to zero:\n{rendered}"
    );
    assert!(rendered.contains("sgc_jobs_submitted_total 40"), "{rendered}");
    assert!(rendered.contains("sgc_jobs_rejected_total 0"), "{rendered}");
}

#[test]
fn soak_is_byte_identical_for_a_fixed_seed() {
    let (a, _, _, _) = soak(0x5eed);
    let (b, _, _, _) = soak(0x5eed);
    assert_eq!(
        format!("{:?}", a.reports),
        format!("{:?}", b.reports),
        "same-seed soak runs must produce byte-identical per-job reports"
    );
    assert_eq!(format!("{:?}", a.outcomes), format!("{:?}", b.outcomes));
    assert_eq!(format!("{}", a.utilization), format!("{}", b.utilization));
}
