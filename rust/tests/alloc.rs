//! Counting-allocator verification of the §Perf claim (rust/DESIGN.md
//! §Performance): the steady-state round loop —
//! `begin_round_into` → `submit_all` → `close_round` — performs only a
//! small constant number of heap allocations per round, independent of
//! `n`. The survivors are the report's own per-round storage (two
//! pattern rows, the event list, the round record's completed-jobs
//! list); the decision path itself (μ-rule, wait-out, scheme commit,
//! decode scan) runs entirely in reused scratch buffers.
//!
//! Before the allocation-free rework each round cost O(n) allocations
//! (task-list clones, per-unit chunk vectors, ledger clones, fresh
//! responder/straggler/pending vectors), i.e. hundreds per round at
//! n = 256 — this test fails loudly if any of that creeps back.

use sgc::cluster::{LatencyParams, SimCluster};
use sgc::coding::SchemeConfig;
use sgc::sched::{JobScheduler, JobSpec};
use sgc::session::{RoundPlan, SessionConfig, SgcSession};
use sgc::straggler::NoStragglers;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

struct CountingAllocator;

// SAFETY: defers entirely to the system allocator; the counter is a
// relaxed atomic with no allocation of its own.
unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAllocator = CountingAllocator;

/// This file holds exactly one test so no sibling test thread can bleed
/// allocations into the measured window.
#[test]
fn steady_state_round_allocations_are_constant_and_small() {
    let n = 256;
    let s = 15;
    let warmup = 16usize;
    let measured = 32usize;
    let jobs = warmup + measured;

    let mut session = SgcSession::new(
        &SchemeConfig::gc(n, s),
        SessionConfig { jobs, ..Default::default() },
    );
    let mut plan = RoundPlan::default();
    // quiet cluster: everyone finishes together, no wait-outs
    let finish = vec![1.0f64; n];

    let run_round = |session: &mut SgcSession, plan: &mut RoundPlan| {
        session.begin_round_into(plan);
        session.submit_all(&finish);
        let events = session.close_round();
        assert!(!events.is_empty());
    };

    for _ in 0..warmup {
        run_round(&mut session, &mut plan);
    }

    let before = ALLOCATIONS.load(Ordering::Relaxed);
    for _ in 0..measured {
        run_round(&mut session, &mut plan);
    }
    let total = ALLOCATIONS.load(Ordering::Relaxed) - before;
    let per_round = total as f64 / measured as f64;

    // Expected steady state: ~4-5 allocations per round (detected +
    // effective pattern rows, the event vec, the round record's
    // completed-jobs vec) plus occasional amortized growth of the
    // report's round storage. The old per-round protocol cost hundreds
    // at n = 256; 8 is a tight-but-robust ceiling.
    assert!(
        per_round <= 8.0,
        "steady-state round loop allocated {per_round:.1} times/round \
         ({total} over {measured} rounds) — the allocation-free engine \
         regressed (expected ≤ 8; the pre-rework protocol costs O(n))"
    );

    // --- Phase 2: the scheduler pump over the event-driven simulator ---
    // One job through `JobScheduler` on `SimCluster` adds, per round: the
    // straggler-process row, the recorded true-state row, and the
    // session's own report storage from phase 1 — while the pump itself
    // (submit/poll queues, event batches, load placement, pending-worker
    // scans via `pending_workers_into`) runs entirely in reused buffers.
    // O(n) per-round allocation anywhere in the event path would put
    // this in the hundreds at n = 256.
    let sched_rounds = 400usize;
    let mut sim =
        SimCluster::new(n, LatencyParams::default(), Box::new(NoStragglers { n }), 7);
    let mut sched = JobScheduler::new(&mut sim);
    sched
        .admit(&JobSpec {
            scheme: SchemeConfig::gc(n, s),
            session: SessionConfig { jobs: sched_rounds, ..Default::default() },
        })
        .expect("sizes match");
    let before = ALLOCATIONS.load(Ordering::Relaxed);
    let out = sched.run().expect("quiet run completes");
    let total = ALLOCATIONS.load(Ordering::Relaxed) - before;
    assert_eq!(out.reports[0].rounds.len(), sched_rounds);
    let per_round = total as f64 / sched_rounds as f64;
    assert!(
        per_round <= 16.0,
        "scheduler pump allocated {per_round:.1} times/round ({total} over \
         {sched_rounds} rounds) — the event-path allocation budget regressed \
         (expected ≤ 16; an O(n) event path costs hundreds at n = 256)"
    );

    // --- Phase 3: the observability record path is allocation-free ---
    // Counters, gauges, histogram records and journal appends are the
    // per-event hot path of `sgc::obs` — registration allocates once up
    // front; recording must never allocate, including after the journal
    // ring wraps (2000 appends into a 1024-slot ring below cover the
    // overwrite path).
    let obs = sgc::obs::Obs::with_capacity(1024);
    let c = obs.metrics.counter("alloc_test_total", "", "phase-3 counter");
    let g = obs.metrics.gauge("alloc_test_gauge", "", "phase-3 gauge");
    let h = obs.metrics.histogram("alloc_test_seconds", "", "phase-3 histogram");
    // prime the ring to capacity so wraps are exercised from the start
    for i in 0..1024 {
        obs.journal.record(i as f64, sgc::obs::EventKind::RoundClose, 0, i as i64, 0, 0.5);
    }
    let iters = 2000usize;
    let before = ALLOCATIONS.load(Ordering::Relaxed);
    for i in 0..iters {
        c.inc();
        g.set(i as f64);
        h.record(0.001 * i as f64);
        obs.journal.record(
            i as f64,
            sgc::obs::EventKind::WorkerArrive,
            0,
            i as i64,
            (i % 7) as i64,
            0.25,
        );
    }
    let total = ALLOCATIONS.load(Ordering::Relaxed) - before;
    assert_eq!(
        total, 0,
        "obs record path allocated {total} times over {iters} \
         counter+gauge+histogram+journal iterations (expected 0: \
         registration allocates, recording must not)"
    );
}
