//! End-to-end tests over the real AOT artifacts: PJRT load + execute,
//! numeric gradient properties, and full coded training runs.
//!
//! All tests that *execute* artifacts are gated behind the `pjrt` feature
//! (the xla crate needs a prebuilt xla_extension that offline/CI
//! environments lack) and additionally need `make artifacts` to have run;
//! they are skipped (with a note) when `artifacts/model.hlo.txt` is
//! absent so `cargo test` stays green on a fresh checkout.

use sgc::runtime::ComputePool;

#[cfg(feature = "pjrt")]
use sgc::runtime::artifacts_dir;

/// Failure injection: a bad artifact directory must error cleanly, not
/// hang or panic. (Runs with or without the `pjrt` feature: the stub
/// pool validates artifact metadata the same way.)
#[test]
fn compute_pool_bad_artifacts_errors() {
    let bad = std::env::temp_dir().join("sgc-definitely-missing");
    let err = match ComputePool::new(bad, 1) {
        Ok(_) => panic!("expected error for missing artifacts"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("model_meta.txt") || msg.contains("reading"), "{msg}");
}

#[cfg(feature = "pjrt")]
use sgc::cluster::SimCluster;
#[cfg(feature = "pjrt")]
use sgc::coding::SchemeConfig;
#[cfg(feature = "pjrt")]
use sgc::runtime::GradExecutable;
#[cfg(feature = "pjrt")]
use sgc::straggler::GilbertElliot;
#[cfg(feature = "pjrt")]
use sgc::train::{Dataset, DatasetConfig, MultiModelTrainer, TrainConfig};
#[cfg(feature = "pjrt")]
use sgc::util::rng::Pcg32;
#[cfg(feature = "pjrt")]
use std::sync::Arc;

#[cfg(feature = "pjrt")]
fn have_artifacts() -> bool {
    let ok = artifacts_dir().join("model.hlo.txt").exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first");
    }
    ok
}

#[cfg(feature = "pjrt")]
fn init_params(dims: &sgc::runtime::ModelDims, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::seeded(seed);
    dims.param_shapes()
        .iter()
        .map(|&(r, c)| {
            let scale = if r == 1 { 0.0 } else { (2.0 / r as f64).sqrt() };
            (0..r * c).map(|_| (rng.normal() * scale) as f32).collect()
        })
        .collect()
}

#[cfg(feature = "pjrt")]
#[test]
fn artifact_loads_and_runs() {
    if !have_artifacts() {
        return;
    }
    let exe = GradExecutable::load(&artifacts_dir()).expect("load artifact");
    let d = exe.dims;
    let params = init_params(&d, 42);
    let mut rng = Pcg32::seeded(7);
    let x: Vec<f32> = (0..d.chunk * d.input).map(|_| rng.normal() as f32).collect();
    let mut y = vec![0.0f32; d.chunk * d.classes];
    for row in 0..d.chunk {
        y[row * d.classes + rng.below(d.classes)] = 1.0;
    }
    let w = vec![1.0 / d.chunk as f32; d.chunk];
    let (loss, grads) = exe.grad_chunk(&params, &x, &y, &w).expect("grad_chunk");
    // loss ≈ ln(10) for random init on 10 classes
    assert!(loss > 0.5 && loss < 10.0, "loss {loss}");
    assert_eq!(grads.len(), 6);
    for (g, len) in grads.iter().zip(d.param_lens()) {
        assert_eq!(g.len(), len);
    }
    let norm: f32 = grads.iter().flatten().map(|v| v * v).sum::<f32>().sqrt();
    assert!(norm > 1e-4, "gradient should be non-trivial, norm {norm}");
}

#[cfg(feature = "pjrt")]
#[test]
fn padding_rows_do_not_change_gradients() {
    if !have_artifacts() {
        return;
    }
    let exe = GradExecutable::load(&artifacts_dir()).expect("load artifact");
    let d = exe.dims;
    let params = init_params(&d, 1);
    let mut rng = Pcg32::seeded(3);
    let real = d.chunk / 2;
    let mut x = vec![0.0f32; d.chunk * d.input];
    let mut y = vec![0.0f32; d.chunk * d.classes];
    let mut w = vec![0.0f32; d.chunk];
    for row in 0..real {
        for k in 0..d.input {
            x[row * d.input + k] = rng.normal() as f32;
        }
        y[row * d.classes + rng.below(d.classes)] = 1.0;
        w[row] = 1.0 / real as f32;
    }
    let (l1, g1) = exe.grad_chunk(&params, &x, &y, &w).unwrap();
    // fill padding with garbage — zero weight must nullify it
    for row in real..d.chunk {
        for k in 0..d.input {
            x[row * d.input + k] = 1e3;
        }
        y[row * d.classes] = 1.0;
    }
    let (l2, g2) = exe.grad_chunk(&params, &x, &y, &w).unwrap();
    assert!((l1 - l2).abs() < 1e-4, "{l1} vs {l2}");
    for (a, b) in g1.iter().flatten().zip(g2.iter().flatten()) {
        assert!((a - b).abs() < 1e-4);
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn chunk_gradients_are_additive() {
    if !have_artifacts() {
        return;
    }
    let exe = GradExecutable::load(&artifacts_dir()).expect("load artifact");
    let d = exe.dims;
    let params = init_params(&d, 5);
    let ds = Dataset::generate(DatasetConfig::default());
    let mut rng = Pcg32::seeded(11);
    let batch = ds.sample_batch(d.chunk, &mut rng);
    let wfull = 1.0 / batch.len() as f32;
    // full batch in one chunk
    let (xa, ya, wa) = ds.chunk_tensors(&batch, d.chunk, wfull);
    let (loss_full, g_full) = exe.grad_chunk(&params, &xa, &ya, &wa).unwrap();
    // two half chunks, summed
    let (h1, h2) = batch.split_at(batch.len() / 2);
    let mut loss_sum = 0.0f32;
    let mut g_sum: Vec<Vec<f32>> = d.param_lens().iter().map(|&l| vec![0.0; l]).collect();
    for half in [h1, h2] {
        let (x, y, w) = ds.chunk_tensors(half, d.chunk, wfull);
        let (l, g) = exe.grad_chunk(&params, &x, &y, &w).unwrap();
        loss_sum += l;
        for (acc, gi) in g_sum.iter_mut().zip(&g) {
            for (a, v) in acc.iter_mut().zip(gi) {
                *a += v;
            }
        }
    }
    assert!((loss_full - loss_sum).abs() < 1e-3, "{loss_full} vs {loss_sum}");
    for (a, b) in g_full.iter().flatten().zip(g_sum.iter().flatten()) {
        assert!((a - b).abs() < 1e-3, "{a} vs {b}");
    }
}

/// Train a few iterations under each scheme; the loss must decrease and
/// all coded/plain decode paths must agree with training progress.
#[cfg(feature = "pjrt")]
#[test]
fn coded_training_reduces_loss() {
    if !have_artifacts() {
        return;
    }
    let n = 8;
    let pool = Arc::new(ComputePool::new(artifacts_dir(), 2).expect("pool"));
    let dataset = Dataset::generate(DatasetConfig { train_size: 2048, ..Default::default() });
    for scheme in [
        SchemeConfig::gc(n, 2),
        SchemeConfig::msgc(n, 1, 2, 2),
        SchemeConfig::sr_sgc(n, 1, 2, 3),
        SchemeConfig::uncoded(n),
    ] {
        let cfg = TrainConfig {
            models: 2,
            iterations: 8,
            batch: 128,
            lr: 4e-3,
            seed: 9,
            ..Default::default()
        };
        let mut trainer =
            MultiModelTrainer::new(scheme.clone(), cfg, Arc::clone(&pool), dataset.clone())
                .expect("trainer");
        let mut cluster =
            SimCluster::from_gilbert_elliot(n, GilbertElliot::new(n, 0.05, 0.6, 3), 13);
        let report = trainer.run(&mut cluster).expect("train");
        assert_eq!(report.deadline_violations, 0, "{}", scheme.label());
        assert_eq!(report.jobs_completed, 16, "{}", scheme.label());
        for (m, curve) in report.losses.iter().enumerate() {
            let first = curve.first().expect("loss logged").loss;
            let last = curve.last().unwrap().loss;
            assert!(
                last < first,
                "{} model {m}: loss {first} → {last} did not decrease",
                scheme.label()
            );
        }
    }
}

/// Replication-base variants (Appendix G) train correctly too.
#[cfg(feature = "pjrt")]
#[test]
fn rep_variants_train() {
    if !have_artifacts() {
        return;
    }
    let n = 6;
    let pool = Arc::new(ComputePool::new(artifacts_dir(), 2).expect("pool"));
    let dataset = Dataset::generate(DatasetConfig { train_size: 1024, ..Default::default() });
    for spec in ["gc-rep:2", "sr-sgc-rep:1,2,3", "m-sgc-rep:1,2,1"] {
        let scheme = SchemeConfig::parse(n, spec).unwrap();
        let cfg = TrainConfig {
            models: 2,
            iterations: 5,
            batch: 96,
            seed: 3,
            ..Default::default()
        };
        let mut trainer =
            MultiModelTrainer::new(scheme, cfg, Arc::clone(&pool), dataset.clone()).unwrap();
        let mut cluster =
            SimCluster::from_gilbert_elliot(n, GilbertElliot::new(n, 0.05, 0.7, 4), 11);
        let report = trainer.run(&mut cluster).expect("train");
        assert_eq!(report.deadline_violations, 0, "{spec}");
        for curve in &report.losses {
            assert!(curve.last().unwrap().loss < curve.first().unwrap().loss, "{spec}");
        }
    }
}

/// Appendix-I multi-model learning: each model trains on its *own*
/// dataset; all still converge under coded scheduling.
#[cfg(feature = "pjrt")]
#[test]
fn multi_dataset_training() {
    if !have_artifacts() {
        return;
    }
    let n = 8;
    let pool = Arc::new(ComputePool::new(artifacts_dir(), 2).expect("pool"));
    let datasets: Vec<Dataset> = (0..2u64)
        .map(|k| {
            Dataset::generate(DatasetConfig {
                train_size: 1024,
                seed: 100 + k,
                noise: 0.5 + 0.3 * k as f64,
                ..Default::default()
            })
        })
        .collect();
    let cfg = TrainConfig { models: 2, iterations: 6, batch: 128, seed: 5, ..Default::default() };
    let mut trainer = MultiModelTrainer::with_datasets(
        SchemeConfig::msgc(n, 1, 2, 2),
        cfg,
        pool,
        datasets,
    )
    .unwrap();
    let mut cluster =
        SimCluster::from_gilbert_elliot(n, GilbertElliot::new(n, 0.05, 0.7, 2), 6);
    let report = trainer.run(&mut cluster).expect("train");
    assert_eq!(report.deadline_violations, 0);
    for (m, curve) in report.losses.iter().enumerate() {
        assert!(
            curve.last().unwrap().loss < curve.first().unwrap().loss,
            "model {m} on its own dataset must improve"
        );
    }
    // wrong dataset count must be rejected
    let pool2 = Arc::new(ComputePool::new(artifacts_dir(), 1).expect("pool"));
    let bad = MultiModelTrainer::with_datasets(
        SchemeConfig::msgc(n, 1, 2, 2),
        TrainConfig { models: 3, ..Default::default() },
        pool2,
        vec![
            Dataset::generate(DatasetConfig { train_size: 64, ..Default::default() }),
            Dataset::generate(DatasetConfig { train_size: 64, ..Default::default() }),
        ],
    );
    assert!(bad.is_err());
}

/// The decoded coded gradient must match the plain sum: run the same seed
/// under uncoded and GC; with no stragglers and identical batches the
/// loss trajectories must coincide up to decode round-off.
#[cfg(feature = "pjrt")]
#[test]
fn gc_decode_matches_uncoded_gradients() {
    if !have_artifacts() {
        return;
    }
    let n = 6;
    let pool = Arc::new(ComputePool::new(artifacts_dir(), 2).expect("pool"));
    let dataset = Dataset::generate(DatasetConfig { train_size: 1024, ..Default::default() });
    let run = |scheme: SchemeConfig| {
        let cfg = TrainConfig {
            models: 1,
            iterations: 4,
            batch: 60,
            lr: 4e-3,
            seed: 21,
            ..Default::default()
        };
        let mut trainer =
            MultiModelTrainer::new(scheme, cfg, Arc::clone(&pool), dataset.clone()).unwrap();
        // no stragglers → identical effective responses
        let mut cluster = SimCluster::new(
            n,
            sgc::cluster::LatencyParams::default(),
            Box::new(sgc::straggler::NoStragglers { n }),
            5,
        );
        trainer.run(&mut cluster).unwrap()
    };
    let unc = run(SchemeConfig::uncoded(n));
    let gc = run(SchemeConfig::gc(n, 2));
    let lu: Vec<f64> = unc.losses[0].iter().map(|p| p.loss).collect();
    let lg: Vec<f64> = gc.losses[0].iter().map(|p| p.loss).collect();
    assert_eq!(lu.len(), lg.len());
    for (a, b) in lu.iter().zip(&lg) {
        assert!(
            (a - b).abs() < 2e-2 * (1.0 + a.abs()),
            "loss curves diverged: {lu:?} vs {lg:?}"
        );
    }
}
