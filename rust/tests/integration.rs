//! Integration tests: session protocol + schemes + simulated cluster +
//! probe, at Table-1-like (but scaled-down) configurations.

use sgc::cluster::{EventCluster, LatencyParams, SimCluster, SyncAdapter};
use sgc::coding::SchemeConfig;
use sgc::coordinator::{Master, RunConfig, WaitPolicy};
use sgc::probe::{grid_search, DelayProfile, SearchSpace};
use sgc::session::{self, SessionConfig, SessionEvent, SgcSession};
use sgc::straggler::{GilbertElliot, NoStragglers, Pattern, TraceProcess};

fn ge_cluster(n: usize, seed: u64) -> SimCluster {
    SimCluster::from_gilbert_elliot(n, GilbertElliot::default_fit(n, seed), seed ^ 0x77)
}

fn run(scheme: SchemeConfig, jobs: usize, seed: u64) -> sgc::coordinator::RunReport {
    let n = scheme.n;
    session::drive(
        &scheme,
        &SessionConfig { jobs, ..Default::default() },
        &mut ge_cluster(n, seed).sync(),
    )
    .unwrap()
}

#[test]
fn scheme_ordering_matches_table1() {
    // Table 1's qualitative ordering at a scaled-down config:
    // M-SGC < SR-SGC ≤ GC < uncoded in total runtime (averaged seeds).
    let n = 128;
    let jobs = 60;
    let avg = |cfg: SchemeConfig| -> f64 {
        (0..4).map(|s| run(cfg.clone(), jobs, 100 + s).total_runtime_s).sum::<f64>() / 4.0
    };
    // parameters scaled from the paper's n=256 selections (λ ≈ n/10)
    let msgc = avg(SchemeConfig::msgc(n, 1, 2, 14));
    let srsgc = avg(SchemeConfig::sr_sgc(n, 2, 3, 12));
    let gc = avg(SchemeConfig::gc(n, 8));
    let unc = avg(SchemeConfig::uncoded(n));
    assert!(msgc < gc, "m-sgc {msgc} vs gc {gc}");
    assert!(srsgc < unc, "sr-sgc {srsgc} vs uncoded {unc}");
    assert!(gc < unc, "gc {gc} vs uncoded {unc}");
    assert!(msgc <= srsgc * 1.05, "m-sgc {msgc} vs sr-sgc {srsgc}");
}

#[test]
fn all_jobs_always_decode_with_conformance_repair() {
    for spec in ["gc:6", "gc-rep:7", "sr-sgc:1,2,8", "m-sgc:1,2,8", "m-sgc:2,3,10", "uncoded"] {
        let cfg = SchemeConfig::parse(32, spec).unwrap();
        let rep = run(cfg, 40, 5);
        assert_eq!(rep.deadline_violations, 0, "{spec}");
        assert!(rep.job_completion_s.iter().all(|t| t.is_finite()), "{spec}");
        // completion times are monotone in job index... up to batching of
        // rounds: job t completes no later than job t+1
        for w in rep.job_completion_s.windows(2) {
            assert!(w[0] <= w[1] + 1e-9, "{spec}: non-monotone completions");
        }
    }
}

#[test]
fn deadline_decode_can_violate_on_msgc_but_not_conformance() {
    // A hostile trace: worker 0 straggles two rounds in every three —
    // violates (B=1, W=2)-style models persistently.
    let n = 8;
    let mut rows = Vec::new();
    for r in 0..60usize {
        let mut row = vec![false; n];
        if r % 3 != 2 {
            row[0] = true;
        }
        rows.push(row);
    }
    let pattern = Pattern::from_rows(rows);
    let mk = |policy| {
        let mut master = Master::new(
            SchemeConfig::msgc(n, 1, 2, 2),
            RunConfig { jobs: 40, wait_policy: policy, ..Default::default() },
        );
        let mut cluster = SimCluster::new(
            n,
            // no severity decay: the burst continuer stays slow, forcing
            // explicit wait-outs every burst
            LatencyParams { straggle_decay: 1.0, ..Default::default() },
            Box::new(TraceProcess::new(pattern.clone())),
            9,
        );
        master.run(&mut cluster.sync()).unwrap()
    };
    let repair = mk(WaitPolicy::ConformanceRepair);
    assert_eq!(repair.deadline_violations, 0);
    // repair must have waited out rounds to stay conforming
    assert!(repair.waitout_rounds() > 5);
    let lazy = mk(WaitPolicy::DeadlineDecode);
    // lazy waits only at deadlines; with M-SGC's fixed diagonal it still
    // decodes (single worker straggling), but must wait at deadline
    // rounds instead
    assert_eq!(lazy.rounds.len(), repair.rounds.len());
}

#[test]
fn mu_controls_straggler_sensitivity() {
    // Larger μ admits more workers before cutoff → fewer detected
    // stragglers.
    let n = 64;
    let detect = |mu: f64| {
        let mut master =
            Master::new(SchemeConfig::gc(n, 6), RunConfig { jobs: 30, mu, ..Default::default() });
        let rep = master.run(&mut ge_cluster(n, 42).sync()).unwrap();
        rep.rounds.iter().map(|r| r.detected_stragglers).sum::<usize>()
    };
    let tight = detect(0.3);
    let loose = detect(5.0);
    assert!(loose < tight, "mu=5 detected {loose} vs mu=0.3 {tight}");
}

#[test]
fn no_stragglers_means_no_waitouts_and_tight_rounds() {
    let n = 16;
    let mut master =
        Master::new(SchemeConfig::msgc(n, 1, 2, 4), RunConfig { jobs: 20, ..Default::default() });
    let mut cluster =
        SimCluster::new(n, LatencyParams::default(), Box::new(NoStragglers { n }), 3);
    let rep = master.run(&mut cluster.sync()).unwrap();
    assert_eq!(rep.deadline_violations, 0);
    assert_eq!(rep.waitout_rounds(), 0);
    assert!(rep.true_pattern.straggle_fraction() == 0.0);
}

#[test]
fn detected_stragglers_track_true_states() {
    let n = 128;
    let mut master =
        Master::new(SchemeConfig::gc(n, 12), RunConfig { jobs: 50, ..Default::default() });
    let rep = master.run(&mut ge_cluster(n, 11).sync()).unwrap();
    // per-round agreement between μ-rule detections and GE ground truth
    let mut agree = 0usize;
    let mut total = 0usize;
    for r in 1..=rep.detected_pattern.rounds() {
        for i in 0..n {
            total += 1;
            if rep.detected_pattern.is_straggler(i, r) == rep.true_pattern.is_straggler(i, r) {
                agree += 1;
            }
        }
    }
    let acc = agree as f64 / total as f64;
    assert!(acc > 0.95, "detection accuracy {acc}");
}

#[test]
fn probe_selects_reasonable_gc_parameter() {
    // With the default GE fit at n=64 (~3-4 stragglers/round), the probe
    // should not pick extreme s values.
    let n = 64;
    let mut cluster = ge_cluster(n, 21);
    let profile =
        DelayProfile::capture(&mut SyncAdapter::new(&mut cluster), 30, 1.0 / n as f64);
    let alpha = cluster.latency.alpha_s_per_load;
    let cands: Vec<SchemeConfig> = (1..=16).map(|s| SchemeConfig::gc(n, s)).collect();
    let ranked = grid_search(&cands, &profile, alpha, 30);
    let best_s = match ranked[0].config.kind {
        sgc::coding::SchemeKind::Gc { s } => s,
        _ => unreachable!(),
    };
    assert!((2..=12).contains(&best_s), "probe picked s={best_s}");
}

#[test]
fn search_space_enumerations_are_buildable() {
    let sp = SearchSpace::paper_default(32);
    let total = sp.gc_candidates().len() + sp.sr_sgc_candidates().len()
        + sp.m_sgc_candidates().len();
    assert!(total > 50, "search space too small: {total}");
}

#[test]
fn runs_are_deterministic_given_seed() {
    let a = run(SchemeConfig::msgc(16, 1, 2, 4), 25, 77);
    let b = run(SchemeConfig::msgc(16, 1, 2, 4), 25, 77);
    assert_eq!(a.total_runtime_s, b.total_runtime_s);
    assert_eq!(a.job_completion_s, b.job_completion_s);
}

#[test]
fn master_facade_equals_session_drive() {
    // The classic Master API is a thin driver over the same session: the
    // two entry points must agree exactly.
    let scheme = SchemeConfig::sr_sgc(32, 1, 2, 8);
    let jobs = 20;
    let via_session = run(scheme.clone(), jobs, 5);
    let mut master = Master::new(scheme, RunConfig { jobs, ..Default::default() });
    let via_master = master.run(&mut ge_cluster(32, 5).sync()).unwrap();
    // the event-native scheduler path agrees too
    let via_events = master.run_events(&mut ge_cluster(32, 5)).unwrap();
    assert_eq!(via_events.total_runtime_s, via_session.total_runtime_s);
    assert_eq!(via_events.job_completion_s, via_session.job_completion_s);
    assert_eq!(via_master.total_runtime_s, via_session.total_runtime_s);
    assert_eq!(via_master.job_completion_s, via_session.job_completion_s);
    assert_eq!(via_master.deadline_violations, via_session.deadline_violations);
}

#[test]
fn session_event_stream_is_consistent_with_report() {
    // Pump a session by hand; the event stream must agree with the final
    // report: every job decodes exactly once, violations match, and the
    // clock in RunComplete equals the report total.
    let n = 16;
    let jobs = 20;
    let scheme = SchemeConfig::msgc(n, 1, 2, 4);
    let mut cluster = ge_cluster(n, 13);
    let mut session =
        SgcSession::new(&scheme, SessionConfig { jobs, ..Default::default() });
    let mut decoded = Vec::new();
    let mut violated = 0usize;
    let mut final_clock = None;
    while !session.is_complete() {
        let plan = session.begin_round();
        assert_eq!(plan.round, session.current_round());
        let sample = cluster.sample_round(&plan.loads);
        session.record_true_state(&sample.state);
        session.submit_all(&sample.finish);
        for ev in session.close_round() {
            match ev {
                SessionEvent::JobDecoded { job, .. } => decoded.push(job),
                SessionEvent::DeadlineViolated { .. } => violated += 1,
                SessionEvent::RunComplete { total_runtime_s } => {
                    final_clock = Some(total_runtime_s)
                }
                SessionEvent::WaitingFor { .. } => panic!("all times were submitted"),
                SessionEvent::RoundClosed { .. } => {}
            }
        }
    }
    let report = session.into_report();
    let mut sorted = decoded.clone();
    sorted.sort_unstable();
    sorted.dedup();
    assert_eq!(sorted.len(), decoded.len(), "a job decoded twice");
    assert_eq!(decoded.len(), jobs, "every job decodes under conformance repair");
    assert_eq!(violated, report.deadline_violations);
    assert_eq!(final_clock, Some(report.total_runtime_s));
}

#[test]
fn decode_in_idle_hides_decode_cost() {
    let n = 32;
    let mk = |decode_in_idle| {
        let mut master = Master::new(
            SchemeConfig::gc(n, 4),
            RunConfig { jobs: 20, measure_decode: true, decode_in_idle, ..Default::default() },
        );
        master.run(&mut ge_cluster(n, 9).sync()).unwrap().total_runtime_s
    };
    let hidden = mk(true);
    let exposed = mk(false);
    assert!(exposed >= hidden, "decode-on-path {exposed} < hidden {hidden}");
}

#[test]
fn storage_bound_cluster_has_fatter_tails() {
    // Appendix L: EFS-bound workload: completion CDF tail forces larger μ.
    use sgc::cluster::StorageParams;
    let n = 64;
    let mk = |with_storage: bool| {
        let mut c = ge_cluster(n, 31);
        if with_storage {
            c = c.with_storage(StorageParams::resnet18_efs());
        }
        let s = c.sample_round(&vec![0.02; n]);
        let mut f = s.finish;
        f.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // p90 / p10 spread
        f[(0.9 * n as f64) as usize] / f[(0.1 * n as f64) as usize]
    };
    assert!(mk(true) > mk(false), "storage must widen the spread");
}
