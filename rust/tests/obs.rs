//! Observability-stack integration tests (see `sgc::obs` and
//! DESIGN.md §Observability):
//!
//! 1. **Zero perturbation** — an instrumented scheduler run produces a
//!    byte-identical `ScheduleReport` to an uninstrumented one (the
//!    hooks are read-only, and on the simulator they must not touch
//!    the RNG stream).
//! 2. **Journal coverage** — an instrumented run journals the full
//!    round lifecycle (assign → arrivals → μ-cut → close → decode),
//!    and the journal JSON round-trips through `events_from_json`.
//! 3. **Chrome trace validity** — `chrome_trace` output parses back as
//!    JSON and its `B`/`E` round spans balance per process.
//! 4. **Reactor-served `/metrics`** — a real HTTP scrape over TCP
//!    against a loopback fleet returns per-job latency quantiles,
//!    served by the fleet's own poll(2) reactor (no metrics thread).

use sgc::cluster::{EventCluster, SimCluster};
use sgc::coding::SchemeConfig;
use sgc::fleet::LoopbackFleet;
use sgc::obs::{chrome_trace, events_from_json, EventKind, Obs};
use sgc::sched::{JobScheduler, JobSpec};
use sgc::session::SessionConfig;
use sgc::straggler::GilbertElliot;
use sgc::util::json::Json;
use std::sync::Arc;
use std::time::Duration;

/// One deterministic two-job scheduler run over the Gilbert-Elliot
/// simulator, optionally instrumented; returns the report's JSON text.
fn run_serve(obs: Option<&Arc<Obs>>) -> String {
    let n = 12;
    let mut sim =
        SimCluster::from_gilbert_elliot(n, GilbertElliot::default_fit(n, 19), 19 ^ 0xc1);
    if let Some(o) = obs {
        sim.set_obs(o.clone());
    }
    let mut sched = JobScheduler::new(&mut sim);
    if let Some(o) = obs {
        sched.set_obs(o.clone());
    }
    let spec = JobSpec {
        scheme: SchemeConfig::gc(n, 2),
        session: SessionConfig { jobs: 6, ..Default::default() },
    };
    for _ in 0..2 {
        sched.admit(&spec).expect("sizes match");
    }
    sched.run().expect("quiet run completes").to_json().to_string()
}

#[test]
fn instrumented_run_report_is_byte_identical() {
    let plain = run_serve(None);
    let obs = Arc::new(Obs::new());
    let instrumented = run_serve(Some(&obs));
    assert_eq!(
        plain, instrumented,
        "observability hooks perturbed the run: reports differ"
    );
    // and the instrumentation actually observed the run
    assert!(!obs.journal.is_empty(), "instrumented run journaled nothing");
    let rendered = obs.metrics.render_prometheus();
    assert!(
        rendered.contains("sgc_round_latency_seconds{job=\"0\",quantile=\"0.5\"}"),
        "missing per-job latency series:\n{rendered}"
    );
    assert!(rendered.contains("sgc_rounds_closed_total"), "{rendered}");
}

#[test]
fn journal_covers_the_round_lifecycle_and_roundtrips() {
    let obs = Arc::new(Obs::new());
    run_serve(Some(&obs));
    let events = obs.journal.snapshot();
    for kind in [
        EventKind::JobAdmit,
        EventKind::RoundAssign,
        EventKind::WorkerArrive,
        EventKind::CutDecision,
        EventKind::RoundClose,
        EventKind::JobDecode,
        EventKind::JobFinish,
        EventKind::QueueDepth,
        EventKind::TrueStragglers,
    ] {
        assert!(
            events.iter().any(|e| e.kind == kind),
            "no {:?} event journaled ({} events total)",
            kind,
            events.len()
        );
    }
    // timestamps ride the cluster clock: non-negative and non-absurd
    assert!(events.iter().all(|e| e.ts_s >= 0.0));
    // JSON round-trip preserves the event list
    let doc = Json::parse(&obs.journal.to_json().to_string()).expect("journal JSON parses");
    let back = events_from_json(&doc).expect("journal JSON decodes");
    assert_eq!(back.len(), events.len());
    assert!(back
        .iter()
        .zip(&events)
        .all(|(a, b)| a.kind == b.kind && a.job == b.job && a.round == b.round));
}

#[test]
fn chrome_trace_is_valid_and_spans_balance() {
    let obs = Arc::new(Obs::new());
    run_serve(Some(&obs));
    let trace = chrome_trace(&obs.journal.snapshot());
    // must parse back as JSON and carry a non-empty traceEvents array
    let doc = Json::parse(&trace.to_string()).expect("chrome trace parses");
    let events = doc
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .expect("traceEvents array");
    assert!(!events.is_empty());
    // B/E round spans must balance per pid; X spans must carry durations
    let mut open: std::collections::HashMap<i64, i64> = std::collections::HashMap::new();
    let mut complete_spans = 0usize;
    for e in events {
        let ph = e.get("ph").and_then(|p| p.as_str()).expect("ph");
        let pid = e.get("pid").and_then(|p| p.as_f64()).expect("pid") as i64;
        match ph {
            "B" => *open.entry(pid).or_insert(0) += 1,
            "E" => {
                let c = open.entry(pid).or_insert(0);
                *c -= 1;
                assert!(*c >= 0, "E without matching B on pid {pid}");
            }
            "X" => {
                complete_spans += 1;
                let dur = e.get("dur").and_then(|d| d.as_f64()).expect("dur");
                assert!(dur >= 0.0);
            }
            "i" | "M" => {}
            other => panic!("unexpected phase {other:?}"),
        }
    }
    assert!(open.values().all(|&c| c == 0), "unbalanced round spans: {open:?}");
    assert!(complete_spans > 0, "no worker task spans in the trace");
}

/// Scrape `/metrics` over a real TCP connection while the fleet's
/// reactor serves it — the endpoint shares the master's poll(2) loop,
/// so the scrape completes while the main thread pumps `poll`.
#[test]
fn fleet_reactor_serves_metrics_over_http() {
    let mut fleet = LoopbackFleet::spawn(2, None).expect("spawn");
    let obs = Arc::new(Obs::new());
    fleet.cluster.set_obs(obs.clone());
    let bound = fleet.cluster.serve_metrics("127.0.0.1:0").expect("bind metrics");
    {
        let mut sched = JobScheduler::new(&mut fleet.cluster);
        sched.set_obs(obs.clone());
        sched
            .admit(&JobSpec {
                scheme: SchemeConfig::gc(2, 1),
                session: SessionConfig { jobs: 4, ..Default::default() },
            })
            .expect("sizes match");
        sched.run().expect("fleet run completes");
    }
    let client = std::thread::spawn(move || {
        use std::io::{Read, Write};
        let mut s = std::net::TcpStream::connect(&bound).expect("connect scrape");
        s.write_all(b"GET /metrics HTTP/1.0\r\nHost: sgc\r\n\r\n").expect("send request");
        let mut out = String::new();
        s.read_to_string(&mut out).expect("read response");
        out
    });
    // the reactor serves the scrape from inside poll()
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while !client.is_finished() {
        assert!(std::time::Instant::now() < deadline, "scrape never completed");
        let now = fleet.cluster.now_s();
        let _ = fleet.cluster.poll(now + 0.05);
    }
    let resp = client.join().expect("client thread");
    assert!(resp.starts_with("HTTP/1.0 200"), "bad response head:\n{resp}");
    assert!(resp.contains("Content-Type: text/plain; version=0.0.4"), "{resp}");
    assert!(
        resp.contains("sgc_round_latency_seconds{job=\"0\",quantile=\"0.5\"}"),
        "missing p50 series:\n{resp}"
    );
    assert!(
        resp.contains("sgc_round_latency_seconds{job=\"0\",quantile=\"0.99\"}"),
        "missing p99 series:\n{resp}"
    );
    assert!(resp.contains("sgc_frame_bytes_in_total"), "{resp}");
    fleet.shutdown().expect("shutdown");
}
