//! Property/fuzz tests for the fleet wire codec: deterministic seeded
//! corpora of truncated, extended, bit-flipped and purely random byte
//! strings must decode to `Err`/`Ok`, never panic, hang, or over-read —
//! the master's reactor feeds attacker-controlled bytes straight into
//! these paths.

use sgc::fleet::wire::{GradUnit, TensorAssembly, MAX_TENSOR_FLOATS};
use sgc::fleet::{Frame, FrameBuffer};
use sgc::util::rng::Pcg32;

/// The valid-frame corpus the mutations start from — every v1 frame
/// plus the v2 gradient data-plane frames, with NaN/Inf/extreme payloads.
fn corpus() -> Vec<Frame> {
    vec![
        Frame::Hello { worker_id: 0 },
        Frame::Hello { worker_id: u32::MAX },
        Frame::Assign { round: 1, work_units: 0.25, chunks: vec![1, 2, 3] },
        Frame::Assign { round: u32::MAX, work_units: f64::MAX, chunks: vec![] },
        Frame::Assign { round: 7, work_units: -0.0, chunks: (0..64).collect() },
        Frame::Result { worker_id: 3, round: 9, compute_s: 0.001, checksum: u64::MAX },
        Frame::Result { worker_id: 0, round: 0, compute_s: f64::NAN, checksum: 0 },
        Frame::Heartbeat { worker_id: 12, round: 4096 },
        Frame::Shutdown,
        Frame::Error { code: 0, msg: String::new() },
        Frame::Error { code: u8::MAX, msg: "wire version 1 (expected 2)".into() },
        Frame::JobSpec { job: 0, input: 64, classes: 10, hidden1: 64, hidden2: 32 },
        Frame::JobSpec {
            job: u32::MAX,
            input: u32::MAX,
            classes: 0,
            hidden1: 1,
            hidden2: u32::MAX,
        },
        Frame::Partition { job: 1, chunk: 0, rows: 4, off: 0, total: 0, data: vec![] },
        Frame::Partition {
            job: 1,
            chunk: 3,
            rows: 2,
            off: 8,
            total: MAX_TENSOR_FLOATS,
            data: vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0, 1e-38, f32::MAX],
        },
        Frame::Params { job: 2, version: 1, off: 0, total: 3, data: vec![0.5, -0.5, 0.0] },
        Frame::Params {
            job: 2,
            version: u32::MAX,
            off: MAX_TENSOR_FLOATS,
            total: MAX_TENSOR_FLOATS,
            data: vec![],
        },
        Frame::GradAssign {
            job: 3,
            round: 9,
            param_version: 2,
            work_units: 0.125,
            units: vec![
                GradUnit::Plain { job: 0, chunk: 7 },
                GradUnit::Coded { job: 1, terms: vec![(0, f64::NAN), (3, f64::INFINITY)] },
                GradUnit::Coded { job: 2, terms: vec![] },
            ],
        },
        Frame::GradAssign {
            job: u32::MAX,
            round: u32::MAX,
            param_version: u32::MAX,
            work_units: f64::NEG_INFINITY,
            units: vec![],
        },
        Frame::GradResult {
            worker_id: 3,
            job: 1,
            round: 5,
            param_version: 2,
            compute_s: f64::NAN,
            off: 0,
            total: 4,
            data: vec![f32::NAN, -f32::INFINITY, 0.0, 2.5],
        },
        Frame::GradResult {
            worker_id: 0,
            job: 0,
            round: 0,
            param_version: 0,
            compute_s: 0.0,
            off: 0,
            total: 0,
            data: vec![],
        },
        // serving-loop control frames (ISSUE 10): every mutation suite
        // below also sweeps the admission path the job endpoint exposes
        Frame::Submit {
            name: "soak-w0-0".into(),
            scheme: "m-sgc:1,2,2".into(),
            session_jobs: 4,
            priority: 9,
        },
        Frame::Submit {
            name: String::new(),
            scheme: String::new(),
            session_jobs: 0,
            priority: 0,
        },
        Frame::Submit {
            name: "dup".into(),
            scheme: "gc:2".into(),
            session_jobs: u32::MAX,
            priority: u8::MAX,
        },
        Frame::Accepted { job: 0, queue_depth: 0 },
        Frame::Accepted { job: u32::MAX, queue_depth: u32::MAX },
        Frame::Rejected { reason: "queue full (max 64)".into() },
        Frame::Rejected { reason: String::new() },
    ]
}

/// Run one mutated byte string through every decode surface. Success is
/// simply "no panic, no over-read": `Frame::decode` and `read_frame` may
/// return any `Ok`/`Err`, and the incremental `FrameBuffer` must either
/// produce frames, ask for more bytes, or die with a framing error.
fn exercise_all_decoders(bytes: &[u8]) {
    let _ = Frame::decode(bytes);

    // blocking reader over the same bytes: drain until EOF or error
    let mut r = bytes;
    for _ in 0..bytes.len() + 1 {
        if sgc::fleet::wire::read_frame(&mut r).is_err() {
            break;
        }
    }

    // incremental reassembly, fed in two arbitrary halves
    let mid = bytes.len() / 2;
    let mut fb = FrameBuffer::new();
    fb.feed(&bytes[..mid]);
    loop {
        match fb.next_frame() {
            Ok(Some(_)) => continue,
            Ok(None) | Err(_) => break,
        }
    }
    fb.feed(&bytes[mid..]);
    loop {
        match fb.next_frame() {
            Ok(Some(_)) => continue,
            Ok(None) | Err(_) => break,
        }
    }
}

#[test]
fn truncations_never_panic() {
    for frame in corpus() {
        let bytes = frame.encode();
        for cut in 0..bytes.len() {
            exercise_all_decoders(&bytes[..cut]);
            // a strict prefix must never decode as a whole frame
            assert!(
                Frame::decode(&bytes[..cut]).is_err(),
                "truncated {frame:?} at {cut} decoded"
            );
        }
    }
}

#[test]
fn random_extensions_never_panic() {
    let mut rng = Pcg32::seeded(0x51ab);
    for frame in corpus() {
        let base = frame.encode();
        for extra in [1usize, 3, 8, 64] {
            let mut bytes = base.clone();
            for _ in 0..extra {
                bytes.push(rng.next_u32() as u8);
            }
            exercise_all_decoders(&bytes);
            // whole-buffer decode must reject the trailing garbage
            assert!(
                Frame::decode(&bytes).is_err(),
                "extended {frame:?} by {extra} decoded"
            );
        }
    }
}

#[test]
fn single_bit_flips_never_panic_or_over_read() {
    for frame in corpus() {
        let base = frame.encode();
        for byte in 0..base.len() {
            for bit in 0..8 {
                let mut bytes = base.clone();
                bytes[byte] ^= 1 << bit;
                exercise_all_decoders(&bytes);
            }
        }
    }
}

#[test]
fn random_byte_soup_never_panics() {
    let mut rng = Pcg32::seeded(0xbad_5009);
    for _ in 0..2000 {
        let len = rng.below(96);
        let bytes: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
        exercise_all_decoders(&bytes);
    }
}

#[test]
fn adversarial_length_prefixes_never_allocate_unboundedly() {
    let mut rng = Pcg32::seeded(0x1e47);
    // hand-crafted length prefixes around every boundary the codec checks
    let lens: Vec<u32> = vec![
        0,
        1,
        2,
        3,
        sgc::fleet::wire::MAX_FRAME_LEN - 1,
        sgc::fleet::wire::MAX_FRAME_LEN,
        sgc::fleet::wire::MAX_FRAME_LEN + 1,
        u32::MAX,
        rng.next_u32(),
        rng.next_u32(),
    ];
    for len in lens {
        let mut bytes = len.to_le_bytes().to_vec();
        // a short body regardless of the declared length
        for _ in 0..rng.below(16) {
            bytes.push(rng.next_u32() as u8);
        }
        exercise_all_decoders(&bytes);
    }
}

#[test]
fn tensor_header_mutations_never_allocate_unboundedly() {
    // mutate the off/total/float-count headers of every tensor-bearing
    // frame through hostile values; decode must reject (or produce a
    // harmless frame) without trusting the lying prefix
    let frames = vec![
        Frame::Partition { job: 1, chunk: 2, rows: 3, off: 0, total: 4, data: vec![1.0; 4] },
        Frame::Params { job: 1, version: 7, off: 0, total: 4, data: vec![1.0; 4] },
        Frame::GradResult {
            worker_id: 2,
            job: 1,
            round: 3,
            param_version: 7,
            compute_s: 0.01,
            off: 0,
            total: 4,
            data: vec![1.0; 4],
        },
    ];
    for frame in frames {
        let base = frame.encode();
        // the off/total/count words are the 12 bytes before the floats
        let data_off = base.len() - 4 * 4;
        for field in 0..3 {
            let at = data_off - 12 + 4 * field;
            for hostile in [5u32, 1000, MAX_TENSOR_FLOATS, MAX_TENSOR_FLOATS + 1, u32::MAX] {
                let mut bytes = base.clone();
                bytes[at..at + 4].copy_from_slice(&hostile.to_le_bytes());
                exercise_all_decoders(&bytes);
            }
        }
    }
}

#[test]
fn tensor_assembly_rejects_hostile_slices_without_overallocating() {
    // a lying `total` is clamped at construction: a hostile peer cannot
    // make the receiver reserve more than MAX_TENSOR_FLOATS
    let mut asm = TensorAssembly::new(u32::MAX);
    assert!(asm.accept(0, &[1.0, 2.0]).is_ok());
    // out-of-order and overlapping slices are framing errors
    let mut asm = TensorAssembly::new(8);
    assert!(asm.accept(4, &[0.0; 4]).is_err(), "out-of-order slice accepted");
    assert!(!asm.accept(0, &[0.0; 4]).unwrap());
    assert!(asm.accept(0, &[0.0; 4]).is_err(), "overlapping slice accepted");
    assert!(asm.accept(4, &[0.0; 8]).is_err(), "overrunning slice accepted");
    assert!(asm.accept(4, &[0.0; 4]).unwrap(), "completing slice rejected");
}

#[test]
fn grad_assign_term_mutations_never_panic() {
    let frame = Frame::GradAssign {
        job: 1,
        round: 2,
        param_version: 3,
        work_units: 0.5,
        units: vec![
            GradUnit::Coded { job: 0, terms: vec![(0, 1.0), (1, -1.0), (2, 0.5)] },
            GradUnit::Plain { job: 0, chunk: 9 },
        ],
    };
    let base = frame.encode();
    // walk a hostile u32 through every aligned offset of the body: this
    // sweeps the unit count, unit kinds, term counts and term chunks
    for at in (6..base.len() - 4).step_by(4) {
        for hostile in [0u32, 3, 255, 1 << 16, u32::MAX] {
            let mut bytes = base.clone();
            bytes[at..at + 4].copy_from_slice(&hostile.to_le_bytes());
            exercise_all_decoders(&bytes);
        }
    }
}

#[test]
fn submission_string_length_mutations_never_allocate_unboundedly() {
    use sgc::fleet::wire::{MAX_JOB_NAME, MAX_SUBMIT_SPEC};
    // mutate the name/scheme length words of a valid Submit through
    // hostile values; decode must reject without allocating `len` bytes
    let frame = Frame::Submit {
        name: "job-a".into(),
        scheme: "gc:2".into(),
        session_jobs: 2,
        priority: 5,
    };
    let base = frame.encode();
    // layout: 4 len + 1 ver + 1 tag, then name (u32 count + bytes),
    // scheme (u32 count + bytes), session_jobs u32, priority u8
    let name_at = 4 + 1 + 1;
    let scheme_at = name_at + 4 + "job-a".len();
    for (at, cap) in [(name_at, MAX_JOB_NAME), (scheme_at, MAX_SUBMIT_SPEC)] {
        for hostile in [cap as u32 + 1, 1 << 20, u32::MAX] {
            let mut bytes = base.clone();
            bytes[at..at + 4].copy_from_slice(&hostile.to_le_bytes());
            exercise_all_decoders(&bytes);
            assert!(
                Frame::decode(&bytes).is_err(),
                "hostile string length {hostile} at byte {at} decoded"
            );
        }
    }
}

#[test]
fn oversized_submission_strings_truncate_on_encode_and_stay_bounded() {
    use sgc::fleet::wire::{MAX_JOB_NAME, MAX_SUBMIT_SPEC};
    // a client shovelling a 4×-oversized name/spec must still produce a
    // bounded, decodable frame — the encoder truncates, the decoder
    // sees strings at exactly the caps
    let f = Frame::Submit {
        name: "n".repeat(MAX_JOB_NAME * 4),
        scheme: "s".repeat(MAX_SUBMIT_SPEC * 4),
        session_jobs: 1,
        priority: 1,
    };
    let bytes = f.encode();
    assert!(
        bytes.len() <= 4 + 2 + (4 + MAX_JOB_NAME) + (4 + MAX_SUBMIT_SPEC) + 4 + 1,
        "oversized Submit encoded to {} bytes",
        bytes.len()
    );
    match Frame::decode(&bytes).expect("truncated-on-encode Submit decodes") {
        Frame::Submit { name, scheme, session_jobs, priority } => {
            assert_eq!(name.len(), MAX_JOB_NAME);
            assert_eq!(scheme.len(), MAX_SUBMIT_SPEC);
            assert_eq!((session_jobs, priority), (1, 1));
        }
        other => panic!("decoded {other:?}"),
    }
    exercise_all_decoders(&bytes);
}

#[test]
fn duplicate_submissions_stream_cleanly_through_the_frame_buffer() {
    // the codec is policy-free: forty byte-identical Submit frames (the
    // same job name resubmitted over and over) must reassemble
    // one-for-one even when a slow sender splits the stream at
    // arbitrary chunk boundaries — duplicate handling is the serving
    // loop's job, never the decoder's
    let submit = Frame::Submit {
        name: "dup-job".into(),
        scheme: "gc:1".into(),
        session_jobs: 2,
        priority: 0,
    };
    let one = submit.encode();
    let mut stream = Vec::new();
    for _ in 0..40 {
        stream.extend_from_slice(&one);
    }
    let mut rng = Pcg32::seeded(0xd0b);
    let mut fb = FrameBuffer::new();
    let (mut fed, mut got) = (0usize, 0usize);
    while fed < stream.len() {
        let take = (1 + rng.below(23)).min(stream.len() - fed);
        fb.feed(&stream[fed..fed + take]);
        fed += take;
        while let Ok(Some(f)) = fb.next_frame() {
            assert_eq!(f, submit);
            got += 1;
        }
    }
    assert_eq!(got, 40, "frame buffer dropped or invented submissions");
}

#[test]
fn chunk_count_mutations_never_allocate_unboundedly() {
    // mutate the chunk-count field of a valid Assign through hostile
    // values; decode must reject without allocating `count` elements
    let frame = Frame::Assign { round: 2, work_units: 0.5, chunks: vec![9, 9, 9] };
    let base = frame.encode();
    // layout: 4 len + 1 ver + 1 tag + 4 round + 8 work_units, then count
    let count_off = 4 + 1 + 1 + 4 + 8;
    for hostile in [4u32, 5, 1000, 1 << 20, u32::MAX] {
        let mut bytes = base.clone();
        bytes[count_off..count_off + 4].copy_from_slice(&hostile.to_le_bytes());
        exercise_all_decoders(&bytes);
        assert!(
            Frame::decode(&bytes).is_err(),
            "hostile chunk count {hostile} decoded"
        );
    }
}
