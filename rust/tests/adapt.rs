//! Integration tests of the adaptive control plane (`sgc::adapt`)
//! through the public `JobScheduler` surface: determinism of swap
//! decisions, the stationary-profile no-swap golden, and the
//! regime-shift acceptance scenario (adaptive M-SGC beats the
//! statically-fit incumbent).

use sgc::adapt::AdaptiveConfig;
use sgc::cluster::{EventCluster, LatencyParams, SimCluster};
use sgc::coding::SchemeConfig;
use sgc::sched::{JobScheduler, JobSpec, ScheduleReport};
use sgc::session::SessionConfig;
use sgc::straggler::{NoStragglers, Pattern};

/// Scripted backend: quiet until `shift_at` cluster rounds, then a
/// persistent heavy regime (alternating straggle/clear rows keep each
/// burst at full severity; the long tail never wraps back into the
/// quiet prefix). Mirrors `sgc serve --regime-shift`.
fn regime_shift_sim(n: usize, shift_at: usize, seed: u64) -> SimCluster {
    let mut rows = vec![vec![false; n]; shift_at];
    for k in 0..4096usize {
        rows.push((0..n).map(|w| k % 2 == 0 && w % 3 == 0).collect());
    }
    SimCluster::from_trace(n, Pattern::from_rows(rows), seed)
}

fn serve_one(
    sim: &mut SimCluster,
    spec: &JobSpec,
    adaptive: Option<AdaptiveConfig>,
) -> ScheduleReport {
    let mut sched = JobScheduler::new(sim);
    if let Some(a) = adaptive {
        sched.set_adaptive(a);
    }
    sched.admit(spec).expect("admit");
    sched.run().expect("run")
}

/// Fixed seed + scripted regime shift ⇒ the whole `ScheduleReport` —
/// per-job reports, executed swaps, utilization — is identical across
/// repeated runs AND across event-batching settings (the controller
/// folds arrivals in worker-index order at round close, so how the
/// backend batches event delivery cannot change a swap decision).
#[test]
fn swap_decisions_are_deterministic_across_runs_and_event_batching() {
    let n = 8;
    let spec = JobSpec {
        scheme: SchemeConfig::gc(n, 1),
        session: SessionConfig { jobs: 60, ..Default::default() },
    };
    let run = |batch: Option<usize>| -> String {
        let mut sim = regime_shift_sim(n, 10, 42);
        if let Some(k) = batch {
            sim.set_max_events_per_poll(k);
        }
        let out = serve_one(&mut sim, &spec, Some(AdaptiveConfig::default()));
        assert!(
            !out.swaps.is_empty(),
            "the regime shift must trigger a hot-swap: {}",
            out.utilization
        );
        format!("{out:?}")
    };
    let reference = run(None);
    assert_eq!(reference, run(None), "identical runs must report identically");
    assert_eq!(reference, run(Some(1)), "event batching must not change swap decisions");
}

/// Golden: with adaptation ON over a stationary profile, the shift gate
/// holds — zero swaps, and the per-job reports are byte-identical to a
/// non-adaptive run of the same seed (the profiler is purely
/// observational; the background re-fit still runs).
#[test]
fn stationary_profile_never_swaps_and_matches_the_non_adaptive_run() {
    let n = 8;
    let seed = 3;
    let spec = JobSpec {
        scheme: SchemeConfig::gc(n, 1),
        session: SessionConfig { jobs: 40, ..Default::default() },
    };
    let quiet =
        || SimCluster::new(n, LatencyParams::default(), Box::new(NoStragglers { n }), seed);

    let mut plain_sim = quiet();
    let plain = serve_one(&mut plain_sim, &spec, None);
    let mut adapt_sim = quiet();
    let adapted = serve_one(&mut adapt_sim, &spec, Some(AdaptiveConfig::default()));

    assert_eq!(adapted.swaps.len(), 0, "stationary profile must never swap");
    assert_eq!(adapted.utilization.scheme_swaps, 0);
    assert!(
        adapted.utilization.refit_candidates > 0,
        "the background re-fit runs regardless: {}",
        adapted.utilization
    );
    assert_eq!(
        format!("{:?}", adapted.reports),
        format!("{:?}", plain.reports),
        "adaptation must be invisible without a swap"
    );
    assert_eq!(adapt_sim.now_s(), plain_sim.now_s(), "same cluster clock at run end");
}

/// The acceptance scenario: a statically-fit M-SGC keeps paying
/// straggler wait-outs after the regime shift, while the adaptive run
/// hot-swaps to a re-fitted scheme and finishes sooner — with the swap
/// visible in the `ScheduleReport`.
#[test]
fn adaptive_msgc_beats_statically_fit_msgc_after_a_regime_shift() {
    let n = 8;
    let spec = JobSpec {
        scheme: SchemeConfig::msgc(n, 1, 2, 1),
        session: SessionConfig { jobs: 100, ..Default::default() },
    };

    let mut static_sim = regime_shift_sim(n, 10, 42);
    let static_out = serve_one(&mut static_sim, &spec, None);
    let static_t = static_sim.now_s();

    let mut adapt_sim = regime_shift_sim(n, 10, 42);
    let adapt_out = serve_one(&mut adapt_sim, &spec, Some(AdaptiveConfig::default()));
    let adapt_t = adapt_sim.now_s();

    assert_eq!(static_out.swaps.len(), 0, "no control plane, no swaps");
    assert!(
        !adapt_out.swaps.is_empty(),
        "the swap must be visible in the report: {}",
        adapt_out.utilization
    );
    assert_eq!(adapt_out.utilization.scheme_swaps as usize, adapt_out.swaps.len());
    for sw in &adapt_out.swaps {
        assert_eq!(sw.job, 0);
        assert!(sw.predicted_gain > 0.0);
        assert_ne!(sw.from, sw.to);
    }
    assert!(
        adapt_t < static_t,
        "adaptive run must finish sooner: adaptive {adapt_t:.2}s vs static {static_t:.2}s"
    );

    // the merged report still accounts for every paper-job exactly once
    let rep = &adapt_out.reports[0];
    assert_eq!(rep.job_completion_s.len(), 100);
    assert!(rep.job_completion_s.iter().all(|t| t.is_finite()));
}
