//! Golden equivalence: the sans-IO `SgcSession` must reproduce the seed
//! master loop *bit for bit*.
//!
//! `reference_run` below is a frozen copy of the pre-session
//! `Master::run` + `decide_round` logic (the duplicated round loop the
//! session refactor deleted from the library). For every scheme kind, a
//! run driven through the new session on an identically-seeded cluster
//! must produce a byte-identical `RunReport` — same f64 bit patterns,
//! same round records, same patterns — which we check by comparing the
//! full `Debug` rendering.

use sgc::cluster::{Cluster, EventCluster, SimCluster};
use sgc::coding::{Scheme, SchemeConfig, ToleranceSpec};
use sgc::coordinator::{Master, RoundRecord, RunConfig, RunReport, WaitPolicy};
use sgc::straggler::{GilbertElliot, Pattern, ToleranceChecker};

struct RefDecision {
    responded: Vec<bool>,
    duration: f64,
    kappa: f64,
    detected: usize,
    admitted: usize,
}

/// Frozen copy of the seed `decide_round`.
#[allow(clippy::too_many_arguments)]
fn ref_decide(
    finish: &[f64],
    mu: f64,
    policy: WaitPolicy,
    checker: &ToleranceChecker,
    scheme: &dyn Scheme,
    r: usize,
    deadline_already_done: bool,
) -> RefDecision {
    let n = finish.len();
    let kappa = finish.iter().cloned().fold(f64::INFINITY, f64::min);
    let cutoff = (1.0 + mu) * kappa;
    let mut responded: Vec<bool> = finish.iter().map(|&f| f <= cutoff).collect();
    let detected = n - responded.iter().filter(|&&x| x).count();
    let mut duration = if detected == 0 {
        finish.iter().cloned().fold(0.0, f64::max)
    } else {
        cutoff
    };

    let mut pending: Vec<usize> = (0..n).filter(|&i| !responded[i]).collect();
    pending.sort_by(|&a, &b| finish[a].partial_cmp(&finish[b]).unwrap());
    let mut admitted = 0usize;
    let mut next = pending.into_iter();
    loop {
        let satisfied = match policy {
            WaitPolicy::WaitAll => responded.iter().all(|&x| x),
            WaitPolicy::ConformanceRepair => {
                let stragglers: Vec<bool> = responded.iter().map(|&x| !x).collect();
                checker.acceptable(&stragglers)
            }
            WaitPolicy::DeadlineDecode => match scheme.deadline_job(r) {
                Some(t) if !deadline_already_done => scheme.decodable_with(t, r, &responded),
                _ => true,
            },
        };
        if satisfied {
            break;
        }
        match next.next() {
            Some(w) => {
                responded[w] = true;
                duration = duration.max(finish[w]);
                admitted += 1;
            }
            None => break,
        }
    }

    if policy == WaitPolicy::ConformanceRepair {
        if let Some(t) = scheme.deadline_job(r) {
            if !deadline_already_done {
                let mut rest: Vec<usize> = (0..n).filter(|&i| !responded[i]).collect();
                rest.sort_by(|&a, &b| finish[a].partial_cmp(&finish[b]).unwrap());
                let mut rest = rest.into_iter();
                while !scheme.decodable_with(t, r, &responded) {
                    match rest.next() {
                        Some(w) => {
                            responded[w] = true;
                            duration = duration.max(finish[w]);
                            admitted += 1;
                        }
                        None => break,
                    }
                }
            }
        }
    }

    RefDecision { responded, duration, kappa, detected, admitted }
}

/// Frozen copy of the seed `Master::run` (with `measure_decode = false`,
/// `decode_in_idle = true`, so no wall-clock decode timing enters the
/// report and the comparison is fully deterministic).
fn reference_run(
    scheme_cfg: &SchemeConfig,
    jobs: usize,
    mu: f64,
    wait_policy: WaitPolicy,
    cluster: &mut dyn Cluster,
) -> RunReport {
    let mut scheme = scheme_cfg.build(jobs);
    let n = scheme.spec().n;
    assert_eq!(cluster.n(), n, "cluster/scheme size mismatch");
    let total_rounds = scheme.total_rounds();
    let wait_policy = if matches!(scheme.spec().tolerance, ToleranceSpec::None) {
        WaitPolicy::WaitAll
    } else {
        wait_policy
    };
    let mut checker = ToleranceChecker::new(n, scheme.spec().tolerance.clone());

    let mut clock = 0.0f64;
    let mut rounds = Vec::with_capacity(total_rounds);
    let mut job_done = vec![false; jobs];
    let mut job_completion = vec![f64::NAN; jobs];
    let mut frontier = 1usize;
    let mut violations = 0usize;
    let mut true_pattern = Pattern::new(n);
    let mut detected_pattern = Pattern::new(n);

    for r in 1..=total_rounds {
        let tasks = scheme.assign_round(r);
        let loads: Vec<f64> = tasks.iter().map(|t| scheme.spec().task_load(t)).collect();
        let sample = cluster.sample_round(&loads);
        true_pattern.push_round(sample.state.clone());

        let deadline_done = scheme.deadline_job(r).map(|t| job_done[t - 1]).unwrap_or(true);
        let decision = ref_decide(
            &sample.finish,
            mu,
            wait_policy,
            &checker,
            scheme.as_ref(),
            r,
            deadline_done,
        );
        let RefDecision { responded, duration, kappa, detected, admitted } = decision;
        detected_pattern
            .push_round(sample.finish.iter().map(|&f| f > (1.0 + mu) * kappa).collect());

        let effective_stragglers: Vec<bool> = responded.iter().map(|&x| !x).collect();
        checker.commit(&effective_stragglers);
        scheme.commit_round(r, &responded);

        let mut completed = Vec::new();
        for t in frontier..=jobs.min(r) {
            if job_done[t - 1] || !scheme.decodable(t) {
                continue;
            }
            job_done[t - 1] = true;
            completed.push(t);
        }
        while frontier <= jobs && job_done[frontier - 1] {
            frontier += 1;
        }
        clock += duration;
        for &t in &completed {
            job_completion[t - 1] = clock;
        }
        if let Some(t) = scheme.deadline_job(r) {
            if !job_done[t - 1] {
                violations += 1;
            }
        }
        rounds.push(RoundRecord {
            round: r,
            duration_s: duration,
            kappa_s: kappa,
            detected_stragglers: detected,
            waited_out: admitted,
            decode_s: 0.0,
            jobs_completed: completed,
        });
    }

    RunReport {
        scheme: scheme_cfg.label(),
        load: scheme_cfg.load(),
        delay: scheme_cfg.delay(),
        jobs,
        total_runtime_s: clock,
        rounds,
        job_completion_s: job_completion,
        deadline_violations: violations,
        true_pattern,
        effective_pattern: checker.pattern().clone(),
        detected_pattern,
    }
}

fn cluster(n: usize, seed: u64) -> SimCluster {
    SimCluster::from_gilbert_elliot(n, GilbertElliot::new(n, 0.06, 0.6, seed), seed ^ 0x5a)
}

#[test]
fn session_matches_reference_loop_byte_for_byte() {
    // Replication variants need their group size to divide n = 24:
    // gc-rep/sr-sgc-rep have (s+1) = 4 | 24, m-sgc-rep has (λ+1) = 6 | 24.
    let n = 24;
    let jobs = 30;
    let specs = [
        "gc:4",
        "gc-rep:3",
        "sr-sgc:1,2,6",
        "sr-sgc-rep:1,2,6",
        "m-sgc:1,2,6",
        "m-sgc-rep:1,2,5",
        "uncoded",
    ];
    for spec in specs {
        let cfg = SchemeConfig::parse(n, spec).unwrap();
        let reference = reference_run(
            &cfg,
            jobs,
            1.0,
            WaitPolicy::ConformanceRepair,
            &mut cluster(n, 11).sync(),
        );
        let mut master =
            Master::new(cfg, RunConfig { jobs, ..Default::default() });
        let session = master.run(&mut cluster(n, 11).sync()).unwrap();
        assert_eq!(
            format!("{reference:?}"),
            format!("{session:?}"),
            "{spec}: session-driven report diverged from the reference loop"
        );
    }
}

#[test]
fn session_matches_reference_under_deadline_decode() {
    let n = 16;
    let jobs = 25;
    for spec in ["gc:3", "m-sgc:1,2,4"] {
        let cfg = SchemeConfig::parse(n, spec).unwrap();
        let reference = reference_run(
            &cfg,
            jobs,
            1.0,
            WaitPolicy::DeadlineDecode,
            &mut cluster(n, 29).sync(),
        );
        let mut master = Master::new(
            cfg,
            RunConfig { jobs, wait_policy: WaitPolicy::DeadlineDecode, ..Default::default() },
        );
        let session = master.run(&mut cluster(n, 29).sync()).unwrap();
        assert_eq!(
            format!("{reference:?}"),
            format!("{session:?}"),
            "{spec}: deadline-decode report diverged from the reference loop"
        );
    }
}
