//! Chaos-matrix integration tests: every scripted fault kind, injected
//! into both execution backends (virtual-time simulator and loopback TCP
//! fleet), with the multi-job scheduler's failure domains absorbing the
//! blast — plus the same-seed determinism contract of the harness.

use sgc::chaos::{ChaosPlan, FaultKind};
use sgc::cluster::SimCluster;
use sgc::coding::SchemeConfig;
use sgc::fleet::LoopbackFleet;
use sgc::grad::{DataPlane, GradConfig, GradPump};
use sgc::sched::{JobScheduler, JobSpec, JobStatus, ScheduleReport};
use sgc::session::SessionConfig;
use sgc::straggler::GilbertElliot;
use std::time::Duration;

const KINDS: [&str; 6] = ["crash", "hang", "byz", "part", "rejoin", "shrink"];

/// One multi-job simulator run under the given chaos spec: 3 jobs of a
/// 1-straggler-tolerant GC scheme over 6 workers, fully virtual time.
fn sim_run(spec: &str, chaos_seed: u64) -> ScheduleReport {
    let n = 6;
    let plan = ChaosPlan::parse(spec, chaos_seed).expect("parse chaos spec").resolve(n);
    let mut sim =
        SimCluster::from_gilbert_elliot(n, GilbertElliot::default_fit(n, 21), 21 ^ 0xc1);
    sim.set_chaos(plan);
    let mut sched = JobScheduler::new(&mut sim);
    let spec = JobSpec {
        scheme: SchemeConfig::gc(n, 1),
        session: SessionConfig { jobs: 3, ..Default::default() },
    };
    for _ in 0..3 {
        sched.admit(&spec).expect("admit");
    }
    sched.run().expect("scheduler run survives scripted chaos")
}

#[test]
fn sim_matrix_every_fault_kind_is_absorbed_by_tolerance() {
    // gc(6, 1) tolerates one missing worker per round, so each
    // single-victim fault must leave all three jobs green — the fault is
    // absorbed by the code, not by retries.
    for kind in KINDS {
        let out = sim_run(&format!("{kind}@r4:w2"), 0xc405);
        assert_eq!(out.reports.len(), 3, "{kind}");
        assert!(!out.all_failed(), "{kind}: no job may fail");
        for (j, o) in out.outcomes.iter().enumerate() {
            assert_eq!(
                o.status,
                JobStatus::Completed,
                "{kind}: job {j} should complete under a tolerated fault: {o:?}"
            );
        }
        for (j, rep) in out.reports.iter().enumerate() {
            assert!(
                rep.job_completion_s.iter().all(|t| t.is_finite()),
                "{kind}: job {j} left undecoded paper-jobs"
            );
        }
    }
}

#[test]
fn sim_an_armed_but_unfired_plan_is_byte_identical_to_no_chaos() {
    // Per-job isolation rests on the harness being free until a fault
    // actually fires: an armed plan whose rounds never arrive must not
    // perturb a single service-time draw, so the whole report matches
    // the plain run byte for byte (the cluster-level RNG-parity pin is
    // `sim::tests::chaos_leaves_the_survivors_rng_stream_intact`).
    let plain = {
        let n = 6;
        let mut sim =
            SimCluster::from_gilbert_elliot(n, GilbertElliot::default_fit(n, 21), 21 ^ 0xc1);
        let mut sched = JobScheduler::new(&mut sim);
        let spec = JobSpec {
            scheme: SchemeConfig::gc(n, 1),
            session: SessionConfig { jobs: 3, ..Default::default() },
        };
        for _ in 0..3 {
            sched.admit(&spec).expect("admit");
        }
        sched.run().expect("plain run")
    };
    let chaotic = sim_run("crash@r999,shrink@r900:3", 0xc405);
    assert_eq!(
        format!("{plain:?}"),
        format!("{chaotic:?}"),
        "an unfired chaos plan must be invisible in the report"
    );
}

#[test]
fn sim_chaos_is_deterministic_for_a_fixed_seed() {
    // The whole report — per-round timings, retries, outcomes,
    // utilization counters — must be byte-identical across two runs with
    // the same chaos spec and seed, for every fault kind.
    for kind in KINDS {
        let spec = format!("{kind}@r3:w1,{kind}@r7");
        let a = sim_run(&spec, 7);
        let b = sim_run(&spec, 7);
        assert_eq!(
            format!("{a:?}"),
            format!("{b:?}"),
            "{kind}: same seed must reproduce the identical run"
        );
    }
}

#[test]
fn chaos_plan_resolution_is_a_pure_function_of_the_seed() {
    // A spec without an explicit :w picks its victim from the seed; the
    // plan (not the run) is where the nondeterminism would live, so pin
    // it at the plan level: resolution is a pure function of the seed.
    let spec = "crash@r2,hang@r5";
    let a = ChaosPlan::parse(spec, 1).unwrap().resolve(8);
    let b = ChaosPlan::parse(spec, 1).unwrap().resolve(8);
    assert_eq!(a, b, "same seed, same victims");
    let kinds: Vec<FaultKind> = a.faults.iter().map(|f| f.kind).collect();
    assert_eq!(kinds, [FaultKind::Crash, FaultKind::Hang]);
}

#[test]
fn sim_wait_all_jobs_degrade_in_isolation_instead_of_failing_the_run() {
    // Zero-tolerance (uncoded, wait-all) jobs cannot absorb a crashed
    // worker: the failure-domain machinery must retry, escalate each
    // affected job to degraded never-wait decode, and still finish the
    // run with an explicit error bound — never a scheduler error exit.
    let n = 4;
    let plan = ChaosPlan::parse("crash@r3:w1", 9).unwrap().resolve(n);
    let mut sim =
        SimCluster::from_gilbert_elliot(n, GilbertElliot::default_fit(n, 3), 3 ^ 0xc1);
    sim.set_chaos(plan);
    let out = {
        let mut sched = JobScheduler::new(&mut sim);
        let spec = JobSpec {
            scheme: SchemeConfig::uncoded(n),
            session: SessionConfig { jobs: 3, ..Default::default() },
        };
        sched.admit(&spec).expect("admit 0");
        sched.admit(&spec).expect("admit 1");
        sched.run().expect("run must survive a crash under wait-all")
    };
    assert!(!out.all_failed(), "degraded jobs are not failed jobs");
    assert!(
        out.outcomes.iter().any(|o| o.status == JobStatus::Degraded),
        "a wait-all job hit by the crash must end degraded: {:?}",
        out.outcomes
    );
    assert!(out.utilization.job_retries >= 1, "{}", out.utilization);
    assert!(out.utilization.degraded_rounds >= 1, "{}", out.utilization);
    // degraded reports advertise what is missing instead of inventing it
    for o in &out.outcomes {
        if o.status == JobStatus::Degraded {
            assert!(o.error_bound > 0.0 && o.error_bound <= 1.0, "{o:?}");
        }
    }
}

/// One multi-job loopback-fleet run under the given chaos spec: 2 jobs
/// of a 1-straggler-tolerant GC scheme over 4 real TCP workers, both
/// jobs on the real-gradient data plane — so every fault kind is also
/// exercised against partition shipping, param broadcast and coded
/// payload decode (byzantine in particular only manifests there: the
/// scripted liar sign-flips its gradient payloads and must be caught by
/// the code's redundancy, audited and retired).
fn fleet_run(spec: &str) -> ScheduleReport {
    let n = 4;
    let plan = ChaosPlan::parse(spec, 0xf1ee7).expect("parse chaos spec").resolve(n);
    let worker_plan = plan.clone();
    let mut fleet = LoopbackFleet::spawn_with(n, move |id, addr| {
        let mut cfg = sgc::fleet::WorkerConfig::loopback(id, addr.to_string(), None);
        cfg.fault = worker_plan.worker_fault(id as usize);
        cfg
    })
    .expect("spawn fleet");
    fleet.cluster.set_chaos(plan);
    // tight reaping so a hung worker is retired within the test budget
    fleet.cluster.set_membership(sgc::fleet::MembershipConfig {
        reap_after: Duration::from_secs(2),
        ..Default::default()
    });
    let mut pump = GradPump::new(
        DataPlane::shared(),
        GradConfig { seed: 0xf1ee7, batch: 64, train_size: 256, ..Default::default() },
    );
    fleet.cluster.set_dataplane(pump.dataplane());
    let out = {
        let mut sched = JobScheduler::new(&mut fleet.cluster);
        sched.set_dataplane(pump.dataplane());
        let spec = JobSpec {
            scheme: SchemeConfig::gc(n, 1),
            session: SessionConfig { jobs: 4, ..Default::default() },
        };
        let j0 = sched.admit(&spec).expect("admit 0");
        pump.configure_job(j0, &spec.scheme).expect("configure 0");
        let j1 = sched.admit(&spec).expect("admit 1");
        pump.configure_job(j1, &spec.scheme).expect("configure 1");
        sched.run_observed(&mut pump).expect("fleet run survives scripted chaos")
    };
    // drain stragglers' late results so workers are idle at Shutdown
    let _ = fleet.cluster.finish_trace(Duration::from_secs(5), 1.0);
    fleet.shutdown().expect("chaos workers still exit cleanly");
    out
}

#[test]
fn fleet_crash_is_absorbed_and_the_run_completes() {
    let out = fleet_run("crash@r3:w1");
    assert!(!out.all_failed());
    assert!(
        out.outcomes.iter().all(|o| o.status == JobStatus::Completed),
        "gc(4,1) tolerates the crashed worker: {:?}",
        out.outcomes
    );
    assert!(out.utilization.worker_retired_events >= 1, "{}", out.utilization);
}

#[test]
fn fleet_hang_is_absorbed_and_the_run_completes() {
    let out = fleet_run("hang@r3:w1");
    assert!(!out.all_failed());
    assert!(
        out.outcomes.iter().all(|o| o.status != JobStatus::Quarantined),
        "{:?}",
        out.outcomes
    );
    for rep in &out.reports {
        assert_eq!(rep.rounds.len(), 4, "every job's rounds must commit");
    }
}

#[test]
fn fleet_byzantine_worker_is_retired_and_the_run_completes() {
    let out = fleet_run("byz@r2:w2");
    assert!(!out.all_failed());
    assert!(
        out.outcomes.iter().all(|o| o.status == JobStatus::Completed),
        "{:?}",
        out.outcomes
    );
    // the corrupted gradient payloads failed the redundancy audit and
    // got the worker retired for good
    assert!(out.utilization.worker_retired_events >= 1, "{}", out.utilization);
}

#[test]
fn fleet_partition_heals_and_the_run_completes() {
    let out = fleet_run("part@r2:w0");
    assert!(!out.all_failed());
    assert!(
        out.outcomes.iter().all(|o| o.status != JobStatus::Quarantined),
        "{:?}",
        out.outcomes
    );
}

#[test]
fn fleet_shrink_retires_the_victim_and_the_run_completes() {
    let out = fleet_run("shrink@r2:w3");
    assert!(!out.all_failed());
    assert!(
        out.outcomes.iter().all(|o| o.status == JobStatus::Completed),
        "{:?}",
        out.outcomes
    );
    assert!(out.utilization.worker_retired_events >= 1, "{}", out.utilization);
}

#[test]
fn fleet_reconnect_rejoins_and_the_run_completes() {
    let out = fleet_run("rejoin@r2:w1");
    assert!(!out.all_failed());
    assert!(
        out.outcomes.iter().all(|o| o.status != JobStatus::Quarantined),
        "{:?}",
        out.outcomes
    );
}
