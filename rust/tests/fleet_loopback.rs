//! End-to-end loopback-fleet tests: real TCP workers on localhost, the
//! wall-clock μ-rule, chaos injection, and trace record/replay — the
//! acceptance scenario of the fleet subsystem.

use sgc::cluster::{EventCluster, RecordingCluster, RunTrace, SimCluster};
use sgc::coding::SchemeConfig;
use sgc::fleet::{drive_fleet, ChaosConfig, LoopbackFleet, WorkerConfig};
use sgc::session::{self, SessionConfig};
use sgc::straggler::GilbertElliot;

/// `sgc run --fleet 8 --jobs 20` with seeded chaos: completes all jobs,
/// applies the μ-rule from wall-clock arrivals, and its recorded trace
/// replays to the identical protocol outcome.
#[test]
fn fleet_8_workers_with_chaos_completes_and_replays() {
    let n = 8;
    let jobs = 20;
    let scheme = SchemeConfig::gc(n, 2);
    let cfg = SessionConfig { jobs, ..Default::default() };
    let mut fleet =
        LoopbackFleet::spawn(n, Some(ChaosConfig::default_fit(42))).expect("spawn fleet");
    let run = drive_fleet(&scheme, &cfg, &mut fleet.cluster).expect("fleet run");
    let stats = fleet.shutdown().expect("clean shutdown");

    // every job completed, zero deadline violations (ConformanceRepair)
    assert_eq!(run.report.rounds.len(), jobs, "GC has delay 0: J rounds");
    assert_eq!(run.report.deadline_violations, 0);
    assert!(run.report.job_completion_s.iter().all(|t| t.is_finite()));
    assert!(run.report.total_runtime_s > 0.0);
    // every worker served every round (cut stragglers still finish late)
    assert!(stats.iter().all(|s| s.rounds_served == jobs), "{stats:?}");

    // trace is complete: n × rounds finite wall-clock delays + states
    assert_eq!(run.trace.n, n);
    assert_eq!(run.trace.rounds(), jobs);
    assert!(run
        .trace
        .rounds
        .iter()
        .all(|r| r.finish.iter().all(|&f| f.is_finite() && f > 0.0)));
    let pattern = run.trace.pattern().expect("fleet trace records μ-detections");
    assert_eq!(pattern.rounds(), jobs);

    // JSON round-trip, then exact replay: identical responder sets,
    // durations and job completions per round.
    let trace = RunTrace::from_json(&run.trace.to_json()).expect("trace json");
    let replayed =
        session::drive(&scheme, &cfg, &mut trace.replay().sync()).expect("replay drive");
    assert_eq!(replayed.effective_pattern, run.report.effective_pattern);
    assert_eq!(replayed.detected_pattern, run.report.detected_pattern);
    assert_eq!(replayed.deadline_violations, run.report.deadline_violations);
    for (a, b) in replayed.rounds.iter().zip(&run.report.rounds) {
        assert_eq!(a.round, b.round);
        assert_eq!(a.jobs_completed, b.jobs_completed);
        assert_eq!(a.waited_out, b.waited_out);
        assert_eq!(a.detected_stragglers, b.detected_stragglers);
        // κ and the duration are pure functions of the recorded times
        assert_eq!(a.kappa_s, b.kappa_s, "round {}", a.round);
        assert_eq!(a.duration_s, b.duration_s, "round {}", a.round);
    }
    assert_eq!(replayed.total_runtime_s, run.report.total_runtime_s);
    assert_eq!(replayed.job_completion_s, run.report.job_completion_s);

    // the detected pattern is also loadable as a SimCluster trace
    let mut sim = SimCluster::from_trace(n, pattern.clone(), 7);
    let sim_report = session::drive(&scheme, &cfg, &mut sim.sync()).expect("sim replay");
    assert_eq!(
        sim_report.true_pattern.rows[..pattern.rounds().min(sim_report.true_pattern.rounds())],
        pattern.rows[..pattern.rounds().min(sim_report.true_pattern.rounds())],
        "SimCluster::from_trace replays the recorded straggler pattern"
    );
}

/// Two sessions multiplexed over ONE shared fleet through the
/// event-driven scheduler: wire-level sequence numbers route each
/// arrival back to the owning `(job, round)`, every worker serves both
/// jobs' every round, and both protocol runs complete.
#[test]
fn two_jobs_multiplex_over_one_fleet() {
    use sgc::sched::{JobScheduler, JobSpec};
    use std::time::Duration;

    let n = 4;
    let jobs = 6;
    let mut fleet =
        LoopbackFleet::spawn(n, Some(ChaosConfig::default_fit(5))).expect("spawn fleet");
    let spec = JobSpec {
        scheme: SchemeConfig::gc(n, 1),
        session: SessionConfig { jobs, ..Default::default() },
    };
    let out = {
        let mut sched = JobScheduler::new(&mut fleet.cluster);
        sched.admit(&spec).expect("admit job 0");
        sched.admit(&spec).expect("admit job 1");
        sched.run().expect("multiplexed fleet run")
    };
    // drain cut stragglers' late results so workers are idle at Shutdown
    let _ = fleet.cluster.finish_trace(Duration::from_secs(10), 1.0);
    let stats = fleet.shutdown().expect("clean shutdown");

    assert_eq!(out.reports.len(), 2);
    for rep in &out.reports {
        assert_eq!(rep.rounds.len(), jobs, "GC has delay 0: J rounds per job");
        assert_eq!(rep.deadline_violations, 0);
        assert!(rep.job_completion_s.iter().all(|t| t.is_finite()));
        assert!(rep.total_runtime_s > 0.0);
    }
    // both jobs' every round reached every worker (2 × jobs wire rounds)
    assert!(stats.iter().all(|s| s.rounds_served == 2 * jobs), "{stats:?}");
    assert_eq!(out.utilization.jobs, 2);
    assert_eq!(out.utilization.rounds, 2 * jobs);
    assert!(out.utilization.worker_done_events > 0);
}

/// Elastic membership end to end: a 4-worker fleet gains two late
/// joiners and loses one original worker mid-run; the scheduler
/// re-places the dead worker's logical slot onto a live spare, finishes
/// every job, and the report notes the membership churn.
#[test]
fn late_join_and_worker_death_are_absorbed() {
    use sgc::sched::{JobScheduler, JobSpec};
    use std::time::Duration;

    let n = 4;
    let jobs = 12;
    // worker 1 crashes (socket drop, no Shutdown handshake) after
    // serving 5 wire rounds; chaos off for determinism
    let mut fleet = LoopbackFleet::spawn_with(n, |id, addr| {
        let mut cfg = WorkerConfig::loopback(id, addr.to_string(), None);
        if id == 1 {
            cfg.fail_after_rounds = Some(5);
        }
        cfg
    })
    .expect("spawn fleet");
    // two late joiners under fresh ids: admitted inside the master's
    // event loop once the run is underway
    let addr = fleet.cluster.addr().to_string();
    fleet.join_worker(WorkerConfig::loopback(4, addr.clone(), None));
    fleet.join_worker(WorkerConfig::loopback(5, addr, None));

    let out = {
        let mut sched = JobScheduler::new(&mut fleet.cluster);
        sched
            .admit(&JobSpec {
                scheme: SchemeConfig::gc(n, 1),
                session: SessionConfig { jobs, ..Default::default() },
            })
            .expect("admit");
        sched.run().expect("elastic fleet run")
    };
    // drain stragglers' late results so workers are idle at Shutdown
    let _ = fleet.cluster.finish_trace(Duration::from_secs(10), 1.0);
    let stats = fleet.shutdown().expect("clean shutdown");

    let rep = &out.reports[0];
    assert_eq!(rep.rounds.len(), jobs);
    assert_eq!(rep.deadline_violations, 0);
    assert!(rep.job_completion_s.iter().all(|t| t.is_finite()));
    let u = &out.utilization;
    assert_eq!(u.worker_joined_events, 2, "{u}");
    assert!(u.worker_retired_events >= 1, "{u}");
    assert!(u.replacements >= 1, "the report must note the re-placement: {u}");
    // the crashed worker served exactly its configured 5 rounds
    assert_eq!(stats[1].rounds_served, 5, "{stats:?}");
    // the survivors served every submission they saw; at least one late
    // joiner picked up real work after the re-placement
    assert!(stats[0].rounds_served >= jobs, "{stats:?}");
    assert!(stats[4].rounds_served + stats[5].rounds_served > 0, "{stats:?}");
}

/// Rejoin replay: a worker that drops mid-round and reconnects under its
/// old id is re-sent every Assign it still owes a Result for, and the
/// replayed Result is absorbed against the original checksum log — the
/// open round completes with a genuine `WorkerDone` instead of eating a
/// μ-cut.
#[test]
fn rejoined_worker_receives_replayed_assigns() {
    use sgc::cluster::ClusterEvent;
    use std::time::{Duration, Instant};

    let mut fleet = LoopbackFleet::spawn_with(2, |id, addr| {
        let mut cfg = WorkerConfig::loopback(id, addr.to_string(), None);
        if id == 1 {
            // serve one round's Result, then drop the socket cold
            cfg.fail_after_rounds = Some(1);
        }
        cfg
    })
    .expect("spawn fleet");

    // Two back-to-back submissions: both Assigns reach worker 1's socket
    // buffer before it crashes, so it dies owing wire round 2 a Result.
    fleet.cluster.submit(0, 1, &[0.05, 0.05]);
    fleet.cluster.submit(0, 2, &[0.05, 0.05]);

    let deadline = Instant::now() + Duration::from_secs(30);
    let mut seen: Vec<ClusterEvent> = Vec::new();

    // the crash surfaces as WorkerRetired(1) plus the owed WorkerDead
    // for the still-open wire round 2
    while !seen
        .iter()
        .any(|e| matches!(e, ClusterEvent::WorkerDead { job: 0, round: 2, worker: 1 }))
    {
        assert!(Instant::now() < deadline, "worker 1's crash never surfaced: {seen:?}");
        let until = fleet.cluster.now_s() + 0.05;
        seen.extend(fleet.cluster.poll(until).iter().copied());
    }
    assert!(
        seen.iter().any(|e| matches!(e, ClusterEvent::WorkerRetired { worker: 1 })),
        "{seen:?}"
    );

    // rejoin under the SAME id: the master replays wire round 2's Assign
    // and the fresh worker's Result must absorb like the original would
    let addr = fleet.cluster.addr().to_string();
    fleet.join_worker(WorkerConfig::loopback(1, addr, None));
    let all_done = |seen: &[ClusterEvent]| {
        [(1u64, 0usize), (1, 1), (2, 0), (2, 1)].iter().all(|&(r, w)| {
            seen.iter().any(|e| {
                matches!(
                    e,
                    ClusterEvent::WorkerDone { round, worker, .. }
                        if *round == r && *worker == w
                )
            })
        })
    };
    while !all_done(&seen) {
        assert!(
            Instant::now() < deadline,
            "replayed Assign never produced round 2's WorkerDone: {seen:?}"
        );
        let until = fleet.cluster.now_s() + 0.05;
        seen.extend(fleet.cluster.poll(until).iter().copied());
    }
    assert!(
        seen.iter().any(|e| matches!(e, ClusterEvent::WorkerJoined { worker: 1 })),
        "{seen:?}"
    );
    let replayed = seen
        .iter()
        .find(|e| matches!(e, ClusterEvent::WorkerDone { round: 2, worker: 1, .. }))
        .expect("replayed WorkerDone");
    if let ClusterEvent::WorkerDone { job, finish_s, .. } = replayed {
        assert_eq!(*job, 0);
        assert!(finish_s.is_finite() && *finish_s > 0.0);
    }

    let stats = fleet.shutdown().expect("clean shutdown");
    // spawn order: worker 0 (both rounds), the original worker 1 (round
    // 1 only), the rejoined worker 1 (exactly the one replayed round)
    assert_eq!(stats[0].rounds_served, 2, "{stats:?}");
    assert_eq!(stats[1].rounds_served, 1, "{stats:?}");
    assert_eq!(stats[2].rounds_served, 1, "{stats:?}");
}

/// Acceptance pin of the reactor rewrite: one master — a single I/O
/// thread, no per-connection readers — holds a 64-worker loopback fleet
/// and completes a run. (The single-thread property is structural:
/// `FleetCluster` owns plain `Connection`s and spawns nothing; this
/// test exercises that architecture at a width the thread-per-socket
/// design made expensive.)
#[test]
fn fleet_64_workers_on_a_single_io_thread() {
    let n = 64;
    let jobs = 3;
    let scheme = SchemeConfig::gc(n, 7);
    let cfg = SessionConfig { jobs, ..Default::default() };
    let mut fleet = LoopbackFleet::spawn(n, None).expect("spawn 64 workers");
    let run = drive_fleet(&scheme, &cfg, &mut fleet.cluster).expect("fleet run");
    let stats = fleet.shutdown().expect("clean shutdown");
    assert_eq!(run.report.rounds.len(), jobs);
    assert_eq!(run.report.deadline_violations, 0);
    assert!(run.report.job_completion_s.iter().all(|t| t.is_finite()));
    assert_eq!(run.trace.n, n);
    assert!(stats.iter().all(|s| s.rounds_served == jobs), "{stats:?}");
}

/// Two fleets with the same chaos seed produce the same straggle/serve
/// counts — the reproducibility contract of seeded chaos injection.
#[test]
fn chaos_injection_is_reproducible_across_fleets() {
    let n = 4;
    let jobs = 8;
    let scheme = SchemeConfig::gc(n, 1);
    let cfg = SessionConfig { jobs, ..Default::default() };
    let run_once = || {
        let mut fleet =
            LoopbackFleet::spawn(n, Some(ChaosConfig::default_fit(123))).expect("spawn");
        let _ = drive_fleet(&scheme, &cfg, &mut fleet.cluster).expect("run");
        let stats = fleet.shutdown().expect("shutdown");
        stats.iter().map(|s| s.chaos_rounds).collect::<Vec<_>>()
    };
    assert_eq!(run_once(), run_once(), "same seed ⇒ same chaos schedule");
}

/// A recorded *simulator* run replays to an identical report through the
/// exact-replay cluster (the `--record-trace` / `--replay-trace` path).
#[test]
fn recorded_sim_run_replays_identically() {
    let n = 16;
    let scheme = SchemeConfig::parse(n, "m-sgc:1,2,3").unwrap();
    let cfg = SessionConfig { jobs: 15, ..Default::default() };
    let sim = SimCluster::from_gilbert_elliot(n, GilbertElliot::new(n, 0.07, 0.6, 3), 11);
    let mut rec = RecordingCluster::new(sim.sync());
    let original = session::drive(&scheme, &cfg, &mut rec).unwrap();
    let trace = rec.into_trace();

    // through JSON and back, then replayed
    let trace = RunTrace::from_json(&trace.to_json()).unwrap();
    let replayed = session::drive(&scheme, &cfg, &mut trace.replay().sync()).unwrap();
    assert_eq!(replayed.total_runtime_s, original.total_runtime_s);
    assert_eq!(replayed.job_completion_s, original.job_completion_s);
    assert_eq!(replayed.deadline_violations, original.deadline_violations);
    assert_eq!(replayed.true_pattern, original.true_pattern);
    assert_eq!(replayed.effective_pattern, original.effective_pattern);
    assert_eq!(replayed.detected_pattern, original.detected_pattern);
    for (a, b) in replayed.rounds.iter().zip(&original.rounds) {
        assert_eq!(a.duration_s, b.duration_s);
        assert_eq!(a.kappa_s, b.kappa_s);
        assert_eq!(a.jobs_completed, b.jobs_completed);
    }
}
