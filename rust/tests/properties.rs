//! Property-based tests of the paper's theorems over randomized
//! conforming straggler patterns (Prop 3.1, Prop 3.2, Appendix F/G).

use sgc::coding::{
    GcRepScheme, GcScheme, MSgcParams, MSgcScheme, Scheme, SrSgcParams, SrSgcScheme,
};
use sgc::straggler::generators::{gen_conforming, periodic_bursty, Model};
use sgc::straggler::{conforms_bursty, Pattern};
use sgc::testing::{check, Gen};

/// Drive a scheme over a fixed pattern; returns whether every job was
/// decodable at its deadline.
fn decodes_all(mut scheme: Box<dyn Scheme>, pattern: &Pattern) -> bool {
    let total = scheme.total_rounds();
    assert!(pattern.rounds() >= total, "pattern too short");
    let mut ok = true;
    for r in 1..=total {
        scheme.assign_round(r);
        let responded: Vec<bool> =
            (0..pattern.n).map(|i| !pattern.is_straggler(i, r)).collect();
        scheme.commit_round(r, &responded);
        if let Some(t) = scheme.deadline_job(r) {
            ok &= scheme.decodable(t);
        }
    }
    ok
}

#[test]
fn prop_gc_tolerates_s_per_round() {
    check("gc-s-per-round", 60, |g: &mut Gen| {
        let n = g.usize_in(3, 20);
        let s = g.usize_in(0, n - 1);
        let jobs = g.usize_in(1, 20);
        let pat = gen_conforming(n, jobs + 1, Model::PerRound { s }, 0.5, g.rng());
        assert!(
            decodes_all(Box::new(GcScheme::new(n, s, jobs)), &pat),
            "GC(n={n},s={s}) failed on conforming pattern"
        );
    });
}

#[test]
fn prop_3_1_sr_sgc_tolerates_bursty() {
    check("sr-sgc-bursty", 50, |g: &mut Gen| {
        let n = g.usize_in(4, 16);
        let b = g.usize_in(1, 3);
        let x = g.usize_in(1, 3);
        let w = x * b + 1;
        let lambda = g.usize_in(1, n);
        let p = SrSgcParams { n, b, w, lambda };
        if p.s() >= n {
            return;
        }
        let jobs = g.usize_in(1, 25);
        let pat = gen_conforming(
            n,
            jobs + b + 1,
            Model::Bursty { b, w, lambda },
            0.4,
            g.rng(),
        );
        assert!(
            decodes_all(Box::new(SrSgcScheme::new(p, jobs)), &pat),
            "SR-SGC{p:?} failed on conforming bursty pattern"
        );
    });
}

#[test]
fn prop_3_1_sr_sgc_tolerates_s_per_round_windows() {
    check("sr-sgc-per-round", 50, |g: &mut Gen| {
        let n = g.usize_in(4, 16);
        let b = g.usize_in(1, 3);
        let w = b + 1; // x = 1
        let lambda = g.usize_in(1, n);
        let p = SrSgcParams { n, b, w, lambda };
        if p.s() >= n {
            return;
        }
        let jobs = g.usize_in(1, 20);
        let pat =
            gen_conforming(n, jobs + b + 1, Model::PerRound { s: p.s() }, 0.5, g.rng());
        assert!(
            decodes_all(Box::new(SrSgcScheme::new(p, jobs)), &pat),
            "SR-SGC{p:?} failed on s-per-round pattern"
        );
    });
}

#[test]
fn prop_3_2_m_sgc_tolerates_bursty() {
    check("m-sgc-bursty", 50, |g: &mut Gen| {
        let n = g.usize_in(3, 12);
        let w = g.usize_in(2, 5);
        let b = g.usize_in(1, w - 1);
        let lambda = g.usize_in(0, n);
        let p = MSgcParams { n, b, w, lambda };
        let jobs = g.usize_in(1, 20);
        let pat = gen_conforming(
            n,
            jobs + p.delay() + 1,
            Model::Bursty { b, w, lambda },
            0.35,
            g.rng(),
        );
        assert!(
            decodes_all(Box::new(MSgcScheme::new(p, jobs)), &pat),
            "M-SGC{p:?} failed on conforming bursty pattern"
        );
    });
}

#[test]
fn prop_3_2_m_sgc_tolerates_arbitrary() {
    check("m-sgc-arbitrary", 50, |g: &mut Gen| {
        let n = g.usize_in(3, 12);
        let w = g.usize_in(2, 5);
        let b = g.usize_in(1, w - 1);
        let lambda = g.usize_in(0, n);
        let p = MSgcParams { n, b, w, lambda };
        let jobs = g.usize_in(1, 20);
        // (N = B, W' = W + B - 1, λ' = λ)-arbitrary
        let pat = gen_conforming(
            n,
            jobs + p.delay() + 1,
            Model::Arbitrary { n_limit: b, w: w + b - 1, lambda },
            0.35,
            g.rng(),
        );
        assert!(
            decodes_all(Box::new(MSgcScheme::new(p, jobs)), &pat),
            "M-SGC{p:?} failed on conforming arbitrary pattern"
        );
    });
}

#[test]
fn prop_m_sgc_survives_worst_case_periodic() {
    // The Appendix-F lower-bound pattern (Fig. 8) is tight for M-SGC:
    // the scheme must still decode every job at its deadline.
    check("m-sgc-worst-case", 30, |g: &mut Gen| {
        let n = g.usize_in(3, 10);
        let w = g.usize_in(2, 4);
        let b = g.usize_in(1, w - 1);
        let lambda = g.usize_in(0, n);
        let p = MSgcParams { n, b, w, lambda };
        let jobs = g.usize_in(5, 25);
        let pat = periodic_bursty(n, jobs + p.delay() + 1, b, w, lambda);
        assert!(conforms_bursty(&pat, b, w, lambda));
        assert!(
            decodes_all(Box::new(MSgcScheme::new(p, jobs)), &pat),
            "M-SGC{p:?} failed on the worst-case periodic pattern"
        );
    });
}

#[test]
fn prop_gc_rep_tolerates_one_survivor_per_group() {
    check("gc-rep-survivor", 40, |g: &mut Gen| {
        let groups = g.usize_in(1, 5);
        let s = g.usize_in(0, 4);
        let n = groups * (s + 1);
        let jobs = g.usize_in(1, 10);
        let mut scheme = GcRepScheme::new(n, s, jobs);
        for r in 1..=jobs {
            scheme.assign_round(r);
            // in each group, pick exactly one survivor at random
            let mut responded = vec![false; n];
            for grp in 0..groups {
                let survivor = grp * (s + 1) + g.usize_in(0, s);
                responded[survivor] = true;
            }
            scheme.commit_round(r, &responded);
            assert!(scheme.decodable(r), "n={n},s={s},r={r}");
        }
    });
}

#[test]
fn prop_gc_code_numeric_decode_over_random_subsets() {
    use sgc::coding::GcCode;
    check("gc-code-numeric", 25, |g: &mut Gen| {
        let n = g.usize_in(3, 24);
        let s = g.usize_in(0, (n - 1).min(8));
        let dim = g.usize_in(1, 12);
        let mut code = GcCode::new(n, s, 1234);
        // random partial gradients
        let partials: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| g.rng().normal() as f32).collect())
            .collect();
        let truth: Vec<f32> =
            (0..dim).map(|d| partials.iter().map(|p| p[d]).sum()).collect();
        let mut workers = g.rng().sample_indices(n, n - s);
        workers.sort_unstable(); // decode_coeffs' canonical (set-keyed) order
        let encoded: Vec<Vec<f32>> = workers
            .iter()
            .map(|&i| {
                let sup = sgc::coding::gc::cyclic_support(i, s, n);
                let refs: Vec<&[f32]> = sup.iter().map(|&c| partials[c].as_slice()).collect();
                code.encode(i, &refs)
            })
            .collect();
        let results: Vec<&[f32]> = encoded.iter().map(|e| e.as_slice()).collect();
        let decoded = code.decode(&workers, &results).expect("decodable");
        for (a, b) in decoded.iter().zip(&truth) {
            assert!((a - b).abs() < 5e-3 * (1.0 + b.abs()), "{a} vs {b} (n={n},s={s})");
        }
    });
}

#[test]
fn prop_m_sgc_round_load_never_exceeds_formula() {
    check("m-sgc-load-bound", 30, |g: &mut Gen| {
        let n = g.usize_in(3, 10);
        let w = g.usize_in(2, 5);
        let b = g.usize_in(1, w - 1);
        let lambda = g.usize_in(0, n);
        let p = MSgcParams { n, b, w, lambda };
        let jobs = g.usize_in(3, 15);
        let mut scheme = MSgcScheme::new(p, jobs);
        let spec = scheme.spec().clone();
        for r in 1..=scheme.total_rounds() {
            let tasks = scheme.assign_round(r);
            for t in &tasks {
                assert!(spec.task_load(t) <= spec.load + 1e-9);
            }
            // random responses (any pattern: load bound is unconditional)
            let responded: Vec<bool> = (0..n).map(|_| g.rng().chance(0.8)).collect();
            scheme.commit_round(r, &responded);
        }
    });
}

/// §Perf invariant: decode plans served by the process-wide
/// `CodePlanCache` are bit-identical to fresh, uncached solves of the
/// same `(n, s, responder set)` — sharing across sessions must be
/// observationally invisible.
#[test]
fn prop_cached_decode_plans_bit_identical_to_fresh_solves() {
    use sgc::coding::{CodePlanCache, GcCode, PLAN_SEED};
    use std::sync::Arc;
    check("plan-cache-bit-identical", 20, |g: &mut Gen| {
        let n = g.usize_in(4, 32);
        let s = g.usize_in(1, (n - 1).min(6));
        let plan = CodePlanCache::global().get(n, s);
        let mut fresh = GcCode::new(n, s, PLAN_SEED);
        // sorted responder sets: the canonical order every production
        // caller (session decode timer, trainer) uses
        let mut workers = g.rng().sample_indices(n, n - s);
        workers.sort_unstable();
        let cached = plan.decode_coeffs(&workers).expect("decodable whp");
        let direct = fresh.decode_coeffs(&workers).expect("decodable whp");
        assert_eq!(cached.len(), direct.len());
        for (a, b) in cached.iter().zip(direct) {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "cached plan diverged from fresh solve (n={n}, s={s})"
            );
        }
        // a second lookup is a pure cache hit on the same allocation
        let again = plan.decode_coeffs(&workers).unwrap();
        assert!(Arc::ptr_eq(&cached, &again));
    });
}

/// §Perf invariant: the 4-wide chunked f32 encode/decode kernels match a
/// scalar reference implementation within 1e-6 (elementwise axpy is in
/// fact bit-identical; the end-to-end encode accumulates s+1 terms).
#[test]
fn prop_chunked_f32_kernels_match_scalar_reference() {
    use sgc::coding::GcCode;
    use sgc::util::linalg;
    check("chunked-f32-kernels", 30, |g: &mut Gen| {
        // axpy vs scalar loop
        let len = g.usize_in(1, 200);
        let x: Vec<f32> = (0..len).map(|_| g.rng().normal() as f32).collect();
        let base: Vec<f32> = (0..len).map(|_| g.rng().normal() as f32).collect();
        let a = g.rng().normal() as f32;
        let mut chunked = base.clone();
        linalg::axpy_f32(&mut chunked, a, &x);
        for ((c, b), &xv) in chunked.iter().zip(&base).zip(&x) {
            let scalar = b + a * xv;
            assert!((c - scalar).abs() <= 1e-6 * (1.0 + scalar.abs()), "{c} vs {scalar}");
        }

        // GcCode::encode vs a scalar reference encode
        let n = g.usize_in(3, 16);
        let s = g.usize_in(0, (n - 1).min(4));
        let dim = g.usize_in(1, 40);
        let code = GcCode::new(n, s, 555);
        let row = g.usize_in(0, n - 1);
        let partials: Vec<Vec<f32>> = (0..=s)
            .map(|_| (0..dim).map(|_| g.rng().normal() as f32).collect())
            .collect();
        let refs: Vec<&[f32]> = partials.iter().map(|p| p.as_slice()).collect();
        let encoded = code.encode(row, &refs);
        for d in 0..dim {
            let mut scalar = 0.0f32;
            for (k, p) in partials.iter().enumerate() {
                let chunk = (row + k) % n;
                scalar += code.b[(row, chunk)] as f32 * p[d];
            }
            assert!(
                (encoded[d] - scalar).abs() <= 1e-6 * (1.0 + scalar.abs()),
                "encode[{d}] = {} vs scalar {scalar}",
                encoded[d]
            );
        }

        // chunked f64 dot vs a sequential sum
        let u: Vec<f64> = (0..len).map(|_| g.rng().normal()).collect();
        let v: Vec<f64> = (0..len).map(|_| g.rng().normal()).collect();
        let scalar: f64 = u.iter().zip(&v).map(|(p, q)| p * q).sum();
        assert!((linalg::dot(&u, &v) - scalar).abs() <= 1e-9 * (1.0 + scalar.abs()));
    });
}

/// Tentpole invariant of the event-driven redesign: one job driven by
/// the multi-tenant `JobScheduler` over the event-native `SimCluster`
/// (μ-rule pumped incrementally off the arrival stream, stragglers cut
/// as unboundedly-late) produces a **byte-identical** `RunReport` to the
/// classic blocking `session::drive` over the same simulator behind a
/// `SyncAdapter`.
#[test]
fn prop_scheduler_single_job_matches_drive() {
    use sgc::cluster::EventCluster;
    use sgc::cluster::SimCluster;
    use sgc::coding::SchemeConfig;
    use sgc::sched;
    use sgc::session::{self, SessionConfig};
    use sgc::straggler::GilbertElliot;

    check("scheduler-single-job-equivalence", 15, |g: &mut Gen| {
        let n = g.usize_in(6, 14);
        let spec =
            *g.rng().choose(&["gc:1", "gc:2", "m-sgc:1,2,2", "sr-sgc:1,2,2", "uncoded"]);
        let scheme = match SchemeConfig::parse(n, spec) {
            Ok(s) => s,
            Err(_) => return, // parameters invalid at this n; skip case
        };
        let jobs = g.usize_in(2, 12);
        let cfg = SessionConfig { jobs, ..Default::default() };
        let seed = g.rng().next_u64();
        let mk = || {
            SimCluster::from_gilbert_elliot(
                n,
                GilbertElliot::new(n, 0.08, 0.6, seed),
                seed ^ 0x33,
            )
        };
        let blocking = session::drive(&scheme, &cfg, &mut mk().sync()).unwrap();
        let scheduled = sched::drive_events(&scheme, &cfg, &mut mk()).unwrap();
        assert_eq!(
            format!("{blocking:?}"),
            format!("{scheduled:?}"),
            "{spec}: scheduler-driven report diverged from blocking drive (n={n})"
        );
    });
}

/// Multi-tenant determinism: two jobs multiplexed over one shared
/// simulator with a fixed seed reproduce byte-identical reports across
/// runs, and the outcome is invariant to how the backend batches event
/// delivery (one event per `poll` vs everything co-timed at once).
#[test]
fn prop_scheduler_two_jobs_deterministic_and_batching_invariant() {
    use sgc::cluster::SimCluster;
    use sgc::coding::SchemeConfig;
    use sgc::sched::{JobScheduler, JobSpec};
    use sgc::session::SessionConfig;
    use sgc::straggler::GilbertElliot;

    check("scheduler-two-job-determinism", 10, |g: &mut Gen| {
        let n = g.usize_in(6, 12);
        let jobs_a = g.usize_in(2, 8);
        let jobs_b = g.usize_in(2, 8);
        let seed = g.rng().next_u64();
        let run = |max_events_per_poll: usize| -> String {
            let mut sim = SimCluster::from_gilbert_elliot(
                n,
                GilbertElliot::new(n, 0.07, 0.6, seed),
                seed ^ 0x7a,
            );
            if max_events_per_poll > 0 {
                sim.set_max_events_per_poll(max_events_per_poll);
            }
            let mut sched = JobScheduler::new(&mut sim);
            sched
                .admit(&JobSpec {
                    scheme: SchemeConfig::gc(n, 1),
                    session: SessionConfig { jobs: jobs_a, ..Default::default() },
                })
                .unwrap();
            sched
                .admit(&JobSpec {
                    scheme: SchemeConfig::gc(n, 2),
                    session: SessionConfig { jobs: jobs_b, ..Default::default() },
                })
                .unwrap();
            let out = sched.run().unwrap();
            assert_eq!(out.reports.len(), 2);
            for rep in &out.reports {
                assert_eq!(rep.deadline_violations, 0);
                assert!(rep.job_completion_s.iter().all(|t| t.is_finite()));
            }
            format!("{:?}", out.reports)
        };
        let a = run(0);
        let b = run(0);
        assert_eq!(a, b, "fixed seed must reproduce the multi-job run (n={n})");
        let c = run(1);
        assert_eq!(a, c, "event-delivery batching leaked into the schedule (n={n})");
    });
}

/// Elastic-membership determinism: over a scripted backend whose joins,
/// retirements and completion times are pure functions of a generated
/// script, two scheduler runs deliver membership events in the *same
/// order* and produce byte-identical reports and re-placement counts —
/// membership churn must not introduce nondeterminism.
#[test]
fn prop_membership_event_ordering_deterministic() {
    use sgc::cluster::{ClusterEvent, EventCluster, JobId};
    use sgc::coding::SchemeConfig;
    use sgc::sched::{JobScheduler, JobSpec};
    use sgc::session::SessionConfig;

    /// Scripted elastic backend: at submission `t`, `joins`/`retires`
    /// with trigger `t` fire (a join admits a fresh id = the current
    /// capacity; a retire removes an initial worker that has just
    /// finished its last round). Completion times are a pure function
    /// of `(submission, worker)`.
    struct ElasticScript {
        cap: usize,
        clock: f64,
        submissions: u64,
        live: Vec<bool>,
        joins: Vec<u64>,
        retires: Vec<(u64, usize)>,
        staged: Vec<ClusterEvent>,
        buf: Vec<ClusterEvent>,
        membership_log: Vec<ClusterEvent>,
    }

    impl ElasticScript {
        fn new(n: usize, joins: Vec<u64>, retires: Vec<(u64, usize)>) -> Self {
            ElasticScript {
                cap: n,
                clock: 0.0,
                submissions: 0,
                live: vec![true; n],
                joins,
                retires,
                staged: Vec::new(),
                buf: Vec::new(),
                membership_log: Vec::new(),
            }
        }
    }

    impl EventCluster for ElasticScript {
        fn n(&self) -> usize {
            self.cap
        }

        fn now_s(&self) -> f64 {
            self.clock
        }

        fn submit(&mut self, job: JobId, round: u64, loads: &[f64]) {
            assert_eq!(loads.len(), self.cap);
            self.submissions += 1;
            for (worker, &load) in loads.iter().enumerate() {
                if load <= 0.0 {
                    continue; // spare / retired slot
                }
                assert!(self.live[worker], "scheduler placed load on a dead worker");
                // pure function of (submission, worker): reproducible
                let jitter = (self.submissions * 17 + worker as u64 * 31) % 13;
                let finish_s = 1.0 + jitter as f64 * 0.01;
                self.staged.push(ClusterEvent::WorkerDone { job, round, worker, finish_s });
            }
            // script fires after the submission's own completions
            for &at in &self.joins {
                if at == self.submissions {
                    self.live.push(true);
                    let worker = self.cap;
                    self.cap += 1;
                    let ev = ClusterEvent::WorkerJoined { worker };
                    self.staged.push(ev);
                    self.membership_log.push(ev);
                }
            }
            for &(at, worker) in &self.retires {
                if at == self.submissions && self.live[worker] {
                    self.live[worker] = false;
                    let ev = ClusterEvent::WorkerRetired { worker };
                    self.staged.push(ev);
                    self.membership_log.push(ev);
                }
            }
        }

        fn poll(&mut self, until_s: f64) -> &[ClusterEvent] {
            self.buf.clear();
            if self.staged.is_empty() {
                if until_s.is_finite() && until_s > self.clock {
                    self.clock = until_s;
                }
            } else {
                self.clock += 0.25;
                std::mem::swap(&mut self.buf, &mut self.staged);
            }
            &self.buf
        }

        fn true_state(&self, _job: JobId, _round: u64) -> Option<&[bool]> {
            None
        }
    }

    check("membership-ordering-determinism", 20, |g: &mut Gen| {
        let n = g.usize_in(3, 8);
        let rounds = g.usize_in(4, 10);
        let churn = g.usize_in(1, (n - 2).min(2));
        // each churn pair: a join at `j`, then the retirement of initial
        // worker `k` at or after `j` — so a live spare always exists by
        // the time the scheduler re-places the retiree's slot
        let mut joins = Vec::new();
        let mut retires = Vec::new();
        for k in 0..churn {
            let j = g.usize_in(1, rounds - 1) as u64;
            let r = g.usize_in(j as usize, rounds - 1) as u64;
            joins.push(j);
            retires.push((r, k));
        }
        let run = || {
            let mut cluster = ElasticScript::new(n, joins.clone(), retires.clone());
            let out = {
                let mut sched = JobScheduler::new(&mut cluster);
                sched
                    .admit(&JobSpec {
                        scheme: SchemeConfig::gc(n, 1),
                        session: SessionConfig { jobs: rounds, ..Default::default() },
                    })
                    .unwrap();
                sched.run().unwrap()
            };
            assert_eq!(out.reports[0].rounds.len(), rounds);
            assert_eq!(out.reports[0].deadline_violations, 0);
            assert_eq!(out.utilization.worker_retired_events as usize, retires.len());
            (
                format!("{:?}", out.reports),
                format!("{:?}", cluster.membership_log),
                out.utilization.replacements,
            )
        };
        let (rep_a, log_a, repl_a) = run();
        let (rep_b, log_b, repl_b) = run();
        assert_eq!(log_a, log_b, "membership-event order diverged (n={n})");
        assert_eq!(rep_a, rep_b, "reports diverged under membership churn (n={n})");
        assert_eq!(repl_a, repl_b, "re-placement counts diverged (n={n})");
    });
}

/// Satellite invariant behind the fleet's streaming driver: pushing the
/// same completion times through `submit` in *any* permutation (with
/// arbitrary idempotent re-submits sprinkled in) yields byte-identical
/// `close_round` events and an identical `RunReport` to `submit_all`.
#[test]
fn prop_submit_order_invariance() {
    use sgc::cluster::SimCluster;
    use sgc::coding::SchemeConfig;
    use sgc::session::{SessionConfig, SgcSession};
    use sgc::straggler::GilbertElliot;

    check("submit-order-invariance", 25, |g: &mut Gen| {
        let n = g.usize_in(6, 12);
        let spec = *g.rng().choose(&["gc:1", "m-sgc:1,2,2", "sr-sgc:1,2,2", "uncoded"]);
        let scheme = match SchemeConfig::parse(n, spec) {
            Ok(s) => s,
            Err(_) => return, // parameters invalid at this n; skip case
        };
        let jobs = g.usize_in(2, 10);
        let cfg = SessionConfig { jobs, ..Default::default() };
        let seed = g.rng().next_u64();
        let mut cluster = SimCluster::from_gilbert_elliot(
            n,
            GilbertElliot::new(n, 0.08, 0.6, seed),
            seed ^ 0x51,
        );

        let mut reference = SgcSession::new(&scheme, cfg.clone());
        let mut shuffled = SgcSession::new(&scheme, cfg);
        while !reference.is_complete() {
            let plan = reference.begin_round();
            let plan2 = shuffled.begin_round();
            assert_eq!(plan.round, plan2.round);
            let sample = cluster.sample_round(&plan.loads);

            reference.submit_all(&sample.finish);
            let expected = reference.close_round();

            let mut order: Vec<usize> = (0..n).collect();
            g.rng().shuffle(&mut order);
            for &w in &order {
                shuffled.submit(w, sample.finish[w]);
                if g.rng().chance(0.3) {
                    shuffled.submit(w, sample.finish[w]); // idempotent re-submit
                }
            }
            let got = shuffled.close_round();
            assert_eq!(got, expected, "events diverged in round {}", plan.round);
        }
        assert!(shuffled.is_complete());
        let a = reference.into_report();
        let b = shuffled.into_report();
        assert_eq!(a.total_runtime_s, b.total_runtime_s);
        assert_eq!(a.job_completion_s, b.job_completion_s);
        assert_eq!(a.deadline_violations, b.deadline_violations);
        assert_eq!(a.effective_pattern, b.effective_pattern);
        assert_eq!(a.detected_pattern, b.detected_pattern);
    });
}

/// Serving-loop invariant (ISSUE 10): a co-timed burst of submissions
/// is admitted deterministically and *activated* in priority-then-id
/// order, no matter how the cluster batches its event delivery. Runs
/// the same scripted burst with unbounded event batching and with
/// `set_max_events_per_poll(1)` (one event per poll) and demands
/// byte-identical verdicts, reports, and first-activation order.
#[test]
fn prop_serve_admission_order_priority_then_id_batching_invariant() {
    use sgc::cluster::SimCluster;
    use sgc::coding::SchemeConfig;
    use sgc::obs::{EventKind, Obs};
    use sgc::sched::{
        ArrivalAt, JobScheduler, JobSpec, NoopObserver, ScriptedSource, ServeConfig,
    };
    use sgc::session::SessionConfig;
    use sgc::straggler::GilbertElliot;
    use std::sync::Arc;

    check("serve-admission-order", 12, |g: &mut Gen| {
        let n = g.usize_in(6, 10);
        let k = g.usize_in(3, 6);
        let pris: Vec<u8> = (0..k).map(|_| g.usize_in(0, 4) as u8).collect();
        let seed = g.rng().next_u64();

        let run = |batch: usize| {
            let mut sim = SimCluster::from_gilbert_elliot(
                n,
                GilbertElliot::new(n, 0.05, 0.6, seed),
                seed ^ 0x21,
            );
            if batch > 0 {
                sim.set_max_events_per_poll(batch);
            }
            let obs = Arc::new(Obs::new());
            sim.set_obs(obs.clone());
            let mut src = ScriptedSource::new();
            for (i, &p) in pris.iter().enumerate() {
                src.submit_at(
                    ArrivalAt::Time(0.0),
                    &format!("burst-{i}"),
                    p,
                    JobSpec {
                        scheme: SchemeConfig::gc(n, 1),
                        session: SessionConfig { jobs: 2, ..Default::default() },
                    },
                );
            }
            // max_active 1 serialises activations, making the
            // priority-then-id activation order directly observable
            let cfg = ServeConfig { max_active: 1, ..Default::default() };
            let mut sched = JobScheduler::new(&mut sim);
            sched.set_obs(obs.clone());
            let out = sched.serve(&mut src, &cfg, &mut NoopObserver).unwrap();
            assert_eq!(out.reports.len(), k, "n={n} pris={pris:?}");
            let mut order: Vec<usize> = Vec::new();
            for e in obs.journal.snapshot() {
                if matches!(e.kind, EventKind::RoundAssign) {
                    let j = e.job as usize;
                    if e.job >= 0 && !order.contains(&j) {
                        order.push(j);
                    }
                }
            }
            (format!("{:?}", out.reports), format!("{:?}", src.verdicts), order)
        };

        let (rep_a, ver_a, ord_a) = run(0);
        // co-timed requests admit (and take job ids) in submission
        // order; activation is highest-priority first, ties by id
        let mut expect: Vec<usize> = (0..k).collect();
        expect.sort_by_key(|&j| (std::cmp::Reverse(pris[j]), j));
        assert_eq!(
            ord_a, expect,
            "activation order is not priority-then-id (pris {pris:?})"
        );

        let (rep_b, ver_b, ord_b) = run(1);
        assert_eq!(ord_a, ord_b, "event batching changed activation order");
        assert_eq!(ver_a, ver_b, "event batching changed admission verdicts");
        assert_eq!(rep_a, rep_b, "event batching leaked into the served schedule");
    });
}

/// Serving-loop invariant (ISSUE 10): preemption is safe. A low-
/// priority job that is preempted when the fleet shrinks below the
/// capacity budget, then resumed once the high-priority job drains,
/// ends with exactly the same completed-job ledger as an unpreempted
/// run of the same seed — every paper-job decoded, none lost or
/// duplicated across the banked segments.
#[test]
fn prop_serve_preemption_preserves_the_job_ledger() {
    use sgc::chaos::ChaosPlan;
    use sgc::cluster::{LatencyParams, SimCluster};
    use sgc::coding::SchemeConfig;
    use sgc::sched::{
        ArrivalAt, JobScheduler, JobSpec, JobStatus, NoopObserver, ScriptedSource,
        ServeConfig,
    };
    use sgc::session::SessionConfig;
    use sgc::straggler::NoStragglers;

    check("serve-preemption-safety", 10, |g: &mut Gen| {
        let n = 8;
        let jobs = g.usize_in(5, 8);
        let shrink_at = g.usize_in(3, 5);
        let seed = g.rng().next_u64();
        let spec = JobSpec {
            scheme: SchemeConfig::gc(n, 4),
            session: SessionConfig { jobs, ..Default::default() },
        };

        let run = |preempt: bool| {
            let mut sim =
                SimCluster::new(n, LatencyParams::default(), Box::new(NoStragglers { n }), seed);
            if preempt {
                // retire half the fleet mid-stream: two co-active n=8
                // jobs (demand 16) overrun budget 2.0 × 4 = 8
                let plan = ChaosPlan::parse(&format!("shrink@r{shrink_at}:4"), seed ^ 0x7e)
                    .unwrap()
                    .resolve(n);
                sim.set_chaos(plan);
            }
            let mut src = ScriptedSource::new();
            src.submit_at(ArrivalAt::Time(0.0), "hi", 9, spec.clone());
            src.submit_at(ArrivalAt::Time(0.0), "lo", 1, spec.clone());
            let cfg = ServeConfig { oversub: 2.0, ..Default::default() };
            let mut sched = JobScheduler::new(&mut sim);
            sched.serve(&mut src, &cfg, &mut NoopObserver).unwrap()
        };

        let base = run(false);
        let out = run(true);
        assert_eq!(base.utilization.preemptions, 0);
        assert!(
            out.utilization.preemptions >= 1,
            "shrink@r{shrink_at} with jobs={jobs} never preempted: {}",
            out.utilization
        );

        // ledger equality: same job count, same per-job completed
        // ledger length, everything decoded, in both runs
        assert_eq!(base.reports.len(), out.reports.len());
        for ((bo, br), (oo, or)) in base
            .outcomes
            .iter()
            .zip(&base.reports)
            .zip(out.outcomes.iter().zip(&out.reports))
        {
            assert_eq!(bo.status, JobStatus::Completed, "job {}", bo.job);
            assert_eq!(oo.status, JobStatus::Completed, "job {} (preempted run)", oo.job);
            assert_eq!(
                br.job_completion_s.len(),
                or.job_completion_s.len(),
                "job {}: preemption changed the ledger length",
                bo.job
            );
            assert_eq!(or.job_completion_s.len(), jobs);
            assert!(br.job_completion_s.iter().all(|t| t.is_finite()));
            assert!(
                or.job_completion_s.iter().all(|t| t.is_finite()),
                "job {}: preempted run lost a paper-job",
                oo.job
            );
            assert_eq!(br.deadline_violations, or.deadline_violations);
        }
    });
}

/// Serving-loop invariant (ISSUE 10): backpressure is monotone in
/// offered load. At a fixed `max_queue` capacity, submitting more
/// co-timed jobs never *reduces* the number of rejections, and the
/// shed count is exactly `offered − min(offered, max_queue)`.
#[test]
fn prop_serve_backpressure_monotone_in_offered_load() {
    use sgc::cluster::{LatencyParams, SimCluster};
    use sgc::coding::SchemeConfig;
    use sgc::sched::{
        ArrivalAt, JobScheduler, JobSpec, NoopObserver, ScriptedSource, ServeConfig,
    };
    use sgc::session::SessionConfig;
    use sgc::straggler::NoStragglers;

    check("serve-backpressure-monotone", 12, |g: &mut Gen| {
        let n = 6;
        let q = g.usize_in(1, 4);
        let seed = g.rng().next_u64();

        let rejections = |offered: usize| {
            let mut sim =
                SimCluster::new(n, LatencyParams::default(), Box::new(NoStragglers { n }), seed);
            let mut src = ScriptedSource::new();
            for i in 0..offered {
                src.submit_at(
                    ArrivalAt::Time(0.0),
                    &format!("load-{i}"),
                    0,
                    JobSpec {
                        scheme: SchemeConfig::gc(n, 1),
                        session: SessionConfig { jobs: 2, ..Default::default() },
                    },
                );
            }
            let cfg = ServeConfig { max_queue: q, ..Default::default() };
            let mut sched = JobScheduler::new(&mut sim);
            let out = sched.serve(&mut src, &cfg, &mut NoopObserver).unwrap();
            assert_eq!(
                out.utilization.jobs_rejected as usize,
                src.rejected(),
                "utilization disagrees with delivered verdicts"
            );
            assert_eq!(src.accepted() + src.rejected(), offered);
            src.rejected()
        };

        let lo = g.usize_in(0, 8);
        let hi = lo + g.usize_in(0, 6);
        let r_lo = rejections(lo);
        let r_hi = rejections(hi);
        // exact shedding for a co-timed burst against an idle loop …
        assert_eq!(r_lo, lo.saturating_sub(q), "offered={lo} max_queue={q}");
        assert_eq!(r_hi, hi.saturating_sub(q), "offered={hi} max_queue={q}");
        // … hence rejections are nondecreasing in offered load
        assert!(
            r_hi >= r_lo,
            "rejections fell from {r_lo} to {r_hi} as load rose {lo}→{hi} (q={q})"
        );
    });
}
