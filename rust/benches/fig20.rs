//! Fig. 20 / Appendix L: the ResNet-18-on-CIFAR-100 analog — 22.5 MB
//! gradients must go through shared storage (EFS model), fattening the
//! completion-time tail; μ = 5; 1000 rounds, 4 models.
//!
//! Expected shape: M-SGC ≈ 11.6% faster than GC, ≈ 21.5% faster than
//! uncoded.

use sgc::cluster::StorageParams;
use sgc::coordinator::{Master, RunConfig};
use sgc::experiments::{fast_mode, save_json, PaperSetup, TablePrinter};
use sgc::util::json::Json;
use sgc::util::stats::MeanStd;

fn main() {
    let base = PaperSetup::table1();
    let jobs = if fast_mode() { 60 } else { 1000 };
    let reps = if fast_mode() { 2 } else { 5 };
    let mu = 5.0; // Appendix L: higher variance needs a looser cutoff
    println!(
        "== Fig 20: ResNet-18/CIFAR-100 analog over shared storage (n={}, J={jobs}, μ={mu}) ==\n",
        base.n
    );
    let t = TablePrinter::new(
        &["Scheme", "Params", "Load", "Run Time (s)"],
        &[10, 22, 9, 24],
    );
    let mut json = Json::obj();
    let mut results = Vec::new();
    for (name, scheme) in base.table1_schemes() {
        let xs: Vec<f64> = (0..reps)
            .map(|r| {
                let mut master = Master::new(
                    scheme.clone(),
                    RunConfig { jobs, mu, ..Default::default() },
                );
                let mut cluster =
                    base.cluster(5000 + r as u64).with_storage(StorageParams::resnet18_efs());
                master.run_events(&mut cluster).expect("sizes match").total_runtime_s
            })
            .collect();
        let stats = MeanStd::of(&xs);
        t.row(&[
            name.to_string(),
            scheme.label(),
            format!("{:.3}", scheme.load()),
            format!("{:.0} ± {:.0}", stats.mean, stats.std),
        ]);
        let mut o = Json::obj();
        o.set("load", scheme.load())
            .set("runtime_mean_s", stats.mean)
            .set("runtime_std_s", stats.std);
        json.set(name, o);
        results.push((name, stats.mean));
    }
    save_json("fig20", &json);
    let get = |n: &str| results.iter().find(|(k, _)| *k == n).unwrap().1;
    println!("\nshape checks:");
    println!("  M-SGC vs GC:      {:+.1}% (paper: -11.6%)", 100.0 * (get("M-SGC") - get("GC")) / get("GC"));
    println!("  M-SGC vs uncoded: {:+.1}% (paper: -21.5%)", 100.0 * (get("M-SGC") - get("No Coding")) / get("No Coding"));
    assert!(get("M-SGC") < get("GC"));
    assert!(get("M-SGC") < get("No Coding"));
}
