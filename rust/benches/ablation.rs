//! Ablations of the design choices DESIGN.md calls out:
//!
//! 1. **Wait-out policy** — Remark-2.3 conformance repair vs lazy
//!    deadline-decode waiting vs wait-all.
//! 2. **Decode-coefficient memoization** — the L3 hot-path cache.
//! 3. **GC vs GC-Rep base** (Appendix G) — same load, different straggler
//!    sets tolerated.
//! 4. **Within-burst severity decay** — the latency-model assumption the
//!    Table-1 calibration rests on.

use sgc::bench_harness::Bench;
use sgc::cluster::{LatencyParams, SimCluster};
use sgc::coding::{GcCode, SchemeConfig};
use sgc::coordinator::{Master, RunConfig, WaitPolicy};
use sgc::experiments::{fast_mode, save_json, PaperSetup};
use sgc::straggler::GilbertElliot;
use sgc::util::json::Json;
use sgc::util::rng::Pcg32;

fn main() {
    let setup = PaperSetup::table1();
    let jobs = if fast_mode() { 40 } else { 240 };
    let mut json = Json::obj();

    // --- 1. wait policy --------------------------------------------------
    println!("== ablation 1: wait-out policy (m-sgc(1,2,λ), n={}) ==", setup.n);
    let lam = (setup.n / 10).max(2);
    let scheme = SchemeConfig::msgc(setup.n, 1, 2, lam);
    let mut pol_json = Json::obj();
    for (name, policy) in [
        ("conformance-repair", WaitPolicy::ConformanceRepair),
        ("deadline-decode", WaitPolicy::DeadlineDecode),
        ("wait-all", WaitPolicy::WaitAll),
    ] {
        let mut master = Master::new(
            scheme.clone(),
            RunConfig { jobs, wait_policy: policy, ..Default::default() },
        );
        let mut cluster = setup.cluster(71);
        let rep = master.run_events(&mut cluster).expect("sizes match");
        println!(
            "  {name:<20} runtime {:>8.1}s  waitouts {:>4}  violations {}",
            rep.total_runtime_s,
            rep.waitout_rounds(),
            rep.deadline_violations
        );
        let mut o = Json::obj();
        o.set("runtime_s", rep.total_runtime_s)
            .set("waitouts", rep.waitout_rounds())
            .set("violations", rep.deadline_violations);
        pol_json.set(name, o);
    }
    json.set("wait_policy", pol_json);

    // --- 2. decode-coefficient cache --------------------------------------
    println!("\n== ablation 2: decode-coefficient memoization (n=256, s=15) ==");
    let mut b = Bench::new("ablation-decode-cache");
    let n = 256;
    let s = 15;
    let mut rng = Pcg32::seeded(5);
    // GE-like repeating straggler sets: high cache-hit regime (sorted:
    // decode_coeffs' canonical set-keyed order)
    let subsets: Vec<Vec<usize>> = (0..8)
        .map(|_| {
            let mut sub = rng.sample_indices(n, n - s);
            sub.sort_unstable();
            sub
        })
        .collect();
    {
        let mut code = GcCode::new(n, s, 7);
        let mut i = 0;
        b.run("with-cache(8 repeating patterns)", || {
            let _ = code.decode_coeffs(&subsets[i % 8]).unwrap();
            i += 1;
        });
    }
    {
        let mut i = 0;
        b.run("no-cache(fresh code each call)", || {
            let mut code = GcCode::new(n, s, 7);
            let _ = code.decode_coeffs(&subsets[i % 8]).unwrap();
            i += 1;
        });
    }

    // --- 3. GC vs GC-Rep --------------------------------------------------
    println!("\n== ablation 3: GC vs GC-Rep base (same load) ==");
    let n3 = if setup.n % 16 == 0 { setup.n } else { 64 };
    let s3 = 15; // (s+1)=16 divides n3
    let mut rep_json = Json::obj();
    for (name, cfg) in [
        ("gc", SchemeConfig::gc(n3, s3)),
        ("gc-rep", SchemeConfig::gc_rep(n3, s3)),
    ] {
        let xs: Vec<f64> = (0..3)
            .map(|r| {
                let mut master =
                    Master::new(cfg.clone(), RunConfig { jobs, ..Default::default() });
                let mut cluster = setup.cluster(900 + r);
                master.run_events(&mut cluster).expect("sizes match").total_runtime_s
            })
            .collect();
        let m = sgc::util::stats::mean(&xs);
        println!("  {name:<8} load {:.4}  runtime {m:>8.1}s", cfg.load());
        let mut o = Json::obj();
        o.set("load", cfg.load()).set("runtime_s", m);
        rep_json.set(name, o);
    }
    json.set("gc_vs_gc_rep", rep_json);

    // --- 4. severity decay ------------------------------------------------
    println!("\n== ablation 4: within-burst severity decay ==");
    let mut decay_json = Json::obj();
    for decay in [1.0, 0.45, 0.1] {
        let latency = LatencyParams { straggle_decay: decay, ..Default::default() };
        let mut runtimes = Vec::new();
        for (label, cfg) in [
            ("m-sgc", SchemeConfig::msgc(setup.n, 1, 2, (setup.n / 10).max(2))),
            ("gc", SchemeConfig::gc(setup.n, (setup.n / 17).max(2))),
        ] {
            let mut master = Master::new(cfg, RunConfig { jobs, ..Default::default() });
            let mut cluster = SimCluster::new(
                setup.n,
                latency.clone(),
                Box::new(GilbertElliot::default_fit(setup.n, 7)),
                55,
            );
            let rep = master.run_events(&mut cluster).expect("sizes match");
            runtimes.push((label, rep.total_runtime_s));
        }
        let msgc = runtimes[0].1;
        let gc = runtimes[1].1;
        println!(
            "  decay={decay:<5} m-sgc {msgc:>8.1}s  gc {gc:>8.1}s  ratio {:.2}",
            msgc / gc
        );
        let mut o = Json::obj();
        o.set("m_sgc_s", msgc).set("gc_s", gc).set("ratio", msgc / gc);
        decay_json.set(&format!("{decay}"), o);
    }
    json.set("severity_decay", decay_json);
    println!("  (decay=1: burst continuers stay slow → M-SGC's B=1 wait-outs erase its load win)");

    save_json("ablation", &json);
    b.save();
}
