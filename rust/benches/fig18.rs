//! Fig. 18 / Appendix K.2: training starts *uncoded*, and after
//! `T_probe = 40` rounds the master selects coding parameters from the
//! observed delay profile and switches to coded mode. Reports the
//! completed-jobs-vs-time curve for each scheme family plus the search
//! cost, and checks the coded phase outpaces the uncoded phase.

use sgc::coding::SchemeConfig;
use sgc::coordinator::{Master, RunConfig};
use sgc::experiments::{fast_mode, save_json, PaperSetup};
use sgc::probe::{grid_search, DelayProfile, SearchSpace};
use sgc::util::json::Json;
use sgc::util::timer::Stopwatch;

fn main() {
    let setup = PaperSetup::table1();
    let t_probe = if fast_mode() { 15 } else { 40 };
    let jobs_after = setup.jobs.saturating_sub(t_probe);
    println!(
        "== Fig 18: uncoded→coded switch after T_probe={t_probe} rounds (n={}) ==\n",
        setup.n
    );

    // Phase 1: uncoded probing (shared across schemes, same seed).
    let mut probe_master = Master::new(
        SchemeConfig::uncoded(setup.n),
        RunConfig { jobs: t_probe, ..Default::default() },
    );
    let mut cluster = setup.cluster(777);
    let probe_report = probe_master.run_events(&mut cluster).expect("sizes match");
    let probe_time = probe_report.total_runtime_s;
    // reuse the measured per-round times as the reference profile
    let profile = DelayProfile {
        n: setup.n,
        base_load: 1.0 / setup.n as f64,
        times: std::sync::Arc::new({
            // re-simulate the same rounds for per-worker times
            let mut c2 = setup.cluster(777);
            (0..t_probe).map(|_| c2.sample_round(&vec![1.0 / setup.n as f64; setup.n]).finish).collect()
        }),
    };
    let alpha = cluster.latency.alpha_s_per_load;
    println!("probe phase: {t_probe} uncoded rounds in {probe_time:.1}s\n");

    let space = SearchSpace::paper_default(setup.n);
    let mut json = Json::obj();
    json.set("t_probe", t_probe).set("probe_time_s", probe_time);
    println!(
        "{:<10} {:<18} {:>12} {:>14} {:>14}",
        "family", "selected", "search (s)", "coded (s)", "total (s)"
    );
    let mut totals = Vec::new();
    for (fam, cands) in [
        ("M-SGC", space.m_sgc_candidates()),
        ("SR-SGC", space.sr_sgc_candidates()),
        ("GC", space.gc_candidates()),
        ("uncoded", vec![SchemeConfig::uncoded(setup.n)]),
    ] {
        let sw = Stopwatch::start();
        let ranked = grid_search(&cands, &profile, alpha, t_probe.min(30));
        let search_s = sw.elapsed_s();
        let best = ranked[0].config.clone();
        // Phase 2: run the remaining jobs coded.
        let mut master =
            Master::new(best.clone(), RunConfig { jobs: jobs_after, ..Default::default() });
        let mut c3 = setup.cluster(888);
        let coded = master.run_events(&mut c3).expect("sizes match");
        let total = probe_time + search_s + coded.total_runtime_s;
        println!(
            "{:<10} {:<18} {:>12.2} {:>14.1} {:>14.1}",
            fam,
            best.label(),
            search_s,
            coded.total_runtime_s,
            total
        );
        let mut o = Json::obj();
        o.set("selected", best.label())
            .set("search_s", search_s)
            .set("coded_s", coded.total_runtime_s)
            .set("total_s", total);
        json.set(fam, o);
        totals.push((fam, total));
    }
    save_json("fig18", &json);
    let get = |n: &str| totals.iter().find(|(k, _)| *k == n).unwrap().1;
    assert!(
        get("M-SGC") < get("uncoded"),
        "switching to M-SGC must beat staying uncoded"
    );
    println!("\n(paper shape: M-SGC gains survive the probing overhead; search takes seconds)");
}
