//! Fig. 11: normalized loads of SR-SGC and M-SGC vs the Theorem-F.1 lower
//! bound, at n=20, B=3, λ=4 with W varied (W = 3x+1 for SR-SGC validity).

use sgc::coding::bounds;
use sgc::experiments::{save_json, TablePrinter};
use sgc::util::json::Json;

fn main() {
    let (n, b, lambda) = (20usize, 3usize, 4usize);
    println!("== Fig 11: normalized load vs W (n={n}, B={b}, λ={lambda}) ==\n");
    let t = TablePrinter::new(
        &["W", "SR-SGC", "M-SGC", "bound L_B*", "M-SGC gap"],
        &[4, 10, 10, 12, 11],
    );
    let mut rows = Vec::new();
    let mut prev_gap = f64::INFINITY;
    for x in 1..=8usize {
        let w = 3 * x + 1;
        let sr = bounds::sr_sgc_load(n, b, w, lambda);
        let m = bounds::m_sgc_load(n, b, w, lambda);
        let lb = bounds::bursty_lower_bound(n, b, w, lambda);
        let gap = m / lb;
        t.row(&[
            w.to_string(),
            format!("{sr:.4}"),
            format!("{m:.4}"),
            format!("{lb:.4}"),
            format!("{:.2}%", 100.0 * (gap - 1.0)),
        ]);
        assert!(m < sr, "M-SGC below SR-SGC at W={w}");
        assert!(m >= lb - 1e-12, "no bound violation at W={w}");
        assert!(gap <= prev_gap + 1e-12, "gap must shrink with W (O(1/W))");
        prev_gap = gap;
        let mut o = Json::obj();
        o.set("w", w).set("sr_sgc", sr).set("m_sgc", m).set("bound", lb);
        rows.push(o);
    }
    // optimality spot checks (Remark F.1)
    for lam in [n - 1, n] {
        let gap = bounds::m_sgc_gap(n, b, 7, lam);
        println!("\nλ={lam}: M-SGC/bound = {gap:.6} (Remark F.1: optimal)");
        assert!((gap - 1.0).abs() < 1e-9);
    }
    let mut json = Json::obj();
    json.set("rows", Json::Arr(rows));
    save_json("fig11", &json);
}
