//! Table 4: decoding time per scheme — the real GC linear-algebra solve
//! measured at each decoded job, plus the "longest decoding < fastest
//! round" check that lets Appendix K hide decoding in master idle time.

use sgc::experiments::{save_json, PaperSetup, TablePrinter};
use sgc::util::json::Json;

fn main() {
    let mut setup = PaperSetup::table1();
    setup.reps = setup.reps.min(3); // decode stats converge quickly
    println!(
        "== Table 4: decoding time (n={}, J={}, measured solves) ==\n",
        setup.n, setup.jobs
    );
    let t = TablePrinter::new(
        &["Scheme", "Params", "Decode (ms)", "Longest (ms)", "Fastest round (ms)"],
        &[10, 22, 18, 14, 20],
    );
    let mut json = Json::obj();
    for (name, scheme) in setup.table1_schemes() {
        if name == "No Coding" {
            continue; // paper's Table 4 covers the coded schemes
        }
        let mut means = Vec::new();
        let mut longest: f64 = 0.0;
        let mut fastest_round = f64::INFINITY;
        for rep in 0..setup.reps {
            let report = setup.run_once(&scheme, 3000 + rep as u64, true);
            let (mean, _std, max) = report.decode_stats();
            means.push(mean);
            longest = longest.max(max);
            fastest_round = fastest_round.min(report.fastest_round_s());
        }
        let mean_ms = 1e3 * sgc::util::stats::mean(&means);
        let std_ms = 1e3 * sgc::util::stats::std_dev(&means);
        t.row(&[
            name.to_string(),
            scheme.label(),
            format!("{mean_ms:.1} ± {std_ms:.1}"),
            format!("{:.1}", longest * 1e3),
            format!("{:.1}", fastest_round * 1e3),
        ]);
        assert!(
            longest < fastest_round,
            "{name}: decoding ({longest}s) must fit in master idle time \
             (fastest round {fastest_round}s) — Appendix K"
        );
        let mut o = Json::obj();
        o.set("decode_mean_ms", mean_ms)
            .set("decode_std_ms", std_ms)
            .set("longest_ms", longest * 1e3)
            .set("fastest_round_ms", fastest_round * 1e3);
        json.set(name, o);
    }
    save_json("table4", &json);
    println!("\n(paper shape: decode ≤ hundreds of ms, always below the fastest round)");
}
