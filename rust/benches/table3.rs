//! Table 3: sensitivity of parameter selection to the probe length
//! `T_probe ∈ {10, 20, 40, 60, 80}` — selected parameters, their load and
//! the resulting training runtime.

use sgc::coding::SchemeConfig;
use sgc::experiments::{fast_mode, save_json, PaperSetup, TablePrinter};
use sgc::probe::{grid_search, DelayProfile, SearchSpace};
use sgc::util::json::Json;

fn main() {
    let setup = PaperSetup::table1();
    let probes: Vec<usize> =
        if fast_mode() { vec![10, 40] } else { vec![10, 20, 40, 60, 80] };
    println!(
        "== Table 3: parameter selection vs T_probe (n={}, J={}) ==\n",
        setup.n, setup.jobs
    );
    let space = SearchSpace::paper_default(setup.n);
    let t = TablePrinter::new(
        &["Scheme", "T_probe", "Selected", "Load", "Runtime (s)"],
        &[8, 8, 20, 10, 20],
    );
    let mut json = Json::obj();
    let jobs_for_estimate = setup.jobs.min(80);
    for (fam, cands) in [
        ("M-SGC", space.m_sgc_candidates()),
        ("SR-SGC", space.sr_sgc_candidates()),
        ("GC", space.gc_candidates()),
    ] {
        let mut fam_json = Json::obj();
        for &tp in &probes {
            // capture a T_probe-round uncoded profile
            let mut cluster = setup.cluster(4242);
            let alpha = cluster.latency.alpha_s_per_load;
            let profile = DelayProfile::capture(
                &mut sgc::cluster::SyncAdapter::new(&mut cluster),
                tp,
                1.0 / setup.n as f64,
            );
            let ranked = grid_search(&cands, &profile, alpha, jobs_for_estimate);
            let best: &SchemeConfig = &ranked[0].config;
            // actually run the selected parameters (fewer reps: this is a
            // 15-cell table)
            let reps = if fast_mode() { 2 } else { 5 };
            let small = PaperSetup { reps, ..setup.clone() };
            let stats = small.runtime_stats(best, false);
            t.row(&[
                fam.to_string(),
                tp.to_string(),
                best.label(),
                format!("{:.4}", best.load()),
                format!("{:.2} ± {:.2}", stats.mean, stats.std),
            ]);
            let mut o = Json::obj();
            o.set("selected", best.label())
                .set("load", best.load())
                .set("runtime_mean_s", stats.mean)
                .set("runtime_std_s", stats.std);
            fam_json.set(&tp.to_string(), o);
        }
        json.set(fam, fam_json);
    }
    save_json("table3", &json);
    println!("\n(paper shape: selections stabilize with larger T_probe; M-SGC is robust even at T_probe=10)");
}
