//! Fig. 17: estimated runtime of 80 jobs across the (B, W, λ) parameter
//! grid for SR-SGC (left) and M-SGC (right), via the Appendix-J
//! load-adjusted profile replay. Prints the grid minima and the
//! sensitivity ridges the paper discusses in J.1.

use sgc::coding::SchemeConfig;
use sgc::experiments::{fast_mode, save_json, PaperSetup};
use sgc::probe::{estimate_runtime, DelayProfile};
use sgc::util::json::Json;

fn main() {
    let setup = PaperSetup::table1();
    let jobs = if fast_mode() { 30 } else { 80 };
    let t_probe = if fast_mode() { 20 } else { 80 };
    println!("== Fig 17: estimated runtime over the parameter grid (n={}) ==\n", setup.n);
    let mut cluster = setup.cluster(4242);
    let alpha = cluster.latency.alpha_s_per_load;
    let profile = DelayProfile::capture(
        &mut sgc::cluster::SyncAdapter::new(&mut cluster),
        t_probe,
        1.0 / setup.n as f64,
    );

    let lam_step = (setup.n / 32).max(1);
    let lambdas: Vec<usize> = (1..=setup.n / 4).step_by(lam_step).collect();

    let mut json = Json::obj();
    for fam in ["SR-SGC", "M-SGC"] {
        println!("{fam}:");
        let mut best: Option<(f64, SchemeConfig)> = None;
        let mut grid = Vec::new();
        for (b, w) in [(1usize, 2usize), (2, 3), (3, 4), (1, 3), (2, 5)] {
            // SR-SGC needs W = xB + 1
            if fam == "SR-SGC" && (w - 1) % b != 0 {
                continue;
            }
            let mut row = Vec::new();
            for &lambda in &lambdas {
                let cfg = if fam == "SR-SGC" {
                    let p = sgc::coding::SrSgcParams { n: setup.n, b, w, lambda };
                    if p.s() == 0 || p.s() >= setup.n {
                        row.push(f64::NAN);
                        continue;
                    }
                    SchemeConfig::sr_sgc(setup.n, b, w, lambda)
                } else {
                    if lambda >= setup.n {
                        row.push(f64::NAN);
                        continue;
                    }
                    SchemeConfig::msgc(setup.n, b, w, lambda)
                };
                let est = estimate_runtime(&cfg, &profile, alpha, jobs);
                row.push(est);
                if best.as_ref().map(|(e, _)| est < *e).unwrap_or(true) {
                    best = Some((est, cfg));
                }
            }
            let shown: Vec<String> = row
                .iter()
                .map(|v| if v.is_nan() { "  -  ".into() } else { format!("{v:5.0}") })
                .collect();
            println!("  B={b} W={w}: {}", shown.join(" "));
            let mut o = Json::obj();
            o.set("b", b).set("w", w).set("estimates", row);
            grid.push(o);
        }
        let (est, cfg) = best.unwrap();
        println!("  λ grid: {lambdas:?}");
        println!("  → best: {} at {est:.0}s\n", cfg.label());
        let mut o = Json::obj();
        o.set("grid", Json::Arr(grid)).set("best", cfg.label()).set("best_estimate_s", est);
        json.set(fam, o);
    }
    save_json("fig17", &json);
    println!("(paper shape J.1: SR-SGC runtime climbs steeply with λ; M-SGC is flat in λ above a threshold)");
}
