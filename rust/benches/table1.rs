//! Table 1: total run time achieved by different coding schemes
//! (n = 256, J = 480, 10 repetitions, naturally occurring GE stragglers).
//!
//! Expected shape (paper): M-SGC ≈ 16% faster than GC at ~8x lower load;
//! SR-SGC slightly faster than GC; uncoded slowest.

use sgc::experiments::{save_json, PaperSetup, TablePrinter};
use sgc::util::json::Json;

fn main() {
    let setup = PaperSetup::table1();
    println!(
        "== Table 1: total runtime (n={}, J={}, {} reps) ==\n",
        setup.n, setup.jobs, setup.reps
    );
    let t = TablePrinter::new(
        &["Scheme", "Parameters", "Load", "Run Time (s)"],
        &[10, 22, 10, 22],
    );
    let mut json = Json::obj();
    let mut results = Vec::new();
    for (name, scheme) in setup.table1_schemes() {
        let stats = setup.runtime_stats(&scheme, false);
        t.row(&[
            name.to_string(),
            scheme.label(),
            format!("{:.3}", scheme.load()),
            format!("{:.2} ± {:.2}", stats.mean, stats.std),
        ]);
        let mut o = Json::obj();
        o.set("scheme", name)
            .set("params", scheme.label())
            .set("load", scheme.load())
            .set("runtime_mean_s", stats.mean)
            .set("runtime_std_s", stats.std);
        json.set(name, o);
        results.push((name, stats.mean));
    }
    save_json("table1", &json);

    // Shape assertions (who wins, roughly by how much).
    let get = |n: &str| results.iter().find(|(k, _)| *k == n).unwrap().1;
    let (msgc, srsgc, gc, unc) = (get("M-SGC"), get("SR-SGC"), get("GC"), get("No Coding"));
    println!("\nshape checks:");
    println!(
        "  M-SGC vs GC:     {:+.1}% (paper: -16%)",
        100.0 * (msgc - gc) / gc
    );
    println!(
        "  SR-SGC vs GC:    {:+.1}% (paper: -6.6%)",
        100.0 * (srsgc - gc) / gc
    );
    println!(
        "  GC vs No Coding: {:+.1}% (paper: -18.6%)",
        100.0 * (gc - unc) / unc
    );
    assert!(msgc < gc, "M-SGC must beat GC");
    assert!(gc < unc, "GC must beat No Coding");
}
