//! Fig. 16: average worker run time scales linearly with the per-worker
//! computational load — the observation parameter selection builds on.

use sgc::cluster::SimCluster;
use sgc::experiments::{fast_mode, save_json};
use sgc::straggler::GilbertElliot;
use sgc::util::json::Json;
use sgc::util::stats;

fn main() {
    let (n, rounds) = if fast_mode() { (64, 20) } else { (256, 100) };
    println!("== Fig 16: worker runtime vs load (n={n}, {rounds} rounds/point) ==\n");
    let mut cluster = SimCluster::from_gilbert_elliot(n, GilbertElliot::default_fit(n, 7), 3);
    let loads: Vec<f64> = (0..=10).map(|i| i as f64 / 10.0).collect();
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    println!("{:>6}  {:>12}", "load", "avg time (s)");
    for &load in &loads {
        let mut acc = Vec::new();
        for _ in 0..rounds {
            let s = cluster.sample_round(&vec![load; n]);
            // average of *non-straggler* completions (the paper's workers'
            // run time, not the straggler tail)
            let normal: Vec<f64> = s
                .finish
                .iter()
                .zip(&s.state)
                .filter(|(_, &st)| !st)
                .map(|(&f, _)| f)
                .collect();
            acc.push(stats::mean(&normal));
        }
        let avg = stats::mean(&acc);
        println!("{load:>6.2}  {avg:>12.3}");
        xs.push(load);
        ys.push(avg);
    }
    let (a, slope, r2) = stats::linear_fit(&xs, &ys);
    println!("\nlinear fit: t = {a:.3} + {slope:.3}·L, R² = {r2:.5}");
    assert!(r2 > 0.99, "Fig 16 linearity must hold (R²={r2})");
    let mut json = Json::obj();
    json.set("loads", xs).set("avg_time_s", ys).set("intercept", a).set("slope", slope).set("r2", r2);
    save_json("fig16", &json);
}
