//! `adapt_refit` — hot-path costs of the adaptive control plane
//! (`sgc::adapt`): folding one observed round into the online profile,
//! and one budgeted grid-search slice (`Refitter::tick`), at n=64 and
//! n=256. Finishes with the regime-shift acceptance comparison:
//! `sgc serve --adapt` semantics (adaptive M-SGC) against the
//! statically-fit incumbent on the same scripted trace. Emits the
//! `BENCH_6.json` perf snapshot.

use sgc::adapt::{AdaptiveConfig, OnlineProfiler, ProfilerConfig, Refitter};
use sgc::bench_harness::Bench;
use sgc::cluster::{EventCluster, SimCluster};
use sgc::coding::SchemeConfig;
use sgc::sched::{JobScheduler, JobSpec};
use sgc::session::SessionConfig;
use sgc::straggler::Pattern;

fn mean_s(b: &Bench, name: &str) -> f64 {
    b.result(name).map(|r| r.mean.as_secs_f64()).unwrap_or(f64::NAN)
}

/// Quiet until `shift_at` cluster rounds, then a persistent heavy
/// regime (mirrors `sgc serve --regime-shift` and tests/adapt.rs).
fn regime_shift_sim(n: usize, shift_at: usize, seed: u64) -> SimCluster {
    let mut rows = vec![vec![false; n]; shift_at];
    for k in 0..4096usize {
        rows.push((0..n).map(|w| k % 2 == 0 && w % 3 == 0).collect());
    }
    SimCluster::from_trace(n, Pattern::from_rows(rows), seed)
}

/// Feed `rounds` synthetic observed rounds into the profiler; returns
/// the next start round.
fn feed_rounds(p: &mut OnlineProfiler, n: usize, rounds: u64, start: u64) -> u64 {
    let place: Vec<usize> = (0..n).collect();
    let loads = vec![1.0 / n as f64; n];
    for r in start + 1..=start + rounds {
        p.register_round(0, r, &place, &loads);
        for w in 0..n {
            p.observe(0, r, w, 1.0 + 0.001 * ((w as u64 + r) % 7) as f64);
        }
        p.fold_round(0, r);
    }
    start + rounds
}

fn main() {
    let fast = std::env::var("SGC_BENCH_FAST").ok().as_deref() == Some("1");
    let mut b = Bench::new("adapt-refit");
    b.header();

    // --- online profile update: one full observed round folded in -----
    for &n in &[64usize, 256] {
        let mut p = OnlineProfiler::new(ProfilerConfig::default());
        let mut r = feed_rounds(&mut p, n, 4, 0);
        b.run(&format!("profile_fold(n={n})"), || {
            r = feed_rounds(&mut p, n, 1, r);
        });
    }

    // --- one budgeted grid-search slice (4 candidates × 8 jobs) -------
    for &n in &[64usize, 256] {
        let mut p = OnlineProfiler::new(ProfilerConfig::default());
        feed_rounds(&mut p, n, 16, 0);
        let snap = p.snapshot(0).expect("rows folded");
        let alpha = p.alpha();
        let inc = SchemeConfig::msgc(n, 1, 2, (n / 16).max(1));
        let mut rf = Refitter::new(&inc, 4, 8);
        b.run(&format!("refit_tick_budget4(n={n})"), || {
            if !rf.pass_active() {
                rf.begin_pass(snap.clone(), alpha);
            }
            let _ = rf.tick();
        });
    }

    // --- regime-shift acceptance: adaptive vs statically-fit M-SGC ----
    let n = 64;
    let jobs = if fast { 40 } else { 100 };
    let spec = JobSpec {
        scheme: SchemeConfig::msgc(n, 1, 2, 2),
        session: SessionConfig { jobs, ..Default::default() },
    };
    let serve = |adaptive: bool| -> (f64, usize) {
        let mut sim = regime_shift_sim(n, 10, 42);
        let out = {
            let mut sched = JobScheduler::new(&mut sim);
            if adaptive {
                sched.set_adaptive(AdaptiveConfig::default());
            }
            sched.admit(&spec).expect("admit");
            sched.run().expect("serve run")
        };
        (sim.now_s(), out.swaps.len())
    };
    let (static_t, _) = serve(false);
    let (adapt_t, swaps) = serve(true);
    println!(
        "  regime-shift serve (n={n}, {jobs} jobs): static {static_t:.1}s vs \
         adaptive {adapt_t:.1}s, {swaps} swap(s)"
    );

    b.save();
    b.save_snapshot(
        "BENCH_6.json",
        &[
            ("profile_fold_s_n64", mean_s(&b, "profile_fold(n=64)")),
            ("profile_fold_s_n256", mean_s(&b, "profile_fold(n=256)")),
            ("refit_tick_s_n64", mean_s(&b, "refit_tick_budget4(n=64)")),
            ("refit_tick_s_n256", mean_s(&b, "refit_tick_budget4(n=256)")),
            ("regime_shift_static_runtime_s", static_t),
            ("regime_shift_adaptive_runtime_s", adapt_t),
            ("regime_shift_speedup", static_t / adapt_t.max(1e-9)),
            ("regime_shift_swaps", swaps as f64),
        ],
    );
}
