//! Hot-path microbenchmarks (§Perf): GC decode solve (cold + cached),
//! M-SGC assignment, conformance checking, fleet wire-codec
//! encode/decode, one full simulated round, and the end-to-end
//! Table-1-scale run.

use sgc::bench_harness::Bench;
use sgc::cluster::SimCluster;
use sgc::coding::{GcCode, MSgcParams, MSgcScheme, Scheme, SchemeConfig};
use sgc::coordinator::{Master, RunConfig};
use sgc::fleet::Frame;
use sgc::straggler::{GilbertElliot, ToleranceChecker};
use sgc::util::rng::Pcg32;

fn main() {
    let mut b = Bench::new("microbench");
    b.header();
    let n = 256;

    // --- GC decode solve, cold vs cached --------------------------------
    let s = 15;
    let mut rng = Pcg32::seeded(42);
    let subsets: Vec<Vec<usize>> =
        (0..64).map(|_| rng.sample_indices(n, n - s)).collect();
    {
        let mut i = 0usize;
        let mut code = GcCode::new(n, s, 7);
        b.run("gc_decode_cold(n=256,s=15)", || {
            // fresh code each batch of 64 to avoid the cache
            if i % subsets.len() == 0 {
                code = GcCode::new(n, s, 7 + (i / subsets.len()) as u64);
            }
            let _ = code.decode_coeffs(&subsets[i % subsets.len()]).unwrap();
            i += 1;
        });
    }
    {
        let mut code = GcCode::new(n, s, 7);
        for sub in &subsets {
            code.decode_coeffs(sub).unwrap();
        }
        let mut i = 0usize;
        b.run("gc_decode_cached(n=256,s=15)", || {
            let _ = code.decode_coeffs(&subsets[i % subsets.len()]).unwrap();
            i += 1;
        });
    }
    // larger code (M-SGC's λ=27)
    {
        let s2 = 27;
        let mut code = GcCode::new(n, s2, 9);
        let sub = rng.sample_indices(n, n - s2);
        b.run("gc_decode_cold(n=256,s=27)", || {
            code = GcCode::new(n, s2, 9);
            let _ = code.decode_coeffs(&sub).unwrap();
        });
    }

    // --- GcCode construction --------------------------------------------
    b.run("gc_code_construct(n=256,s=15)", || {
        let _ = GcCode::new(n, s, 11);
    });

    // --- M-SGC assignment throughput -------------------------------------
    {
        let p = MSgcParams { n, b: 1, w: 2, lambda: 27 };
        let mut scheme = MSgcScheme::new(p, 100_000);
        let mut r = 0usize;
        let responded = vec![true; n];
        b.run("msgc_assign_commit_round(n=256)", || {
            r += 1;
            scheme.assign_round(r);
            scheme.commit_round(r, &responded);
        });
    }

    // --- conformance checker ---------------------------------------------
    {
        let spec = sgc::coding::ToleranceSpec::BurstyOrArbitrary { b: 1, w: 2, lambda: 27 };
        let mut checker = ToleranceChecker::new(n, spec);
        let mut ge = GilbertElliot::default_fit(n, 5);
        use sgc::straggler::StragglerProcess;
        let rows: Vec<Vec<bool>> = (0..256).map(|_| ge.next_round()).collect();
        let mut i = 0usize;
        b.run("conformance_check+commit(n=256)", || {
            let row = &rows[i % rows.len()];
            let _ = checker.acceptable(row);
            // commit an all-clear so history stays conforming
            checker.commit(&vec![false; n]);
            i += 1;
        });
    }

    // --- fleet wire codec --------------------------------------------------
    // Serialization must stay O(100ns)/frame — far beneath the ~0.1 ms
    // localhost RTT, so the codec never shows up on the fleet hot path.
    {
        // a worst-case realistic Assign: full-replication task at n=256
        let assign = Frame::Assign {
            round: 480,
            work_units: 0.0625,
            chunks: (0..256).collect(),
        };
        let result = Frame::Result {
            worker_id: 255,
            round: 480,
            compute_s: 1.2345,
            checksum: 0xfeed_f00d_dead_beef,
        };
        b.run("wire_encode_assign(256 chunks)", || {
            let _ = assign.encode();
        });
        let assign_bytes = assign.encode();
        b.run("wire_decode_assign(256 chunks)", || {
            let _ = Frame::decode(&assign_bytes).unwrap();
        });
        b.run("wire_encode_result", || {
            let _ = result.encode();
        });
        let result_bytes = result.encode();
        b.run("wire_decode_result", || {
            let _ = Frame::decode(&result_bytes).unwrap();
        });
        let hb = Frame::Heartbeat { worker_id: 1, round: 2 }.encode();
        b.run("wire_roundtrip_heartbeat", || {
            let _ = Frame::decode(&hb).unwrap();
        });
    }

    // --- one simulated cluster round --------------------------------------
    {
        let mut cluster =
            SimCluster::from_gilbert_elliot(n, GilbertElliot::default_fit(n, 5), 6);
        let loads = vec![0.0078; n];
        b.run("sim_cluster_round(n=256)", || {
            let _ = cluster.sample_round(&loads);
        });
    }

    // --- end-to-end Table-1 run -------------------------------------------
    for (label, spec) in
        [("e2e_msgc_480jobs", "m-sgc:1,2,27"), ("e2e_gc_480jobs", "gc:15")]
    {
        let scheme = SchemeConfig::parse(n, spec).unwrap();
        b.run_n(label, 3, || {
            let mut master =
                Master::new(scheme.clone(), RunConfig { jobs: 480, ..Default::default() });
            let mut cluster =
                SimCluster::from_gilbert_elliot(n, GilbertElliot::default_fit(n, 3), 4);
            let _ = master.run(&mut cluster).expect("sizes match");
        });
    }

    b.save();
}
