//! Hot-path microbenchmarks (§Perf): GC decode solve (cold + cached +
//! shared plan cache), session round-engine throughput, multi-job
//! scheduler throughput (1/4/16 sessions multiplexed over one shared
//! simulator), Appendix-J grid-search throughput, M-SGC assignment,
//! conformance checking, fleet wire-codec encode/decode, one full
//! simulated round, and the end-to-end Table-1-scale run.
//!
//! Besides the usual per-label report this bench emits the repo-level
//! `BENCH_4.json` snapshot (rounds/sec, scheduler throughput,
//! grid-search speedup, decode-plan speedup) so the perf trajectory
//! accumulates across PRs.

use sgc::bench_harness::Bench;
use sgc::cluster::{EventCluster, SimCluster};
use sgc::coding::{CodePlanCache, GcCode, MSgcParams, MSgcScheme, Scheme, SchemeConfig};
use sgc::coordinator::{Master, RunConfig};
use sgc::fleet::Frame;
use sgc::probe::{estimate_runtime, grid_search, DelayProfile};
use sgc::sched::{JobScheduler, JobSpec};
use sgc::session::{RoundPlan, SessionConfig, SgcSession};
use sgc::straggler::{GilbertElliot, ToleranceChecker};
use sgc::util::rng::Pcg32;
use std::sync::Arc;

fn mean_s(b: &Bench, name: &str) -> f64 {
    b.result(name).map(|r| r.mean.as_secs_f64()).unwrap_or(f64::NAN)
}

fn main() {
    let fast = std::env::var("SGC_BENCH_FAST").ok().as_deref() == Some("1");
    let mut b = Bench::new("microbench");
    b.header();
    let n = 256;

    // --- GC decode solve, cold vs cached --------------------------------
    let s = 15;
    let mut rng = Pcg32::seeded(42);
    // sorted: decode_coeffs keys the responder *set* (see plan_cache)
    let subsets: Vec<Vec<usize>> = (0..64)
        .map(|_| {
            let mut sub = rng.sample_indices(n, n - s);
            sub.sort_unstable();
            sub
        })
        .collect();
    {
        let mut i = 0usize;
        let mut code = GcCode::new(n, s, 7);
        b.run("gc_decode_cold(n=256,s=15)", || {
            // fresh code each batch of 64 to avoid the cache
            if i % subsets.len() == 0 {
                code = GcCode::new(n, s, 7 + (i / subsets.len()) as u64);
            }
            let _ = code.decode_coeffs(&subsets[i % subsets.len()]).unwrap();
            i += 1;
        });
    }
    {
        let mut code = GcCode::new(n, s, 7);
        for sub in &subsets {
            code.decode_coeffs(sub).unwrap();
        }
        let mut i = 0usize;
        b.run("gc_decode_cached(n=256,s=15)", || {
            let _ = code.decode_coeffs(&subsets[i % subsets.len()]).unwrap();
            i += 1;
        });
    }
    // shared process-wide plan cache: the per-session-free hit path
    {
        let plan = CodePlanCache::global().get(n, s);
        for sub in &subsets {
            plan.decode_coeffs(sub).unwrap();
        }
        let mut i = 0usize;
        b.run("plan_cache_hit(n=256,s=15)", || {
            let _ = plan.decode_coeffs(&subsets[i % subsets.len()]).unwrap();
            i += 1;
        });
    }
    // larger code (M-SGC's λ=27)
    {
        let s2 = 27;
        let mut code = GcCode::new(n, s2, 9);
        let mut sub = rng.sample_indices(n, n - s2);
        sub.sort_unstable();
        b.run("gc_decode_cold(n=256,s=27)", || {
            code = GcCode::new(n, s2, 9);
            let _ = code.decode_coeffs(&sub).unwrap();
        });
    }

    // --- GcCode construction --------------------------------------------
    b.run("gc_code_construct(n=256,s=15)", || {
        let _ = GcCode::new(n, s, 11);
    });

    // --- session round-engine throughput ----------------------------------
    // Pre-sampled completion times, so the measured body is exactly one
    // begin_round_into + submit_all + close_round cycle of the
    // allocation-free engine.
    for (bench_n, bench_s) in [(64usize, 7usize), (256, 15)] {
        let scheme = SchemeConfig::gc(bench_n, bench_s);
        let cfg = SessionConfig { jobs: 4000, ..Default::default() };
        let loads = vec![(bench_s + 1) as f64 / bench_n as f64; bench_n];
        let mut cluster = SimCluster::from_gilbert_elliot(
            bench_n,
            GilbertElliot::default_fit(bench_n, 21),
            22,
        );
        let rows: Vec<Vec<f64>> =
            (0..64).map(|_| cluster.sample_round(&loads).finish).collect();
        let mut session = SgcSession::new(&scheme, cfg.clone());
        let mut plan = RoundPlan::default();
        let mut i = 0usize;
        b.run(&format!("session_round(n={bench_n},gc)"), || {
            if session.is_complete() {
                session = SgcSession::new(&scheme, cfg.clone());
            }
            session.begin_round_into(&mut plan);
            session.submit_all(&rows[i % rows.len()]);
            session.close_round();
            i += 1;
        });
    }

    // --- multi-job scheduler throughput -----------------------------------
    // 1/4/16 concurrent GC sessions multiplexed over ONE shared n=64
    // simulator through the event-driven JobScheduler: measures the whole
    // pump (submit → per-worker FIFO queues → poll → incremental μ-rule
    // close) end to end. Rounds/sec here is aggregate across jobs.
    let mut sched_mean = [0.0f64; 3];
    let sched_session_jobs = if fast { 30 } else { 120 };
    for (slot, jobs) in [1usize, 4, 16].into_iter().enumerate() {
        let sn = 64;
        let scheme = SchemeConfig::gc(sn, 7);
        let reps = if fast { 2 } else { 5 };
        let mut seed = 0u64;
        let label = format!("sched_multiplex(n=64,jobs={jobs})");
        b.run_n(&label, reps, || {
            seed += 1;
            let mut sim = SimCluster::from_gilbert_elliot(
                sn,
                GilbertElliot::default_fit(sn, 91 + seed),
                191 + seed,
            );
            let mut sched = JobScheduler::new(&mut sim);
            for _ in 0..jobs {
                sched
                    .admit(&JobSpec {
                        scheme: scheme.clone(),
                        session: SessionConfig {
                            jobs: sched_session_jobs,
                            ..Default::default()
                        },
                    })
                    .expect("sizes match");
            }
            let out = sched.run().expect("quiet multiplexed run completes");
            assert_eq!(out.reports.len(), jobs);
        });
        sched_mean[slot] = mean_s(&b, &label);
    }

    // --- Appendix-J grid search: shared vs per-candidate rebuild ----------
    // The shared path is `probe::grid_search`: one Arc-shared delay
    // matrix, candidates fanned over the batch driver, GC code plans
    // from the process-wide cache. The legacy arm emulates the
    // pre-optimization shape: sequential candidates, a deep O(n×rounds)
    // profile copy and a from-scratch GcCode construction per candidate.
    {
        let (gn, rounds, jobs, reps) = if fast { (64, 12, 10, 1) } else { (256, 40, 30, 3) };
        let mut cluster =
            SimCluster::from_gilbert_elliot(gn, GilbertElliot::default_fit(gn, 31), 32)
                .sync();
        let profile = DelayProfile::capture(&mut cluster, rounds, 1.0 / gn as f64);
        let alpha = 9.5;
        let cands: Vec<SchemeConfig> =
            (1..=8).map(|k| SchemeConfig::gc(gn, 2 * k)).collect();
        let shared_name = format!("grid_search_shared(n={gn},{} cands)", cands.len());
        let legacy_name = format!("grid_search_percand_rebuild(n={gn},{} cands)", cands.len());
        b.run_n(&shared_name, reps, || {
            let _ = grid_search(&cands, &profile, alpha, jobs);
        });
        b.run_n(&legacy_name, reps, || {
            for c in &cands {
                let deep = DelayProfile {
                    n: profile.n,
                    base_load: profile.base_load,
                    times: Arc::new((*profile.times).clone()),
                };
                let s_of = match c.kind {
                    sgc::coding::SchemeKind::Gc { s } => s,
                    _ => unreachable!(),
                };
                // per-candidate code rebuild (what the shared plan cache
                // eliminates)
                let _ = GcCode::new(gn, s_of, 0xdec0de);
                let _ = estimate_runtime(c, &deep, alpha, jobs);
            }
        });
        let grid_speedup = mean_s(&b, &legacy_name) / mean_s(&b, &shared_name);
        println!("  grid-search speedup (shared vs per-candidate rebuild): {grid_speedup:.1}x");
    }

    // --- M-SGC assignment throughput -------------------------------------
    {
        let p = MSgcParams { n, b: 1, w: 2, lambda: 27 };
        let mut scheme = MSgcScheme::new(p, 100_000);
        let mut r = 0usize;
        let responded = vec![true; n];
        let mut tasks = Vec::new();
        b.run("msgc_assign_commit_round(n=256)", || {
            r += 1;
            scheme.assign_round_into(r, &mut tasks);
            scheme.commit_round(r, &responded);
        });
    }

    // --- conformance checker ---------------------------------------------
    {
        let spec = sgc::coding::ToleranceSpec::BurstyOrArbitrary { b: 1, w: 2, lambda: 27 };
        let mut checker = ToleranceChecker::new(n, spec);
        let mut ge = GilbertElliot::default_fit(n, 5);
        use sgc::straggler::StragglerProcess;
        let rows: Vec<Vec<bool>> = (0..256).map(|_| ge.next_round()).collect();
        let mut i = 0usize;
        b.run("conformance_check+commit(n=256)", || {
            let row = &rows[i % rows.len()];
            let _ = checker.acceptable(row);
            // commit an all-clear so history stays conforming
            checker.commit(&vec![false; n]);
            i += 1;
        });
    }

    // --- fleet wire codec --------------------------------------------------
    // Serialization must stay O(100ns)/frame — far beneath the ~0.1 ms
    // localhost RTT, so the codec never shows up on the fleet hot path.
    {
        // a worst-case realistic Assign: full-replication task at n=256
        let assign = Frame::Assign {
            round: 480,
            work_units: 0.0625,
            chunks: (0..256).collect(),
        };
        let result = Frame::Result {
            worker_id: 255,
            round: 480,
            compute_s: 1.2345,
            checksum: 0xfeed_f00d_dead_beef,
        };
        b.run("wire_encode_assign(256 chunks)", || {
            let _ = assign.encode();
        });
        let assign_bytes = assign.encode();
        b.run("wire_decode_assign(256 chunks)", || {
            let _ = Frame::decode(&assign_bytes).unwrap();
        });
        b.run("wire_encode_result", || {
            let _ = result.encode();
        });
        let result_bytes = result.encode();
        b.run("wire_decode_result", || {
            let _ = Frame::decode(&result_bytes).unwrap();
        });
        let hb = Frame::Heartbeat { worker_id: 1, round: 2 }.encode();
        b.run("wire_roundtrip_heartbeat", || {
            let _ = Frame::decode(&hb).unwrap();
        });
    }

    // --- one simulated cluster round --------------------------------------
    {
        let mut cluster =
            SimCluster::from_gilbert_elliot(n, GilbertElliot::default_fit(n, 5), 6);
        let loads = vec![0.0078; n];
        b.run("sim_cluster_round(n=256)", || {
            let _ = cluster.sample_round(&loads);
        });
    }

    // --- end-to-end Table-1 run -------------------------------------------
    for (label, spec) in
        [("e2e_msgc_480jobs", "m-sgc:1,2,27"), ("e2e_gc_480jobs", "gc:15")]
    {
        let scheme = SchemeConfig::parse(n, spec).unwrap();
        b.run_n(label, 3, || {
            let mut master =
                Master::new(scheme.clone(), RunConfig { jobs: 480, ..Default::default() });
            let mut cluster =
                SimCluster::from_gilbert_elliot(n, GilbertElliot::default_fit(n, 3), 4);
            let _ = master.run_events(&mut cluster).expect("sizes match");
        });
    }

    // --- observability record path ----------------------------------------
    // The per-event hot path of sgc::obs: one histogram record (bucket
    // scan + three atomics) and one journal append (mutex + slot write).
    // Both must stay O(10-100ns) so instrumented runs cost nothing
    // measurable per round (the zero-perturbation claim in
    // DESIGN.md §Observability; tests/alloc.rs pins the 0-alloc half).
    {
        let obs = sgc::obs::Obs::with_capacity(4096);
        let h = obs.metrics.histogram("bench_seconds", "", "bench histogram");
        let mut i = 0u64;
        b.run("obs_histogram_record", || {
            h.record((i % 100) as f64 * 0.01);
            i += 1;
        });
        let mut j = 0u64;
        b.run("obs_journal_append(ring wrap)", || {
            obs.journal.record(
                j as f64,
                sgc::obs::EventKind::WorkerArrive,
                0,
                j as i64,
                (j % 64) as i64,
                0.25,
            );
            j += 1;
        });
    }

    b.save();

    // --- BENCH_7.json observability snapshot ------------------------------
    b.save_snapshot(
        "BENCH_7.json",
        &[
            ("histogram_record_ns", mean_s(&b, "obs_histogram_record") * 1e9),
            ("journal_append_ns", mean_s(&b, "obs_journal_append(ring wrap)") * 1e9),
        ],
    );

    // --- BENCH_4.json perf snapshot ---------------------------------------
    let grid_n = if fast { 64 } else { 256 };
    let shared = mean_s(&b, &format!("grid_search_shared(n={grid_n},8 cands)"));
    let legacy = mean_s(&b, &format!("grid_search_percand_rebuild(n={grid_n},8 cands)"));
    let round64 = mean_s(&b, "session_round(n=64,gc)");
    let round256 = mean_s(&b, "session_round(n=256,gc)");
    // aggregate scheduler throughput: (jobs × rounds-per-job) / wall time
    let sched_rps =
        |jobs: usize, mean: f64| (jobs * sched_session_jobs) as f64 / mean.max(1e-12);
    let metrics = [
        ("session_rounds_per_sec_n64", 1.0 / round64),
        ("session_rounds_per_sec_n256", 1.0 / round256),
        ("sched_rounds_per_sec_jobs1_n64", sched_rps(1, sched_mean[0])),
        ("sched_rounds_per_sec_jobs4_n64", sched_rps(4, sched_mean[1])),
        ("sched_rounds_per_sec_jobs16_n64", sched_rps(16, sched_mean[2])),
        ("grid_search_shared_s", shared),
        ("grid_search_percand_rebuild_s", legacy),
        ("grid_search_speedup", legacy / shared),
        (
            "decode_plan_speedup_cold_vs_hit",
            mean_s(&b, "gc_decode_cold(n=256,s=15)") / mean_s(&b, "plan_cache_hit(n=256,s=15)"),
        ),
    ];
    b.save_snapshot("BENCH_4.json", &metrics);
}
