//! Fig. 2: (a) completed jobs vs clock time, (b) training loss vs clock
//! time, for all four schemes (averaged over repetitions).
//!
//! (a) uses the metadata simulator at the paper's scale; (b) attaches the
//! real-compute trainer when artifacts are available.

use sgc::experiments::{fast_mode, save_json, PaperSetup};
use sgc::util::json::Json;

fn main() {
    let setup = PaperSetup::table1();
    println!("== Fig 2(a): completed jobs vs time (n={}, J={}) ==\n", setup.n, setup.jobs);
    let mut json = Json::obj();
    let checkpoints = [0.25, 0.5, 0.75, 1.0];
    println!(
        "{:<12} {}",
        "scheme",
        checkpoints.map(|c| format!("t@{:3.0}% jobs", 100.0 * c)).join("  ")
    );
    let mut final_times = Vec::new();
    for (name, scheme) in setup.table1_schemes() {
        // average the completion curve over reps at fixed job counts
        let mut at = vec![0.0f64; checkpoints.len()];
        for rep in 0..setup.reps {
            let report = setup.run_once(&scheme, 2000 + rep as u64, false);
            let curve = report.completion_curve();
            for (k, &frac) in checkpoints.iter().enumerate() {
                let target = ((setup.jobs as f64) * frac).ceil() as usize;
                let t = curve
                    .iter()
                    .find(|&&(_, done)| done >= target)
                    .map(|&(t, _)| t)
                    .unwrap_or(report.total_runtime_s);
                at[k] += t / setup.reps as f64;
            }
        }
        println!(
            "{:<12} {}",
            name,
            at.iter().map(|t| format!("{t:>11.1}s")).collect::<Vec<_>>().join("  ")
        );
        let mut o = Json::obj();
        o.set("checkpoints_t_s", at.clone());
        json.set(name, o);
        final_times.push((name, *at.last().unwrap()));
    }
    let get = |n: &str| final_times.iter().find(|(k, _)| *k == n).unwrap().1;
    assert!(get("M-SGC") < get("No Coding"), "M-SGC curve must dominate");

    // Fig 2(b): loss vs time through the real-compute trainer.
    let artifacts = sgc::runtime::artifacts_dir();
    if artifacts.join("model.hlo.txt").exists() {
        println!("\n== Fig 2(b): training loss vs time (real PJRT compute) ==\n");
        use sgc::cluster::SimCluster;
        use sgc::straggler::GilbertElliot;
        use sgc::train::{Dataset, DatasetConfig, MultiModelTrainer, TrainConfig};
        use std::sync::Arc;
        let n = 16;
        let iters = if fast_mode() { 8 } else { 25 };
        let pool = Arc::new(sgc::runtime::ComputePool::new(artifacts, 4).expect("pool"));
        let dataset = Dataset::generate(DatasetConfig::default());
        let mut loss_json = Json::obj();
        for spec in ["m-sgc:1,2,4", "sr-sgc:2,3,4", "gc:2", "uncoded"] {
            let scheme = sgc::coding::SchemeConfig::parse(n, spec).unwrap();
            let cfg = TrainConfig {
                models: 4,
                iterations: iters,
                batch: 256,
                seed: 7,
                ..Default::default()
            };
            let mut tr =
                MultiModelTrainer::new(scheme, cfg, Arc::clone(&pool), dataset.clone()).unwrap();
            let mut cluster =
                SimCluster::from_gilbert_elliot(n, GilbertElliot::default_fit(n, 7), 31);
            let rep = tr.run(&mut cluster).expect("train");
            let c0 = &rep.losses[0];
            println!(
                "{spec:<14} model-0 loss {:.3} → {:.3} by sim t={:.0}s",
                c0.first().map(|p| p.loss).unwrap_or(f64::NAN),
                c0.last().map(|p| p.loss).unwrap_or(f64::NAN),
                rep.sim_runtime_s
            );
            let series: Vec<Json> = c0
                .iter()
                .map(|p| {
                    let mut o = Json::obj();
                    o.set("t", p.sim_time_s).set("loss", p.loss);
                    o
                })
                .collect();
            loss_json.set(spec, Json::Arr(series));
        }
        json.set("loss_vs_time_model0", loss_json);
    } else {
        println!("\n(fig 2(b) skipped: run `make artifacts` for the real-compute loss curves)");
    }
    save_json("fig2", &json);
}
