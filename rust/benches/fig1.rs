//! Fig. 1: response-time statistics of 256 workers across 100 rounds —
//! (a) straggler-map density, (b) burst-length histogram, (c) empirical
//! completion-time CDF.

use sgc::cluster::SimCluster;
use sgc::experiments::{fast_mode, save_json};
use sgc::straggler::{GilbertElliot, Pattern};
use sgc::util::json::Json;
use sgc::util::stats;

fn main() {
    let (n, rounds) = if fast_mode() { (64, 40) } else { (256, 100) };
    let mu = 1.0;
    let load = 1.0 / n as f64; // one MNIST-batch-sized task per worker
    let mut cluster = SimCluster::from_gilbert_elliot(n, GilbertElliot::default_fit(n, 7), 13);

    let mut detected = Pattern::new(n);
    let mut times = Vec::with_capacity(n * rounds);
    for _ in 0..rounds {
        let s = cluster.sample_round(&vec![load; n]);
        let kappa = s.finish.iter().cloned().fold(f64::INFINITY, f64::min);
        detected.push_round(s.finish.iter().map(|&f| f > (1.0 + mu) * kappa).collect());
        times.extend_from_slice(&s.finish);
    }

    println!("== Fig 1 (n={n}, {rounds} rounds, μ={mu}) ==\n");
    println!("(a) straggler map: {:.2}% white cells", 100.0 * detected.straggle_fraction());
    let per_round: Vec<f64> = (1..=rounds).map(|r| detected.count_in_round(r) as f64).collect();
    println!(
        "    stragglers/round mean {:.1} (min {:.0}, max {:.0})",
        stats::mean(&per_round),
        stats::min(&per_round),
        stats::max(&per_round)
    );

    println!("\n(b) burst-length histogram:");
    let bursts = detected.burst_lengths();
    let maxlen = bursts.iter().cloned().max().unwrap_or(1);
    let mut hist = vec![0usize; maxlen + 1];
    for &b in &bursts {
        hist[b] += 1;
    }
    for (len, &c) in hist.iter().enumerate().skip(1) {
        if c > 0 {
            println!("    len {len:>2}: {c:>5}");
        }
    }
    println!("    (paper shape: short isolated bursts dominate)");

    println!("\n(c) completion-time CDF:");
    let mut sorted = times.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for q in [25.0, 50.0, 75.0, 90.0, 95.0, 99.0] {
        println!("    p{q:<4}: {:>7.2}s", stats::percentile_sorted(&sorted, q));
    }
    let tail = stats::percentile_sorted(&sorted, 99.0) / stats::percentile_sorted(&sorted, 50.0);
    println!("    p99/p50 = {tail:.2} (long tail ⇒ stragglers)");
    assert!(tail > 1.5, "CDF must have a straggler tail");

    let mut json = Json::obj();
    json.set("straggle_fraction", detected.straggle_fraction())
        .set("stragglers_per_round_mean", stats::mean(&per_round))
        .set("burst_hist", hist.iter().map(|&c| c as u64).collect::<Vec<_>>())
        .set("cdf_p50", stats::percentile_sorted(&sorted, 50.0))
        .set("cdf_p99", stats::percentile_sorted(&sorted, 99.0));
    save_json("fig1", &json);
}
