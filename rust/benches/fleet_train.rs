//! End-to-end training bench for the gradient data plane (§Measurement):
//! GC vs SR-SGC vs M-SGC run real coded partial gradients over the
//! loopback TCP fleet — partitions shipped, MLP forward/backward at the
//! workers, β-decode + Adam at the master — and the measured wall-clock
//! per round is compared against the virtual-time simulator's prediction
//! for the *same* delay profile (the workers' own `base_s + α·load`
//! pacing model, jitter-free). The gap between the two columns is the
//! real-world overhead the simulator does not model: TCP, the reactor,
//! serialization and the gradient math itself.
//!
//! Emits the repo-level `BENCH_9.json` snapshot (per-scheme fleet vs
//! sim round times, their ratio, and the loss drop actually trained)
//! so the fleet/sim fidelity trajectory accumulates across PRs.

use sgc::bench_harness::Bench;
use sgc::cluster::{LatencyParams, SimCluster};
use sgc::coding::SchemeConfig;
use sgc::fleet::{LoopbackFleet, WorkerConfig};
use sgc::grad::{DataPlane, GradConfig, GradJobSummary, GradPump};
use sgc::sched::{drive_events, JobScheduler, JobSpec, JobStatus};
use sgc::session::SessionConfig;
use sgc::straggler::NoStragglers;
use std::time::Duration;

/// What one fleet training run leaves behind for the comparison table.
struct FleetRun {
    /// Mean protocol-clock round duration (real seconds on the fleet).
    round_s: f64,
    /// Rounds the session actually ran (≥ jobs for delayed schemes).
    rounds: usize,
    sum: GradJobSummary,
}

/// One full training run on a fresh loopback fleet: spawn, ship
/// partitions, train `jobs` paper jobs with real coded gradients,
/// shut down.
fn fleet_train(scheme: &SchemeConfig, jobs: usize, seed: u64) -> FleetRun {
    let n = scheme.n;
    let mut fleet = LoopbackFleet::spawn(n, None).expect("spawn fleet");
    let cfg = GradConfig { seed, batch: 64, train_size: 512, ..Default::default() };
    let mut pump = GradPump::new(DataPlane::shared(), cfg);
    fleet.cluster.set_dataplane(pump.dataplane());
    let out = {
        let mut sched = JobScheduler::new(&mut fleet.cluster);
        sched.set_dataplane(pump.dataplane());
        let spec = JobSpec {
            scheme: scheme.clone(),
            session: SessionConfig { jobs, ..Default::default() },
        };
        let j = sched.admit(&spec).expect("admit");
        pump.configure_job(j, scheme).expect("configure");
        sched.run_observed(&mut pump).expect("fleet run")
    };
    let _ = fleet.cluster.finish_trace(Duration::from_secs(5), 1.0);
    fleet.shutdown().expect("clean shutdown");
    assert!(
        out.outcomes.iter().all(|o| o.status == JobStatus::Completed),
        "healthy fleet run must complete: {:?}",
        out.outcomes
    );
    let rep = &out.reports[0];
    let sum = pump.summary().remove(0);
    assert_eq!(sum.steps, jobs, "every paper job must decode into an optimizer step");
    FleetRun { round_s: rep.mean_round_s(), rounds: rep.rounds.len(), sum }
}

/// The simulator's prediction for the identical workload: same scheme,
/// same job count, and the fleet workers' own pacing profile
/// (`WorkerConfig::{base_s, alpha_s}`) as a jitter-free latency model.
fn sim_predict(scheme: &SchemeConfig, jobs: usize, seed: u64) -> (f64, usize) {
    let n = scheme.n;
    let wc = WorkerConfig::loopback(0, String::new(), None);
    let params = LatencyParams {
        overhead_median_s: wc.base_s,
        overhead_sigma: 0.0,
        alpha_s_per_load: wc.alpha_s,
        compute_jitter: 0.0,
        ..Default::default()
    };
    let mut sim = SimCluster::new(n, params, Box::new(NoStragglers { n }), seed);
    let rep = drive_events(scheme, &SessionConfig { jobs, ..Default::default() }, &mut sim)
        .expect("sim prediction");
    (rep.mean_round_s(), rep.rounds.len())
}

fn main() {
    let fast = std::env::var("SGC_BENCH_FAST").ok().as_deref() == Some("1");
    let mut b = Bench::new("fleet_train");
    b.header();
    let n = 4;
    let jobs = if fast { 5 } else { 16 };
    let reps: u64 = if fast { 1 } else { 3 };
    let seed = 0x9_bea_c09u64;
    let schemes = [
        ("gc", SchemeConfig::gc(n, 1)),
        ("sr_sgc", SchemeConfig::sr_sgc(n, 1, 2, 1)),
        ("m_sgc", SchemeConfig::msgc(n, 1, 2, 1)),
    ];

    let mut metrics: Vec<(String, f64)> = Vec::new();
    for (key, scheme) in &schemes {
        let label = format!("fleet_train_{key}(n={n},jobs={jobs})");
        let mut last: Option<FleetRun> = None;
        b.run_n(&label, reps, || last = Some(fleet_train(scheme, jobs, seed)));
        let run = last.expect("run_n executed at least once");
        let (sim_round_s, sim_rounds) = sim_predict(scheme, jobs, seed ^ 0x51);
        if run.rounds != sim_rounds {
            // CI jitter can cost the fleet a re-attempt round; surface
            // the divergence instead of failing the bench on it
            println!("  {key}: fleet ran {} rounds, sim predicted {}", run.rounds, sim_rounds);
        }
        let ratio = run.round_s / sim_round_s.max(1e-12);
        let s = &run.sum;
        println!(
            "  {:<28} fleet {:>7.1} ms/round vs sim {:>7.1} ms predicted (x{:.2}); \
             loss {:.4} -> {:.4} over {} steps (fallbacks={})",
            scheme.label(),
            run.round_s * 1e3,
            sim_round_s * 1e3,
            ratio,
            s.first_loss,
            s.last_loss,
            s.steps,
            s.fallback_decodes,
        );
        assert!(
            s.last_loss < s.first_loss,
            "{key}: real training must reduce the loss: {:?}",
            s.losses
        );
        metrics.push((format!("{key}_fleet_round_s"), run.round_s));
        metrics.push((format!("{key}_sim_round_s"), sim_round_s));
        metrics.push((format!("{key}_fleet_vs_sim"), ratio));
        metrics.push((
            format!("{key}_loss_drop"),
            (s.first_loss - s.last_loss) / s.first_loss.abs().max(1e-12),
        ));
        metrics.push((format!("{key}_fallback_decodes"), s.fallback_decodes as f64));
    }

    b.save();
    metrics.push(("fleet_jobs".to_string(), jobs as f64));
    metrics.push(("fleet_workers".to_string(), n as f64));
    let named: Vec<(&str, f64)> = metrics.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    b.save_snapshot("BENCH_9.json", &named);
}
