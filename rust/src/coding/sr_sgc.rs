//! Selective-Reattempt Sequential Gradient Coding (SR-SGC) — Sec. 3.2.
//!
//! Base scheme is `(n, s)`-GC with `s = ⌈Bλ / (W-1+B)⌉`; whenever fewer
//! than `n-s` task results for job `t-B` arrived in round `t-B`, the
//! minimum necessary number of those tasks is re-attempted in round `t`
//! by workers that did not previously return them (Algorithm 1). Delay
//! `T = B`; load `(s+1)/n`.
//!
//! With `(s+1) | n`, the GC-Rep base of Appendix G applies and Algorithm 3
//! is used instead (`rep = true`): a worker whose *group* result was
//! already returned never re-attempts.
//!
//! Per-round state is compact (§Perf): the scheme records which job each
//! worker's unit targeted (`job_of`) and the responder history — no
//! `TaskDesc` storage — and `commit_round` / `decodable_with` reconstruct
//! deliveries from those, the latter through a reusable scratch ledger.

use super::gc::cyclic_support;
use super::scheme::{fill_tasks, JobLedger, Scheme, SchemeSpec, TaskDesc, ToleranceSpec, WorkUnit};
use std::cell::RefCell;
use std::collections::HashSet;
use std::sync::Arc;

/// SR-SGC design parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SrSgcParams {
    /// Worker count.
    pub n: usize,
    /// Maximum burst length `B`.
    pub b: usize,
    /// Window length `W = xB + 1`.
    pub w: usize,
    /// Maximum straggling workers per window `λ`.
    pub lambda: usize,
}

impl SrSgcParams {
    /// `s = ⌈Bλ / (W-1+B)⌉` (Sec. 3.2 design rule).
    pub fn s(&self) -> usize {
        (self.b * self.lambda).div_ceil(self.w - 1 + self.b)
    }

    /// Normalized load `(s+1)/n`.
    pub fn load(&self) -> f64 {
        (self.s() + 1) as f64 / self.n as f64
    }

    /// Panic unless the parameters satisfy the design constraints.
    pub fn validate(&self) {
        assert!(self.lambda > 0 && self.lambda <= self.n, "need 0 < λ ≤ n");
        assert!(self.b > 0, "need B > 0");
        assert!(self.w > 1 && (self.w - 1) % self.b == 0, "need W = xB + 1, x ≥ 1");
        assert!(self.s() < self.n, "s must be < n");
    }
}

/// SR-SGC scheme state (also covers SR-SGC-Rep when `rep`).
pub struct SrSgcScheme {
    spec: SchemeSpec,
    params: SrSgcParams,
    s: usize,
    rep: bool,
    jobs: usize,
    ledgers: Vec<JobLedger>,
    /// Per assigned round: the job each worker's single unit targets
    /// (`0` = noop). `job_of[r-1][i]`.
    job_of: Vec<Vec<usize>>,
    responded: Vec<Vec<bool>>,
    committed: usize,
    /// Chunk list of each worker's coded unit (cyclic support, or the
    /// replication group's chunks), shared into every assignment.
    chunk_sets: Vec<Arc<[usize]>>,
    /// Reusable `decodable_with` ledger (replaces `JobLedger::clone`).
    scratch: RefCell<JobLedger>,
}

impl SrSgcScheme {
    /// SR-SGC protocol state for a `jobs`-job run.
    pub fn new(params: SrSgcParams, jobs: usize) -> Self {
        Self::build(params, jobs, false)
    }

    /// SR-SGC-Rep (Algorithm 3); requires `(s+1) | n`.
    pub fn new_rep(params: SrSgcParams, jobs: usize) -> Self {
        assert_eq!(params.n % (params.s() + 1), 0, "SR-SGC-Rep needs (s+1) | n");
        Self::build(params, jobs, true)
    }

    fn build(params: SrSgcParams, jobs: usize, rep: bool) -> Self {
        params.validate();
        let n = params.n;
        let s = params.s();
        let placement: Vec<Vec<usize>> = if rep {
            (0..n).map(|i| Self::rep_group_chunks(i / (s + 1), s)).collect()
        } else {
            (0..n).map(|i| cyclic_support(i, s, n)).collect()
        };
        let chunk_sets: Vec<Arc<[usize]>> =
            placement.iter().map(|c| Arc::from(c.clone())).collect();
        let spec = SchemeSpec {
            name: format!(
                "sr-sgc{}(n={n},B={},W={},λ={},s={s})",
                if rep { "-rep" } else { "" },
                params.b,
                params.w,
                params.lambda
            ),
            n,
            delay: params.b,
            load: params.load(),
            num_chunks: n,
            chunk_sizes: vec![1.0 / n as f64; n],
            placement,
            tolerance: ToleranceSpec::BurstyOrPerRound {
                b: params.b,
                w: params.w,
                lambda: params.lambda,
                s,
            },
        };
        let ledgers = (0..jobs)
            .map(|_| {
                if rep {
                    let groups = n / (s + 1);
                    JobLedger {
                        plain_missing: HashSet::new(),
                        coded_got: vec![HashSet::with_capacity(s + 1); groups],
                        coded_need: vec![1; groups],
                    }
                } else {
                    JobLedger {
                        plain_missing: HashSet::new(),
                        coded_got: vec![HashSet::with_capacity(n)],
                        coded_need: vec![n - s],
                    }
                }
            })
            .collect();
        SrSgcScheme {
            spec,
            params,
            s,
            rep,
            jobs,
            ledgers,
            job_of: Vec::new(),
            responded: Vec::new(),
            committed: 0,
            chunk_sets,
            scratch: RefCell::new(JobLedger::empty()),
        }
    }

    /// The design parameters this instance was built with.
    pub fn params(&self) -> SrSgcParams {
        self.params
    }

    /// Effective `s` of the base GC code.
    pub fn s_value(&self) -> usize {
        self.s
    }

    fn rep_group_chunks(g: usize, s: usize) -> Vec<usize> {
        (g * (s + 1)..(g + 1) * (s + 1)).collect()
    }

    /// Ledger group of a worker's coded unit.
    fn group_of(&self, worker: usize) -> usize {
        if self.rep {
            worker / (self.s + 1)
        } else {
            0
        }
    }

    /// `N(t)`: number of task results for job `t` returned in round `t`.
    /// By the paper's convention, `N(t') = n` for `t' ∉ [1:J]`.
    fn n_of(&self, t: isize) -> usize {
        if t < 1 || t as usize > self.jobs {
            return self.spec.n;
        }
        let t = t as usize;
        if t > self.responded.len() {
            return 0; // round t not yet played
        }
        (0..self.spec.n)
            .filter(|&i| self.job_of[t - 1][i] == t && self.responded[t - 1][i])
            .count()
    }

    /// Did worker `i` return its task result for job `t-B` in round `t-B`?
    fn returned_in_round(&self, worker: usize, job: usize) -> bool {
        if job < 1 || job > self.responded.len() {
            return false;
        }
        self.job_of[job - 1][worker] == job && self.responded[job - 1][worker]
    }

    /// Did any worker of `worker`'s group return the group result for
    /// `job` in round `job`? (Rep variant, Algorithm 3.)
    fn group_returned_in_round(&self, worker: usize, job: usize) -> bool {
        if job < 1 || job > self.responded.len() {
            return false;
        }
        let g = worker / (self.s + 1);
        (g * (self.s + 1)..(g + 1) * (self.s + 1))
            .any(|m| self.job_of[job - 1][m] == job && self.responded[job - 1][m])
    }
}

impl Scheme for SrSgcScheme {
    fn spec(&self) -> &SchemeSpec {
        &self.spec
    }

    fn jobs(&self) -> usize {
        self.jobs
    }

    /// Algorithm 1 (Algorithm 3 when `rep`).
    fn assign_round_into(&mut self, r: usize, out: &mut Vec<TaskDesc>) {
        assert_eq!(r, self.job_of.len() + 1, "rounds must be assigned in order");
        assert_eq!(self.committed, self.job_of.len(), "previous round not committed");
        let n = self.spec.n;
        let old = r as isize - self.params.b as isize; // job t-B
        let mut delta = self.n_of(old);
        let mut jobs_r = vec![0usize; n];
        for (i, slot) in jobs_r.iter_mut().enumerate() {
            let reattempt_old = if old >= 1 && (old as usize) <= self.jobs {
                let old = old as usize;
                if self.rep && self.group_returned_in_round(i, old) {
                    // Algorithm 3 first branch: group already returned —
                    // never re-attempt.
                    false
                } else {
                    delta < n - self.s && !self.returned_in_round(i, old)
                }
            } else {
                false
            };
            if reattempt_old {
                *slot = old as usize;
                delta += 1;
            } else if r >= 1 && r <= self.jobs {
                *slot = r;
            } else {
                *slot = 0; // noop (round beyond J)
            }
        }
        let chunk_sets = &self.chunk_sets;
        let rep = self.rep;
        let s = self.s;
        fill_tasks(out, n, |i, task| {
            task.units.push(if jobs_r[i] == 0 {
                WorkUnit::Noop
            } else {
                WorkUnit::Coded {
                    job: jobs_r[i],
                    group: if rep { i / (s + 1) } else { 0 },
                    row: i,
                    chunks: Arc::clone(&chunk_sets[i]),
                }
            });
        });
        self.job_of.push(jobs_r);
    }

    fn commit_round(&mut self, r: usize, responded: &[bool]) {
        assert_eq!(r, self.committed + 1);
        assert_eq!(r, self.job_of.len(), "round not assigned");
        assert_eq!(responded.len(), self.spec.n);
        for (i, &ok) in responded.iter().enumerate() {
            if !ok {
                continue;
            }
            let job = self.job_of[r - 1][i];
            if job == 0 {
                continue;
            }
            let g = if self.rep { i / (self.s + 1) } else { 0 };
            self.ledgers[job - 1].coded_got[g].insert(i);
        }
        self.responded.push(responded.to_vec());
        self.committed = r;
    }

    fn decodable(&self, job: usize) -> bool {
        self.ledgers[job - 1].complete()
    }

    fn ledger(&self, job: usize) -> &JobLedger {
        &self.ledgers[job - 1]
    }

    fn decodable_with(&self, job: usize, r: usize, responded: &[bool]) -> bool {
        debug_assert_eq!(r, self.committed + 1);
        debug_assert_eq!(r, self.job_of.len());
        let mut scratch = self.scratch.borrow_mut();
        scratch.copy_into_from(&self.ledgers[job - 1]);
        let row = &self.job_of[r - 1];
        for (i, &ok) in responded.iter().enumerate() {
            if ok && row[i] == job {
                scratch.coded_got[self.group_of(i)].insert(i);
            }
        }
        scratch.complete()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_true(n: usize) -> Vec<bool> {
        vec![true; n]
    }

    #[test]
    fn s_formula_matches_paper_table1() {
        // Table 1: SR-SGC with B=2, W=3, λ=23 has s = 12 at n = 256.
        let p = SrSgcParams { n: 256, b: 2, w: 3, lambda: 23 };
        p.validate();
        assert_eq!(p.s(), 12);
        assert!((p.load() - 13.0 / 256.0).abs() < 1e-12);
    }

    #[test]
    fn no_stragglers_behaves_like_gc() {
        let p = SrSgcParams { n: 8, b: 1, w: 2, lambda: 3 };
        assert_eq!(p.s(), 2);
        let mut sch = SrSgcScheme::new(p, 5);
        sch.spec().validate();
        for r in 1..=5 {
            let tasks = sch.assign_round(r);
            // all units target job r
            for t in &tasks {
                assert_eq!(t.units[0].job(), Some(r));
            }
            sch.commit_round(r, &all_true(8));
            assert!(sch.decodable(r), "job {r} should decode in its own round");
        }
    }

    #[test]
    fn reattempts_after_burst() {
        // n=8, B=1, W=2, λ=3 → s=2. Round 1: 4 stragglers (> s) —
        // round 2 must re-attempt exactly (4 - s) = 2 job-1 tasks by
        // workers that failed in round 1.
        let p = SrSgcParams { n: 8, b: 1, w: 2, lambda: 3 };
        let mut sch = SrSgcScheme::new(p, 3);
        sch.assign_round(1);
        let resp1 = vec![false, false, false, false, true, true, true, true];
        assert!(!sch.decodable_with(1, 1, &resp1));
        sch.commit_round(1, &resp1);
        assert!(!sch.decodable(1));

        let tasks2 = sch.assign_round(2);
        let job1_reattempts: Vec<usize> = (0..8)
            .filter(|&i| tasks2[i].units[0].job() == Some(1))
            .collect();
        assert_eq!(job1_reattempts, vec![0, 1], "minimum re-attempts by failed workers");
        // per the bursty model round-2 workers 0,1 are now non-stragglers
        sch.commit_round(2, &all_true(8));
        assert!(sch.decodable(1), "job 1 decodes with delay B=1");
        // job 2 got only 6 results in round 2 (= n - s) → decodable too
        assert!(sch.decodable(2));
    }

    #[test]
    fn cascading_reattempts_resolve() {
        // Proof-of-Prop-3.1 shape: λ0 > s stragglers at t', then λ1 more
        // at t'+B; job t'+B finishes at t'+2B.
        let p = SrSgcParams { n: 8, b: 1, w: 3, lambda: 4 }; // s = ceil(4/3) = 2
        assert_eq!(p.s(), 2);
        let mut sch = SrSgcScheme::new(p, 4);
        sch.assign_round(1);
        // λ0 = 3 stragglers in round 1: workers 0,1,2
        let r1 = vec![false, false, false, true, true, true, true, true];
        sch.commit_round(1, &r1);
        assert!(!sch.decodable(1));
        let t2 = sch.assign_round(2);
        // 1 re-attempt (λ0 - s = 1) for job 1 by worker 0
        assert_eq!(t2[0].units[0].job(), Some(1));
        assert_eq!(t2[1].units[0].job(), Some(2));
        // λ1 = 2 stragglers in round 2: workers 3,4 (distinct from before)
        let r2 = vec![true, true, true, false, false, true, true, true];
        sch.commit_round(2, &r2);
        assert!(sch.decodable(1), "job 1 done at round 2 (delay B)");
        // job 2: results from workers 1,2,5,6,7 = 5 < n-s=6 → pending
        assert!(!sch.decodable(2));
        let t3 = sch.assign_round(3);
        // need 1 more job-2 result; by workers that did not return it
        let job2_workers: Vec<usize> =
            (0..8).filter(|&i| t3[i].units[0].job() == Some(2)).collect();
        assert_eq!(job2_workers.len(), 1);
        assert!([0usize, 3, 4].contains(&job2_workers[0]));
        sch.commit_round(3, &all_true(8));
        assert!(sch.decodable(2));
        assert!(sch.decodable(3));
    }

    #[test]
    fn rep_variant_group_shortcut() {
        // n=6, s=2 (B=1, W=2, λ=3 → s=2), groups {0,1,2} {3,4,5}.
        let p = SrSgcParams { n: 6, b: 1, w: 2, lambda: 3 };
        assert_eq!(p.s(), 2);
        let mut sch = SrSgcScheme::new_rep(p, 2);
        sch.assign_round(1);
        // group 0: worker 0 responds; group 1: all straggle.
        let r1 = vec![true, false, false, false, false, false];
        assert!(!sch.decodable_with(1, 1, &r1));
        sch.commit_round(1, &r1);
        let t2 = sch.assign_round(2);
        // workers 1,2 (group 0) must NOT re-attempt job 1 (their group
        // result was returned); some group-1 workers must.
        assert_eq!(t2[1].units[0].job(), Some(2));
        assert_eq!(t2[2].units[0].job(), Some(2));
        let reattempts: Vec<usize> =
            (0..6).filter(|&i| t2[i].units[0].job() == Some(1)).collect();
        assert!(!reattempts.is_empty());
        assert!(reattempts.iter().all(|&i| i >= 3));
        sch.commit_round(2, &all_true(6));
        assert!(sch.decodable(1));
    }

    #[test]
    fn tail_rounds_are_noop_except_reattempts() {
        let p = SrSgcParams { n: 4, b: 1, w: 2, lambda: 2 };
        let mut sch = SrSgcScheme::new(p, 2);
        sch.assign_round(1);
        sch.commit_round(1, &all_true(4));
        sch.assign_round(2);
        sch.commit_round(2, &all_true(4));
        // round 3 = J + T: no pending re-attempts → all noop
        let t3 = sch.assign_round(3);
        assert!(t3.iter().all(|t| t.is_trivial()));
    }
}
