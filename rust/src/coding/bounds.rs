//! Information-theoretic lower bounds on normalized load (Appendix F) and
//! the closed-form loads of all schemes — used by Fig. 11 and the
//! optimality tests of Remark F.1.

use super::m_sgc::MSgcParams;
use super::sr_sgc::SrSgcParams;

/// Theorem F.1: lower bound `L_B*` for any sequential gradient coding
/// scheme tolerating the `(B, W, λ)`-bursty straggler model.
pub fn bursty_lower_bound(n: usize, b: usize, w: usize, lambda: usize) -> f64 {
    assert!(b >= 1 && b <= w && lambda <= n);
    let (nf, bf, wf, lf) = (n as f64, b as f64, w as f64, lambda as f64);
    if b < w {
        (wf - 1.0 + bf) / (nf * (wf - 1.0) + bf * (nf - lf))
    } else {
        1.0 / (nf - lf)
    }
}

/// Theorem F.2: lower bound `L_A*` for the `(N, W', λ')`-arbitrary model.
pub fn arbitrary_lower_bound(n: usize, nn: usize, w_prime: usize, lambda_p: usize) -> f64 {
    assert!(nn <= w_prime && lambda_p <= n);
    let (nf, nnf, wf, lf) = (n as f64, nn as f64, w_prime as f64, lambda_p as f64);
    if nn < w_prime {
        wf / (nf * (wf - nnf) + nnf * (nf - lf))
    } else {
        1.0 / (nf - lf)
    }
}

/// `(n, s)`-GC load `(s+1)/n`.
pub fn gc_load(n: usize, s: usize) -> f64 {
    (s + 1) as f64 / n as f64
}

/// GC's required `s` against a `(B,W,λ)`-bursty adversary without
/// temporal coding (Remark 3.1): `s = λ` whenever `λ < n`.
pub fn gc_required_s_bursty(lambda: usize) -> usize {
    lambda
}

/// SR-SGC load for `{n, B, W, λ}`.
pub fn sr_sgc_load(n: usize, b: usize, w: usize, lambda: usize) -> f64 {
    SrSgcParams { n, b, w, lambda }.load()
}

/// M-SGC load for `{n, B, W, λ}` (equation 1).
pub fn m_sgc_load(n: usize, b: usize, w: usize, lambda: usize) -> f64 {
    MSgcParams { n, b, w, lambda }.load()
}

/// Multiplicative gap of M-SGC to the bursty lower bound.
pub fn m_sgc_gap(n: usize, b: usize, w: usize, lambda: usize) -> f64 {
    m_sgc_load(n, b, w, lambda) / bursty_lower_bound(n, b, w, lambda)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn msgc_optimal_at_lambda_n() {
        // Remark F.1: λ = n → optimal.
        for (n, b, w) in [(4, 1, 2), (8, 2, 4), (20, 3, 7)] {
            let gap = m_sgc_gap(n, b, w, n);
            assert!((gap - 1.0).abs() < 1e-9, "gap {gap} at n={n},B={b},W={w}");
        }
    }

    #[test]
    fn msgc_optimal_at_lambda_n_minus_1() {
        for (n, b, w) in [(4, 1, 2), (8, 2, 4), (20, 3, 7)] {
            let gap = m_sgc_gap(n, b, w, n - 1);
            assert!((gap - 1.0).abs() < 1e-9, "gap {gap} at n={n},B={b},W={w}");
        }
    }

    #[test]
    fn msgc_gap_shrinks_as_one_over_w() {
        // Remark F.1: for fixed n, B, λ, the gap decreases as O(1/W).
        let (n, b, lambda) = (20, 3, 4);
        let mut prev_excess = f64::INFINITY;
        for w in [4usize, 8, 16, 32, 64] {
            let excess = m_sgc_gap(n, b, w, lambda) - 1.0;
            assert!(excess >= -1e-12);
            assert!(excess < prev_excess, "excess not shrinking at W={w}");
            prev_excess = excess;
        }
        // and the W=64 gap is small
        assert!(prev_excess < 0.05, "gap {prev_excess}");
    }

    #[test]
    fn loads_never_beat_the_bound() {
        for n in [4usize, 8, 20] {
            for b in 1..3usize {
                for w in (b + 1)..6 {
                    for lambda in 0..=n {
                        let lb = bursty_lower_bound(n, b, w, lambda);
                        assert!(
                            m_sgc_load(n, b, w, lambda) >= lb - 1e-12,
                            "M-SGC beats bound at n={n},B={b},W={w},λ={lambda}"
                        );
                        if lambda >= 1 && (w - 1) % b == 0 {
                            assert!(
                                sr_sgc_load(n, b, w, lambda) >= lb - 1e-12,
                                "SR-SGC beats bound at n={n},B={b},W={w},λ={lambda}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn msgc_load_below_sr_sgc_load() {
        // Fig. 11 shape: for n=20, B=3, λ=4 and W = xB+1, M-SGC is
        // strictly cheaper than SR-SGC.
        for x in 1..=6usize {
            let w = 3 * x + 1;
            let m = m_sgc_load(20, 3, w, 4);
            let s = sr_sgc_load(20, 3, w, 4);
            assert!(m < s, "W={w}: m={m} s={s}");
        }
    }

    #[test]
    fn example_f1_matches_bound() {
        // Example F.1: n=4, B=1, W=2, λ=4 → M-SGC load 1/2 == L_B*.
        let lb = bursty_lower_bound(4, 1, 2, 4);
        assert!((lb - 0.5).abs() < 1e-12);
        assert!((m_sgc_load(4, 1, 2, 4) - lb).abs() < 1e-12);
        // SR-SGC needs 3/4 there.
        assert!((sr_sgc_load(4, 1, 2, 4) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn arbitrary_bound_edges() {
        // N = W' degenerates to 1/(n-λ').
        assert!((arbitrary_lower_bound(10, 4, 4, 3) - 1.0 / 7.0).abs() < 1e-12);
        // Larger window → smaller bound.
        let a = arbitrary_lower_bound(10, 2, 4, 3);
        let b = arbitrary_lower_bound(10, 2, 8, 3);
        assert!(b < a);
    }
}
