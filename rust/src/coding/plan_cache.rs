//! Process-wide cache of GC code plans (§Perf).
//!
//! Every consumer of a numeric `(n, s)`-GC code — the session's decode
//! timer, the multi-model trainer, the probe's grid search, the bench
//! harness and the fleet master (all of which drive sessions) — used to
//! build its own [`GcCode`]: 256 Cholesky-backed `s×s` solves per
//! construction at the paper's scale, repeated per session even though
//! the code for a given `(n, s, seed)` is a pure function. The
//! [`CodePlanCache`] constructs each code **once per process** and shares
//! it immutably; decode coefficients are memoized per responder set
//! behind a fixed-width [`ResponderMask`] so the hit path performs no
//! heap allocation (the key lives on the stack, the value is a shared
//! `Arc<[f64]>` — a refcount bump).
//!
//! Sharing is sound because everything cached is deterministic:
//! construction uses the fixed [`PLAN_SEED`], and a decode solve is a
//! pure function of `(B, responder set)` — two sessions racing on the
//! same subset compute bit-identical coefficients, and `or_insert` keeps
//! whichever arrived first (`tests/properties.rs` pins cached plans to
//! fresh solves bit for bit). Callers must pass responder sets in a
//! canonical (sorted) order: the mask key identifies the *set*, and the
//! returned β is aligned with the first `n-s` entries of the first
//! caller's ordering.

use super::gc::{
    responder_mask, solve_decode_coeffs, GcCode, ResponderMask, MAX_MEMOIZED_WORKERS,
};
use crate::util::linalg::Matrix;
use std::collections::HashMap;
use std::sync::{Arc, OnceLock, RwLock};

/// Construction seed shared by every cache consumer (the historical
/// `0xdec0de` the session and trainer both used).
pub const PLAN_SEED: u64 = 0xdec0de;

/// One immutable `(n, s)` code plus its shared decode-coefficient cache.
pub struct CodePlan {
    n: usize,
    s: usize,
    b: Matrix,
    /// β per responder set. Values have length `n - s`, aligned with the
    /// first `n - s` responders of the computing caller's order.
    coeffs: RwLock<HashMap<ResponderMask, Arc<[f64]>>>,
}

impl CodePlan {
    fn new(n: usize, s: usize) -> Self {
        let code = GcCode::new(n, s, PLAN_SEED);
        CodePlan { n, s, b: code.b, coeffs: RwLock::new(HashMap::new()) }
    }

    /// Worker count of this code.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Straggler tolerance of this code.
    pub fn s(&self) -> usize {
        self.s
    }

    /// The (immutable) `n × n` coefficient matrix `B`.
    pub fn b(&self) -> &Matrix {
        &self.b
    }

    /// Decode coefficients `β` with `Σ_k β_k B[workers[k],:] = 1ᵀ` over
    /// the first `n - s` responders (further responders carry implicit
    /// coefficient 0), shared across every session in the process.
    /// `None` if the set is too small or numerically undecodable.
    ///
    /// Hit path: a read lock, a stack-key lookup and an `Arc` clone — no
    /// heap allocation. `workers` must be sorted: the mask key identifies
    /// the responder *set*, so an unsorted caller would receive a β
    /// aligned to a different ordering (debug-asserted below). Codes
    /// beyond [`MAX_MEMOIZED_WORKERS`] solve per call without memoizing.
    pub fn decode_coeffs(&self, workers: &[usize]) -> Option<Arc<[f64]>> {
        let k = self.n - self.s;
        if workers.len() < k {
            return None;
        }
        let used = &workers[..k];
        debug_assert!(
            used.windows(2).all(|w| w[0] < w[1]),
            "decode_coeffs requires sorted responder ids (β is set-keyed)"
        );
        if self.n > MAX_MEMOIZED_WORKERS {
            return solve_decode_coeffs(&self.b, used).map(Into::into);
        }
        let key = responder_mask(used);
        if let Some(c) = self.coeffs.read().unwrap().get(&key) {
            return Some(Arc::clone(c));
        }
        // Miss: solve outside the write lock (solves are the expensive
        // part; racing duplicates are bit-identical and `or_insert`
        // keeps the first).
        let x = solve_decode_coeffs(&self.b, used)?;
        let arc: Arc<[f64]> = x.into();
        let mut map = self.coeffs.write().unwrap();
        Some(Arc::clone(map.entry(key).or_insert(arc)))
    }

    /// Number of memoized decode plans.
    pub fn cached_plans(&self) -> usize {
        self.coeffs.read().unwrap().len()
    }
}

/// Process-wide registry of [`CodePlan`]s keyed by `(n, s)`.
pub struct CodePlanCache {
    plans: RwLock<HashMap<(usize, usize), Arc<CodePlan>>>,
}

impl CodePlanCache {
    /// The global cache (created on first use).
    pub fn global() -> &'static CodePlanCache {
        static GLOBAL: OnceLock<CodePlanCache> = OnceLock::new();
        GLOBAL.get_or_init(|| CodePlanCache { plans: RwLock::new(HashMap::new()) })
    }

    /// Fetch (or construct, once per process) the `(n, s)` code plan.
    pub fn get(&self, n: usize, s: usize) -> Arc<CodePlan> {
        if let Some(p) = self.plans.read().unwrap().get(&(n, s)) {
            return Arc::clone(p);
        }
        // Construct outside the write lock: GcCode::new is the expensive
        // part, and a racing duplicate is deterministic (fixed seed) —
        // `or_insert` keeps exactly one.
        let plan = Arc::new(CodePlan::new(n, s));
        let mut map = self.plans.write().unwrap();
        Arc::clone(map.entry((n, s)).or_insert(plan))
    }

    /// Number of distinct `(n, s)` codes constructed so far.
    pub fn len(&self) -> usize {
        self.plans.read().unwrap().len()
    }

    /// No codes constructed yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_cache_shares_plans() {
        let a = CodePlanCache::global().get(12, 3);
        let b = CodePlanCache::global().get(12, 3);
        assert!(Arc::ptr_eq(&a, &b), "same (n, s) must share one plan");
        assert_eq!(a.n(), 12);
        assert_eq!(a.s(), 3);
    }

    #[test]
    fn plan_decode_matches_gc_code() {
        let plan = CodePlanCache::global().get(10, 2);
        let mut code = GcCode::new(10, 2, PLAN_SEED);
        let workers: Vec<usize> = (0..8).collect();
        let cached = plan.decode_coeffs(&workers).expect("decodable");
        let fresh = code.decode_coeffs(&workers).expect("decodable");
        assert_eq!(cached.len(), fresh.len());
        for (a, b) in cached.iter().zip(fresh) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn plan_hit_returns_shared_allocation() {
        let plan = CodePlanCache::global().get(9, 2);
        let workers: Vec<usize> = (1..8).collect();
        let first = plan.decode_coeffs(&workers).unwrap();
        let hits_before = plan.cached_plans();
        let second = plan.decode_coeffs(&workers).unwrap();
        assert!(Arc::ptr_eq(&first, &second), "hit must reuse the cached allocation");
        assert_eq!(plan.cached_plans(), hits_before);
    }

    #[test]
    fn plan_rejects_undecodable_sets() {
        let plan = CodePlanCache::global().get(8, 2);
        assert!(plan.decode_coeffs(&[0, 1, 2]).is_none(), "too few responders");
    }
}
