//! The sequential-gradient-coding scheme abstraction (Sec. 2 of the paper).
//!
//! A scheme answers three questions for the master:
//!
//! 1. **Placement** — how the dataset is chunked and which chunks each
//!    worker stores (`SchemeSpec`).
//! 2. **Assignment** — which work units each worker attempts in round `t`,
//!    possibly depending on past straggler outcomes
//!    ([`Scheme::assign_round_into`]).
//! 3. **Decodability** — given the responses recorded so far, can job `t`
//!    be decoded ([`Scheme::decodable`])?
//!
//! Work units are *metadata*: the simulator only needs to know what was
//! attempted and what arrived; the real-compute trainer additionally maps
//! units to PJRT executions and numeric encode/decode (see
//! [`crate::coding::gc::GcCode`] and [`crate::train`]).
//!
//! Assignment is allocation-conscious (§Perf): chunk lists inside
//! [`WorkUnit::Coded`] are shared `Arc<[usize]>` slices precomputed at
//! scheme construction, and [`Scheme::assign_round_into`] refills a
//! caller-owned task buffer, so a steady-state round assigns `n` tasks
//! without touching the heap.

use std::collections::HashSet;
use std::sync::Arc;

/// One unit of work inside a worker's task for a round.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WorkUnit {
    /// Trivial unit (job index out of `[1:J]`) — costs nothing.
    Noop,
    /// Compute the partial gradient `g_chunk(job)` and return it raw.
    Plain { job: usize, chunk: usize },
    /// Compute partial gradients for every chunk in `chunks` and return
    /// their GC-encoded linear combination `ℓ_{worker,group}(job)`.
    /// `row` selects the encoding row in the scheme's GC coefficient
    /// matrix (== worker index for all schemes in the paper). The chunk
    /// list is a shared slice: cloning a unit bumps a refcount instead of
    /// copying the ids.
    Coded { job: usize, group: usize, row: usize, chunks: Arc<[usize]> },
}

impl WorkUnit {
    /// Job this unit contributes to, if any.
    pub fn job(&self) -> Option<usize> {
        match self {
            WorkUnit::Noop => None,
            WorkUnit::Plain { job, .. } | WorkUnit::Coded { job, .. } => Some(*job),
        }
    }
}

/// Task assigned to one worker for one round (a sequence of mini-tasks; a
/// single-unit task for GC/SR-SGC, `W-1+B` units for M-SGC).
#[derive(Clone, Debug, Default)]
pub struct TaskDesc {
    /// Mini-tasks in assignment order.
    pub units: Vec<WorkUnit>,
}

impl TaskDesc {
    /// A do-nothing assignment (idle worker this round).
    pub fn noop() -> Self {
        TaskDesc { units: vec![WorkUnit::Noop] }
    }

    /// Every unit is a no-op.
    pub fn is_trivial(&self) -> bool {
        self.units.iter().all(|u| matches!(u, WorkUnit::Noop))
    }
}

/// Reset `out` to `n` tasks — reusing both the outer buffer and each
/// task's `units` allocation — and fill task `i` through `fill(i, task)`.
/// The workhorse behind every scheme's [`Scheme::assign_round_into`].
pub fn fill_tasks(
    out: &mut Vec<TaskDesc>,
    n: usize,
    mut fill: impl FnMut(usize, &mut TaskDesc),
) {
    out.resize_with(n, TaskDesc::default);
    for (i, task) in out.iter_mut().enumerate() {
        task.units.clear();
        fill(i, task);
    }
}

/// Which deterministic straggler models a scheme was designed against —
/// drives the master's wait-out conformance repair (Remark 2.3).
#[derive(Clone, Debug, PartialEq)]
pub enum ToleranceSpec {
    /// Classical GC: at most `s` stragglers per round.
    PerRound { s: usize },
    /// SR-SGC (Prop 3.1): within every window of `W` rounds, either the
    /// `(B,W,λ)`-bursty constraints hold or there are at most `s`
    /// stragglers per round.
    BurstyOrPerRound { b: usize, w: usize, lambda: usize, s: usize },
    /// M-SGC (Prop 3.2): the pattern conforms to the `(B,W,λ)`-bursty
    /// model or to the `(N=B, W'=W+B-1, λ'=λ)`-arbitrary model.
    BurstyOrArbitrary { b: usize, w: usize, lambda: usize },
    /// Uncoded: no stragglers tolerated (master waits for everyone).
    None,
}

/// Static description of a scheme instance.
#[derive(Clone, Debug)]
pub struct SchemeSpec {
    /// Human-readable label, e.g. `gc(n=256,s=15)`.
    pub name: String,
    /// Number of workers.
    pub n: usize,
    /// Decoding delay `T`: job `t` must decode by end of round `t + T`.
    pub delay: usize,
    /// Normalized per-worker per-round computational load `L`.
    pub load: f64,
    /// Number of data chunks `η`.
    pub num_chunks: usize,
    /// Fraction of the dataset in each chunk (sums to 1).
    pub chunk_sizes: Vec<f64>,
    /// `D_i` — chunk ids stored at worker `i`.
    pub placement: Vec<Vec<usize>>,
    /// Design straggler model for conformance repair.
    pub tolerance: ToleranceSpec,
}

impl SchemeSpec {
    /// Sanity-check internal consistency (used by tests).
    pub fn validate(&self) {
        assert_eq!(self.chunk_sizes.len(), self.num_chunks);
        let total: f64 = self.chunk_sizes.iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "chunk sizes sum to {total}");
        assert_eq!(self.placement.len(), self.n);
        for d in &self.placement {
            for &c in d {
                assert!(c < self.num_chunks);
            }
        }
    }

    /// Per-round normalized load implied by a task (sum of chunk fractions
    /// the worker touches).
    pub fn task_load(&self, task: &TaskDesc) -> f64 {
        task.units
            .iter()
            .map(|u| match u {
                WorkUnit::Noop => 0.0,
                WorkUnit::Plain { chunk, .. } => self.chunk_sizes[*chunk],
                WorkUnit::Coded { chunks, .. } => {
                    chunks.iter().map(|&c| self.chunk_sizes[c]).sum()
                }
            })
            .sum()
    }
}

/// What a decoded job still needs. Kept per job by every scheme through
/// the shared [`JobLedger`].
#[derive(Clone, Debug)]
pub struct JobLedger {
    /// Plain chunks still missing.
    pub plain_missing: HashSet<usize>,
    /// Per coded group: distinct workers whose ℓ has arrived.
    pub coded_got: Vec<HashSet<usize>>,
    /// Per coded group: how many distinct results decode requires
    /// (`n - s`), or for replication groups, `1`.
    pub coded_need: Vec<usize>,
}

impl JobLedger {
    /// An empty ledger (nothing needed, nothing delivered) — the initial
    /// state of every scheme's reusable `decodable_with` scratch.
    pub fn empty() -> Self {
        JobLedger {
            plain_missing: HashSet::new(),
            coded_got: Vec::new(),
            coded_need: Vec::new(),
        }
    }

    /// Copy `src`'s state into `self`, reusing `self`'s allocations
    /// (hash tables, vectors). The allocation-free replacement for
    /// `JobLedger::clone` on the per-round `decodable_with` path: after
    /// warmup the scratch ledger's capacity covers every job's state.
    pub fn copy_into_from(&mut self, src: &JobLedger) {
        self.plain_missing.clear();
        self.plain_missing.extend(src.plain_missing.iter().copied());
        self.coded_got.truncate(src.coded_got.len());
        while self.coded_got.len() < src.coded_got.len() {
            self.coded_got.push(HashSet::new());
        }
        for (dst, s) in self.coded_got.iter_mut().zip(&src.coded_got) {
            dst.clear();
            dst.extend(s.iter().copied());
        }
        self.coded_need.clear();
        self.coded_need.extend_from_slice(&src.coded_need);
    }

    /// Every chunk's contribution is recoverable.
    pub fn complete(&self) -> bool {
        self.plain_missing.is_empty()
            && self.coded_got.iter().zip(&self.coded_need).all(|(g, &k)| g.len() >= k)
    }

    /// Apply one delivered unit from `worker`.
    pub fn deliver(&mut self, worker: usize, unit: &WorkUnit) {
        match unit {
            WorkUnit::Noop => {}
            WorkUnit::Plain { chunk, .. } => {
                self.plain_missing.remove(chunk);
            }
            WorkUnit::Coded { group, .. } => {
                self.coded_got[*group].insert(worker);
            }
        }
    }
}

/// Core scheme interface used by the coordinator and the simulator.
///
/// Protocol: for each round `r = 1, 2, …` in order, the master calls
/// [`assign_round_into`](Scheme::assign_round_into) (or the allocating
/// [`assign_round`](Scheme::assign_round) wrapper), executes the tasks,
/// then calls [`commit_round`](Scheme::commit_round) with the final
/// responder set (after any wait-outs).
/// [`decodable_with`](Scheme::decodable_with) supports the wait-out
/// policy's tentative evaluation before a commit.
pub trait Scheme: Send {
    /// Static parameters of this instance.
    fn spec(&self) -> &SchemeSpec;

    /// Produce task assignments for round `r` (1-based) into `out`,
    /// reusing its buffers (see [`fill_tasks`]). Must be called in round
    /// order, after the previous round was committed. Schemes do not
    /// retain the task list: `commit_round` and `decodable_with`
    /// reconstruct deliveries from the scheme's own compact state, so the
    /// caller owns the only copy.
    fn assign_round_into(&mut self, r: usize, out: &mut Vec<TaskDesc>);

    /// Allocating convenience wrapper over
    /// [`assign_round_into`](Scheme::assign_round_into).
    fn assign_round(&mut self, r: usize) -> Vec<TaskDesc> {
        let mut out = Vec::new();
        self.assign_round_into(r, &mut out);
        out
    }

    /// Record the final responder set for round `r`.
    fn commit_round(&mut self, r: usize, responded: &[bool]);

    /// Is job `t` decodable from everything committed so far?
    fn decodable(&self, job: usize) -> bool;

    /// Delivery ledger of a job (what arrived, what is still needed) —
    /// the master uses it to derive the decode workload (Table 4).
    fn ledger(&self, job: usize) -> &JobLedger;

    /// Would job `t` be decodable if, additionally, round `r`'s responders
    /// were `responded`? (`r` must be the currently assigned, uncommitted
    /// round.)
    fn decodable_with(&self, job: usize, r: usize, responded: &[bool]) -> bool;

    /// Number of jobs `J` this instance was constructed for.
    fn jobs(&self) -> usize;

    /// Total rounds `J + T`.
    fn total_rounds(&self) -> usize {
        self.jobs() + self.spec().delay
    }

    /// The job whose decode deadline is the end of round `r`, if in range.
    ///
    /// Uses checked arithmetic: any `delay ≥ r` (including delays beyond
    /// `isize::MAX`, which the previous `as isize` casts silently
    /// wrapped on) simply means no job is due yet.
    fn deadline_job(&self, r: usize) -> Option<usize> {
        let t = r.checked_sub(self.spec().delay)?;
        (1..=self.jobs()).contains(&t).then_some(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_ledger_plain_and_coded() {
        let mut l = JobLedger {
            plain_missing: [0usize, 1].into_iter().collect(),
            coded_got: vec![HashSet::new()],
            coded_need: vec![2],
        };
        assert!(!l.complete());
        l.deliver(0, &WorkUnit::Plain { job: 1, chunk: 0 });
        l.deliver(1, &WorkUnit::Plain { job: 1, chunk: 1 });
        assert!(!l.complete());
        l.deliver(0, &WorkUnit::Coded { job: 1, group: 0, row: 0, chunks: Vec::new().into() });
        // dup worker
        l.deliver(0, &WorkUnit::Coded { job: 1, group: 0, row: 0, chunks: Vec::new().into() });
        assert!(!l.complete());
        l.deliver(3, &WorkUnit::Coded { job: 1, group: 0, row: 3, chunks: Vec::new().into() });
        assert!(l.complete());
    }

    #[test]
    fn ledger_copy_into_from_matches_clone() {
        let src = JobLedger {
            plain_missing: [3usize, 7].into_iter().collect(),
            coded_got: vec![[1usize, 2].into_iter().collect(), HashSet::new()],
            coded_need: vec![2, 1],
        };
        let mut scratch = JobLedger::empty();
        scratch.copy_into_from(&src);
        assert_eq!(scratch.plain_missing, src.plain_missing);
        assert_eq!(scratch.coded_got, src.coded_got);
        assert_eq!(scratch.coded_need, src.coded_need);
        // reuse with a smaller source: stale state must not leak
        let small = JobLedger {
            plain_missing: HashSet::new(),
            coded_got: vec![HashSet::new()],
            coded_need: vec![4],
        };
        scratch.copy_into_from(&small);
        assert!(scratch.plain_missing.is_empty());
        assert_eq!(scratch.coded_got.len(), 1);
        assert!(scratch.coded_got[0].is_empty());
        assert_eq!(scratch.coded_need, vec![4]);
    }

    #[test]
    fn fill_tasks_reuses_and_resizes() {
        let mut buf: Vec<TaskDesc> = Vec::new();
        fill_tasks(&mut buf, 3, |i, t| {
            t.units.push(WorkUnit::Plain { job: 1, chunk: i });
        });
        assert_eq!(buf.len(), 3);
        assert_eq!(buf[2].units, vec![WorkUnit::Plain { job: 1, chunk: 2 }]);
        // shrink: stale tasks are dropped, survivors refilled
        fill_tasks(&mut buf, 2, |_, t| t.units.push(WorkUnit::Noop));
        assert_eq!(buf.len(), 2);
        assert!(buf.iter().all(|t| t.is_trivial()));
        // grow again
        fill_tasks(&mut buf, 4, |i, t| {
            t.units.push(WorkUnit::Plain { job: 2, chunk: i });
        });
        assert_eq!(buf.len(), 4);
        assert_eq!(buf[3].units[0], WorkUnit::Plain { job: 2, chunk: 3 });
    }

    /// Minimal scheme for exercising the trait's default methods.
    struct DummyScheme {
        spec: SchemeSpec,
        jobs: usize,
        ledger: JobLedger,
    }

    impl DummyScheme {
        fn with_delay(delay: usize, jobs: usize) -> Self {
            DummyScheme {
                spec: SchemeSpec {
                    name: "dummy".into(),
                    n: 1,
                    delay,
                    load: 1.0,
                    num_chunks: 1,
                    chunk_sizes: vec![1.0],
                    placement: vec![vec![0]],
                    tolerance: ToleranceSpec::None,
                },
                jobs,
                ledger: JobLedger::empty(),
            }
        }
    }

    impl Scheme for DummyScheme {
        fn spec(&self) -> &SchemeSpec {
            &self.spec
        }
        fn assign_round_into(&mut self, _r: usize, out: &mut Vec<TaskDesc>) {
            fill_tasks(out, 1, |_, t| t.units.push(WorkUnit::Noop));
        }
        fn commit_round(&mut self, _r: usize, _responded: &[bool]) {}
        fn decodable(&self, _job: usize) -> bool {
            true
        }
        fn ledger(&self, _job: usize) -> &JobLedger {
            &self.ledger
        }
        fn decodable_with(&self, _job: usize, _r: usize, _responded: &[bool]) -> bool {
            true
        }
        fn jobs(&self) -> usize {
            self.jobs
        }
    }

    #[test]
    fn assign_round_wrapper_delegates() {
        let mut s = DummyScheme::with_delay(0, 1);
        let tasks = s.assign_round(1);
        assert_eq!(tasks.len(), 1);
        assert!(tasks[0].is_trivial());
    }

    #[test]
    fn deadline_job_uses_checked_arithmetic() {
        // delay = 0: job t is due at round t, nothing after J.
        let s = DummyScheme::with_delay(0, 3);
        assert_eq!(s.deadline_job(1), Some(1));
        assert_eq!(s.deadline_job(3), Some(3));
        assert_eq!(s.deadline_job(4), None);

        // delay = 2: rounds 1..2 have no due job (r - delay ≤ 0).
        let s = DummyScheme::with_delay(2, 3);
        assert_eq!(s.deadline_job(1), None);
        assert_eq!(s.deadline_job(2), None);
        assert_eq!(s.deadline_job(3), Some(1));
        assert_eq!(s.deadline_job(5), Some(3));

        // Pathological delays (beyond isize::MAX) must not wrap: the old
        // `as isize` cast turned these into bogus positive job indices.
        let s = DummyScheme::with_delay(usize::MAX, 3);
        assert_eq!(s.deadline_job(1), None);
        assert_eq!(s.deadline_job(usize::MAX), None); // t = 0 is out of range
        let s = DummyScheme::with_delay(usize::MAX - 1, 3);
        assert_eq!(s.deadline_job(usize::MAX), Some(1));
    }

    #[test]
    fn task_load_sums_chunks() {
        let spec = SchemeSpec {
            name: "t".into(),
            n: 2,
            delay: 0,
            load: 0.75,
            num_chunks: 4,
            chunk_sizes: vec![0.25; 4],
            placement: vec![vec![0, 1, 2], vec![1, 2, 3]],
            tolerance: ToleranceSpec::None,
        };
        spec.validate();
        let task = TaskDesc {
            units: vec![
                WorkUnit::Plain { job: 1, chunk: 0 },
                WorkUnit::Coded { job: 1, group: 0, row: 0, chunks: vec![1, 2].into() },
                WorkUnit::Noop,
            ],
        };
        assert!((spec.task_load(&task) - 0.75).abs() < 1e-12);
    }
}
