//! Classical `(n, s)` Gradient Coding (Tandon et al. 2017) — Sec. 3.1.
//!
//! Two pieces live here:
//!
//! * [`GcCode`] — the numeric code: the cyclic-support coefficient matrix
//!   `B` (worker `i` returns `ℓ_i = Σ_{j ∈ [i:i+s]*} α_{i,j} g_j`) and the
//!   decoder that finds `β` with `Σ_w β_w B[w,:] = 1ᵀ` for any responding
//!   set of ≥ `n-s` workers. Decoding solves the consistent system via
//!   normal equations (see [`crate::util::linalg`]); coefficients are
//!   memoized per straggler pattern behind a fixed-width responder
//!   bitmask, which is the L3 hot-path optimization the §Perf pass
//!   measures. For a cache *shared across sessions* see
//!   [`crate::coding::CodePlanCache`].
//! * [`GcScheme`] — GC applied to the sequential setting (delay `T = 0`,
//!   every worker computes `ℓ_i(t)` in round `t`).
//!
//! The `(s+1) | n` replication simplification of Appendix G ("GC-Rep") is
//! [`GcRepScheme`]: workers are partitioned into `n/(s+1)` groups; each
//! group replicates the plain sum of its `s+1` chunks, so decode is the
//! trivial sum of one response per group.

use super::scheme::{fill_tasks, JobLedger, Scheme, SchemeSpec, TaskDesc, ToleranceSpec, WorkUnit};
use crate::util::linalg::{self, Matrix};
use crate::util::rng::Pcg32;
use std::cell::RefCell;
use std::collections::HashMap;
use std::collections::HashSet;
use std::sync::Arc;

/// The cyclic support `[i : i+s]* = {i mod n, …, (i+s) mod n}`.
pub fn cyclic_support(i: usize, s: usize, n: usize) -> Vec<usize> {
    (0..=s).map(|k| (i + k) % n).collect()
}

/// Fixed-width responder bitmask: bit `w` set ⇔ worker `w` responded.
/// Covers the paper's maximum cluster size (`n ≤ 256`) without heap
/// allocation, so cache lookups on the decode hot path never allocate.
pub type ResponderMask = [u64; 4];

/// Largest cluster size the fixed-width [`ResponderMask`] covers —
/// decode-coefficient *memoization* is limited to codes this size;
/// larger codes still decode, paying a fresh solve per call.
pub const MAX_MEMOIZED_WORKERS: usize = 256;

/// Build the fixed-width bitmask key for a responder set (all ids < 256).
#[inline]
pub fn responder_mask(workers: &[usize]) -> ResponderMask {
    let mut mask = [0u64; 4];
    for &w in workers {
        debug_assert!(w < MAX_MEMOIZED_WORKERS);
        mask[w >> 6] |= 1 << (w & 63);
    }
    mask
}

/// Solve for decode coefficients over the given rows of `b`: `β` with
/// `Σ_k β_k b[used[k],:] = 1ᵀ`, aligned with `used`. Normal equations +
/// iterative refinement (see [`GcCode::decode_coeffs`]); `None` when the
/// subset is numerically undecodable. Shared by the per-instance
/// [`GcCode`] cache and the process-wide
/// [`CodePlan`](crate::coding::CodePlan).
pub(crate) fn solve_decode_coeffs(b: &Matrix, used: &[usize]) -> Option<Vec<f64>> {
    let k = used.len();
    let n = b.cols;
    let mut a = Matrix::zeros(k, n);
    for (r, &w) in used.iter().enumerate() {
        a.row_mut(r).copy_from_slice(b.row(w));
    }
    let ones = vec![1.0; n];
    // Normal equations + iterative-refinement sweeps: the Gram matrix
    // squares the conditioning, refinement recovers the lost digits
    // (worst-case residual ~1e-10 at n=256 in calibration). The factor
    // and solve scratch live in caller-owned buffers reused across the
    // refinement sweeps.
    let gram = a.gram_rows();
    let mut l = Matrix::zeros(k, k);
    if !linalg::cholesky_into(&gram, &mut l) {
        return None;
    }
    let mut y = Vec::with_capacity(k);
    let mut x = Vec::with_capacity(k);
    linalg::cholesky_solve_into(&l, &a.matvec(&ones), &mut y, &mut x);
    let mut dx = Vec::with_capacity(k);
    for _ in 0..8 {
        if linalg::residual_inf(&a, &x, &ones) <= 1e-8 {
            break;
        }
        let atx = a.tr_matvec(&x);
        let resid: Vec<f64> = ones.iter().zip(&atx).map(|(o, v)| o - v).collect();
        linalg::cholesky_solve_into(&l, &a.matvec(&resid), &mut y, &mut dx);
        for (xi, di) in x.iter_mut().zip(&dx) {
            *xi += di;
        }
    }
    if linalg::residual_inf(&a, &x, &ones) > 1e-5 {
        return None;
    }
    Some(x)
}

/// Numeric `(n, s)`-GC code.
#[derive(Clone, Debug)]
pub struct GcCode {
    /// Worker count.
    pub n: usize,
    /// Straggler tolerance per round.
    pub s: usize,
    /// Dense `n × n` coefficient matrix with cyclic support.
    pub b: Matrix,
    /// Decode coefficient cache keyed by the fixed-width responder
    /// bitmask (`n ≤ 256` only). Values have length `n - s`, aligned
    /// with the first `n - s` responders handed to
    /// [`Self::decode_coeffs`].
    cache: HashMap<ResponderMask, Vec<f64>>,
    /// Result slot for unmemoized solves (`n > 256`, beyond the
    /// fixed-width mask): reused per call so the borrowed-return API is
    /// uniform.
    spill: Vec<f64>,
}

impl GcCode {
    /// Tandon et al. Algorithm-2 construction: draw a random
    /// `H ∈ R^{s×n}` whose columns sum to zero (so `H·1 = 0`), then choose
    /// every row `b_i` inside `null(H)` with cyclic support `[i:i+s]*` and
    /// `b_i[i] = 1`. All rows live in the `(n-s)`-dimensional `null(H)`
    /// which contains `1`; any `n-s` rows are generically independent and
    /// therefore span it — every `(n-s)`-subset decodes with probability
    /// 1. [`Self::verify_random_subsets`] spot-checks the genericity.
    pub fn new(n: usize, s: usize, seed: u64) -> Self {
        assert!(s < n, "need s < n");
        let mut rng = Pcg32::new(seed, 0x6c0de);
        let mut b = Matrix::zeros(n, n);
        if s == 0 {
            // degenerate: every worker returns its own partial gradient
            for i in 0..n {
                b[(i, i)] = 1.0;
            }
            return GcCode { n, s, b, cache: HashMap::new(), spill: Vec::new() };
        }
        // H with columns summing to zero: H·1 = 0.
        let mut h = Matrix::zeros(s, n);
        for r in 0..s {
            let mut sum = 0.0;
            for c in 0..n - 1 {
                let v = rng.normal();
                h[(r, c)] = v;
                sum += v;
            }
            h[(r, n - 1)] = -sum;
        }
        // Row i: b_i[i] = 1; remaining support entries y solve
        // H[:, rest] · y = -H[:, i].
        for i in 0..n {
            let support = cyclic_support(i, s, n);
            let rest = &support[1..];
            let mut sub = Matrix::zeros(s, s);
            for (c, &col) in rest.iter().enumerate() {
                for r in 0..s {
                    sub[(r, c)] = h[(r, col)];
                }
            }
            let rhs: Vec<f64> = (0..s).map(|r| -h[(r, i)]).collect();
            let y = linalg::solve_square(&sub, &rhs)
                .expect("generic H gives nonsingular subsystems");
            b[(i, i)] = 1.0;
            for (&col, &v) in rest.iter().zip(&y) {
                b[(i, col)] = v;
            }
        }
        // Row-normalize: unit-norm rows keep the decode Gram matrix well
        // conditioned (near-singular H subsystems otherwise blow row
        // magnitudes up to ~1e2-1e3).
        for i in 0..n {
            let norm = linalg::dot(b.row(i), b.row(i)).sqrt();
            for v in b.row_mut(i) {
                *v /= norm;
            }
        }
        GcCode { n, s, b, cache: HashMap::new(), spill: Vec::new() }
    }

    /// Encode: combine the `s+1` partial-gradient vectors computed by
    /// worker `row` into the single task result `ℓ_row`.
    ///
    /// `partials[k]` is the gradient w.r.t. chunk `(row + k) mod n` (the
    /// cyclic support, in order).
    pub fn encode(&self, row: usize, partials: &[&[f32]]) -> Vec<f32> {
        let mut out = Vec::new();
        self.encode_into(row, partials, &mut out);
        out
    }

    /// [`Self::encode`] into a caller-owned buffer (cleared, zero-filled,
    /// accumulated via the chunked [`linalg::axpy_f32`] kernel).
    pub fn encode_into(&self, row: usize, partials: &[&[f32]], out: &mut Vec<f32>) {
        assert_eq!(partials.len(), self.s + 1);
        let dim = partials[0].len();
        out.clear();
        out.resize(dim, 0.0);
        for (k, part) in partials.iter().enumerate() {
            let chunk = (row + k) % self.n;
            let alpha = self.b[(row, chunk)] as f32;
            debug_assert_eq!(part.len(), dim);
            linalg::axpy_f32(out, alpha, part);
        }
    }

    /// Decode coefficients for a responder set: `β` such that
    /// `Σ_k β_k B[workers[k],:] = 1ᵀ` over the first `n - s` responders
    /// (the code's decode threshold; further responders carry implicit
    /// coefficient 0). Returns `None` if the set is too small or
    /// (numerically) undecodable.
    ///
    /// Results are memoized per responder set: round-over-round straggler
    /// patterns repeat heavily (GE model dwell times), so the cache hit
    /// rate in long runs is high — see EXPERIMENTS.md §Perf. The returned
    /// slice borrows the cache entry directly; a hit performs no heap
    /// allocation (the key is a stack-resident [`ResponderMask`]).
    /// Memoization only applies up to [`MAX_MEMOIZED_WORKERS`]; larger
    /// codes pay a fresh solve per call but never fail on size.
    pub fn decode_coeffs(&mut self, workers: &[usize]) -> Option<&[f64]> {
        let k = self.n - self.s;
        if workers.len() < k {
            return None;
        }
        // Rows all lie in the (n-s)-dimensional null(H): use exactly n-s
        // of them (more would make the Gram matrix singular); the
        // returned β is aligned with `workers[..n-s]`.
        let used = &workers[..k];
        if self.n > MAX_MEMOIZED_WORKERS {
            // Beyond the fixed-width mask: solve without memoizing.
            self.spill = solve_decode_coeffs(&self.b, used)?;
            return Some(&self.spill);
        }
        debug_assert!(
            used.windows(2).all(|w| w[0] < w[1]),
            "decode_coeffs requires sorted responder ids (β is set-keyed)"
        );
        match self.cache.entry(responder_mask(used)) {
            std::collections::hash_map::Entry::Occupied(e) => Some(e.into_mut().as_slice()),
            std::collections::hash_map::Entry::Vacant(e) => {
                let x = solve_decode_coeffs(&self.b, used)?;
                Some(e.insert(x).as_slice())
            }
        }
    }

    /// Decode: combine received `ℓ` vectors into the full gradient
    /// `g = Σ_j g_j`.
    pub fn decode(&mut self, workers: &[usize], results: &[&[f32]]) -> Option<Vec<f32>> {
        assert_eq!(workers.len(), results.len());
        if workers.len() < self.n - self.s {
            return None; // too few responders (also covers empty input)
        }
        let mut out = vec![0.0f32; results[0].len()];
        self.decode_into(workers, results, &mut out)?;
        Some(out)
    }

    /// [`Self::decode`] accumulating into a caller-owned (zeroed) buffer
    /// via the chunked [`linalg::axpy_f32`] kernel.
    pub fn decode_into(
        &mut self,
        workers: &[usize],
        results: &[&[f32]],
        out: &mut [f32],
    ) -> Option<()> {
        assert_eq!(workers.len(), results.len());
        let beta = self.decode_coeffs(workers)?;
        // β covers the first n-s responders; the rest have coefficient 0.
        for (b, r) in beta.iter().zip(results) {
            linalg::axpy_f32(out, *b as f32, r);
        }
        Some(())
    }

    /// Spot-check decodability over `trials` random `(n-s)`-subsets.
    pub fn verify_random_subsets(&mut self, trials: usize, seed: u64) -> bool {
        let mut rng = Pcg32::new(seed, 0xc3ec);
        for _ in 0..trials {
            let mut subset = rng.sample_indices(self.n, self.n - self.s);
            subset.sort_unstable();
            if self.decode_coeffs(&subset).is_none() {
                return false;
            }
        }
        true
    }

    /// Decode-cache statistics `(entries)` for perf reporting.
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }
}

/// `(n, s)`-GC in the sequential setting: `T = 0`, `η = n` equal chunks,
/// worker `i` stores chunks `[i : i+s]*` and returns `ℓ_i(t)` in round `t`.
///
/// Round `r`'s tasks all serve job `r`, so the scheme keeps no per-round
/// task storage: `commit_round` and `decodable_with` reconstruct the
/// deliveries directly from the responder set (§Perf).
pub struct GcScheme {
    spec: SchemeSpec,
    jobs: usize,
    /// Ledger per job (index `t-1`).
    ledgers: Vec<JobLedger>,
    /// Cyclic support per worker, shared (refcounted) into every round's
    /// coded units.
    supports: Vec<Arc<[usize]>>,
    assigned: usize,
    committed: usize,
    /// Reusable `decodable_with` ledger (replaces `JobLedger::clone`).
    scratch: RefCell<JobLedger>,
}

impl GcScheme {
    /// `(n, s)`-GC protocol state for a `jobs`-round run.
    pub fn new(n: usize, s: usize, jobs: usize) -> Self {
        assert!(s < n);
        // One computation of the cyclic supports backs both the spec's
        // placement and the shared per-round chunk lists.
        let supports: Vec<Arc<[usize]>> =
            (0..n).map(|i| cyclic_support(i, s, n).into()).collect();
        let spec = SchemeSpec {
            name: format!("gc(n={n},s={s})"),
            n,
            delay: 0,
            load: (s + 1) as f64 / n as f64,
            num_chunks: n,
            chunk_sizes: vec![1.0 / n as f64; n],
            placement: supports.iter().map(|c| c.to_vec()).collect(),
            tolerance: ToleranceSpec::PerRound { s },
        };
        let ledgers = (0..jobs)
            .map(|_| JobLedger {
                plain_missing: HashSet::new(),
                // preallocated for all n possible responders so the
                // steady-state commit path never grows the table
                coded_got: vec![HashSet::with_capacity(n)],
                coded_need: vec![n - s],
            })
            .collect();
        GcScheme {
            spec,
            jobs,
            ledgers,
            supports,
            assigned: 0,
            committed: 0,
            scratch: RefCell::new(JobLedger::empty()),
        }
    }
}

impl Scheme for GcScheme {
    fn spec(&self) -> &SchemeSpec {
        &self.spec
    }

    fn jobs(&self) -> usize {
        self.jobs
    }

    fn assign_round_into(&mut self, r: usize, out: &mut Vec<TaskDesc>) {
        assert_eq!(r, self.assigned + 1, "rounds must be assigned in order");
        assert_eq!(self.committed, self.assigned, "previous round not committed");
        let in_range = r >= 1 && r <= self.jobs;
        let supports = &self.supports;
        fill_tasks(out, self.spec.n, |i, task| {
            task.units.push(if in_range {
                WorkUnit::Coded { job: r, group: 0, row: i, chunks: Arc::clone(&supports[i]) }
            } else {
                WorkUnit::Noop
            });
        });
        self.assigned = r;
    }

    fn commit_round(&mut self, r: usize, responded: &[bool]) {
        assert_eq!(r, self.committed + 1);
        assert_eq!(r, self.assigned, "round not assigned");
        assert_eq!(responded.len(), self.spec.n);
        if r >= 1 && r <= self.jobs {
            let got = &mut self.ledgers[r - 1].coded_got[0];
            for (w, &ok) in responded.iter().enumerate() {
                if ok {
                    got.insert(w);
                }
            }
        }
        self.committed = r;
    }

    fn decodable(&self, job: usize) -> bool {
        self.ledgers[job - 1].complete()
    }

    fn ledger(&self, job: usize) -> &JobLedger {
        &self.ledgers[job - 1]
    }

    fn decodable_with(&self, job: usize, r: usize, responded: &[bool]) -> bool {
        debug_assert_eq!(r, self.committed + 1);
        debug_assert_eq!(r, self.assigned);
        let mut scratch = self.scratch.borrow_mut();
        scratch.copy_into_from(&self.ledgers[job - 1]);
        // Round r's units all serve job r.
        if job == r && r <= self.jobs {
            for (w, &ok) in responded.iter().enumerate() {
                if ok {
                    scratch.coded_got[0].insert(w);
                }
            }
        }
        scratch.complete()
    }
}

/// Appendix G `GC-Rep`: requires `(s+1) | n`. Worker `i` belongs to group
/// `⌊i/(s+1)⌋`; all workers in group `g` compute the same plain sum
/// `ℓ^(g) = Σ_{j ∈ group g chunks} g_j`. Decode = one response per group.
pub struct GcRepScheme {
    spec: SchemeSpec,
    s: usize,
    jobs: usize,
    ledgers: Vec<JobLedger>,
    /// Chunk list per replication group, shared into the coded units.
    group_chunks: Vec<Arc<[usize]>>,
    assigned: usize,
    committed: usize,
    scratch: RefCell<JobLedger>,
}

impl GcRepScheme {
    /// Replication-based `(n, s)`-GC (needs `(s+1) | n`).
    pub fn new(n: usize, s: usize, jobs: usize) -> Self {
        assert!(s < n);
        assert_eq!(n % (s + 1), 0, "GC-Rep needs (s+1) | n");
        let groups = n / (s + 1);
        let group_chunks: Vec<Arc<[usize]>> =
            (0..groups).map(|g| Self::group_chunk_ids(g, s).into()).collect();
        let spec = SchemeSpec {
            name: format!("gc-rep(n={n},s={s})"),
            n,
            delay: 0,
            load: (s + 1) as f64 / n as f64,
            num_chunks: n,
            chunk_sizes: vec![1.0 / n as f64; n],
            placement: (0..n).map(|i| group_chunks[i / (s + 1)].to_vec()).collect(),
            tolerance: ToleranceSpec::PerRound { s },
        };
        let ledgers = (0..jobs)
            .map(|_| JobLedger {
                plain_missing: HashSet::new(),
                // one coded "replication group" per worker group, threshold
                // 1; all s+1 members may respond, so preallocate for them
                coded_got: vec![HashSet::with_capacity(s + 1); groups],
                coded_need: vec![1; groups],
            })
            .collect();
        GcRepScheme {
            spec,
            s,
            jobs,
            ledgers,
            group_chunks,
            assigned: 0,
            committed: 0,
            scratch: RefCell::new(JobLedger::empty()),
        }
    }

    fn group_chunk_ids(g: usize, s: usize) -> Vec<usize> {
        (g * (s + 1)..(g + 1) * (s + 1)).collect()
    }

    /// Group of a worker.
    pub fn group_of(&self, worker: usize) -> usize {
        worker / (self.s + 1)
    }
}

impl Scheme for GcRepScheme {
    fn spec(&self) -> &SchemeSpec {
        &self.spec
    }

    fn jobs(&self) -> usize {
        self.jobs
    }

    fn assign_round_into(&mut self, r: usize, out: &mut Vec<TaskDesc>) {
        assert_eq!(r, self.assigned + 1, "rounds must be assigned in order");
        assert_eq!(self.committed, self.assigned, "previous round not committed");
        let in_range = r >= 1 && r <= self.jobs;
        let s = self.s;
        let group_chunks = &self.group_chunks;
        fill_tasks(out, self.spec.n, |i, task| {
            task.units.push(if in_range {
                let g = i / (s + 1);
                WorkUnit::Coded {
                    job: r,
                    group: g,
                    row: i,
                    chunks: Arc::clone(&group_chunks[g]),
                }
            } else {
                WorkUnit::Noop
            });
        });
        self.assigned = r;
    }

    fn commit_round(&mut self, r: usize, responded: &[bool]) {
        assert_eq!(r, self.committed + 1);
        assert_eq!(r, self.assigned, "round not assigned");
        assert_eq!(responded.len(), self.spec.n);
        if r >= 1 && r <= self.jobs {
            let ledger = &mut self.ledgers[r - 1];
            for (w, &ok) in responded.iter().enumerate() {
                if ok {
                    ledger.coded_got[w / (self.s + 1)].insert(w);
                }
            }
        }
        self.committed = r;
    }

    fn decodable(&self, job: usize) -> bool {
        self.ledgers[job - 1].complete()
    }

    fn ledger(&self, job: usize) -> &JobLedger {
        &self.ledgers[job - 1]
    }

    fn decodable_with(&self, job: usize, r: usize, responded: &[bool]) -> bool {
        debug_assert_eq!(r, self.committed + 1);
        debug_assert_eq!(r, self.assigned);
        let mut scratch = self.scratch.borrow_mut();
        scratch.copy_into_from(&self.ledgers[job - 1]);
        if job == r && r <= self.jobs {
            for (w, &ok) in responded.iter().enumerate() {
                if ok {
                    scratch.coded_got[w / (self.s + 1)].insert(w);
                }
            }
        }
        scratch.complete()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn cyclic_support_wraps() {
        assert_eq!(cyclic_support(4, 2, 6), vec![4, 5, 0]);
        assert_eq!(cyclic_support(0, 0, 3), vec![0]);
    }

    #[test]
    fn responder_mask_is_order_independent() {
        assert_eq!(responder_mask(&[0, 63, 64, 255]), responder_mask(&[255, 64, 0, 63]));
        assert_ne!(responder_mask(&[0, 1]), responder_mask(&[0, 2]));
        let m = responder_mask(&[5, 70, 200]);
        assert_eq!(m[0], 1 << 5);
        assert_eq!(m[1], 1 << 6);
        assert_eq!(m[3], 1 << (200 - 192));
    }

    #[test]
    fn gc_code_decodes_all_small_subsets() {
        // exhaustively check all (n-s)-subsets for a small code
        let n = 7;
        let s = 2;
        let mut code = GcCode::new(n, s, 42);
        let mut count = 0;
        // enumerate subsets of size n-s via bitmask
        for mask in 0u32..(1 << n) {
            if mask.count_ones() as usize != n - s {
                continue;
            }
            let subset: Vec<usize> = (0..n).filter(|&i| mask & (1 << i) != 0).collect();
            assert!(code.decode_coeffs(&subset).is_some(), "subset {subset:?} undecodable");
            count += 1;
        }
        assert_eq!(count, 21);
    }

    #[test]
    fn gc_code_large_spot_check() {
        let mut code = GcCode::new(64, 7, 7);
        assert!(code.verify_random_subsets(50, 99));
    }

    #[test]
    fn gc_code_rejects_too_few() {
        let mut code = GcCode::new(8, 2, 1);
        assert!(code.decode_coeffs(&[0, 1, 2]).is_none());
    }

    #[test]
    fn gc_encode_decode_numeric_roundtrip() {
        let n = 6;
        let s = 2;
        let dim = 5;
        let mut rng = Pcg32::seeded(3);
        let mut code = GcCode::new(n, s, 11);
        // random partial gradients per chunk
        let partials: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
            .collect();
        let truth: Vec<f32> = (0..dim)
            .map(|d| partials.iter().map(|p| p[d]).sum())
            .collect();
        // every worker encodes
        let encoded: Vec<Vec<f32>> = (0..n)
            .map(|i| {
                let sup = cyclic_support(i, s, n);
                let refs: Vec<&[f32]> = sup.iter().map(|&c| partials[c].as_slice()).collect();
                code.encode(i, &refs)
            })
            .collect();
        // drop workers 1 and 4 (s = 2 stragglers)
        let workers = vec![0, 2, 3, 5];
        let results: Vec<&[f32]> = workers.iter().map(|&w| encoded[w].as_slice()).collect();
        let decoded = code.decode(&workers, &results).unwrap();
        for (a, b) in decoded.iter().zip(&truth) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn decode_beyond_mask_width_still_solves() {
        // n > 256 is outside the fixed-width memoization mask: decodes
        // must still succeed (fresh solve per call, nothing cached).
        let n = 260;
        let s = 2;
        let mut code = GcCode::new(n, s, 13);
        let workers: Vec<usize> = (s..n).collect(); // workers 2..260 respond
        let beta = code.decode_coeffs(&workers).expect("decodable").to_vec();
        assert_eq!(beta.len(), n - s);
        assert_eq!(code.cache_len(), 0, "oversized codes must not populate the mask cache");
        // repeat solve is identical
        let again = code.decode_coeffs(&workers).unwrap();
        assert_eq!(beta, again);
    }

    #[test]
    fn decode_cache_hits() {
        let mut code = GcCode::new(12, 3, 5);
        let w: Vec<usize> = (0..9).collect();
        code.decode_coeffs(&w).unwrap();
        assert_eq!(code.cache_len(), 1);
        code.decode_coeffs(&w).unwrap();
        assert_eq!(code.cache_len(), 1);
    }

    #[test]
    fn gc_scheme_decodes_with_s_stragglers() {
        let n = 8;
        let s = 3;
        let mut sch = GcScheme::new(n, s, 4);
        sch.spec().validate();
        for r in 1..=4usize {
            sch.assign_round(r);
            // workers 0..s straggle every round
            let responded: Vec<bool> = (0..n).map(|i| i >= s).collect();
            assert!(sch.decodable_with(r, r, &responded));
            sch.commit_round(r, &responded);
            assert!(sch.decodable(r));
        }
    }

    #[test]
    fn gc_scheme_fails_with_s_plus_1_stragglers() {
        let n = 8;
        let s = 3;
        let mut sch = GcScheme::new(n, s, 1);
        sch.assign_round(1);
        let responded: Vec<bool> = (0..n).map(|i| i > s).collect(); // s+1 stragglers
        assert!(!sch.decodable_with(1, 1, &responded));
        sch.commit_round(1, &responded);
        assert!(!sch.decodable(1));
    }

    #[test]
    fn gc_scheme_assign_reuses_buffers() {
        let n = 4;
        let mut sch = GcScheme::new(n, 1, 3);
        let mut buf = Vec::new();
        sch.assign_round_into(1, &mut buf);
        assert_eq!(buf.len(), n);
        let chunk_ptrs: Vec<*const usize> = buf
            .iter()
            .map(|t| match &t.units[0] {
                WorkUnit::Coded { chunks, .. } => chunks.as_ptr(),
                other => panic!("expected coded unit, got {other:?}"),
            })
            .collect();
        sch.commit_round(1, &[true; 4]);
        sch.assign_round_into(2, &mut buf);
        // the chunk slices are the same shared allocations round over round
        for (t, &p) in buf.iter().zip(&chunk_ptrs) {
            match &t.units[0] {
                WorkUnit::Coded { job, chunks, .. } => {
                    assert_eq!(*job, 2);
                    assert_eq!(chunks.as_ptr(), p);
                }
                other => panic!("expected coded unit, got {other:?}"),
            }
        }
    }

    #[test]
    fn gc_rep_needs_one_per_group() {
        let n = 6;
        let s = 2; // 2 groups: {0,1,2}, {3,4,5}
        let mut sch = GcRepScheme::new(n, s, 1);
        sch.spec().validate();
        sch.assign_round(1);
        // only workers 2 and 3 respond: one in each group → decodable
        let resp = vec![false, false, true, true, false, false];
        assert!(sch.decodable_with(1, 1, &resp));
        // all of group 0 straggles → not decodable even though only 3 stragglers
        let resp2 = vec![false, false, false, true, true, true];
        assert!(!sch.decodable_with(1, 1, &resp2));
        sch.commit_round(1, &resp);
        assert!(sch.decodable(1));
    }

    #[test]
    fn gc_rep_tolerates_patterns_gc_cannot() {
        // Appendix G example: n=6, s=2, stragglers {1,2,3,5} (4 > s) but
        // one worker per group survives.
        let mut rep = GcRepScheme::new(6, 2, 1);
        rep.assign_round(1);
        let resp = vec![true, false, false, false, true, false];
        assert!(rep.decodable_with(1, 1, &resp));
    }
}
