//! Classical `(n, s)` Gradient Coding (Tandon et al. 2017) — Sec. 3.1.
//!
//! Two pieces live here:
//!
//! * [`GcCode`] — the numeric code: the cyclic-support coefficient matrix
//!   `B` (worker `i` returns `ℓ_i = Σ_{j ∈ [i:i+s]*} α_{i,j} g_j`) and the
//!   decoder that finds `β` with `Σ_w β_w B[w,:] = 1ᵀ` for any responding
//!   set of ≥ `n-s` workers. Decoding solves the consistent system via
//!   normal equations (see [`crate::util::linalg`]); coefficients are
//!   memoized per straggler pattern, which is the L3 hot-path optimization
//!   the §Perf pass measures.
//! * [`GcScheme`] — GC applied to the sequential setting (delay `T = 0`,
//!   every worker computes `ℓ_i(t)` in round `t`).
//!
//! The `(s+1) | n` replication simplification of Appendix G ("GC-Rep") is
//! [`GcRepScheme`]: workers are partitioned into `n/(s+1)` groups; each
//! group replicates the plain sum of its `s+1` chunks, so decode is the
//! trivial sum of one response per group.

use super::scheme::{JobLedger, Scheme, SchemeSpec, TaskDesc, ToleranceSpec, WorkUnit};
use crate::util::linalg::{self, Matrix};
use crate::util::rng::Pcg32;
use std::collections::HashMap;
use std::collections::HashSet;

/// The cyclic support `[i : i+s]* = {i mod n, …, (i+s) mod n}`.
pub fn cyclic_support(i: usize, s: usize, n: usize) -> Vec<usize> {
    (0..=s).map(|k| (i + k) % n).collect()
}

/// Numeric `(n, s)`-GC code.
#[derive(Clone, Debug)]
pub struct GcCode {
    pub n: usize,
    pub s: usize,
    /// Dense `n × n` coefficient matrix with cyclic support.
    pub b: Matrix,
    /// Decode coefficient cache keyed by the responder bitmask (as bytes).
    cache: HashMap<Vec<u64>, Vec<f64>>,
}

impl GcCode {
    /// Tandon et al. Algorithm-2 construction: draw a random
    /// `H ∈ R^{s×n}` whose columns sum to zero (so `H·1 = 0`), then choose
    /// every row `b_i` inside `null(H)` with cyclic support `[i:i+s]*` and
    /// `b_i[i] = 1`. All rows live in the `(n-s)`-dimensional `null(H)`
    /// which contains `1`; any `n-s` rows are generically independent and
    /// therefore span it — every `(n-s)`-subset decodes with probability
    /// 1. [`Self::verify_random_subsets`] spot-checks the genericity.
    pub fn new(n: usize, s: usize, seed: u64) -> Self {
        assert!(s < n, "need s < n");
        let mut rng = Pcg32::new(seed, 0x6c0de);
        let mut b = Matrix::zeros(n, n);
        if s == 0 {
            // degenerate: every worker returns its own partial gradient
            for i in 0..n {
                b[(i, i)] = 1.0;
            }
            return GcCode { n, s, b, cache: HashMap::new() };
        }
        // H with columns summing to zero: H·1 = 0.
        let mut h = Matrix::zeros(s, n);
        for r in 0..s {
            let mut sum = 0.0;
            for c in 0..n - 1 {
                let v = rng.normal();
                h[(r, c)] = v;
                sum += v;
            }
            h[(r, n - 1)] = -sum;
        }
        // Row i: b_i[i] = 1; remaining support entries y solve
        // H[:, rest] · y = -H[:, i].
        for i in 0..n {
            let support = cyclic_support(i, s, n);
            let rest = &support[1..];
            let mut sub = Matrix::zeros(s, s);
            for (c, &col) in rest.iter().enumerate() {
                for r in 0..s {
                    sub[(r, c)] = h[(r, col)];
                }
            }
            let rhs: Vec<f64> = (0..s).map(|r| -h[(r, i)]).collect();
            let y = linalg::solve_square(&sub, &rhs)
                .expect("generic H gives nonsingular subsystems");
            b[(i, i)] = 1.0;
            for (&col, &v) in rest.iter().zip(&y) {
                b[(i, col)] = v;
            }
        }
        // Row-normalize: unit-norm rows keep the decode Gram matrix well
        // conditioned (near-singular H subsystems otherwise blow row
        // magnitudes up to ~1e2-1e3).
        for i in 0..n {
            let norm = linalg::dot(b.row(i), b.row(i)).sqrt();
            for v in b.row_mut(i) {
                *v /= norm;
            }
        }
        GcCode { n, s, b, cache: HashMap::new() }
    }

    /// Encode: combine the `s+1` partial-gradient vectors computed by
    /// worker `row` into the single task result `ℓ_row`.
    ///
    /// `partials[k]` is the gradient w.r.t. chunk `support[k]`.
    pub fn encode(&self, row: usize, partials: &[&[f32]]) -> Vec<f32> {
        let support = cyclic_support(row, self.s, self.n);
        assert_eq!(partials.len(), support.len());
        let dim = partials[0].len();
        let mut out = vec![0.0f32; dim];
        for (k, &chunk) in support.iter().enumerate() {
            let alpha = self.b[(row, chunk)] as f32;
            debug_assert_eq!(partials[k].len(), dim);
            for (o, &g) in out.iter_mut().zip(partials[k]) {
                *o += alpha * g;
            }
        }
        out
    }

    /// Decode coefficients for a responder set: `β` such that
    /// `Σ_{w ∈ workers} β_w B[w,:] = 1ᵀ`. Returns `None` if the set is too
    /// small or (numerically) undecodable.
    ///
    /// Results are memoized: round-over-round straggler patterns repeat
    /// heavily (GE model dwell times), so the cache hit rate in long runs
    /// is high — see EXPERIMENTS.md §Perf.
    pub fn decode_coeffs(&mut self, workers: &[usize]) -> Option<Vec<f64>> {
        let k = self.n - self.s;
        if workers.len() < k {
            return None;
        }
        // Rows all lie in the (n-s)-dimensional null(H): use exactly n-s
        // of them (more would make the Gram matrix singular); the
        // returned β is aligned with `workers`, zero beyond the first k.
        let used = &workers[..k];
        let key = bitmask(used, self.n);
        if let Some(c) = self.cache.get(&key) {
            let mut full = c.clone();
            full.resize(workers.len(), 0.0);
            return Some(full);
        }
        let rows: Vec<Vec<f64>> = used.iter().map(|&w| self.b.row(w).to_vec()).collect();
        let a = Matrix::from_rows(&rows);
        let ones = vec![1.0; self.n];
        // Normal equations + two iterative-refinement sweeps: the Gram
        // matrix squares the conditioning, refinement recovers the lost
        // digits (worst-case residual ~1e-10 at n=256 in calibration).
        let gram = a.gram_rows();
        let l = linalg::cholesky(&gram)?;
        let mut x = linalg::cholesky_solve(&l, &a.matvec(&ones));
        // Iterative refinement until the residual converges (usually 2
        // sweeps; ill-conditioned subsets occasionally need a few more).
        for _ in 0..8 {
            if linalg::residual_inf(&a, &x, &ones) <= 1e-8 {
                break;
            }
            let atx = a.tr_matvec(&x);
            let resid: Vec<f64> = ones.iter().zip(&atx).map(|(o, v)| o - v).collect();
            let dx = linalg::cholesky_solve(&l, &a.matvec(&resid));
            for (xi, di) in x.iter_mut().zip(&dx) {
                *xi += di;
            }
        }
        if linalg::residual_inf(&a, &x, &ones) > 1e-5 {
            return None;
        }
        self.cache.insert(key, x.clone());
        let mut full = x;
        full.resize(workers.len(), 0.0);
        Some(full)
    }

    /// Decode: combine received `ℓ` vectors into the full gradient
    /// `g = Σ_j g_j`.
    pub fn decode(&mut self, workers: &[usize], results: &[&[f32]]) -> Option<Vec<f32>> {
        assert_eq!(workers.len(), results.len());
        let beta = self.decode_coeffs(workers)?;
        let dim = results[0].len();
        let mut out = vec![0.0f32; dim];
        for (k, r) in results.iter().enumerate() {
            let b = beta[k] as f32;
            for (o, &v) in out.iter_mut().zip(*r) {
                *o += b * v;
            }
        }
        Some(out)
    }

    /// Spot-check decodability over `trials` random `(n-s)`-subsets.
    pub fn verify_random_subsets(&mut self, trials: usize, seed: u64) -> bool {
        let mut rng = Pcg32::new(seed, 0xc3ec);
        for _ in 0..trials {
            let subset = rng.sample_indices(self.n, self.n - self.s);
            if self.decode_coeffs(&subset).is_none() {
                return false;
            }
        }
        true
    }

    /// Decode-cache statistics `(entries)` for perf reporting.
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }
}

fn bitmask(workers: &[usize], n: usize) -> Vec<u64> {
    let mut mask = vec![0u64; n.div_ceil(64)];
    for &w in workers {
        mask[w / 64] |= 1 << (w % 64);
    }
    mask
}

/// `(n, s)`-GC in the sequential setting: `T = 0`, `η = n` equal chunks,
/// worker `i` stores chunks `[i : i+s]*` and returns `ℓ_i(t)` in round `t`.
pub struct GcScheme {
    spec: SchemeSpec,
    s: usize,
    jobs: usize,
    /// Ledger per job (index `t-1`).
    ledgers: Vec<JobLedger>,
    assigned: Vec<Vec<TaskDesc>>, // per committed/assigned round (index r-1)
    committed: usize,
}

impl GcScheme {
    pub fn new(n: usize, s: usize, jobs: usize) -> Self {
        assert!(s < n);
        let spec = SchemeSpec {
            name: format!("gc(n={n},s={s})"),
            n,
            delay: 0,
            load: (s + 1) as f64 / n as f64,
            num_chunks: n,
            chunk_sizes: vec![1.0 / n as f64; n],
            placement: (0..n).map(|i| cyclic_support(i, s, n)).collect(),
            tolerance: ToleranceSpec::PerRound { s },
        };
        let ledgers = (0..jobs)
            .map(|_| JobLedger {
                plain_missing: HashSet::new(),
                coded_got: vec![HashSet::new()],
                coded_need: vec![n - s],
            })
            .collect();
        GcScheme { spec, s, jobs, ledgers, assigned: Vec::new(), committed: 0 }
    }

    fn task_for(&self, worker: usize, job: usize) -> TaskDesc {
        if job < 1 || job > self.jobs {
            return TaskDesc::noop();
        }
        TaskDesc {
            units: vec![WorkUnit::Coded {
                job,
                group: 0,
                row: worker,
                chunks: cyclic_support(worker, self.s, self.spec.n),
            }],
        }
    }
}

impl Scheme for GcScheme {
    fn spec(&self) -> &SchemeSpec {
        &self.spec
    }

    fn jobs(&self) -> usize {
        self.jobs
    }

    fn assign_round(&mut self, r: usize) -> Vec<TaskDesc> {
        assert_eq!(r, self.assigned.len() + 1, "rounds must be assigned in order");
        assert_eq!(self.committed, self.assigned.len(), "previous round not committed");
        let tasks: Vec<TaskDesc> = (0..self.spec.n).map(|i| self.task_for(i, r)).collect();
        self.assigned.push(tasks.clone());
        tasks
    }

    fn commit_round(&mut self, r: usize, responded: &[bool]) {
        assert_eq!(r, self.committed + 1);
        assert_eq!(responded.len(), self.spec.n);
        let tasks = &self.assigned[r - 1];
        for (w, task) in tasks.iter().enumerate() {
            if !responded[w] {
                continue;
            }
            for unit in &task.units {
                if let Some(job) = unit.job() {
                    self.ledgers[job - 1].deliver(w, unit);
                }
            }
        }
        // Committed rounds are never read again — drop their task
        // storage so long runs stay O(window), not O(rounds).
        self.assigned[r - 1] = Vec::new();
        self.committed = r;
    }

    fn decodable(&self, job: usize) -> bool {
        self.ledgers[job - 1].complete()
    }

    fn ledger(&self, job: usize) -> &JobLedger {
        &self.ledgers[job - 1]
    }

    fn decodable_with(&self, job: usize, r: usize, responded: &[bool]) -> bool {
        debug_assert_eq!(r, self.committed + 1);
        let mut ledger = self.ledgers[job - 1].clone();
        for (w, task) in self.assigned[r - 1].iter().enumerate() {
            if !responded[w] {
                continue;
            }
            for unit in &task.units {
                if unit.job() == Some(job) {
                    ledger.deliver(w, unit);
                }
            }
        }
        ledger.complete()
    }
}

/// Appendix G `GC-Rep`: requires `(s+1) | n`. Worker `i` belongs to group
/// `⌊i/(s+1)⌋`; all workers in group `g` compute the same plain sum
/// `ℓ^(g) = Σ_{j ∈ group g chunks} g_j`. Decode = one response per group.
pub struct GcRepScheme {
    spec: SchemeSpec,
    s: usize,
    jobs: usize,
    ledgers: Vec<JobLedger>,
    assigned: Vec<Vec<TaskDesc>>,
    committed: usize,
}

impl GcRepScheme {
    pub fn new(n: usize, s: usize, jobs: usize) -> Self {
        assert!(s < n);
        assert_eq!(n % (s + 1), 0, "GC-Rep needs (s+1) | n");
        let groups = n / (s + 1);
        let spec = SchemeSpec {
            name: format!("gc-rep(n={n},s={s})"),
            n,
            delay: 0,
            load: (s + 1) as f64 / n as f64,
            num_chunks: n,
            chunk_sizes: vec![1.0 / n as f64; n],
            placement: (0..n).map(|i| Self::group_chunks(i / (s + 1), s)).collect(),
            tolerance: ToleranceSpec::PerRound { s },
        };
        let ledgers = (0..jobs)
            .map(|_| JobLedger {
                plain_missing: HashSet::new(),
                // one coded "replication group" per worker group, threshold 1
                coded_got: vec![HashSet::new(); groups],
                coded_need: vec![1; groups],
            })
            .collect();
        GcRepScheme { spec, s, jobs, ledgers, assigned: Vec::new(), committed: 0 }
    }

    fn group_chunks(g: usize, s: usize) -> Vec<usize> {
        (g * (s + 1)..(g + 1) * (s + 1)).collect()
    }

    /// Group of a worker.
    pub fn group_of(&self, worker: usize) -> usize {
        worker / (self.s + 1)
    }

    fn task_for(&self, worker: usize, job: usize) -> TaskDesc {
        if job < 1 || job > self.jobs {
            return TaskDesc::noop();
        }
        let g = worker / (self.s + 1);
        TaskDesc {
            units: vec![WorkUnit::Coded {
                job,
                group: g,
                row: worker,
                chunks: Self::group_chunks(g, self.s),
            }],
        }
    }
}

impl Scheme for GcRepScheme {
    fn spec(&self) -> &SchemeSpec {
        &self.spec
    }

    fn jobs(&self) -> usize {
        self.jobs
    }

    fn assign_round(&mut self, r: usize) -> Vec<TaskDesc> {
        assert_eq!(r, self.assigned.len() + 1);
        assert_eq!(self.committed, self.assigned.len());
        let tasks: Vec<TaskDesc> = (0..self.spec.n).map(|i| self.task_for(i, r)).collect();
        self.assigned.push(tasks.clone());
        tasks
    }

    fn commit_round(&mut self, r: usize, responded: &[bool]) {
        assert_eq!(r, self.committed + 1);
        for (w, task) in self.assigned[r - 1].iter().enumerate() {
            if !responded[w] {
                continue;
            }
            for unit in &task.units {
                if let Some(job) = unit.job() {
                    self.ledgers[job - 1].deliver(w, unit);
                }
            }
        }
        // Committed rounds are never read again — drop their task
        // storage so long runs stay O(window), not O(rounds).
        self.assigned[r - 1] = Vec::new();
        self.committed = r;
    }

    fn decodable(&self, job: usize) -> bool {
        self.ledgers[job - 1].complete()
    }

    fn ledger(&self, job: usize) -> &JobLedger {
        &self.ledgers[job - 1]
    }

    fn decodable_with(&self, job: usize, r: usize, responded: &[bool]) -> bool {
        debug_assert_eq!(r, self.committed + 1);
        let mut ledger = self.ledgers[job - 1].clone();
        for (w, task) in self.assigned[r - 1].iter().enumerate() {
            if !responded[w] {
                continue;
            }
            for unit in &task.units {
                if unit.job() == Some(job) {
                    ledger.deliver(w, unit);
                }
            }
        }
        ledger.complete()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn cyclic_support_wraps() {
        assert_eq!(cyclic_support(4, 2, 6), vec![4, 5, 0]);
        assert_eq!(cyclic_support(0, 0, 3), vec![0]);
    }

    #[test]
    fn gc_code_decodes_all_small_subsets() {
        // exhaustively check all (n-s)-subsets for a small code
        let n = 7;
        let s = 2;
        let mut code = GcCode::new(n, s, 42);
        let mut count = 0;
        // enumerate subsets of size n-s via bitmask
        for mask in 0u32..(1 << n) {
            if mask.count_ones() as usize != n - s {
                continue;
            }
            let subset: Vec<usize> = (0..n).filter(|&i| mask & (1 << i) != 0).collect();
            assert!(code.decode_coeffs(&subset).is_some(), "subset {subset:?} undecodable");
            count += 1;
        }
        assert_eq!(count, 21);
    }

    #[test]
    fn gc_code_large_spot_check() {
        let mut code = GcCode::new(64, 7, 7);
        assert!(code.verify_random_subsets(50, 99));
    }

    #[test]
    fn gc_code_rejects_too_few() {
        let mut code = GcCode::new(8, 2, 1);
        assert!(code.decode_coeffs(&[0, 1, 2]).is_none());
    }

    #[test]
    fn gc_encode_decode_numeric_roundtrip() {
        let n = 6;
        let s = 2;
        let dim = 5;
        let mut rng = Pcg32::seeded(3);
        let mut code = GcCode::new(n, s, 11);
        // random partial gradients per chunk
        let partials: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
            .collect();
        let truth: Vec<f32> = (0..dim)
            .map(|d| partials.iter().map(|p| p[d]).sum())
            .collect();
        // every worker encodes
        let encoded: Vec<Vec<f32>> = (0..n)
            .map(|i| {
                let sup = cyclic_support(i, s, n);
                let refs: Vec<&[f32]> = sup.iter().map(|&c| partials[c].as_slice()).collect();
                code.encode(i, &refs)
            })
            .collect();
        // drop workers 1 and 4 (s = 2 stragglers)
        let workers = vec![0, 2, 3, 5];
        let results: Vec<&[f32]> = workers.iter().map(|&w| encoded[w].as_slice()).collect();
        let decoded = code.decode(&workers, &results).unwrap();
        for (a, b) in decoded.iter().zip(&truth) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn decode_cache_hits() {
        let mut code = GcCode::new(12, 3, 5);
        let w: Vec<usize> = (0..9).collect();
        code.decode_coeffs(&w).unwrap();
        assert_eq!(code.cache_len(), 1);
        code.decode_coeffs(&w).unwrap();
        assert_eq!(code.cache_len(), 1);
    }

    #[test]
    fn gc_scheme_decodes_with_s_stragglers() {
        let n = 8;
        let s = 3;
        let mut sch = GcScheme::new(n, s, 4);
        sch.spec().validate();
        for r in 1..=4usize {
            sch.assign_round(r);
            // workers 0..s straggle every round
            let responded: Vec<bool> = (0..n).map(|i| i >= s).collect();
            assert!(sch.decodable_with(r, r, &responded));
            sch.commit_round(r, &responded);
            assert!(sch.decodable(r));
        }
    }

    #[test]
    fn gc_scheme_fails_with_s_plus_1_stragglers() {
        let n = 8;
        let s = 3;
        let mut sch = GcScheme::new(n, s, 1);
        sch.assign_round(1);
        let responded: Vec<bool> = (0..n).map(|i| i > s).collect(); // s+1 stragglers
        assert!(!sch.decodable_with(1, 1, &responded));
        sch.commit_round(1, &responded);
        assert!(!sch.decodable(1));
    }

    #[test]
    fn gc_rep_needs_one_per_group() {
        let n = 6;
        let s = 2; // 2 groups: {0,1,2}, {3,4,5}
        let mut sch = GcRepScheme::new(n, s, 1);
        sch.spec().validate();
        sch.assign_round(1);
        // only workers 2 and 3 respond: one in each group → decodable
        let resp = vec![false, false, true, true, false, false];
        assert!(sch.decodable_with(1, 1, &resp));
        // all of group 0 straggles → not decodable even though only 3 stragglers
        let resp2 = vec![false, false, false, true, true, true];
        assert!(!sch.decodable_with(1, 1, &resp2));
        sch.commit_round(1, &resp);
        assert!(sch.decodable(1));
    }

    #[test]
    fn gc_rep_tolerates_patterns_gc_cannot() {
        // Appendix G example: n=6, s=2, stragglers {1,2,3,5} (4 > s) but
        // one worker per group survives.
        let mut rep = GcRepScheme::new(6, 2, 1);
        rep.assign_round(1);
        let resp = vec![true, false, false, false, true, false];
        assert!(rep.decodable_with(1, 1, &resp));
    }
}
