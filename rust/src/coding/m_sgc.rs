//! Multiplexed Sequential Gradient Coding (M-SGC) — Sec. 3.3, the paper's
//! main contribution.
//!
//! The dataset is split into an *uncoded* part `D1` (large chunks, each
//! owned by exactly one worker, protected by re-attempting failed
//! computations across rounds) and a *coded* part `D2` (small chunks in
//! `B` groups, each group protected by an `(n, λ)`-GC code). Worker tasks
//! are `W-1+B` diagonally interleaved mini-tasks; the mini-tasks
//! `T_i(t;0), T_i(t+1;1), …, T_i(t+W-2+B; W-2+B)` all serve job `t`
//! (Fig. 5). Delay `T = W-2+B`; load per equation (1).
//!
//! Mini-task layout for worker `i` in round `r`, slot `j` (job `t = r-j`):
//!
//! * `j ∈ [0, W-1)` — first attempt of the D1 partial gradient
//!   `g_{i(W-1)+j}(t)`.
//! * `j ∈ [W-1, W-1+B)` — if worker `i` still has failed D1 partials for
//!   job `t`, re-attempt the oldest one; otherwise compute the coded
//!   result `ℓ_{i, j-W+1}(t)` over D2 group `j-W+1` (Algorithm 2).
//!
//! `λ = n` (Remark 3.2) degenerates to `D2 = ∅` with all-plain mini-tasks.
//! `(λ+1) | n` enables the GC-Rep base for D2 (Appendix G, "M-SGC-Rep").
//!
//! A round's mini-tasks are a pure function of the round index and the
//! pending-failure state at assignment time — and each `(worker, slot)`
//! cell touches only its own job's state — so the scheme stores no
//! `TaskDesc`s: `commit_round` and `decodable_with` re-derive each unit
//! through [`MSgcScheme::unit_kind`] (§Perf).

use super::gc::cyclic_support;
use super::scheme::{fill_tasks, JobLedger, Scheme, SchemeSpec, TaskDesc, ToleranceSpec, WorkUnit};
use std::cell::RefCell;
use std::collections::HashSet;
use std::sync::Arc;

/// M-SGC design parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MSgcParams {
    /// Worker count.
    pub n: usize,
    /// Maximum burst length `B`.
    pub b: usize,
    /// Window length `W`.
    pub w: usize,
    /// Maximum straggling workers per window `λ`.
    pub lambda: usize,
}

impl MSgcParams {
    /// Panic unless the parameters satisfy the design constraints.
    pub fn validate(&self) {
        assert!(self.lambda <= self.n, "need 0 ≤ λ ≤ n");
        assert!(self.b > 0 && self.b < self.w, "need 0 < B < W");
    }

    /// Delay `T = W - 2 + B`.
    pub fn delay(&self) -> usize {
        self.w - 2 + self.b
    }

    /// Normalized load, equation (1).
    pub fn load(&self) -> f64 {
        let (n, b, w, l) = (self.n as f64, self.b as f64, self.w as f64, self.lambda as f64);
        if self.lambda < self.n {
            (l + 1.0) * (w - 1.0 + b) / (n * (b + (w - 1.0) * (l + 1.0)))
        } else {
            (w - 1.0 + b) / (n * (w - 1.0))
        }
    }
}

/// What one mini-task does, without the chunk list — the compact form
/// `commit_round`/`decodable_with` re-derive deliveries from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum UnitKind {
    Noop,
    Plain { job: usize, chunk: usize },
    Coded { job: usize, group: usize },
}

/// M-SGC scheme state (also M-SGC-Rep when `rep`).
pub struct MSgcScheme {
    spec: SchemeSpec,
    params: MSgcParams,
    rep: bool,
    jobs: usize,
    /// Number of D1 chunks `(W-1)·n` (D1 chunk of worker `i`, slot `j`
    /// is `i(W-1)+j`).
    #[allow(dead_code)]
    d1_chunks: usize,
    ledgers: Vec<JobLedger>,
    /// Pending failed D1 chunks per job (index `t-1`) per worker, oldest
    /// first. Only populated for jobs whose window is active.
    failed_d1: Vec<Vec<Vec<usize>>>,
    /// Precomputed D2 chunk lists, indexed `m * n + worker`, shared
    /// (refcounted) into every round's coded units (§Perf: rebuilding
    /// these per round dominated `assign_round`).
    d2_table: Vec<Arc<[usize]>>,
    assigned: usize,
    committed: usize,
    /// Reusable `decodable_with` ledger (replaces `JobLedger::clone`).
    scratch: RefCell<JobLedger>,
}

impl MSgcScheme {
    /// M-SGC protocol state for a `jobs`-job run.
    pub fn new(params: MSgcParams, jobs: usize) -> Self {
        Self::build(params, jobs, false)
    }

    /// M-SGC-Rep: D2 groups coded with the Appendix-G replication base.
    /// Requires `λ < n` and `(λ+1) | n`.
    pub fn new_rep(params: MSgcParams, jobs: usize) -> Self {
        assert!(params.lambda < params.n, "rep variant needs λ < n");
        assert_eq!(params.n % (params.lambda + 1), 0, "M-SGC-Rep needs (λ+1) | n");
        Self::build(params, jobs, true)
    }

    fn build(params: MSgcParams, jobs: usize, rep: bool) -> Self {
        params.validate();
        let (n, b, w, lambda) = (params.n, params.b, params.w, params.lambda);
        let d1_chunks = (w - 1) * n;
        let coded = lambda < n;
        let num_chunks = if coded { (w - 1 + b) * n } else { d1_chunks };
        // Chunk sizes (Sec. 3.3.2 data placement).
        let mut chunk_sizes = Vec::with_capacity(num_chunks);
        if coded {
            let denom = n as f64 * (b as f64 + (w - 1) as f64 * (lambda + 1) as f64);
            chunk_sizes.extend(std::iter::repeat((lambda + 1) as f64 / denom).take(d1_chunks));
            chunk_sizes.extend(std::iter::repeat(1.0 / denom).take(b * n));
        } else {
            chunk_sizes.extend(std::iter::repeat(1.0 / d1_chunks as f64).take(d1_chunks));
        }
        // Placement: worker i owns D1 chunks [i(W-1), (i+1)(W-1)) and, for
        // each D2 group j, the (λ+1) chunks (W-1+j)n + [i : i+λ]*.
        let placement: Vec<Vec<usize>> = (0..n)
            .map(|i| {
                let mut d: Vec<usize> = (i * (w - 1)..(i + 1) * (w - 1)).collect();
                if coded {
                    for j in 0..b {
                        let base = (w - 1 + j) * n;
                        if rep {
                            let g = i / (lambda + 1);
                            d.extend((g * (lambda + 1)..(g + 1) * (lambda + 1)).map(|k| base + k));
                        } else {
                            d.extend(cyclic_support(i, lambda, n).into_iter().map(|k| base + k));
                        }
                    }
                }
                d
            })
            .collect();
        let spec = SchemeSpec {
            name: format!("m-sgc{}(n={n},B={b},W={w},λ={lambda})", if rep { "-rep" } else { "" }),
            n,
            delay: params.delay(),
            load: params.load(),
            num_chunks,
            chunk_sizes,
            placement,
            tolerance: ToleranceSpec::BurstyOrArbitrary { b, w, lambda },
        };
        let rep_groups = if rep { n / (lambda + 1) } else { 1 };
        let ledgers = (0..jobs)
            .map(|_| JobLedger {
                plain_missing: (0..d1_chunks).collect(),
                // not preallocated: M-SGC instances are built for very
                // large J (the assignment microbench uses 100k jobs) and
                // only window-active jobs ever receive coded deliveries
                coded_got: if coded {
                    vec![HashSet::new(); b * rep_groups]
                } else {
                    Vec::new()
                },
                coded_need: if coded {
                    if rep {
                        vec![1; b * rep_groups]
                    } else {
                        vec![n - lambda; b]
                    }
                } else {
                    Vec::new()
                },
            })
            .collect();
        MSgcScheme {
            spec,
            params,
            rep,
            jobs,
            d1_chunks,
            ledgers,
            failed_d1: vec![vec![Vec::new(); n]; jobs],
            d2_table: Self::build_d2_table(&params, rep),
            assigned: 0,
            committed: 0,
            scratch: RefCell::new(JobLedger::empty()),
        }
    }

    fn build_d2_table(params: &MSgcParams, rep: bool) -> Vec<Arc<[usize]>> {
        let (n, b, w, lambda) = (params.n, params.b, params.w, params.lambda);
        if lambda >= n {
            return Vec::new();
        }
        let mut table = Vec::with_capacity(b * n);
        for m in 0..b {
            let base = (w - 1 + m) * n;
            for worker in 0..n {
                let chunks: Vec<usize> = if rep {
                    let g = worker / (lambda + 1);
                    (g * (lambda + 1)..(g + 1) * (lambda + 1)).map(|k| base + k).collect()
                } else {
                    cyclic_support(worker, lambda, n).into_iter().map(|k| base + k).collect()
                };
                table.push(chunks.into());
            }
        }
        table
    }

    /// The design parameters this instance was built with.
    pub fn params(&self) -> MSgcParams {
        self.params
    }

    /// Ledger group index for D2 group `m` and worker `i`.
    fn ledger_group(&self, m: usize, worker: usize) -> usize {
        if self.rep {
            let rep_groups = self.spec.n / (self.params.lambda + 1);
            m * rep_groups + worker / (self.params.lambda + 1)
        } else {
            m
        }
    }

    /// The compact mini-task for worker `i`, round `r`, slot `j`
    /// (Algorithm 2). Depends only on the round index and the worker's
    /// pending-failure list for job `r - j` — each `(worker, slot)` cell
    /// reads exactly the state its own commit step mutates, which is what
    /// makes re-derivation at commit time sound.
    fn unit_kind(&self, worker: usize, r: usize, slot: usize) -> UnitKind {
        let t = r as isize - slot as isize;
        if t < 1 || t as usize > self.jobs {
            return UnitKind::Noop;
        }
        let t = t as usize;
        let w = self.params.w;
        if slot < w - 1 {
            // First attempt of D1 partial g_{i(W-1)+slot}(t).
            UnitKind::Plain { job: t, chunk: worker * (w - 1) + slot }
        } else {
            let m = slot - (w - 1);
            if let Some(&chunk) = self.failed_d1[t - 1][worker].first() {
                // Re-attempt the oldest failed D1 partial for job t.
                UnitKind::Plain { job: t, chunk }
            } else if self.params.lambda < self.spec.n {
                UnitKind::Coded { job: t, group: self.ledger_group(m, worker) }
            } else {
                UnitKind::Noop // Remark 3.2: trivial partial gradients
            }
        }
    }

    /// Build the full mini-task (with its shared chunk list) for worker
    /// `i`, round `r`, slot `j`.
    fn unit_for(&self, worker: usize, r: usize, slot: usize) -> WorkUnit {
        match self.unit_kind(worker, r, slot) {
            UnitKind::Noop => WorkUnit::Noop,
            UnitKind::Plain { job, chunk } => WorkUnit::Plain { job, chunk },
            UnitKind::Coded { job, group } => {
                let m = slot - (self.params.w - 1);
                WorkUnit::Coded {
                    job,
                    group,
                    row: worker,
                    chunks: Arc::clone(&self.d2_table[m * self.spec.n + worker]),
                }
            }
        }
    }
}

impl Scheme for MSgcScheme {
    fn spec(&self) -> &SchemeSpec {
        &self.spec
    }

    fn jobs(&self) -> usize {
        self.jobs
    }

    fn assign_round_into(&mut self, r: usize, out: &mut Vec<TaskDesc>) {
        assert_eq!(r, self.assigned + 1, "rounds must be assigned in order");
        assert_eq!(self.committed, self.assigned, "previous round not committed");
        let slots = self.params.w - 1 + self.params.b;
        // `fill_tasks` needs `&mut out` alongside reads of `self`; the
        // shared-borrow closure only consults immutable scheme state.
        let this = &*self;
        fill_tasks(out, self.spec.n, |i, task| {
            for j in 0..slots {
                task.units.push(this.unit_for(i, r, j));
            }
        });
        self.assigned = r;
    }

    fn commit_round(&mut self, r: usize, responded: &[bool]) {
        assert_eq!(r, self.committed + 1);
        assert_eq!(r, self.assigned, "round not assigned");
        assert_eq!(responded.len(), self.spec.n);
        let w = self.params.w;
        let slots = w - 1 + self.params.b;
        // Re-derive each mini-task from the assign-time state. The
        // mutations below only touch the (job, worker) cell the current
        // slot serves, and every slot of a (worker, round) pair serves a
        // distinct job, so later derivations still see assign-time state.
        for i in 0..self.spec.n {
            for slot in 0..slots {
                match self.unit_kind(i, r, slot) {
                    UnitKind::Noop => {}
                    UnitKind::Plain { job, chunk } => {
                        if responded[i] {
                            self.ledgers[job - 1].plain_missing.remove(&chunk);
                            // A successful re-attempt clears the pending
                            // entry (first attempts have none).
                            self.failed_d1[job - 1][i].retain(|c| *c != chunk);
                        } else if slot < w - 1 {
                            // Failed *first attempt* → queue for re-attempts.
                            self.failed_d1[job - 1][i].push(chunk);
                        }
                        // Failed re-attempts: nothing to record — the
                        // pending entry is still queued.
                    }
                    UnitKind::Coded { job, group } => {
                        if responded[i] {
                            self.ledgers[job - 1].coded_got[group].insert(i);
                        }
                    }
                }
            }
        }
        self.committed = r;
    }

    fn decodable(&self, job: usize) -> bool {
        self.ledgers[job - 1].complete()
    }

    fn ledger(&self, job: usize) -> &JobLedger {
        &self.ledgers[job - 1]
    }

    fn decodable_with(&self, job: usize, r: usize, responded: &[bool]) -> bool {
        debug_assert_eq!(r, self.committed + 1);
        debug_assert_eq!(r, self.assigned);
        let mut scratch = self.scratch.borrow_mut();
        scratch.copy_into_from(&self.ledgers[job - 1]);
        // Slot j of round r serves job r - j: at most one slot serves
        // `job`, namely j = r - job (when within the task window).
        let slots = self.params.w - 1 + self.params.b;
        if let Some(slot) = r.checked_sub(job) {
            if slot < slots {
                for (i, &ok) in responded.iter().enumerate() {
                    if !ok {
                        continue;
                    }
                    match self.unit_kind(i, r, slot) {
                        UnitKind::Plain { job: j, chunk } if j == job => {
                            scratch.plain_missing.remove(&chunk);
                        }
                        UnitKind::Coded { job: j, group } if j == job => {
                            scratch.coded_got[group].insert(i);
                        }
                        _ => {}
                    }
                }
            }
        }
        scratch.complete()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_true(n: usize) -> Vec<bool> {
        vec![true; n]
    }

    /// Run the scheme over a straggler pattern matrix `strag[r-1][i]` and
    /// return decode status per job at each job's deadline.
    fn run_pattern(mut sch: MSgcScheme, strag: &[Vec<bool>]) -> Vec<bool> {
        let total = sch.total_rounds();
        assert!(strag.len() >= total);
        let mut ok = vec![false; sch.jobs()];
        for r in 1..=total {
            sch.assign_round(r);
            let responded: Vec<bool> = strag[r - 1].iter().map(|&s| !s).collect();
            sch.commit_round(r, &responded);
            if let Some(t) = sch.deadline_job(r) {
                ok[t - 1] = sch.decodable(t);
            }
        }
        ok
    }

    #[test]
    fn load_matches_paper_values() {
        // Table 1: M-SGC B=1, W=2, λ=27, n=256 → load ≈ 0.0078
        let p = MSgcParams { n: 256, b: 1, w: 2, lambda: 27 };
        p.validate();
        assert_eq!(p.delay(), 1);
        let expected = 28.0 * 2.0 / (256.0 * (1.0 + 28.0));
        assert!((p.load() - expected).abs() < 1e-12);
        assert!(p.load() < 0.008, "paper reports 0.008 (rounded)");

        // Remark 3.3: load ≤ 2/n for any λ.
        for lambda in 0..=16 {
            let p = MSgcParams { n: 16, b: 2, w: 4, lambda };
            assert!(p.load() <= 2.0 / 16.0 + 1e-12);
        }
    }

    #[test]
    fn example_f1_load() {
        // Example F.1: n=4, B=1, W=2, λ=4 → M-SGC load 1/2 (vs SR-SGC 3/4).
        let p = MSgcParams { n: 4, b: 1, w: 2, lambda: 4 };
        assert!((p.load() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn spec_is_consistent() {
        for (n, b, w, lambda) in [(4, 2, 3, 2), (8, 1, 2, 3), (6, 1, 3, 6), (9, 2, 4, 2)] {
            let sch = MSgcScheme::new(MSgcParams { n, b, w, lambda }, 5);
            sch.spec().validate();
        }
    }

    #[test]
    fn no_stragglers_decodes_every_job_at_deadline() {
        let p = MSgcParams { n: 4, b: 2, w: 3, lambda: 2 };
        let sch = MSgcScheme::new(p, 6);
        let total = 6 + p.delay();
        let strag = vec![vec![false; 4]; total];
        let ok = run_pattern(sch, &strag);
        assert!(ok.iter().all(|&x| x), "{ok:?}");
    }

    #[test]
    fn paper_example_sec_3_3_1() {
        // n=4, B=2, W=3, λ=2; Fig. 6 pattern: worker 0 straggles in round
        // 2; worker 1 in rounds 2 and 3. Job 2 must decode by round 5
        // (T = 3).
        let p = MSgcParams { n: 4, b: 2, w: 3, lambda: 2 };
        assert_eq!(p.delay(), 3);
        let jobs = 6;
        let total = jobs + p.delay();
        let mut strag = vec![vec![false; 4]; total];
        strag[1][0] = true; // round 2, worker 0
        strag[1][1] = true; // round 2, worker 1
        strag[2][1] = true; // round 3, worker 1
        let ok = run_pattern(MSgcScheme::new(p, jobs), &strag);
        assert!(ok.iter().all(|&x| x), "{ok:?}");
    }

    #[test]
    fn reattempt_slots_pick_up_failed_d1() {
        let p = MSgcParams { n: 4, b: 2, w: 3, lambda: 2 };
        let mut sch = MSgcScheme::new(p, 4);
        sch.assign_round(1);
        // worker 0 straggles in round 1 → its slot-0 first attempt for
        // job 1 (chunk 0*(W-1)+0 = 0) failed.
        sch.commit_round(1, &[false, true, true, true]);
        sch.assign_round(2);
        sch.commit_round(2, &all_true(4));
        // Round 3 = job-1's first re-attempt slot (slot W-1=2): worker 0
        // should re-attempt chunk 0 instead of the coded unit.
        let t3 = sch.assign_round(3);
        match &t3[0].units[2] {
            WorkUnit::Plain { job: 1, chunk: 0 } => {}
            other => panic!("expected re-attempt of chunk 0, got {other:?}"),
        }
        // worker 1 had no failures → coded unit in slot 2.
        assert!(matches!(&t3[1].units[2], WorkUnit::Coded { job: 1, .. }));
        sch.commit_round(3, &all_true(4));
        // job 1 D1 now complete; needs coded groups by deadline (round 4).
        sch.assign_round(4);
        sch.commit_round(4, &all_true(4));
        assert!(sch.decodable(1));
    }

    #[test]
    fn burst_of_b_failures_still_decodes() {
        // Worker 0 straggles B=2 consecutive rounds within each job's
        // window; bursty model with λ=1 ≥ 1 distinct straggler.
        let p = MSgcParams { n: 5, b: 2, w: 4, lambda: 1 };
        let jobs = 8;
        let total = jobs + p.delay();
        let mut strag = vec![vec![false; 5]; total];
        // a burst at rounds 3-4 (B=2), next burst earliest at round
        // 3 + W + B - 1… keep just one burst to conform to every window.
        strag[2][0] = true;
        strag[3][0] = true;
        let ok = run_pattern(MSgcScheme::new(p, jobs), &strag);
        assert!(ok.iter().all(|&x| x), "{ok:?}");
    }

    #[test]
    fn lambda_equals_n_all_plain() {
        // Remark 3.2 / Example F.1(b): n=4, B=1, W=2, λ=4; all workers
        // straggle in odd rounds; jobs still decode by deadline T=1.
        let p = MSgcParams { n: 4, b: 1, w: 2, lambda: 4 };
        assert_eq!(p.delay(), 1);
        let jobs = 6;
        let total = jobs + 1;
        let mut strag = vec![vec![false; 4]; total];
        for r in (0..total).step_by(2) {
            strag[r] = vec![true; 4]; // rounds 1,3,5,… all stragglers
        }
        let sch = MSgcScheme::new(p, jobs);
        // no coded groups at λ=n
        assert!(sch.ledgers[0].coded_need.is_empty());
        let ok = run_pattern(sch, &strag);
        assert!(ok.iter().all(|&x| x), "{ok:?}");
    }

    #[test]
    fn too_many_stragglers_fails_at_deadline() {
        // Worker 0 straggles B+1 rounds in a job's window — exceeds the
        // re-attempt capacity; that job's D1 part cannot finish on time.
        let p = MSgcParams { n: 4, b: 1, w: 3, lambda: 1 };
        let jobs = 4;
        let total = jobs + p.delay();
        let mut strag = vec![vec![false; 4]; total];
        // job 1's window is rounds 1..=3 (W-1+B = 3 slots): fail worker 0
        // in rounds 1 and 3 → first attempt and the only re-attempt die.
        strag[0][0] = true;
        strag[2][0] = true;
        let ok = run_pattern(MSgcScheme::new(p, jobs), &strag);
        assert!(!ok[0], "job 1 must miss its deadline under a non-conforming pattern");
    }

    #[test]
    fn rep_variant_thresholds() {
        // n=4, λ=1, (λ+1)|n → 2 rep-groups per D2 group.
        let p = MSgcParams { n: 4, b: 1, w: 2, lambda: 1 };
        let sch = MSgcScheme::new_rep(p, 2);
        sch.spec().validate();
        assert_eq!(sch.ledgers[0].coded_need, vec![1, 1]);
        // all workers respond → decodes
        let mut sch = sch;
        for r in 1..=sch.total_rounds() {
            sch.assign_round(r);
            sch.commit_round(r, &all_true(4));
        }
        assert!(sch.decodable(1) && sch.decodable(2));
    }

    #[test]
    fn task_load_equals_spec_load_every_round() {
        // The per-round assigned load never exceeds the closed-form L and
        // equals it for interior rounds with no stragglers.
        let p = MSgcParams { n: 4, b: 2, w: 3, lambda: 2 };
        let mut sch = MSgcScheme::new(p, 10);
        let spec = sch.spec().clone();
        for r in 1..=sch.total_rounds() {
            let tasks = sch.assign_round(r);
            for t in &tasks {
                let load = spec.task_load(t);
                assert!(load <= spec.load + 1e-12, "round {r}: load {load} > {}", spec.load);
                if r > p.delay() && r <= 10 {
                    assert!((load - spec.load).abs() < 1e-12, "round {r}: {load}");
                }
            }
            let n = spec.n;
            sch.commit_round(r, &all_true(n));
        }
    }

    #[test]
    fn commit_rederivation_matches_assigned_units() {
        // The compact unit_kind re-derivation must agree with the full
        // units actually handed out, round over round, under stragglers.
        let p = MSgcParams { n: 5, b: 2, w: 3, lambda: 2 };
        let mut sch = MSgcScheme::new(p, 6);
        let slots = p.w - 1 + p.b;
        for r in 1..=sch.total_rounds() {
            let tasks = sch.assign_round(r);
            for (i, task) in tasks.iter().enumerate() {
                for (j, unit) in task.units.iter().enumerate() {
                    let kind = sch.unit_kind(i, r, j);
                    let expected = match unit {
                        WorkUnit::Noop => UnitKind::Noop,
                        WorkUnit::Plain { job, chunk } => {
                            UnitKind::Plain { job: *job, chunk: *chunk }
                        }
                        WorkUnit::Coded { job, group, .. } => {
                            UnitKind::Coded { job: *job, group: *group }
                        }
                    };
                    assert_eq!(kind, expected, "worker {i} slot {j} round {r}");
                }
                assert_eq!(task.units.len(), slots);
            }
            // worker r % n straggles this round
            let responded: Vec<bool> = (0..p.n).map(|i| i != r % p.n).collect();
            sch.commit_round(r, &responded);
        }
    }
}
