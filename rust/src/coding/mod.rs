//! Coding schemes: classical GC (Sec. 3.1), SR-SGC (Sec. 3.2),
//! M-SGC (Sec. 3.3), the uncoded baseline, and the Appendix-F bounds.

pub mod bounds;
pub mod gc;
pub mod m_sgc;
pub mod plan_cache;
pub mod scheme;
pub mod sr_sgc;
pub mod uncoded;

pub use gc::{
    responder_mask, GcCode, GcRepScheme, GcScheme, ResponderMask, MAX_MEMOIZED_WORKERS,
};
pub use m_sgc::{MSgcParams, MSgcScheme};
pub use plan_cache::{CodePlan, CodePlanCache, PLAN_SEED};
pub use scheme::{fill_tasks, JobLedger, Scheme, SchemeSpec, TaskDesc, ToleranceSpec, WorkUnit};
pub use sr_sgc::{SrSgcParams, SrSgcScheme};
pub use uncoded::UncodedScheme;

/// Which scheme to instantiate (CLI / probe / bench surface).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SchemeKind {
    /// Gradient coding, tolerance `s` stragglers per round (delay 0).
    Gc { s: usize },
    /// GC via chunk replication instead of coded combinations.
    GcRep { s: usize },
    /// Selective-repeat SGC under the `(B, W, lambda)` bursty model.
    SrSgc { b: usize, w: usize, lambda: usize },
    /// SR-SGC with replication-based per-round codes.
    SrSgcRep { b: usize, w: usize, lambda: usize },
    /// Multiplexed SGC (lowest load, window-length delay).
    MSgc { b: usize, w: usize, lambda: usize },
    /// M-SGC with replication-based component codes.
    MSgcRep { b: usize, w: usize, lambda: usize },
    /// No redundancy: every round waits for all `n` workers.
    Uncoded,
}

/// Scheme configuration: kind + cluster size.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SchemeConfig {
    /// Worker count the scheme is built for.
    pub n: usize,
    /// Which scheme (and its parameters).
    pub kind: SchemeKind,
}

impl SchemeConfig {
    /// GC tolerating `s` stragglers per round.
    pub fn gc(n: usize, s: usize) -> Self {
        SchemeConfig { n, kind: SchemeKind::Gc { s } }
    }

    /// Replication-based GC tolerating `s` stragglers per round.
    pub fn gc_rep(n: usize, s: usize) -> Self {
        SchemeConfig { n, kind: SchemeKind::GcRep { s } }
    }

    /// SR-SGC for the `(B, W, lambda)` bursty model.
    pub fn sr_sgc(n: usize, b: usize, w: usize, lambda: usize) -> Self {
        SchemeConfig { n, kind: SchemeKind::SrSgc { b, w, lambda } }
    }

    /// Replication-based SR-SGC for the `(B, W, lambda)` bursty model.
    pub fn sr_sgc_rep(n: usize, b: usize, w: usize, lambda: usize) -> Self {
        SchemeConfig { n, kind: SchemeKind::SrSgcRep { b, w, lambda } }
    }

    /// M-SGC for the `(B, W, lambda)` bursty model.
    pub fn msgc(n: usize, b: usize, w: usize, lambda: usize) -> Self {
        SchemeConfig { n, kind: SchemeKind::MSgc { b, w, lambda } }
    }

    /// Replication-based M-SGC for the `(B, W, lambda)` bursty model.
    pub fn msgc_rep(n: usize, b: usize, w: usize, lambda: usize) -> Self {
        SchemeConfig { n, kind: SchemeKind::MSgcRep { b, w, lambda } }
    }

    /// The uncoded baseline (waits for everyone).
    pub fn uncoded(n: usize) -> Self {
        SchemeConfig { n, kind: SchemeKind::Uncoded }
    }

    /// Normalized per-worker load of the configured scheme.
    pub fn load(&self) -> f64 {
        match &self.kind {
            SchemeKind::Gc { s } | SchemeKind::GcRep { s } => bounds::gc_load(self.n, *s),
            SchemeKind::SrSgc { b, w, lambda } | SchemeKind::SrSgcRep { b, w, lambda } => {
                bounds::sr_sgc_load(self.n, *b, *w, *lambda)
            }
            SchemeKind::MSgc { b, w, lambda } | SchemeKind::MSgcRep { b, w, lambda } => {
                bounds::m_sgc_load(self.n, *b, *w, *lambda)
            }
            SchemeKind::Uncoded => 1.0 / self.n as f64,
        }
    }

    /// Decode delay `T` of the configured scheme.
    pub fn delay(&self) -> usize {
        match &self.kind {
            SchemeKind::Gc { .. } | SchemeKind::GcRep { .. } | SchemeKind::Uncoded => 0,
            SchemeKind::SrSgc { b, .. } | SchemeKind::SrSgcRep { b, .. } => *b,
            SchemeKind::MSgc { b, w, .. } | SchemeKind::MSgcRep { b, w, .. } => w - 2 + b,
        }
    }

    /// Stragglers the scheme tolerates in a single round while staying
    /// decodable: `s` for GC, `λ` for the bursty schemes (their
    /// per-round budget inside a window), 0 for uncoded. The scheduler
    /// uses this to spot a live roster too small to ever conform —
    /// `live < n - tolerance` — and enter degraded mode instead of
    /// waiting forever.
    pub fn per_round_tolerance(&self) -> usize {
        match &self.kind {
            SchemeKind::Gc { s } | SchemeKind::GcRep { s } => *s,
            SchemeKind::SrSgc { lambda, .. }
            | SchemeKind::SrSgcRep { lambda, .. }
            | SchemeKind::MSgc { lambda, .. }
            | SchemeKind::MSgcRep { lambda, .. } => *lambda,
            SchemeKind::Uncoded => 0,
        }
    }

    /// Instantiate scheme state for a run of `jobs` jobs.
    pub fn build(&self, jobs: usize) -> Box<dyn Scheme> {
        match &self.kind {
            SchemeKind::Gc { s } => Box::new(GcScheme::new(self.n, *s, jobs)),
            SchemeKind::GcRep { s } => Box::new(GcRepScheme::new(self.n, *s, jobs)),
            SchemeKind::SrSgc { b, w, lambda } => Box::new(SrSgcScheme::new(
                SrSgcParams { n: self.n, b: *b, w: *w, lambda: *lambda },
                jobs,
            )),
            SchemeKind::SrSgcRep { b, w, lambda } => Box::new(SrSgcScheme::new_rep(
                SrSgcParams { n: self.n, b: *b, w: *w, lambda: *lambda },
                jobs,
            )),
            SchemeKind::MSgc { b, w, lambda } => Box::new(MSgcScheme::new(
                MSgcParams { n: self.n, b: *b, w: *w, lambda: *lambda },
                jobs,
            )),
            SchemeKind::MSgcRep { b, w, lambda } => Box::new(MSgcScheme::new_rep(
                MSgcParams { n: self.n, b: *b, w: *w, lambda: *lambda },
                jobs,
            )),
            SchemeKind::Uncoded => Box::new(UncodedScheme::new(self.n, jobs)),
        }
    }

    /// Short display label ("m-sgc(1,2,27)" style, used in reports).
    pub fn label(&self) -> String {
        match &self.kind {
            SchemeKind::Gc { s } => format!("gc(s={s})"),
            SchemeKind::GcRep { s } => format!("gc-rep(s={s})"),
            SchemeKind::SrSgc { b, w, lambda } => format!("sr-sgc({b},{w},{lambda})"),
            SchemeKind::SrSgcRep { b, w, lambda } => format!("sr-sgc-rep({b},{w},{lambda})"),
            SchemeKind::MSgc { b, w, lambda } => format!("m-sgc({b},{w},{lambda})"),
            SchemeKind::MSgcRep { b, w, lambda } => format!("m-sgc-rep({b},{w},{lambda})"),
            SchemeKind::Uncoded => "uncoded".to_string(),
        }
    }

    /// Parse a CLI spec like `gc:15`, `sr-sgc:2,3,23`, `m-sgc:1,2,27`,
    /// `uncoded` — or the [`label`](Self::label) form (`gc(s=15)`,
    /// `m-sgc-rep(1,2,27)`), so labels round-trip through `parse`.
    pub fn parse(n: usize, spec: &str) -> anyhow::Result<Self> {
        let (kind, rest) = match spec.split_once(':') {
            Some((k, r)) => (k, r),
            None => match spec.strip_suffix(')').and_then(|s| s.split_once('(')) {
                // label form: `kind(params…)`, with GC's `s=` prefix
                Some((k, inner)) => (k, inner.strip_prefix("s=").unwrap_or(inner)),
                None => (spec, ""),
            },
        };
        let nums: Vec<usize> = if rest.is_empty() {
            Vec::new()
        } else {
            rest.split(',')
                .map(|t| t.trim().parse::<usize>())
                .collect::<Result<_, _>>()
                .map_err(|e| anyhow::anyhow!("bad scheme spec {spec:?}: {e}"))?
        };
        let need = |k: usize| -> anyhow::Result<()> {
            if nums.len() != k {
                anyhow::bail!("scheme {kind:?} needs {k} parameters, got {}", nums.len());
            }
            Ok(())
        };
        let kind = match kind {
            "gc" => {
                need(1)?;
                SchemeKind::Gc { s: nums[0] }
            }
            "gc-rep" => {
                need(1)?;
                SchemeKind::GcRep { s: nums[0] }
            }
            "sr-sgc" => {
                need(3)?;
                SchemeKind::SrSgc { b: nums[0], w: nums[1], lambda: nums[2] }
            }
            "sr-sgc-rep" => {
                need(3)?;
                SchemeKind::SrSgcRep { b: nums[0], w: nums[1], lambda: nums[2] }
            }
            "m-sgc" => {
                need(3)?;
                SchemeKind::MSgc { b: nums[0], w: nums[1], lambda: nums[2] }
            }
            "m-sgc-rep" => {
                need(3)?;
                SchemeKind::MSgcRep { b: nums[0], w: nums[1], lambda: nums[2] }
            }
            "uncoded" | "none" => {
                need(0)?;
                SchemeKind::Uncoded
            }
            other => anyhow::bail!("unknown scheme {other:?}"),
        };
        Ok(SchemeConfig { n, kind })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips() {
        let cases = [
            ("gc:15", SchemeKind::Gc { s: 15 }),
            ("gc-rep:15", SchemeKind::GcRep { s: 15 }),
            ("sr-sgc:2,3,23", SchemeKind::SrSgc { b: 2, w: 3, lambda: 23 }),
            ("sr-sgc-rep:2,3,23", SchemeKind::SrSgcRep { b: 2, w: 3, lambda: 23 }),
            ("m-sgc:1,2,27", SchemeKind::MSgc { b: 1, w: 2, lambda: 27 }),
            ("m-sgc-rep:1,2,27", SchemeKind::MSgcRep { b: 1, w: 2, lambda: 27 }),
            ("uncoded", SchemeKind::Uncoded),
        ];
        for (spec, kind) in cases {
            let c = SchemeConfig::parse(256, spec).unwrap();
            assert_eq!(c.kind, kind, "{spec}");
        }
        assert!(SchemeConfig::parse(4, "nope:1").is_err());
        assert!(SchemeConfig::parse(4, "gc:1,2").is_err());
        assert!(SchemeConfig::parse(4, "sr-sgc-rep:1").is_err());
    }

    #[test]
    fn labels_round_trip_through_parse() {
        // Every SchemeKind's display label parses back to itself.
        let configs = [
            SchemeConfig::gc(64, 5),
            SchemeConfig::gc_rep(64, 7),
            SchemeConfig::sr_sgc(64, 2, 3, 23),
            SchemeConfig::sr_sgc_rep(64, 2, 3, 23),
            SchemeConfig::msgc(64, 1, 2, 27),
            SchemeConfig::msgc_rep(64, 1, 2, 27),
            SchemeConfig::uncoded(64),
        ];
        for cfg in configs {
            let label = cfg.label();
            let parsed = SchemeConfig::parse(cfg.n, &label).unwrap();
            assert_eq!(parsed, cfg, "label {label:?} did not round-trip");
        }
    }

    #[test]
    fn table1_loads() {
        // Table 1 normalized loads at n = 256.
        let msgc = SchemeConfig::msgc(256, 1, 2, 27);
        let srsgc = SchemeConfig::sr_sgc(256, 2, 3, 23);
        let gc = SchemeConfig::gc(256, 15);
        let unc = SchemeConfig::uncoded(256);
        assert!((msgc.load() - 0.00754).abs() < 1e-4); // paper: 0.008
        assert!((srsgc.load() - 0.0508).abs() < 1e-3); // paper: 0.051
        assert!((gc.load() - 0.0625).abs() < 1e-12); // paper: 0.062
        assert!((unc.load() - 0.0039).abs() < 1e-4); // paper: 0.004
        // delays
        assert_eq!(msgc.delay(), 1);
        assert_eq!(srsgc.delay(), 2);
        assert_eq!(gc.delay(), 0);
    }

    #[test]
    fn build_produces_matching_specs() {
        for spec in ["gc:3", "gc-rep:3", "sr-sgc:1,2,4", "m-sgc:1,2,4", "uncoded"] {
            let c = SchemeConfig::parse(8, spec).unwrap();
            let s = c.build(10);
            assert_eq!(s.spec().n, 8);
            assert_eq!(s.spec().delay, c.delay(), "{spec}");
            assert!((s.spec().load - c.load()).abs() < 1e-12, "{spec}");
            s.spec().validate();
        }
    }
}
