//! The "No Coding" baseline of Table 1: the dataset is split into `n`
//! equal chunks, worker `i` computes only chunk `i`, and the master must
//! wait for **every** worker in every round (no straggler tolerance).

use super::scheme::{JobLedger, Scheme, SchemeSpec, TaskDesc, ToleranceSpec, WorkUnit};
use std::collections::HashSet;

/// Uncoded distributed gradient descent.
pub struct UncodedScheme {
    spec: SchemeSpec,
    jobs: usize,
    ledgers: Vec<JobLedger>,
    assigned: Vec<Vec<TaskDesc>>,
    committed: usize,
}

impl UncodedScheme {
    pub fn new(n: usize, jobs: usize) -> Self {
        let spec = SchemeSpec {
            name: format!("uncoded(n={n})"),
            n,
            delay: 0,
            load: 1.0 / n as f64,
            num_chunks: n,
            chunk_sizes: vec![1.0 / n as f64; n],
            placement: (0..n).map(|i| vec![i]).collect(),
            tolerance: ToleranceSpec::None,
        };
        let ledgers = (0..jobs)
            .map(|_| JobLedger {
                plain_missing: (0..n).collect::<HashSet<_>>(),
                coded_got: Vec::new(),
                coded_need: Vec::new(),
            })
            .collect();
        UncodedScheme { spec, jobs, ledgers, assigned: Vec::new(), committed: 0 }
    }
}

impl Scheme for UncodedScheme {
    fn spec(&self) -> &SchemeSpec {
        &self.spec
    }

    fn jobs(&self) -> usize {
        self.jobs
    }

    fn assign_round(&mut self, r: usize) -> Vec<TaskDesc> {
        assert_eq!(r, self.assigned.len() + 1);
        assert_eq!(self.committed, self.assigned.len());
        let tasks: Vec<TaskDesc> = (0..self.spec.n)
            .map(|i| {
                if r >= 1 && r <= self.jobs {
                    TaskDesc { units: vec![WorkUnit::Plain { job: r, chunk: i }] }
                } else {
                    TaskDesc::noop()
                }
            })
            .collect();
        self.assigned.push(tasks.clone());
        tasks
    }

    fn commit_round(&mut self, r: usize, responded: &[bool]) {
        assert_eq!(r, self.committed + 1);
        for (w, task) in self.assigned[r - 1].iter().enumerate() {
            if !responded[w] {
                continue;
            }
            for unit in &task.units {
                if let Some(job) = unit.job() {
                    self.ledgers[job - 1].deliver(w, unit);
                }
            }
        }
        // Committed rounds are never read again — drop their task
        // storage so long runs stay O(window), not O(rounds).
        self.assigned[r - 1] = Vec::new();
        self.committed = r;
    }

    fn decodable(&self, job: usize) -> bool {
        self.ledgers[job - 1].complete()
    }

    fn ledger(&self, job: usize) -> &JobLedger {
        &self.ledgers[job - 1]
    }

    fn decodable_with(&self, job: usize, r: usize, responded: &[bool]) -> bool {
        debug_assert_eq!(r, self.committed + 1);
        let mut ledger = self.ledgers[job - 1].clone();
        for (w, task) in self.assigned[r - 1].iter().enumerate() {
            if !responded[w] {
                continue;
            }
            for unit in &task.units {
                if unit.job() == Some(job) {
                    ledger.deliver(w, unit);
                }
            }
        }
        ledger.complete()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn needs_every_worker() {
        let mut sch = UncodedScheme::new(4, 2);
        sch.spec().validate();
        sch.assign_round(1);
        assert!(!sch.decodable_with(1, 1, &[true, true, true, false]));
        assert!(sch.decodable_with(1, 1, &[true; 4]));
        sch.commit_round(1, &[true, true, true, false]);
        assert!(!sch.decodable(1));
    }
}
