//! The "No Coding" baseline of Table 1: the dataset is split into `n`
//! equal chunks, worker `i` computes only chunk `i`, and the master must
//! wait for **every** worker in every round (no straggler tolerance).

use super::scheme::{fill_tasks, JobLedger, Scheme, SchemeSpec, TaskDesc, ToleranceSpec, WorkUnit};
use std::cell::RefCell;
use std::collections::HashSet;

/// Uncoded distributed gradient descent.
///
/// Round `r`'s task for worker `i` is always `Plain { job: r, chunk: i }`
/// (or a noop past `J`), so no per-round task storage is kept (§Perf).
pub struct UncodedScheme {
    spec: SchemeSpec,
    jobs: usize,
    ledgers: Vec<JobLedger>,
    assigned: usize,
    committed: usize,
    /// Reusable `decodable_with` ledger (replaces `JobLedger::clone`).
    scratch: RefCell<JobLedger>,
}

impl UncodedScheme {
    /// Uncoded baseline over `n` workers for a `jobs`-round run.
    pub fn new(n: usize, jobs: usize) -> Self {
        let spec = SchemeSpec {
            name: format!("uncoded(n={n})"),
            n,
            delay: 0,
            load: 1.0 / n as f64,
            num_chunks: n,
            chunk_sizes: vec![1.0 / n as f64; n],
            placement: (0..n).map(|i| vec![i]).collect(),
            tolerance: ToleranceSpec::None,
        };
        let ledgers = (0..jobs)
            .map(|_| JobLedger {
                plain_missing: (0..n).collect::<HashSet<_>>(),
                coded_got: Vec::new(),
                coded_need: Vec::new(),
            })
            .collect();
        UncodedScheme {
            spec,
            jobs,
            ledgers,
            assigned: 0,
            committed: 0,
            scratch: RefCell::new(JobLedger::empty()),
        }
    }
}

impl Scheme for UncodedScheme {
    fn spec(&self) -> &SchemeSpec {
        &self.spec
    }

    fn jobs(&self) -> usize {
        self.jobs
    }

    fn assign_round_into(&mut self, r: usize, out: &mut Vec<TaskDesc>) {
        assert_eq!(r, self.assigned + 1, "rounds must be assigned in order");
        assert_eq!(self.committed, self.assigned, "previous round not committed");
        let in_range = r >= 1 && r <= self.jobs;
        fill_tasks(out, self.spec.n, |i, task| {
            task.units.push(if in_range {
                WorkUnit::Plain { job: r, chunk: i }
            } else {
                WorkUnit::Noop
            });
        });
        self.assigned = r;
    }

    fn commit_round(&mut self, r: usize, responded: &[bool]) {
        assert_eq!(r, self.committed + 1);
        assert_eq!(r, self.assigned, "round not assigned");
        assert_eq!(responded.len(), self.spec.n);
        if r >= 1 && r <= self.jobs {
            let ledger = &mut self.ledgers[r - 1];
            for (w, &ok) in responded.iter().enumerate() {
                if ok {
                    ledger.plain_missing.remove(&w);
                }
            }
        }
        self.committed = r;
    }

    fn decodable(&self, job: usize) -> bool {
        self.ledgers[job - 1].complete()
    }

    fn ledger(&self, job: usize) -> &JobLedger {
        &self.ledgers[job - 1]
    }

    fn decodable_with(&self, job: usize, r: usize, responded: &[bool]) -> bool {
        debug_assert_eq!(r, self.committed + 1);
        debug_assert_eq!(r, self.assigned);
        let mut scratch = self.scratch.borrow_mut();
        scratch.copy_into_from(&self.ledgers[job - 1]);
        if job == r && r <= self.jobs {
            for (w, &ok) in responded.iter().enumerate() {
                if ok {
                    scratch.plain_missing.remove(&w);
                }
            }
        }
        scratch.complete()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn needs_every_worker() {
        let mut sch = UncodedScheme::new(4, 2);
        sch.spec().validate();
        sch.assign_round(1);
        assert!(!sch.decodable_with(1, 1, &[true, true, true, false]));
        assert!(sch.decodable_with(1, 1, &[true; 4]));
        sch.commit_round(1, &[true, true, true, false]);
        assert!(!sch.decodable(1));
    }
}
