//! Adaptive control plane: online straggler profiling, background
//! re-fit, and hot-swap of scheme parameters.
//!
//! The paper's schemes are *parameterized by* past straggler behavior —
//! `(B, W, λ)` are chosen to match the observed burst model (Appendix
//! J). This module closes that loop online, the full
//! observe → estimate → re-fit → swap cycle:
//!
//! * [`OnlineProfiler`] (*observe*) folds the live `WorkerDone` stream
//!   into per-worker delay estimates and detects straggler-regime
//!   shifts (exponentially-weighted fast-vs-slow divergence);
//! * [`Refitter`] (*estimate/re-fit*) re-runs the Appendix-J candidate
//!   search against the live profile, amortized a few candidates per
//!   round so the scheduler hot path never blocks;
//! * [`SwapPolicy`] (*decide*) accepts a re-fitted scheme only with a
//!   predicted-gain margin, a cooldown, and (by default) a detected
//!   regime shift — stationary profiles never swap;
//! * [`AdaptiveController`] ties the three together per scheduled job
//!   and is what [`crate::sched::JobScheduler`] drives when serving
//!   with adaptation enabled (`sgc serve --adapt`).
//!
//! Swaps themselves are executed by the scheduler at **job
//! boundaries**: the incumbent session is truncated after its currently
//! assigned paper-jobs, runs only its decode tail, and a fresh
//! [`crate::session::SgcSession`] with the re-fitted parameters takes
//! over the remaining jobs — never mid-round, never dropping a job the
//! ledger still owes (see DESIGN.md §Adaptive).

pub mod profiler;
pub mod refit;
pub mod swap;

pub use profiler::{OnlineProfiler, ProfilerConfig};
pub use refit::{refit_candidates, FitOutcome, Refitter};
pub use swap::SwapPolicy;

use crate::coding::SchemeConfig;
use crate::obs::{Counter, EventKind, Obs};
use crate::util::json::Json;
use std::sync::Arc;

/// Configuration of the adaptive control plane.
#[derive(Clone, Debug)]
pub struct AdaptiveConfig {
    /// Candidates estimated per round close per job (`--refit-budget`).
    pub refit_budget: usize,
    /// Profile rounds required (post-shift) before a re-fit pass may
    /// start.
    pub min_profile_rounds: usize,
    /// Jobs replayed per candidate estimate.
    pub estimate_jobs: usize,
    /// Swap acceptance policy (`--swap-margin` feeds its margin).
    pub policy: SwapPolicy,
    /// Online profiler knobs (window, decay, shift threshold).
    pub profiler: ProfilerConfig,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            refit_budget: 4,
            min_profile_rounds: 6,
            estimate_jobs: 12,
            policy: SwapPolicy::default(),
            profiler: ProfilerConfig::default(),
        }
    }
}

/// One executed hot-swap, as recorded in
/// [`crate::sched::ScheduleReport::swaps`].
#[derive(Clone, Debug, PartialEq)]
pub struct SchemeSwapped {
    /// Scheduler job id that migrated.
    pub job: usize,
    /// Cluster round count of the job at the moment the new scheme took
    /// over (its first round runs as cluster round `at_round + 1`).
    pub at_round: u64,
    /// Label of the scheme migrated away from.
    pub from: String,
    /// Label of the re-fitted scheme migrated to.
    pub to: String,
    /// Fractional runtime improvement the re-fit predicted.
    pub predicted_gain: f64,
    /// Cluster wall-clock at the swap.
    pub at_s: f64,
}

impl SchemeSwapped {
    /// Serialize every field (part of
    /// [`crate::sched::ScheduleReport::to_json`]).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("job", self.job)
            .set("at_round", self.at_round)
            .set("from", self.from.as_str())
            .set("to", self.to.as_str())
            .set("predicted_gain", self.predicted_gain)
            .set("at_s", self.at_s);
        o
    }
}

impl std::fmt::Display for SchemeSwapped {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "job {}: {} -> {} at round {} (predicted -{:.1}%, t={:.1}s)",
            self.job,
            self.from,
            self.to,
            self.at_round,
            self.predicted_gain * 100.0,
            self.at_s
        )
    }
}

/// Per-job adaptation state.
#[derive(Debug, Default)]
struct JobAdapt {
    refitter: Option<Refitter>,
    pending: Option<(SchemeConfig, f64)>,
    rounds_since_swap: u64,
    shift_armed: bool,
}

/// Drives the adaptive loop for every job of a
/// [`crate::sched::JobScheduler`] run (see module docs). All methods
/// are deterministic functions of the observed event stream — the
/// controller draws no randomness, so identical runs make identical
/// swap decisions.
#[derive(Debug)]
pub struct AdaptiveController {
    cfg: AdaptiveConfig,
    profiler: OnlineProfiler,
    jobs: Vec<JobAdapt>,
    evaluated_total: u64,
    last_pass_at: u64,
    obs: Option<AdaptObs>,
}

/// Observability handles for the control plane (see [`crate::obs`]).
struct AdaptObs {
    obs: Arc<Obs>,
    shifts: Counter,
    passes: Counter,
    staged: Counter,
}

impl std::fmt::Debug for AdaptObs {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("AdaptObs { .. }")
    }
}

impl AdaptiveController {
    /// Controller with the given knobs.
    pub fn new(cfg: AdaptiveConfig) -> Self {
        let profiler = OnlineProfiler::new(cfg.profiler.clone());
        AdaptiveController {
            cfg,
            profiler,
            jobs: Vec::new(),
            evaluated_total: 0,
            last_pass_at: 0,
            obs: None,
        }
    }

    /// Attach an observability bundle: regime shifts, completed re-fit
    /// passes and staged swaps are counted and journaled. The scheduler
    /// calls this at run start when both observability and adaptation
    /// are configured; the hooks are read-only, so decisions are
    /// unchanged by instrumentation.
    pub fn set_obs(&mut self, obs: Arc<Obs>) {
        let shifts =
            obs.metrics.counter("sgc_regime_shifts_total", "", "Straggler-regime shifts detected");
        let passes = obs.metrics.counter(
            "sgc_refit_passes_total",
            "",
            "Completed background re-fit passes",
        );
        let staged =
            obs.metrics.counter("sgc_swaps_staged_total", "", "Swaps staged by the swap policy");
        self.obs = Some(AdaptObs { obs, shifts, passes, staged });
    }

    /// Hook: a round fanned out (`place[i]` = physical worker serving
    /// logical worker `i` at `loads[i]`).
    pub fn register_round(&mut self, job: usize, round: u64, place: &[usize], loads: &[f64]) {
        self.profiler.register_round(job, round, place, loads);
    }

    /// Hook: a `WorkerDone` arrived for logical worker `logical` of an
    /// open round.
    pub fn observe_done(&mut self, job: usize, round: u64, logical: usize, finish_s: f64) {
        self.profiler.observe(job, round, logical, finish_s);
    }

    /// Hook: the scheduler closed `(job, round)` with `incumbent` as
    /// the job's current scheme at cluster time `now_s` (used only for
    /// journaling). Folds the round into the profile, propagates regime
    /// shifts, runs one budgeted re-fit tick, and — when a completed
    /// pass clears the swap policy — stages a pending swap for the job
    /// (query with [`pending_swap`](Self::pending_swap)).
    pub fn round_closed(&mut self, job: usize, round: u64, incumbent: &SchemeConfig, now_s: f64) {
        self.ensure_job(job);
        if self.profiler.fold_round(job, round) {
            if let Some(ob) = &self.obs {
                ob.shifts.inc();
                ob.obs.journal.record(
                    now_s,
                    EventKind::RegimeShift,
                    job as i64,
                    round as i64,
                    -1,
                    0.0,
                );
            }
            // Regime shift: stale-regime passes are worthless, and every
            // job becomes eligible to swap once its window refills.
            for st in self.jobs.iter_mut() {
                st.shift_armed = true;
                if let Some(rf) = st.refitter.as_mut() {
                    rf.abort_pass();
                }
            }
        }
        let min_rounds = self.cfg.min_profile_rounds;
        let budget = self.cfg.refit_budget;
        let estimate_jobs = self.cfg.estimate_jobs;
        let st = &mut self.jobs[job];
        st.rounds_since_swap += 1;
        if st.pending.is_some() {
            return; // draining toward an accepted swap: stop fitting
        }
        let rf = st
            .refitter
            .get_or_insert_with(|| Refitter::new(incumbent, budget, estimate_jobs));
        if rf.candidate_count() <= 1 {
            return; // nothing to re-fit (uncoded)
        }
        let before = rf.evaluated();
        rf.maybe_begin(&self.profiler, job, min_rounds);
        let outcome = rf.tick();
        self.evaluated_total += rf.evaluated() - before;
        if let Some(outcome) = outcome {
            self.last_pass_at = self.profiler.rounds_folded();
            if let Some(ob) = &self.obs {
                ob.passes.inc();
                ob.obs.journal.record(
                    now_s,
                    EventKind::RefitPass,
                    job as i64,
                    round as i64,
                    -1,
                    self.evaluated_total as f64,
                );
            }
            if let Some(accept) =
                self.cfg.policy.decide(&outcome, incumbent, st.rounds_since_swap, st.shift_armed)
            {
                if let Some(ob) = &self.obs {
                    ob.staged.inc();
                    ob.obs.journal.record(
                        now_s,
                        EventKind::SwapStaged,
                        job as i64,
                        round as i64,
                        -1,
                        accept.1,
                    );
                }
                st.pending = Some(accept);
            }
        }
    }

    /// The swap staged for a job, if any: the scheduler truncates the
    /// incumbent session and executes the swap once its decode tail
    /// completes.
    pub fn pending_swap(&self, job: usize) -> Option<&(SchemeConfig, f64)> {
        self.jobs.get(job).and_then(|st| st.pending.as_ref())
    }

    /// Consume the staged swap and reset the job's hysteresis state
    /// (cooldown restarts, the shift gate re-arms only on the next
    /// detected shift, and the re-fitter is rebuilt around the new
    /// incumbent).
    pub fn take_swap(&mut self, job: usize) -> Option<(SchemeConfig, f64)> {
        let st = self.jobs.get_mut(job)?;
        let accepted = st.pending.take()?;
        st.shift_armed = false;
        st.rounds_since_swap = 0;
        st.refitter = None;
        Some(accepted)
    }

    /// Profile-driven spare selection: among live workers outside
    /// `place`, the one with the lowest observed fast delay mean
    /// (unobserved workers rank last; ties break to the lowest id,
    /// matching the scheduler's non-adaptive first-fit).
    pub fn prefer_spare(&self, live: &[bool], place: &[usize]) -> Option<usize> {
        let mut best: Option<(f64, usize)> = None;
        for c in 0..live.len() {
            if !live[c] || place.contains(&c) {
                continue;
            }
            let m = self.profiler.fast_mean(c).unwrap_or(f64::INFINITY);
            match best {
                Some((bm, _)) if m >= bm => {}
                _ => best = Some((m, c)),
            }
        }
        best.map(|(_, c)| c)
    }

    /// Re-fit candidates evaluated so far (all jobs).
    pub fn candidates_evaluated(&self) -> u64 {
        self.evaluated_total
    }

    /// Rounds folded since the last completed re-fit pass — how stale
    /// the fitted parameters are relative to the live profile.
    pub fn profile_staleness(&self) -> u64 {
        self.profiler.rounds_folded() - self.last_pass_at
    }

    /// Regime shifts detected so far.
    pub fn shifts(&self) -> u64 {
        self.profiler.shifts()
    }

    /// Shared read access to the profiler (inspection / tests).
    pub fn profiler(&self) -> &OnlineProfiler {
        &self.profiler
    }

    fn ensure_job(&mut self, job: usize) {
        if job >= self.jobs.len() {
            self.jobs.resize_with(job + 1, JobAdapt::default);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Feed `rounds` identical rounds for one job at the given
    /// per-worker times.
    fn feed(
        ad: &mut AdaptiveController,
        inc: &SchemeConfig,
        start: u64,
        rounds: u64,
        times: &dyn Fn(u64, usize) -> f64,
    ) -> u64 {
        let n = inc.n;
        let place: Vec<usize> = (0..n).collect();
        let loads = vec![1.0 / n as f64; n];
        for r in start + 1..=start + rounds {
            ad.register_round(0, r, &place, &loads);
            for w in 0..n {
                ad.observe_done(0, r, w, times(r, w));
            }
            ad.round_closed(0, r, inc, r as f64);
        }
        start + rounds
    }

    #[test]
    fn stationary_profile_never_stages_a_swap() {
        let mut ad = AdaptiveController::new(AdaptiveConfig::default());
        let inc = SchemeConfig::gc(8, 1);
        feed(&mut ad, &inc, 0, 40, &|_, w| 1.0 + 0.01 * w as f64);
        assert!(ad.pending_swap(0).is_none(), "shift gate must hold on a stationary profile");
        assert_eq!(ad.shifts(), 0);
        // ...even though the background re-fit has been running
        assert!(ad.candidates_evaluated() > 0, "re-fit runs in the background regardless");
    }

    #[test]
    fn regime_shift_plus_margin_stages_a_swap() {
        let mut ad = AdaptiveController::new(AdaptiveConfig::default());
        // deliberately over-provisioned GC: s=3 of n=8 → load 0.5; on a
        // quiet cluster the re-fit prefers a cheaper s once it may swap
        let inc = SchemeConfig::gc(8, 3);
        let r = feed(&mut ad, &inc, 0, 20, &|_, w| 1.0 + 0.01 * w as f64);
        assert!(ad.pending_swap(0).is_none(), "no shift yet");
        // shift: workers 0..4 become 8× slower, then profile refills
        feed(&mut ad, &inc, r, 40, &|_, w| {
            if w < 4 {
                8.0
            } else {
                1.0 + 0.01 * w as f64
            }
        });
        assert_eq!(ad.shifts(), 1);
        let (to, gain) = ad.pending_swap(0).expect("swap staged after the shift").clone();
        assert_ne!(to, inc);
        assert!(gain > 0.0);
        // consuming the swap resets hysteresis
        assert!(ad.take_swap(0).is_some());
        assert!(ad.pending_swap(0).is_none());
        assert!(ad.take_swap(0).is_none());
    }

    #[test]
    fn spare_preference_ranks_by_observed_speed() {
        let mut ad = AdaptiveController::new(AdaptiveConfig::default());
        let inc = SchemeConfig::gc(2, 1);
        // job runs on physical {2, 5}; 5 is slow
        let loads = [0.5, 0.5];
        for r in 1..=4u64 {
            ad.register_round(0, r, &[2, 5], &loads);
            ad.observe_done(0, r, 0, 1.0);
            ad.observe_done(0, r, 1, 5.0);
            ad.round_closed(0, r, &inc, r as f64);
        }
        let live = vec![true; 6];
        // replacing within place [0, 3]: worker 2 (observed fast) wins
        // over 1, 4, 5 even though 1 has the lower id
        assert_eq!(ad.prefer_spare(&live, &[0, 3]), Some(2));
        // with 2 occupied, unobserved spares tie at the lowest id
        assert_eq!(ad.prefer_spare(&live, &[0, 2]), Some(1));
        // nothing live and free
        assert_eq!(ad.prefer_spare(&[false; 6], &[0, 2]), None);
    }
}
