//! Incremental background re-fit of scheme parameters.
//!
//! [`Refitter`] is the *estimate/re-fit* leg of the adaptive control
//! plane: it re-runs the Appendix-J candidate search
//! ([`grid_search`]) against the live profile, but **amortized** — at
//! most `budget` candidates are evaluated per scheduler round close, so
//! a full pass over the (coarsened) grid spreads across several rounds
//! and never blocks the reactor hot path. Candidate replays go through
//! the same [`crate::probe::ProfileCluster`] + session machinery as the
//! offline search (and therefore share the process-wide
//! [`crate::coding::CodePlanCache`]), so an online estimate and an
//! offline probe of the same candidate agree exactly.

use super::profiler::OnlineProfiler;
use crate::coding::{SchemeConfig, SchemeKind};
use crate::probe::{grid_search, DelayProfile, SearchSpace};

/// Result of one completed re-fit pass over the candidate grid.
#[derive(Clone, Debug)]
pub struct FitOutcome {
    /// Best candidate of the pass (may be the incumbent itself).
    pub best: SchemeConfig,
    /// Estimated runtime of the best candidate on the pass profile.
    pub best_runtime_s: f64,
    /// Estimated runtime of the incumbent on the same profile.
    pub incumbent_runtime_s: f64,
    /// Profile rounds the pass replayed.
    pub profile_rounds: usize,
}

impl FitOutcome {
    /// Predicted fractional runtime improvement of `best` over the
    /// incumbent (0 when the incumbent is already best).
    pub fn predicted_gain(&self) -> f64 {
        if self.incumbent_runtime_s <= 0.0 {
            return 0.0;
        }
        ((self.incumbent_runtime_s - self.best_runtime_s) / self.incumbent_runtime_s).max(0.0)
    }
}

/// In-flight pass state: one frozen profile snapshot, runtimes filled
/// candidate by candidate.
#[derive(Debug)]
struct PassState {
    profile: DelayProfile,
    alpha: f64,
    runtimes: Vec<f64>,
}

/// Budgeted re-fit of one job's scheme parameters (see module docs).
#[derive(Debug)]
pub struct Refitter {
    incumbent: SchemeConfig,
    candidates: Vec<SchemeConfig>,
    budget: usize,
    estimate_jobs: usize,
    pass: Option<PassState>,
    evaluated: u64,
}

impl Refitter {
    /// Re-fitter for `incumbent`'s scheme family, evaluating at most
    /// `budget` candidates per [`tick`](Self::tick), each estimated by
    /// replaying `estimate_jobs` jobs of the profile.
    pub fn new(incumbent: &SchemeConfig, budget: usize, estimate_jobs: usize) -> Self {
        Refitter {
            incumbent: incumbent.clone(),
            candidates: refit_candidates(incumbent),
            budget: budget.max(1),
            estimate_jobs: estimate_jobs.max(1),
            pass: None,
            evaluated: 0,
        }
    }

    /// Whether a pass is currently in flight.
    pub fn pass_active(&self) -> bool {
        self.pass.is_some()
    }

    /// Candidates in the (coarsened) grid, incumbent included.
    pub fn candidate_count(&self) -> usize {
        self.candidates.len()
    }

    /// Total candidates evaluated over the re-fitter's lifetime.
    pub fn evaluated(&self) -> u64 {
        self.evaluated
    }

    /// Freeze a profile snapshot and start a pass over the grid.
    /// Replaces any pass already in flight (used on regime shifts: a
    /// stale-regime pass is worthless).
    pub fn begin_pass(&mut self, profile: DelayProfile, alpha: f64) {
        self.pass = Some(PassState { profile, alpha, runtimes: Vec::new() });
    }

    /// Drop the in-flight pass, if any.
    pub fn abort_pass(&mut self) {
        self.pass = None;
    }

    /// Evaluate the next `budget` candidates of the in-flight pass via
    /// a [`grid_search`] slice. Returns the pass outcome once every
    /// candidate has been estimated; `None` while the pass (or no pass)
    /// is still in flight.
    pub fn tick(&mut self) -> Option<FitOutcome> {
        let pass = self.pass.as_mut()?;
        let lo = pass.runtimes.len();
        let hi = (lo + self.budget).min(self.candidates.len());
        if lo < hi {
            let slice = &self.candidates[lo..hi];
            let ranked = grid_search(slice, &pass.profile, pass.alpha, self.estimate_jobs);
            for c in slice {
                let est = ranked
                    .iter()
                    .find(|r| r.config == *c)
                    .expect("grid_search returns every candidate")
                    .estimated_runtime_s;
                pass.runtimes.push(est);
            }
            self.evaluated += (hi - lo) as u64;
        }
        if pass.runtimes.len() < self.candidates.len() {
            return None;
        }
        // Pass complete: pick the minimum (stable tie-break on grid
        // order, so at equal estimates the incumbent — index 0 — wins).
        let mut best = 0usize;
        for (i, &rt) in pass.runtimes.iter().enumerate() {
            if rt < pass.runtimes[best] {
                best = i;
            }
        }
        let outcome = FitOutcome {
            best: self.candidates[best].clone(),
            best_runtime_s: pass.runtimes[best],
            incumbent_runtime_s: pass.runtimes[0],
            profile_rounds: pass.profile.rounds(),
        };
        self.pass = None;
        Some(outcome)
    }

    /// Convenience: start a pass from the profiler's current window for
    /// `job` if none is in flight and the window holds at least
    /// `min_rounds` rows.
    pub fn maybe_begin(&mut self, profiler: &OnlineProfiler, job: usize, min_rounds: usize) {
        if self.pass.is_some() || profiler.job_rounds(job) < min_rounds {
            return;
        }
        if let Some(profile) = profiler.snapshot(job) {
            self.begin_pass(profile, profiler.alpha());
        }
    }
}

/// The coarsened candidate grid for an incumbent's scheme family: the
/// incumbent first, then same-kind candidates with `B` pinned and
/// `W`/`λ` (or `s` for GC) swept over the paper ranges with λ and `s`
/// on a power-of-two grid. Coarsening keeps a full pass within a few
/// budgeted ticks; the swap hysteresis makes chasing the exact offline
/// optimum unnecessary.
pub fn refit_candidates(incumbent: &SchemeConfig) -> Vec<SchemeConfig> {
    let n = incumbent.n;
    let mut space = SearchSpace::paper_default(n);
    space.lambda = pow2_grid((n / 8).max(8).min(n.saturating_sub(1)));
    space.s = pow2_grid((n / 8).max(4));
    let family: Vec<SchemeConfig> = match &incumbent.kind {
        SchemeKind::Gc { .. } => space.gc_candidates(),
        SchemeKind::GcRep { .. } => space
            .gc_candidates()
            .into_iter()
            .map(|c| match c.kind {
                SchemeKind::Gc { s } => SchemeConfig::gc_rep(n, s),
                _ => unreachable!("gc_candidates yields Gc"),
            })
            .collect(),
        SchemeKind::SrSgc { b, .. } => {
            space.b = vec![*b];
            space.sr_sgc_candidates()
        }
        SchemeKind::SrSgcRep { b, .. } => {
            space.b = vec![*b];
            space
                .sr_sgc_candidates()
                .into_iter()
                .map(|c| match c.kind {
                    SchemeKind::SrSgc { b, w, lambda } => SchemeConfig::sr_sgc_rep(n, b, w, lambda),
                    _ => unreachable!("sr_sgc_candidates yields SrSgc"),
                })
                .collect()
        }
        SchemeKind::MSgc { b, .. } => {
            space.b = vec![*b];
            space.m_sgc_candidates()
        }
        SchemeKind::MSgcRep { b, .. } => {
            space.b = vec![*b];
            space
                .m_sgc_candidates()
                .into_iter()
                .map(|c| match c.kind {
                    SchemeKind::MSgc { b, w, lambda } => SchemeConfig::msgc_rep(n, b, w, lambda),
                    _ => unreachable!("m_sgc_candidates yields MSgc"),
                })
                .collect()
        }
        // The uncoded baseline has no parameters to re-fit.
        SchemeKind::Uncoded => Vec::new(),
    };
    let mut out = vec![incumbent.clone()];
    for c in family {
        if !out.contains(&c) {
            out.push(c);
        }
    }
    out
}

/// `1, 2, 4, … ≤ max` (always non-empty for `max ≥ 1`).
fn pow2_grid(max: usize) -> Vec<usize> {
    let mut v = Vec::new();
    let mut x = 1usize;
    while x <= max {
        v.push(x);
        x *= 2;
    }
    if v.is_empty() {
        v.push(1);
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn flat_profile(n: usize, rounds: usize, t: f64) -> DelayProfile {
        DelayProfile {
            n,
            base_load: 1.0 / n as f64,
            times: Arc::new(vec![vec![t; n]; rounds]),
        }
    }

    #[test]
    fn candidate_grids_stay_in_family_and_start_at_incumbent() {
        let inc = SchemeConfig::msgc(16, 1, 3, 2);
        let cands = refit_candidates(&inc);
        assert_eq!(cands[0], inc);
        assert!(cands.len() > 1);
        assert!(cands.iter().all(|c| matches!(c.kind, SchemeKind::MSgc { b: 1, .. })));
        // no duplicates
        for (i, a) in cands.iter().enumerate() {
            assert!(!cands[i + 1..].contains(a), "duplicate {a:?}");
        }
        // rep-ness is preserved
        let rep = refit_candidates(&SchemeConfig::gc_rep(16, 2));
        assert!(rep.iter().all(|c| matches!(c.kind, SchemeKind::GcRep { .. })));
        // uncoded has nothing to re-fit
        assert_eq!(refit_candidates(&SchemeConfig::uncoded(16)).len(), 1);
    }

    #[test]
    fn pass_is_amortized_over_budgeted_ticks() {
        let inc = SchemeConfig::gc(16, 2);
        let mut rf = Refitter::new(&inc, 2, 4);
        let total = rf.candidate_count();
        assert!(total > 2, "need multiple ticks for this test");
        rf.begin_pass(flat_profile(16, 6, 1.0), 9.5);
        let mut ticks = 0;
        let outcome = loop {
            ticks += 1;
            if let Some(o) = rf.tick() {
                break o;
            }
            assert!(ticks < 100, "pass never completed");
        };
        assert_eq!(ticks, total.div_ceil(2));
        assert_eq!(rf.evaluated(), total as u64);
        assert!(outcome.best_runtime_s <= outcome.incumbent_runtime_s);
        assert!(outcome.predicted_gain() >= 0.0);
        assert!(!rf.pass_active());
    }

    #[test]
    fn tick_without_pass_is_a_no_op() {
        let mut rf = Refitter::new(&SchemeConfig::gc(8, 1), 4, 4);
        assert!(rf.tick().is_none());
        assert_eq!(rf.evaluated(), 0);
    }
}
