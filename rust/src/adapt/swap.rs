//! Hot-swap decision policy.
//!
//! [`SwapPolicy`] is the *decide* leg of the adaptive control plane: it
//! turns a completed re-fit pass ([`FitOutcome`]) into an accept/reject
//! decision, with hysteresis so a marginally-better estimate on a noisy
//! profile never churns the scheme:
//!
//! * **margin** — the predicted fractional improvement must reach
//!   `swap_margin`;
//! * **cooldown** — at least `cooldown_rounds` round closes must have
//!   passed since the job's last swap;
//! * **shift gating** — by default a swap also requires a detected
//!   straggler-regime shift since the last swap. A stationary profile
//!   therefore *never* swaps, no matter how the estimates wobble — the
//!   invariant the stationary golden test pins.

use super::refit::FitOutcome;
use crate::coding::SchemeConfig;

/// Hysteresis policy for accepting a re-fitted scheme (see module docs).
#[derive(Clone, Debug)]
pub struct SwapPolicy {
    /// Minimum predicted fractional runtime improvement (0.10 = 10 %).
    pub swap_margin: f64,
    /// Minimum round closes between two swaps of the same job.
    pub cooldown_rounds: u64,
    /// Require a detected regime shift since the last swap.
    pub require_shift: bool,
}

impl Default for SwapPolicy {
    fn default() -> Self {
        SwapPolicy { swap_margin: 0.10, cooldown_rounds: 8, require_shift: true }
    }
}

impl SwapPolicy {
    /// Accept or reject a completed pass for a job whose current scheme
    /// is `incumbent`. `rounds_since_swap` counts the job's round
    /// closes since its last swap (or admission); `shift_armed` is
    /// whether a regime shift has been detected since then. Returns the
    /// accepted target and its predicted gain.
    pub fn decide(
        &self,
        outcome: &FitOutcome,
        incumbent: &SchemeConfig,
        rounds_since_swap: u64,
        shift_armed: bool,
    ) -> Option<(SchemeConfig, f64)> {
        if self.require_shift && !shift_armed {
            return None;
        }
        if rounds_since_swap < self.cooldown_rounds {
            return None;
        }
        if outcome.best == *incumbent {
            return None;
        }
        let gain = outcome.predicted_gain();
        if gain < self.swap_margin {
            return None;
        }
        Some((outcome.best.clone(), gain))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(best: SchemeConfig, best_s: f64, inc_s: f64) -> FitOutcome {
        FitOutcome { best, best_runtime_s: best_s, incumbent_runtime_s: inc_s, profile_rounds: 16 }
    }

    #[test]
    fn margin_cooldown_and_shift_all_gate() {
        let pol = SwapPolicy::default();
        let inc = SchemeConfig::gc(16, 1);
        let better = SchemeConfig::gc(16, 4);
        let good = outcome(better.clone(), 8.0, 10.0); // 20 % predicted gain

        // all conditions met
        let (to, gain) = pol.decide(&good, &inc, 20, true).expect("swap accepted");
        assert_eq!(to, better);
        assert!((gain - 0.2).abs() < 1e-12);

        // no shift since last swap
        assert!(pol.decide(&good, &inc, 20, false).is_none());
        // cooldown not elapsed
        assert!(pol.decide(&good, &inc, 3, true).is_none());
        // gain below margin
        let meh = outcome(better.clone(), 9.5, 10.0); // 5 % < 10 %
        assert!(pol.decide(&meh, &inc, 20, true).is_none());
        // best is the incumbent itself
        let same = outcome(inc.clone(), 8.0, 10.0);
        assert!(pol.decide(&same, &inc, 20, true).is_none());
    }

    #[test]
    fn shift_gate_can_be_disabled() {
        let pol = SwapPolicy { require_shift: false, ..Default::default() };
        let inc = SchemeConfig::gc(16, 1);
        let good = outcome(SchemeConfig::gc(16, 4), 8.0, 10.0);
        assert!(pol.decide(&good, &inc, 20, false).is_some());
    }
}
