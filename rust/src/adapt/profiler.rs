//! Online straggler profiling from the live cluster-event stream.
//!
//! [`OnlineProfiler`] is the *observe* leg of the adaptive control
//! plane. It folds `WorkerDone` arrivals into two views of worker
//! delay:
//!
//! 1. a sliding window of per-round completion-time rows — an
//!    exponentially-aged extension of [`DelayProfile`] sharing its
//!    `Arc`'d matrix representation, which the background re-fit
//!    ([`crate::adapt::Refitter`]) replays through the real round
//!    protocol; and
//! 2. per-worker exponentially-weighted **fast** (recent) and **slow**
//!    (historical) delay means, whose relative divergence detects
//!    straggler-regime shifts.
//!
//! All observed times are normalized to the profile's base load with
//! the Fig.-16 adjustment `t − (load − base)·α`, where `α` is re-fitted
//! online from observed (load, time) points via
//! [`DelayProfile::fit_alpha`] — the same slope the Appendix-J probe
//! fits offline. Workers cut by the μ-rule whose results never arrived
//! by round close are filled with a penalty multiple of the round's
//! slowest observed finish, so the replayed profile still "remembers"
//! that waiting on them was expensive.
//!
//! The profiler is purely observational: it draws no randomness and
//! never reorders scheduler work, so enabling it cannot perturb a run's
//! protocol outcome.

use crate::probe::DelayProfile;
use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;

/// Knobs of the online profiler (window + decay, regime-shift
/// detection, cut-straggler penalty, α re-fit).
#[derive(Clone, Debug)]
pub struct ProfilerConfig {
    /// Per-round rows kept per job for re-fit snapshots (the profile
    /// window).
    pub window: usize,
    /// Exponential weight of the *fast* (recent) per-worker delay mean.
    pub fast_decay: f64,
    /// Exponential weight of the *slow* (historical) per-worker delay
    /// mean. Must be smaller than [`fast_decay`](Self::fast_decay) for
    /// the divergence detector to see shifts.
    pub slow_decay: f64,
    /// Mean relative fast-vs-slow divergence above which a regime shift
    /// is declared.
    pub shift_threshold: f64,
    /// A worker cut by the μ-rule (no result by round close) is charged
    /// this multiple of the round's slowest *observed* finish.
    pub cut_penalty: f64,
    /// Load-slope α used until enough load spread has been observed to
    /// fit one online (default: the simulator's calibrated slope).
    pub alpha_fallback: f64,
    /// Ring capacity of (load, time) calibration points for the online
    /// α fit.
    pub alpha_points: usize,
}

impl Default for ProfilerConfig {
    fn default() -> Self {
        ProfilerConfig {
            window: 32,
            fast_decay: 0.35,
            slow_decay: 0.05,
            shift_threshold: 0.35,
            cut_penalty: 2.0,
            alpha_fallback: 9.5,
            alpha_points: 256,
        }
    }
}

/// One in-flight round: placement, logical loads, and the finish times
/// observed so far (NaN = not yet arrived).
#[derive(Debug)]
struct OpenRound {
    place: Vec<usize>,
    loads: Vec<f64>,
    finish: Vec<f64>,
}

/// Per-job window of normalized completion-time rows, in the job's
/// *logical* worker coordinates (so a snapshot replays directly against
/// candidate schemes of the job's own width).
#[derive(Debug)]
struct JobHistory {
    n: usize,
    base_load: f64,
    rows: VecDeque<Vec<f64>>,
}

/// Online per-worker delay estimator (see the module docs).
#[derive(Debug)]
pub struct OnlineProfiler {
    cfg: ProfilerConfig,
    /// Open (job, cluster-round) records awaiting their close.
    open: BTreeMap<(usize, u64), OpenRound>,
    /// Per-job row windows (logical coordinates).
    histories: Vec<Option<JobHistory>>,
    /// Per-*physical*-worker EW means (shared across jobs).
    fast: Vec<f64>,
    slow: Vec<f64>,
    seen: Vec<bool>,
    /// (load, observed time) ring for the online α fit.
    points: Vec<(f64, f64)>,
    point_cursor: usize,
    alpha_hat: f64,
    rounds_folded: u64,
    shifts: u64,
}

impl OnlineProfiler {
    /// New profiler; capacities grow lazily with the jobs and workers
    /// it observes.
    pub fn new(cfg: ProfilerConfig) -> Self {
        let alpha_hat = cfg.alpha_fallback;
        OnlineProfiler {
            cfg,
            open: BTreeMap::new(),
            histories: Vec::new(),
            fast: Vec::new(),
            slow: Vec::new(),
            seen: Vec::new(),
            points: Vec::new(),
            point_cursor: 0,
            alpha_hat,
            rounds_folded: 0,
            shifts: 0,
        }
    }

    /// Record a round fan-out: `place[i]` is the physical worker
    /// serving logical worker `i`, `loads[i]` its normalized load.
    pub fn register_round(&mut self, job: usize, round: u64, place: &[usize], loads: &[f64]) {
        debug_assert_eq!(place.len(), loads.len());
        self.open.insert(
            (job, round),
            OpenRound {
                place: place.to_vec(),
                loads: loads.to_vec(),
                finish: vec![f64::NAN; loads.len()],
            },
        );
    }

    /// Record a `WorkerDone` arrival for logical worker `logical` of an
    /// open round. Arrivals for already-folded rounds are ignored.
    pub fn observe(&mut self, job: usize, round: u64, logical: usize, finish_s: f64) {
        if let Some(rec) = self.open.get_mut(&(job, round)) {
            if logical < rec.finish.len() && rec.finish[logical].is_nan() {
                rec.finish[logical] = finish_s;
            }
        }
    }

    /// Fold a closed round into the profile: normalize observed times,
    /// penalty-fill cut workers, update the EW means, and run shift
    /// detection. Returns `true` when this fold crossed the
    /// regime-shift threshold (at which point the row windows are
    /// cleared so re-fits see only the new regime).
    pub fn fold_round(&mut self, job: usize, round: u64) -> bool {
        let Some(rec) = self.open.remove(&(job, round)) else { return false };
        let n = rec.loads.len();
        let max_obs = rec
            .finish
            .iter()
            .cloned()
            .filter(|f| f.is_finite())
            .fold(f64::NEG_INFINITY, f64::max);
        if !max_obs.is_finite() {
            return false; // nothing arrived: nothing to learn
        }
        let base_load = 1.0 / n as f64;
        let alpha = self.alpha_hat;
        let mut row = Vec::with_capacity(n);
        for i in 0..n {
            let observed = rec.finish[i].is_finite();
            let t = if observed { rec.finish[i] } else { self.cfg.cut_penalty * max_obs };
            if observed {
                self.push_point(rec.loads[i], t);
            }
            row.push((t - (rec.loads[i] - base_load) * alpha).max(1e-6));
        }

        // EW means per physical worker (worker-index order: invariant
        // to event-arrival order within the round).
        for (i, &tn) in row.iter().enumerate() {
            let w = rec.place[i];
            if w >= self.fast.len() {
                self.fast.resize(w + 1, 0.0);
                self.slow.resize(w + 1, 0.0);
                self.seen.resize(w + 1, false);
            }
            if !self.seen[w] {
                self.seen[w] = true;
                self.fast[w] = tn;
                self.slow[w] = tn;
            } else {
                self.fast[w] += self.cfg.fast_decay * (tn - self.fast[w]);
                self.slow[w] += self.cfg.slow_decay * (tn - self.slow[w]);
            }
        }

        if job >= self.histories.len() {
            self.histories.resize_with(job + 1, || None);
        }
        let h = self.histories[job]
            .get_or_insert_with(|| JobHistory { n, base_load, rows: VecDeque::new() });
        if h.n == n {
            h.rows.push_back(row);
            while h.rows.len() > self.cfg.window {
                h.rows.pop_front();
            }
        }
        self.rounds_folded += 1;
        self.refit_alpha();

        // Regime-shift detection: mean relative fast-vs-slow divergence.
        let (mut div, mut cnt) = (0.0, 0usize);
        for w in 0..self.seen.len() {
            if self.seen[w] {
                div += (self.fast[w] - self.slow[w]).abs() / self.slow[w].max(1e-9);
                cnt += 1;
            }
        }
        if cnt > 0 && div / cnt as f64 > self.cfg.shift_threshold {
            // Re-anchor history at the new regime so the detector fires
            // once per shift, and drop cross-regime rows: re-fits must
            // not average the old world into the new one.
            self.slow.copy_from_slice(&self.fast);
            for h in self.histories.iter_mut().flatten() {
                h.rows.clear();
            }
            self.shifts += 1;
            return true;
        }
        false
    }

    /// Snapshot the job's row window as a replayable [`DelayProfile`]
    /// (O(window × n) copy into a fresh `Arc` matrix; candidate replays
    /// then clone it O(1)). `None` until at least one row is folded.
    pub fn snapshot(&self, job: usize) -> Option<DelayProfile> {
        let h = self.histories.get(job)?.as_ref()?;
        if h.rows.is_empty() {
            return None;
        }
        Some(DelayProfile {
            n: h.n,
            base_load: h.base_load,
            times: Arc::new(h.rows.iter().cloned().collect()),
        })
    }

    /// Rows currently in the job's window (resets on regime shift).
    pub fn job_rounds(&self, job: usize) -> usize {
        self.histories.get(job).and_then(|h| h.as_ref()).map_or(0, |h| h.rows.len())
    }

    /// Current load-slope estimate α (the fallback until enough load
    /// spread has been observed to fit one).
    pub fn alpha(&self) -> f64 {
        self.alpha_hat
    }

    /// Normalized EW *fast* delay mean of a physical worker, `None`
    /// until it has been observed at least once.
    pub fn fast_mean(&self, worker: usize) -> Option<f64> {
        (worker < self.seen.len() && self.seen[worker]).then(|| self.fast[worker])
    }

    /// Rounds folded so far.
    pub fn rounds_folded(&self) -> u64 {
        self.rounds_folded
    }

    /// Regime shifts detected so far.
    pub fn shifts(&self) -> u64 {
        self.shifts
    }

    fn push_point(&mut self, load: f64, t: f64) {
        if self.points.len() < self.cfg.alpha_points {
            self.points.push((load, t));
        } else {
            self.points[self.point_cursor] = (load, t);
            self.point_cursor = (self.point_cursor + 1) % self.cfg.alpha_points;
        }
    }

    /// Re-fit α from the calibration ring; keeps the current estimate
    /// unless the points span enough load range for a meaningful slope.
    fn refit_alpha(&mut self) {
        if self.points.len() < 8 {
            return;
        }
        let lo = self.points.iter().map(|p| p.0).fold(f64::INFINITY, f64::min);
        let hi = self.points.iter().map(|p| p.0).fold(f64::NEG_INFINITY, f64::max);
        if hi - lo < 0.01 {
            return;
        }
        let a = DelayProfile::fit_alpha(&self.points);
        if a.is_finite() && a > 0.0 {
            self.alpha_hat = a;
        }
    }
}

/// Standalone observer wiring: drive the profiler straight from a
/// scheduler (or trainer) run's round boundaries, with no adaptive
/// controller around it. Placement is the identity here — physical ids
/// equal logical ids — which matches any single-job run anchored at
/// worker 0; the [`crate::sched::JobScheduler`]'s built-in adaptation
/// path uses the richer placement-aware hooks instead.
impl crate::sched::RoundObserver for OnlineProfiler {
    fn round_started(
        &mut self,
        job: crate::cluster::JobId,
        _session: &crate::session::SgcSession,
        plan: &crate::session::RoundPlan,
    ) -> crate::Result<()> {
        let place: Vec<usize> = (0..plan.loads.len()).collect();
        self.register_round(job, plan.round as u64, &place, &plan.loads);
        Ok(())
    }

    fn round_closed(
        &mut self,
        job: crate::cluster::JobId,
        session: &crate::session::SgcSession,
        plan: &crate::session::RoundPlan,
        _events: &[crate::session::SessionEvent],
    ) -> crate::Result<()> {
        let round = plan.round as u64;
        for (logical, finish) in session.last_finish().iter().enumerate() {
            if let Some(f) = finish {
                self.observe(job, round, logical, *f);
            }
        }
        self.fold_round(job, round);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn identity_place(n: usize) -> Vec<usize> {
        (0..n).collect()
    }

    #[test]
    fn folds_rows_and_snapshots() {
        let mut p = OnlineProfiler::new(ProfilerConfig::default());
        let n = 4;
        for r in 1..=5u64 {
            p.register_round(0, r, &identity_place(n), &vec![0.25; n]);
            for w in 0..n {
                p.observe(0, r, w, 1.0 + w as f64 * 0.1);
            }
            assert!(!p.fold_round(0, r));
        }
        assert_eq!(p.job_rounds(0), 5);
        let snap = p.snapshot(0).expect("rows folded");
        assert_eq!(snap.n, n);
        assert_eq!(snap.rounds(), 5);
        // loads at base (1/n): normalization is the identity
        assert!((snap.times[0][1] - 1.1).abs() < 1e-12);
        assert_eq!(p.rounds_folded(), 5);
    }

    #[test]
    fn cut_workers_are_penalty_filled() {
        let mut p = OnlineProfiler::new(ProfilerConfig::default());
        let n = 3;
        p.register_round(0, 1, &identity_place(n), &vec![1.0 / 3.0; n]);
        p.observe(0, 1, 0, 1.0);
        p.observe(0, 1, 1, 2.0);
        // worker 2 cut: never reported
        p.fold_round(0, 1);
        let snap = p.snapshot(0).unwrap();
        assert!((snap.times[0][2] - 4.0).abs() < 1e-12, "2.0 × slowest observed (2.0)");
    }

    #[test]
    fn late_observations_for_folded_rounds_are_dropped() {
        let mut p = OnlineProfiler::new(ProfilerConfig::default());
        p.register_round(0, 1, &[0, 1], &[0.5, 0.5]);
        p.observe(0, 1, 0, 1.0);
        p.fold_round(0, 1);
        p.observe(0, 1, 1, 9.0); // round already folded: no-op
        assert_eq!(p.job_rounds(0), 1);
    }

    #[test]
    fn regime_shift_fires_once_and_clears_windows() {
        let cfg = ProfilerConfig::default();
        let mut p = OnlineProfiler::new(cfg);
        let n = 4;
        let quiet = vec![1.0; n];
        let mut r = 0u64;
        let mut feed = |p: &mut OnlineProfiler, times: &[f64]| -> bool {
            r += 1;
            p.register_round(0, r, &identity_place(n), &vec![0.25; n]);
            for (w, &t) in times.iter().enumerate() {
                p.observe(0, r, w, t);
            }
            p.fold_round(0, r)
        };
        for _ in 0..12 {
            assert!(!feed(&mut p, &quiet), "stationary profile must not shift");
        }
        // half the fleet becomes 6× slower: fast mean diverges from slow
        let slow_world = [6.0, 6.0, 1.0, 1.0];
        let mut fired = 0;
        for _ in 0..10 {
            if feed(&mut p, &slow_world) {
                fired += 1;
            }
        }
        assert_eq!(fired, 1, "one shift per regime change");
        assert_eq!(p.shifts(), 1);
        // window restarted at the shift
        assert!(p.job_rounds(0) < 10);
    }

    #[test]
    fn alpha_is_refit_from_load_spread() {
        let mut p = OnlineProfiler::new(ProfilerConfig::default());
        assert_eq!(p.alpha(), 9.5, "fallback before any fit");
        let n = 2;
        let mut r = 0u64;
        // perfect linear law t = 1 + 3·load over a wide load spread
        for &load in &[0.1, 0.2, 0.4, 0.8, 0.1, 0.3, 0.5, 0.7] {
            r += 1;
            p.register_round(0, r, &identity_place(n), &vec![load; n]);
            for w in 0..n {
                p.observe(0, r, w, 1.0 + 3.0 * load);
            }
            p.fold_round(0, r);
        }
        assert!((p.alpha() - 3.0).abs() < 1e-9, "alpha {}", p.alpha());
    }

    #[test]
    fn fast_means_rank_workers() {
        let mut p = OnlineProfiler::new(ProfilerConfig::default());
        for r in 1..=6u64 {
            p.register_round(0, r, &[2, 5], &[0.5, 0.5]);
            p.observe(0, r, 0, 1.0); // physical 2 is fast
            p.observe(0, r, 1, 3.0); // physical 5 is slow
            p.fold_round(0, r);
        }
        assert!(p.fast_mean(2).unwrap() < p.fast_mean(5).unwrap());
        assert_eq!(p.fast_mean(0), None, "never observed");
    }

    #[test]
    fn round_observer_impl_profiles_a_scheduler_run() {
        use crate::cluster::{LatencyParams, SimCluster};
        use crate::coding::SchemeConfig;
        use crate::sched::{JobScheduler, JobSpec};
        use crate::session::SessionConfig;
        use crate::straggler::models::NoStragglers;

        let n = 6;
        let mut sim =
            SimCluster::new(n, LatencyParams::default(), Box::new(NoStragglers { n }), 11);
        let mut sched = JobScheduler::new(&mut sim);
        sched
            .admit(&JobSpec {
                scheme: SchemeConfig::gc(n, 1),
                session: SessionConfig { jobs: 5, ..Default::default() },
            })
            .unwrap();
        let mut profiler = OnlineProfiler::new(ProfilerConfig::default());
        sched.run_observed(&mut profiler).unwrap();
        assert_eq!(profiler.rounds_folded(), 5);
        assert_eq!(profiler.job_rounds(0), 5);
        assert!(profiler.snapshot(0).is_some());
        assert!((0..n).all(|w| profiler.fast_mean(w).is_some()));
    }
}
