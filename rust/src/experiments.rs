//! Shared setup for the paper-reproduction benches (`rust/benches/*`).
//!
//! Every bench regenerates one table or figure of the paper at the
//! paper's own configuration (n = 256 workers, J = 480 jobs, 10
//! repetitions) unless `SGC_BENCH_FAST=1` scales it down for CI.

use crate::cluster::{Cluster, EventCluster, SimCluster};
use crate::coding::SchemeConfig;
use crate::coordinator::RunReport;
use crate::session::{self, BatchItem, SessionConfig};
use crate::straggler::GilbertElliot;
use crate::util::json::Json;
use crate::util::stats::MeanStd;

/// The paper's evaluation configuration (Sec. 4.2).
#[derive(Clone, Debug)]
pub struct PaperSetup {
    /// Workers `n` (the paper's headline tables use 256).
    pub n: usize,
    /// Jobs `J` per run.
    pub jobs: usize,
    /// Repetitions per scheme (seeds).
    pub reps: usize,
    /// μ-rule tolerance.
    pub mu: f64,
}

impl PaperSetup {
    /// n=256, J=480, 10 repetitions (Table 1); honours SGC_BENCH_FAST.
    pub fn table1() -> Self {
        if fast_mode() {
            PaperSetup { n: 64, jobs: 60, reps: 3, mu: 1.0 }
        } else {
            PaperSetup { n: 256, jobs: 480, reps: 10, mu: 1.0 }
        }
    }

    /// The Table-1 scheme selections, scaled to `n` when not 256.
    pub fn table1_schemes(&self) -> Vec<(&'static str, SchemeConfig)> {
        let n = self.n;
        let scale = n as f64 / 256.0;
        let lam_m = ((27.0 * scale).round() as usize).clamp(1, n - 1);
        let lam_sr = ((23.0 * scale).round() as usize).clamp(1, n);
        let s_gc = ((15.0 * scale).round() as usize).clamp(1, n - 1);
        vec![
            ("M-SGC", SchemeConfig::msgc(n, 1, 2, lam_m)),
            ("SR-SGC", SchemeConfig::sr_sgc(n, 2, 3, lam_sr)),
            ("GC", SchemeConfig::gc(n, s_gc)),
            ("No Coding", SchemeConfig::uncoded(n)),
        ]
    }

    /// Session parameters for one simulated run.
    fn session_config(&self, measure_decode: bool) -> SessionConfig {
        SessionConfig { jobs: self.jobs, mu: self.mu, measure_decode, ..Default::default() }
    }

    /// One simulated run.
    pub fn run_once(&self, scheme: &SchemeConfig, seed: u64, measure_decode: bool) -> RunReport {
        let mut cluster = self.cluster(seed).sync();
        session::drive(scheme, &self.session_config(measure_decode), &mut cluster)
            .expect("setup builds matching cluster/scheme sizes")
    }

    /// The default GE-straggler cluster.
    pub fn cluster(&self, seed: u64) -> SimCluster {
        SimCluster::from_gilbert_elliot(
            self.n,
            GilbertElliot::default_fit(self.n, seed),
            seed ^ 0xc1a5,
        )
    }

    /// Repeat runs and summarise total runtime. Repetitions are
    /// independent sessions and run concurrently on the batch driver;
    /// seeds are `1000 + rep`, so results are identical to the old
    /// sequential loop.
    pub fn runtime_stats(&self, scheme: &SchemeConfig, measure_decode: bool) -> MeanStd {
        let items: Vec<BatchItem> = (0..self.reps)
            .map(|_| BatchItem {
                scheme: scheme.clone(),
                session: self.session_config(measure_decode),
            })
            .collect();
        let setup = self.clone();
        let reports = session::run_parallel(items, session::default_threads(), move |i, _| {
            Box::new(setup.cluster(1000 + i as u64).sync()) as Box<dyn Cluster + Send>
        })
        .expect("setup builds matching cluster/scheme sizes");
        let xs: Vec<f64> = reports.iter().map(|r| r.total_runtime_s).collect();
        MeanStd::of(&xs)
    }
}

/// `SGC_BENCH_FAST=1` shrinks every bench for smoke runs.
pub fn fast_mode() -> bool {
    std::env::var("SGC_BENCH_FAST").ok().as_deref() == Some("1")
}

/// Save a bench's JSON payload under `target/experiments/`.
pub fn save_json(name: &str, json: &Json) {
    let path = format!("target/experiments/{name}.json");
    match json.save(&path) {
        Ok(()) => println!("(saved {path})"),
        Err(e) => crate::log_warn!("could not save {path}: {e}"),
    }
}

/// Markdown-ish table printer.
pub struct TablePrinter {
    widths: Vec<usize>,
}

impl TablePrinter {
    /// Print the header row and return the printer.
    pub fn new(headers: &[&str], widths: &[usize]) -> Self {
        assert_eq!(headers.len(), widths.len());
        let row: Vec<String> = headers
            .iter()
            .zip(widths)
            .map(|(h, &w)| format!("{h:>w$}"))
            .collect();
        println!("{}", row.join("  "));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        TablePrinter { widths: widths.to_vec() }
    }

    /// Print one aligned data row.
    pub fn row(&self, cells: &[String]) {
        let row: Vec<String> = cells
            .iter()
            .zip(&self.widths)
            .map(|(c, &w)| format!("{c:>w$}"))
            .collect();
        println!("{}", row.join("  "));
    }
}
