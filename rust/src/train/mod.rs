//! Training stack: synthetic dataset, Adam optimizer, and the interleaved
//! multi-model trainer (Remark 2.1 / Appendix I) that drives real PJRT
//! gradient computation through a coding scheme.

pub mod adam;
pub mod dataset;
pub mod trainer;

pub use adam::Adam;
pub use dataset::{Dataset, DatasetConfig};
pub use trainer::{MultiModelTrainer, TrainConfig, TrainReport};
