//! Interleaved multi-model trainer (Sec. 4.2, Appendix I).
//!
//! Trains `M` models concurrently: job `Mi + j` is iteration `i` of model
//! `j` (so any scheme with delay `T ≤ M-1` keeps the gradient pipeline
//! full, Remark 2.1). Round timing comes from the simulated cluster
//! (straggling, μ-rule, wait-outs identical to [`crate::coordinator`]);
//! gradient *values* are computed for real through the AOT PJRT
//! executables, GC-encoded per work unit, and numerically decoded by the
//! master at each job's completion.

use crate::cluster::{EventCluster, JobId};
use crate::coding::{CodePlan, CodePlanCache, Scheme, SchemeConfig, SchemeKind, WorkUnit};
use crate::runtime::{ComputePool, GradRequest};
use crate::sched::{JobScheduler, JobSpec, RoundObserver};
use crate::session::{RoundPlan, SessionConfig, SessionEvent, SgcSession};
use crate::train::adam::Adam;
use crate::train::dataset::Dataset;
use crate::util::rng::Pcg32;
use crate::util::timer::Stopwatch;
use anyhow::{Context, Result};
use std::collections::{HashMap, HashSet};
use std::sync::Arc;

/// Trainer configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Number of concurrently trained models `M`.
    pub models: usize,
    /// Gradient iterations per model (jobs `J = M · iterations`).
    pub iterations: usize,
    /// Batch size per job.
    pub batch: usize,
    /// Adam learning rate.
    pub lr: f32,
    /// μ-rule tolerance for the underlying session.
    pub mu: f64,
    /// Seed for data sampling and initialization.
    pub seed: u64,
    /// Evaluate the model loss on the held-out batch every `eval_every`
    /// iterations (1 = every update).
    pub eval_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            models: 4,
            iterations: 30,
            batch: 256,
            lr: 2e-3,
            mu: 1.0,
            seed: 7,
            eval_every: 1,
        }
    }
}

/// One logged evaluation point.
#[derive(Clone, Copy, Debug)]
pub struct LossPoint {
    /// Gradient iteration (per model).
    pub iteration: usize,
    /// Simulated cluster time of the evaluation.
    pub sim_time_s: f64,
    /// Held-out loss at that point.
    pub loss: f64,
}

/// Training run report.
#[derive(Clone, Debug)]
pub struct TrainReport {
    /// Scheme label the models trained under.
    pub scheme: String,
    /// Simulated cluster wall-clock (what the paper's Table 1 measures).
    pub sim_runtime_s: f64,
    /// Real wall-clock of this process (for the §Perf log).
    pub wall_runtime_s: f64,
    /// Per model: loss curve.
    pub losses: Vec<Vec<LossPoint>>,
    /// Jobs (gradient updates) that decoded.
    pub jobs_completed: usize,
    /// Deadline violations across all sessions.
    pub deadline_violations: usize,
    /// Cumulative completed-jobs curve: (sim time, jobs).
    pub completion_curve: Vec<(f64, usize)>,
}

/// Per-job numeric state while the job's window is active.
struct JobState {
    model: usize,
    params: Arc<Vec<Vec<f32>>>,
    /// Sample indices per chunk id.
    chunk_indices: Vec<Vec<usize>>,
    sample_weight: f32,
    /// Sum of delivered plain partial gradients.
    plain_sum: Option<Vec<Vec<f32>>>,
    delivered_chunks: HashSet<usize>,
    /// Coded results per ledger group: (worker, ℓ per param tensor).
    coded: HashMap<usize, Vec<(usize, Vec<Vec<f32>>)>>,
    loss_sum: f64,
    done: bool,
}

/// Interleaved multi-model trainer.
pub struct MultiModelTrainer {
    scheme_cfg: SchemeConfig,
    cfg: TrainConfig,
    pool: Arc<ComputePool>,
    /// One dataset per model (Appendix I "multi-model learning": models
    /// need not share data), or a single shared dataset.
    datasets: Vec<Dataset>,
    rep_coding: bool,
}

impl MultiModelTrainer {
    /// All models share one dataset (the Sec. 4.2 setup).
    pub fn new(
        scheme_cfg: SchemeConfig,
        cfg: TrainConfig,
        pool: Arc<ComputePool>,
        dataset: Dataset,
    ) -> Result<Self> {
        Self::with_datasets(scheme_cfg, cfg, pool, vec![dataset])
    }

    /// One dataset per model (`datasets.len()` must be 1 or `M`) —
    /// the multi-model-learning setting of Appendix I.
    pub fn with_datasets(
        scheme_cfg: SchemeConfig,
        cfg: TrainConfig,
        pool: Arc<ComputePool>,
        datasets: Vec<Dataset>,
    ) -> Result<Self> {
        anyhow::ensure!(
            scheme_cfg.delay() + 1 <= cfg.models,
            "scheme delay T={} needs at least M=T+1={} pipelined models (Remark 2.1)",
            scheme_cfg.delay(),
            scheme_cfg.delay() + 1
        );
        anyhow::ensure!(
            datasets.len() == 1 || datasets.len() == cfg.models,
            "need 1 or M datasets, got {}",
            datasets.len()
        );
        for ds in &datasets {
            anyhow::ensure!(
                ds.cfg.input == pool.dims().input && ds.cfg.classes == pool.dims().classes,
                "dataset dims must match the compiled artifact"
            );
        }
        let rep_coding = matches!(
            scheme_cfg.kind,
            SchemeKind::GcRep { .. } | SchemeKind::SrSgcRep { .. } | SchemeKind::MSgcRep { .. }
        );
        Ok(MultiModelTrainer { scheme_cfg, cfg, pool, datasets, rep_coding })
    }

    /// Dataset used by a model.
    fn dataset_of(&self, model: usize) -> &Dataset {
        if self.datasets.len() == 1 {
            &self.datasets[0]
        } else {
            &self.datasets[model]
        }
    }

    /// He-style init for the 6 parameter tensors.
    fn init_params(&self, model: usize) -> Vec<Vec<f32>> {
        let dims = self.pool.dims();
        let mut rng = Pcg32::new(self.cfg.seed ^ 0x1219, model as u64 + 1);
        dims.param_shapes()
            .iter()
            .map(|&(r, c)| {
                let fan_in = if r == 1 { 0 } else { r };
                if fan_in == 0 {
                    vec![0.0f32; c] // biases
                } else {
                    let scale = (2.0 / fan_in as f64).sqrt();
                    (0..r * c).map(|_| (rng.normal() * scale) as f32).collect()
                }
            })
            .collect()
    }

    /// Run the training loop against a (simulated-time) event backend.
    ///
    /// Round decisions (μ-rule, wait-outs, commit, decodability) are made
    /// by the sans-IO [`SgcSession`], scheduled as one job on the shared
    /// backend by a [`JobScheduler`]; the trainer hooks the scheduler's
    /// [`RoundObserver`] to execute the plan's tasks for real (PJRT
    /// gradients, GC encode) and numerically decode the jobs the session
    /// reports as complete.
    pub fn run(&mut self, cluster: &mut dyn EventCluster) -> Result<TrainReport> {
        let wall = Stopwatch::start();
        let jobs = self.cfg.models * self.cfg.iterations;
        anyhow::ensure!(cluster.n() == self.scheme_cfg.n, "cluster size mismatch");
        let chunk_cap = self.pool.dims().chunk;
        let dims = self.pool.dims();

        // Held-out eval batch per model (fixed).
        let eval_batches: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = (0..self.cfg.models)
            .map(|m| {
                let ds = self.dataset_of(m);
                let eval_idx: Vec<usize> = (0..chunk_cap.min(ds.len())).collect();
                ds.chunk_tensors(&eval_idx, chunk_cap, 1.0 / eval_idx.len() as f32)
            })
            .collect();

        let this: &MultiModelTrainer = self;
        let mut pump = TrainPump {
            t: this,
            jobs,
            chunk_cap,
            batch_rng: Pcg32::new(this.cfg.seed, 0xba7c),
            // GC code plans drawn from the process-wide cache (constructed
            // once per (n, s) across every trainer/session in the process).
            plans: HashMap::new(),
            params: (0..this.cfg.models).map(|m| Arc::new(this.init_params(m))).collect(),
            opts: (0..this.cfg.models)
                .map(|_| Adam::new(this.cfg.lr, &dims.param_lens()))
                .collect(),
            iter_of_model: vec![0usize; this.cfg.models],
            eval_batches,
            jobs_state: (0..jobs).map(|_| None).collect(),
            losses: vec![Vec::new(); this.cfg.models],
            completed: 0,
            curve: Vec::new(),
        };

        let mut sched = JobScheduler::new(cluster);
        sched.admit(&JobSpec {
            scheme: this.scheme_cfg.clone(),
            session: SessionConfig { jobs, mu: this.cfg.mu, ..Default::default() },
        })?;
        let out = sched.run_observed(&mut pump)?;
        let report = &out.reports[0];

        Ok(TrainReport {
            scheme: self.scheme_cfg.label(),
            sim_runtime_s: report.total_runtime_s,
            wall_runtime_s: wall.elapsed_s(),
            losses: pump.losses,
            jobs_completed: pump.completed,
            deadline_violations: report.deadline_violations,
            completion_curve: pump.curve,
        })
    }

    /// Execute all responders' units for round `r` through the compute
    /// pool and fold results into the job states.
    fn compute_round(
        &self,
        scheme: &dyn Scheme,
        tasks: &[crate::coding::TaskDesc],
        responded: &[bool],
        jobs_state: &mut [Option<JobState>],
        plans: &mut HashMap<usize, Arc<CodePlan>>,
    ) -> Result<()> {
        // Phase 1 — collect the distinct (job, chunk) gradients this round
        // needs and submit them all (they run in parallel across compute
        // lanes).
        let mut needed: HashSet<(usize, usize)> = HashSet::new();
        for (i, task) in tasks.iter().enumerate() {
            if !responded[i] {
                continue;
            }
            for unit in &task.units {
                let Some(job) = unit.job() else { continue };
                let Some(js) = jobs_state[job - 1].as_ref() else { continue };
                if js.done {
                    continue;
                }
                match unit {
                    WorkUnit::Plain { chunk, .. } => {
                        if !js.delivered_chunks.contains(chunk) {
                            needed.insert((job, *chunk));
                        }
                    }
                    WorkUnit::Coded { chunks, .. } => {
                        for &c in chunks.iter() {
                            needed.insert((job, c));
                        }
                    }
                    WorkUnit::Noop => {}
                }
            }
        }
        let mut pending = Vec::with_capacity(needed.len());
        for &(job, chunk) in &needed {
            let js = jobs_state[job - 1].as_ref().unwrap();
            let (x, y, w) = self.dataset_of(js.model).chunk_tensors(
                &js.chunk_indices[chunk],
                self.pool.dims().chunk,
                js.sample_weight,
            );
            let rx =
                self.pool.submit(GradRequest { params: Arc::clone(&js.params), x, y, wgt: w });
            pending.push((job, chunk, rx));
        }
        let mut values: HashMap<(usize, usize), (f32, Vec<Vec<f32>>)> = HashMap::new();
        for (job, chunk, rx) in pending {
            let (loss, grads, _secs) =
                rx.recv().expect("compute lane alive").context("grad_chunk failed")?;
            values.insert((job, chunk), (loss, grads));
        }

        // Phase 2 — fold per work unit: plain results accumulate directly;
        // coded units are GC-encoded into ℓ_{row,group}(job).
        let n = self.scheme_cfg.n;
        for (i, task) in tasks.iter().enumerate() {
            if !responded[i] {
                continue;
            }
            for unit in &task.units {
                let Some(job) = unit.job() else { continue };
                let done = jobs_state[job - 1].as_ref().map(|j| j.done).unwrap_or(true);
                if done {
                    continue;
                }
                match unit {
                    WorkUnit::Plain { chunk, .. } => {
                        let js = jobs_state[job - 1].as_mut().unwrap();
                        if js.delivered_chunks.insert(*chunk) {
                            let (loss, grads) =
                                values.get(&(job, *chunk)).expect("plain value computed");
                            js.loss_sum += *loss as f64;
                            add_into(&mut js.plain_sum, grads);
                        }
                    }
                    WorkUnit::Coded { group, row, chunks, .. } => {
                        let need = scheme.ledger(job).coded_need[*group];
                        let mut ell: Vec<Vec<f32>> = self
                            .pool
                            .dims()
                            .param_lens()
                            .iter()
                            .map(|&l| vec![0.0f32; l])
                            .collect();
                        for &c in chunks.iter() {
                            let coeff = if self.rep_coding || need <= 1 {
                                1.0f32
                            } else {
                                let s = n - need;
                                let plan = plans
                                    .entry(s)
                                    .or_insert_with(|| CodePlanCache::global().get(n, s));
                                plan.b()[(*row, c % n)] as f32
                            };
                            let (_, grads) = values.get(&(job, c)).expect("coded value");
                            for (e, g) in ell.iter_mut().zip(grads) {
                                for (x, &y) in e.iter_mut().zip(g) {
                                    *x += coeff * y;
                                }
                            }
                        }
                        let js = jobs_state[job - 1].as_mut().unwrap();
                        let entry = js.coded.entry(*group).or_default();
                        if !entry.iter().any(|(w, _)| w == row) {
                            entry.push((*row, ell));
                        }
                    }
                    WorkUnit::Noop => {}
                }
            }
        }
        Ok(())
    }

    /// Aggregate a decodable job's numeric gradient.
    fn finalize_job(
        &self,
        scheme: &dyn Scheme,
        job: usize,
        jobs_state: &mut [Option<JobState>],
        plans: &mut HashMap<usize, Arc<CodePlan>>,
    ) -> Result<Vec<Vec<f32>>> {
        let n = scheme.spec().n;
        let dims = self.pool.dims();
        let js = jobs_state[job - 1].as_ref().unwrap();
        let mut total: Vec<Vec<f32>> = js
            .plain_sum
            .clone()
            .unwrap_or_else(|| dims.param_lens().iter().map(|&l| vec![0.0; l]).collect());
        let ledger = scheme.ledger(job);
        for (g, (got, &need)) in
            ledger.coded_got.iter().zip(&ledger.coded_need).enumerate()
        {
            let results = js.coded.get(&g).context("missing coded group results")?;
            if need == 1 {
                // replication group: any single ℓ is the group sum
                let (_, ell) = results.first().context("no replication result")?;
                add_into_vec(&mut total, ell);
            } else {
                let s = n - need;
                let plan =
                    plans.entry(s).or_insert_with(|| CodePlanCache::global().get(n, s));
                let mut chosen: Vec<&(usize, Vec<Vec<f32>>)> = results.iter().collect();
                chosen.sort_by_key(|(w, _)| *w);
                chosen.dedup_by_key(|(w, _)| *w);
                chosen.truncate(need);
                anyhow::ensure!(chosen.len() >= need, "not enough coded results");
                let workers: Vec<usize> = chosen.iter().map(|(w, _)| *w).collect();
                let beta = plan
                    .decode_coeffs(&workers)
                    .context("undecodable coded group (numeric)")?;
                for (k, (_, ell)) in chosen.iter().enumerate() {
                    let b = beta[k] as f32;
                    for (tot, e) in total.iter_mut().zip(ell) {
                        for (t, &v) in tot.iter_mut().zip(e) {
                            *t += b * v;
                        }
                    }
                }
            }
            let _ = got;
        }
        Ok(total)
    }
}

/// The trainer's [`RoundObserver`]: runs the *numeric* side of every
/// round boundary the scheduler reports — job setup at round start, real
/// gradient compute and model updates at round close. The metadata
/// protocol (μ-rule, wait-outs, decodability) never leaves the session.
struct TrainPump<'a> {
    t: &'a MultiModelTrainer,
    /// Total jobs `J = M · iterations`.
    jobs: usize,
    chunk_cap: usize,
    batch_rng: Pcg32,
    plans: HashMap<usize, Arc<CodePlan>>,
    /// Per-model parameters (snapshotted per job).
    params: Vec<Arc<Vec<Vec<f32>>>>,
    opts: Vec<Adam>,
    iter_of_model: Vec<usize>,
    eval_batches: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)>,
    jobs_state: Vec<Option<JobState>>,
    losses: Vec<Vec<LossPoint>>,
    completed: usize,
    curve: Vec<(f64, usize)>,
}

impl RoundObserver for TrainPump<'_> {
    fn round_started(
        &mut self,
        _job: JobId,
        session: &SgcSession,
        plan: &RoundPlan,
    ) -> crate::Result<()> {
        let r = plan.round;
        if r > self.jobs {
            return Ok(()); // trailing delay rounds start no new job
        }
        // Start job r: snapshot the owning model's params, sample and
        // split the batch.
        let model = (r - 1) % self.t.cfg.models;
        let batch =
            self.t.dataset_of(model).sample_batch(self.t.cfg.batch, &mut self.batch_rng);
        let chunk_indices = Dataset::split_batch(&batch, &session.scheme().spec().chunk_sizes);
        for (c, idx) in chunk_indices.iter().enumerate() {
            anyhow::ensure!(
                idx.len() <= self.chunk_cap,
                "chunk {c} has {} samples > compiled capacity {}; \
                 lower --batch or recompile with a larger chunk",
                idx.len(),
                self.chunk_cap
            );
        }
        self.jobs_state[r - 1] = Some(JobState {
            model,
            params: Arc::clone(&self.params[model]),
            chunk_indices,
            sample_weight: 1.0 / self.t.cfg.batch as f32,
            plain_sum: None,
            delivered_chunks: HashSet::new(),
            coded: HashMap::new(),
            loss_sum: 0.0,
            done: false,
        });
        Ok(())
    }

    fn round_closed(
        &mut self,
        _job: JobId,
        session: &SgcSession,
        plan: &RoundPlan,
        events: &[SessionEvent],
    ) -> crate::Result<()> {
        // Real compute for responders' units on still-active jobs.
        self.t.compute_round(
            session.scheme(),
            &plan.tasks,
            session.last_responded(),
            &mut self.jobs_state,
            &mut self.plans,
        )?;

        // Numerically decode the jobs the session decoded at the
        // metadata level, update models, log losses.
        let clock = session.clock_s();
        for ev in events {
            let SessionEvent::JobDecoded { job: t, .. } = ev else { continue };
            let t = *t;
            let grad =
                self.t.finalize_job(session.scheme(), t, &mut self.jobs_state, &mut self.plans)?;
            let js = self.jobs_state[t - 1].as_mut().unwrap();
            js.done = true;
            self.completed += 1;
            let model = js.model;
            let mut p = (*self.params[model]).clone();
            self.opts[model].update(&mut p, &grad);
            self.params[model] = Arc::new(p);
            self.iter_of_model[model] += 1;
            if self.iter_of_model[model] % self.t.cfg.eval_every == 0 {
                let (ex, ey, ew) = &self.eval_batches[model];
                let (loss, _, _) = self
                    .t
                    .pool
                    .grad_chunk_blocking(GradRequest {
                        params: Arc::clone(&self.params[model]),
                        x: ex.clone(),
                        y: ey.clone(),
                        wgt: ew.clone(),
                    })
                    .context("eval loss")?;
                self.losses[model].push(LossPoint {
                    iteration: self.iter_of_model[model],
                    sim_time_s: clock,
                    loss: loss as f64,
                });
            }
        }
        self.curve.push((clock, self.completed));
        // Drop job state once past its deadline to bound memory.
        if let Some(t) = session.scheme().deadline_job(plan.round) {
            if let Some(js) = self.jobs_state[t - 1].as_mut() {
                js.chunk_indices.clear();
                js.coded.clear();
            }
        }
        Ok(())
    }
}

fn add_into(acc: &mut Option<Vec<Vec<f32>>>, grads: &[Vec<f32>]) {
    match acc {
        None => *acc = Some(grads.to_vec()),
        Some(a) => add_into_vec(a, grads),
    }
}

fn add_into_vec(acc: &mut [Vec<f32>], grads: &[Vec<f32>]) {
    for (a, g) in acc.iter_mut().zip(grads) {
        for (x, &y) in a.iter_mut().zip(g) {
            *x += y;
        }
    }
}

