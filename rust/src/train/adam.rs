//! Adam optimizer (Kingma & Ba) — the paper's experiments use ADAM for
//! all models (Sec. 4.2).

/// Adam state over a list of flattened parameter tensors.
#[derive(Clone, Debug)]
pub struct Adam {
    /// Learning rate.
    pub lr: f32,
    /// First-moment decay.
    pub beta1: f32,
    /// Second-moment decay.
    pub beta2: f32,
    /// Denominator fuzz.
    pub eps: f32,
    step: u64,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Adam {
    /// Fresh state for tensors of the given flattened lengths.
    pub fn new(lr: f32, param_lens: &[usize]) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            step: 0,
            m: param_lens.iter().map(|&l| vec![0.0; l]).collect(),
            v: param_lens.iter().map(|&l| vec![0.0; l]).collect(),
        }
    }

    /// Apply one update in place.
    pub fn update(&mut self, params: &mut [Vec<f32>], grads: &[Vec<f32>]) {
        assert_eq!(params.len(), grads.len());
        self.step += 1;
        let t = self.step as f32;
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        for ((p, g), (m, v)) in
            params.iter_mut().zip(grads).zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            assert_eq!(p.len(), g.len());
            for i in 0..p.len() {
                m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * g[i];
                v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * g[i] * g[i];
                let mhat = m[i] / bc1;
                let vhat = v[i] / bc2;
                p[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
            }
        }
    }

    /// Updates applied so far.
    pub fn steps(&self) -> u64 {
        self.step
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_on_quadratic() {
        // minimize f(x) = ||x - c||²
        let c = [3.0f32, -2.0, 0.5];
        let mut params = vec![vec![0.0f32; 3]];
        let mut adam = Adam::new(0.05, &[3]);
        for _ in 0..2000 {
            let g: Vec<f32> = params[0].iter().zip(&c).map(|(x, t)| 2.0 * (x - t)).collect();
            adam.update(&mut params, &[g]);
        }
        for (x, t) in params[0].iter().zip(&c) {
            assert!((x - t).abs() < 1e-2, "{x} vs {t}");
        }
    }

    #[test]
    fn first_step_is_lr_sized() {
        let mut params = vec![vec![0.0f32]];
        let mut adam = Adam::new(0.1, &[1]);
        adam.update(&mut params, &[vec![123.0]]);
        // Adam's first step is ≈ -lr · sign(g)
        assert!((params[0][0] + 0.1).abs() < 1e-3, "{}", params[0][0]);
        assert_eq!(adam.steps(), 1);
    }
}
