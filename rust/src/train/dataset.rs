//! Synthetic classification corpus.
//!
//! Substitute for MNIST/CIFAR (DESIGN.md §2): a Gaussian-mixture image
//! model with one prototype per class plus per-sample noise, so the MLP
//! has real class structure to learn and the loss curve has the familiar
//! decaying shape (Fig. 2(b)).

use crate::util::rng::Pcg32;

/// Dataset configuration.
#[derive(Clone, Copy, Debug)]
pub struct DatasetConfig {
    /// Feature width.
    pub input: usize,
    /// Class count.
    pub classes: usize,
    /// Training examples generated.
    pub train_size: usize,
    /// Noise std around class prototypes (larger = harder problem).
    pub noise: f64,
    /// Generation seed.
    pub seed: u64,
}

impl Default for DatasetConfig {
    fn default() -> Self {
        DatasetConfig { input: 64, classes: 10, train_size: 8192, noise: 0.8, seed: 1234 }
    }
}

/// In-memory synthetic dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// The configuration it was generated from.
    pub cfg: DatasetConfig,
    /// Row-major `train_size × input`.
    pub x: Vec<f32>,
    /// Labels in `[0, classes)`.
    pub y: Vec<u32>,
    prototypes: Vec<f32>,
}

impl Dataset {
    /// Deterministically generate the class-prototype dataset.
    pub fn generate(cfg: DatasetConfig) -> Self {
        let mut rng = Pcg32::new(cfg.seed, 0xda7a);
        let prototypes: Vec<f32> = (0..cfg.classes * cfg.input)
            .map(|_| rng.normal() as f32)
            .collect();
        let mut x = Vec::with_capacity(cfg.train_size * cfg.input);
        let mut y = Vec::with_capacity(cfg.train_size);
        for _ in 0..cfg.train_size {
            let c = rng.below(cfg.classes);
            y.push(c as u32);
            for d in 0..cfg.input {
                let proto = prototypes[c * cfg.input + d];
                x.push(proto + (cfg.noise * rng.normal()) as f32);
            }
        }
        Dataset { cfg, x, y, prototypes }
    }

    /// Training examples available.
    pub fn len(&self) -> usize {
        self.cfg.train_size
    }

    /// No examples (degenerate config).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Sample a batch of `size` indices.
    pub fn sample_batch(&self, size: usize, rng: &mut Pcg32) -> Vec<usize> {
        (0..size).map(|_| rng.below(self.len())).collect()
    }

    /// Materialize samples into a padded chunk: `(x, y_onehot, wgt)` of
    /// the fixed `chunk` size, with each real sample carrying weight
    /// `sample_weight` and padding rows weight 0.
    pub fn chunk_tensors(
        &self,
        indices: &[usize],
        chunk: usize,
        sample_weight: f32,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        assert!(indices.len() <= chunk, "chunk overflow: {} > {chunk}", indices.len());
        let (input, classes) = (self.cfg.input, self.cfg.classes);
        let mut x = vec![0.0f32; chunk * input];
        let mut y = vec![0.0f32; chunk * classes];
        let mut w = vec![0.0f32; chunk];
        for (row, &idx) in indices.iter().enumerate() {
            x[row * input..(row + 1) * input]
                .copy_from_slice(&self.x[idx * input..(idx + 1) * input]);
            y[row * classes + self.y[idx] as usize] = 1.0;
            w[row] = sample_weight;
        }
        (x, y, w)
    }

    /// Split a batch across `fractions` (chunk sizes of a scheme):
    /// chunk `j` receives `round(frac_j · batch)` samples (with remainder
    /// balancing so every sample lands in exactly one chunk).
    pub fn split_batch(batch: &[usize], fractions: &[f64]) -> Vec<Vec<usize>> {
        let n = batch.len();
        let mut out: Vec<Vec<usize>> = Vec::with_capacity(fractions.len());
        // largest-remainder apportionment
        let raw: Vec<f64> = fractions.iter().map(|f| f * n as f64).collect();
        let mut counts: Vec<usize> = raw.iter().map(|r| r.floor() as usize).collect();
        let mut rem: Vec<(f64, usize)> =
            raw.iter().enumerate().map(|(i, r)| (r - r.floor(), i)).collect();
        rem.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let assigned: usize = counts.iter().sum();
        for k in 0..n.saturating_sub(assigned) {
            counts[rem[k % rem.len()].1] += 1;
        }
        let mut cursor = 0;
        for &c in &counts {
            out.push(batch[cursor..cursor + c].to_vec());
            cursor += c;
        }
        debug_assert_eq!(cursor, n);
        out
    }

    /// Class prototypes (for tests).
    pub fn prototype(&self, class: usize) -> &[f32] {
        &self.prototypes[class * self.cfg.input..(class + 1) * self.cfg.input]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let a = Dataset::generate(DatasetConfig::default());
        let b = Dataset::generate(DatasetConfig::default());
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn samples_cluster_around_prototypes() {
        let cfg = DatasetConfig { noise: 0.1, ..Default::default() };
        let ds = Dataset::generate(cfg);
        // distance to own prototype < distance to another class's
        let mut better = 0;
        for i in 0..200 {
            let c = ds.y[i] as usize;
            let other = (c + 1) % cfg.classes;
            let dist = |proto: &[f32]| -> f32 {
                (0..cfg.input)
                    .map(|d| (ds.x[i * cfg.input + d] - proto[d]).powi(2))
                    .sum()
            };
            if dist(ds.prototype(c)) < dist(ds.prototype(other)) {
                better += 1;
            }
        }
        assert!(better > 190, "{better}/200");
    }

    #[test]
    fn chunk_tensors_pads_with_zero_weight() {
        let ds = Dataset::generate(DatasetConfig::default());
        let (x, y, w) = ds.chunk_tensors(&[0, 1, 2], 8, 0.5);
        assert_eq!(x.len(), 8 * 64);
        assert_eq!(y.len(), 8 * 10);
        assert_eq!(w, vec![0.5, 0.5, 0.5, 0.0, 0.0, 0.0, 0.0, 0.0]);
        // one-hot rows sum to 1 for real samples, 0 for padding
        for row in 0..8 {
            let s: f32 = y[row * 10..(row + 1) * 10].iter().sum();
            assert_eq!(s, if row < 3 { 1.0 } else { 0.0 });
        }
    }

    #[test]
    fn split_batch_partitions_exactly() {
        let batch: Vec<usize> = (0..100).collect();
        let fractions = vec![0.5, 0.25, 0.25];
        let parts = Dataset::split_batch(&batch, &fractions);
        assert_eq!(parts.len(), 3);
        assert_eq!(parts.iter().map(|p| p.len()).sum::<usize>(), 100);
        assert_eq!(parts[0].len(), 50);
        // unequal fractions (M-SGC style)
        let fr2 = vec![3.0 / 32.0; 8].into_iter().chain(vec![1.0 / 32.0; 8]).collect::<Vec<_>>();
        let parts2 = Dataset::split_batch(&batch, &fr2);
        assert_eq!(parts2.iter().map(|p| p.len()).sum::<usize>(), 100);
    }
}
