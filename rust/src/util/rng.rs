//! Deterministic pseudo-random number generation.
//!
//! PCG-XSH-RR 64/32 (O'Neill 2014) — small state, excellent statistical
//! quality, and fully reproducible across platforms, which matters because
//! every experiment in EXPERIMENTS.md is seeded.

/// PCG-XSH-RR 64/32 generator.
///
/// Each logical component of the system (straggler model, data synthesis,
/// GC coefficient design, …) owns its own `Pcg32` derived from a root seed
/// via [`Pcg32::split`], so adding randomness in one module never perturbs
/// another module's stream.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and a stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (stream << 1) | 1;
        let mut rng = Pcg32 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng.state = rng.state.wrapping_add(seed);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng.next_u32();
        rng
    }

    /// Seed-only constructor on the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Derive an independent generator; `tag` distinguishes children.
    pub fn split(&mut self, tag: u64) -> Pcg32 {
        let seed = (self.next_u64()).wrapping_add(tag.wrapping_mul(0x9e3779b97f4a7c15));
        Pcg32::new(seed, tag | 1)
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64-bit output (two 32-bit draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, bound)` via Lemire's method (unbiased).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "below(0)");
        let bound = bound as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mulwide(x, bound);
            if lo >= bound || lo >= x.wrapping_neg() % bound {
                return hi as usize;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; cheap enough).
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean / std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with given rate.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-300).ln() / rate
    }

    /// Log-normal with the given underlying mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Pareto(scale, shape) — heavy-tail latency spikes.
    pub fn pareto(&mut self, scale: f64, shape: f64) -> f64 {
        scale / self.f64().max(1e-300).powf(1.0 / shape)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)`.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher–Yates
        for i in 0..k {
            let j = self.range_usize(i, n - 1);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[inline]
fn mulwide(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg32::new(42, 7);
        let mut b = Pcg32::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_streams_diverge() {
        let mut a = Pcg32::new(42, 7);
        let mut b = Pcg32::new(42, 8);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg32::seeded(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Pcg32::seeded(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(9);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg32::seeded(11);
        for _ in 0..100 {
            let s = r.sample_indices(20, 8);
            let mut t = s.clone();
            t.sort_unstable();
            t.dedup();
            assert_eq!(t.len(), 8);
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg32::seeded(13);
        let n = 100_000;
        let m = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.02, "mean {m}");
    }
}
