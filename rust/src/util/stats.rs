//! Summary statistics for experiment reports.
//!
//! Every table in the paper reports `avg ± std` over repetitions; the
//! figures need percentiles, CDFs, histograms and one linear fit (Fig. 16).

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator; 0.0 for len < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Minimum (NaN-free inputs assumed).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// Maximum.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Percentile via linear interpolation on the sorted copy; `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&s, q)
}

/// Percentile on an already sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = (q / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Incremental mean/variance (Welford) — used by long-running metric
/// accumulators that should not buffer every sample.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    /// Empty accumulator.
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Fold one sample in.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Samples folded in so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest sample seen.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest sample seen.
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// `avg ± std` pair, the unit every paper table reports.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MeanStd {
    /// Sample mean.
    pub mean: f64,
    /// Sample standard deviation.
    pub std: f64,
}

impl MeanStd {
    /// Mean and standard deviation of `xs`.
    pub fn of(xs: &[f64]) -> Self {
        MeanStd { mean: mean(xs), std: std_dev(xs) }
    }
}

impl std::fmt::Display for MeanStd {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.2} ± {:.2}", self.mean, self.std)
    }
}

/// Fixed-bin histogram over `[lo, hi)`; used for Fig. 1(b) burst lengths.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// Inclusive lower bound of the binned range.
    pub lo: f64,
    /// Exclusive upper bound of the binned range.
    pub hi: f64,
    /// Per-bin sample counts.
    pub counts: Vec<u64>,
}

impl Histogram {
    /// `bins` equal-width bins over `[lo, hi)`.
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins] }
    }

    /// Count one sample (out-of-range samples clamp to the edge bins).
    pub fn push(&mut self, x: f64) {
        let bins = self.counts.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * bins as f64) as isize).clamp(0, bins as isize - 1) as usize;
        self.counts[idx] += 1;
    }

    /// Total samples counted.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }
}

/// Empirical CDF evaluated at the sample points — Fig. 1(c) / Fig. 19(b).
pub fn ecdf(xs: &[f64]) -> Vec<(f64, f64)> {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = s.len() as f64;
    s.iter().enumerate().map(|(i, &x)| (x, (i + 1) as f64 / n)).collect()
}

/// Ordinary least squares `y = a + b x`; returns `(a, b, r2)`.
/// Fig. 16 calibrates the latency model's load slope with this.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
        syy += (y - my) * (y - my);
    }
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    let _ = n;
    (a, b, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935299395).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64).sin() * 3.0 + 1.0).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-9);
        assert!((w.std_dev() - std_dev(&xs)).abs() < 1e-9);
        assert_eq!(w.count(), 1000);
    }

    #[test]
    fn histogram_counts() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.push(i as f64 + 0.5);
        }
        assert!(h.counts.iter().all(|&c| c == 1));
        h.push(-5.0); // clamps to first bin
        h.push(50.0); // clamps to last bin
        assert_eq!(h.counts[0], 2);
        assert_eq!(h.counts[9], 2);
    }

    #[test]
    fn ecdf_monotone() {
        let xs = [3.0, 1.0, 2.0];
        let c = ecdf(&xs);
        assert_eq!(c.len(), 3);
        assert!((c[0].1 - 1.0 / 3.0).abs() < 1e-12);
        assert!((c[2].1 - 1.0).abs() < 1e-12);
        assert!(c.windows(2).all(|w| w[0].0 <= w[1].0 && w[0].1 <= w[1].1));
    }

    #[test]
    fn linear_fit_exact() {
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }
}
