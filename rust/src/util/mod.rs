//! Substrate utilities.
//!
//! This environment is fully offline, so the usual ecosystem crates
//! (`rand`, `clap`, `serde_json`, `rayon`, …) are unavailable. Everything a
//! downstream user would expect from them is implemented here, scoped to
//! what the library needs:
//!
//! * [`rng`] — a PCG-family PRNG with normal/exponential samplers.
//! * [`stats`] — summary statistics, percentiles, histograms, linear fits.
//! * [`linalg`] — the dense solver behind GC decoding.
//! * [`cli`] — a small argv parser for the `sgc` binary and examples.
//! * [`threadpool`] — fixed-size worker pool used by the real-compute
//!   cluster.
//! * [`json`] — a writer for machine-readable metric dumps.
//! * [`timer`] — wall-clock helpers.

pub mod cli;
pub mod json;
pub mod linalg;
pub mod rng;
pub mod stats;
pub mod threadpool;
pub mod timer;
