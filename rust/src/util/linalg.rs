//! Dense linear algebra for GC coefficient design and decoding.
//!
//! The (n,s)-GC decode step finds coefficients `β` over the responding
//! workers `W` such that `Σ_w β_w B[w,:] = 1ᵀ` (Tandon et al. 2017). We
//! solve the consistent overdetermined system through its normal equations
//! (Cholesky on the (n-s)×(n-s) Gram matrix), which is both faster and more
//! cache-friendly than Gaussian elimination on the full n×(n-s) system at
//! the paper's n = 256.
//!
//! The kernels here are shaped for the decode hot path (see
//! `rust/DESIGN.md` §Performance):
//!
//! * [`dot`] / [`axpy_f64`] / [`axpy_f32`] run 4-wide chunked loops (four
//!   independent accumulators / lanes the compiler can keep in registers
//!   and auto-vectorize).
//! * [`cholesky_into`] and [`cholesky_solve_into`] factor and solve into
//!   caller-owned buffers, so repeated solves (iterative refinement, the
//!   probe's candidate sweeps) reuse their scratch instead of
//!   reallocating per call. The allocating [`cholesky`] /
//!   [`cholesky_solve`] wrappers remain for one-shot callers.
//! * [`axpy_f32`] is the f32 encode/decode kernel behind
//!   [`crate::coding::GcCode::encode`]/`decode`: elementwise, so its
//!   results are bit-identical to the scalar reference loop.

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Row-major storage, `rows * cols` long.
    pub data: Vec<f64>,
}

impl Matrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// `n x n` identity.
    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Matrix from equal-length rows.
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    /// Borrow row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutably borrow row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// `self * v` (v has len = cols).
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        let mut out = Vec::new();
        self.matvec_into(v, &mut out);
        out
    }

    /// `self * v` into a caller-owned buffer (cleared and refilled).
    pub fn matvec_into(&self, v: &[f64], out: &mut Vec<f64>) {
        assert_eq!(v.len(), self.cols);
        out.clear();
        out.extend((0..self.rows).map(|i| dot(self.row(i), v)));
    }

    /// `selfᵀ * v` (v has len = rows).
    pub fn tr_matvec(&self, v: &[f64]) -> Vec<f64> {
        let mut out = Vec::new();
        self.tr_matvec_into(v, &mut out);
        out
    }

    /// `selfᵀ * v` into a caller-owned buffer (cleared and refilled).
    pub fn tr_matvec_into(&self, v: &[f64], out: &mut Vec<f64>) {
        assert_eq!(v.len(), self.rows);
        out.clear();
        out.resize(self.cols, 0.0);
        for i in 0..self.rows {
            let vi = v[i];
            if vi == 0.0 {
                continue;
            }
            axpy_f64(out, vi, self.row(i));
        }
    }

    /// Dense matmul (small sizes only — verification paths).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows);
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                axpy_f64(out_row, a, orow);
            }
        }
        out
    }

    /// Gram matrix `self * selfᵀ` (rows × rows), exploiting symmetry.
    pub fn gram_rows(&self) -> Matrix {
        let n = self.rows;
        let mut g = Matrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = dot(self.row(i), self.row(j));
                g[(i, j)] = v;
                g[(j, i)] = v;
            }
        }
        g
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// 4-wide chunked dot product: four independent accumulators break the
/// add-latency dependency chain (the B rows at n = 256 are long enough
/// for this to dominate decode setup).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n4 = a.len() & !3;
    let (a4, at) = a.split_at(n4);
    let (b4, bt) = b.split_at(n4);
    let mut acc = [0.0f64; 4];
    for (ca, cb) in a4.chunks_exact(4).zip(b4.chunks_exact(4)) {
        acc[0] += ca[0] * cb[0];
        acc[1] += ca[1] * cb[1];
        acc[2] += ca[2] * cb[2];
        acc[3] += ca[3] * cb[3];
    }
    let mut tail = 0.0;
    for (x, y) in at.iter().zip(bt) {
        tail += x * y;
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
}

/// `out += a * x`, 4-wide chunked. Elementwise, so bit-identical to the
/// scalar loop.
#[inline]
pub fn axpy_f64(out: &mut [f64], a: f64, x: &[f64]) {
    debug_assert_eq!(out.len(), x.len());
    let n4 = out.len() & !3;
    let (o4, ot) = out.split_at_mut(n4);
    let (x4, xt) = x.split_at(n4);
    for (oc, xc) in o4.chunks_exact_mut(4).zip(x4.chunks_exact(4)) {
        oc[0] += a * xc[0];
        oc[1] += a * xc[1];
        oc[2] += a * xc[2];
        oc[3] += a * xc[3];
    }
    for (o, &v) in ot.iter_mut().zip(xt) {
        *o += a * v;
    }
}

/// `out += a * x` over f32 gradients — the encode/decode kernel of
/// [`crate::coding::GcCode`]. Elementwise (each output lane sees the same
/// operation order as a scalar loop), so results are bit-identical to the
/// scalar reference.
#[inline]
pub fn axpy_f32(out: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    let n4 = out.len() & !3;
    let (o4, ot) = out.split_at_mut(n4);
    let (x4, xt) = x.split_at(n4);
    for (oc, xc) in o4.chunks_exact_mut(4).zip(x4.chunks_exact(4)) {
        oc[0] += a * xc[0];
        oc[1] += a * xc[1];
        oc[2] += a * xc[2];
        oc[3] += a * xc[3];
    }
    for (o, &v) in ot.iter_mut().zip(xt) {
        *o += a * v;
    }
}

/// Solve a square system `A x = b` with partial-pivoting Gaussian
/// elimination. Returns `None` when `A` is (numerically) singular.
pub fn solve_square(a: &Matrix, b: &[f64]) -> Option<Vec<f64>> {
    assert_eq!(a.rows, a.cols);
    assert_eq!(b.len(), a.rows);
    let n = a.rows;
    // augmented [A | b]
    let mut m = vec![0.0; n * (n + 1)];
    for i in 0..n {
        m[i * (n + 1)..i * (n + 1) + n].copy_from_slice(a.row(i));
        m[i * (n + 1) + n] = b[i];
    }
    let w = n + 1;
    for col in 0..n {
        // pivot
        let mut piv = col;
        let mut best = m[col * w + col].abs();
        for r in col + 1..n {
            let v = m[r * w + col].abs();
            if v > best {
                best = v;
                piv = r;
            }
        }
        if best < 1e-12 {
            return None;
        }
        if piv != col {
            for j in 0..w {
                m.swap(col * w + j, piv * w + j);
            }
        }
        let d = m[col * w + col];
        for r in col + 1..n {
            let f = m[r * w + col] / d;
            if f == 0.0 {
                continue;
            }
            for j in col..w {
                m[r * w + j] -= f * m[col * w + j];
            }
        }
    }
    // back substitution
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut acc = m[i * w + n];
        for j in i + 1..n {
            acc -= m[i * w + j] * x[j];
        }
        x[i] = acc / m[i * w + i];
    }
    Some(x)
}

/// Cholesky factorisation of an SPD matrix into a caller-owned factor
/// buffer (resized/zeroed as needed, so repeated factorizations reuse the
/// allocation). Returns `false` if `a` is not positive definite; the
/// contents of `l` are unspecified in that case.
///
/// The inner update is the 4-wide [`dot`] over the already-factored row
/// prefixes — the classic `ℓ_{ij} = (a_{ij} − Σ_k ℓ_{ik} ℓ_{jk}) / ℓ_{jj}`
/// with the sum as one dot product over contiguous row storage.
pub fn cholesky_into(a: &Matrix, l: &mut Matrix) -> bool {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    if l.rows != n || l.cols != n {
        *l = Matrix::zeros(n, n);
    } else {
        l.data.fill(0.0);
    }
    for i in 0..n {
        for j in 0..=i {
            let sum =
                a[(i, j)] - dot(&l.data[i * n..i * n + j], &l.data[j * n..j * n + j]);
            if i == j {
                if sum <= 1e-12 {
                    return false;
                }
                l.data[i * n + j] = sum.sqrt();
            } else {
                l.data[i * n + j] = sum / l.data[j * n + j];
            }
        }
    }
    true
}

/// Allocating wrapper over [`cholesky_into`].
pub fn cholesky(a: &Matrix) -> Option<Matrix> {
    let mut l = Matrix::zeros(a.rows, a.cols);
    cholesky_into(a, &mut l).then_some(l)
}

/// Solve `L Lᵀ x = b` given the Cholesky factor `L`, with caller-owned
/// forward-solve scratch `y` and output `x` (both cleared and refilled).
pub fn cholesky_solve_into(l: &Matrix, b: &[f64], y: &mut Vec<f64>, x: &mut Vec<f64>) {
    let n = l.rows;
    assert_eq!(b.len(), n);
    // forward: L y = b (row-prefix dot over contiguous storage)
    y.clear();
    y.resize(n, 0.0);
    for i in 0..n {
        let acc = b[i] - dot(&l.data[i * n..i * n + i], &y[..i]);
        y[i] = acc / l.data[i * n + i];
    }
    // backward: Lᵀ x = y (column access; strided, left as scalar loop)
    x.clear();
    x.resize(n, 0.0);
    for i in (0..n).rev() {
        let mut acc = y[i];
        for k in i + 1..n {
            acc -= l.data[k * n + i] * x[k];
        }
        x[i] = acc / l.data[i * n + i];
    }
}

/// Allocating wrapper over [`cholesky_solve_into`].
pub fn cholesky_solve(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let mut y = Vec::new();
    let mut x = Vec::new();
    cholesky_solve_into(l, b, &mut y, &mut x);
    x
}

/// Minimum-norm/least-squares solve of `Aᵀ x = b` where `A` is (k×n) with
/// k ≤ n and full row rank: solves `(A Aᵀ) x = A b` via Cholesky.
///
/// This is exactly the GC decode shape: rows of `A` are the returned
/// workers' coefficient vectors, `b` is the all-ones target.
pub fn solve_consistent_rows(a: &Matrix, b: &[f64]) -> Option<Vec<f64>> {
    assert_eq!(b.len(), a.cols);
    let gram = a.gram_rows();
    let rhs = a.matvec(b);
    let l = cholesky(&gram)?;
    Some(cholesky_solve(&l, &rhs))
}

/// Residual `‖Aᵀ x − b‖∞` — used to verify decodability.
pub fn residual_inf(a: &Matrix, x: &[f64], b: &[f64]) -> f64 {
    let atx = a.tr_matvec(x);
    atx.iter().zip(b).map(|(p, q)| (p - q).abs()).fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn solve_square_identity() {
        let a = Matrix::identity(4);
        let b = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(solve_square(&a, &b).unwrap(), b);
    }

    #[test]
    fn solve_square_random() {
        let mut rng = Pcg32::seeded(17);
        for n in [1, 2, 5, 20] {
            let mut a = Matrix::zeros(n, n);
            for v in a.data.iter_mut() {
                *v = rng.normal();
            }
            let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = a.matvec(&x_true);
            let x = solve_square(&a, &b).expect("nonsingular whp");
            for (p, q) in x.iter().zip(&x_true) {
                assert!((p - q).abs() < 1e-8, "{p} vs {q}");
            }
        }
    }

    #[test]
    fn solve_square_singular_detected() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]);
        assert!(solve_square(&a, &[1.0, 2.0]).is_none());
    }

    #[test]
    fn cholesky_roundtrip() {
        let mut rng = Pcg32::seeded(23);
        let n = 12;
        let mut m = Matrix::zeros(n, n + 3);
        for v in m.data.iter_mut() {
            *v = rng.normal();
        }
        let spd = m.gram_rows(); // full rank whp → SPD
        let l = cholesky(&spd).unwrap();
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let x = cholesky_solve(&l, &b);
        let back = spd.matvec(&x);
        for (p, q) in back.iter().zip(&b) {
            assert!((p - q).abs() < 1e-8);
        }
    }

    #[test]
    fn cholesky_into_reuses_buffers() {
        let mut rng = Pcg32::seeded(29);
        let mut l = Matrix::zeros(1, 1); // deliberately the wrong shape
        let mut y = Vec::new();
        let mut x = Vec::new();
        for n in [3usize, 8, 8, 5] {
            let mut m = Matrix::zeros(n, n + 2);
            for v in m.data.iter_mut() {
                *v = rng.normal();
            }
            let spd = m.gram_rows();
            assert!(cholesky_into(&spd, &mut l), "SPD must factor");
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            cholesky_solve_into(&l, &b, &mut y, &mut x);
            let back = spd.matvec(&x);
            for (p, q) in back.iter().zip(&b) {
                assert!((p - q).abs() < 1e-7);
            }
        }
    }

    #[test]
    fn cholesky_into_rejects_indefinite() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // eigenvalues 3, -1
        let mut l = Matrix::zeros(2, 2);
        assert!(!cholesky_into(&a, &mut l));
    }

    #[test]
    fn consistent_rows_recovers_ones() {
        // A simple decodable GC-like system: 3 rows over 4 columns whose
        // row space contains the ones vector.
        let a = Matrix::from_rows(&[
            vec![0.5, 1.0, 0.0, 0.0],
            vec![0.0, 1.0, -1.0, 0.0],
            vec![0.5, 0.0, 1.0, 1.0],
        ]);
        // x = (2, -1, ?) -- solved numerically
        let ones = vec![1.0; 4];
        let x = solve_consistent_rows(&a, &ones).unwrap();
        assert!(residual_inf(&a, &x, &ones) < 1e-9);
    }

    #[test]
    fn matvec_tr_matvec_agree() {
        let mut rng = Pcg32::seeded(31);
        let a = {
            let mut m = Matrix::zeros(6, 9);
            for v in m.data.iter_mut() {
                *v = rng.normal();
            }
            m
        };
        let x: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..9).map(|_| rng.normal()).collect();
        // xᵀ (A y) == (Aᵀ x)ᵀ y
        let lhs = dot(&x, &a.matvec(&y));
        let rhs = dot(&a.tr_matvec(&x), &y);
        assert!((lhs - rhs).abs() < 1e-9);
    }

    #[test]
    fn chunked_dot_matches_scalar() {
        let mut rng = Pcg32::seeded(37);
        for len in [0usize, 1, 3, 4, 5, 8, 17, 64, 200] {
            let a: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
            let scalar: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!(
                (dot(&a, &b) - scalar).abs() <= 1e-10 * (1.0 + scalar.abs()),
                "len {len}"
            );
        }
    }

    #[test]
    fn axpy_kernels_match_scalar() {
        let mut rng = Pcg32::seeded(41);
        for len in [0usize, 1, 2, 3, 4, 7, 32, 101] {
            let x32: Vec<f32> = (0..len).map(|_| rng.normal() as f32).collect();
            let base32: Vec<f32> = (0..len).map(|_| rng.normal() as f32).collect();
            let a32 = rng.normal() as f32;
            let mut got = base32.clone();
            axpy_f32(&mut got, a32, &x32);
            for ((g, b), &xv) in got.iter().zip(&base32).zip(&x32) {
                assert_eq!(g.to_bits(), (b + a32 * xv).to_bits(), "len {len}");
            }

            let x64: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
            let base64: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
            let a64 = rng.normal();
            let mut got = base64.clone();
            axpy_f64(&mut got, a64, &x64);
            for ((g, b), &xv) in got.iter().zip(&base64).zip(&x64) {
                assert_eq!(g.to_bits(), (b + a64 * xv).to_bits(), "len {len}");
            }
        }
    }
}
