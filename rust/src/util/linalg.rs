//! Dense linear algebra for GC coefficient design and decoding.
//!
//! The (n,s)-GC decode step finds coefficients `β` over the responding
//! workers `W` such that `Σ_w β_w B[w,:] = 1ᵀ` (Tandon et al. 2017). We
//! solve the consistent overdetermined system through its normal equations
//! (Cholesky on the (n-s)×(n-s) Gram matrix), which is both faster and more
//! cache-friendly than Gaussian elimination on the full n×(n-s) system at
//! the paper's n = 256.

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// `self * v` (v has len = cols).
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.cols);
        (0..self.rows).map(|i| dot(self.row(i), v)).collect()
    }

    /// `selfᵀ * v` (v has len = rows).
    pub fn tr_matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.rows);
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let vi = v[i];
            if vi == 0.0 {
                continue;
            }
            for (o, &a) in out.iter_mut().zip(self.row(i)) {
                *o += vi * a;
            }
        }
        out
    }

    /// Dense matmul (small sizes only — verification paths).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows);
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for (o, &b) in out_row.iter_mut().zip(orow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Gram matrix `self * selfᵀ` (rows × rows), exploiting symmetry.
    pub fn gram_rows(&self) -> Matrix {
        let n = self.rows;
        let mut g = Matrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = dot(self.row(i), self.row(j));
                g[(i, j)] = v;
                g[(j, i)] = v;
            }
        }
        g
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Solve a square system `A x = b` with partial-pivoting Gaussian
/// elimination. Returns `None` when `A` is (numerically) singular.
pub fn solve_square(a: &Matrix, b: &[f64]) -> Option<Vec<f64>> {
    assert_eq!(a.rows, a.cols);
    assert_eq!(b.len(), a.rows);
    let n = a.rows;
    // augmented [A | b]
    let mut m = vec![0.0; n * (n + 1)];
    for i in 0..n {
        m[i * (n + 1)..i * (n + 1) + n].copy_from_slice(a.row(i));
        m[i * (n + 1) + n] = b[i];
    }
    let w = n + 1;
    for col in 0..n {
        // pivot
        let mut piv = col;
        let mut best = m[col * w + col].abs();
        for r in col + 1..n {
            let v = m[r * w + col].abs();
            if v > best {
                best = v;
                piv = r;
            }
        }
        if best < 1e-12 {
            return None;
        }
        if piv != col {
            for j in 0..w {
                m.swap(col * w + j, piv * w + j);
            }
        }
        let d = m[col * w + col];
        for r in col + 1..n {
            let f = m[r * w + col] / d;
            if f == 0.0 {
                continue;
            }
            for j in col..w {
                m[r * w + j] -= f * m[col * w + j];
            }
        }
    }
    // back substitution
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut acc = m[i * w + n];
        for j in i + 1..n {
            acc -= m[i * w + j] * x[j];
        }
        x[i] = acc / m[i * w + i];
    }
    Some(x)
}

/// Cholesky factorisation of an SPD matrix (in place lower triangle).
/// Returns `None` if not positive definite.
pub fn cholesky(a: &Matrix) -> Option<Matrix> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[(i, j)];
            for k in 0..j {
                sum -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if sum <= 1e-12 {
                    return None;
                }
                l[(i, j)] = sum.sqrt();
            } else {
                l[(i, j)] = sum / l[(j, j)];
            }
        }
    }
    Some(l)
}

/// Solve `L Lᵀ x = b` given the Cholesky factor `L`.
pub fn cholesky_solve(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    // forward: L y = b
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut acc = b[i];
        for k in 0..i {
            acc -= l[(i, k)] * y[k];
        }
        y[i] = acc / l[(i, i)];
    }
    // backward: Lᵀ x = y
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut acc = y[i];
        for k in i + 1..n {
            acc -= l[(k, i)] * x[k];
        }
        x[i] = acc / l[(i, i)];
    }
    x
}

/// Minimum-norm/least-squares solve of `Aᵀ x = b` where `A` is (k×n) with
/// k ≤ n and full row rank: solves `(A Aᵀ) x = A b` via Cholesky.
///
/// This is exactly the GC decode shape: rows of `A` are the returned
/// workers' coefficient vectors, `b` is the all-ones target.
pub fn solve_consistent_rows(a: &Matrix, b: &[f64]) -> Option<Vec<f64>> {
    assert_eq!(b.len(), a.cols);
    let gram = a.gram_rows();
    let rhs = a.matvec(b);
    let l = cholesky(&gram)?;
    Some(cholesky_solve(&l, &rhs))
}

/// Residual `‖Aᵀ x − b‖∞` — used to verify decodability.
pub fn residual_inf(a: &Matrix, x: &[f64], b: &[f64]) -> f64 {
    let atx = a.tr_matvec(x);
    atx.iter().zip(b).map(|(p, q)| (p - q).abs()).fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn solve_square_identity() {
        let a = Matrix::identity(4);
        let b = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(solve_square(&a, &b).unwrap(), b);
    }

    #[test]
    fn solve_square_random() {
        let mut rng = Pcg32::seeded(17);
        for n in [1, 2, 5, 20] {
            let mut a = Matrix::zeros(n, n);
            for v in a.data.iter_mut() {
                *v = rng.normal();
            }
            let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = a.matvec(&x_true);
            let x = solve_square(&a, &b).expect("nonsingular whp");
            for (p, q) in x.iter().zip(&x_true) {
                assert!((p - q).abs() < 1e-8, "{p} vs {q}");
            }
        }
    }

    #[test]
    fn solve_square_singular_detected() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]);
        assert!(solve_square(&a, &[1.0, 2.0]).is_none());
    }

    #[test]
    fn cholesky_roundtrip() {
        let mut rng = Pcg32::seeded(23);
        let n = 12;
        let mut m = Matrix::zeros(n, n + 3);
        for v in m.data.iter_mut() {
            *v = rng.normal();
        }
        let spd = m.gram_rows(); // full rank whp → SPD
        let l = cholesky(&spd).unwrap();
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let x = cholesky_solve(&l, &b);
        let back = spd.matvec(&x);
        for (p, q) in back.iter().zip(&b) {
            assert!((p - q).abs() < 1e-8);
        }
    }

    #[test]
    fn consistent_rows_recovers_ones() {
        // A simple decodable GC-like system: 3 rows over 4 columns whose
        // row space contains the ones vector.
        let a = Matrix::from_rows(&[
            vec![0.5, 1.0, 0.0, 0.0],
            vec![0.0, 1.0, -1.0, 0.0],
            vec![0.5, 0.0, 1.0, 1.0],
        ]);
        // x = (2, -1, ?) -- solved numerically
        let ones = vec![1.0; 4];
        let x = solve_consistent_rows(&a, &ones).unwrap();
        assert!(residual_inf(&a, &x, &ones) < 1e-9);
    }

    #[test]
    fn matvec_tr_matvec_agree() {
        let mut rng = Pcg32::seeded(31);
        let a = {
            let mut m = Matrix::zeros(6, 9);
            for v in m.data.iter_mut() {
                *v = rng.normal();
            }
            m
        };
        let x: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let y: Vec<f64> = (0..9).map(|_| rng.normal()).collect();
        // xᵀ (A y) == (Aᵀ x)ᵀ y
        let lhs = dot(&x, &a.matvec(&y));
        let rhs = dot(&a.tr_matvec(&x), &y);
        assert!((lhs - rhs).abs() < 1e-9);
    }
}
