//! Tiny JSON value + writer (serde is unavailable offline).
//!
//! Benches and examples dump their series here so plots / downstream
//! analysis can consume `target/experiments/*.json`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// JSON value. `BTreeMap` keeps object keys deterministic, which keeps
/// experiment artifacts diffable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics on non-object — programmer error).
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("set() on non-object"),
        }
        self
    }

    /// Render to a compact string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Render with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(xs) if !xs.is_empty() => {
                out.push_str("[\n");
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    x.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
            other => other.write(out),
        }
    }

    /// Write pretty JSON to a file, creating parent dirs.
    pub fn save(&self, path: &str) -> std::io::Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_pretty())
    }
}

fn push_indent(out: &mut String, n: usize) {
    for _ in 0..n {
        out.push_str("  ");
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        if x == x.trunc() && x.abs() < 1e15 {
            let _ = write!(out, "{}", x as i64);
        } else {
            let _ = write!(out, "{x}");
        }
    } else {
        out.push_str("null"); // JSON has no NaN/Inf
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Self {
        Json::Str(x)
    }
}
impl<T: Into<Json> + Clone> From<&[T]> for Json {
    fn from(xs: &[T]) -> Self {
        Json::Arr(xs.iter().cloned().map(Into::into).collect())
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Self {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compact_rendering() {
        let mut o = Json::obj();
        o.set("name", "m-sgc").set("load", 0.008).set("rounds", 482usize);
        assert_eq!(o.to_string(), r#"{"load":0.008,"name":"m-sgc","rounds":482}"#);
    }

    #[test]
    fn arrays_and_nesting() {
        let mut o = Json::obj();
        o.set("xs", vec![1.0, 2.5, 3.0]);
        let mut inner = Json::obj();
        inner.set("ok", true);
        o.set("meta", inner);
        assert_eq!(o.to_string(), r#"{"meta":{"ok":true},"xs":[1,2.5,3]}"#);
    }

    #[test]
    fn escapes() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn pretty_is_reparseable_shape() {
        let mut o = Json::obj();
        o.set("a", vec![1.0, 2.0]);
        let p = o.to_pretty();
        assert!(p.contains("\"a\": ["));
    }
}
