//! Tiny JSON value, writer and parser (serde is unavailable offline).
//!
//! Benches and examples dump their series here so plots / downstream
//! analysis can consume `target/experiments/*.json`; the parser loads
//! recorded fleet/sim traces back ([`crate::cluster::RunTrace`]).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// JSON value. `BTreeMap` keeps object keys deterministic, which keeps
/// experiment artifacts diffable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (JSON has only doubles).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object with deterministically-ordered keys.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Fresh empty object.
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics on non-object — programmer error).
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("set() on non-object"),
        }
        self
    }

    /// Render to a compact string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Render with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(xs) if !xs.is_empty() => {
                out.push_str("[\n");
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    x.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
            other => other.write(out),
        }
    }

    /// Write pretty JSON to a file, creating parent dirs.
    pub fn save(&self, path: &str) -> std::io::Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_pretty())
    }

    /// Parse a JSON document (the inverse of [`to_string`](Self::to_string)
    /// / [`to_pretty`](Self::to_pretty)). Numbers parse as `f64`;
    /// `null` literals round-trip back from non-finite numbers.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let bytes = s.as_bytes();
        let mut p = Parser { bytes, pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Read and parse a JSON file.
    pub fn load(path: &str) -> crate::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {path}: {e}"))?;
        Json::parse(&text).map_err(|e| anyhow::anyhow!("parse {path}: {e}"))
    }

    // --- typed accessors (None on shape mismatch) ----------------------

    /// Object member lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Number value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Number as usize (must be a non-negative integer).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && *x == x.trunc() => Some(*x as usize),
            _ => None,
        }
    }

    /// Bool value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }
}

/// Parse failure with byte offset.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JsonError {
    /// Byte offset of the failure in the input.
    pub offset: usize,
    /// What went wrong there.
    pub message: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

/// Nesting bound: parsing recurses per level, so a hostile document of
/// repeated `[` must error instead of overflowing the stack.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl Parser<'_> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn descend(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting deeper than 128 levels"));
        }
        Ok(())
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn eat_lit(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.eat_lit("true", Json::Bool(true)),
            Some(b'f') => self.eat_lit("false", Json::Bool(false)),
            Some(b'n') => self.eat_lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.descend()?;
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            m.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.descend()?;
        self.eat(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.pos += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .and_then(|h| u32::from_str_radix(h, 16).ok())
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // surrogate pairs are not produced by our writer;
                            // map lone surrogates to the replacement char.
                            out.push(char::from_u32(hex).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control character in string")),
                _ => {
                    // copy one UTF-8 scalar
                    let start = self.pos;
                    let rest = &self.bytes[start..];
                    let ch_len = match rest[0] {
                        c if c < 0x80 => 1,
                        c if c >= 0xf0 => 4,
                        c if c >= 0xe0 => 3,
                        _ => 2,
                    };
                    let s = std::str::from_utf8(&rest[..ch_len.min(rest.len())])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.pos += s.len();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }
}

fn push_indent(out: &mut String, n: usize) {
    for _ in 0..n {
        out.push_str("  ");
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        if x == x.trunc() && x.abs() < 1e15 {
            let _ = write!(out, "{}", x as i64);
        } else {
            let _ = write!(out, "{x}");
        }
    } else {
        out.push_str("null"); // JSON has no NaN/Inf
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Self {
        Json::Str(x)
    }
}
impl<T: Into<Json> + Clone> From<&[T]> for Json {
    fn from(xs: &[T]) -> Self {
        Json::Arr(xs.iter().cloned().map(Into::into).collect())
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Self {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compact_rendering() {
        let mut o = Json::obj();
        o.set("name", "m-sgc").set("load", 0.008).set("rounds", 482usize);
        assert_eq!(o.to_string(), r#"{"load":0.008,"name":"m-sgc","rounds":482}"#);
    }

    #[test]
    fn arrays_and_nesting() {
        let mut o = Json::obj();
        o.set("xs", vec![1.0, 2.5, 3.0]);
        let mut inner = Json::obj();
        inner.set("ok", true);
        o.set("meta", inner);
        assert_eq!(o.to_string(), r#"{"meta":{"ok":true},"xs":[1,2.5,3]}"#);
    }

    #[test]
    fn escapes() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn pretty_is_reparseable_shape() {
        let mut o = Json::obj();
        o.set("a", vec![1.0, 2.0]);
        let p = o.to_pretty();
        assert!(p.contains("\"a\": ["));
    }

    fn doc() -> Json {
        let mut o = Json::obj();
        o.set("name", "m-sgc:1,2,27")
            .set("load", 0.0078125)
            .set("neg", -3.5e-2)
            .set("big", 1e300)
            .set("ok", true)
            .set("off", false)
            .set("nothing", Json::Null)
            .set("xs", vec![1.0, 2.5, 3.0])
            .set("text", "quote\" slash\\ nl\n tab\t unicode→λ");
        let mut inner = Json::obj();
        inner.set("empty_arr", Json::Arr(vec![])).set("empty_obj", Json::obj());
        o.set("meta", inner);
        o
    }

    #[test]
    fn parse_round_trips_compact_and_pretty() {
        let d = doc();
        assert_eq!(Json::parse(&d.to_string()).unwrap(), d);
        assert_eq!(Json::parse(&d.to_pretty()).unwrap(), d);
    }

    #[test]
    fn parse_accepts_whitespace_everywhere() {
        let j = Json::parse(" { \"a\" : [ 1 , 2 ] , \"b\" : null } ").unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(j.get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\":}", "tru", "1 2", "\"unterminated", "{\"a\":1,}"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn parse_bounds_nesting_depth() {
        // deep but legal
        let ok = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&ok).is_ok());
        // hostile: must be a JsonError, not a stack overflow
        let deep = "[".repeat(100_000);
        let err = Json::parse(&deep).unwrap_err();
        assert!(err.message.contains("nesting"), "{err}");
    }

    #[test]
    fn accessors_return_none_on_mismatch() {
        let d = doc();
        assert_eq!(d.get("load").unwrap().as_f64(), Some(0.0078125));
        assert_eq!(d.get("xs").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(d.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(d.get("name").unwrap().as_str(), Some("m-sgc:1,2,27"));
        assert_eq!(d.get("load").unwrap().as_usize(), None, "0.0078 is not a usize");
        assert_eq!(d.get("missing"), None);
        assert_eq!(Json::Num(3.0).as_usize(), Some(3));
        assert_eq!(Json::Num(-3.0).as_usize(), None);
    }

    #[test]
    fn unicode_escape_parses() {
        assert_eq!(Json::parse(r#""a\u00e9b""#).unwrap().as_str(), Some("aéb"));
        // raw multi-byte UTF-8 passes through unescaped
        assert_eq!(Json::parse("\"λ→μ\"").unwrap().as_str(), Some("λ→μ"));
    }
}
