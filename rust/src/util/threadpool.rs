//! Fixed-size thread pool (tokio/rayon are unavailable offline).
//!
//! The real-compute cluster runs each simulated Lambda worker's partial
//! gradient computation as a pool job; the master blocks on a round barrier
//! built from the returned [`JobHandle`]s.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads consuming a shared job queue.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `size` worker threads.
    pub fn new(size: usize) -> Self {
        assert!(size > 0);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let in_flight = Arc::clone(&in_flight);
                std::thread::Builder::new()
                    .name(format!("sgc-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                in_flight.fetch_sub(1, Ordering::AcqRel);
                            }
                            Err(_) => break, // pool dropped
                        }
                    })
                    .expect("spawn worker thread")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, in_flight }
    }

    /// Number of jobs submitted but not yet finished.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Acquire)
    }

    /// Submit a fire-and-forget job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.fetch_add(1, Ordering::AcqRel);
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(Box::new(f))
            .expect("worker threads alive");
    }

    /// Submit a job returning a value; the result arrives on a
    /// [`JobHandle`].
    pub fn submit<T, F>(&self, f: F) -> JobHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let (tx, rx) = channel();
        self.execute(move || {
            // Receiver may have been dropped (cancelled round) — ignore.
            let _ = tx.send(f());
        });
        JobHandle { rx }
    }

    /// Block until the queue is drained and all jobs finished.
    pub fn wait_idle(&self) {
        while self.in_flight() > 0 {
            std::thread::yield_now();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes the channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Handle for a submitted job's result.
pub struct JobHandle<T> {
    rx: Receiver<T>,
}

impl<T> JobHandle<T> {
    /// Block for the result.
    pub fn join(self) -> T {
        self.rx.recv().expect("job panicked or pool dropped")
    }

    /// Non-blocking poll.
    pub fn try_join(&self) -> Option<T> {
        self.rx.try_recv().ok()
    }

    /// Wait up to `timeout`; `None` on expiry.
    pub fn join_timeout(&self, timeout: std::time::Duration) -> Option<T> {
        self.rx.recv_timeout(timeout).ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn submit_returns_values() {
        let pool = ThreadPool::new(3);
        let handles: Vec<_> = (0..20).map(|i| pool.submit(move || i * i)).collect();
        let results: Vec<usize> = handles.into_iter().map(|h| h.join()).collect();
        assert_eq!(results, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn join_timeout_expires() {
        let pool = ThreadPool::new(1);
        let h = pool.submit(|| {
            std::thread::sleep(std::time::Duration::from_millis(200));
            42
        });
        assert!(h.join_timeout(std::time::Duration::from_millis(10)).is_none());
        assert_eq!(h.join_timeout(std::time::Duration::from_secs(5)), Some(42));
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(20)));
        drop(pool); // must not hang or panic
    }
}
