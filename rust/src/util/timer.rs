//! Wall-clock helpers for benches and the real-compute cluster.

use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    /// Time since start (or the last restart).
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed time in seconds.
    pub fn elapsed_s(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Elapsed time in milliseconds.
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }

    /// Return the elapsed time and reset the start point to now.
    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Human-readable duration, e.g. `1.24s`, `380ms`, `25.1us`.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.1}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.1}us", s * 1e6)
    } else {
        format!("{:.0}ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(sw.elapsed_ms() >= 4.0);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.00s");
        assert_eq!(fmt_duration(Duration::from_millis(380)), "380.0ms");
        assert_eq!(fmt_duration(Duration::from_micros(25)), "25.0us");
        assert_eq!(fmt_duration(Duration::from_nanos(100)), "100ns");
    }
}
