//! Minimal argv parser (clap is unavailable offline).
//!
//! Supports `subcommand --flag`, `--key value`, `--key=value` and
//! positional arguments, with typed getters and a generated usage string.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First non-flag token, if the binary uses subcommands.
    pub subcommand: Option<String>,
    /// `--key value` / `--key=value` pairs.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse an explicit token stream.
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Self {
        let toks: Vec<String> = tokens.into_iter().collect();
        let mut args = Args::default();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(stripped) = t.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    args.options.insert(stripped.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(t.clone());
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        args
    }

    /// String option with default.
    pub fn get(&self, key: &str, default: &str) -> String {
        self.options.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Typed option with default; panics with a clear message on parse
    /// failure (CLI surface, not library surface).
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.options.get(key) {
            None => default,
            Some(v) => match v.parse() {
                Ok(x) => x,
                Err(e) => panic!("invalid value for --{key}: {v:?} ({e})"),
            },
        }
    }

    /// Is a bare flag present?
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Option present (either form)?
    pub fn has(&self, key: &str) -> bool {
        self.options.contains_key(key) || self.has_flag(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("run --scheme msgc --jobs=480 extra --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("scheme", "gc"), "msgc");
        assert_eq!(a.get_parse::<usize>("jobs", 0), 480);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
        // note: `--verbose extra` would instead parse as verbose=extra —
        // bare flags must come last or use `--flag=`-style options.
    }

    #[test]
    fn defaults_apply() {
        let a = parse("bench");
        assert_eq!(a.get("scheme", "gc"), "gc");
        assert_eq!(a.get_parse::<f64>("mu", 1.0), 1.0);
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("x --a --b v");
        assert!(a.has_flag("a"));
        assert_eq!(a.get("b", ""), "v");
    }

    #[test]
    #[should_panic(expected = "invalid value for --jobs")]
    fn bad_typed_value_panics() {
        let a = parse("run --jobs abc");
        let _: usize = a.get_parse("jobs", 0);
    }
}
