//! Trace record / replay: capture the per-round `(n × rounds)` delay
//! matrix of *any* [`Cluster`] and replay it bit-exactly.
//!
//! Recording wraps a backend ([`RecordingCluster`]) or is built into the
//! fleet driver ([`crate::fleet::drive_fleet`]); the result is a
//! [`RunTrace`] that serializes through [`crate::util::json`] and loads
//! back three ways:
//!
//! * [`RunTrace::replay`] — a [`TraceReplayCluster`] returning the
//!   recorded completion times verbatim, so a rerun of the same scheme
//!   reproduces the identical `RunReport` (responder sets, durations,
//!   job completions);
//! * [`crate::probe::DelayProfile::from_trace`] — feed a recorded run
//!   into the Appendix-J load-adjusted parameter search;
//! * [`RunTrace::pattern`] + [`SimCluster::from_trace`](super::SimCluster::from_trace)
//!   — reuse just the straggler *states* (when the source knew them)
//!   under freshly sampled latencies.

use super::event::{ClusterEvent, EventCluster, JobId};
use super::{Cluster, RoundSample};
use crate::straggler::Pattern;
use crate::util::json::Json;
use std::collections::HashMap;

/// Trace format version written to JSON.
pub const TRACE_VERSION: usize = 1;

/// One recorded round.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRound {
    /// Normalized load each worker was assigned.
    pub loads: Vec<f64>,
    /// Completion time per worker (seconds from round start).
    pub finish: Vec<f64>,
    /// Ground-truth straggler states, when the source cluster knew them
    /// (simulators do; a real fleet does not).
    pub state: Option<Vec<bool>>,
}

/// A recorded `(n × rounds)` delay matrix plus per-round loads/states.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RunTrace {
    /// Worker count (row width).
    pub n: usize,
    /// One entry per recorded submission, in submission order.
    pub rounds: Vec<TraceRound>,
}

impl RunTrace {
    /// Empty trace over `n` workers.
    pub fn new(n: usize) -> Self {
        RunTrace { n, rounds: Vec::new() }
    }

    /// Rounds recorded.
    pub fn rounds(&self) -> usize {
        self.rounds.len()
    }

    /// Nothing recorded yet.
    pub fn is_empty(&self) -> bool {
        self.rounds.is_empty()
    }

    /// Record one round.
    pub fn push(&mut self, loads: Vec<f64>, finish: Vec<f64>, state: Option<Vec<bool>>) {
        assert_eq!(loads.len(), self.n, "loads length mismatch");
        assert_eq!(finish.len(), self.n, "finish length mismatch");
        if let Some(s) = &state {
            assert_eq!(s.len(), self.n, "state length mismatch");
        }
        self.rounds.push(TraceRound { loads, finish, state });
    }

    /// The straggler-state pattern, if every round recorded one — the
    /// input to [`SimCluster::from_trace`](super::SimCluster::from_trace).
    pub fn pattern(&self) -> Option<Pattern> {
        let rows: Option<Vec<Vec<bool>>> =
            self.rounds.iter().map(|r| r.state.clone()).collect();
        let mut p = Pattern::new(self.n);
        for row in rows? {
            p.push_round(row);
        }
        Some(p)
    }

    /// Exact-replay cluster over this trace.
    pub fn replay(&self) -> TraceReplayCluster {
        TraceReplayCluster {
            trace: self.clone(),
            cursor: 0,
            clock: 0.0,
            pending: Vec::new(),
            events_buf: Vec::new(),
            submissions: HashMap::new(),
        }
    }

    /// Serialize (versioned; the inverse of [`from_json`](Self::from_json)).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("version", TRACE_VERSION).set("n", self.n).set("rounds", self.rounds());
        o.set(
            "loads",
            Json::Arr(self.rounds.iter().map(|r| Json::from(r.loads.clone())).collect()),
        );
        o.set(
            "times",
            Json::Arr(self.rounds.iter().map(|r| Json::from(r.finish.clone())).collect()),
        );
        let states: Vec<Json> = self
            .rounds
            .iter()
            .map(|r| match &r.state {
                Some(s) => Json::from(s.clone()),
                None => Json::Null,
            })
            .collect();
        o.set("states", Json::Arr(states));
        o
    }

    /// Parse a trace written by [`to_json`](Self::to_json).
    pub fn from_json(j: &Json) -> crate::Result<RunTrace> {
        let fail = |what: &str| anyhow::anyhow!("trace json: bad or missing {what}");
        let version =
            j.get("version").and_then(Json::as_usize).ok_or_else(|| fail("version"))?;
        anyhow::ensure!(version == TRACE_VERSION, "unsupported trace version {version}");
        let n = j.get("n").and_then(Json::as_usize).ok_or_else(|| fail("n"))?;
        let rounds = j.get("rounds").and_then(Json::as_usize).ok_or_else(|| fail("rounds"))?;
        let row_f64 = |v: &Json, what: &str| -> crate::Result<Vec<f64>> {
            let xs = v.as_arr().ok_or_else(|| fail(what))?;
            anyhow::ensure!(xs.len() == n, "{what} row has {} entries, expected {n}", xs.len());
            xs.iter().map(|x| x.as_f64().ok_or_else(|| fail(what))).collect()
        };
        let loads = j.get("loads").and_then(Json::as_arr).ok_or_else(|| fail("loads"))?;
        let times = j.get("times").and_then(Json::as_arr).ok_or_else(|| fail("times"))?;
        let states = j.get("states").and_then(Json::as_arr).ok_or_else(|| fail("states"))?;
        anyhow::ensure!(
            loads.len() == rounds && times.len() == rounds && states.len() == rounds,
            "trace json: matrix shapes disagree with rounds={rounds}"
        );
        let mut trace = RunTrace::new(n);
        for ((l, t), s) in loads.iter().zip(times).zip(states) {
            let state = match s {
                Json::Null => None,
                v => {
                    let xs = v.as_arr().ok_or_else(|| fail("states"))?;
                    anyhow::ensure!(xs.len() == n, "states row length");
                    Some(
                        xs.iter()
                            .map(|x| x.as_bool().ok_or_else(|| fail("states")))
                            .collect::<crate::Result<Vec<bool>>>()?,
                    )
                }
            };
            trace.push(row_f64(l, "loads")?, row_f64(t, "times")?, state);
        }
        Ok(trace)
    }

    /// Save as pretty JSON (creates parent dirs).
    pub fn save(&self, path: &str) -> crate::Result<()> {
        self.to_json().save(path).map_err(|e| anyhow::anyhow!("save {path}: {e}"))
    }

    /// Load a trace file.
    pub fn load(path: &str) -> crate::Result<RunTrace> {
        Self::from_json(&Json::load(path)?)
    }
}

/// One undelivered replayed completion.
#[derive(Clone, Copy, Debug)]
struct PendingDone {
    job: JobId,
    round: u64,
    worker: usize,
    submit_s: f64,
    finish_rel: f64,
}

/// Replays a recorded trace verbatim: each *submission* consumes the
/// next recorded row and returns exactly its completion times (and
/// states), wrapping around when the session outlives the trace. Only
/// meaningful when driven by the same scheme that produced the recording
/// — the loads are not re-adjusted (use [`crate::probe::DelayProfile`]
/// for load-adjusted replay).
///
/// As an [`EventCluster`] the replay has no contention model of its own:
/// the recorded times already embody whatever queueing the source run
/// saw, so a task's completion lands at `submit + recorded_finish`
/// regardless of other in-flight jobs. Drive it blocking via
/// [`EventCluster::sync`].
pub struct TraceReplayCluster {
    trace: RunTrace,
    cursor: usize,
    clock: f64,
    pending: Vec<PendingDone>,
    events_buf: Vec<ClusterEvent>,
    /// Latest submission per job: `(round, trace row index)`.
    submissions: HashMap<JobId, (u64, usize)>,
}

impl EventCluster for TraceReplayCluster {
    fn n(&self) -> usize {
        self.trace.n
    }

    fn now_s(&self) -> f64 {
        self.clock
    }

    fn submit(&mut self, job: JobId, round: u64, loads: &[f64]) {
        assert_eq!(loads.len(), self.trace.n);
        assert!(!self.trace.is_empty(), "replay of an empty trace");
        let idx = self.cursor % self.trace.rounds();
        self.cursor += 1;
        // a fresh assignment supersedes the job's stale tasks
        self.pending.retain(|p| p.job != job);
        let row = &self.trace.rounds[idx];
        for (worker, &finish_rel) in row.finish.iter().enumerate() {
            self.pending.push(PendingDone {
                job,
                round,
                worker,
                submit_s: self.clock,
                finish_rel,
            });
        }
        self.submissions.insert(job, (round, idx));
    }

    fn poll(&mut self, until_s: f64) -> &[ClusterEvent] {
        assert!(!until_s.is_nan(), "poll horizon must not be NaN");
        self.events_buf.clear();
        let horizon = until_s.max(self.clock);
        let earliest = self
            .pending
            .iter()
            .map(|p| p.submit_s + p.finish_rel)
            .fold(f64::INFINITY, f64::min);
        if earliest <= horizon {
            self.clock = self.clock.max(earliest);
            let mut buf = std::mem::take(&mut self.events_buf);
            self.pending.retain(|p| {
                if p.submit_s + p.finish_rel <= earliest {
                    buf.push(ClusterEvent::WorkerDone {
                        job: p.job,
                        round: p.round,
                        worker: p.worker,
                        finish_s: p.finish_rel,
                    });
                    false
                } else {
                    true
                }
            });
            self.events_buf = buf;
        } else if until_s.is_finite() && until_s > self.clock {
            self.clock = until_s;
        }
        &self.events_buf
    }

    fn true_state(&self, job: JobId, round: u64) -> Option<&[bool]> {
        let &(r, idx) = self.submissions.get(&job)?;
        if r != round {
            return None;
        }
        self.trace.rounds[idx].state.as_deref()
    }
}

/// Wraps any [`Cluster`] and records every round it serves. With
/// [`autosave`](Self::autosave), the trace is written to disk when the
/// recorder is dropped — which is what lets `--record-trace` capture
/// runs that execute deep inside the batch driver's cluster factory.
pub struct RecordingCluster<C: Cluster> {
    inner: C,
    trace: RunTrace,
    autosave: Option<String>,
}

impl<C: Cluster> RecordingCluster<C> {
    /// Record every round sampled through `inner`.
    pub fn new(inner: C) -> Self {
        let n = inner.n();
        RecordingCluster { inner, trace: RunTrace::new(n), autosave: None }
    }

    /// Record and write the trace to `path` on drop (errors go to
    /// stderr — drop sites cannot propagate).
    pub fn autosave(inner: C, path: impl Into<String>) -> Self {
        let mut rec = Self::new(inner);
        rec.autosave = Some(path.into());
        rec
    }

    /// The trace recorded so far.
    pub fn trace(&self) -> &RunTrace {
        &self.trace
    }

    /// Take the trace out (disables autosave).
    pub fn into_trace(mut self) -> RunTrace {
        self.autosave = None;
        std::mem::take(&mut self.trace)
    }
}

impl<C: Cluster> Cluster for RecordingCluster<C> {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn sample_round(&mut self, loads: &[f64]) -> RoundSample {
        let sample = self.inner.sample_round(loads);
        self.trace.push(loads.to_vec(), sample.finish.clone(), Some(sample.state.clone()));
        sample
    }
}

impl<C: Cluster> Drop for RecordingCluster<C> {
    fn drop(&mut self) {
        if let Some(path) = self.autosave.take() {
            if self.trace.is_empty() {
                return;
            }
            if let Err(e) = self.trace.save(&path) {
                crate::log_warn!("could not save trace: {e}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::SimCluster;
    use crate::straggler::GilbertElliot;

    fn recorded_run(n: usize, rounds: usize) -> RunTrace {
        let sim = SimCluster::from_gilbert_elliot(n, GilbertElliot::new(n, 0.06, 0.6, 5), 9);
        let mut rec = RecordingCluster::new(sim.sync());
        for r in 0..rounds {
            let load = 0.05 + 0.01 * (r % 3) as f64;
            rec.sample_round(&vec![load; n]);
        }
        rec.into_trace()
    }

    #[test]
    fn json_round_trip_is_identity() {
        let trace = recorded_run(6, 12);
        let back = RunTrace::from_json(&trace.to_json()).unwrap();
        // bit-exact: the writer prints shortest-round-trip f64s, and the
        // fleet replay tests depend on that exactness
        assert_eq!(back, trace);
        assert_eq!(back.pattern().unwrap().rounds(), 12);
    }

    #[test]
    fn replay_returns_recorded_times_verbatim() {
        let trace = recorded_run(4, 5);
        let mut replay = trace.replay().sync();
        for r in 0..5 {
            let s = replay.sample_round(&[0.1; 4]);
            assert_eq!(s.finish, trace.rounds[r].finish);
            assert_eq!(&s.state, trace.rounds[r].state.as_ref().unwrap());
        }
        // wraps around
        let s = replay.sample_round(&[0.1; 4]);
        assert_eq!(s.finish, trace.rounds[0].finish);
    }

    #[test]
    fn replay_events_are_anchored_at_the_submit_instant() {
        let trace = recorded_run(3, 2);
        let mut replay = trace.replay();
        assert!(replay.poll(2.0).is_empty(), "nothing in flight");
        assert_eq!(replay.now_s(), 2.0);
        replay.submit(4, 9, &[0.1; 3]);
        assert_eq!(replay.true_state(4, 9), trace.rounds[0].state.as_deref());
        let mut got = 0;
        loop {
            let evs: Vec<ClusterEvent> = replay.poll(f64::INFINITY).to_vec();
            if evs.is_empty() {
                break;
            }
            for ev in evs {
                let ClusterEvent::WorkerDone { job, round, worker, finish_s } = ev else {
                    panic!("unexpected event {ev:?}");
                };
                assert_eq!((job, round), (4, 9));
                assert_eq!(finish_s, trace.rounds[0].finish[worker]);
                got += 1;
            }
        }
        assert_eq!(got, 3);
        assert!(replay.now_s() >= 2.0);
    }

    #[test]
    fn from_json_rejects_malformed() {
        let trace = recorded_run(3, 2);
        let mut j = trace.to_json();
        j.set("n", 99usize); // rows no longer match n
        assert!(RunTrace::from_json(&j).is_err());
        let mut j2 = trace.to_json();
        j2.set("version", TRACE_VERSION + 1);
        assert!(RunTrace::from_json(&j2).is_err());
    }

    #[test]
    fn fleet_style_trace_without_states_has_no_pattern() {
        let mut t = RunTrace::new(2);
        t.push(vec![0.1, 0.1], vec![1.0, 2.0], None);
        assert!(t.pattern().is_none());
        let back = RunTrace::from_json(&t.to_json()).unwrap();
        assert_eq!(back.rounds[0].state, None);
    }

    #[test]
    fn autosave_writes_on_drop() {
        let dir = std::env::temp_dir().join(format!("sgc-trace-{}", std::process::id()));
        let path = dir.join("autosave.json").to_string_lossy().into_owned();
        {
            let sim =
                SimCluster::from_gilbert_elliot(3, GilbertElliot::new(3, 0.05, 0.6, 2), 3);
            let mut rec = RecordingCluster::autosave(sim.sync(), path.clone());
            rec.sample_round(&[0.1; 3]);
        }
        let loaded = RunTrace::load(&path).unwrap();
        assert_eq!(loaded.rounds(), 1);
        let _ = std::fs::remove_dir_all(dir);
    }
}
