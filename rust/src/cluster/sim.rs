//! Discrete-time serverless-cluster simulator.
//!
//! Substitutes for the paper's 256-worker AWS Lambda fleet: each
//! submitted round, every worker gets a service time from the latency
//! model, with the straggler process deciding which workers are in a
//! slow state. The master (coordinator) then applies the μ-rule on the
//! resulting completion times exactly as the paper's master does on real
//! response times.
//!
//! The simulator is an [`EventCluster`]: many jobs can have task sets in
//! flight at once, and each worker executes its queue in FIFO order — a
//! worker still busy on job A's task starts job B's task only when A's
//! finishes, so concurrent sessions contend for workers like they do on
//! a real shared fleet instead of being sampled independently. A fresh
//! submission for a job *preempts* that job's still-queued tasks (the
//! master only re-assigns a worker it already cut from the previous
//! round); other jobs' tasks are never preempted. Blocking callers reach
//! the same sampler through [`SyncAdapter`](super::SyncAdapter), which
//! drains every round fully — on an idle fleet the completion times are
//! the service times themselves, byte-identical to the pre-event-API
//! blocking implementation.

use super::event::{ClusterEvent, EventCluster, JobId};
use super::latency::LatencyParams;
use super::storage::StorageParams;
use crate::chaos::{FaultKind, ResolvedPlan};
use crate::straggler::models::{GilbertElliot, StragglerProcess, TraceProcess};
use crate::straggler::Pattern;
use crate::util::rng::Pcg32;
use std::collections::{HashMap, VecDeque};

/// Ground-truth outcome of one simulated round.
#[derive(Clone, Debug)]
pub struct RoundSample {
    /// Completion time (seconds from round start) per worker.
    pub finish: Vec<f64>,
    /// True straggler state per worker (the master never sees this; it is
    /// recorded for Fig.-1-style analysis).
    pub state: Vec<bool>,
}

/// One queued task on a simulated worker.
#[derive(Clone, Copy, Debug)]
struct SimTask {
    job: JobId,
    round: u64,
    submit_s: f64,
    service_s: f64,
}

/// A chaos-afflicted worker's fate for one submission.
#[derive(Clone, Copy, PartialEq)]
enum Fate {
    /// Healthy: queue the task as usual.
    Serve,
    /// The master knows the worker is gone (crashed / retired / socket
    /// dropped): the submission is owed an immediate `WorkerDead`.
    Dead,
    /// Silent loss (hang, partition): no completion, no death — only
    /// the staged `RoundTimeout` backstop closes the round.
    Silent,
}

/// Chaos-injection state attached via [`SimCluster::set_chaos`]. All
/// effects are applied strictly *after* the round's service-time draws,
/// so a chaos run never perturbs the RNG stream of the corresponding
/// fault-free run.
struct SimChaos {
    plan: ResolvedPlan,
    /// Cluster submission ordinal of the latest `submit` (1-based; the
    /// counter fault rounds are scripted against).
    submissions: u64,
    /// Workers permanently gone (crash / byzantine / shrink victims).
    dead: Vec<bool>,
    /// Workers silently hung: deliveries vanish with no `WorkerDead`.
    hung: Vec<bool>,
    /// Per-worker partition window end (submission ordinal, exclusive).
    silent_until: Vec<u64>,
    /// Per-worker rejoin ordinal for reconnect faults (0 = not away):
    /// the worker counts as dead until the cluster's submission ordinal
    /// reaches this value, then a `WorkerJoined` is staged.
    rejoin_at: Vec<u64>,
    /// Membership / timeout events staged with their virtual due time.
    staged: Vec<(f64, ClusterEvent)>,
}

impl SimChaos {
    fn fate(&self, w: usize) -> Fate {
        if self.dead[w] || self.rejoin_at[w] != 0 {
            Fate::Dead
        } else if self.hung[w] || self.submissions < self.silent_until[w] {
            Fate::Silent
        } else {
            Fate::Serve
        }
    }
}

/// Stage a `RoundTimeout` for `(job, round)` at `due`, deduplicated —
/// several silent victims may drain tasks of the same round. A free
/// function so callers can hold disjoint borrows of the plan alongside.
fn stage_timeout(staged: &mut Vec<(f64, ClusterEvent)>, due: f64, job: JobId, round: u64) {
    let already = staged.iter().any(|(_, e)| {
        matches!(e, ClusterEvent::RoundTimeout { job: j, round: r } if *j == job && *r == round)
    });
    if !already {
        staged.push((due, ClusterEvent::RoundTimeout { job, round }));
    }
}

/// The simulated cluster.
pub struct SimCluster {
    /// Worker count.
    pub n: usize,
    /// Latency law `base + α·load` plus straggler uplift parameters.
    pub latency: LatencyParams,
    /// Optional shared-storage contention model (Appendix L).
    pub storage: Option<StorageParams>,
    process: Box<dyn StragglerProcess>,
    rng: Pcg32,
    /// Consecutive straggling rounds per worker *before* the current one
    /// (drives within-burst severity decay).
    burst_age: Vec<usize>,
    // --- event-mode state -------------------------------------------------
    /// Virtual clock (seconds).
    clock: f64,
    /// Per-worker FIFO task queue.
    queues: Vec<VecDeque<SimTask>>,
    /// Instant each worker last became free (committed work only).
    free_at: Vec<f64>,
    /// Reused event-delivery buffer ([`EventCluster::poll`] returns a
    /// slice of it).
    events_buf: Vec<ClusterEvent>,
    /// Ground-truth straggler states of each job's latest submission.
    states: HashMap<JobId, (u64, Vec<bool>)>,
    /// Scratch for the per-submission service-time draw.
    service_scratch: Vec<f64>,
    state_scratch: Vec<bool>,
    /// Test knob: cap on events handed out per `poll` call (splits
    /// same-timestamp batches so delivery-batching invariance can be
    /// exercised). `usize::MAX` in production.
    max_events_per_poll: usize,
    /// Observability hub, when attached: ground-truth straggler draws
    /// are journaled per submission (virtual clusters only — a real
    /// fleet has no ground truth). Never consulted by the simulation
    /// itself: the RNG stream is identical with or without it.
    obs: Option<std::sync::Arc<crate::obs::Obs>>,
    /// Scripted fault injection (see [`Self::set_chaos`]); `None` in
    /// ordinary runs — the fault-free path is byte-identical to the
    /// pre-chaos simulator.
    chaos: Option<SimChaos>,
}

impl SimCluster {
    /// Simulator over `n` workers with the given straggler process.
    pub fn new(
        n: usize,
        latency: LatencyParams,
        process: Box<dyn StragglerProcess>,
        seed: u64,
    ) -> Self {
        assert_eq!(process.n(), n);
        SimCluster {
            n,
            latency,
            storage: None,
            process,
            rng: Pcg32::new(seed, 0xc105),
            burst_age: vec![0; n],
            clock: 0.0,
            queues: vec![VecDeque::new(); n],
            free_at: vec![0.0; n],
            events_buf: Vec::new(),
            states: HashMap::new(),
            service_scratch: Vec::new(),
            state_scratch: Vec::new(),
            max_events_per_poll: usize::MAX,
            obs: None,
            chaos: None,
        }
    }

    /// Attach a resolved chaos plan (see [`crate::chaos`]): scripted
    /// faults fire on the cluster's 1-based submission ordinal. The
    /// plan is applied strictly *after* each round's service-time draws,
    /// so the RNG stream — and therefore every unaffected worker's
    /// completion time — is byte-identical to the fault-free run.
    ///
    /// * Crash / byzantine / shrink victims are retired: a
    ///   [`ClusterEvent::WorkerRetired`] fires, their queued tasks
    ///   convert to [`ClusterEvent::WorkerDead`]s, and every later
    ///   submission placing them is owed an immediate `WorkerDead`.
    /// * Hang / partition victims go *silent*: their completions are
    ///   dropped with no death notice, and each affected submission
    ///   stages a [`ClusterEvent::RoundTimeout`] at
    ///   `submit + sim_timeout_s` — the sim's stand-in for the fleet's
    ///   round-timeout backstop.
    /// * Reconnect victims are retired and count as dead for
    ///   `reconnect_rounds` submissions, then a
    ///   [`ClusterEvent::WorkerJoined`] restores them.
    pub fn set_chaos(&mut self, plan: ResolvedPlan) {
        self.chaos = Some(SimChaos {
            plan,
            submissions: 0,
            dead: vec![false; self.n],
            hung: vec![false; self.n],
            silent_until: vec![0; self.n],
            rejoin_at: vec![0; self.n],
            staged: Vec::new(),
        });
    }

    /// Attach an observability hub (see [`crate::obs`]): each
    /// submission journals its ground-truth straggler count as a
    /// [`TrueStragglers`](crate::obs::EventKind::TrueStragglers) event,
    /// stamped on the virtual clock. Read-only — results are
    /// byte-identical with or without it.
    pub fn set_obs(&mut self, obs: std::sync::Arc<crate::obs::Obs>) {
        self.obs = Some(obs);
    }

    /// Cluster driven by a Gilbert-Elliot straggler process with the
    /// Fig.-1 fit.
    pub fn from_gilbert_elliot(n: usize, ge: GilbertElliot, seed: u64) -> Self {
        Self::new(n, LatencyParams::default(), Box::new(ge), seed)
    }

    /// Cluster replaying a recorded straggler pattern.
    pub fn from_trace(n: usize, pattern: Pattern, seed: u64) -> Self {
        Self::new(n, LatencyParams::default(), Box::new(TraceProcess::new(pattern)), seed)
    }

    /// Attach a shared-storage model (Appendix L / Fig. 20 setup).
    pub fn with_storage(mut self, storage: StorageParams) -> Self {
        self.storage = Some(storage);
        self
    }

    /// Test knob: deliver at most `k` events per [`EventCluster::poll`]
    /// call, splitting same-timestamp batches. Delivery batching must be
    /// observationally invisible to schedulers (`tests/properties.rs::
    /// prop_scheduler_two_jobs_deterministic_and_batching_invariant`).
    pub fn set_max_events_per_poll(&mut self, k: usize) {
        self.max_events_per_poll = k.max(1);
    }

    /// Draw one round's straggler states and per-worker service times
    /// (seconds of work from task start, excluding any queueing). This is
    /// the one sampling routine both the blocking and the event path use,
    /// so the RNG stream is identical however the cluster is driven.
    fn sample_service_into(
        &mut self,
        loads: &[f64],
        service: &mut Vec<f64>,
        state: &mut Vec<bool>,
    ) {
        assert_eq!(loads.len(), self.n);
        let drawn = self.process.next_round();
        state.clear();
        state.extend_from_slice(&drawn);
        service.clear();
        for i in 0..self.n {
            // UNPLACED slots still draw, at the load a zero-load spare
            // assignment used to carry — the RNG stream (and thus every
            // other worker's time) is byte-identical whether or not a
            // submission places all n workers; `submit` simply never
            // queues the unplaced task.
            service.push(self.latency.sample(
                loads[i].max(0.0),
                state[i],
                self.burst_age[i],
                &mut self.rng,
            ));
        }
        for i in 0..self.n {
            self.burst_age[i] = if state[i] { self.burst_age[i] + 1 } else { 0 };
        }
        if let Some(st) = &self.storage {
            // all workers write their result concurrently near round end
            for f in service.iter_mut() {
                *f += st.sample(self.n, &mut self.rng);
            }
        }
    }

    /// Sample one *independent* round at the given per-worker loads: the
    /// raw one-shot sampler, bypassing the event queues (every worker
    /// idle at round start). Blocking drivers get exactly this through
    /// [`SyncAdapter`](super::SyncAdapter); it stays public for
    /// calibration and benches that want the bare latency law.
    pub fn sample_round(&mut self, loads: &[f64]) -> RoundSample {
        let mut finish = Vec::with_capacity(self.n);
        let mut state = Vec::with_capacity(self.n);
        self.sample_service_into(loads, &mut finish, &mut state);
        RoundSample { finish, state }
    }
}

impl EventCluster for SimCluster {
    fn n(&self) -> usize {
        self.n
    }

    fn now_s(&self) -> f64 {
        self.clock
    }

    fn submit(&mut self, job: JobId, round: u64, loads: &[f64]) {
        let mut service = std::mem::take(&mut self.service_scratch);
        let mut state = std::mem::take(&mut self.state_scratch);
        self.sample_service_into(loads, &mut service, &mut state);
        // record ground truth for `true_state` (reusing the job's buffer)
        let slot = self.states.entry(job).or_insert_with(|| (round, Vec::new()));
        slot.0 = round;
        slot.1.clear();
        slot.1.extend_from_slice(&state);
        if let Some(obs) = &self.obs {
            let stragglers = state.iter().filter(|&&s| s).count();
            obs.journal.record(
                self.clock,
                crate::obs::EventKind::TrueStragglers,
                job as i64,
                round as i64,
                -1,
                stragglers as f64,
            );
        }
        let clock = self.clock;
        // Chaos activation: advance the submission ordinal, restore
        // workers whose reconnect window just closed, then fire every
        // fault scripted for this ordinal. Runs strictly *after* the
        // service draws above so the RNG stream matches the fault-free
        // run byte for byte.
        if let Some(ch) = &mut self.chaos {
            ch.submissions += 1;
            let k = ch.submissions;
            for w in 0..self.n {
                if ch.rejoin_at[w] != 0 && k >= ch.rejoin_at[w] {
                    ch.rejoin_at[w] = 0;
                    ch.staged.push((clock, ClusterEvent::WorkerJoined { worker: w }));
                }
            }
            for fault in &ch.plan.faults {
                if fault.round != k {
                    continue;
                }
                let kind = fault.kind;
                for &victim in &fault.workers {
                    let w = victim % self.n;
                    if let Some(obs) = &self.obs {
                        obs.journal.record(
                            clock,
                            crate::obs::EventKind::ChaosFault,
                            -1,
                            k as i64,
                            w as i64,
                            f64::from(kind.discriminant()),
                        );
                    }
                    match kind {
                        FaultKind::Crash | FaultKind::Byzantine | FaultKind::Shrink => {
                            // The master observes the loss (socket drop /
                            // checksum reject): retire the slot and convert
                            // its in-flight tasks to deaths.
                            if !ch.dead[w] {
                                ch.dead[w] = true;
                                ch.staged.push((clock, ClusterEvent::WorkerRetired { worker: w }));
                                while let Some(t) = self.queues[w].pop_front() {
                                    ch.staged.push((
                                        clock,
                                        ClusterEvent::WorkerDead {
                                            job: t.job,
                                            round: t.round,
                                            worker: w,
                                        },
                                    ));
                                }
                            }
                        }
                        FaultKind::Reconnect => {
                            ch.rejoin_at[w] = k + ch.plan.reconnect_rounds;
                            ch.staged.push((clock, ClusterEvent::WorkerRetired { worker: w }));
                            while let Some(t) = self.queues[w].pop_front() {
                                ch.staged.push((
                                    clock,
                                    ClusterEvent::WorkerDead {
                                        job: t.job,
                                        round: t.round,
                                        worker: w,
                                    },
                                ));
                            }
                        }
                        FaultKind::Hang | FaultKind::Partition => {
                            // Silent loss: in-flight results vanish and
                            // only the timeout backstop closes the rounds.
                            if kind == FaultKind::Hang {
                                ch.hung[w] = true;
                            } else {
                                ch.silent_until[w] = k + ch.plan.partition_rounds;
                            }
                            let due = clock + ch.plan.sim_timeout_s;
                            while let Some(t) = self.queues[w].pop_front() {
                                stage_timeout(&mut ch.staged, due, t.job, t.round);
                            }
                        }
                    }
                }
            }
        }
        let mut silent_loss = false;
        for w in 0..self.n {
            let q = &mut self.queues[w];
            // Same-job preemption: the fresh assignment supersedes any
            // stale task of this job. If the stale task was at the head
            // it has (at least partially) run — the worker frees now.
            if q.iter().any(|t| t.job == job) {
                if matches!(q.front(), Some(t) if t.job == job) {
                    self.free_at[w] = self.free_at[w].max(clock);
                }
                q.retain(|t| t.job != job);
            }
            // An UNPLACED slot owes no task (and no completion event):
            // the stale-task preemption above still applies — a worker
            // that just migrated out of the job's placement drops the
            // superseded assignment — but nothing new is queued.
            if loads[w] >= 0.0 {
                match self.chaos.as_ref().map_or(Fate::Serve, |ch| ch.fate(w)) {
                    Fate::Serve => {
                        self.queues[w].push_back(SimTask {
                            job,
                            round,
                            submit_s: clock,
                            service_s: service[w],
                        });
                    }
                    Fate::Dead => {
                        let ch = self.chaos.as_mut().expect("fate came from the plan");
                        ch.staged.push((
                            clock,
                            ClusterEvent::WorkerDead { job, round, worker: w },
                        ));
                    }
                    Fate::Silent => silent_loss = true,
                }
            }
        }
        if silent_loss {
            let ch = self.chaos.as_mut().expect("silent loss implies chaos");
            let due = clock + ch.plan.sim_timeout_s;
            stage_timeout(&mut ch.staged, due, job, round);
        }
        self.service_scratch = service;
        self.state_scratch = state;
    }

    fn poll(&mut self, until_s: f64) -> &[ClusterEvent] {
        assert!(!until_s.is_nan(), "poll horizon must not be NaN");
        self.events_buf.clear();
        // Events at or before the current clock are always deliverable,
        // even when the caller's horizon lies in the past.
        let horizon = until_s.max(self.clock);
        // Earliest staged chaos event (membership changes, deaths,
        // timeout backstops).
        let mut earliest_staged = f64::INFINITY;
        if let Some(ch) = &self.chaos {
            for (due, _) in &ch.staged {
                if *due < earliest_staged {
                    earliest_staged = *due;
                }
            }
        }
        // Earliest head-of-queue completion across workers.
        let mut earliest = f64::INFINITY;
        for w in 0..self.n {
            if let Some(t) = self.queues[w].front() {
                let fin = self.free_at[w].max(t.submit_s) + t.service_s;
                if fin < earliest {
                    earliest = fin;
                }
            }
        }
        // Staged chaos events win ties with completions at the same
        // instant: membership changes and timeouts are what the round's
        // fate hangs on, and a fixed order keeps reruns byte-identical.
        if earliest_staged.is_finite() && earliest_staged <= horizon && earliest_staged <= earliest
        {
            self.clock = self.clock.max(earliest_staged);
            let cap = self.max_events_per_poll;
            let ch = self.chaos.as_mut().expect("staged events imply chaos");
            let mut i = 0;
            while i < ch.staged.len() {
                if self.events_buf.len() >= cap {
                    break; // rest of the tie delivered next call
                }
                if ch.staged[i].0 <= earliest_staged {
                    let (_, ev) = ch.staged.remove(i);
                    self.events_buf.push(ev);
                } else {
                    i += 1;
                }
            }
            return &self.events_buf;
        }
        if earliest <= horizon {
            self.clock = self.clock.max(earliest);
            let cap = self.max_events_per_poll;
            for w in 0..self.n {
                if self.events_buf.len() >= cap {
                    break; // rest of the tie delivered next call
                }
                if let Some(t) = self.queues[w].front() {
                    let fin = self.free_at[w].max(t.submit_s) + t.service_s;
                    if fin <= earliest {
                        let t = self.queues[w].pop_front().expect("head exists");
                        self.free_at[w] = fin;
                        self.events_buf.push(ClusterEvent::WorkerDone {
                            job: t.job,
                            round: t.round,
                            worker: w,
                            finish_s: fin - t.submit_s,
                        });
                    }
                }
            }
        } else if until_s.is_finite() && until_s > self.clock {
            self.clock = until_s;
        }
        &self.events_buf
    }

    fn true_state(&self, job: JobId, round: u64) -> Option<&[bool]> {
        self.states
            .get(&job)
            .and_then(|(r, s)| if *r == round { Some(s.as_slice()) } else { None })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::straggler::models::NoStragglers;

    #[test]
    fn uniform_loads_give_similar_times() {
        let mut c = SimCluster::new(
            16,
            LatencyParams::default(),
            Box::new(NoStragglers { n: 16 }),
            1,
        );
        let s = c.sample_round(&vec![0.05; 16]);
        let min = s.finish.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = s.finish.iter().cloned().fold(0.0, f64::max);
        assert!(max / min < 2.0, "no stragglers → tight spread, got {min}..{max}");
        assert!(s.state.iter().all(|&x| !x));
    }

    #[test]
    fn straggler_states_slow_down_workers() {
        // Alternate straggle/clear so worker 3 is a *fresh* straggler
        // each time (within-burst severity decay otherwise fades it).
        let strag_row = {
            let mut row = vec![false; 16];
            row[3] = true;
            row
        };
        let pat = Pattern::from_rows(vec![strag_row, vec![false; 16]]);
        let mut c = SimCluster::from_trace(16, pat, 2);
        let mut slow = 0.0;
        let mut fast = 0.0;
        for round in 0..50 {
            let s = c.sample_round(&vec![0.05; 16]);
            if round % 2 == 0 {
                slow += s.finish[3];
            } else {
                slow += 0.0;
            }
            fast += s.finish[4] / 2.0;
        }
        assert!(slow > 1.8 * fast, "straggler mean {slow} vs {fast}");
    }

    #[test]
    fn burst_severity_decays_with_age() {
        // A permanent straggler's completion times shrink towards normal.
        let pat = Pattern::from_rows(vec![{
            let mut row = vec![false; 8];
            row[0] = true;
            row
        }]);
        let mut c = SimCluster::from_trace(8, pat, 7);
        let mut early = 0.0;
        let mut late = 0.0;
        for round in 0..40 {
            let s = c.sample_round(&vec![0.05; 8]);
            if round == 0 {
                early = s.finish[0];
            }
            if round == 39 {
                late = s.finish[0];
            }
        }
        assert!(late < early, "decay must fade severity: {early} → {late}");
    }

    #[test]
    fn storage_adds_contention_delay() {
        let mk = |storage| {
            let mut c = SimCluster::new(
                64,
                LatencyParams::default(),
                Box::new(NoStragglers { n: 64 }),
                3,
            );
            if storage {
                c = c.with_storage(StorageParams::resnet18_efs());
            }
            let s = c.sample_round(&vec![0.01; 64]);
            crate::util::stats::mean(&s.finish)
        };
        assert!(mk(true) > mk(false) + 1.0);
    }

    /// Helper: drain every pending event.
    fn drain(c: &mut SimCluster) -> Vec<ClusterEvent> {
        let mut out = Vec::new();
        loop {
            let evs = c.poll(f64::INFINITY);
            if evs.is_empty() {
                break;
            }
            out.extend_from_slice(evs);
        }
        out
    }

    #[test]
    fn event_submission_matches_the_blocking_sampler() {
        let n = 8;
        let mk = || SimCluster::new(n, LatencyParams::default(), Box::new(NoStragglers { n }), 9);
        let loads = vec![0.05; n];
        let reference = mk().sample_round(&loads);

        let mut ev = mk();
        ev.submit(0, 1, &loads);
        assert_eq!(ev.true_state(0, 1), Some(&reference.state[..]));
        assert_eq!(ev.true_state(0, 2), None);
        let mut finish = vec![f64::NAN; n];
        for e in drain(&mut ev) {
            match e {
                ClusterEvent::WorkerDone { job: 0, round: 1, worker, finish_s } => {
                    finish[worker] = finish_s;
                }
                other => panic!("unexpected event {other:?}"),
            }
        }
        assert_eq!(finish, reference.finish, "idle-fleet events = raw service times");
    }

    #[test]
    fn busy_worker_delays_the_second_jobs_task() {
        let n = 4;
        let mut c =
            SimCluster::new(n, LatencyParams::default(), Box::new(NoStragglers { n }), 5);
        let loads = vec![0.1; n];
        c.submit(0, 1, &loads);
        c.submit(1, 1, &loads); // queued behind job 0 on every worker
        let evs = drain(&mut c);
        assert_eq!(evs.len(), 2 * n);
        let mut fin = [vec![0.0; n], vec![0.0; n]];
        for e in evs {
            if let ClusterEvent::WorkerDone { job, worker, finish_s, .. } = e {
                fin[job][worker] = finish_s;
            }
        }
        for w in 0..n {
            assert!(
                fin[1][w] > fin[0][w],
                "job 1 on worker {w} must wait out job 0: {} vs {}",
                fin[1][w],
                fin[0][w]
            );
        }
    }

    #[test]
    fn fresh_submission_preempts_the_same_jobs_stale_tasks() {
        let n = 3;
        let mut c =
            SimCluster::new(n, LatencyParams::default(), Box::new(NoStragglers { n }), 6);
        let loads = vec![0.1; n];
        c.submit(7, 1, &loads);
        c.submit(7, 2, &loads); // supersedes round 1 before anything ran
        let evs = drain(&mut c);
        assert_eq!(evs.len(), n, "round 1 tasks were preempted");
        assert!(evs
            .iter()
            .all(|e| matches!(e, ClusterEvent::WorkerDone { job: 7, round: 2, .. })));
    }

    #[test]
    fn poll_horizon_advances_the_clock_without_events() {
        let n = 2;
        let mut c =
            SimCluster::new(n, LatencyParams::default(), Box::new(NoStragglers { n }), 8);
        assert_eq!(c.now_s(), 0.0);
        assert!(c.poll(1.5).is_empty());
        assert_eq!(c.now_s(), 1.5);
        // an infinite horizon with nothing queued cannot advance
        assert!(c.poll(f64::INFINITY).is_empty());
        assert_eq!(c.now_s(), 1.5);
        // a submission's finish times are relative to the submit instant
        c.submit(0, 1, &[0.05, 0.05]);
        let evs = drain(&mut c);
        assert_eq!(evs.len(), 2);
        assert!(c.now_s() > 1.5);
    }

    #[test]
    fn unplaced_slots_owe_no_events_and_leave_the_rng_stream_intact() {
        use super::super::event::UNPLACED;
        let n = 4;
        let mk = || SimCluster::new(n, LatencyParams::default(), Box::new(NoStragglers { n }), 9);
        let mut all = mk();
        all.submit(0, 1, &vec![0.05; n]);
        let mut reference = vec![f64::NAN; n];
        for e in drain(&mut all) {
            if let ClusterEvent::WorkerDone { worker, finish_s, .. } = e {
                reference[worker] = finish_s;
            }
        }
        let mut part = mk();
        let mut loads = vec![0.05; n];
        loads[2] = UNPLACED;
        part.submit(0, 1, &loads);
        let evs = drain(&mut part);
        assert_eq!(evs.len(), n - 1, "unplaced slot owes no completion");
        for e in evs {
            if let ClusterEvent::WorkerDone { worker, finish_s, .. } = e {
                assert_ne!(worker, 2, "unplaced slot must not report");
                assert_eq!(
                    finish_s, reference[worker],
                    "skipping a slot must not shift the other workers' RNG draws"
                );
            }
        }
    }

    #[test]
    fn chaos_crash_retires_the_worker_and_converts_tasks_to_deaths() {
        use crate::chaos::ChaosPlan;
        let n = 4;
        let mut c =
            SimCluster::new(n, LatencyParams::default(), Box::new(NoStragglers { n }), 9);
        c.set_chaos(ChaosPlan::parse("crash@r2:w1", 7).unwrap().resolve(n));
        let loads = vec![0.05; n];
        c.submit(0, 1, &loads); // ordinal 1: healthy
        c.submit(5, 1, &loads); // ordinal 2: worker 1 crashes
        let evs = drain(&mut c);
        assert!(evs.iter().any(|e| matches!(e, ClusterEvent::WorkerRetired { worker: 1 })));
        // its in-flight ordinal-1 task converts to a death, and the
        // crashing submission is owed an immediate one
        assert!(evs
            .iter()
            .any(|e| matches!(e, ClusterEvent::WorkerDead { job: 0, round: 1, worker: 1 })));
        assert!(evs
            .iter()
            .any(|e| matches!(e, ClusterEvent::WorkerDead { job: 5, round: 1, worker: 1 })));
        let done_by_victim = evs
            .iter()
            .filter(|e| matches!(e, ClusterEvent::WorkerDone { worker: 1, .. }))
            .count();
        assert_eq!(done_by_victim, 0, "a crashed worker completes nothing");
        let dones =
            evs.iter().filter(|e| matches!(e, ClusterEvent::WorkerDone { .. })).count();
        assert_eq!(dones, 2 * n - 2, "every survivor still completes both rounds");
    }

    #[test]
    fn chaos_hang_raises_the_round_timeout_backstop() {
        use crate::chaos::ChaosPlan;
        let n = 3;
        let mut c =
            SimCluster::new(n, LatencyParams::default(), Box::new(NoStragglers { n }), 11);
        c.set_chaos(ChaosPlan::parse("hang@r1:w0", 7).unwrap().resolve(n));
        c.submit(2, 4, &vec![0.05; n]);
        let evs = drain(&mut c);
        let dones =
            evs.iter().filter(|e| matches!(e, ClusterEvent::WorkerDone { .. })).count();
        assert_eq!(dones, n - 1, "the hung worker never reports");
        assert!(
            !evs.iter().any(|e| matches!(e, ClusterEvent::WorkerDead { .. })),
            "a silent hang owes no death notice"
        );
        assert!(evs
            .iter()
            .any(|e| matches!(e, ClusterEvent::RoundTimeout { job: 2, round: 4 })));
        // the backstop fires sim_timeout_s after the submit instant
        assert!((c.now_s() - 8.0).abs() < 1e-9, "clock {}", c.now_s());
    }

    #[test]
    fn chaos_leaves_the_survivors_rng_stream_intact() {
        use crate::chaos::ChaosPlan;
        let n = 4;
        let mk = || SimCluster::new(n, LatencyParams::default(), Box::new(NoStragglers { n }), 9);
        let loads = vec![0.05; n];
        let mut plain = mk();
        plain.submit(0, 1, &loads);
        let mut reference = vec![f64::NAN; n];
        for e in drain(&mut plain) {
            if let ClusterEvent::WorkerDone { worker, finish_s, .. } = e {
                reference[worker] = finish_s;
            }
        }
        let mut chaotic = mk();
        chaotic.set_chaos(ChaosPlan::parse("crash@r1:w2", 7).unwrap().resolve(n));
        chaotic.submit(0, 1, &loads);
        for e in drain(&mut chaotic) {
            if let ClusterEvent::WorkerDone { worker, finish_s, .. } = e {
                assert_ne!(worker, 2, "the crashed worker must not report");
                assert_eq!(
                    finish_s, reference[worker],
                    "chaos must not shift the survivors' RNG draws"
                );
            }
        }
    }

    #[test]
    fn chaos_reconnect_rejoins_after_the_away_window() {
        use crate::chaos::ChaosPlan;
        let n = 2;
        let mut c =
            SimCluster::new(n, LatencyParams::default(), Box::new(NoStragglers { n }), 13);
        c.set_chaos(ChaosPlan::parse("reconnect@r1:w1", 7).unwrap().resolve(n));
        let loads = vec![0.05; n];
        c.submit(0, 1, &loads); // ordinal 1: worker 1 drops
        let evs = drain(&mut c);
        assert!(evs.iter().any(|e| matches!(e, ClusterEvent::WorkerRetired { worker: 1 })));
        assert!(evs
            .iter()
            .any(|e| matches!(e, ClusterEvent::WorkerDead { job: 0, round: 1, worker: 1 })));
        c.submit(0, 2, &loads); // ordinal 2: still away (window = 2)
        let evs = drain(&mut c);
        assert!(evs
            .iter()
            .any(|e| matches!(e, ClusterEvent::WorkerDead { job: 0, round: 2, worker: 1 })));
        assert!(!evs.iter().any(|e| matches!(e, ClusterEvent::WorkerJoined { .. })));
        c.submit(0, 3, &loads); // ordinal 3: window closed — rejoined
        let evs = drain(&mut c);
        assert!(evs.iter().any(|e| matches!(e, ClusterEvent::WorkerJoined { worker: 1 })));
        assert!(evs.iter().any(|e| matches!(e, ClusterEvent::WorkerDone { worker: 1, .. })));
    }

    #[test]
    fn event_batching_knob_splits_ties() {
        let n = 4;
        // Deterministic equal service times would need a degenerate
        // latency model; instead just check the cap bounds batch size.
        let mut c =
            SimCluster::new(n, LatencyParams::default(), Box::new(NoStragglers { n }), 4);
        c.set_max_events_per_poll(1);
        c.submit(0, 1, &vec![0.05; n]);
        let mut total = 0;
        loop {
            let evs = c.poll(f64::INFINITY);
            if evs.is_empty() {
                break;
            }
            assert!(evs.len() <= 1);
            total += evs.len();
        }
        assert_eq!(total, n);
    }
}
