//! Discrete-time serverless-cluster simulator.
//!
//! Substitutes for the paper's 256-worker AWS Lambda fleet: each round,
//! every worker gets a completion time from the latency model, with the
//! straggler process deciding which workers are in a slow state. The
//! master (coordinator) then applies the μ-rule on these times exactly as
//! the paper's master does on real response times.

use super::latency::LatencyParams;
use super::storage::StorageParams;
use crate::straggler::models::{GilbertElliot, StragglerProcess, TraceProcess};
use crate::straggler::Pattern;
use crate::util::rng::Pcg32;

/// Ground-truth outcome of one simulated round.
#[derive(Clone, Debug)]
pub struct RoundSample {
    /// Completion time (seconds from round start) per worker.
    pub finish: Vec<f64>,
    /// True straggler state per worker (the master never sees this; it is
    /// recorded for Fig.-1-style analysis).
    pub state: Vec<bool>,
}

/// The simulated cluster.
pub struct SimCluster {
    pub n: usize,
    pub latency: LatencyParams,
    pub storage: Option<StorageParams>,
    process: Box<dyn StragglerProcess>,
    rng: Pcg32,
    /// Consecutive straggling rounds per worker *before* the current one
    /// (drives within-burst severity decay).
    burst_age: Vec<usize>,
}

impl SimCluster {
    pub fn new(
        n: usize,
        latency: LatencyParams,
        process: Box<dyn StragglerProcess>,
        seed: u64,
    ) -> Self {
        assert_eq!(process.n(), n);
        SimCluster {
            n,
            latency,
            storage: None,
            process,
            rng: Pcg32::new(seed, 0xc105),
            burst_age: vec![0; n],
        }
    }

    /// Cluster driven by a Gilbert-Elliot straggler process with the
    /// Fig.-1 fit.
    pub fn from_gilbert_elliot(n: usize, ge: GilbertElliot, seed: u64) -> Self {
        Self::new(n, LatencyParams::default(), Box::new(ge), seed)
    }

    /// Cluster replaying a recorded straggler pattern.
    pub fn from_trace(n: usize, pattern: Pattern, seed: u64) -> Self {
        Self::new(n, LatencyParams::default(), Box::new(TraceProcess::new(pattern)), seed)
    }

    /// Attach a shared-storage model (Appendix L / Fig. 20 setup).
    pub fn with_storage(mut self, storage: StorageParams) -> Self {
        self.storage = Some(storage);
        self
    }

    /// Simulate one round at the given per-worker loads.
    pub fn sample_round(&mut self, loads: &[f64]) -> RoundSample {
        assert_eq!(loads.len(), self.n);
        let state = self.process.next_round();
        let mut finish: Vec<f64> = (0..self.n)
            .map(|i| self.latency.sample(loads[i], state[i], self.burst_age[i], &mut self.rng))
            .collect();
        for i in 0..self.n {
            self.burst_age[i] = if state[i] { self.burst_age[i] + 1 } else { 0 };
        }
        if let Some(st) = &self.storage {
            // all workers write their result concurrently near round end
            for f in finish.iter_mut() {
                *f += st.sample(self.n, &mut self.rng);
            }
        }
        RoundSample { finish, state }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::straggler::models::NoStragglers;

    #[test]
    fn uniform_loads_give_similar_times() {
        let mut c = SimCluster::new(
            16,
            LatencyParams::default(),
            Box::new(NoStragglers { n: 16 }),
            1,
        );
        let s = c.sample_round(&vec![0.05; 16]);
        let min = s.finish.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = s.finish.iter().cloned().fold(0.0, f64::max);
        assert!(max / min < 2.0, "no stragglers → tight spread, got {min}..{max}");
        assert!(s.state.iter().all(|&x| !x));
    }

    #[test]
    fn straggler_states_slow_down_workers() {
        // Alternate straggle/clear so worker 3 is a *fresh* straggler
        // each time (within-burst severity decay otherwise fades it).
        let strag_row = {
            let mut row = vec![false; 16];
            row[3] = true;
            row
        };
        let pat = Pattern::from_rows(vec![strag_row, vec![false; 16]]);
        let mut c = SimCluster::from_trace(16, pat, 2);
        let mut slow = 0.0;
        let mut fast = 0.0;
        for round in 0..50 {
            let s = c.sample_round(&vec![0.05; 16]);
            if round % 2 == 0 {
                slow += s.finish[3];
            } else {
                slow += 0.0;
            }
            fast += s.finish[4] / 2.0;
        }
        assert!(slow > 1.8 * fast, "straggler mean {slow} vs {fast}");
    }

    #[test]
    fn burst_severity_decays_with_age() {
        // A permanent straggler's completion times shrink towards normal.
        let pat = Pattern::from_rows(vec![{
            let mut row = vec![false; 8];
            row[0] = true;
            row
        }]);
        let mut c = SimCluster::from_trace(8, pat, 7);
        let mut early = 0.0;
        let mut late = 0.0;
        for round in 0..40 {
            let s = c.sample_round(&vec![0.05; 8]);
            if round == 0 {
                early = s.finish[0];
            }
            if round == 39 {
                late = s.finish[0];
            }
        }
        assert!(late < early, "decay must fade severity: {early} → {late}");
    }

    #[test]
    fn storage_adds_contention_delay() {
        let mk = |storage| {
            let mut c = SimCluster::new(
                64,
                LatencyParams::default(),
                Box::new(NoStragglers { n: 64 }),
                3,
            );
            if storage {
                c = c.with_storage(StorageParams::resnet18_efs());
            }
            let s = c.sample_round(&vec![0.01; 64]);
            crate::util::stats::mean(&s.finish)
        };
        assert!(mk(true) > mk(false) + 1.0);
    }
}
