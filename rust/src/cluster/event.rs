//! Event-driven execution backend API: the multi-job successor of the
//! blocking [`Cluster`] trait.
//!
//! A [`Cluster`] serves exactly one session and one round at a time —
//! `sample_round` blocks until every worker's completion time is known.
//! [`EventCluster`] inverts that: any number of `(job, round)` task sets
//! can be in flight at once, and the backend *streams* per-worker
//! completions back as [`ClusterEvent`]s. This is what lets one shared
//! fleet execute many SGC sessions concurrently (the paper's multi-model
//! headline experiment) with real cross-job contention: a worker busy on
//! job A delays its job-B task instead of being sampled independently
//! per session.
//!
//! The driving loop (see [`crate::sched::JobScheduler`]):
//!
//! ```text
//! cluster.submit(job, round, loads)        // fan a round's tasks out
//! loop {
//!     for ev in cluster.poll(until_s) {    // stream arrivals back
//!         match ev {
//!             WorkerDone { .. } => session.submit(..),
//!             WorkerDead { .. } | RoundTimeout { .. } => ..,
//!         }
//!     }
//!     session.try_close_round(now) ..      // μ-rule on the event stream
//! }
//! ```
//!
//! The old blocking trait is kept as a thin bridge: [`SyncAdapter`]
//! implements [`Cluster`] on top of *any* [`EventCluster`] by submitting
//! one round and draining events until all `n` workers have reported —
//! so every existing single-session caller (`session::drive`, trace
//! recording, the probe) keeps working, while each backend implements
//! exactly one execution protocol.

use super::sim::RoundSample;
use super::Cluster;

/// Identifies one admitted session within a multi-job backend.
pub type JobId = usize;

/// The job id [`SyncAdapter`] submits under (reserved; schedulers number
/// their jobs from 0).
pub const SYNC_JOB: JobId = usize::MAX;

/// Sentinel load marking a worker slot as *not part of a submission*:
/// the worker is a spare (or a retired slot) outside the submitting
/// job's placement. Backends skip these slots entirely — no task is
/// queued, no frame is sent, no completion event is owed — which is
/// what keeps wide spare pools (cluster capacity ≫ scheme `n`) free of
/// per-round no-op traffic. Distinct from a genuine `0.0` load, which
/// some schemes legitimately assign (an M-SGC no-op round slot still
/// reports back). Any negative load is treated as unplaced; this
/// constant is the canonical spelling.
pub const UNPLACED: f64 = -1.0;

/// One streamed backend event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ClusterEvent {
    /// A worker finished its `(job, round)` task. `finish_s` is seconds
    /// from that submission's start — exactly what
    /// [`SgcSession::submit`](crate::session::SgcSession::submit) wants —
    /// and includes any queueing delay behind other jobs' tasks.
    WorkerDone { job: JobId, round: u64, worker: usize, finish_s: f64 },
    /// A worker can *permanently* no longer produce a result for
    /// `(job, round)`: its connection dropped, it returned a byzantine
    /// result, or it was already unusable when the round was assigned.
    /// Recoverable conditions (a stale heartbeat on a loaded box) are
    /// deliberately not reported — the backend's round-timeout backstop
    /// covers a stall that never recovers. Simulated backends never emit
    /// this.
    WorkerDead { job: JobId, round: u64, worker: usize },
    /// `(job, round)` exceeded the backend's hard per-round wall-clock
    /// cap with results still missing. Emitted at most once per
    /// submission; harmless for rounds the driver already closed.
    RoundTimeout { job: JobId, round: u64 },
    /// A worker was admitted into the live roster after startup
    /// (elastic membership): a fresh id grows [`EventCluster::n`], a
    /// reclaimed id revives a retired slot. Schedulers fold the worker
    /// into their placement spare set. Backends with fixed membership
    /// (simulators, trace replays) never emit this.
    WorkerJoined {
        /// Physical worker-slot id that joined.
        worker: usize,
    },
    /// A worker left the roster permanently: its socket dropped, it
    /// went byzantine, or its heartbeats stayed silent past the
    /// backend's reap deadline. Per-submission `WorkerDead` events for
    /// everything it still owed accompany this; schedulers additionally
    /// re-place the worker's logical slots onto live spares at the next
    /// round start. Backends with fixed membership never emit this.
    WorkerRetired {
        /// Physical worker-slot id that retired.
        worker: usize,
    },
}

/// Event-driven execution backend: accepts task sets for many `(job,
/// round)` pairs concurrently and streams per-worker completions.
///
/// Implementations in-tree: [`SimCluster`](super::SimCluster) (virtual
/// clock, per-worker FIFO queues — real cross-job contention),
/// [`TraceReplayCluster`](super::TraceReplayCluster) (recorded delay
/// matrix, one row per submission) and
/// [`FleetCluster`](crate::fleet::FleetCluster) (live TCP workers, wall
/// clock).
pub trait EventCluster {
    /// Number of worker slots `n` — the length [`submit`](Self::submit)
    /// expects of its `loads`. Fixed-membership backends keep this
    /// constant; an elastic backend grows it when a worker joins under a
    /// fresh id (after staging [`ClusterEvent::WorkerJoined`]) and never
    /// shrinks it (retired slots stay addressable).
    fn n(&self) -> usize;

    /// Current cluster clock in seconds since the cluster started:
    /// virtual (advanced by [`poll`](Self::poll)) for simulators, wall
    /// time for real fleets.
    fn now_s(&self) -> f64;

    /// Fan one round's tasks out: worker `i` receives normalized load
    /// `loads[i]` for `(job, round)`, starting no earlier than the
    /// current clock (and, under contention, no earlier than the worker
    /// finishing its queued work). `(job, round)` must be unique among
    /// in-flight submissions; `loads.len()` must equal
    /// [`n`](Self::n).
    ///
    /// A `loads[i]` of [`UNPLACED`] (any negative value) marks worker
    /// `i` as outside this submission: the backend must skip the slot
    /// entirely — no task queued, no frame sent, no `WorkerDone` or
    /// `WorkerDead` owed for it. A `0.0` load, by contrast, is a real
    /// (no-op) assignment that reports back like any other.
    ///
    /// Submitting a later round of a job whose earlier tasks are still
    /// queued *preempts* those tasks on simulated backends — the master
    /// only re-assigns a worker after cutting it from the previous
    /// round, so the fresh assignment supersedes the stale one. Tasks of
    /// *other* jobs are never preempted; they queue FIFO.
    fn submit(&mut self, job: JobId, round: u64, loads: &[f64]);

    /// Deliver pending events with timestamps up to `until_s` (absolute,
    /// same axis as [`now_s`](Self::now_s)).
    ///
    /// Contract:
    /// * a *simulated* clock never advances past `until_s`, and never
    ///   past an undelivered event — after a non-empty return, `now_s()`
    ///   equals the delivered events' timestamp. Wall-clock backends
    ///   treat the horizon as a sleep bound only (real time keeps
    ///   flowing);
    /// * a call may return a *partial* batch (or, for wall-clock
    ///   backends, an empty one at an implementation-defined heartbeat
    ///   pace before `until_s`); callers loop until they have what they
    ///   need;
    /// * with nothing in flight and a finite `until_s`, the clock
    ///   advances to `until_s` and the slice is empty.
    fn poll(&mut self, until_s: f64) -> &[ClusterEvent];

    /// Ground-truth straggler states of a submission, when the backend
    /// knows them (simulators and trace replays do; a real fleet returns
    /// `None`). Valid at least until the next `submit` for the same job.
    fn true_state(&self, job: JobId, round: u64) -> Option<&[bool]>;

    /// Wrap this backend in the blocking [`SyncAdapter`] bridge (one
    /// round in flight, wait for all `n` results). Borrow-friendly:
    /// `SyncAdapter::new(&mut backend)` works too, via the `&mut E`
    /// blanket impl.
    fn sync(self) -> SyncAdapter<Self>
    where
        Self: Sized,
    {
        SyncAdapter::new(self)
    }
}

impl<E: EventCluster + ?Sized> EventCluster for &mut E {
    fn n(&self) -> usize {
        (**self).n()
    }

    fn now_s(&self) -> f64 {
        (**self).now_s()
    }

    fn submit(&mut self, job: JobId, round: u64, loads: &[f64]) {
        (**self).submit(job, round, loads)
    }

    fn poll(&mut self, until_s: f64) -> &[ClusterEvent] {
        (**self).poll(until_s)
    }

    fn true_state(&self, job: JobId, round: u64) -> Option<&[bool]> {
        (**self).true_state(job, round)
    }
}

/// Blocking bridge: drives an [`EventCluster`] through the classic
/// [`Cluster`] protocol — submit one round, drain events until every
/// worker reported, return the dense [`RoundSample`].
///
/// Because simulated backends start a submission's tasks on idle workers
/// (the previous round fully drained first), the sample equals what the
/// backend's pre-event blocking implementation produced — byte for byte,
/// RNG draw for RNG draw — which is what keeps `tests/golden.rs` and
/// trace replays pinned across the API redesign.
///
/// The blocking protocol has no error channel, so a dead worker or a
/// round timeout panics here (exactly like the old blocking fleet
/// implementation); fallible paths should drive the event API via
/// [`crate::sched::JobScheduler`] instead.
pub struct SyncAdapter<E: EventCluster> {
    inner: E,
    rounds: u64,
}

impl<E: EventCluster> SyncAdapter<E> {
    /// Wrap an event backend in the blocking bridge.
    pub fn new(inner: E) -> Self {
        SyncAdapter { inner, rounds: 0 }
    }

    /// The wrapped backend.
    pub fn get_ref(&self) -> &E {
        &self.inner
    }

    /// Mutable access to the wrapped backend.
    pub fn get_mut(&mut self) -> &mut E {
        &mut self.inner
    }

    /// Unwrap, returning the backend.
    pub fn into_inner(self) -> E {
        self.inner
    }
}

impl<E: EventCluster> Cluster for SyncAdapter<E> {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn sample_round(&mut self, loads: &[f64]) -> RoundSample {
        let n = self.inner.n();
        assert_eq!(loads.len(), n, "loads length mismatch");
        self.rounds += 1;
        let round = self.rounds;
        self.inner.submit(SYNC_JOB, round, loads);
        // One allocation per blocking round is inherent: the buffer is
        // handed to the caller inside the returned RoundSample.
        let mut finish = vec![f64::NAN; n];
        let mut missing = n;
        let mut stalls = 0u32;
        while missing > 0 {
            let before = self.inner.now_s();
            let events = self.inner.poll(f64::INFINITY);
            if events.is_empty() {
                // A wall-clock backend legitimately returns empty at its
                // heartbeat pace (time advanced); a simulator with no
                // pending events can never make progress — fail loudly
                // instead of spinning forever.
                stalls = if self.inner.now_s() > before { 0 } else { stalls + 1 };
                assert!(
                    stalls < 1000,
                    "SyncAdapter: backend made no progress with {missing} results missing"
                );
                continue;
            }
            stalls = 0;
            for ev in events {
                match *ev {
                    ClusterEvent::WorkerDone { job, round: r, worker, finish_s }
                        if job == SYNC_JOB && r == round =>
                    {
                        if finish[worker].is_nan() {
                            finish[worker] = finish_s;
                            missing -= 1;
                        }
                    }
                    ClusterEvent::WorkerDone { .. } => {} // stale round: ignore
                    ClusterEvent::WorkerDead { worker, .. } => {
                        panic!("worker {worker} died during a blocking round")
                    }
                    ClusterEvent::RoundTimeout { job, round: r }
                        if job == SYNC_JOB && r == round =>
                    {
                        panic!("blocking round {round} timed out")
                    }
                    ClusterEvent::RoundTimeout { .. } => {}
                    // membership churn is a scheduler concern; the
                    // blocking bridge pins one fixed round and ignores it
                    // (a death that matters surfaces as WorkerDead above)
                    ClusterEvent::WorkerJoined { .. }
                    | ClusterEvent::WorkerRetired { .. } => {}
                }
            }
        }
        let state = match self.inner.true_state(SYNC_JOB, round) {
            Some(s) => s.to_vec(),
            None => vec![false; n],
        };
        RoundSample { finish, state }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal scripted backend for adapter tests.
    struct Scripted {
        n: usize,
        clock: f64,
        pending: Vec<ClusterEvent>,
        buf: Vec<ClusterEvent>,
        state: Vec<bool>,
    }

    impl EventCluster for Scripted {
        fn n(&self) -> usize {
            self.n
        }

        fn now_s(&self) -> f64 {
            self.clock
        }

        fn submit(&mut self, job: JobId, round: u64, loads: &[f64]) {
            assert_eq!(loads.len(), self.n);
            // finish in reverse worker order, one second apart
            for w in 0..self.n {
                self.pending.push(ClusterEvent::WorkerDone {
                    job,
                    round,
                    worker: w,
                    finish_s: (self.n - w) as f64,
                });
            }
        }

        fn poll(&mut self, until_s: f64) -> &[ClusterEvent] {
            self.buf.clear();
            if let Some(ev) = self.pending.first().copied() {
                let t = match ev {
                    ClusterEvent::WorkerDone { finish_s, .. } => self.clock + finish_s,
                    _ => self.clock,
                };
                if t <= until_s {
                    self.pending.remove(0);
                    self.buf.push(ev);
                }
            }
            &self.buf
        }

        fn true_state(&self, _job: JobId, _round: u64) -> Option<&[bool]> {
            Some(&self.state)
        }
    }

    #[test]
    fn sync_adapter_collects_all_workers() {
        let scripted = Scripted {
            n: 3,
            clock: 0.0,
            pending: Vec::new(),
            buf: Vec::new(),
            state: vec![false, true, false],
        };
        let mut sync = scripted.sync();
        let sample = sync.sample_round(&[0.1, 0.1, 0.1]);
        assert_eq!(sample.finish, vec![3.0, 2.0, 1.0]);
        assert_eq!(sample.state, vec![false, true, false]);
        assert_eq!(Cluster::n(&sync), 3);
    }

    #[test]
    #[should_panic(expected = "no progress")]
    fn sync_adapter_detects_a_stalled_backend() {
        struct Stalled;
        impl EventCluster for Stalled {
            fn n(&self) -> usize {
                1
            }
            fn now_s(&self) -> f64 {
                0.0
            }
            fn submit(&mut self, _: JobId, _: u64, _: &[f64]) {}
            fn poll(&mut self, _: f64) -> &[ClusterEvent] {
                &[]
            }
            fn true_state(&self, _: JobId, _: u64) -> Option<&[bool]> {
                None
            }
        }
        Stalled.sync().sample_round(&[0.1]);
    }

    #[test]
    fn mut_ref_delegation_works() {
        let mut scripted = Scripted {
            n: 2,
            clock: 0.0,
            pending: Vec::new(),
            buf: Vec::new(),
            state: vec![false; 2],
        };
        // borrow — the backend stays usable afterwards
        let mut sync = SyncAdapter::new(&mut scripted);
        let sample = sync.sample_round(&[0.5, 0.5]);
        assert_eq!(sample.finish.len(), 2);
        assert_eq!(scripted.pending.len(), 0);
    }
}
