//! Shared-storage (EFS) delay model — Appendix L.
//!
//! When task results exceed the Lambda 6 MB payload limit (ResNet-18
//! gradients are ~22.5 MB), workers write them to a shared file system
//! whose aggregate write throughput is limited; concurrent writers divide
//! the bandwidth. This fattens the completion-time tail (Fig. 19(b)) and
//! forces a larger μ.

use crate::util::rng::Pcg32;

/// Shared storage bandwidth model.
#[derive(Clone, Debug)]
pub struct StorageParams {
    /// Payload each worker writes per round, MB.
    pub payload_mb: f64,
    /// Aggregate write bandwidth of the file system, MB/s.
    pub aggregate_bw_mb_s: f64,
    /// Per-client cap, MB/s.
    pub per_client_bw_mb_s: f64,
    /// Fixed metadata/open latency per write, seconds.
    pub op_latency_s: f64,
    /// Lognormal sigma on the effective write time (burst credits,
    /// contention noise).
    pub jitter_sigma: f64,
}

impl StorageParams {
    /// Appendix-L configuration: ResNet-18 fp16 gradients over EFS.
    pub fn resnet18_efs() -> Self {
        StorageParams {
            payload_mb: 22.5,
            aggregate_bw_mb_s: 1024.0,
            per_client_bw_mb_s: 35.0,
            op_latency_s: 0.08,
            jitter_sigma: 0.45,
        }
    }

    /// Expected write delay with `concurrent` simultaneous writers.
    pub fn mean_delay(&self, concurrent: usize) -> f64 {
        let fair = self.aggregate_bw_mb_s / concurrent.max(1) as f64;
        let bw = fair.min(self.per_client_bw_mb_s);
        self.op_latency_s + self.payload_mb / bw
    }

    /// Sample a write delay.
    pub fn sample(&self, concurrent: usize, rng: &mut Pcg32) -> f64 {
        let mean = self.mean_delay(concurrent);
        self.op_latency_s + (mean - self.op_latency_s) * rng.lognormal(0.0, self.jitter_sigma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contention_raises_delay() {
        let s = StorageParams::resnet18_efs();
        assert!(s.mean_delay(256) > s.mean_delay(8));
        // 256 writers share 1 GB/s → 4 MB/s each → 22.5/4 + op ≈ 5.7 s
        let d = s.mean_delay(256);
        assert!((5.0..7.0).contains(&d), "delay {d}");
    }

    #[test]
    fn per_client_cap_binds_at_low_concurrency() {
        let s = StorageParams::resnet18_efs();
        let d1 = s.mean_delay(1);
        let d4 = s.mean_delay(4);
        assert!((d1 - d4).abs() < 1e-9, "cap should bind for both");
    }

    #[test]
    fn samples_have_heavy_spread() {
        let s = StorageParams::resnet18_efs();
        let mut rng = Pcg32::seeded(4);
        let xs: Vec<f64> = (0..5000).map(|_| s.sample(256, &mut rng)).collect();
        let mean = crate::util::stats::mean(&xs);
        let p95 = crate::util::stats::percentile(&xs, 95.0);
        assert!(p95 / mean > 1.5, "tail too thin: p95/mean = {}", p95 / mean);
    }
}
