//! Worker completion-time model for the serverless-cluster simulator.
//!
//! Substitutes for the paper's AWS Lambda fleet (Appendix H). A worker's
//! response time in a round decomposes as
//!
//! ```text
//! t = overhead + α · load · (1 + jitter) + straggle_extra + storage
//! ```
//!
//! * `overhead` — HTTP invocation + runtime init, lognormal (long tail,
//!   Fig. 1(c)).
//! * `α · load` — gradient compute, linear in normalized load (the Fig. 16
//!   observation that parameter selection exploits).
//! * `straggle_extra` — a multiplicative slowdown drawn from a Pareto
//!   tail while the worker's Gilbert-Elliot state is "straggler".
//! * `storage` — optional shared-storage (EFS) write delay, Appendix L.
//!
//! Defaults are calibrated so that the Table-1 workload (n = 256,
//! J = 480) lands in the paper's runtime regime (~1-3 s rounds).

use crate::util::rng::Pcg32;

/// Parameters of the per-worker latency model.
#[derive(Clone, Debug)]
pub struct LatencyParams {
    /// Median invocation/runtime overhead in seconds.
    pub overhead_median_s: f64,
    /// Lognormal sigma of the overhead.
    pub overhead_sigma: f64,
    /// Compute seconds per unit normalized load (slope of Fig. 16).
    pub alpha_s_per_load: f64,
    /// Relative jitter std-dev on the compute term.
    pub compute_jitter: f64,
    /// Pareto shape of the straggler slowdown multiplier (smaller =
    /// heavier tail).
    pub straggle_shape: f64,
    /// Minimum straggler slowdown multiplier (> 1 + μ so the μ-rule
    /// detects model-state stragglers reliably).
    pub straggle_scale: f64,
    /// Within-burst severity decay: a worker in its `age`-th consecutive
    /// slow round has its slowdown shrunk as `1 + (u-1)·decay^age`.
    /// Lambda contention transients fade — this is what makes the paper's
    /// observed bursts "short and isolated" (Fig. 1(b)) and wait-outs for
    /// burst continuers cheap (Table 1's No-Coding column is only ~23%
    /// above GC, so even full straggler waits are mild).
    pub straggle_decay: f64,
}

impl Default for LatencyParams {
    fn default() -> Self {
        LatencyParams {
            overhead_median_s: 0.85,
            overhead_sigma: 0.11,
            alpha_s_per_load: 9.5,
            compute_jitter: 0.06,
            // Calibrated against Table 1's own arithmetic: "No Coding"
            // (which waits for *every* straggler each round) is only ~23%
            // slower than GC, so straggler completions sit at ~2.5-3.5×
            // the fastest worker — a mild Pareto tail, not a heavy one.
            straggle_shape: 6.5,
            straggle_scale: 2.1,
            straggle_decay: 0.68,
        }
    }
}

/// Within-burst decayed slowdown multiplier: a worker in its `age`-th
/// consecutive slow round stretches by `1 + (raw - 1)·decay^age`. Shared
/// by the simulator's latency model and the fleet's chaos injection
/// ([`crate::fleet::ChaosConfig`]) so the two stay one process.
pub fn decayed_uplift(raw: f64, decay: f64, burst_age: usize) -> f64 {
    1.0 + (raw - 1.0) * decay.powi(burst_age as i32)
}

impl LatencyParams {
    /// Expected *non-straggler* completion time at a given load (used by
    /// the Appendix-J load-adjustment rule).
    pub fn mean_time(&self, load: f64) -> f64 {
        let overhead =
            self.overhead_median_s * (0.5 * self.overhead_sigma * self.overhead_sigma).exp();
        overhead + self.alpha_s_per_load * load
    }

    /// Sample a completion time. `burst_age` is the number of consecutive
    /// straggling rounds *before* this one (0 = fresh straggler).
    pub fn sample(&self, load: f64, straggling: bool, burst_age: usize, rng: &mut Pcg32) -> f64 {
        let overhead = rng.lognormal(self.overhead_median_s.ln(), self.overhead_sigma);
        let compute = self.alpha_s_per_load * load * (1.0 + self.compute_jitter * rng.normal());
        let base = overhead + compute.max(0.0);
        if straggling {
            let raw = rng.pareto(self.straggle_scale, self.straggle_shape);
            base * decayed_uplift(raw, self.straggle_decay, burst_age)
        } else {
            base
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_scales_linearly_with_load() {
        let p = LatencyParams::default();
        let mut rng = Pcg32::seeded(1);
        let avg = |load: f64, rng: &mut Pcg32| {
            (0..4000).map(|_| p.sample(load, false, 0, rng)).sum::<f64>() / 4000.0
        };
        let t0 = avg(0.0, &mut rng);
        let t1 = avg(0.5, &mut rng);
        let t2 = avg(1.0, &mut rng);
        // linear: t1 ≈ (t0 + t2) / 2
        let mid = (t0 + t2) / 2.0;
        assert!((t1 - mid).abs() / mid < 0.05, "t1={t1} mid={mid}");
        // slope ≈ alpha
        assert!(((t2 - t0) - p.alpha_s_per_load).abs() < 0.5);
    }

    #[test]
    fn stragglers_are_separably_slower() {
        let p = LatencyParams::default();
        let mut rng = Pcg32::seeded(2);
        let load = 0.06;
        let normal: Vec<f64> = (0..2000).map(|_| p.sample(load, false, 0, &mut rng)).collect();
        let strag: Vec<f64> = (0..2000).map(|_| p.sample(load, true, 0, &mut rng)).collect();
        // μ = 1 rule: stragglers must mostly exceed 2× the fastest worker
        let fastest = normal.iter().cloned().fold(f64::INFINITY, f64::min);
        let detected =
            strag.iter().filter(|&&t| t > 2.0 * fastest).count() as f64 / strag.len() as f64;
        assert!(detected > 0.95, "detected {detected}");
        // medians are far apart
        let med = |xs: &[f64]| crate::util::stats::percentile(xs, 50.0);
        assert!(med(&strag) > 2.0 * med(&normal));
    }

    #[test]
    fn mean_time_tracks_samples() {
        let p = LatencyParams::default();
        let mut rng = Pcg32::seeded(3);
        let emp = (0..20000).map(|_| p.sample(0.25, false, 0, &mut rng)).sum::<f64>() / 20000.0;
        assert!((emp - p.mean_time(0.25)).abs() / emp < 0.03, "emp {emp} vs {}", p.mean_time(0.25));
    }
}
