//! Serverless-cluster substrate: latency model, shared-storage model and
//! the discrete-time simulator standing in for the paper's AWS Lambda
//! fleet (Appendices H and L).

pub mod latency;
pub mod sim;
pub mod storage;
pub mod trace;

pub use latency::LatencyParams;
pub use sim::{RoundSample, SimCluster};
pub use storage::StorageParams;
pub use trace::{RecordingCluster, RunTrace, TraceReplayCluster};

/// The unified execution backend the session drivers pump rounds
/// through: the stochastic simulator ([`SimCluster`]), trace/profile
/// replay ([`crate::probe::ProfileCluster`], [`SimCluster::from_trace`],
/// [`TraceReplayCluster`]), a real-compute thread pool, or the live TCP
/// fleet ([`crate::fleet::FleetCluster`]). Backends only turn per-worker
/// loads into per-worker completion times; every protocol decision stays
/// in [`crate::session::SgcSession`].
pub trait Cluster {
    fn n(&self) -> usize;

    /// Execute one round at the given per-worker normalized loads and
    /// report per-worker completion times.
    fn sample_round(&mut self, loads: &[f64]) -> RoundSample;
}

impl Cluster for SimCluster {
    fn n(&self) -> usize {
        self.n
    }

    fn sample_round(&mut self, loads: &[f64]) -> RoundSample {
        SimCluster::sample_round(self, loads)
    }
}
