//! Serverless-cluster substrate: latency model, shared-storage model,
//! the discrete-time simulator standing in for the paper's AWS Lambda
//! fleet (Appendices H and L), and the event-driven multi-job backend
//! API ([`EventCluster`]) every execution backend implements natively.

pub mod event;
pub mod latency;
pub mod sim;
pub mod storage;
pub mod trace;

pub use event::{ClusterEvent, EventCluster, JobId, SyncAdapter, SYNC_JOB, UNPLACED};
pub use latency::LatencyParams;
pub use sim::{RoundSample, SimCluster};
pub use storage::StorageParams;
pub use trace::{RecordingCluster, RunTrace, TraceReplayCluster};

/// The classic blocking backend protocol: one session, one round at a
/// time, all `n` completion times at once.
///
/// Execution backends ([`SimCluster`], [`TraceReplayCluster`],
/// [`crate::fleet::FleetCluster`]) implement the event-driven
/// [`EventCluster`] natively; this trait survives as the single-session
/// bridge over it — wrap any event backend in [`SyncAdapter`] (or call
/// [`EventCluster::sync`]) to drive it through the blocking drivers
/// ([`crate::session::drive`], [`RecordingCluster`], the probe). Pure
/// replayers with no multi-job semantics
/// ([`crate::probe::ProfileCluster`], [`RecordingCluster`]) implement it
/// directly. Backends only turn per-worker loads into per-worker
/// completion times; every protocol decision stays in
/// [`crate::session::SgcSession`].
pub trait Cluster {
    /// Number of workers.
    fn n(&self) -> usize;

    /// Execute one round at the given per-worker normalized loads and
    /// report per-worker completion times.
    fn sample_round(&mut self, loads: &[f64]) -> RoundSample;
}
