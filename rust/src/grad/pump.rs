//! The decode side of the gradient data plane: a [`RoundObserver`]
//! that folds worker payloads at every round close, numerically decodes
//! each paper job the session reports complete, audits the code's
//! redundancy for byzantine payloads, and steps Adam.
//!
//! This is the fleet twin of `train::trainer`'s `TrainPump`: the same
//! coefficient and β-decode logic, but the per-chunk gradients arrive
//! over TCP as coded payloads instead of being computed locally, so the
//! pump never touches the dataset on the hot path — only for audits.

use crate::cluster::JobId;
use crate::coding::{CodePlanCache, Scheme, SchemeConfig, SchemeKind};
use crate::fleet::wire::GradUnit;
use crate::grad::dataplane::{ChunkData, FoldUnit, RoundEntry, SharedDataPlane};
use crate::grad::mlp;
use crate::runtime::ModelDims;
use crate::sched::RoundObserver;
use crate::session::{RoundPlan, SessionEvent, SgcSession};
use crate::train::{Adam, Dataset, DatasetConfig};
use crate::util::rng::Pcg32;
use anyhow::Result;
use std::collections::{HashMap, HashSet};

/// Relative tolerance before two decodes of the same group are called
/// inconsistent (triggers a payload audit).
const CONSISTENCY_RTOL: f32 = 1e-3;

/// Configuration of the real-gradient path for one scheduler job.
#[derive(Clone, Debug)]
pub struct GradConfig {
    /// Model shapes; `chunk` is recomputed from the batch split.
    pub dims: ModelDims,
    /// Fixed batch the job trains on (full-batch GD per paper job, so
    /// decoded gradients are reproducible round over round).
    pub batch: usize,
    /// Adam learning rate.
    pub lr: f32,
    /// Root seed for data generation, batch choice and init.
    pub seed: u64,
    /// Dataset noise level.
    pub noise: f64,
    /// Generated corpus size.
    pub train_size: usize,
}

impl Default for GradConfig {
    fn default() -> Self {
        GradConfig {
            dims: ModelDims { input: 64, classes: 10, hidden1: 64, hidden2: 32, chunk: 0 },
            batch: 256,
            lr: 2e-3,
            seed: 7,
            noise: 0.8,
            train_size: 2048,
        }
    }
}

/// One coded result retained until its paper job decodes.
#[derive(Clone, Debug)]
struct CodedResult {
    /// Encoding-matrix row (logical worker).
    row: usize,
    /// The coded payload segment, flat.
    ell: Vec<f32>,
    /// Physical seat that produced it (for flagging).
    physical: usize,
    /// Parameter version it was computed against.
    version: u32,
    /// `(chunk, coefficient)` terms the worker was told to apply.
    terms: Vec<(u32, f64)>,
}

/// Accumulated contributions of one paper job.
#[derive(Debug, Default)]
struct PaperState {
    plain: Option<Vec<f32>>,
    delivered_chunks: HashSet<usize>,
    coded: HashMap<usize, Vec<CodedResult>>,
}

/// Per-scheduler-job pump state.
struct PumpJob {
    dims: ModelDims,
    params: Vec<Vec<f32>>,
    opt: Adam,
    paper: HashMap<usize, PaperState>,
    /// Full-batch loss after each decode (index 0 = at init).
    losses: Vec<f64>,
    decoded: usize,
    /// Logical rows caught corrupting payloads.
    flagged_rows: HashSet<usize>,
    audits: usize,
    fallback_decodes: usize,
}

/// Loss trajectory and decode counters of one job, for reports.
#[derive(Clone, Debug)]
pub struct GradJobSummary {
    /// Scheduler job id.
    pub job: JobId,
    /// Optimizer steps taken (paper jobs decoded).
    pub steps: usize,
    /// Full-batch loss at initialization.
    pub first_loss: f64,
    /// Full-batch loss after the last decode.
    pub last_loss: f64,
    /// Loss after every decode (index 0 = at init).
    pub losses: Vec<f64>,
    /// Payload audits triggered by inconsistent decodes.
    pub audits: usize,
    /// Decodes that fell back to a master-computed reference gradient.
    pub fallback_decodes: usize,
}

/// The real-gradient decode observer (see module docs).
pub struct GradPump {
    dp: SharedDataPlane,
    cfg: GradConfig,
    jobs: HashMap<JobId, PumpJob>,
}

impl GradPump {
    /// A pump folding payloads out of `dp`.
    pub fn new(dp: SharedDataPlane, cfg: GradConfig) -> Self {
        GradPump { dp, cfg, jobs: HashMap::new() }
    }

    /// The shared data plane this pump decodes from.
    pub fn dataplane(&self) -> SharedDataPlane {
        std::sync::Arc::clone(&self.dp)
    }

    /// Opt scheduler job `job` into the real-gradient path: generate its
    /// dataset, shard the fixed batch into the scheme's chunks, install
    /// partitions + initial params into the data plane.
    pub fn configure_job(&mut self, job: JobId, scheme: &SchemeConfig) -> Result<()> {
        let rep = matches!(
            scheme.kind,
            SchemeKind::GcRep { .. } | SchemeKind::SrSgcRep { .. } | SchemeKind::MSgcRep { .. }
        );
        let (dims, chunks, params) = build_job(&self.cfg, job, scheme);
        let first_loss = full_loss(&dims, &params, &chunks);
        self.dp.lock().unwrap().configure_job(
            job as u32,
            dims,
            rep,
            chunks,
            mlp::flatten(&params),
        );
        self.jobs.insert(
            job,
            PumpJob {
                dims,
                opt: Adam::new(self.cfg.lr, &dims.param_lens()),
                params,
                paper: HashMap::new(),
                losses: vec![first_loss],
                decoded: 0,
                flagged_rows: HashSet::new(),
                audits: 0,
                fallback_decodes: 0,
            },
        );
        Ok(())
    }

    /// The exact reference trajectory the fleet path must reproduce:
    /// plain per-chunk gradient sums (no coding), stepping the same
    /// Adam over the same dataset, sharding and init that
    /// [`Self::configure_job`] installs for `job`. `steps` optimizer
    /// steps produce `steps + 1` losses (index 0 = at init). The e2e
    /// contract — pinned by `tests/grad_fleet.rs` — is that a healthy
    /// fleet run's decoded losses match this within float noise.
    pub fn reference_losses(
        cfg: &GradConfig,
        job: JobId,
        scheme: &SchemeConfig,
        steps: usize,
    ) -> Vec<f64> {
        let (dims, chunks, mut params) = build_job(cfg, job, scheme);
        let mut opt = Adam::new(cfg.lr, &dims.param_lens());
        let mut losses = vec![full_loss(&dims, &params, &chunks)];
        for _ in 0..steps {
            let mut total = vec![0.0f32; dims.param_count()];
            for ch in &chunks {
                let (_, g) = mlp::grad_chunk(&dims, &params, &ch.x, &ch.y, &ch.w);
                add_into(&mut total, &mlp::flatten(&g));
            }
            let grads =
                mlp::unflatten(&dims, &total).expect("reference gradient has the param length");
            opt.update(&mut params, &grads);
            losses.push(full_loss(&dims, &params, &chunks));
        }
        losses
    }

    /// Per-job summaries for reports (sorted by job id).
    pub fn summary(&self) -> Vec<GradJobSummary> {
        let mut out: Vec<GradJobSummary> = self
            .jobs
            .iter()
            .map(|(&job, pj)| GradJobSummary {
                job,
                steps: pj.decoded,
                first_loss: pj.losses.first().copied().unwrap_or(f64::NAN),
                last_loss: pj.losses.last().copied().unwrap_or(f64::NAN),
                losses: pj.losses.clone(),
                audits: pj.audits,
                fallback_decodes: pj.fallback_decodes,
            })
            .collect();
        out.sort_by_key(|s| s.job);
        out
    }

    /// Fold the responders' payload segments of one consumed entry into
    /// the paper-job accumulators.
    fn fold_entry(pj: &mut PumpJob, entry: &RoundEntry, responded: &[bool]) {
        let pc = pj.dims.param_count();
        for (logical, &resp) in responded.iter().enumerate() {
            if !resp {
                continue;
            }
            let Some(&phys) = entry.place.get(logical) else { continue };
            if phys >= entry.payloads.len() {
                continue;
            }
            let Some(payload) = &entry.payloads[phys] else { continue };
            let units = &entry.fold[phys];
            if payload.len() != pc * units.len() {
                continue; // malformed payload: treat as a non-response
            }
            for (k, fu) in units.iter().enumerate() {
                let seg = &payload[k * pc..(k + 1) * pc];
                match fu {
                    FoldUnit::Plain { job: t, chunk } => {
                        let st = pj.paper.entry(*t).or_default();
                        if st.delivered_chunks.insert(*chunk) {
                            match &mut st.plain {
                                None => st.plain = Some(seg.to_vec()),
                                Some(acc) => {
                                    for (a, &v) in acc.iter_mut().zip(seg) {
                                        *a += v;
                                    }
                                }
                            }
                        }
                    }
                    FoldUnit::Coded { job: t, group, row } => {
                        let terms = match &entry.wire[phys][k] {
                            GradUnit::Coded { terms, .. } => terms.clone(),
                            _ => Vec::new(),
                        };
                        let st = pj.paper.entry(*t).or_default();
                        let results = st.coded.entry(*group).or_default();
                        if !results.iter().any(|r| r.row == *row) {
                            results.push(CodedResult {
                                row: *row,
                                ell: seg.to_vec(),
                                physical: phys,
                                version: entry.param_version,
                                terms,
                            });
                        }
                    }
                }
            }
        }
    }

    /// Decode paper job `t` of scheduler job `job`, audit if the
    /// redundancy disagrees, step Adam, publish the new params.
    fn finalize(&mut self, job: JobId, t: usize, scheme: &dyn Scheme) -> Result<()> {
        let n = scheme.spec().n;
        let pj = self.jobs.get_mut(&job).expect("finalize on unconfigured job");
        let pc = pj.dims.param_count();
        let st = pj.paper.remove(&t).unwrap_or_default();
        let mut total = st.plain.unwrap_or_else(|| vec![0.0f32; pc]);
        let ledger = scheme.ledger(t);
        let mut fallback = false;
        for (g, &need) in ledger.coded_need.iter().enumerate() {
            let empty = Vec::new();
            let results = st.coded.get(&g).unwrap_or(&empty);
            // drop results from rows already caught corrupting payloads
            let mut clean: Vec<&CodedResult> =
                results.iter().filter(|r| !pj.flagged_rows.contains(&r.row)).collect();
            clean.sort_by_key(|r| r.row);
            if need <= 1 {
                match clean.first() {
                    Some(r) => add_into(&mut total, &r.ell),
                    None => fallback = true,
                }
                continue;
            }
            let s = n - need;
            let plan = CodePlanCache::global().get(n, s);
            let decode = |subset: &[&CodedResult]| -> Option<Vec<f32>> {
                let rows: Vec<usize> = subset.iter().map(|r| r.row).collect();
                let beta = plan.decode_coeffs(&rows)?;
                let mut sum = vec![0.0f32; pc];
                for (k, r) in subset.iter().enumerate() {
                    let b = beta[k] as f32;
                    for (x, &v) in sum.iter_mut().zip(&r.ell) {
                        *x += b * v;
                    }
                }
                Some(sum)
            };
            if clean.len() < need {
                fallback = true;
                continue;
            }
            let primary: Vec<&CodedResult> = clean[..need].to_vec();
            let Some(mut group_sum) = decode(&primary) else {
                fallback = true;
                continue;
            };
            // Redundancy check: a spare responder lets us decode the same
            // group from a different subset; disagreement means some
            // payload lies, and the audit pins down which.
            if clean.len() > need {
                let mut alt: Vec<&CodedResult> = clean[clean.len() - need..].to_vec();
                alt.sort_by_key(|r| r.row);
                if let Some(alt_sum) = decode(&alt) {
                    if !close(&group_sum, &alt_sum, CONSISTENCY_RTOL) {
                        pj.audits += 1;
                        let culprits = audit_group(&self.dp, job, results);
                        for &(row, phys) in &culprits {
                            pj.flagged_rows.insert(row);
                            self.dp.lock().unwrap().flag_worker(phys);
                        }
                        let mut verified: Vec<&CodedResult> = results
                            .iter()
                            .filter(|r| !pj.flagged_rows.contains(&r.row))
                            .collect();
                        verified.sort_by_key(|r| r.row);
                        verified.truncate(need);
                        match (verified.len() >= need).then(|| decode(&verified)).flatten() {
                            Some(sum) => group_sum = sum,
                            None => {
                                fallback = true;
                                continue;
                            }
                        }
                    }
                }
            }
            add_into(&mut total, &group_sum);
        }
        if fallback {
            // Not enough trustworthy payloads: the master computes the
            // reference gradient itself so the run keeps making progress.
            pj.fallback_decodes += 1;
            total = reference_gradient(&self.dp, job, pj);
        }
        let grads = mlp::unflatten(&pj.dims, &total)
            .ok_or_else(|| anyhow::anyhow!("decoded gradient has wrong length"))?;
        pj.opt.update(&mut pj.params, &grads);
        let dims = pj.dims;
        let flat = mlp::flatten(&pj.params);
        let loss = {
            let mut dp = self.dp.lock().unwrap();
            dp.set_params(job as u32, flat);
            let jd = dp.job(job as u32).expect("configured");
            full_loss(&dims, &pj.params, &jd.chunks)
        };
        pj.losses.push(loss);
        pj.decoded += 1;
        Ok(())
    }
}

impl RoundObserver for GradPump {
    fn round_closed(
        &mut self,
        job: JobId,
        session: &SgcSession,
        plan: &RoundPlan,
        events: &[SessionEvent],
    ) -> crate::Result<()> {
        let entry = self.dp.lock().unwrap().take_session_round(job as u32, plan.round);
        let Some(entry) = entry else {
            return Ok(()); // not a real-gradient job
        };
        if let Some(pj) = self.jobs.get_mut(&job) {
            Self::fold_entry(pj, &entry, session.last_responded());
        }
        for ev in events {
            if let SessionEvent::JobDecoded { job: t, .. } = ev {
                self.finalize(job, *t, session.scheme())?;
            }
        }
        Ok(())
    }
}

/// Everything [`GradPump::configure_job`] derives from the config for
/// one job: the dims (chunk capacity resolved from the scheme's batch
/// split), the sharded fixed batch, and the initial parameters.
fn build_job(
    cfg: &GradConfig,
    job: JobId,
    scheme: &SchemeConfig,
) -> (ModelDims, Vec<ChunkData>, Vec<Vec<f32>>) {
    let spec_holder = scheme.build(1);
    let spec = spec_holder.spec();
    let data = Dataset::generate(DatasetConfig {
        input: cfg.dims.input,
        classes: cfg.dims.classes,
        train_size: cfg.train_size,
        noise: cfg.noise,
        seed: cfg.seed ^ 0xda7a_0000 ^ job as u64,
    });
    let mut rng = Pcg32::new(cfg.seed ^ 0xba7c, job as u64 + 1);
    let batch = data.sample_batch(cfg.batch, &mut rng);
    let parts = Dataset::split_batch(&batch, &spec.chunk_sizes);
    let chunk_cap = parts.iter().map(|p| p.len()).max().unwrap_or(1).max(1);
    let dims = ModelDims { chunk: chunk_cap, ..cfg.dims };
    let weight = 1.0 / batch.len() as f32;
    let chunks: Vec<ChunkData> = parts
        .iter()
        .map(|idx| {
            let (x, y, w) = data.chunk_tensors(idx, chunk_cap, weight);
            ChunkData { rows: chunk_cap, x, y, w }
        })
        .collect();
    let params = mlp::init_params(&dims, cfg.seed ^ 0x1219 ^ job as u64);
    (dims, chunks, params)
}

/// Full-batch loss: sum of weighted chunk losses (weights are `1/batch`
/// so this is the mean sample loss).
fn full_loss(dims: &ModelDims, params: &[Vec<f32>], chunks: &[ChunkData]) -> f64 {
    chunks
        .iter()
        .map(|c| mlp::loss_chunk(dims, params, &c.x, &c.y, &c.w) as f64)
        .sum()
}

fn add_into(acc: &mut [f32], v: &[f32]) {
    for (a, &x) in acc.iter_mut().zip(v) {
        *a += x;
    }
}

/// `‖a − b‖∞ ≤ rtol · (1 + max(‖a‖∞, ‖b‖∞))`?
fn close(a: &[f32], b: &[f32], rtol: f32) -> bool {
    let mut diff = 0.0f32;
    let mut mag = 0.0f32;
    for (&x, &y) in a.iter().zip(b) {
        diff = diff.max((x - y).abs());
        mag = mag.max(x.abs()).max(y.abs());
    }
    diff <= rtol * (1.0 + mag)
}

/// Recompute every result's expected coded payload from the master's own
/// partitions and flag the ones that do not match. Returns
/// `(row, physical)` culprits.
fn audit_group(
    dp: &SharedDataPlane,
    job: JobId,
    results: &[CodedResult],
) -> Vec<(usize, usize)> {
    let dp = dp.lock().unwrap();
    let Some(jd) = dp.job(job as u32) else { return Vec::new() };
    let mut chunk_grads: HashMap<(u32, u32), Vec<f32>> = HashMap::new();
    let mut culprits = Vec::new();
    for r in results {
        let Some(params_flat) = jd.params_at(r.version) else { continue };
        let Some(params) = mlp::unflatten(&jd.dims, params_flat) else { continue };
        let mut expected = vec![0.0f32; jd.dims.param_count()];
        for &(c, coeff) in &r.terms {
            let grads = chunk_grads.entry((c, r.version)).or_insert_with(|| {
                let ch = &jd.chunks[c as usize % jd.chunks.len()];
                let (_, g) = mlp::grad_chunk(&jd.dims, &params, &ch.x, &ch.y, &ch.w);
                mlp::flatten(&g)
            });
            for (e, &g) in expected.iter_mut().zip(grads.iter()) {
                *e += coeff as f32 * g;
            }
        }
        if !close(&expected, &r.ell, CONSISTENCY_RTOL) {
            culprits.push((r.row, r.physical));
        }
    }
    culprits
}

/// The master's own full-batch gradient at the current params — the
/// degraded-decode fallback when payloads cannot be trusted or are
/// insufficient.
fn reference_gradient(dp: &SharedDataPlane, job: JobId, pj: &PumpJob) -> Vec<f32> {
    let dp = dp.lock().unwrap();
    let mut total = vec![0.0f32; pj.dims.param_count()];
    let Some(jd) = dp.job(job as u32) else { return total };
    for ch in &jd.chunks {
        let (_, g) = mlp::grad_chunk(&pj.dims, &pj.params, &ch.x, &ch.y, &ch.w);
        add_into(&mut total, &mlp::flatten(&g));
    }
    total
}
