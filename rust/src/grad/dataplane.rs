//! Master-side state of the gradient data plane.
//!
//! The `DataPlane` owns, per scheduler job: the model dimensions, the
//! partitioned training chunks (what `Partition` frames ship), the
//! current flat parameter vector (what `Params` frames broadcast,
//! versioned), and per-cluster-round staging entries that pin — at the
//! moment the scheduler launches a round — which wire work units each
//! physical worker must compute and which parameter version they must
//! be computed against.
//!
//! It is shared between the scheduler (stages rounds), the fleet master
//! (ships partitions/params/assignments, stores reassembled payloads)
//! and the [`super::GradPump`] observer (folds payloads, decodes,
//! steps the optimizer) behind a mutex: every touch is short and
//! allocation-light, and the fleet master already runs single-threaded
//! around its poll loop.

use crate::coding::{CodePlanCache, Scheme, WorkUnit};
use crate::fleet::wire::GradUnit;
use crate::runtime::ModelDims;
use crate::session::RoundPlan;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Placement sentinel: logical worker has no physical seat this round.
pub const UNPLACED_WORKER: usize = usize::MAX;

/// How many historical parameter versions a job keeps for payload
/// audits (delay schemes fold payloads computed a few versions back).
const PARAM_HISTORY: usize = 8;

/// One training partition: the padded tensors a worker needs to compute
/// the chunk's partial gradient.
#[derive(Clone, Debug)]
pub struct ChunkData {
    /// Padded row count (`x` is `rows × input`, `y` is `rows × classes`).
    pub rows: usize,
    /// Row-major inputs.
    pub x: Vec<f32>,
    /// One-hot labels.
    pub y: Vec<f32>,
    /// Per-sample weights (0 for padding rows).
    pub w: Vec<f32>,
}

impl ChunkData {
    /// Wire layout: `x ‖ y ‖ w` as one flat tensor.
    pub fn flat(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.x.len() + self.y.len() + self.w.len());
        out.extend_from_slice(&self.x);
        out.extend_from_slice(&self.y);
        out.extend_from_slice(&self.w);
        out
    }

    /// Flat length implied by `dims` and `rows`.
    pub fn flat_len(dims: &ModelDims, rows: usize) -> usize {
        rows * (dims.input + dims.classes + 1)
    }

    /// Rebuild from the wire layout; `None` on a length mismatch.
    pub fn from_flat(dims: &ModelDims, rows: usize, flat: &[f32]) -> Option<Self> {
        if flat.len() != Self::flat_len(dims, rows) {
            return None;
        }
        let nx = rows * dims.input;
        let ny = rows * dims.classes;
        Some(ChunkData {
            rows,
            x: flat[..nx].to_vec(),
            y: flat[nx..nx + ny].to_vec(),
            w: flat[nx + ny..].to_vec(),
        })
    }
}

/// Everything the data plane knows about one scheduler job.
#[derive(Clone, Debug)]
pub struct JobData {
    /// Model shapes (what `JobSpec` frames announce).
    pub dims: ModelDims,
    /// Replication-style coding (coefficients are all 1).
    pub rep: bool,
    /// The k partitions, indexed by chunk id.
    pub chunks: Vec<ChunkData>,
    /// Current flat parameter vector.
    pub params: Vec<f32>,
    /// Monotone parameter version; bumped on every optimizer step.
    pub version: u32,
    /// Recent `(version, params)` snapshots for payload audits.
    history: Vec<(u32, Vec<f32>)>,
}

impl JobData {
    /// Parameters as they were at `version`, if still retained.
    pub fn params_at(&self, version: u32) -> Option<&[f32]> {
        if version == self.version {
            return Some(&self.params);
        }
        self.history.iter().find(|(v, _)| *v == version).map(|(_, p)| p.as_slice())
    }
}

/// Fold-time view of one wire work unit (what the decode pass needs to
/// attribute a payload segment; coefficients were applied worker-side).
#[derive(Clone, Copy, Debug)]
pub enum FoldUnit {
    /// Raw partial gradient of `chunk` for paper job `job`.
    Plain {
        /// 1-based paper job.
        job: usize,
        /// Chunk id.
        chunk: usize,
    },
    /// Coded combination `ℓ_{row,group}(job)`.
    Coded {
        /// 1-based paper job.
        job: usize,
        /// Ledger group index.
        group: usize,
        /// Encoding-matrix row (== logical worker).
        row: usize,
    },
}

/// Per-cluster-round staging: the work units shipped to each physical
/// worker and the payloads that came back.
#[derive(Clone, Debug)]
pub struct RoundEntry {
    /// The session's 1-based round index this entry serves.
    pub session_round: usize,
    /// Parameter version the assignments were staged against.
    pub param_version: u32,
    /// Logical worker → physical seat ([`UNPLACED_WORKER`] if none).
    pub place: Vec<usize>,
    /// Wire units per physical worker (empty = nothing to send).
    pub wire: Vec<Vec<GradUnit>>,
    /// Fold metadata per physical worker, aligned with `wire`.
    pub fold: Vec<Vec<FoldUnit>>,
    /// Reassembled payload per physical worker.
    pub payloads: Vec<Option<Vec<f32>>>,
}

/// The shared handle every layer holds.
pub type SharedDataPlane = Arc<Mutex<DataPlane>>;

/// Master-side gradient data-plane state (see module docs).
#[derive(Debug, Default)]
pub struct DataPlane {
    jobs: HashMap<u32, JobData>,
    rounds: HashMap<(u32, u64), RoundEntry>,
    by_session: HashMap<(u32, usize), u64>,
    flagged: Vec<usize>,
    grad_bytes: HashMap<u32, u64>,
}

impl DataPlane {
    /// Empty data plane (no job opted in).
    pub fn new() -> Self {
        DataPlane::default()
    }

    /// Empty data plane behind the shared handle.
    pub fn shared() -> SharedDataPlane {
        Arc::new(Mutex::new(DataPlane::new()))
    }

    /// Opt a scheduler job into the real-gradient path.
    pub fn configure_job(
        &mut self,
        job: u32,
        dims: ModelDims,
        rep: bool,
        chunks: Vec<ChunkData>,
        params: Vec<f32>,
    ) {
        assert_eq!(params.len(), dims.param_count(), "flat params must match dims");
        self.jobs.insert(
            job,
            JobData { dims, rep, chunks, params, version: 1, history: Vec::new() },
        );
    }

    /// Is this scheduler job on the real-gradient path?
    pub fn is_grad_job(&self, job: u32) -> bool {
        self.jobs.contains_key(&job)
    }

    /// The job's data, if configured.
    pub fn job(&self, job: u32) -> Option<&JobData> {
        self.jobs.get(&job)
    }

    /// Install freshly stepped parameters, bumping the version (the old
    /// vector is retained for audits of in-flight payloads).
    pub fn set_params(&mut self, job: u32, params: Vec<f32>) -> u32 {
        let jd = self.jobs.get_mut(&job).expect("set_params on unconfigured job");
        assert_eq!(params.len(), jd.dims.param_count());
        let old = std::mem::replace(&mut jd.params, params);
        jd.history.push((jd.version, old));
        if jd.history.len() > PARAM_HISTORY {
            jd.history.remove(0);
        }
        jd.version += 1;
        jd.version
    }

    /// Stage the launching round: translate the session's task plan into
    /// wire units (resolving the GC coefficients master-side, so workers
    /// never need the code plan) and pin the parameter version.
    ///
    /// Called by the scheduler after placement, before the cluster
    /// `submit`, so the fleet master finds the entry when it fans the
    /// round out.
    pub fn stage_round(
        &mut self,
        job: u32,
        cluster_round: u64,
        scheme: &dyn Scheme,
        plan: &RoundPlan,
        place: &[usize],
        physical_n: usize,
    ) {
        let Some(jd) = self.jobs.get(&job) else { return };
        let n = scheme.spec().n;
        let paper_jobs = scheme.jobs();
        let mut wire: Vec<Vec<GradUnit>> = vec![Vec::new(); physical_n];
        let mut fold: Vec<Vec<FoldUnit>> = vec![Vec::new(); physical_n];
        for (logical, task) in plan.tasks.iter().enumerate() {
            let phys = place.get(logical).copied().unwrap_or(UNPLACED_WORKER);
            if phys == UNPLACED_WORKER || phys >= physical_n {
                continue;
            }
            for unit in &task.units {
                match unit {
                    WorkUnit::Noop => {}
                    WorkUnit::Plain { job: t, chunk } => {
                        if *t < 1 || *t > paper_jobs {
                            continue;
                        }
                        wire[phys]
                            .push(GradUnit::Plain { job: *t as u32, chunk: *chunk as u32 });
                        fold[phys].push(FoldUnit::Plain { job: *t, chunk: *chunk });
                    }
                    WorkUnit::Coded { job: t, group, row, chunks } => {
                        if *t < 1 || *t > paper_jobs {
                            continue;
                        }
                        let need = scheme.ledger(*t).coded_need[*group];
                        let terms: Vec<(u32, f64)> = chunks
                            .iter()
                            .map(|&c| {
                                let coeff = if jd.rep || need <= 1 {
                                    1.0f64
                                } else {
                                    let s = n - need;
                                    let plan_b = CodePlanCache::global().get(n, s);
                                    plan_b.b()[(*row, c % n)]
                                };
                                (c as u32, coeff)
                            })
                            .collect();
                        wire[phys].push(GradUnit::Coded { job: *t as u32, terms });
                        fold[phys].push(FoldUnit::Coded { job: *t, group: *group, row: *row });
                    }
                }
            }
        }
        let entry = RoundEntry {
            session_round: plan.round,
            param_version: jd.version,
            place: place.to_vec(),
            wire,
            fold,
            payloads: vec![None; physical_n],
        };
        self.by_session.insert((job, plan.round), cluster_round);
        self.rounds.insert((job, cluster_round), entry);
    }

    /// The staged entry for a cluster round, if any (what the fleet
    /// master consults when fanning out assignments).
    pub fn round(&self, job: u32, cluster_round: u64) -> Option<&RoundEntry> {
        self.rounds.get(&(job, cluster_round))
    }

    /// Store a worker's reassembled payload for a staged round.
    ///
    /// `false` when the entry is gone (round already folded — a very
    /// late straggler) or the version is stale: the payload is dropped.
    pub fn store_payload(
        &mut self,
        job: u32,
        cluster_round: u64,
        physical: usize,
        param_version: u32,
        payload: Vec<f32>,
    ) -> bool {
        let Some(entry) = self.rounds.get_mut(&(job, cluster_round)) else {
            return false;
        };
        if entry.param_version != param_version || physical >= entry.payloads.len() {
            return false;
        }
        entry.payloads[physical] = Some(payload);
        true
    }

    /// Remove and return the entry serving a session round (the decode
    /// pass consumes it exactly once, at round close).
    pub fn take_session_round(&mut self, job: u32, session_round: usize) -> Option<RoundEntry> {
        let cluster_round = self.by_session.remove(&(job, session_round))?;
        self.rounds.remove(&(job, cluster_round))
    }

    /// Mark a physical worker as byzantine; the fleet master drains
    /// these via [`DataPlane::take_flagged`] and retires them.
    pub fn flag_worker(&mut self, physical: usize) {
        if !self.flagged.contains(&physical) {
            self.flagged.push(physical);
        }
    }

    /// Drain the byzantine flags raised since the last call.
    pub fn take_flagged(&mut self) -> Vec<usize> {
        std::mem::take(&mut self.flagged)
    }

    /// Count gradient payload bytes received for a job.
    pub fn add_grad_bytes(&mut self, job: u32, bytes: u64) {
        *self.grad_bytes.entry(job).or_insert(0) += bytes;
    }

    /// Total gradient payload bytes received for a job.
    pub fn grad_bytes(&self, job: u32) -> u64 {
        self.grad_bytes.get(&job).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims { input: 4, classes: 2, hidden1: 3, hidden2: 3, chunk: 2 }
    }

    fn chunk(rows: usize, fill: f32) -> ChunkData {
        let d = dims();
        ChunkData {
            rows,
            x: vec![fill; rows * d.input],
            y: vec![0.0; rows * d.classes],
            w: vec![1.0; rows],
        }
    }

    #[test]
    fn chunk_flat_round_trips() {
        let c = chunk(3, 0.5);
        let flat = c.flat();
        assert_eq!(flat.len(), ChunkData::flat_len(&dims(), 3));
        let back = ChunkData::from_flat(&dims(), 3, &flat).unwrap();
        assert_eq!(back.x, c.x);
        assert_eq!(back.y, c.y);
        assert_eq!(back.w, c.w);
        assert!(ChunkData::from_flat(&dims(), 4, &flat).is_none(), "bad rows rejected");
    }

    #[test]
    fn params_versioning_retains_history() {
        let mut dp = DataPlane::new();
        let d = dims();
        let p0 = vec![0.0f32; d.param_count()];
        dp.configure_job(7, d, false, vec![chunk(2, 0.1)], p0.clone());
        assert!(dp.is_grad_job(7));
        assert!(!dp.is_grad_job(8));
        assert_eq!(dp.job(7).unwrap().version, 1);
        let p1 = vec![1.0f32; d.param_count()];
        let v = dp.set_params(7, p1.clone());
        assert_eq!(v, 2);
        let jd = dp.job(7).unwrap();
        assert_eq!(jd.params_at(2).unwrap(), &p1[..]);
        assert_eq!(jd.params_at(1).unwrap(), &p0[..]);
        assert!(jd.params_at(3).is_none());
    }

    #[test]
    fn payload_store_rejects_stale_version_and_unknown_round() {
        let mut dp = DataPlane::new();
        let d = dims();
        dp.configure_job(0, d, false, vec![chunk(1, 0.0)], vec![0.0; d.param_count()]);
        // no staged entry yet
        assert!(!dp.store_payload(0, 5, 0, 1, vec![1.0]));
        dp.rounds.insert(
            (0, 5),
            RoundEntry {
                session_round: 1,
                param_version: 1,
                place: vec![0],
                wire: vec![Vec::new()],
                fold: vec![Vec::new()],
                payloads: vec![None],
            },
        );
        dp.by_session.insert((0, 1), 5);
        assert!(!dp.store_payload(0, 5, 0, 2, vec![1.0]), "stale version dropped");
        assert!(dp.store_payload(0, 5, 0, 1, vec![1.0]));
        let entry = dp.take_session_round(0, 1).unwrap();
        assert_eq!(entry.payloads[0].as_deref(), Some(&[1.0f32][..]));
        assert!(dp.take_session_round(0, 1).is_none(), "consumed exactly once");
    }

    #[test]
    fn flags_and_byte_counters_accumulate() {
        let mut dp = DataPlane::new();
        dp.flag_worker(2);
        dp.flag_worker(2);
        dp.flag_worker(1);
        assert_eq!(dp.take_flagged(), vec![2, 1]);
        assert!(dp.take_flagged().is_empty());
        dp.add_grad_bytes(3, 100);
        dp.add_grad_bytes(3, 28);
        assert_eq!(dp.grad_bytes(3), 128);
        assert_eq!(dp.grad_bytes(4), 0);
    }
}
