//! CPU forward/backward for the 3-layer MLP the AOT artifact lowers
//! (`runtime::artifact`): relu → relu → softmax cross-entropy, with
//! per-sample weights so padded rows (weight 0) contribute nothing and
//! partial gradients over chunks sum to the full-batch gradient.
//!
//! This is the worker-side compute of the gradient data plane. It
//! mirrors the compiled PJRT program's contract
//! `(W1,b1,W2,b2,W3,b3,x,y,wgt) → (loss_sum, gW1..gb3)` exactly, but in
//! portable scalar Rust, so the loopback fleet computes *real*
//! gradients without the `pjrt` feature. Determinism matters more than
//! speed here: plain loops in a fixed order give bit-identical results
//! on every platform, which the decode bit-stability tests pin.

use crate::runtime::ModelDims;
use crate::util::rng::Pcg32;

/// Deterministic He-style initialization of the 6 parameter tensors.
///
/// Weights are `normal · sqrt(2 / fan_in)`, biases zero, all drawn from
/// a stream derived only from `seed` — master and tests can regenerate
/// the exact same starting point.
pub fn init_params(dims: &ModelDims, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::new(seed, 0x6d1b);
    dims.param_shapes()
        .iter()
        .map(|&(rows, cols)| {
            if rows == 1 {
                vec![0.0; cols]
            } else {
                let scale = (2.0 / rows as f64).sqrt();
                (0..rows * cols).map(|_| (rng.normal() * scale) as f32).collect()
            }
        })
        .collect()
}

/// Flatten the 6 tensors into one wire-ready vector (program order).
pub fn flatten(params: &[Vec<f32>]) -> Vec<f32> {
    params.iter().flat_map(|p| p.iter().copied()).collect()
}

/// Split a flat vector back into the 6 tensors of `dims`.
///
/// `None` if the length does not match [`ModelDims::param_count`] — a
/// stale or corrupt broadcast must not panic the worker.
pub fn unflatten(dims: &ModelDims, flat: &[f32]) -> Option<Vec<Vec<f32>>> {
    if flat.len() != dims.param_count() {
        return None;
    }
    let mut out = Vec::with_capacity(6);
    let mut off = 0;
    for len in dims.param_lens() {
        out.push(flat[off..off + len].to_vec());
        off += len;
    }
    Some(out)
}

/// `y = x·W + b` for row-major `x: rows×in`, `w: in×out`, `b: out`.
fn affine(x: &[f32], w: &[f32], b: &[f32], rows: usize, nin: usize, nout: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; rows * nout];
    for r in 0..rows {
        let xr = &x[r * nin..(r + 1) * nin];
        let yr = &mut y[r * nout..(r + 1) * nout];
        yr.copy_from_slice(b);
        for (i, &xi) in xr.iter().enumerate() {
            if xi != 0.0 {
                let wrow = &w[i * nout..(i + 1) * nout];
                for (yj, &wj) in yr.iter_mut().zip(wrow) {
                    *yj += xi * wj;
                }
            }
        }
    }
    y
}

/// One forward pass, returning pre-activations and activations.
struct Forward {
    z1: Vec<f32>,
    a1: Vec<f32>,
    z2: Vec<f32>,
    a2: Vec<f32>,
    /// Softmax probabilities, rows × classes.
    p: Vec<f32>,
}

fn forward(dims: &ModelDims, params: &[Vec<f32>], x: &[f32], rows: usize) -> Forward {
    let (ni, h1, h2, nc) = (dims.input, dims.hidden1, dims.hidden2, dims.classes);
    let z1 = affine(x, &params[0], &params[1], rows, ni, h1);
    let a1: Vec<f32> = z1.iter().map(|&v| v.max(0.0)).collect();
    let z2 = affine(&a1, &params[2], &params[3], rows, h1, h2);
    let a2: Vec<f32> = z2.iter().map(|&v| v.max(0.0)).collect();
    let z3 = affine(&a2, &params[4], &params[5], rows, h2, nc);
    let mut p = z3;
    for r in 0..rows {
        let row = &mut p[r * nc..(r + 1) * nc];
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
    Forward { z1, a1, z2, a2, p }
}

/// Weighted loss sum over one chunk: `Σᵢ wgtᵢ · CE(softmax(f(xᵢ)), yᵢ)`.
///
/// Row count is taken from `wgt.len()`; `x`/`y` must match it.
pub fn loss_chunk(dims: &ModelDims, params: &[Vec<f32>], x: &[f32], y: &[f32], wgt: &[f32]) -> f32 {
    let rows = wgt.len();
    assert_eq!(x.len(), rows * dims.input, "x shape");
    assert_eq!(y.len(), rows * dims.classes, "y shape");
    let f = forward(dims, params, x, rows);
    let nc = dims.classes;
    let mut loss = 0.0f32;
    for (r, &w) in wgt.iter().enumerate() {
        if w == 0.0 {
            continue;
        }
        for c in 0..nc {
            let t = y[r * nc + c];
            if t != 0.0 {
                loss += w * t * -(f.p[r * nc + c].max(1e-12).ln());
            }
        }
    }
    loss
}

/// `(loss_sum, grads)` for one chunk — the CPU mirror of
/// `GradExecutable::grad_chunk`.
///
/// * `params` — 6 flattened tensors per [`ModelDims::param_shapes`].
/// * `x` — `rows × input`, row-major; `y` — `rows × classes` one-hot;
///   `wgt` — `rows` per-sample weights (0 for padding).
///
/// With weight `1/batch` on every real sample, the per-chunk gradients
/// of a partition sum to the mean full-batch gradient, which is exactly
/// the linearity the gradient code's decode relies on.
pub fn grad_chunk(
    dims: &ModelDims,
    params: &[Vec<f32>],
    x: &[f32],
    y: &[f32],
    wgt: &[f32],
) -> (f32, Vec<Vec<f32>>) {
    let rows = wgt.len();
    assert_eq!(params.len(), 6, "expected 6 parameter tensors");
    assert_eq!(x.len(), rows * dims.input, "x shape");
    assert_eq!(y.len(), rows * dims.classes, "y shape");
    let (ni, h1, h2, nc) = (dims.input, dims.hidden1, dims.hidden2, dims.classes);
    let f = forward(dims, params, x, rows);

    let mut loss = 0.0f32;
    // dz3 = wgt · (p − y), the weighted softmax-CE gradient
    let mut dz3 = vec![0.0f32; rows * nc];
    for (r, &w) in wgt.iter().enumerate() {
        if w == 0.0 {
            continue;
        }
        for c in 0..nc {
            let t = y[r * nc + c];
            let p = f.p[r * nc + c];
            if t != 0.0 {
                loss += w * t * -(p.max(1e-12).ln());
            }
            dz3[r * nc + c] = w * (p - t);
        }
    }

    // layer 3 grads + backprop through W3
    let (g_w3, g_b3) = grad_affine(&f.a2, &dz3, rows, h2, nc);
    let mut dz2 = matmul_t(&dz3, &params[4], rows, nc, h2);
    for (d, &z) in dz2.iter_mut().zip(&f.z2) {
        if z <= 0.0 {
            *d = 0.0;
        }
    }
    let (g_w2, g_b2) = grad_affine(&f.a1, &dz2, rows, h1, h2);
    let mut dz1 = matmul_t(&dz2, &params[2], rows, h2, h1);
    for (d, &z) in dz1.iter_mut().zip(&f.z1) {
        if z <= 0.0 {
            *d = 0.0;
        }
    }
    let (g_w1, g_b1) = grad_affine(x, &dz1, rows, ni, h1);

    (loss, vec![g_w1, g_b1, g_w2, g_b2, g_w3, g_b3])
}

/// `(gW, gb) = (aᵀ·dz, Σᵣ dz)` for `a: rows×nin`, `dz: rows×nout`.
fn grad_affine(a: &[f32], dz: &[f32], rows: usize, nin: usize, nout: usize) -> (Vec<f32>, Vec<f32>) {
    let mut gw = vec![0.0f32; nin * nout];
    let mut gb = vec![0.0f32; nout];
    for r in 0..rows {
        let dzr = &dz[r * nout..(r + 1) * nout];
        for (gbj, &d) in gb.iter_mut().zip(dzr) {
            *gbj += d;
        }
        let ar = &a[r * nin..(r + 1) * nin];
        for (i, &ai) in ar.iter().enumerate() {
            if ai != 0.0 {
                let gwrow = &mut gw[i * nout..(i + 1) * nout];
                for (g, &d) in gwrow.iter_mut().zip(dzr) {
                    *g += ai * d;
                }
            }
        }
    }
    (gw, gb)
}

/// `da = dz·Wᵀ` for `dz: rows×nout`, `w: nin×nout` → `rows×nin`.
fn matmul_t(dz: &[f32], w: &[f32], rows: usize, nout: usize, nin: usize) -> Vec<f32> {
    let mut da = vec![0.0f32; rows * nin];
    for r in 0..rows {
        let dzr = &dz[r * nout..(r + 1) * nout];
        let dar = &mut da[r * nin..(r + 1) * nin];
        for (i, d) in dar.iter_mut().enumerate() {
            let wrow = &w[i * nout..(i + 1) * nout];
            let mut acc = 0.0f32;
            for (&z, &wj) in dzr.iter().zip(wrow) {
                acc += z * wj;
            }
            *d = acc;
        }
    }
    da
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::train::{Adam, Dataset, DatasetConfig};

    fn tiny_dims() -> ModelDims {
        ModelDims { input: 5, classes: 3, hidden1: 4, hidden2: 4, chunk: 6 }
    }

    fn tiny_batch(dims: &ModelDims, rows: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Pcg32::new(seed, 77);
        let x: Vec<f32> = (0..rows * dims.input).map(|_| rng.normal() as f32).collect();
        let mut y = vec![0.0f32; rows * dims.classes];
        for r in 0..rows {
            y[r * dims.classes + rng.below(dims.classes)] = 1.0;
        }
        let w = vec![1.0 / rows as f32; rows];
        (x, y, w)
    }

    #[test]
    fn init_is_deterministic_and_shaped() {
        let dims = tiny_dims();
        let a = init_params(&dims, 9);
        let b = init_params(&dims, 9);
        assert_eq!(a, b);
        let lens: Vec<usize> = a.iter().map(|p| p.len()).collect();
        assert_eq!(lens, dims.param_lens());
        assert!(a[1].iter().all(|&v| v == 0.0), "biases start at zero");
    }

    #[test]
    fn flatten_unflatten_round_trips() {
        let dims = tiny_dims();
        let p = init_params(&dims, 3);
        let flat = flatten(&p);
        assert_eq!(flat.len(), dims.param_count());
        assert_eq!(unflatten(&dims, &flat).unwrap(), p);
        assert!(unflatten(&dims, &flat[1..]).is_none(), "wrong length is rejected");
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let dims = tiny_dims();
        let params = init_params(&dims, 5);
        let (x, y, w) = tiny_batch(&dims, 4, 1);
        let (_, grads) = grad_chunk(&dims, &params, &x, &y, &w);
        // probe a few coordinates of every tensor with central differences
        let eps = 1e-2f32;
        for t in 0..6 {
            for &i in &[0usize, params[t].len() / 2, params[t].len() - 1] {
                let mut up = params.clone();
                up[t][i] += eps;
                let mut dn = params.clone();
                dn[t][i] -= eps;
                let num = (loss_chunk(&dims, &up, &x, &y, &w)
                    - loss_chunk(&dims, &dn, &x, &y, &w))
                    / (2.0 * eps);
                let ana = grads[t][i];
                assert!(
                    (num - ana).abs() < 2e-3 + 0.05 * ana.abs(),
                    "tensor {t} idx {i}: fd {num} vs analytic {ana}"
                );
            }
        }
    }

    #[test]
    fn chunk_gradients_sum_to_the_batch_gradient() {
        // the linearity the gradient code's decode relies on: splitting a
        // batch into chunks and summing per-chunk gradients reproduces the
        // full-batch gradient (same per-sample weights throughout)
        let dims = tiny_dims();
        let params = init_params(&dims, 8);
        let rows = 6;
        let (x, y, _) = tiny_batch(&dims, rows, 2);
        let w = vec![1.0 / rows as f32; rows];
        let (full_loss, full) = grad_chunk(&dims, &params, &x, &y, &w);
        let cut = 2; // rows 0..2 and 2..6
        let (la, ga) = grad_chunk(
            &dims,
            &params,
            &x[..cut * dims.input],
            &y[..cut * dims.classes],
            &w[..cut],
        );
        let (lb, gb) = grad_chunk(
            &dims,
            &params,
            &x[cut * dims.input..],
            &y[cut * dims.classes..],
            &w[cut..],
        );
        assert!((full_loss - (la + lb)).abs() < 1e-5);
        for t in 0..6 {
            for i in 0..full[t].len() {
                assert!(
                    (full[t][i] - (ga[t][i] + gb[t][i])).abs() < 1e-5,
                    "tensor {t} idx {i}"
                );
            }
        }
    }

    #[test]
    fn zero_weight_rows_contribute_nothing() {
        let dims = tiny_dims();
        let params = init_params(&dims, 4);
        let (x, y, w) = tiny_batch(&dims, 3, 3);
        let (loss, grads) = grad_chunk(&dims, &params, &x, &y, &w);
        // pad with garbage rows at weight 0
        let mut xp = x.clone();
        xp.extend(vec![7.5f32; 2 * dims.input]);
        let mut yp = y.clone();
        yp.extend(vec![0.0f32; 2 * dims.classes]);
        let mut wp = w.clone();
        wp.extend([0.0, 0.0]);
        let (loss_p, grads_p) = grad_chunk(&dims, &params, &xp, &yp, &wp);
        assert_eq!(loss, loss_p);
        assert_eq!(grads, grads_p);
    }

    #[test]
    fn adam_on_mlp_gradients_learns_the_dataset() {
        let data = Dataset::generate(DatasetConfig {
            input: 16,
            classes: 4,
            train_size: 128,
            noise: 0.3,
            seed: 11,
        });
        let dims = ModelDims { input: 16, classes: 4, hidden1: 16, hidden2: 8, chunk: 128 };
        let mut params = init_params(&dims, 1);
        let mut adam = Adam::new(5e-3, &dims.param_lens());
        let idx: Vec<usize> = (0..data.len()).collect();
        let (x, y, w) = data.chunk_tensors(&idx, data.len(), 1.0 / data.len() as f32);
        let first = loss_chunk(&dims, &params, &x, &y, &w);
        for _ in 0..60 {
            let (_, grads) = grad_chunk(&dims, &params, &x, &y, &w);
            adam.update(&mut params, &grads);
        }
        let last = loss_chunk(&dims, &params, &x, &y, &w);
        assert!(last < 0.5 * first, "loss must drop: {first} → {last}");
    }
}
