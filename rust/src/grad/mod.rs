//! The gradient data plane: real partial-gradient compute over the
//! fleet's wire protocol.
//!
//! Three pieces, one per side of the TCP boundary plus the glue:
//!
//! * [`mlp`] — portable CPU forward/backward for the 3-layer MLP,
//!   bit-deterministic, shared by workers (compute), the master
//!   (audits, fallback decode, loss eval) and tests (reference sums).
//! * [`dataplane`] — the master-side state: partitions, versioned
//!   params, per-round staging of wire work units with master-resolved
//!   GC coefficients, reassembled payloads, byzantine flags.
//! * [`pump`] — the [`crate::sched::RoundObserver`] that folds
//!   payloads at round close, β-decodes each paper job, audits the
//!   code's redundancy, and steps Adam.
//!
//! The plane is strictly opt-in per scheduler job: jobs never
//! configured through [`GradPump::configure_job`] keep the legacy
//! synthetic minitask path, byte for byte.

pub mod dataplane;
pub mod mlp;
pub mod pump;

pub use dataplane::{ChunkData, DataPlane, FoldUnit, RoundEntry, SharedDataPlane};
pub use pump::{GradConfig, GradJobSummary, GradPump};
