//! The master node: a thin driver over the sans-IO protocol engine.
//!
//! All round logic — μ-rule straggler identification (Sec. 2), wait-out
//! policies (Remark 2.3), commit and decode — lives in
//! [`crate::session::SgcSession`]; the master merely pumps the session
//! against a backend: [`run_events`](Master::run_events) schedules it as
//! a single job on any event-driven backend
//! ([`crate::sched::JobScheduler`]), [`run`](Master::run) drives the
//! classic blocking protocol via [`crate::session::drive`]. Kept as a
//! facade so CLI, benches and tests have a one-call entry point.

use super::metrics::RunReport;
use crate::cluster::{Cluster, EventCluster};
use crate::coding::SchemeConfig;
use crate::session::{drive, SessionConfig};

/// The master node.
pub struct Master {
    scheme_cfg: SchemeConfig,
    cfg: SessionConfig,
}

impl Master {
    /// Master for one scheme/session configuration.
    pub fn new(scheme_cfg: SchemeConfig, cfg: SessionConfig) -> Self {
        Master { scheme_cfg, cfg }
    }

    /// Run `J` jobs over `J + T` rounds against the given blocking
    /// cluster. Errors if the cluster and scheme sizes disagree.
    pub fn run(&mut self, cluster: &mut dyn Cluster) -> crate::Result<RunReport> {
        drive(&self.scheme_cfg, &self.cfg, cluster)
    }

    /// Run against an event-driven backend through the scheduler path (a
    /// single-job [`crate::sched::JobScheduler`]): identical reports to
    /// [`run`](Self::run) over the same backend behind a
    /// [`SyncAdapter`](crate::cluster::SyncAdapter).
    pub fn run_events(&mut self, cluster: &mut dyn EventCluster) -> crate::Result<RunReport> {
        crate::sched::drive_events(&self.scheme_cfg, &self.cfg, cluster)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{LatencyParams, SimCluster};
    use crate::coordinator::{RunConfig, WaitPolicy};
    use crate::straggler::models::NoStragglers;
    use crate::straggler::{GilbertElliot, Pattern, TraceProcess};

    fn quiet_cluster(n: usize, seed: u64) -> SimCluster {
        SimCluster::new(n, LatencyParams::default(), Box::new(NoStragglers { n }), seed)
    }

    #[test]
    fn gc_run_completes_all_jobs() {
        let mut m = Master::new(
            SchemeConfig::gc(8, 2),
            RunConfig { jobs: 20, ..Default::default() },
        );
        let mut cluster = quiet_cluster(8, 1);
        let rep = m.run_events(&mut cluster).unwrap();
        assert_eq!(rep.deadline_violations, 0);
        assert!(rep.job_completion_s.iter().all(|t| t.is_finite()));
        assert_eq!(rep.rounds.len(), 20);
        assert!(rep.total_runtime_s > 0.0);
    }

    #[test]
    fn msgc_with_ge_stragglers_completes() {
        let n = 16;
        let mut m = Master::new(
            SchemeConfig::msgc(n, 1, 2, 4),
            RunConfig { jobs: 40, ..Default::default() },
        );
        let mut cluster =
            SimCluster::from_gilbert_elliot(n, GilbertElliot::new(n, 0.04, 0.7, 5), 9);
        let rep = m.run_events(&mut cluster).unwrap();
        assert_eq!(rep.deadline_violations, 0, "conformance repair must save every deadline");
        assert_eq!(rep.rounds.len(), 40 + 1);
    }

    #[test]
    fn uncoded_waits_for_everyone() {
        let n = 8;
        let mut m = Master::new(
            SchemeConfig::uncoded(n),
            RunConfig { jobs: 10, ..Default::default() },
        );
        // a fresh straggler every round (rotating worker so severity
        // never decays)
        let rows: Vec<Vec<bool>> =
            (0..10).map(|r| (0..n).map(|i| i == r % n).collect()).collect();
        let pat = Pattern::from_rows(rows);
        let mut cluster = SimCluster::new(
            n,
            LatencyParams::default(),
            Box::new(TraceProcess::new(pat)),
            3,
        );
        let rep = m.run_events(&mut cluster).unwrap();
        assert_eq!(rep.deadline_violations, 0);
        // every round waited out the straggler
        assert!(rep.rounds.iter().all(|r| r.waited_out >= 1));
        // and is therefore slow
        assert!(rep.mean_round_s() > 1.5);
    }

    #[test]
    fn deadline_decode_policy_tracks_violations() {
        // Lazy policy on GC never violates (waiting for decode directly).
        let mut m = Master::new(
            SchemeConfig::gc(8, 1),
            RunConfig {
                jobs: 15,
                wait_policy: WaitPolicy::DeadlineDecode,
                ..Default::default()
            },
        );
        let mut cluster =
            SimCluster::from_gilbert_elliot(8, GilbertElliot::new(8, 0.1, 0.5, 2), 7);
        let rep = m.run_events(&mut cluster).unwrap();
        assert_eq!(rep.deadline_violations, 0);
    }

    #[test]
    fn decode_measurement_records_cost() {
        let mut m = Master::new(
            SchemeConfig::gc(32, 5),
            RunConfig { jobs: 5, measure_decode: true, ..Default::default() },
        );
        let mut cluster = quiet_cluster(32, 4);
        let rep = m.run_events(&mut cluster).unwrap();
        let (mean, _std, max) = rep.decode_stats();
        assert!(mean > 0.0 && max >= mean);
    }

    #[test]
    fn lower_load_means_faster_rounds() {
        let mk = |cfg: SchemeConfig, seed| {
            let mut m = Master::new(cfg, RunConfig { jobs: 30, ..Default::default() });
            let n = 16;
            let mut cluster =
                SimCluster::from_gilbert_elliot(n, GilbertElliot::new(n, 0.03, 0.7, seed), seed);
            m.run_events(&mut cluster).unwrap().total_runtime_s
        };
        let gc = mk(SchemeConfig::gc(16, 6), 11);
        let msgc = mk(SchemeConfig::msgc(16, 1, 2, 6), 11);
        assert!(msgc < gc, "m-sgc {msgc} should beat gc {gc}");
    }
}
