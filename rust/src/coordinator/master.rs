//! The master's round loop.
//!
//! Each round the master: assigns tasks (scheme), executes them on the
//! cluster, applies the μ-rule to identify stragglers (Sec. 2), applies
//! the configured wait-out policy (Remark 2.3), commits the round into
//! the scheme state, and decodes every job whose results are complete
//! (timing the actual GC linear-algebra decode for Table 4).

use super::metrics::{RoundRecord, RunReport};
use crate::cluster::Cluster;
use crate::coding::{GcCode, Scheme, SchemeConfig, ToleranceSpec};
use crate::straggler::{Pattern, ToleranceChecker};
use crate::util::timer::Stopwatch;
use std::collections::HashMap;

/// Wait-out policy applied when the observed straggler pattern exceeds
/// what the scheme was designed for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WaitPolicy {
    /// Remark 2.3 (paper default): wait for stragglers, in completion
    /// order, until the effective pattern conforms to the design model.
    ConformanceRepair,
    /// Lazy ablation: only wait when the job due this round cannot be
    /// decoded; jobs may *miss deadlines permanently* under M-SGC because
    /// earlier non-conforming rounds can leave partial gradients
    /// unattempted (see DESIGN.md).
    DeadlineDecode,
    /// Wait for every worker in every round (the uncoded baseline's
    /// behaviour).
    WaitAll,
}

/// Run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Number of jobs `J`.
    pub jobs: usize,
    /// Straggler-detection tolerance μ (paper uses 1.0; Appendix L uses
    /// 5.0 for the storage-bound workload).
    pub mu: f64,
    pub wait_policy: WaitPolicy,
    /// Measure real GC decode solves and record their cost (Table 4).
    pub measure_decode: bool,
    /// Appendix K: when pipelining M > T+1 models, decode hides in the
    /// master's idle time and does not extend rounds.
    pub decode_in_idle: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            jobs: 100,
            mu: 1.0,
            wait_policy: WaitPolicy::ConformanceRepair,
            measure_decode: false,
            decode_in_idle: true,
        }
    }
}

/// Outcome of the μ-rule + wait-out decision for one round.
#[derive(Clone, Debug)]
pub struct RoundDecision {
    pub responded: Vec<bool>,
    pub duration: f64,
    pub kappa: f64,
    pub detected: usize,
    pub admitted: usize,
}

/// Apply the μ-rule and the wait-out policy to a round's completion
/// times. Shared by [`Master`] (metadata simulation) and
/// [`crate::train::MultiModelTrainer`] (real-compute runs).
///
/// `r` must be the currently assigned, uncommitted round of `scheme`.
pub fn decide_round(
    finish: &[f64],
    mu: f64,
    policy: WaitPolicy,
    checker: &ToleranceChecker,
    scheme: &dyn Scheme,
    r: usize,
    deadline_already_done: bool,
) -> RoundDecision {
    let n = finish.len();
    let kappa = finish.iter().cloned().fold(f64::INFINITY, f64::min);
    let cutoff = (1.0 + mu) * kappa;
    let mut responded: Vec<bool> = finish.iter().map(|&f| f <= cutoff).collect();
    let detected = n - responded.iter().filter(|&&x| x).count();
    let mut duration = if detected == 0 {
        finish.iter().cloned().fold(0.0, f64::max)
    } else {
        cutoff
    };

    let mut pending: Vec<usize> = (0..n).filter(|&i| !responded[i]).collect();
    pending.sort_by(|&a, &b| finish[a].partial_cmp(&finish[b]).unwrap());
    let mut admitted = 0usize;
    let mut next = pending.into_iter();
    loop {
        let satisfied = match policy {
            WaitPolicy::WaitAll => responded.iter().all(|&x| x),
            WaitPolicy::ConformanceRepair => {
                let stragglers: Vec<bool> = responded.iter().map(|&x| !x).collect();
                checker.acceptable(&stragglers)
            }
            WaitPolicy::DeadlineDecode => match scheme.deadline_job(r) {
                Some(t) if !deadline_already_done => scheme.decodable_with(t, r, &responded),
                _ => true,
            },
        };
        if satisfied {
            break;
        }
        match next.next() {
            Some(w) => {
                responded[w] = true;
                duration = duration.max(finish[w]);
                admitted += 1;
            }
            None => break,
        }
    }

    // Backstop (ConformanceRepair): the deadline job must decode now.
    if policy == WaitPolicy::ConformanceRepair {
        if let Some(t) = scheme.deadline_job(r) {
            if !deadline_already_done {
                let mut rest: Vec<usize> = (0..n).filter(|&i| !responded[i]).collect();
                rest.sort_by(|&a, &b| finish[a].partial_cmp(&finish[b]).unwrap());
                let mut rest = rest.into_iter();
                while !scheme.decodable_with(t, r, &responded) {
                    match rest.next() {
                        Some(w) => {
                            responded[w] = true;
                            duration = duration.max(finish[w]);
                            admitted += 1;
                        }
                        None => break,
                    }
                }
            }
        }
    }

    RoundDecision { responded, duration, kappa, detected, admitted }
}

/// The master node.
pub struct Master {
    scheme_cfg: SchemeConfig,
    cfg: RunConfig,
    /// GC decode solvers per code parameter `s`, shared across rounds so
    /// the coefficient cache persists (hot-path memoization).
    codes: HashMap<usize, GcCode>,
}

impl Master {
    pub fn new(scheme_cfg: SchemeConfig, cfg: RunConfig) -> Self {
        Master { scheme_cfg, cfg, codes: HashMap::new() }
    }

    /// Run `J` jobs over `J + T` rounds against the given cluster.
    pub fn run(&mut self, cluster: &mut dyn Cluster) -> RunReport {
        let mut scheme = self.scheme_cfg.build(self.cfg.jobs);
        let n = scheme.spec().n;
        assert_eq!(cluster.n(), n, "cluster/scheme size mismatch");
        let total_rounds = scheme.total_rounds();
        let wait_policy = if matches!(scheme.spec().tolerance, ToleranceSpec::None) {
            WaitPolicy::WaitAll
        } else {
            self.cfg.wait_policy
        };
        let mut checker = ToleranceChecker::new(n, scheme.spec().tolerance.clone());

        let mut clock = 0.0f64;
        let mut rounds = Vec::with_capacity(total_rounds);
        let mut job_done = vec![false; self.cfg.jobs];
        let mut job_completion = vec![f64::NAN; self.cfg.jobs];
        // First job that might still be pending: jobs decode (almost)
        // in order, so the per-round decode scan is O(T) instead of O(J).
        let mut frontier = 1usize;
        let mut violations = 0usize;
        let mut true_pattern = Pattern::new(n);
        let mut detected_pattern = Pattern::new(n);

        for r in 1..=total_rounds {
            let tasks = scheme.assign_round(r);
            let loads: Vec<f64> = tasks.iter().map(|t| scheme.spec().task_load(t)).collect();
            let sample = cluster.sample_round(&loads);
            true_pattern.push_round(sample.state.clone());

            let deadline_done =
                scheme.deadline_job(r).map(|t| job_done[t - 1]).unwrap_or(true);
            let decision = decide_round(
                &sample.finish,
                self.cfg.mu,
                wait_policy,
                &checker,
                scheme.as_ref(),
                r,
                deadline_done,
            );
            let RoundDecision { responded, mut duration, kappa, detected: initially_detected, admitted } =
                decision;
            detected_pattern.push_round(
                sample
                    .finish
                    .iter()
                    .map(|&f| f > (1.0 + self.cfg.mu) * kappa)
                    .collect(),
            );

            let effective_stragglers: Vec<bool> = responded.iter().map(|&x| !x).collect();
            checker.commit(&effective_stragglers);
            scheme.commit_round(r, &responded);

            // Decode every newly complete job; optionally time the real
            // linear-algebra decode.
            let mut completed = Vec::new();
            let mut decode_s = 0.0;
            for t in frontier..=self.cfg.jobs.min(r) {
                if job_done[t - 1] || !scheme.decodable(t) {
                    continue;
                }
                if self.cfg.measure_decode {
                    decode_s += self.time_decode(scheme.as_ref(), t);
                }
                job_done[t - 1] = true;
                completed.push(t);
            }
            while frontier <= self.cfg.jobs && job_done[frontier - 1] {
                frontier += 1;
            }
            if !self.cfg.decode_in_idle {
                duration += decode_s;
            }
            clock += duration;
            for &t in &completed {
                job_completion[t - 1] = clock;
            }
            if let Some(t) = scheme.deadline_job(r) {
                if !job_done[t - 1] {
                    violations += 1;
                }
            }
            rounds.push(RoundRecord {
                round: r,
                duration_s: duration,
                kappa_s: kappa,
                detected_stragglers: initially_detected,
                waited_out: admitted,
                decode_s,
                jobs_completed: completed,
            });
        }

        RunReport {
            scheme: self.scheme_cfg.label(),
            load: self.scheme_cfg.load(),
            delay: self.scheme_cfg.delay(),
            jobs: self.cfg.jobs,
            total_runtime_s: clock,
            rounds,
            job_completion_s: job_completion,
            deadline_violations: violations,
            true_pattern,
            effective_pattern: checker.pattern().clone(),
            detected_pattern,
        }
    }

    /// Time the actual decode work for a job: one coefficient solve per
    /// non-trivially coded group (replication groups decode by a trivial
    /// sum and cost ~0).
    fn time_decode(&mut self, scheme: &dyn Scheme, job: usize) -> f64 {
        let n = scheme.spec().n;
        let ledger = scheme.ledger(job);
        let sw = Stopwatch::start();
        for (got, &need) in ledger.coded_got.iter().zip(&ledger.coded_need) {
            if need <= 1 || need >= n {
                continue; // replication / degenerate group: trivial decode
            }
            let s = n - need;
            let code = self.codes.entry(s).or_insert_with(|| GcCode::new(n, s, 0xdec0de));
            let mut workers: Vec<usize> = got.iter().cloned().collect();
            workers.sort_unstable();
            workers.truncate(need);
            // The solve is the measured cost; failure here would mean a
            // non-decodable set, which `decodable()` already excluded.
            let _ = code.decode_coeffs(&workers);
        }
        sw.elapsed_s()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{LatencyParams, SimCluster};
    use crate::straggler::models::NoStragglers;
    use crate::straggler::{GilbertElliot, TraceProcess};

    fn quiet_cluster(n: usize, seed: u64) -> SimCluster {
        SimCluster::new(n, LatencyParams::default(), Box::new(NoStragglers { n }), seed)
    }

    #[test]
    fn gc_run_completes_all_jobs() {
        let mut m = Master::new(
            SchemeConfig::gc(8, 2),
            RunConfig { jobs: 20, ..Default::default() },
        );
        let mut cluster = quiet_cluster(8, 1);
        let rep = m.run(&mut cluster);
        assert_eq!(rep.deadline_violations, 0);
        assert!(rep.job_completion_s.iter().all(|t| t.is_finite()));
        assert_eq!(rep.rounds.len(), 20);
        assert!(rep.total_runtime_s > 0.0);
    }

    #[test]
    fn msgc_with_ge_stragglers_completes() {
        let n = 16;
        let mut m = Master::new(
            SchemeConfig::msgc(n, 1, 2, 4),
            RunConfig { jobs: 40, ..Default::default() },
        );
        let mut cluster =
            SimCluster::from_gilbert_elliot(n, GilbertElliot::new(n, 0.04, 0.7, 5), 9);
        let rep = m.run(&mut cluster);
        assert_eq!(rep.deadline_violations, 0, "conformance repair must save every deadline");
        assert_eq!(rep.rounds.len(), 40 + 1);
    }

    #[test]
    fn uncoded_waits_for_everyone() {
        let n = 8;
        let mut m = Master::new(
            SchemeConfig::uncoded(n),
            RunConfig { jobs: 10, ..Default::default() },
        );
        // a fresh straggler every round (rotating worker so severity
        // never decays)
        let rows: Vec<Vec<bool>> =
            (0..10).map(|r| (0..n).map(|i| i == r % n).collect()).collect();
        let pat = Pattern::from_rows(rows);
        let mut cluster = SimCluster::new(
            n,
            LatencyParams::default(),
            Box::new(TraceProcess::new(pat)),
            3,
        );
        let rep = m.run(&mut cluster);
        assert_eq!(rep.deadline_violations, 0);
        // every round waited out the straggler
        assert!(rep.rounds.iter().all(|r| r.waited_out >= 1));
        // and is therefore slow
        assert!(rep.mean_round_s() > 1.5);
    }

    #[test]
    fn deadline_decode_policy_tracks_violations() {
        // Lazy policy on GC never violates (waiting for decode directly).
        let mut m = Master::new(
            SchemeConfig::gc(8, 1),
            RunConfig {
                jobs: 15,
                wait_policy: WaitPolicy::DeadlineDecode,
                ..Default::default()
            },
        );
        let mut cluster =
            SimCluster::from_gilbert_elliot(8, GilbertElliot::new(8, 0.1, 0.5, 2), 7);
        let rep = m.run(&mut cluster);
        assert_eq!(rep.deadline_violations, 0);
    }

    #[test]
    fn decode_measurement_records_cost() {
        let mut m = Master::new(
            SchemeConfig::gc(32, 5),
            RunConfig { jobs: 5, measure_decode: true, ..Default::default() },
        );
        let mut cluster = quiet_cluster(32, 4);
        let rep = m.run(&mut cluster);
        let (mean, _std, max) = rep.decode_stats();
        assert!(mean > 0.0 && max >= mean);
    }

    #[test]
    fn lower_load_means_faster_rounds() {
        let mk = |cfg: SchemeConfig, seed| {
            let mut m = Master::new(cfg, RunConfig { jobs: 30, ..Default::default() });
            let n = 16;
            let mut cluster =
                SimCluster::from_gilbert_elliot(n, GilbertElliot::new(n, 0.03, 0.7, seed), seed);
            m.run(&mut cluster).total_runtime_s
        };
        let gc = mk(SchemeConfig::gc(16, 6), 11);
        let msgc = mk(SchemeConfig::msgc(16, 1, 2, 6), 11);
        assert!(msgc < gc, "m-sgc {msgc} should beat gc {gc}");
    }
}
