//! The master: round loop, μ-rule straggler detection, wait-out policies
//! and run metrics (Sec. 2 "Identification of stragglers", Remark 2.3,
//! Sec. 4 measurement methodology).

pub mod master;
pub mod metrics;

pub use master::{Master, RunConfig, WaitPolicy};
pub use metrics::{RoundRecord, RunReport};
