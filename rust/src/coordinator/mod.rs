//! The master facade and run metrics (Sec. 2 "Identification of
//! stragglers", Remark 2.3, Sec. 4 measurement methodology).
//!
//! The round protocol itself lives in [`crate::session`]; this module
//! keeps the one-call [`Master`] entry point plus the report types, and
//! re-exports the session's configuration under its historical names
//! (`RunConfig`, `WaitPolicy`) for callers of the classic API.

pub mod master;
pub mod metrics;

pub use crate::session::{SessionConfig as RunConfig, WaitPolicy};
pub use master::Master;
pub use metrics::{merge_segments, RoundRecord, RunReport};
