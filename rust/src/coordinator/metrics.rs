//! Run metrics: everything the paper's tables and figures are built from.

use crate::straggler::Pattern;
use crate::util::json::Json;
use crate::util::stats;

/// Per-round record.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    /// 1-based round number.
    pub round: usize,
    /// Wall-clock duration of the round (seconds).
    pub duration_s: f64,
    /// Fastest worker's completion time κ(t).
    pub kappa_s: f64,
    /// Workers beyond the μ-cutoff before any wait-out.
    pub detected_stragglers: usize,
    /// Workers admitted past the cutoff by the wait-out policy.
    pub waited_out: usize,
    /// Decode work performed at the end of this round (seconds).
    pub decode_s: f64,
    /// Jobs first decodable at the end of this round.
    pub jobs_completed: Vec<usize>,
}

/// Full report of one run.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Scheme label, e.g. `gc(n=256,s=15)`.
    pub scheme: String,
    /// Normalized per-worker load `L`.
    pub load: f64,
    /// Decoding delay `T`.
    pub delay: usize,
    /// Jobs `J` in the run.
    pub jobs: usize,
    /// Sum of round durations (the protocol clock).
    pub total_runtime_s: f64,
    /// Per-round records, in round order.
    pub rounds: Vec<RoundRecord>,
    /// Wall-clock time at which each job became decodable (`f64::NAN` if
    /// never — only possible under `WaitPolicy::DeadlineDecode`).
    pub job_completion_s: Vec<f64>,
    /// Jobs that missed their `t + T` deadline.
    pub deadline_violations: usize,
    /// Ground-truth straggler states per round (simulator-provided).
    pub true_pattern: Pattern,
    /// Effective straggler pattern after wait-outs (what the scheme saw).
    pub effective_pattern: Pattern,
    /// Stragglers detected by the μ-rule before wait-outs.
    pub detected_pattern: Pattern,
}

impl RunReport {
    /// Number of rounds where the wait-out policy extended the round.
    pub fn waitout_rounds(&self) -> usize {
        self.rounds.iter().filter(|r| r.waited_out > 0).count()
    }

    /// Mean round duration.
    pub fn mean_round_s(&self) -> f64 {
        stats::mean(&self.rounds.iter().map(|r| r.duration_s).collect::<Vec<_>>())
    }

    /// Cumulative (time, jobs-completed) curve — Fig. 2(a).
    pub fn completion_curve(&self) -> Vec<(f64, usize)> {
        let mut curve = Vec::with_capacity(self.rounds.len());
        let mut clock = 0.0;
        let mut done = 0usize;
        for r in &self.rounds {
            clock += r.duration_s;
            done += r.jobs_completed.len();
            curve.push((clock, done));
        }
        curve
    }

    /// Decode-time summary (Table 4): `(mean, std, max)` in seconds over
    /// rounds that performed decode work.
    pub fn decode_stats(&self) -> (f64, f64, f64) {
        let xs: Vec<f64> =
            self.rounds.iter().filter(|r| r.decode_s > 0.0).map(|r| r.decode_s).collect();
        if xs.is_empty() {
            return (0.0, 0.0, 0.0);
        }
        (stats::mean(&xs), stats::std_dev(&xs), stats::max(&xs))
    }

    /// Fastest round duration (Table 4's "Fastest Round" column).
    pub fn fastest_round_s(&self) -> f64 {
        self.rounds.iter().map(|r| r.duration_s).fold(f64::INFINITY, f64::min)
    }

    /// Serialize for `--out` experiment artifacts.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("scheme", self.scheme.as_str())
            .set("load", self.load)
            .set("delay", self.delay)
            .set("jobs", self.jobs)
            .set("total_runtime_s", self.total_runtime_s)
            .set("deadline_violations", self.deadline_violations)
            .set("waitout_rounds", self.waitout_rounds())
            .set("mean_round_s", self.mean_round_s())
            .set(
                "round_durations_s",
                self.rounds.iter().map(|r| r.duration_s).collect::<Vec<_>>(),
            )
            .set(
                "job_completion_s",
                self.job_completion_s.clone(),
            );
        o
    }
}

/// Merge the per-segment reports of a hot-swapped run (see
/// [`crate::adapt`]) into one continuous [`RunReport`].
///
/// `assigned[i]` is the number of paper-jobs segment `i` actually
/// *owned*: the truncation cap for every swapped-away segment, the
/// segment's full job count for the final one. Rounds are renumbered
/// into one continuous sequence, job ids and completion clocks are
/// offset by the preceding segments' totals, and the straggler patterns
/// are concatenated. Decodes a truncated segment achieved for jobs
/// beyond its cap are dropped — those jobs were handed to (and are
/// reported by) the successor segment.
pub fn merge_segments(segments: &[RunReport], assigned: &[usize]) -> RunReport {
    assert_eq!(segments.len(), assigned.len(), "one assigned-job count per segment");
    assert!(!segments.is_empty(), "at least one segment");
    if segments.len() == 1 {
        return segments[0].clone();
    }
    let n = segments[0].true_pattern.n;
    let last = segments.last().expect("non-empty");
    let mut rounds = Vec::new();
    let mut job_completion_s = Vec::new();
    let mut true_pattern = Pattern::new(n);
    let mut effective_pattern = Pattern::new(n);
    let mut detected_pattern = Pattern::new(n);
    let mut violations = 0usize;
    let mut clock_base = 0.0f64;
    let mut round_base = 0usize;
    let mut job_base = 0usize;
    for (seg, &cap) in segments.iter().zip(assigned) {
        for r in &seg.rounds {
            rounds.push(RoundRecord {
                round: round_base + r.round,
                jobs_completed: r
                    .jobs_completed
                    .iter()
                    .filter(|&&t| t <= cap)
                    .map(|&t| job_base + t)
                    .collect(),
                ..r.clone()
            });
        }
        job_completion_s
            .extend(seg.job_completion_s.iter().take(cap).map(|&t| clock_base + t));
        for p in [
            (&seg.true_pattern, &mut true_pattern),
            (&seg.effective_pattern, &mut effective_pattern),
            (&seg.detected_pattern, &mut detected_pattern),
        ] {
            for row in &p.0.rows {
                p.1.push_round(row.clone());
            }
        }
        violations += seg.deadline_violations;
        clock_base += seg.total_runtime_s;
        round_base += seg.rounds.len();
        job_base += cap;
    }
    RunReport {
        scheme: segments.iter().map(|s| s.scheme.as_str()).collect::<Vec<_>>().join("->"),
        load: last.load,
        delay: last.delay,
        jobs: job_base,
        total_runtime_s: clock_base,
        rounds,
        job_completion_s,
        deadline_violations: violations,
        true_pattern,
        effective_pattern,
        detected_pattern,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_report() -> RunReport {
        RunReport {
            scheme: "test".into(),
            load: 0.1,
            delay: 1,
            jobs: 3,
            total_runtime_s: 6.0,
            rounds: vec![
                RoundRecord {
                    round: 1,
                    duration_s: 1.0,
                    kappa_s: 0.5,
                    detected_stragglers: 2,
                    waited_out: 0,
                    decode_s: 0.0,
                    jobs_completed: vec![],
                },
                RoundRecord {
                    round: 2,
                    duration_s: 2.0,
                    kappa_s: 0.5,
                    detected_stragglers: 0,
                    waited_out: 1,
                    decode_s: 0.1,
                    jobs_completed: vec![1, 2],
                },
                RoundRecord {
                    round: 3,
                    duration_s: 3.0,
                    kappa_s: 0.5,
                    detected_stragglers: 1,
                    waited_out: 0,
                    decode_s: 0.3,
                    jobs_completed: vec![3],
                },
            ],
            job_completion_s: vec![3.0, 3.0, 6.0],
            deadline_violations: 0,
            true_pattern: Pattern::new(4),
            effective_pattern: Pattern::new(4),
            detected_pattern: Pattern::new(4),
        }
    }

    #[test]
    fn completion_curve_accumulates() {
        let r = mk_report();
        assert_eq!(r.completion_curve(), vec![(1.0, 0), (3.0, 2), (6.0, 3)]);
        assert_eq!(r.waitout_rounds(), 1);
        assert!((r.mean_round_s() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn merge_segments_renumbers_and_offsets() {
        // segment 1 owned 2 jobs (cap 2; its round-3 decode of job 3 was
        // beyond the cap and belongs to the successor), segment 2 the rest
        let a = mk_report();
        let mut b = mk_report();
        b.scheme = "next".into();
        b.jobs = 2;
        b.rounds.truncate(2);
        b.job_completion_s = vec![1.0, 3.0];
        b.total_runtime_s = 3.0;
        let merged = merge_segments(&[a.clone(), b], &[2, 2]);
        assert_eq!(merged.scheme, "test->next");
        assert_eq!(merged.jobs, 4);
        assert!((merged.total_runtime_s - 9.0).abs() < 1e-12);
        assert_eq!(merged.rounds.len(), 5);
        // continuous round numbering
        assert_eq!(merged.rounds.iter().map(|r| r.round).collect::<Vec<_>>(), vec![1, 2, 3, 4, 5]);
        // beyond-cap decode (job 3 of segment 1) dropped; successor jobs offset
        assert_eq!(merged.rounds[1].jobs_completed, vec![1, 2]);
        assert!(merged.rounds[2].jobs_completed.is_empty());
        assert_eq!(merged.rounds[3].jobs_completed, vec![3, 4]);
        // completions: first cap entries of each, successor offset by 6.0
        assert_eq!(merged.job_completion_s, vec![3.0, 3.0, 7.0, 9.0]);
        // single segment merges to itself
        let solo = merge_segments(&[a.clone()], &[3]);
        assert_eq!(format!("{solo:?}"), format!("{a:?}"));
    }

    #[test]
    fn decode_stats_skip_empty_rounds() {
        let r = mk_report();
        let (mean, _std, max) = r.decode_stats();
        assert!((mean - 0.2).abs() < 1e-12);
        assert!((max - 0.3).abs() < 1e-12);
        assert!((r.fastest_round_s() - 1.0).abs() < 1e-12);
    }
}
