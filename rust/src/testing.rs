//! Randomized property-testing harness (proptest is unavailable offline).
//!
//! [`check`] runs a property over many seeded random cases; on failure it
//! re-runs a bounded shrink loop that retries the generator with "smaller"
//! size hints, then reports the smallest failing seed so the case can be
//! replayed deterministically:
//!
//! ```no_run
//! use sgc::testing::{check, Gen};
//! check("sum is commutative", 200, |g| {
//!     let a = g.usize_in(0, 100);
//!     let b = g.usize_in(0, 100);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::rng::Pcg32;

/// Random case generator handed to properties. Wraps an RNG plus a size
/// hint the shrink loop drives down.
pub struct Gen {
    rng: Pcg32,
    /// Size multiplier in (0, 1]; generators should scale ranges by it.
    pub size: f64,
    /// Case index (for diagnostics).
    pub case: usize,
}

impl Gen {
    /// Generator for one case of a property run.
    pub fn new(seed: u64, case: usize, size: f64) -> Self {
        Gen { rng: Pcg32::new(seed, case as u64), size, case }
    }

    /// Direct access to the underlying RNG stream.
    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }

    /// usize in `[lo, hi]`, range shrunk towards `lo` by the size hint.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        let span = ((hi - lo) as f64 * self.size).round() as usize;
        self.rng.range_usize(lo, lo + span)
    }

    /// f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, lo + (hi - lo) * self.size)
    }

    /// Fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Pick one of the provided choices.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }

    /// Random subset of `[0, n)` with each element included w.p. `p`.
    pub fn subset(&mut self, n: usize, p: f64) -> Vec<usize> {
        (0..n).filter(|_| self.rng.chance(p)).collect()
    }
}

/// Run `prop` over `cases` random cases. Panics (with replay info) if any
/// case fails; the failing case is re-run at smaller sizes first to report
/// the smallest reproduction found.
pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: usize, prop: F) {
    let seed = std::env::var("SGC_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5eeded_u64);
    for case in 0..cases {
        if run_case(&prop, seed, case, 1.0).is_ok() {
            continue;
        }
        // Shrink: retry the same case seed with smaller size hints.
        let mut smallest_failure = 1.0;
        for &size in &[0.05, 0.1, 0.25, 0.5, 0.75] {
            if run_case(&prop, seed, case, size).is_err() {
                smallest_failure = size;
                break;
            }
        }
        // Re-run the smallest failure outside catch_unwind for the real
        // panic message/backtrace.
        crate::log_error!(
            "property '{name}' failed: seed={seed} case={case} size={smallest_failure} \
             (replay with SGC_PROP_SEED={seed})"
        );
        let mut g = Gen::new(seed, case, smallest_failure);
        prop(&mut g);
        unreachable!("property failed under catch_unwind but passed on replay");
    }
}

fn run_case<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    prop: &F,
    seed: u64,
    case: usize,
    size: f64,
) -> Result<(), ()> {
    let result = std::panic::catch_unwind(|| {
        let mut g = Gen::new(seed, case, size);
        prop(&mut g);
    });
    result.map_err(|_| ())
}

/// Quiet wrapper that suppresses the default panic hook while probing
/// cases (the shrink loop intentionally panics many times).
pub fn check_quiet<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    name: &str,
    cases: usize,
    prop: F,
) {
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        check(name, cases, prop);
    }));
    std::panic::set_hook(prev);
    if let Err(e) = outcome {
        std::panic::resume_unwind(e);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("add-commutes", 50, |g| {
            let a = g.usize_in(0, 1000);
            let b = g.usize_in(0, 1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic]
    fn failing_property_panics() {
        check_quiet("always-false", 10, |_g| {
            panic!("nope");
        });
    }

    #[test]
    fn subset_in_range() {
        check("subset-bounds", 50, |g| {
            let s = g.subset(30, 0.3);
            assert!(s.iter().all(|&i| i < 30));
            assert!(s.windows(2).all(|w| w[0] < w[1]));
        });
    }
}
