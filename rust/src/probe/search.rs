//! Grid search over coding-scheme parameters (Appendix J).
//!
//! For each candidate `(B, W, λ)` (or `s` for GC), estimate the total
//! runtime by replaying the load-adjusted reference profile through the
//! actual round protocol ([`crate::session::SgcSession`]), and pick the
//! fastest. Candidates are independent sessions, so the search fans out
//! over the parallel batch driver ([`crate::session::run_parallel`]).

use super::profile::{DelayProfile, ProfileCluster};
use crate::cluster::Cluster;
use crate::coding::{SchemeConfig, SchemeKind};
use crate::session::{self, BatchItem, SessionConfig};

/// A candidate scheme with its estimated runtime.
#[derive(Clone, Debug)]
pub struct Candidate {
    /// The scheme parameters under evaluation.
    pub config: SchemeConfig,
    /// Normalized per-worker load of the candidate.
    pub load: f64,
    /// Runtime estimated by replaying the probe profile.
    pub estimated_runtime_s: f64,
}

/// Which parameter grid to search.
#[derive(Clone, Debug)]
pub struct SearchSpace {
    /// Worker count every candidate is built for.
    pub n: usize,
    /// B values to try.
    pub b: Vec<usize>,
    /// W values to try (filtered per scheme validity).
    pub w: Vec<usize>,
    /// λ values to try.
    pub lambda: Vec<usize>,
    /// s values for plain GC.
    pub s: Vec<usize>,
}

impl SearchSpace {
    /// The paper's search ranges, scaled to cluster size `n`.
    pub fn paper_default(n: usize) -> Self {
        let lam_max = (n / 8).max(8).min(n);
        SearchSpace {
            n,
            b: vec![1, 2, 3],
            w: (2..=7).collect(),
            lambda: (1..=lam_max).collect(),
            s: (1..=(n / 8).max(4)).collect(),
        }
    }

    /// Enumerate valid SR-SGC configs.
    pub fn sr_sgc_candidates(&self) -> Vec<SchemeConfig> {
        let mut out = Vec::new();
        for &b in &self.b {
            for &w in &self.w {
                if w <= 1 || (w - 1) % b != 0 {
                    continue;
                }
                for &lambda in &self.lambda {
                    let s = (b * lambda).div_ceil(w - 1 + b);
                    if s == 0 || s >= self.n {
                        continue;
                    }
                    out.push(SchemeConfig::sr_sgc(self.n, b, w, lambda));
                }
            }
        }
        out
    }

    /// Enumerate valid M-SGC configs.
    pub fn m_sgc_candidates(&self) -> Vec<SchemeConfig> {
        let mut out = Vec::new();
        for &b in &self.b {
            for &w in &self.w {
                if b >= w {
                    continue;
                }
                for &lambda in &self.lambda {
                    if lambda >= self.n {
                        continue;
                    }
                    out.push(SchemeConfig::msgc(self.n, b, w, lambda));
                }
            }
        }
        out
    }

    /// Enumerate GC configs.
    pub fn gc_candidates(&self) -> Vec<SchemeConfig> {
        self.s.iter().map(|&s| SchemeConfig::gc(self.n, s)).collect()
    }
}

/// Estimate total runtime of a scheme over `jobs` jobs by replaying the
/// load-adjusted profile through the real round protocol. Cloning the
/// profile is O(1) (shared `Arc` delay matrix), so per-candidate
/// estimation costs only the session replay itself.
pub fn estimate_runtime(
    config: &SchemeConfig,
    profile: &DelayProfile,
    alpha: f64,
    jobs: usize,
) -> f64 {
    let mut cluster = ProfileCluster::new(profile.clone(), alpha);
    let cfg = SessionConfig { jobs, ..Default::default() };
    session::drive(config, &cfg, &mut cluster)
        .expect("profile and candidate share n by construction")
        .total_runtime_s
}

/// Grid-search a candidate list; returns candidates sorted by estimated
/// runtime (best first). Candidate replays run concurrently on the batch
/// driver; results are deterministic (the profile replay has no mutable
/// shared state across candidates — every candidate's cluster holds an
/// O(1) clone of one shared delay matrix).
pub fn grid_search(
    candidates: &[SchemeConfig],
    profile: &DelayProfile,
    alpha: f64,
    jobs: usize,
) -> Vec<Candidate> {
    let items: Vec<BatchItem> = candidates
        .iter()
        .map(|c| BatchItem {
            scheme: c.clone(),
            session: SessionConfig { jobs, ..Default::default() },
        })
        .collect();
    let profile = profile.clone();
    let reports = session::run_parallel(items, session::default_threads(), move |_, _| {
        Box::new(ProfileCluster::new(profile.clone(), alpha)) as Box<dyn Cluster + Send>
    })
    .expect("profile and candidates share n by construction");
    let mut out: Vec<Candidate> = candidates
        .iter()
        .zip(reports)
        .map(|(c, report)| Candidate {
            config: c.clone(),
            load: c.load(),
            estimated_runtime_s: report.total_runtime_s,
        })
        .collect();
    out.sort_by(|a, b| a.estimated_runtime_s.partial_cmp(&b.estimated_runtime_s).unwrap());
    out
}

/// Human-readable label for a candidate kind (for Table-3-style reports).
pub fn kind_name(k: &SchemeKind) -> &'static str {
    match k {
        SchemeKind::Gc { .. } => "GC",
        SchemeKind::GcRep { .. } => "GC-Rep",
        SchemeKind::SrSgc { .. } => "SR-SGC",
        SchemeKind::SrSgcRep { .. } => "SR-SGC-Rep",
        SchemeKind::MSgc { .. } => "M-SGC",
        SchemeKind::MSgcRep { .. } => "M-SGC-Rep",
        SchemeKind::Uncoded => "No Coding",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{EventCluster, SimCluster};
    use crate::straggler::GilbertElliot;

    #[test]
    fn candidate_enumeration_validity() {
        let sp = SearchSpace::paper_default(16);
        for c in sp.sr_sgc_candidates() {
            // constructible without panicking
            let _ = c.build(2);
        }
        for c in sp.m_sgc_candidates() {
            let _ = c.build(2);
        }
        assert!(!sp.gc_candidates().is_empty());
    }

    #[test]
    fn grid_search_prefers_low_runtime() {
        let n = 16;
        let mut cluster =
            SimCluster::from_gilbert_elliot(n, GilbertElliot::new(n, 0.05, 0.6, 3), 4).sync();
        let profile = DelayProfile::capture(&mut cluster, 12, 1.0 / n as f64);
        let cands = vec![
            SchemeConfig::gc(n, 2),
            SchemeConfig::gc(n, 6),
            SchemeConfig::gc(n, 12),
        ];
        let ranked = grid_search(&cands, &profile, 9.5, 12);
        assert_eq!(ranked.len(), 3);
        assert!(ranked.windows(2).all(|w| {
            w[0].estimated_runtime_s <= w[1].estimated_runtime_s
        }));
    }
}
