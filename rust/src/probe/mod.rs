//! Parameter selection (Appendix J): delay-profile capture, load-adjusted
//! runtime estimation, and grid search over scheme parameters.

pub mod profile;
pub mod search;

pub use profile::{DelayProfile, ProfileCluster};
pub use search::{estimate_runtime, grid_search, Candidate, SearchSpace};
