//! Reference delay profiles (Appendix J).
//!
//! The master runs `T_probe` *uncoded* rounds and stores each worker's
//! completion time (`normalized load = 1/n`). A candidate coding scheme
//! with load `L` is then evaluated by replaying the profile with the
//! Fig.-16 load adjustment: every time is shifted by `(L − 1/n) · α`,
//! where `α` is the fitted seconds-per-unit-load slope.

use crate::cluster::{Cluster, RoundSample};
use crate::util::stats;
use std::sync::Arc;

/// A recorded per-round, per-worker completion-time profile.
///
/// The delay matrix is behind an `Arc`, so cloning a profile is O(1): a
/// grid search fanning hundreds of candidate replays out of one profile
/// shares a single `O(n × rounds)` matrix instead of deep-copying it per
/// candidate (§Perf).
#[derive(Clone, Debug)]
pub struct DelayProfile {
    /// Worker count.
    pub n: usize,
    /// Load at which the profile was captured (1/n for uncoded probing).
    pub base_load: f64,
    /// `times[r][i]` — completion time of worker `i` in probe round `r`.
    pub times: Arc<Vec<Vec<f64>>>,
}

impl DelayProfile {
    /// Capture a profile by running `rounds` rounds on a cluster at
    /// `base_load` per worker.
    pub fn capture(cluster: &mut dyn Cluster, rounds: usize, base_load: f64) -> Self {
        let n = cluster.n();
        let loads = vec![base_load; n];
        let times =
            Arc::new((0..rounds).map(|_| cluster.sample_round(&loads).finish).collect());
        DelayProfile { n, base_load, times }
    }

    /// Build a profile from a recorded run trace
    /// ([`crate::cluster::RunTrace`]): the delay matrix feeds the
    /// Appendix-J candidate search, with the trace's mean recorded load
    /// as the base load for the Fig.-16 adjustment.
    pub fn from_trace(trace: &crate::cluster::RunTrace) -> Self {
        let loads: Vec<f64> = trace.rounds.iter().flat_map(|r| r.loads.clone()).collect();
        let base_load = if loads.is_empty() { 0.0 } else { stats::mean(&loads) };
        DelayProfile {
            n: trace.n,
            base_load,
            times: Arc::new(trace.rounds.iter().map(|r| r.finish.clone()).collect()),
        }
    }

    /// Probe rounds captured.
    pub fn rounds(&self) -> usize {
        self.times.len()
    }

    /// Mean worker completion time across the profile.
    pub fn mean_time(&self) -> f64 {
        let all: Vec<f64> = self.times.iter().flatten().cloned().collect();
        stats::mean(&all)
    }

    /// Fit the load slope α (Fig. 16) from a set of (load, mean time)
    /// calibration points.
    pub fn fit_alpha(points: &[(f64, f64)]) -> f64 {
        let xs: Vec<f64> = points.iter().map(|p| p.0).collect();
        let ys: Vec<f64> = points.iter().map(|p| p.1).collect();
        stats::linear_fit(&xs, &ys).1
    }
}

/// A [`Cluster`] that replays a delay profile with the Appendix-J load
/// adjustment — this is exactly how the paper's master "simulates" a
/// candidate scheme before committing to it. Holding a profile clone is
/// cheap (shared `Arc` matrix), so every grid-search candidate gets its
/// own cursor over one shared recording.
pub struct ProfileCluster {
    profile: DelayProfile,
    /// Fitted seconds-per-unit-load slope α.
    pub alpha: f64,
    cursor: usize,
}

impl ProfileCluster {
    /// Replay `profile`, scaling times by `alpha` per unit of load.
    pub fn new(profile: DelayProfile, alpha: f64) -> Self {
        ProfileCluster { profile, alpha, cursor: 0 }
    }
}

impl Cluster for ProfileCluster {
    fn n(&self) -> usize {
        self.profile.n
    }

    fn sample_round(&mut self, loads: &[f64]) -> RoundSample {
        let row = &self.profile.times[self.cursor % self.profile.rounds()];
        self.cursor += 1;
        let finish: Vec<f64> = row
            .iter()
            .zip(loads)
            .map(|(&t, &l)| (t + (l - self.profile.base_load) * self.alpha).max(1e-6))
            .collect();
        // The replayer has no ground-truth states; report no straggling
        // (analysis uses the μ-rule detections instead).
        RoundSample { state: vec![false; self.profile.n], finish }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{EventCluster, LatencyParams, SimCluster, SyncAdapter};
    use crate::straggler::models::NoStragglers;

    fn cluster(n: usize) -> SyncAdapter<SimCluster> {
        SimCluster::new(n, LatencyParams::default(), Box::new(NoStragglers { n }), 5).sync()
    }

    #[test]
    fn capture_shapes() {
        let mut c = cluster(8);
        let p = DelayProfile::capture(&mut c, 10, 1.0 / 8.0);
        assert_eq!(p.rounds(), 10);
        assert_eq!(p.times[0].len(), 8);
        assert!(p.mean_time() > 0.0);
    }

    #[test]
    fn clone_shares_the_delay_matrix() {
        let mut c = cluster(4);
        let p = DelayProfile::capture(&mut c, 6, 0.25);
        let q = p.clone();
        assert!(Arc::ptr_eq(&p.times, &q.times), "clone must not deep-copy the matrix");
    }

    #[test]
    fn load_adjustment_shifts_times() {
        let mut c = cluster(4);
        let p = DelayProfile::capture(&mut c, 5, 0.25);
        let alpha = 10.0;
        let mut pc = ProfileCluster::new(p.clone(), alpha);
        let base = pc.sample_round(&vec![0.25; 4]);
        let mut pc2 = ProfileCluster::new(p, alpha);
        let up = pc2.sample_round(&vec![0.35; 4]);
        for (b, u) in base.finish.iter().zip(&up.finish) {
            assert!((u - b - 1.0).abs() < 1e-9, "expected +1s shift, got {}", u - b);
        }
    }

    #[test]
    fn fit_alpha_recovers_slope() {
        let pts: Vec<(f64, f64)> = (0..10).map(|i| (i as f64 * 0.1, 1.0 + 9.5 * i as f64 * 0.1)).collect();
        assert!((DelayProfile::fit_alpha(&pts) - 9.5).abs() < 1e-9);
    }
}
