//! Leveled logging facade for library diagnostics.
//!
//! The library layers (fleet master, reactor, trace recorder, bench
//! harness, property-test harness) used to diagnose straight through
//! bare `eprintln!`. This facade replaces those call sites with leveled
//! emission controlled by the `SGC_LOG` environment variable
//! (`off|error|warn|info|debug`) and programmatically by [`set_level`]
//! (the `sgc --verbose` flag maps to [`Level::Info`]). The default is
//! [`Level::Warn`]: errors and warnings always reach stderr, membership
//! and progress chatter is opt-in.
//!
//! Cost model: an enabled-check is one relaxed atomic load, and the
//! [`log_warn!`](crate::log_warn)-family macros only evaluate their
//! format arguments *after* the check passes — a suppressed level costs
//! nothing beyond that load. Deliberate CLI output (tables, reports,
//! usage) stays on `println!` in `main.rs` and is not routed here.

use std::sync::atomic::{AtomicU8, Ordering};

/// Diagnostic severity. Higher levels include all lower ones: setting
/// [`Level::Info`] shows errors, warnings and info lines.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Suppress everything, including errors (`SGC_LOG=off`).
    Off,
    /// An operation failed and was abandoned.
    Error,
    /// Something unexpected that the code recovered from (default).
    Warn,
    /// Membership and progress chatter (`--verbose` / `SGC_LOG=info`).
    Info,
    /// Per-event detail (`SGC_LOG=debug`).
    Debug,
}

impl Level {
    /// Short lowercase label used as the stderr line prefix.
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Off => "off",
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    fn rank(self) -> u8 {
        match self {
            Level::Off => 1,
            Level::Error => 2,
            Level::Warn => 3,
            Level::Info => 4,
            Level::Debug => 5,
        }
    }
}

/// 0 means "not yet initialized from the environment"; otherwise the
/// stored value is `Level::rank()` of the active threshold.
static LEVEL: AtomicU8 = AtomicU8::new(0);

fn rank_from_env() -> u8 {
    match std::env::var("SGC_LOG").ok().as_deref() {
        Some("off") | Some("none") => Level::Off.rank(),
        Some("error") => Level::Error.rank(),
        Some("warn") => Level::Warn.rank(),
        Some("info") => Level::Info.rank(),
        Some("debug") => Level::Debug.rank(),
        _ => Level::Warn.rank(),
    }
}

fn current_rank() -> u8 {
    match LEVEL.load(Ordering::Relaxed) {
        0 => {
            let r = rank_from_env();
            LEVEL.store(r, Ordering::Relaxed);
            r
        }
        r => r,
    }
}

/// Set the active threshold, overriding `SGC_LOG`. `sgc --verbose`
/// calls this with [`Level::Info`].
pub fn set_level(level: Level) {
    LEVEL.store(level.rank(), Ordering::Relaxed);
}

/// Would a message at `level` be emitted right now? The macros check
/// this before formatting; call it directly to skip expensive argument
/// preparation.
pub fn enabled(level: Level) -> bool {
    level != Level::Off && level.rank() <= current_rank()
}

/// Emit one pre-formatted diagnostic line to stderr. Prefer the
/// [`log_warn!`](crate::log_warn)-family macros, which gate on
/// [`enabled`] before formatting.
pub fn emit(level: Level, args: std::fmt::Arguments<'_>) {
    eprintln!("sgc[{}] {}", level.as_str(), args);
}

/// Log at [`Level::Error`]: the operation failed and was abandoned.
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Error) {
            $crate::obs::log::emit($crate::obs::log::Level::Error, format_args!($($arg)*));
        }
    };
}

/// Log at [`Level::Warn`]: unexpected but recovered. Shown by default.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Warn) {
            $crate::obs::log::emit($crate::obs::log::Level::Warn, format_args!($($arg)*));
        }
    };
}

/// Log at [`Level::Info`]: progress and membership chatter. Hidden
/// unless `--verbose` / `SGC_LOG=info`.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Info) {
            $crate::obs::log::emit($crate::obs::log::Level::Info, format_args!($($arg)*));
        }
    };
}

/// Log at [`Level::Debug`]: per-event detail (`SGC_LOG=debug`).
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Debug) {
            $crate::obs::log::emit($crate::obs::log::Level::Debug, format_args!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_gate_in_severity_order() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));

        set_level(Level::Debug);
        assert!(enabled(Level::Debug));

        set_level(Level::Off);
        assert!(!enabled(Level::Error));

        // restore the default so concurrently running tests keep the
        // usual errors-and-warnings behavior
        set_level(Level::Warn);
    }
}
