//! Metrics registry: counters, gauges and fixed-bucket latency
//! histograms with Prometheus text exposition.
//!
//! Cost model, pinned by `tests/alloc.rs`: *registration* allocates
//! (metric names, label strings, the bucket array); *recording* is
//! allocation-free — a counter bump or gauge store is one relaxed
//! atomic op, a histogram record is a scan over a fixed bucket array
//! plus two atomic updates. Handles ([`Counter`], [`Gauge`],
//! [`Histogram`]) are `Arc`-backed and cheap to clone, so layers hold
//! their own handles and never touch the registry on the hot path.
//! Rendering the Prometheus exposition ([`MetricsRegistry::render_prometheus`])
//! is the cold scrape path and may allocate freely.
//!
//! Histograms expose p50/p90/p99 estimates by linear interpolation
//! inside the owning bucket, rendered in Prometheus *summary* style
//! (`name{quantile="0.5"} …` plus `name_sum`/`name_count`). The
//! estimate's resolution is the bucket width — adequate for latency
//! dashboards, and the fixed bounds are what keep recording
//! allocation-free.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotone event counter (wraps at `u64::MAX`, i.e. never in practice).
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// Increment by one.
    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Increment by `k`.
    #[inline]
    pub fn add(&self, k: u64) {
        self.0.fetch_add(k, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins instantaneous value, stored as `f64` bits.
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    /// Set the gauge.
    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Default latency bucket upper bounds in seconds: log-spaced from
/// 1 ms to 64 s, which covers simulated rounds (tens of ms) through
/// fleet wait-outs (multiple timeouts).
pub const LATENCY_BUCKETS: [f64; 17] = [
    0.001, 0.002, 0.004, 0.008, 0.016, 0.032, 0.064, 0.128, 0.256, 0.512, 1.0, 2.0, 4.0, 8.0,
    16.0, 32.0, 64.0,
];

struct HistogramInner {
    /// Sorted finite bucket upper bounds; observations above the last
    /// bound land in an implicit overflow bucket.
    bounds: Box<[f64]>,
    /// One count per bound plus the overflow bucket (`bounds.len() + 1`).
    counts: Box<[AtomicU64]>,
    /// Atomic `f64` accumulator (bit-cast; CAS loop on record).
    sum_bits: AtomicU64,
    count: AtomicU64,
}

/// Fixed-bucket histogram with quantile estimation. Cloning shares the
/// underlying buckets.
#[derive(Clone)]
pub struct Histogram(Arc<HistogramInner>);

impl Histogram {
    fn with_bounds(bounds: &[f64]) -> Self {
        let counts = (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect();
        Histogram(Arc::new(HistogramInner {
            bounds: bounds.into(),
            counts,
            sum_bits: AtomicU64::new(0.0f64.to_bits()),
            count: AtomicU64::new(0),
        }))
    }

    /// Record one observation. Allocation-free: a bucket scan plus two
    /// atomic updates (the sum is a CAS loop, uncontended in the
    /// single-threaded reactor and scheduler).
    #[inline]
    pub fn record(&self, v: f64) {
        let h = &*self.0;
        let mut idx = h.bounds.len();
        for (i, b) in h.bounds.iter().enumerate() {
            if v <= *b {
                idx = i;
                break;
            }
        }
        h.counts[idx].fetch_add(1, Ordering::Relaxed);
        h.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = h.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match h
                .sum_bits
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Total number of observations.
    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    /// Sum of all observations.
    pub fn sum(&self) -> f64 {
        f64::from_bits(self.0.sum_bits.load(Ordering::Relaxed))
    }

    /// Estimate the `q`-quantile (`q` in `0.0..=1.0`) by linear
    /// interpolation inside the bucket that holds it. Returns `NaN`
    /// with no observations; observations in the overflow bucket
    /// report the largest finite bound.
    pub fn quantile(&self, q: f64) -> f64 {
        let h = &*self.0;
        let total = h.count.load(Ordering::Relaxed);
        if total == 0 {
            return f64::NAN;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, cell) in h.counts.iter().enumerate() {
            let c = cell.load(Ordering::Relaxed);
            if c > 0 && seen + c >= target {
                let lo = if i == 0 { 0.0 } else { h.bounds[i - 1] };
                if i == h.bounds.len() {
                    return lo; // overflow bucket: clamp to the last bound
                }
                let frac = (target - seen) as f64 / c as f64;
                return lo + (h.bounds[i] - lo) * frac;
            }
            seen += c;
        }
        h.bounds.last().copied().unwrap_or(f64::NAN)
    }
}

enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

struct Entry {
    name: String,
    labels: String,
    help: String,
    metric: Metric,
}

/// Registry of every metric the process exposes. Layers register once
/// (allocating) and keep the returned handle; the `/metrics` endpoint
/// renders the whole registry on demand. Registering the same
/// `(name, labels)` pair again returns the existing handle, so
/// re-instrumenting across scheduler runs never duplicates series.
pub struct MetricsRegistry {
    entries: Mutex<Vec<Entry>>,
}

impl MetricsRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        MetricsRegistry { entries: Mutex::new(Vec::new()) }
    }

    /// Register (or look up) a counter. `labels` is the literal
    /// Prometheus label body, e.g. `job="0"`, or `""` for none.
    pub fn counter(&self, name: &str, labels: &str, help: &str) -> Counter {
        let mut entries = self.entries.lock().expect("metrics registry poisoned");
        for e in entries.iter() {
            if e.name == name && e.labels == labels {
                if let Metric::Counter(c) = &e.metric {
                    return c.clone();
                }
            }
        }
        let c = Counter(Arc::new(AtomicU64::new(0)));
        entries.push(Entry {
            name: name.to_string(),
            labels: labels.to_string(),
            help: help.to_string(),
            metric: Metric::Counter(c.clone()),
        });
        c
    }

    /// Register (or look up) a gauge.
    pub fn gauge(&self, name: &str, labels: &str, help: &str) -> Gauge {
        let mut entries = self.entries.lock().expect("metrics registry poisoned");
        for e in entries.iter() {
            if e.name == name && e.labels == labels {
                if let Metric::Gauge(g) = &e.metric {
                    return g.clone();
                }
            }
        }
        let g = Gauge(Arc::new(AtomicU64::new(0.0f64.to_bits())));
        entries.push(Entry {
            name: name.to_string(),
            labels: labels.to_string(),
            help: help.to_string(),
            metric: Metric::Gauge(g.clone()),
        });
        g
    }

    /// Register (or look up) a latency histogram with the default
    /// [`LATENCY_BUCKETS`].
    pub fn histogram(&self, name: &str, labels: &str, help: &str) -> Histogram {
        self.histogram_with_buckets(name, labels, help, &LATENCY_BUCKETS)
    }

    /// Register (or look up) a histogram with caller-chosen bucket
    /// upper bounds (must be sorted ascending).
    pub fn histogram_with_buckets(
        &self,
        name: &str,
        labels: &str,
        help: &str,
        bounds: &[f64],
    ) -> Histogram {
        let mut entries = self.entries.lock().expect("metrics registry poisoned");
        for e in entries.iter() {
            if e.name == name && e.labels == labels {
                if let Metric::Histogram(h) = &e.metric {
                    return h.clone();
                }
            }
        }
        let h = Histogram::with_bounds(bounds);
        entries.push(Entry {
            name: name.to_string(),
            labels: labels.to_string(),
            help: help.to_string(),
            metric: Metric::Histogram(h.clone()),
        });
        h
    }

    /// Render the whole registry in Prometheus text exposition format.
    /// Histograms render as summaries with `quantile="0.5|0.9|0.99"`
    /// series plus `_sum` and `_count`. Cold path; allocates.
    pub fn render_prometheus(&self) -> String {
        use std::fmt::Write as _;
        let entries = self.entries.lock().expect("metrics registry poisoned");
        let mut out = String::new();
        let mut described: Vec<&str> = Vec::new();
        for e in entries.iter() {
            if !described.contains(&e.name.as_str()) {
                described.push(&e.name);
                let kind = match &e.metric {
                    Metric::Counter(_) => "counter",
                    Metric::Gauge(_) => "gauge",
                    Metric::Histogram(_) => "summary",
                };
                let _ = writeln!(out, "# HELP {} {}", e.name, e.help);
                let _ = writeln!(out, "# TYPE {} {}", e.name, kind);
            }
            match &e.metric {
                Metric::Counter(c) => {
                    let _ = writeln!(out, "{} {}", series(&e.name, &e.labels, ""), c.get());
                }
                Metric::Gauge(g) => {
                    let _ = writeln!(out, "{} {}", series(&e.name, &e.labels, ""), num(g.get()));
                }
                Metric::Histogram(h) => {
                    for (q, label) in [(0.5, "0.5"), (0.9, "0.9"), (0.99, "0.99")] {
                        let _ = writeln!(
                            out,
                            "{} {}",
                            series(&e.name, &e.labels, &format!("quantile=\"{label}\"")),
                            num(h.quantile(q))
                        );
                    }
                    let _ = writeln!(
                        out,
                        "{} {}",
                        series(&format!("{}_sum", e.name), &e.labels, ""),
                        num(h.sum())
                    );
                    let _ = writeln!(
                        out,
                        "{} {}",
                        series(&format!("{}_count", e.name), &e.labels, ""),
                        h.count()
                    );
                }
            }
        }
        out
    }
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        Self::new()
    }
}

/// One series head: `name`, `name{labels}`, `name{extra}` or
/// `name{labels,extra}`.
fn series(name: &str, labels: &str, extra: &str) -> String {
    match (labels.is_empty(), extra.is_empty()) {
        (true, true) => name.to_string(),
        (true, false) => format!("{name}{{{extra}}}"),
        (false, true) => format!("{name}{{{labels}}}"),
        (false, false) => format!("{name}{{{labels},{extra}}}"),
    }
}

/// Prometheus float rendering: `NaN` is the spec's literal for "no
/// observations yet"; everything else uses Rust's shortest form.
fn num(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_interpolate_within_buckets() {
        let h = Histogram::with_bounds(&LATENCY_BUCKETS);
        // 1..=100 observations at 10ms..1s, uniformly spaced
        for i in 1..=100 {
            h.record(i as f64 * 0.01);
        }
        assert_eq!(h.count(), 100);
        assert!((h.sum() - 50.5).abs() < 1e-6);
        let p50 = h.quantile(0.5);
        assert!((0.25..=0.75).contains(&p50), "p50 estimate off: {p50}");
        let p99 = h.quantile(0.99);
        assert!((0.75..=1.01).contains(&p99), "p99 estimate off: {p99}");
        assert!(h.quantile(0.0) > 0.0);
    }

    #[test]
    fn overflow_bucket_clamps_to_last_bound() {
        let h = Histogram::with_bounds(&[0.1, 1.0]);
        h.record(50.0);
        assert_eq!(h.quantile(0.5), 1.0);
        assert!(Histogram::with_bounds(&[0.1]).quantile(0.5).is_nan());
    }

    #[test]
    fn reregistration_returns_the_same_series() {
        let reg = MetricsRegistry::new();
        let a = reg.counter("sgc_x_total", "job=\"0\"", "x");
        let b = reg.counter("sgc_x_total", "job=\"0\"", "x");
        a.inc();
        b.inc();
        assert_eq!(a.get(), 2);
        let text = reg.render_prometheus();
        assert_eq!(text.matches("sgc_x_total{job=\"0\"} 2").count(), 1);
    }

    #[test]
    fn render_emits_type_lines_and_summary_series() {
        let reg = MetricsRegistry::new();
        reg.counter("sgc_t_total", "", "t").add(3);
        reg.gauge("sgc_g", "", "g").set(2.5);
        let h = reg.histogram("sgc_lat_seconds", "job=\"1\"", "lat");
        h.record(0.02);
        let text = reg.render_prometheus();
        assert!(text.contains("# TYPE sgc_t_total counter"));
        assert!(text.contains("sgc_t_total 3\n"));
        assert!(text.contains("# TYPE sgc_g gauge"));
        assert!(text.contains("sgc_g 2.5\n"));
        assert!(text.contains("# TYPE sgc_lat_seconds summary"));
        assert!(text.contains("sgc_lat_seconds{job=\"1\",quantile=\"0.5\"}"));
        assert!(text.contains("sgc_lat_seconds{job=\"1\",quantile=\"0.99\"}"));
        assert!(text.contains("sgc_lat_seconds_count{job=\"1\"} 1\n"));
    }
}
