//! Observability: metrics registry, structured event journal, and the
//! leveled log facade.
//!
//! Three invariants govern this module, all pinned by tests:
//!
//! 1. **Zero-cost when disabled.** Every layer holds an
//!    `Option<…Obs…>`; with no bundle attached the hooks compile to a
//!    `None` check and the log macros to one relaxed atomic load.
//! 2. **Allocation-free when enabled.** Recording a counter, gauge or
//!    histogram sample is pure atomics; a journal append writes into a
//!    preallocated ring slot (`tests/alloc.rs` pins both at 0
//!    allocations).
//! 3. **Read-only.** Instrumentation observes decisions, it never
//!    participates in them — a run with observability enabled produces
//!    byte-identical `ScheduleReport`s to one without (`tests/obs.rs`
//!    golden test).
//!
//! One [`Obs`] bundle is shared (`Arc`) across the scheduler, cluster
//! backend and adaptive controller so a whole serving stack lands in a
//! single registry and a single timeline. The fleet reactor renders
//! the registry as Prometheus text on its own poll loop
//! (`FleetCluster::serve_metrics`), and the journal exports to Chrome
//! Trace Event Format via [`chrome_trace`] (`sgc trace export`).

pub mod journal;
pub mod log;
pub mod metrics;

pub use journal::{chrome_trace, events_from_json, EventKind, Journal, JournalEvent};
pub use metrics::{Counter, Gauge, Histogram, MetricsRegistry};

/// Default journal bound: 64Ki events ≈ 3 MiB, hours of serving at
/// typical round rates before the ring starts overwriting.
pub const DEFAULT_JOURNAL_EVENTS: usize = 65_536;

/// One observability bundle: the metric registry the `/metrics`
/// endpoint renders, plus the bounded event journal. Shared across
/// layers as `Arc<Obs>`.
pub struct Obs {
    /// Process-wide metric registry (counters, gauges, histograms).
    pub metrics: MetricsRegistry,
    /// Bounded structured event journal.
    pub journal: Journal,
}

impl Obs {
    /// Bundle with the [`DEFAULT_JOURNAL_EVENTS`] journal bound.
    pub fn new() -> Self {
        Self::with_capacity(DEFAULT_JOURNAL_EVENTS)
    }

    /// Bundle with a caller-chosen journal bound.
    pub fn with_capacity(journal_events: usize) -> Self {
        Obs { metrics: MetricsRegistry::new(), journal: Journal::with_capacity(journal_events) }
    }
}

impl Default for Obs {
    fn default() -> Self {
        Self::new()
    }
}
