//! Bounded structured event journal and Chrome Trace export.
//!
//! The journal is a preallocated ring buffer of fixed-size
//! [`JournalEvent`] records: appending in steady state is a mutex
//! lock plus a slot write — no allocation — and once full the ring
//! overwrites its oldest entry and bumps a `dropped` counter, so a
//! long-lived server's memory stays bounded no matter how many rounds
//! it closes. Every layer journals against the *cluster clock*
//! ([`crate::cluster::EventCluster::now_s`]): virtual seconds for
//! simulators, wall seconds for fleets — so sim and fleet runs produce
//! directly comparable timelines.
//!
//! [`chrome_trace`] converts a snapshot into Chrome Trace Event Format
//! JSON (the `chrome://tracing` / Perfetto import format): each
//! scheduler job becomes a trace *process*, round lifecycles become
//! `B`/`E` duration spans, per-worker task executions become `X`
//! complete spans on per-worker tracks, and everything else becomes an
//! `i` instant.

use crate::util::json::Json;
use std::sync::Mutex;

/// What a [`JournalEvent`] records. The event's `job`/`round`/`worker`/
/// `value` fields are overloaded per kind; each variant documents its
/// own encoding (unused integer fields hold `-1`, unused values `0.0`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// A round's tasks were fanned out to workers (`job`, `round`).
    RoundAssign,
    /// One worker's result arrived (`worker` = logical id, `value` =
    /// seconds since the round's fan-out; the span start is `ts_s -
    /// value`).
    WorkerArrive,
    /// μ-cut decision at round close (`value` = κ seconds, `worker` =
    /// number of detected stragglers).
    CutDecision,
    /// A round committed (`value` = protocol round duration in
    /// seconds, `worker` = workers admitted past the μ-cutoff by the
    /// wait-out policy).
    RoundClose,
    /// A paper-job became decodable (`round` = paper-job index).
    JobDecode,
    /// A scheduler job was admitted (`job`).
    JobAdmit,
    /// A scheduler job produced its final report (`job`).
    JobFinish,
    /// Scheduler queue depth changed (`value` = unfinished jobs).
    QueueDepth,
    /// An adaptive hot-swap executed at a job boundary (`value` =
    /// predicted expected-runtime gain).
    SchemeSwap,
    /// The adaptive policy staged a swap for the next boundary
    /// (`value` = predicted gain).
    SwapStaged,
    /// A budgeted background re-fit pass completed (`value` =
    /// cumulative candidates evaluated).
    RefitPass,
    /// The delay profiler detected a straggler-regime shift (`job`).
    RegimeShift,
    /// A logical slot migrated off a dead worker (`worker` = new
    /// physical id, `value` = old physical id).
    Replacement,
    /// Reactor wake overshoot past its computed poll deadline
    /// (`value` = seconds of slop).
    WakeSlop,
    /// Reactor I/O since the previous `FrameBytes` entry (`worker` =
    /// 0 for bytes in, 1 for bytes out; `value` = byte count).
    FrameBytes,
    /// A worker's heartbeats went stale — recoverable (`worker`).
    HeartbeatStale,
    /// A worker was permanently retired (`worker`).
    WorkerRetire,
    /// A worker joined the fleet mid-run (`worker`, `value` = 1 on
    /// rejoin of a known identity, 0 on a fresh join).
    WorkerJoin,
    /// Simulator ground truth: stragglers drawn for one submission
    /// (`value` = straggler count). Only virtual clusters emit this.
    TrueStragglers,
    /// A faulted scheduler job was truncated and re-queued (`value` =
    /// backoff seconds until its restart, `round` = cluster round of
    /// the aborted attempt).
    JobRetry,
    /// A scheduler job exhausted its retry budget and was permanently
    /// quarantined (`value` = retries spent).
    JobQuarantine,
    /// A round closed under degraded (never-wait) decode (`value` =
    /// protocol round duration in seconds).
    DegradedRound,
    /// The chaos harness injected a scripted fault (`worker` = target
    /// worker or `-1`, `value` = fault-kind discriminant — see
    /// [`crate::chaos::FaultKind`]).
    ChaosFault,
    /// The master shipped a dataset partition to a worker (`worker` =
    /// physical id, `value` = flat f32 count).
    PartitionSent,
    /// The master broadcast model parameters to a worker (`worker` =
    /// physical id, `value` = parameter version).
    ParamBroadcast,
    /// The gradient data plane reconstructed a full batch gradient for
    /// a decoded paper-job (`round` = paper-job index).
    GradientDecoded,
    /// The serving loop received a submission (`job` = assigned id, or
    /// `-1` when the submission was rejected before an id existed;
    /// `value` = priority).
    JobSubmit,
    /// The serving loop load-shed a submission (`value` = queue depth
    /// at rejection).
    JobReject,
    /// An active job was preempted to shed load (`job`, `value` =
    /// paper-jobs banked before eviction).
    JobPreempt,
    /// A preempted job was re-activated (`job`, `value` = paper-jobs
    /// still remaining).
    JobResume,
}

/// Every kind, for iteration and parsing.
const ALL_KINDS: [EventKind; 30] = [
    EventKind::RoundAssign,
    EventKind::WorkerArrive,
    EventKind::CutDecision,
    EventKind::RoundClose,
    EventKind::JobDecode,
    EventKind::JobAdmit,
    EventKind::JobFinish,
    EventKind::QueueDepth,
    EventKind::SchemeSwap,
    EventKind::SwapStaged,
    EventKind::RefitPass,
    EventKind::RegimeShift,
    EventKind::Replacement,
    EventKind::WakeSlop,
    EventKind::FrameBytes,
    EventKind::HeartbeatStale,
    EventKind::WorkerRetire,
    EventKind::WorkerJoin,
    EventKind::TrueStragglers,
    EventKind::JobRetry,
    EventKind::JobQuarantine,
    EventKind::DegradedRound,
    EventKind::ChaosFault,
    EventKind::PartitionSent,
    EventKind::ParamBroadcast,
    EventKind::GradientDecoded,
    EventKind::JobSubmit,
    EventKind::JobReject,
    EventKind::JobPreempt,
    EventKind::JobResume,
];

impl EventKind {
    /// Stable snake_case name used in journal JSON and trace output.
    pub fn as_str(self) -> &'static str {
        match self {
            EventKind::RoundAssign => "round_assign",
            EventKind::WorkerArrive => "worker_arrive",
            EventKind::CutDecision => "cut_decision",
            EventKind::RoundClose => "round_close",
            EventKind::JobDecode => "job_decode",
            EventKind::JobAdmit => "job_admit",
            EventKind::JobFinish => "job_finish",
            EventKind::QueueDepth => "queue_depth",
            EventKind::SchemeSwap => "scheme_swap",
            EventKind::SwapStaged => "swap_staged",
            EventKind::RefitPass => "refit_pass",
            EventKind::RegimeShift => "regime_shift",
            EventKind::Replacement => "replacement",
            EventKind::WakeSlop => "wake_slop",
            EventKind::FrameBytes => "frame_bytes",
            EventKind::HeartbeatStale => "heartbeat_stale",
            EventKind::WorkerRetire => "worker_retire",
            EventKind::WorkerJoin => "worker_join",
            EventKind::TrueStragglers => "true_stragglers",
            EventKind::JobRetry => "job_retry",
            EventKind::JobQuarantine => "job_quarantine",
            EventKind::DegradedRound => "degraded_round",
            EventKind::ChaosFault => "chaos_fault",
            EventKind::PartitionSent => "partition_sent",
            EventKind::ParamBroadcast => "param_broadcast",
            EventKind::GradientDecoded => "gradient_decoded",
            EventKind::JobSubmit => "job_submit",
            EventKind::JobReject => "job_reject",
            EventKind::JobPreempt => "job_preempt",
            EventKind::JobResume => "job_resume",
        }
    }

    /// Inverse of [`as_str`](Self::as_str); `None` for unknown names.
    pub fn from_name(s: &str) -> Option<EventKind> {
        ALL_KINDS.into_iter().find(|k| k.as_str() == s)
    }
}

/// One fixed-size journal record. Integer fields hold `-1` when the
/// kind doesn't use them; see [`EventKind`] for each kind's encoding.
#[derive(Clone, Copy, Debug)]
pub struct JournalEvent {
    /// Cluster-clock timestamp (virtual seconds for simulators, wall
    /// seconds since master start for fleets).
    pub ts_s: f64,
    /// What happened.
    pub kind: EventKind,
    /// Scheduler job id, or `-1` when not job-scoped.
    pub job: i64,
    /// Cluster round, or `-1` when not round-scoped.
    pub round: i64,
    /// Worker id or kind-specific small integer, or `-1`.
    pub worker: i64,
    /// Kind-specific measurement, or `0.0`.
    pub value: f64,
}

struct Ring {
    /// Preallocated to `cap` — pushes never reallocate.
    buf: Vec<JournalEvent>,
    cap: usize,
    /// Index of the oldest entry once the ring has wrapped.
    head: usize,
    dropped: u64,
}

/// Bounded ring-buffer journal. Thread-safe (one mutex); append is
/// allocation-free in steady state. See the [module docs](self) for
/// the overall model.
pub struct Journal {
    ring: Mutex<Ring>,
}

impl Journal {
    /// Journal bounded at `cap` events (minimum 1). Memory for the
    /// whole ring is reserved up front.
    pub fn with_capacity(cap: usize) -> Self {
        let cap = cap.max(1);
        Journal {
            ring: Mutex::new(Ring { buf: Vec::with_capacity(cap), cap, head: 0, dropped: 0 }),
        }
    }

    /// Append one event, overwriting the oldest if full.
    pub fn append(&self, ev: JournalEvent) {
        let mut r = self.ring.lock().expect("journal poisoned");
        if r.buf.len() < r.cap {
            r.buf.push(ev);
        } else {
            let head = r.head;
            r.buf[head] = ev;
            r.head = (head + 1) % r.cap;
            r.dropped += 1;
        }
    }

    /// Append one event built from parts — the common call shape at
    /// instrumentation sites.
    pub fn record(&self, ts_s: f64, kind: EventKind, job: i64, round: i64, worker: i64, value: f64) {
        self.append(JournalEvent { ts_s, kind, job, round, worker, value });
    }

    /// Events currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.ring.lock().expect("journal poisoned").buf.len()
    }

    /// True when nothing has been journaled yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events overwritten because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.ring.lock().expect("journal poisoned").dropped
    }

    /// The configured bound.
    pub fn capacity(&self) -> usize {
        self.ring.lock().expect("journal poisoned").cap
    }

    /// Copy out the current contents, oldest first.
    pub fn snapshot(&self) -> Vec<JournalEvent> {
        let r = self.ring.lock().expect("journal poisoned");
        let mut out = Vec::with_capacity(r.buf.len());
        out.extend_from_slice(&r.buf[r.head..]);
        out.extend_from_slice(&r.buf[..r.head]);
        out
    }

    /// Serialize the journal (capacity, drop count, events oldest
    /// first) for `sgc serve --journal PATH`.
    pub fn to_json(&self) -> Json {
        let events = self
            .snapshot()
            .iter()
            .map(|e| {
                let mut o = Json::obj();
                o.set("ts", e.ts_s)
                    .set("kind", e.kind.as_str())
                    .set("job", e.job)
                    .set("round", e.round)
                    .set("worker", e.worker)
                    .set("value", e.value);
                o
            })
            .collect();
        let mut o = Json::obj();
        o.set("capacity", self.capacity())
            .set("dropped", self.dropped())
            .set("events", Json::Arr(events));
        o
    }
}

/// Parse a journal serialized by [`Journal::to_json`] back into its
/// event list (the input side of `sgc trace export`).
pub fn events_from_json(doc: &Json) -> crate::Result<Vec<JournalEvent>> {
    let events = doc
        .get("events")
        .and_then(|e| e.as_arr())
        .ok_or_else(|| anyhow::anyhow!("journal JSON: missing \"events\" array"))?;
    let mut out = Vec::with_capacity(events.len());
    for (i, e) in events.iter().enumerate() {
        let kind_name = e
            .get("kind")
            .and_then(|k| k.as_str())
            .ok_or_else(|| anyhow::anyhow!("journal event {i}: missing \"kind\""))?;
        let kind = EventKind::from_name(kind_name)
            .ok_or_else(|| anyhow::anyhow!("journal event {i}: unknown kind {kind_name:?}"))?;
        let f = |field: &str| e.get(field).and_then(|v| v.as_f64());
        out.push(JournalEvent {
            ts_s: f("ts").unwrap_or(0.0),
            kind,
            job: f("job").unwrap_or(-1.0) as i64,
            round: f("round").unwrap_or(-1.0) as i64,
            worker: f("worker").unwrap_or(-1.0) as i64,
            value: f("value").unwrap_or(0.0),
        });
    }
    Ok(out)
}

/// The trace "process" that hosts non-job-scoped events (reactor,
/// cluster, scheduler housekeeping) in [`chrome_trace`] output.
pub const TRACE_REACTOR_PID: i64 = 9999;

/// Convert journal events into Chrome Trace Event Format JSON
/// (`{"traceEvents": [...]}`), loadable by `chrome://tracing` and
/// Perfetto. Mapping: each scheduler job is a process (`pid` = job id;
/// `pid` [`TRACE_REACTOR_PID`] hosts reactor/cluster events);
/// [`EventKind::RoundAssign`]/[`EventKind::RoundClose`] become `B`/`E`
/// round spans on thread 0; [`EventKind::WorkerArrive`] becomes an
/// `X` complete span of the task's service time on thread
/// `worker + 1`; everything else becomes an `i` instant carrying its
/// `value` in `args`. Timestamps convert to microseconds.
pub fn chrome_trace(events: &[JournalEvent]) -> Json {
    let mut out: Vec<Json> = Vec::new();

    // name each job's process (metadata records), plus the shared one
    let mut jobs: Vec<i64> = events.iter().map(|e| e.job).filter(|&j| j >= 0).collect();
    jobs.sort_unstable();
    jobs.dedup();
    for j in jobs {
        out.push(meta_process(j, &format!("job {j}")));
    }
    out.push(meta_process(TRACE_REACTOR_PID, "reactor / cluster"));

    for e in events {
        let pid = if e.job >= 0 { e.job } else { TRACE_REACTOR_PID };
        let ts = e.ts_s * 1e6;
        match e.kind {
            EventKind::RoundAssign => {
                let mut o = base(pid, 0, ts);
                o.set("ph", "B").set("name", format!("round {}", e.round));
                out.push(o);
            }
            EventKind::RoundClose => {
                let mut args = Json::obj();
                args.set("duration_s", e.value).set("waited_out", e.worker);
                let mut o = base(pid, 0, ts);
                o.set("ph", "E").set("args", args);
                out.push(o);
            }
            EventKind::WorkerArrive => {
                let mut args = Json::obj();
                args.set("service_s", e.value);
                let mut o = base(pid, e.worker + 1, (e.ts_s - e.value) * 1e6);
                o.set("ph", "X")
                    .set("name", format!("task r{}", e.round))
                    .set("dur", e.value * 1e6)
                    .set("args", args);
                out.push(o);
            }
            _ => {
                let mut args = Json::obj();
                args.set("value", e.value).set("round", e.round).set("worker", e.worker);
                let mut o = base(pid, 0, ts);
                o.set("ph", "i").set("name", e.kind.as_str()).set("s", "t").set("args", args);
                out.push(o);
            }
        }
    }

    let mut doc = Json::obj();
    doc.set("displayTimeUnit", "ms").set("traceEvents", Json::Arr(out));
    doc
}

fn base(pid: i64, tid: i64, ts_us: f64) -> Json {
    let mut o = Json::obj();
    o.set("pid", pid).set("tid", tid).set("ts", ts_us);
    o
}

fn meta_process(pid: i64, name: &str) -> Json {
    let mut args = Json::obj();
    args.set("name", name);
    let mut o = base(pid, 0, 0.0);
    o.set("ph", "M").set("name", "process_name").set("args", args);
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let j = Journal::with_capacity(4);
        for i in 0..10 {
            j.record(i as f64, EventKind::RoundAssign, 0, i, -1, 0.0);
        }
        assert_eq!(j.len(), 4);
        assert_eq!(j.capacity(), 4);
        assert_eq!(j.dropped(), 6);
        let rounds: Vec<i64> = j.snapshot().iter().map(|e| e.round).collect();
        assert_eq!(rounds, vec![6, 7, 8, 9]);
    }

    #[test]
    fn kind_names_roundtrip() {
        for k in ALL_KINDS {
            assert_eq!(EventKind::from_name(k.as_str()), Some(k));
        }
        assert_eq!(EventKind::from_name("nope"), None);
    }

    #[test]
    fn json_roundtrip_preserves_events() {
        let j = Journal::with_capacity(16);
        j.record(1.25, EventKind::WorkerArrive, 2, 7, 3, 0.5);
        j.record(1.5, EventKind::QueueDepth, -1, -1, -1, 4.0);
        let doc = Json::parse(&j.to_json().to_string()).unwrap();
        let events = events_from_json(&doc).unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].kind, EventKind::WorkerArrive);
        assert_eq!(events[0].job, 2);
        assert_eq!(events[0].round, 7);
        assert_eq!(events[0].worker, 3);
        assert!((events[0].value - 0.5).abs() < 1e-12);
        assert_eq!(events[1].kind, EventKind::QueueDepth);
        assert_eq!(events[1].job, -1);
    }
}
