//! Deterministic chaos harness: scripted, seeded fault plans injected
//! uniformly into the simulator and the TCP fleet.
//!
//! A [`ChaosPlan`] is parsed from a compact spec
//! (`sgc serve --chaos crash@r2,hang@r4:w1 --chaos-seed 7`) and then
//! *resolved* against a concrete fleet width: every fault without an
//! explicit target draws its victim workers from a [`Pcg32`] stream
//! keyed on `(seed, fault index)`, so the same spec + seed hits the
//! same workers in the same rounds, run after run — which is what makes
//! the chaos matrix tests (`tests/chaos.rs`) assert byte-identical
//! reports across reruns.
//!
//! Fault rounds are **cluster submission ordinals** (1-based): the
//! `k`-th `submit` call on the shared cluster, the same counter the
//! fleet master uses for its wire-level sequence numbers. Injection
//! sites:
//!
//! * [`crate::cluster::SimCluster::set_chaos`] — faults are applied
//!   *after* the round's service-time draws, so a chaos run never
//!   perturbs the RNG stream of the corresponding fault-free run
//!   (unaffected jobs stay byte-identical);
//! * [`crate::fleet::FleetCluster::set_chaos`] — master-side faults
//!   (fleet shrink, inbound-frame partition);
//! * [`crate::fleet::WorkerConfig::fault`] — worker-side faults
//!   (crash, silent hang, byzantine corruption, socket drop +
//!   delayed reconnect), scripted per worker via
//!   [`ResolvedPlan::worker_fault`].
//!
//! Every injected fault is journaled as
//! [`crate::obs::EventKind::ChaosFault`]; the recovery actions it
//! provokes surface through the scheduler's failure-domain counters
//! (`sgc_job_retries_total`, `sgc_degraded_rounds_total`, …).

use crate::util::rng::Pcg32;

/// The fault classes the harness can inject. The discriminant
/// ([`FaultKind::discriminant`]) is what lands in the journal's
/// `value` field for `chaos_fault` events.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Worker dies without ceremony: no further frames after the
    /// scripted round. Fleet: the socket drops and the master retires
    /// the slot; sim: `WorkerDead` for every owed submission.
    Crash,
    /// Silent hang: the worker stops producing results *and*
    /// heartbeats but its socket stays open. Detected only by the
    /// round-timeout backstop (fleet) or a staged `RoundTimeout`
    /// (sim).
    Hang,
    /// Byzantine result corruption: the worker returns a wrong
    /// checksum. The master verifies and permanently poisons the slot.
    Byzantine,
    /// Frame drop / network partition: the worker keeps computing but
    /// its inbound frames are discarded for
    /// [`ChaosPlan::partition_rounds`] submissions.
    Partition,
    /// Socket drop followed by a delayed reconnect: the worker's
    /// results are lost for [`ChaosPlan::reconnect_rounds`]
    /// submissions, then it rejoins (the master replays open assigns).
    Reconnect,
    /// Fleet shrink: `count` workers are retired at once — the
    /// below-tolerance trigger for degraded-mode decode.
    Shrink,
    /// Admission burst (`adm@rR:K`): `count` synthetic job submissions
    /// arrive at once when the serving loop has closed `round` cluster
    /// rounds — the scripted overload that exercises queue bounds,
    /// load-shedding and preemption. Routed to the serving loop's
    /// admission source ([`ResolvedPlan::admission_faults`]), not to
    /// the cluster backends.
    AdmissionBurst,
}

impl FaultKind {
    /// Stable numeric code journaled with `chaos_fault` events.
    pub fn discriminant(self) -> u8 {
        match self {
            FaultKind::Crash => 0,
            FaultKind::Hang => 1,
            FaultKind::Byzantine => 2,
            FaultKind::Partition => 3,
            FaultKind::Reconnect => 4,
            FaultKind::Shrink => 5,
            FaultKind::AdmissionBurst => 6,
        }
    }

    /// Spec keyword (`crash`, `hang`, …); inverse of the parser.
    pub fn as_str(self) -> &'static str {
        match self {
            FaultKind::Crash => "crash",
            FaultKind::Hang => "hang",
            FaultKind::Byzantine => "byzantine",
            FaultKind::Partition => "partition",
            FaultKind::Reconnect => "reconnect",
            FaultKind::Shrink => "shrink",
            FaultKind::AdmissionBurst => "adm",
        }
    }

    fn parse(s: &str) -> Option<FaultKind> {
        Some(match s {
            "crash" => FaultKind::Crash,
            "hang" => FaultKind::Hang,
            "byzantine" | "byz" => FaultKind::Byzantine,
            "partition" | "part" => FaultKind::Partition,
            "reconnect" | "rejoin" => FaultKind::Reconnect,
            "shrink" => FaultKind::Shrink,
            "adm" | "burst" => FaultKind::AdmissionBurst,
            _ => return None,
        })
    }
}

/// One scripted fault, as parsed from the spec (targets may still be
/// unresolved — see [`ChaosPlan::resolve`]).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultEvent {
    /// What to inject.
    pub kind: FaultKind,
    /// Cluster submission ordinal (1-based) at which the fault fires.
    pub round: u64,
    /// Explicit victim (`:wK` in the spec); `None` draws one from the
    /// plan's RNG at resolve time.
    pub worker: Option<usize>,
    /// Victim count (shrink only; `:K` in the spec, default 1).
    pub count: usize,
}

/// A parsed, seeded fault plan. Parse with [`ChaosPlan::parse`], then
/// [`resolve`](Self::resolve) against the fleet width to fix victims.
#[derive(Clone, Debug, PartialEq)]
pub struct ChaosPlan {
    /// Seed for deterministic victim selection.
    pub seed: u64,
    /// The scripted faults, in spec order.
    pub faults: Vec<FaultEvent>,
    /// How many submissions a partition swallows (default 2).
    pub partition_rounds: u64,
    /// How many submissions a reconnecting worker is away (default 2).
    pub reconnect_rounds: u64,
    /// Virtual seconds after which a simulated submission that still
    /// owes events from a hung worker raises `RoundTimeout` (the sim's
    /// stand-in for the fleet's `--round-timeout` backstop; default
    /// 8.0).
    pub sim_timeout_s: f64,
}

impl ChaosPlan {
    /// Parse a fault spec: comma-separated entries of the form
    /// `KIND@rROUND[:wWORKER][:COUNT]`, e.g.
    /// `crash@r2,hang@r4:w1,shrink@r6:2`. Kinds: `crash`, `hang`,
    /// `byzantine`, `partition`, `reconnect`, `shrink`.
    pub fn parse(spec: &str, seed: u64) -> crate::Result<ChaosPlan> {
        let mut faults = Vec::new();
        for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let (kind_s, rest) = entry
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("chaos entry {entry:?}: expected KIND@rROUND"))?;
            let kind = FaultKind::parse(kind_s).ok_or_else(|| {
                anyhow::anyhow!(
                    "chaos entry {entry:?}: unknown fault {kind_s:?} \
                     (crash|hang|byzantine|partition|reconnect|shrink|adm)"
                )
            })?;
            let mut parts = rest.split(':');
            let round_s = parts.next().unwrap_or("");
            let round: u64 = round_s
                .strip_prefix('r')
                .ok_or_else(|| anyhow::anyhow!("chaos entry {entry:?}: round must be rN"))?
                .parse()
                .map_err(|_| anyhow::anyhow!("chaos entry {entry:?}: bad round {round_s:?}"))?;
            anyhow::ensure!(round >= 1, "chaos entry {entry:?}: rounds are 1-based");
            let mut worker = None;
            let mut count = 1usize;
            for p in parts {
                if let Some(w) = p.strip_prefix('w') {
                    worker = Some(w.parse().map_err(|_| {
                        anyhow::anyhow!("chaos entry {entry:?}: bad worker {p:?}")
                    })?);
                } else {
                    count = p.parse().map_err(|_| {
                        anyhow::anyhow!("chaos entry {entry:?}: bad count {p:?}")
                    })?;
                    anyhow::ensure!(count >= 1, "chaos entry {entry:?}: count must be ≥ 1");
                }
            }
            faults.push(FaultEvent { kind, round, worker, count });
        }
        anyhow::ensure!(!faults.is_empty(), "empty chaos spec");
        Ok(ChaosPlan {
            seed,
            faults,
            partition_rounds: 2,
            reconnect_rounds: 2,
            sim_timeout_s: 8.0,
        })
    }

    /// Fix every fault's victim set against a fleet of `n` workers.
    /// Victim selection is a pure function of `(seed, fault index, n)`
    /// — re-resolving the same plan yields the same targets.
    pub fn resolve(&self, n: usize) -> ResolvedPlan {
        assert!(n > 0, "resolve against an empty fleet");
        let mut faults = Vec::with_capacity(self.faults.len());
        for (i, f) in self.faults.iter().enumerate() {
            let mut rng = Pcg32::new(self.seed ^ 0xc4a0_5eed, (i as u64) << 8 | 0x3f);
            let workers: Vec<usize> = match f.worker {
                // admission bursts target the serving loop, not workers
                _ if f.kind == FaultKind::AdmissionBurst => Vec::new(),
                Some(w) => vec![w % n],
                None => {
                    // distinct victims, deterministic order
                    let want = f.count.min(n);
                    let mut picked = Vec::with_capacity(want);
                    while picked.len() < want {
                        let w = rng.below(n);
                        if !picked.contains(&w) {
                            picked.push(w);
                        }
                    }
                    picked
                }
            };
            faults.push(ResolvedFault { kind: f.kind, round: f.round, workers, count: f.count });
        }
        ResolvedPlan {
            faults,
            partition_rounds: self.partition_rounds,
            reconnect_rounds: self.reconnect_rounds,
            sim_timeout_s: self.sim_timeout_s,
        }
    }
}

/// One fault with its victim set fixed.
#[derive(Clone, Debug, PartialEq)]
pub struct ResolvedFault {
    /// What to inject.
    pub kind: FaultKind,
    /// Cluster submission ordinal (1-based) at which it fires.
    pub round: u64,
    /// The victims (one entry except for multi-worker shrinks; empty
    /// for admission bursts, which have no worker targets).
    pub workers: Vec<usize>,
    /// The spec's raw count — the burst size for
    /// [`FaultKind::AdmissionBurst`] (victim counts are already baked
    /// into `workers` for the other kinds).
    pub count: usize,
}

/// A [`ChaosPlan`] resolved against a concrete fleet width — what the
/// injection sites consume.
#[derive(Clone, Debug, PartialEq)]
pub struct ResolvedPlan {
    /// Faults in spec order, victims fixed.
    pub faults: Vec<ResolvedFault>,
    /// See [`ChaosPlan::partition_rounds`].
    pub partition_rounds: u64,
    /// See [`ChaosPlan::reconnect_rounds`].
    pub reconnect_rounds: u64,
    /// See [`ChaosPlan::sim_timeout_s`].
    pub sim_timeout_s: f64,
}

/// A worker-side fault script embedded into a fleet
/// [`crate::fleet::WorkerConfig`]: act out `kind` on receipt of the
/// assignment after `at_round` served rounds (worker-local count, so
/// `at_round == 0` strands the very first assignment — the handshake
/// itself always succeeds, keeping fleet startup deterministic).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WorkerFault {
    /// Crash, hang, byzantine or reconnect (the worker-side kinds).
    pub kind: FaultKind,
    /// Worker-local served-round count at which the next assignment
    /// triggers the fault.
    pub at_round: u64,
    /// For [`FaultKind::Reconnect`]: seconds to stay away before
    /// redialing.
    pub away_s: f64,
}

impl ResolvedPlan {
    /// The worker-side fault scripted for worker `id`, if any (crash /
    /// hang / byzantine / reconnect entries; shrink and partition are
    /// master-side). The first matching fault wins.
    pub fn worker_fault(&self, id: usize) -> Option<WorkerFault> {
        self.faults.iter().find_map(|f| {
            let worker_side = matches!(
                f.kind,
                FaultKind::Crash | FaultKind::Hang | FaultKind::Byzantine | FaultKind::Reconnect
            );
            if worker_side && f.workers.contains(&id) {
                Some(WorkerFault {
                    kind: f.kind,
                    // a cluster submission ordinal approximates the
                    // worker-local assignment count (every submission
                    // assigns every placed worker once)
                    at_round: f.round.saturating_sub(1),
                    away_s: 0.2 * self.reconnect_rounds as f64,
                })
            } else {
                None
            }
        })
    }

    /// Master-side faults (shrink, partition), for
    /// [`crate::fleet::FleetCluster::set_chaos`].
    pub fn master_faults(&self) -> impl Iterator<Item = &ResolvedFault> {
        self.faults
            .iter()
            .filter(|f| matches!(f.kind, FaultKind::Shrink | FaultKind::Partition))
    }

    /// Admission-burst faults (`adm@rR:K`), for the serving loop's
    /// scripted admission source: each yields `(rounds_closed trigger,
    /// burst size)`.
    pub fn admission_faults(&self) -> impl Iterator<Item = (u64, usize)> + '_ {
        self.faults
            .iter()
            .filter(|f| f.kind == FaultKind::AdmissionBurst)
            .map(|f| (f.round, f.count.max(1)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_smoke_spec() {
        let plan = ChaosPlan::parse("crash@r2,hang@r4", 7).unwrap();
        assert_eq!(plan.faults.len(), 2);
        assert_eq!(plan.faults[0], FaultEvent {
            kind: FaultKind::Crash,
            round: 2,
            worker: None,
            count: 1
        });
        assert_eq!(plan.faults[1].kind, FaultKind::Hang);
        assert_eq!(plan.faults[1].round, 4);
    }

    #[test]
    fn parses_targets_and_counts() {
        let plan = ChaosPlan::parse("hang@r4:w1,shrink@r6:2,byz@r3:w0", 7).unwrap();
        assert_eq!(plan.faults[0].worker, Some(1));
        assert_eq!(plan.faults[1].count, 2);
        assert_eq!(plan.faults[2].kind, FaultKind::Byzantine);
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!(ChaosPlan::parse("", 7).is_err());
        assert!(ChaosPlan::parse("crash", 7).is_err());
        assert!(ChaosPlan::parse("crash@2", 7).is_err());
        assert!(ChaosPlan::parse("crash@r0", 7).is_err());
        assert!(ChaosPlan::parse("explode@r2", 7).is_err());
        assert!(ChaosPlan::parse("crash@r2:q9", 7).is_err());
    }

    #[test]
    fn resolution_is_deterministic_and_distinct() {
        let plan = ChaosPlan::parse("shrink@r3:3,crash@r5", 42).unwrap();
        let a = plan.resolve(8);
        let b = plan.resolve(8);
        assert_eq!(a, b, "same seed ⇒ same victims");
        assert_eq!(a.faults[0].workers.len(), 3);
        let mut uniq = a.faults[0].workers.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), 3, "shrink victims are distinct");
        // a different seed picks different victims (overwhelmingly)
        let other = ChaosPlan::parse("shrink@r3:3,crash@r5", 43).unwrap().resolve(8);
        assert!(a != other || plan.resolve(8) == a);
    }

    #[test]
    fn explicit_worker_wins_over_the_rng() {
        let plan = ChaosPlan::parse("hang@r4:w5", 1).unwrap();
        assert_eq!(plan.resolve(8).faults[0].workers, vec![5]);
        // out-of-range explicit targets wrap rather than panic
        assert_eq!(plan.resolve(4).faults[0].workers, vec![1]);
    }

    #[test]
    fn worker_fault_routing() {
        let plan = ChaosPlan::parse("crash@r2:w1,shrink@r3:w2,partition@r4:w3", 7).unwrap();
        let r = plan.resolve(8);
        let f = r.worker_fault(1).expect("worker 1 crashes");
        assert_eq!(f.kind, FaultKind::Crash);
        assert_eq!(f.at_round, 1);
        assert!(r.worker_fault(2).is_none(), "shrink is master-side");
        assert!(r.worker_fault(3).is_none(), "partition is master-side");
        assert_eq!(r.master_faults().count(), 2);
    }

    #[test]
    fn admission_bursts_route_to_the_serving_loop() {
        let plan = ChaosPlan::parse("adm@r3:5,burst@r7,crash@r2", 7).unwrap();
        let r = plan.resolve(8);
        assert_eq!(r.faults[0].kind, FaultKind::AdmissionBurst);
        assert!(r.faults[0].workers.is_empty(), "bursts draw no victims");
        let bursts: Vec<_> = r.admission_faults().collect();
        assert_eq!(bursts, vec![(3, 5), (7, 1)], "count defaults to 1");
        // bursts touch neither workers nor the master's fault feed
        for w in 0..8 {
            if let Some(f) = r.worker_fault(w) {
                assert_eq!(f.kind, FaultKind::Crash);
            }
        }
        assert_eq!(r.master_faults().count(), 0);
        // and resolution stays deterministic with bursts in the mix
        assert_eq!(plan.resolve(8), r);
    }
}
