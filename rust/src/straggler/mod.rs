//! Straggler modeling: patterns and validators (Sec. 2.1), stochastic
//! processes (Appendix C), conforming-pattern generators, worst-case
//! periodic patterns (Appendix F), and the prefix conformance checker
//! behind wait-out repair (Remark 2.3).

pub mod checker;
pub mod generators;
pub mod models;
pub mod pattern;

pub use checker::ToleranceChecker;
pub use generators::{gen_conforming, periodic_arbitrary, periodic_bursty, periodic_bursty_bw, Model};
pub use models::{GilbertElliot, NoStragglers, StragglerProcess, TraceProcess};
pub use pattern::{
    conforms_arbitrary, conforms_bursty, conforms_bursty_or_per_round, conforms_per_round,
    Pattern,
};
