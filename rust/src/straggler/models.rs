//! Stochastic and trace-driven straggler state processes.
//!
//! [`GilbertElliot`] is the 2-state Markov model of Appendix C, which
//! Yang et al. (2019) found to track worker state transitions on EC2;
//! the defaults are fitted to the Fig. 1 statistics (burst-length
//! histogram dominated by short bursts, ~5% straggling cells).

use super::pattern::Pattern;
use crate::util::rng::Pcg32;

/// A process producing per-round straggler states for `n` workers.
pub trait StragglerProcess: Send {
    /// Advance one round; returns the straggler indicator per worker.
    fn next_round(&mut self) -> Vec<bool>;

    /// Number of workers.
    fn n(&self) -> usize;

    /// Materialize the next `rounds` rounds as a [`Pattern`].
    fn take_pattern(&mut self, rounds: usize) -> Pattern {
        let mut p = Pattern::new(self.n());
        for _ in 0..rounds {
            p.push_round(self.next_round());
        }
        p
    }
}

/// One Gilbert–Elliot state transition for a single worker — shared by
/// the n-worker process below and the fleet's per-worker chaos
/// injection ([`crate::fleet::ChaosConfig`]), so the two can never
/// drift apart.
pub fn ge_step(straggling: bool, p_enter: f64, p_exit: f64, rng: &mut Pcg32) -> bool {
    if straggling {
        !rng.chance(p_exit)
    } else {
        rng.chance(p_enter)
    }
}

/// Gilbert–Elliot 2-state model (Appendix C, Fig. 3): a non-straggler
/// becomes a straggler with probability `p_enter`; a straggler recovers
/// with probability `p_exit`.
#[derive(Clone, Debug)]
pub struct GilbertElliot {
    /// Per-round probability of a healthy worker turning straggler.
    pub p_enter: f64,
    /// Per-round probability of a straggler recovering.
    pub p_exit: f64,
    states: Vec<bool>,
    rng: Pcg32,
}

impl GilbertElliot {
    /// Seeded chain over `n` workers, started from the stationary
    /// distribution.
    pub fn new(n: usize, p_enter: f64, p_exit: f64, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&p_enter) && (0.0..1.0).contains(&(1.0 - p_exit)));
        let mut rng = Pcg32::new(seed, 0x9e11);
        // start from the stationary distribution
        let pi_s = p_enter / (p_enter + p_exit);
        let states = (0..n).map(|_| rng.chance(pi_s)).collect();
        GilbertElliot { p_enter, p_exit, states, rng }
    }

    /// Parameters fitted to the paper's Fig. 1 observations: short bursts
    /// (geometric, mean ≈ 1.5 rounds) and ≈5% straggling cells, which at
    /// n = 256 yields ≈13 stragglers per round on average.
    pub fn default_fit(n: usize, seed: u64) -> Self {
        Self::new(n, 0.037, 0.7, seed)
    }

    /// Stationary straggling probability `p_enter / (p_enter + p_exit)`.
    pub fn stationary(&self) -> f64 {
        self.p_enter / (self.p_enter + self.p_exit)
    }

    /// Mean burst length `1 / p_exit`.
    pub fn mean_burst(&self) -> f64 {
        1.0 / self.p_exit
    }
}

impl StragglerProcess for GilbertElliot {
    fn next_round(&mut self) -> Vec<bool> {
        for s in self.states.iter_mut() {
            *s = ge_step(*s, self.p_enter, self.p_exit, &mut self.rng);
        }
        self.states.clone()
    }

    fn n(&self) -> usize {
        self.states.len()
    }
}

/// Replays a recorded pattern (wraps around if exhausted).
#[derive(Clone, Debug)]
pub struct TraceProcess {
    pattern: Pattern,
    cursor: usize,
}

impl TraceProcess {
    /// Replay `pattern` (wrapping around at its end).
    pub fn new(pattern: Pattern) -> Self {
        assert!(pattern.rounds() > 0);
        TraceProcess { pattern, cursor: 0 }
    }
}

impl StragglerProcess for TraceProcess {
    fn next_round(&mut self) -> Vec<bool> {
        let row = self.pattern.rows[self.cursor % self.pattern.rounds()].clone();
        self.cursor += 1;
        row
    }

    fn n(&self) -> usize {
        self.pattern.n
    }
}

/// No stragglers ever (ideal cluster; ablation baseline).
#[derive(Clone, Debug)]
pub struct NoStragglers {
    /// Worker count.
    pub n: usize,
}

impl StragglerProcess for NoStragglers {
    fn next_round(&mut self) -> Vec<bool> {
        vec![false; self.n]
    }

    fn n(&self) -> usize {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ge_stationary_fraction() {
        let mut ge = GilbertElliot::new(64, 0.05, 0.5, 7);
        let p = ge.take_pattern(2000);
        let frac = p.straggle_fraction();
        let expect = 0.05 / 0.55;
        assert!((frac - expect).abs() < 0.02, "frac {frac} vs {expect}");
    }

    #[test]
    fn ge_burst_lengths_geometric() {
        let mut ge = GilbertElliot::new(64, 0.05, 0.5, 11);
        let p = ge.take_pattern(3000);
        let bursts = p.burst_lengths();
        let mean = bursts.iter().sum::<usize>() as f64 / bursts.len() as f64;
        assert!((mean - 2.0).abs() < 0.2, "mean burst {mean} vs 1/p_exit = 2");
    }

    #[test]
    fn default_fit_matches_paper_scale() {
        let mut ge = GilbertElliot::default_fit(256, 3);
        let p = ge.take_pattern(100);
        // average stragglers per round in the low tens
        let avg: f64 =
            (1..=100).map(|r| p.count_in_round(r) as f64).sum::<f64>() / 100.0;
        assert!((8.0..20.0).contains(&avg), "avg stragglers/round {avg}");
        // bursts are short
        let bursts = p.burst_lengths();
        let long = bursts.iter().filter(|&&b| b > 6).count() as f64 / bursts.len() as f64;
        assert!(long < 0.05, "long-burst fraction {long}");
    }

    #[test]
    fn trace_replays_and_wraps() {
        let pat = Pattern::from_rows(vec![vec![true, false], vec![false, true]]);
        let mut tr = TraceProcess::new(pat);
        assert_eq!(tr.next_round(), vec![true, false]);
        assert_eq!(tr.next_round(), vec![false, true]);
        assert_eq!(tr.next_round(), vec![true, false]); // wrap
    }
}
