//! Straggler patterns and conformance validators (Sec. 2.1).
//!
//! A pattern is the indicator matrix `S_i(t)` (worker `i` straggles in
//! round `t`). The three deterministic models of Sec. 2.1 are implemented
//! as window validators; the prefix variants back the master's wait-out
//! conformance repair (Remark 2.3).

/// Straggler indicator matrix. Rounds are 1-based in the API
/// (`round ∈ [1 : rounds]`), matching the paper.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Pattern {
    /// Worker count (row width).
    pub n: usize,
    /// `rows[r-1][i]` = worker `i` straggles in round `r`.
    pub rows: Vec<Vec<bool>>,
}

impl Pattern {
    /// Empty pattern over `n` workers.
    pub fn new(n: usize) -> Self {
        Pattern { n, rows: Vec::new() }
    }

    /// Pattern from equal-length indicator rows.
    pub fn from_rows(rows: Vec<Vec<bool>>) -> Self {
        let n = rows.first().map_or(0, |r| r.len());
        assert!(rows.iter().all(|r| r.len() == n));
        Pattern { n, rows }
    }

    /// Rounds recorded so far.
    pub fn rounds(&self) -> usize {
        self.rows.len()
    }

    /// Append one round's indicator row.
    pub fn push_round(&mut self, row: Vec<bool>) {
        assert_eq!(row.len(), self.n);
        self.rows.push(row);
    }

    /// Did `worker` straggle in (1-based) `round`?
    #[inline]
    pub fn is_straggler(&self, worker: usize, round: usize) -> bool {
        self.rows[round - 1][worker]
    }

    /// Number of stragglers in a round.
    pub fn count_in_round(&self, round: usize) -> usize {
        self.rows[round - 1].iter().filter(|&&s| s).count()
    }

    /// Distinct stragglers in rounds `[lo : hi]` (inclusive, clipped).
    pub fn distinct_in(&self, lo: usize, hi: usize) -> usize {
        let hi = hi.min(self.rounds());
        if lo > hi {
            return 0;
        }
        (0..self.n)
            .filter(|&i| (lo..=hi).any(|r| self.is_straggler(i, r)))
            .count()
    }

    /// Straggle burst lengths across all workers (Fig. 1(b)).
    pub fn burst_lengths(&self) -> Vec<usize> {
        let mut out = Vec::new();
        for i in 0..self.n {
            let mut run = 0usize;
            for r in 1..=self.rounds() {
                if self.is_straggler(i, r) {
                    run += 1;
                } else if run > 0 {
                    out.push(run);
                    run = 0;
                }
            }
            if run > 0 {
                out.push(run);
            }
        }
        out
    }

    /// Fraction of straggling (worker, round) cells.
    pub fn straggle_fraction(&self) -> f64 {
        if self.rows.is_empty() {
            return 0.0;
        }
        let total = self.n * self.rounds();
        let s: usize = (1..=self.rounds()).map(|r| self.count_in_round(r)).sum();
        s as f64 / total as f64
    }
}

/// Read-only view of a straggler pattern — lets the conformance checker
/// evaluate "history + one candidate row" without cloning the history
/// (the wait-out repair loop calls this many times per round; see
/// EXPERIMENTS.md §Perf).
pub trait StragglerView {
    /// Worker count.
    fn n(&self) -> usize;
    /// Rounds the view covers.
    fn rounds(&self) -> usize;
    /// Did `worker` straggle in (1-based) `round`?
    fn is_straggler(&self, worker: usize, round: usize) -> bool;

    /// Stragglers in one round.
    fn count_in_round(&self, round: usize) -> usize {
        (0..self.n()).filter(|&i| self.is_straggler(i, round)).count()
    }

    /// Distinct workers straggling anywhere in rounds `[lo, hi]`.
    fn distinct_in(&self, lo: usize, hi: usize) -> usize {
        let hi = hi.min(self.rounds());
        if lo > hi {
            return 0;
        }
        (0..self.n())
            .filter(|&i| (lo..=hi).any(|r| self.is_straggler(i, r)))
            .count()
    }
}

impl StragglerView for Pattern {
    fn n(&self) -> usize {
        self.n
    }

    fn rounds(&self) -> usize {
        self.rows.len()
    }

    fn is_straggler(&self, worker: usize, round: usize) -> bool {
        Pattern::is_straggler(self, worker, round)
    }

    fn count_in_round(&self, round: usize) -> usize {
        Pattern::count_in_round(self, round)
    }
}

/// A pattern plus one tentative extra round (zero-copy).
pub struct Overlay<'a> {
    /// The committed history.
    pub base: &'a Pattern,
    /// The tentative next row.
    pub extra: &'a [bool],
}

impl StragglerView for Overlay<'_> {
    fn n(&self) -> usize {
        self.base.n
    }

    fn rounds(&self) -> usize {
        self.base.rounds() + 1
    }

    fn is_straggler(&self, worker: usize, round: usize) -> bool {
        if round == self.base.rounds() + 1 {
            self.extra[worker]
        } else {
            self.base.is_straggler(worker, round)
        }
    }
}

/// Does the window `[lo : hi]` (inclusive, already clipped to the pattern)
/// satisfy the `(B, W, λ)`-bursty constraints? `hi - lo + 1 ≤ W` assumed.
pub fn bursty_window_ok<V: StragglerView + ?Sized>(
    p: &V,
    lo: usize,
    hi: usize,
    b: usize,
    lambda: usize,
) -> bool {
    let hi = hi.min(p.rounds());
    // single pass: distinct count + per-worker span
    let mut distinct = 0usize;
    for i in 0..p.n() {
        let mut first = None;
        let mut last = None;
        for r in lo..=hi {
            if p.is_straggler(i, r) {
                if first.is_none() {
                    first = Some(r);
                }
                last = Some(r);
            }
        }
        if let (Some(f), Some(l)) = (first, last) {
            distinct += 1;
            // (2) temporal: straggles span ≤ B rounds
            if l - f + 1 > b {
                return false;
            }
        }
    }
    // (1) spatial: ≤ λ distinct stragglers
    distinct <= lambda
}

/// Does window `[lo : hi]` satisfy the `(N, W', λ')`-arbitrary constraints?
pub fn arbitrary_window_ok<V: StragglerView + ?Sized>(
    p: &V,
    lo: usize,
    hi: usize,
    nn: usize,
    lambda: usize,
) -> bool {
    let hi = hi.min(p.rounds());
    let mut distinct = 0usize;
    for i in 0..p.n() {
        let cnt = (lo..=hi).filter(|&r| p.is_straggler(i, r)).count();
        if cnt > nn {
            return false;
        }
        if cnt > 0 {
            distinct += 1;
        }
    }
    distinct <= lambda
}

/// Does window `[lo : hi]` have at most `s` stragglers in every round?
pub fn per_round_window_ok<V: StragglerView + ?Sized>(
    p: &V,
    lo: usize,
    hi: usize,
    s: usize,
) -> bool {
    (lo..=hi.min(p.rounds())).all(|r| p.count_in_round(r) <= s)
}

/// Full-pattern conformance to the `(B, W, λ)`-bursty model: every window
/// of `W` consecutive rounds (including partial windows at the edges)
/// satisfies the constraints.
pub fn conforms_bursty(p: &Pattern, b: usize, w: usize, lambda: usize) -> bool {
    let rounds = p.rounds();
    if rounds == 0 {
        return true;
    }
    (1..=rounds).all(|j| bursty_window_ok(p, j, (j + w - 1).min(rounds), b, lambda))
}

/// Full-pattern conformance to the `(N, W', λ')`-arbitrary model.
pub fn conforms_arbitrary(p: &Pattern, nn: usize, w_prime: usize, lambda: usize) -> bool {
    let rounds = p.rounds();
    (1..=rounds).all(|j| arbitrary_window_ok(p, j, (j + w_prime - 1).min(rounds), nn, lambda))
}

/// Full-pattern conformance to the `s`-stragglers-per-round model.
pub fn conforms_per_round(p: &Pattern, s: usize) -> bool {
    (1..=p.rounds()).all(|r| p.count_in_round(r) <= s)
}

/// SR-SGC's tolerated set (Prop 3.1): every window of `W` rounds satisfies
/// the bursty constraints *or* the `s`-per-round constraint.
pub fn conforms_bursty_or_per_round(
    p: &Pattern,
    b: usize,
    w: usize,
    lambda: usize,
    s: usize,
) -> bool {
    let rounds = p.rounds();
    (1..=rounds).all(|j| {
        let hi = (j + w - 1).min(rounds);
        bursty_window_ok(p, j, hi, b, lambda) || per_round_window_ok(p, j, hi, s)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pat(rows: &[&[usize]], n: usize) -> Pattern {
        // rows given as lists of straggler indices
        Pattern::from_rows(
            rows.iter()
                .map(|set| (0..n).map(|i| set.contains(&i)).collect())
                .collect(),
        )
    }

    #[test]
    fn burst_lengths_counts_runs() {
        let p = pat(&[&[0], &[0], &[], &[0, 1], &[1]], 3);
        let mut b = p.burst_lengths();
        b.sort_unstable();
        assert_eq!(b, vec![1, 2, 2]); // worker0: 2,1; worker1: 2
    }

    #[test]
    fn bursty_conformance_accepts_conforming() {
        // B=2, W=3, λ=2: worker 0 bursts rounds 1-2; worker 1 at round 4.
        let p = pat(&[&[0], &[0], &[], &[1], &[]], 4);
        assert!(conforms_bursty(&p, 2, 3, 2));
        assert!(!conforms_bursty(&p, 1, 3, 2), "burst of 2 violates B=1");
        assert!(!conforms_bursty(&p, 2, 3, 0), "λ=0 forbids any straggler");
    }

    #[test]
    fn bursty_temporal_violation_detected() {
        // worker 0 straggles rounds 1 and 3: span 3 > B=2 within window W=3.
        let p = pat(&[&[0], &[], &[0]], 2);
        assert!(!conforms_bursty(&p, 2, 3, 2));
        // with B=3 the span fits
        assert!(conforms_bursty(&p, 3, 3, 2));
    }

    #[test]
    fn bursty_spatial_violation_detected() {
        // three distinct stragglers within a W=3 window, λ=2
        let p = pat(&[&[0], &[1], &[2]], 4);
        assert!(!conforms_bursty(&p, 1, 3, 2));
        assert!(conforms_bursty(&p, 1, 3, 3));
    }

    #[test]
    fn arbitrary_conformance() {
        // N=2, W'=4, λ'=1: worker 0 straggles rounds 1 and 3 (non-consecutive).
        let p = pat(&[&[0], &[], &[0], &[]], 3);
        assert!(conforms_arbitrary(&p, 2, 4, 1));
        assert!(!conforms_arbitrary(&p, 1, 4, 1), "2 straggles in window vs N=1");
        // bursty with B=1 would reject this pattern
        assert!(!conforms_bursty(&p, 1, 4, 1));
    }

    #[test]
    fn per_round_conformance() {
        let p = pat(&[&[0, 1], &[2]], 4);
        assert!(conforms_per_round(&p, 2));
        assert!(!conforms_per_round(&p, 1));
    }

    #[test]
    fn mixed_window_disjunction() {
        // A window with 3 distinct-but-one-per-round stragglers conforms
        // to s=1-per-round though not to (B=1,W=3,λ=2)-bursty.
        let p = pat(&[&[0], &[1], &[2]], 4);
        assert!(conforms_bursty_or_per_round(&p, 1, 3, 2, 1));
        assert!(!conforms_bursty_or_per_round(&p, 1, 3, 2, 0));
    }

    #[test]
    fn straggle_fraction() {
        let p = pat(&[&[0], &[0, 1]], 4);
        assert!((p.straggle_fraction() - 3.0 / 8.0).abs() < 1e-12);
    }
}
