//! Conforming-pattern generators for property tests and the worst-case
//! periodic patterns behind the Appendix-F lower bounds (Figs. 8-10).

use super::pattern::{
    arbitrary_window_ok, bursty_window_ok, per_round_window_ok, Pattern,
};
use crate::util::rng::Pcg32;

/// Deterministic straggler model identifier for generation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Model {
    /// `(B, W, lambda)`-bursty: bursts of length <= B per worker, <= lambda
    /// straggling workers per W-round window.
    Bursty { b: usize, w: usize, lambda: usize },
    /// Arbitrary-pattern model: <= `n_limit` distinct stragglers per
    /// W-round window, <= lambda per round.
    Arbitrary { n_limit: usize, w: usize, lambda: usize },
    /// Memoryless per-round model: <= `s` stragglers every round.
    PerRound { s: usize },
}

impl Model {
    fn window(&self) -> usize {
        match self {
            Model::Bursty { w, .. } => *w,
            Model::Arbitrary { w, .. } => *w,
            Model::PerRound { .. } => 1,
        }
    }

    /// Do all windows of the pattern containing its last round conform?
    fn last_round_ok(&self, p: &Pattern) -> bool {
        let r = p.rounds();
        let w = self.window();
        let lo_min = r.saturating_sub(w - 1).max(1);
        (lo_min..=r).all(|lo| {
            let hi = (lo + w - 1).min(r);
            match self {
                Model::Bursty { b, lambda, .. } => bursty_window_ok(p, lo, hi, *b, *lambda),
                Model::Arbitrary { n_limit, lambda, .. } => {
                    arbitrary_window_ok(p, lo, hi, *n_limit, *lambda)
                }
                Model::PerRound { s } => per_round_window_ok(p, lo, hi, *s),
            }
        })
    }
}

/// Generate a random pattern that provably conforms to `model`: each
/// (worker, round) straggle is proposed with probability `p` and accepted
/// only if every window containing it stays valid (greedy rejection).
pub fn gen_conforming(
    n: usize,
    rounds: usize,
    model: Model,
    p: f64,
    rng: &mut Pcg32,
) -> Pattern {
    let mut pat = Pattern::new(n);
    for _ in 0..rounds {
        pat.push_round(vec![false; n]);
        let r = pat.rounds();
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        for &i in &order {
            if !rng.chance(p) {
                continue;
            }
            pat.rows[r - 1][i] = true;
            if !model.last_round_ok(&pat) {
                pat.rows[r - 1][i] = false; // reject
            }
        }
    }
    pat
}

/// Fig. 8 worst-case periodic pattern (B < W): workers `0..λ` straggle in
/// the first `B` rounds of every period of `W-1+B` rounds.
pub fn periodic_bursty(n: usize, rounds: usize, b: usize, w: usize, lambda: usize) -> Pattern {
    assert!(b < w);
    let period = w - 1 + b;
    let rows = (0..rounds)
        .map(|r0| {
            let phase = r0 % period;
            (0..n).map(|i| i < lambda && phase < b).collect()
        })
        .collect();
    Pattern::from_rows(rows)
}

/// Fig. 9 worst-case pattern (B = W): workers `0..λ` straggle in every
/// round.
pub fn periodic_bursty_bw(n: usize, rounds: usize, lambda: usize) -> Pattern {
    Pattern::from_rows((0..rounds).map(|_| (0..n).map(|i| i < lambda).collect()).collect())
}

/// Fig. 10 worst-case pattern for the arbitrary model (N < W'): workers
/// `0..λ'` straggle in `N` evenly spread rounds of every period of `W'`.
pub fn periodic_arbitrary(
    n: usize,
    rounds: usize,
    n_limit: usize,
    w_prime: usize,
    lambda: usize,
) -> Pattern {
    assert!(n_limit <= w_prime);
    let rows = (0..rounds)
        .map(|r0| {
            let phase = r0 % w_prime;
            // straggle on every ⌈W'/N⌉-th slot of the period, N times
            let straggle_round = phase % w_prime.div_ceil(n_limit.max(1)) == 0
                && phase / w_prime.div_ceil(n_limit.max(1)) < n_limit;
            (0..n).map(|i| i < lambda && straggle_round).collect()
        })
        .collect();
    Pattern::from_rows(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::straggler::pattern::{conforms_arbitrary, conforms_bursty, conforms_per_round};

    #[test]
    fn gen_bursty_conforms() {
        let mut rng = Pcg32::seeded(5);
        for _ in 0..10 {
            let (b, w, lambda) = (2, 5, 3);
            let p = gen_conforming(10, 40, Model::Bursty { b, w, lambda }, 0.3, &mut rng);
            assert!(conforms_bursty(&p, b, w, lambda));
            // generator should actually produce some straggles
            assert!(p.straggle_fraction() > 0.0);
        }
    }

    #[test]
    fn gen_arbitrary_conforms() {
        let mut rng = Pcg32::seeded(6);
        for _ in 0..10 {
            let (nl, w, lambda) = (2, 6, 4);
            let p =
                gen_conforming(10, 40, Model::Arbitrary { n_limit: nl, w, lambda }, 0.3, &mut rng);
            assert!(conforms_arbitrary(&p, nl, w, lambda));
        }
    }

    #[test]
    fn gen_per_round_conforms() {
        let mut rng = Pcg32::seeded(7);
        let p = gen_conforming(10, 40, Model::PerRound { s: 3 }, 0.5, &mut rng);
        assert!(conforms_per_round(&p, 3));
        assert!(!conforms_per_round(&p, 0));
    }

    #[test]
    fn periodic_bursty_conforms_and_is_tight() {
        let (n, b, w, lambda) = (8, 2, 4, 3);
        let p = periodic_bursty(n, 36, b, w, lambda);
        assert!(conforms_bursty(&p, b, w, lambda));
        // tight: exactly λ distinct stragglers appear in period windows
        assert_eq!(p.distinct_in(1, w), lambda);
    }

    #[test]
    fn periodic_bw_case() {
        let p = periodic_bursty_bw(6, 12, 2);
        assert!(conforms_bursty(&p, 3, 3, 2));
        assert_eq!(p.count_in_round(5), 2);
    }

    #[test]
    fn periodic_arbitrary_conforms() {
        let (n, nl, w, lambda) = (8, 2, 6, 3);
        let p = periodic_arbitrary(n, 36, nl, w, lambda);
        assert!(conforms_arbitrary(&p, nl, w, lambda));
        assert!(p.straggle_fraction() > 0.0);
    }
}
