//! Prefix conformance checking for the master's wait-out repair
//! (Remark 2.3).
//!
//! If the observed straggler pattern in a round deviates from the design
//! model, the master waits for stragglers (in completion order) until the
//! *effective* pattern conforms again. [`ToleranceChecker`] answers
//! "would the pattern stay acceptable if round `r`'s stragglers were
//! exactly this set?" incrementally, only re-validating windows that
//! contain the new round.

use super::pattern::{
    arbitrary_window_ok, bursty_window_ok, per_round_window_ok, Overlay, Pattern, StragglerView,
};
use crate::coding::ToleranceSpec;

/// Incremental conformance checker for a scheme's design model.
#[derive(Clone, Debug)]
pub struct ToleranceChecker {
    spec: ToleranceSpec,
    /// Effective (post-repair) pattern committed so far.
    pattern: Pattern,
    /// For `BurstyOrArbitrary`: which branches of the disjunction are
    /// still satisfiable by the committed prefix. Once a branch dies it
    /// stays dead (the disjunction is over whole patterns, Prop 3.2).
    bursty_alive: bool,
    arbitrary_alive: bool,
}

impl ToleranceChecker {
    /// Fresh checker for `n` workers under `spec`.
    pub fn new(n: usize, spec: ToleranceSpec) -> Self {
        ToleranceChecker {
            spec,
            pattern: Pattern::new(n),
            bursty_alive: true,
            arbitrary_alive: true,
        }
    }

    /// The effective pattern committed so far.
    pub fn pattern(&self) -> &Pattern {
        &self.pattern
    }

    /// Would appending `stragglers` as round `r` keep the pattern
    /// acceptable? (Does not mutate — evaluates a zero-copy overlay.)
    pub fn acceptable(&self, stragglers: &[bool]) -> bool {
        let probe = Overlay { base: &self.pattern, extra: stragglers };
        self.eval(&probe).0
    }

    /// Commit round `r`'s effective straggler set.
    pub fn commit(&mut self, stragglers: &[bool]) {
        self.pattern.push_round(stragglers.to_vec());
        let (ok, bursty, arb) = self.eval(&self.pattern);
        debug_assert!(
            ok || !matches!(self.spec, ToleranceSpec::None),
            "committed a non-conforming round"
        );
        self.bursty_alive = bursty;
        self.arbitrary_alive = arb;
    }

    /// Evaluate acceptability of `probe` (pattern with the candidate last
    /// round). Returns `(acceptable, bursty_alive', arbitrary_alive')`.
    fn eval<V: StragglerView>(&self, probe: &V) -> (bool, bool, bool) {
        let r = probe.rounds();
        match &self.spec {
            ToleranceSpec::None => {
                (probe.count_in_round(r) == 0, self.bursty_alive, self.arbitrary_alive)
            }
            ToleranceSpec::PerRound { s } => {
                (probe.count_in_round(r) <= *s, self.bursty_alive, self.arbitrary_alive)
            }
            ToleranceSpec::BurstyOrPerRound { b, w, lambda, s } => {
                // per-window disjunction (Prop 3.1): all windows touching r
                let ok = windows_touching(r, *w).all(|(lo, hi)| {
                    bursty_window_ok(probe, lo, hi, *b, *lambda)
                        || per_round_window_ok(probe, lo, hi, *s)
                });
                (ok, self.bursty_alive, self.arbitrary_alive)
            }
            ToleranceSpec::BurstyOrArbitrary { b, w, lambda } => {
                let w_arb = w + b - 1;
                let bursty = self.bursty_alive
                    && windows_touching(r, *w)
                        .all(|(lo, hi)| bursty_window_ok(probe, lo, hi, *b, *lambda));
                let arb = self.arbitrary_alive
                    && windows_touching(r, w_arb)
                        .all(|(lo, hi)| arbitrary_window_ok(probe, lo, hi, *b, *lambda));
                (bursty || arb, bursty, arb)
            }
        }
    }
}

/// All windows of width `w` that contain round `r`, clipped to `[1, r]`:
/// `(lo, hi)` pairs.
fn windows_touching(r: usize, w: usize) -> impl Iterator<Item = (usize, usize)> {
    let lo_min = r.saturating_sub(w - 1).max(1);
    (lo_min..=r).map(move |lo| (lo, (lo + w - 1).min(r)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_round_checker() {
        let mut c = ToleranceChecker::new(4, ToleranceSpec::PerRound { s: 1 });
        assert!(c.acceptable(&[true, false, false, false]));
        assert!(!c.acceptable(&[true, true, false, false]));
        c.commit(&[true, false, false, false]);
        assert!(c.acceptable(&[false, true, false, false]));
    }

    #[test]
    fn none_checker_rejects_any_straggler() {
        let c = ToleranceChecker::new(2, ToleranceSpec::None);
        assert!(c.acceptable(&[false, false]));
        assert!(!c.acceptable(&[true, false]));
    }

    #[test]
    fn bursty_or_per_round_window_logic() {
        // SR-SGC with B=1, W=3, λ=2, s=1: one straggler per round is fine
        // even if three distinct workers straggle in a window (per-round
        // branch); two in one round is fine only via the bursty branch.
        let spec = ToleranceSpec::BurstyOrPerRound { b: 1, w: 3, lambda: 2, s: 1 };
        let mut c = ToleranceChecker::new(4, spec);
        c.commit(&[true, false, false, false]);
        c.commit(&[false, true, false, false]);
        // third distinct straggler: per-round branch saves it
        assert!(c.acceptable(&[false, false, true, false]));
        // two stragglers now: bursty branch needs ≤λ=2 distinct in the
        // window {r-2..r} = {1,2} ∪ {2,3} — workers 0,1 already straggled,
        // so workers {2,3} would make 4 distinct in no window… window
        // [2,4] would hold {1,2,3} = 3 > λ and round has 2 > s → reject.
        assert!(!c.acceptable(&[false, false, true, true]));
    }

    #[test]
    fn bursty_or_arbitrary_branch_death() {
        // M-SGC B=1, W=2, λ=1 → arbitrary model (N=1, W'=2, λ'=1).
        let spec = ToleranceSpec::BurstyOrArbitrary { b: 1, w: 2, lambda: 1 };
        let mut c = ToleranceChecker::new(3, spec);
        // worker 0 straggles twice non-consecutively: kills neither at
        // first…
        c.commit(&[true, false, false]);
        c.commit(&[false, false, false]);
        assert!(c.acceptable(&[true, false, false]));
        c.commit(&[true, false, false]);
        // now two straggles by worker 0 with a 1-gap: both models still
        // alive (burst length 1, ≤1 per W'=2 window). A burst of length 2
        // violates bursty(B=1) and arbitrary(N=1,W'=2) → unacceptable.
        assert!(!c.acceptable(&[true, false, false]));
        // a *different* worker straggling right after violates λ=1 in the
        // window {r3, r4} (2 distinct stragglers) → also unacceptable
        assert!(!c.acceptable(&[false, true, false]));
        // an all-clear round is always fine
        assert!(c.acceptable(&[false, false, false]));
    }

    #[test]
    fn repair_terminates_at_all_false() {
        // Whatever the committed history, an all-clear round is always
        // acceptable for Bursty/PerRound style specs.
        let specs = [
            ToleranceSpec::PerRound { s: 0 },
            ToleranceSpec::BurstyOrPerRound { b: 1, w: 2, lambda: 1, s: 0 },
            ToleranceSpec::BurstyOrArbitrary { b: 1, w: 2, lambda: 1 },
            ToleranceSpec::None,
        ];
        for spec in specs {
            let mut c = ToleranceChecker::new(3, spec.clone());
            c.commit(&[false, false, false]);
            c.commit(&[true, false, false].map(|x| x && !matches!(spec, ToleranceSpec::None)));
            assert!(c.acceptable(&[false, false, false]), "{spec:?}");
        }
    }
}
