//! Multi-tenant job scheduler: many SGC sessions over one shared
//! [`EventCluster`].
//!
//! The paper's headline experiment trains several models concurrently on
//! a single 256-worker Lambda fleet, multiplexing every job's coded and
//! replicated tasks across the same workers. [`JobScheduler`] is that
//! master: it admits `N` independent [`SgcSession`] jobs, fans each
//! job's rounds out through [`EventCluster::submit`], and pumps every
//! session's μ-rule off the shared event stream using the incremental
//! [`deadline_hint`](SgcSession::deadline_hint) /
//! [`try_close_round`](SgcSession::try_close_round) API — so each job's
//! stragglers are cut at that job's own `(1+μ)·κ` cutoff while other
//! jobs keep the fleet busy.
//!
//! A pluggable [`PlacementPolicy`] decides which physical worker
//! initially hosts each job's logical slot `i`: [`RoundRobinPlacement`]
//! rotates jobs one worker apart (fair interleaving),
//! [`DisjointPlacement`] spreads jobs `n / N` workers apart so the
//! cyclic codes' hot-sets land on disjoint worker arcs (echoing M-SGC's
//! multiplexed assignment). Placement is a pure relabelling: events are
//! mapped back to logical worker ids before they reach a session, so
//! every protocol decision is placement-agnostic.
//!
//! **Elastic membership.** On backends whose roster changes at runtime
//! (the TCP fleet), the scheduler tracks
//! [`WorkerJoined`](ClusterEvent::WorkerJoined) /
//! [`WorkerRetired`](ClusterEvent::WorkerRetired) events in a live set
//! and, at each round start, *re-places* any logical slot whose
//! physical worker retired onto a live spare — so an in-flight session
//! migrates off dead workers instead of paying a `WorkerDead` cut every
//! round for a ghost. Re-placements are counted in
//! [`FleetUtilization::replacements`]. Fixed-membership backends
//! (simulators, trace replays) emit no membership events, and placement
//! then never changes — which is what keeps a single-job scheduler run
//! byte-identical to the blocking drivers.
//!
//! **Adaptation.** With [`set_adaptive`](JobScheduler::set_adaptive)
//! the scheduler drives the [`crate::adapt`] control plane: every
//! round's completion times feed an online straggler profile, a
//! background re-fit evaluates a few candidate parameterizations per
//! round close, and when the swap policy accepts a re-fit the job's
//! incumbent session is truncated after its assigned paper-jobs, drains
//! its decode tail, and a fresh session with the re-fitted scheme takes
//! over the remaining jobs — recorded as [`SchemeSwapped`] entries in
//! [`ScheduleReport::swaps`]. Without `set_adaptive` nothing changes:
//! runs are byte-identical to the pre-adaptive scheduler.
//!
//! Drivers that need to execute real work per round (the PJRT trainer)
//! hook in through [`RoundObserver`].
//!
//! # Example
//!
//! Multiplex four GC sessions over one simulated 16-worker cluster and
//! read the aggregate utilization:
//!
//! ```
//! use sgc::cluster::SimCluster;
//! use sgc::coding::SchemeConfig;
//! use sgc::sched::{DisjointPlacement, JobScheduler, JobSpec};
//! use sgc::session::SessionConfig;
//! use sgc::straggler::GilbertElliot;
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut sim = SimCluster::from_gilbert_elliot(16, GilbertElliot::default_fit(16, 7), 7);
//! let mut sched = JobScheduler::with_policy(&mut sim, Box::new(DisjointPlacement));
//! for _ in 0..4 {
//!     sched.admit(&JobSpec {
//!         scheme: SchemeConfig::gc(16, 2),
//!         session: SessionConfig { jobs: 6, ..Default::default() },
//!     })?;
//! }
//! let out = sched.run()?;
//! assert_eq!(out.reports.len(), 4);
//! assert!(out.utilization.multiplexing_gain > 1.0); // sessions overlapped
//! # Ok(())
//! # }
//! ```

use crate::adapt::{AdaptiveConfig, AdaptiveController, SchemeSwapped};
use crate::cluster::{ClusterEvent, EventCluster, JobId, UNPLACED};
use crate::coding::SchemeConfig;
use crate::coordinator::metrics::{merge_segments, RunReport};
use crate::grad::dataplane::SharedDataPlane;
use crate::obs::{Counter, EventKind, Gauge, Histogram, Obs};
use crate::session::{RoundPlan, SessionConfig, SessionEvent, SgcSession, WaitPolicy};
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use std::sync::Arc;

mod serve;

pub use serve::{
    AdmissionSource, AdmissionVerdict, ArrivalAt, ControlQueue, QueueSource, RawSubmit,
    RawVerdict, ScriptedSource, ServeConfig, SharedControl, SubmitRequest,
};

/// Which physical worker *initially* hosts a job's logical worker 0
/// (elastic re-placement may later migrate individual slots off retired
/// workers). Placement must be deterministic — two
/// identically-configured runs must place jobs identically
/// (`tests/properties.rs` pins this).
pub trait PlacementPolicy: Send {
    /// Rotation applied to `job`'s logical worker ids: logical `i`
    /// starts on physical `(i + offset) % n`, where `n` is the
    /// cluster's worker-slot capacity at run start.
    fn offset(&self, job: JobId, n: usize, jobs: usize) -> usize;

    /// Short name recorded in [`FleetUtilization::placement`].
    fn label(&self) -> &'static str;
}

/// Fair rotation: consecutive jobs anchor one worker apart, so no single
/// worker is "worker 0" (the uncoded/plain hot slot) for every job.
pub struct RoundRobinPlacement;

impl PlacementPolicy for RoundRobinPlacement {
    fn offset(&self, job: JobId, n: usize, _jobs: usize) -> usize {
        job % n.max(1)
    }

    fn label(&self) -> &'static str {
        "round-robin"
    }
}

/// Straggler-aware spreading: jobs anchor `n / N` workers apart, so the
/// cyclic codes' coded hot-sets (the `s+1`-wide support windows around
/// each job's current assignment) land on disjoint worker arcs — one
/// straggling worker then sits in at most one job's hot-set at a time.
pub struct DisjointPlacement;

impl PlacementPolicy for DisjointPlacement {
    fn offset(&self, job: JobId, n: usize, jobs: usize) -> usize {
        let stride = (n / jobs.max(1)).max(1);
        (job * stride) % n.max(1)
    }

    fn label(&self) -> &'static str {
        "disjoint"
    }
}

/// One admitted job: a scheme plus its session parameters.
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// Coding scheme (fixes the job's worker count `n`).
    pub scheme: SchemeConfig,
    /// Protocol parameters (rounds, μ, wait-out policy, …).
    pub session: SessionConfig,
}

/// Per-round hooks for drivers that execute real work alongside the
/// metadata protocol (e.g. [`crate::train::MultiModelTrainer`]). Default
/// implementations do nothing.
pub trait RoundObserver {
    /// A job's round was begun (tasks assigned, nothing submitted yet).
    fn round_started(
        &mut self,
        job: JobId,
        session: &SgcSession,
        plan: &RoundPlan,
    ) -> crate::Result<()> {
        let _ = (job, session, plan);
        Ok(())
    }

    /// A job's round committed; `events` are the session's close events
    /// (`RoundClosed` first, then `JobDecoded`/`DeadlineViolated`/…).
    /// `plan` still describes the closed round.
    fn round_closed(
        &mut self,
        job: JobId,
        session: &SgcSession,
        plan: &RoundPlan,
        events: &[SessionEvent],
    ) -> crate::Result<()> {
        let _ = (job, session, plan, events);
        Ok(())
    }
}

/// The do-nothing observer behind [`JobScheduler::run`].
pub struct NoopObserver;

impl RoundObserver for NoopObserver {}

/// How the scheduler reacts when a job's round can no longer make
/// progress: round timeout from the backend, or a wait-out stuck on
/// permanently-dead workers. Instead of failing the whole run, the job
/// is truncated at its last decoded paper-job, re-queued with capped
/// exponential backoff + deterministic jitter, escalated to degraded
/// (never-wait) decode, and finally quarantined — while every other
/// job keeps running. See `rust/DESIGN.md` § Failure domains.
#[derive(Clone, Debug)]
pub struct FailurePolicy {
    /// Re-queue attempts before the job is quarantined.
    pub max_retries: u32,
    /// Retries served with the admitted wait-out policy before the job
    /// escalates to degraded [`WaitPolicy::NeverWait`] decode. A live
    /// roster already below the scheme's straggler tolerance skips
    /// straight to degraded mode.
    pub degrade_after: u32,
    /// First retry's backoff (doubles per retry).
    pub backoff_base_s: f64,
    /// Backoff ceiling.
    pub backoff_cap_s: f64,
    /// Seed for the deterministic backoff jitter (keyed per job and
    /// retry, so identically-configured runs park and resume jobs at
    /// identical instants).
    pub jitter_seed: u64,
}

impl Default for FailurePolicy {
    fn default() -> Self {
        FailurePolicy {
            max_retries: 3,
            degrade_after: 1,
            backoff_base_s: 0.5,
            backoff_cap_s: 8.0,
            jitter_seed: 0xbac0_ff5e,
        }
    }
}

/// Terminal state of one job's failure-domain state machine
/// (`Running → Retrying → Degraded → Completed/Quarantined`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobStatus {
    /// Every paper-job decoded exactly (retries may have occurred).
    Completed,
    /// The job finished but some paper-jobs never decoded — the report
    /// carries the best available partial results and
    /// [`JobOutcome::error_bound`] quantifies what is missing.
    Degraded,
    /// The job exhausted [`FailurePolicy::max_retries`] and was retired
    /// with whatever its committed segments had decoded.
    Quarantined,
}

impl JobStatus {
    /// Stable lowercase name (report JSON, logs).
    pub fn as_str(&self) -> &'static str {
        match self {
            JobStatus::Completed => "completed",
            JobStatus::Degraded => "degraded",
            JobStatus::Quarantined => "quarantined",
        }
    }
}

/// Per-job failure-domain accounting for one scheduler run.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    /// Job id (index into [`ScheduleReport::reports`]).
    pub job: JobId,
    /// Terminal state of the job's outcome state machine.
    pub status: JobStatus,
    /// Re-queue attempts consumed.
    pub retries: u32,
    /// Rounds committed under degraded (never-wait) decode.
    pub degraded_rounds: u64,
    /// Paper-jobs that decoded exactly.
    pub completed_jobs: usize,
    /// Paper-jobs that never decoded (missing from or `NaN` in the
    /// job's report).
    pub failed_jobs: usize,
    /// Fraction of the job's gradient mass with no exact decode:
    /// `failed_jobs / admitted jobs`. 0.0 for a completed job; an
    /// operator-facing bound on how approximate the partial sums are.
    pub error_bound: f64,
}

impl JobOutcome {
    /// Serialize for `sgc serve --report-json`.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("job", self.job)
            .set("status", self.status.as_str())
            .set("retries", self.retries as u64)
            .set("degraded_rounds", self.degraded_rounds)
            .set("completed_jobs", self.completed_jobs)
            .set("failed_jobs", self.failed_jobs)
            .set("error_bound", self.error_bound);
        o
    }
}

/// Aggregate outcome of a multi-job run.
#[derive(Clone, Debug)]
pub struct FleetUtilization {
    /// Worker-slot capacity at run start.
    pub workers: usize,
    /// Jobs admitted (and completed) in this run.
    pub jobs: usize,
    /// Cluster-clock span of the whole run (first submit → last close).
    pub makespan_s: f64,
    /// Σ of the jobs' own protocol runtimes (`RunReport::total_runtime_s`).
    pub total_session_s: f64,
    /// Rounds committed across all jobs.
    pub rounds: usize,
    /// `WorkerDone` events absorbed.
    pub worker_done_events: u64,
    /// `WorkerDead` events absorbed.
    pub worker_dead_events: u64,
    /// `WorkerJoined` events absorbed (elastic backends only).
    pub worker_joined_events: u64,
    /// `WorkerRetired` events absorbed (elastic backends only).
    pub worker_retired_events: u64,
    /// Logical slots migrated off retired workers onto live spares at
    /// round starts — "the report notes re-placement".
    pub replacements: u64,
    /// Job re-queue attempts across all jobs (failure domains; see
    /// [`FailurePolicy`]).
    pub job_retries: u64,
    /// Rounds committed under degraded (never-wait) decode.
    pub degraded_rounds: u64,
    /// Jobs that finished with approximate results ([`JobStatus::Degraded`]).
    pub jobs_degraded: usize,
    /// Jobs retired after exhausting retries ([`JobStatus::Quarantined`]).
    pub jobs_quarantined: usize,
    /// Hot-swaps executed by the adaptive control plane (always 0
    /// without [`JobScheduler::set_adaptive`]).
    pub scheme_swaps: u64,
    /// Re-fit candidates the background [`crate::adapt::Refitter`]
    /// evaluated across all jobs.
    pub refit_candidates: u64,
    /// Rounds folded into the live profile since the last completed
    /// re-fit pass — how stale the fitted parameters were at run end.
    pub profile_staleness: u64,
    /// Length of the union of the jobs' `[admission, finish]` windows on
    /// the cluster clock. Equal to [`makespan_s`](Self::makespan_s) when
    /// every job is admitted up front (the [`JobScheduler::run`] path);
    /// under dynamic admission ([`JobScheduler::serve`]) it excludes the
    /// idle gaps between admission waves, so a mostly-idle serving loop
    /// does not deflate utilization.
    pub busy_span_s: f64,
    /// `total_session_s / busy_span_s`: how much session time the
    /// scheduler packed into each second the fleet actually had work
    /// (> 1 means sessions genuinely overlapped).
    pub multiplexing_gain: f64,
    /// Active jobs evicted (banked and re-queued) to shed load when the
    /// fleet shrank below aggregate demand (always 0 under
    /// [`JobScheduler::run`]).
    pub preemptions: u64,
    /// Submissions load-shed by admission control (always 0 under
    /// [`JobScheduler::run`]).
    pub jobs_rejected: u64,
    /// Placement policy that produced this run.
    pub placement: &'static str,
}

impl std::fmt::Display for FleetUtilization {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} jobs × {} workers [{}]: makespan {:.2}s, session-time {:.2}s \
             (gain {:.2}x), {} rounds, {} arrivals, {} deaths",
            self.jobs,
            self.workers,
            self.placement,
            self.makespan_s,
            self.total_session_s,
            self.multiplexing_gain,
            self.rounds,
            self.worker_done_events,
            self.worker_dead_events
        )?;
        if self.worker_joined_events + self.worker_retired_events + self.replacements > 0 {
            write!(
                f,
                ", {} joins, {} retires, {} re-placements",
                self.worker_joined_events, self.worker_retired_events, self.replacements
            )?;
        }
        if self.scheme_swaps + self.refit_candidates > 0 {
            write!(
                f,
                ", {} swaps, {} refit evals, staleness {}",
                self.scheme_swaps, self.refit_candidates, self.profile_staleness
            )?;
        }
        if self.job_retries + self.degraded_rounds > 0
            || self.jobs_degraded + self.jobs_quarantined > 0
        {
            write!(
                f,
                ", {} retries, {} degraded rounds, {} degraded jobs, {} quarantined",
                self.job_retries, self.degraded_rounds, self.jobs_degraded, self.jobs_quarantined
            )?;
        }
        if self.preemptions + self.jobs_rejected > 0 {
            write!(
                f,
                ", {} preempted, {} rejected",
                self.preemptions, self.jobs_rejected
            )?;
        }
        Ok(())
    }
}

impl FleetUtilization {
    /// Serialize every field (for `sgc serve --report-json`): CI smokes
    /// and operators assert on structured output instead of scraping
    /// stdout.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("workers", self.workers)
            .set("jobs", self.jobs)
            .set("makespan_s", self.makespan_s)
            .set("total_session_s", self.total_session_s)
            .set("rounds", self.rounds)
            .set("worker_done_events", self.worker_done_events)
            .set("worker_dead_events", self.worker_dead_events)
            .set("worker_joined_events", self.worker_joined_events)
            .set("worker_retired_events", self.worker_retired_events)
            .set("replacements", self.replacements)
            .set("job_retries", self.job_retries)
            .set("degraded_rounds", self.degraded_rounds)
            .set("jobs_degraded", self.jobs_degraded)
            .set("jobs_quarantined", self.jobs_quarantined)
            .set("scheme_swaps", self.scheme_swaps)
            .set("refit_candidates", self.refit_candidates)
            .set("profile_staleness", self.profile_staleness)
            .set("busy_span_s", self.busy_span_s)
            .set("multiplexing_gain", self.multiplexing_gain)
            .set("preemptions", self.preemptions)
            .set("jobs_rejected", self.jobs_rejected)
            .set("placement", self.placement);
        o
    }
}

/// Everything a finished multi-job run produced.
#[derive(Clone, Debug)]
pub struct ScheduleReport {
    /// Per-job protocol reports, in admission (job-id) order. A job
    /// that hot-swapped reports the merged view of all its segments
    /// (see [`merge_segments`]).
    pub reports: Vec<RunReport>,
    /// Hot-swaps executed during the run, in execution order (always
    /// empty without [`JobScheduler::set_adaptive`]).
    pub swaps: Vec<SchemeSwapped>,
    /// Per-job failure-domain outcomes, in admission order. A run with
    /// no faults reports every job [`JobStatus::Completed`] with zero
    /// retries.
    pub outcomes: Vec<JobOutcome>,
    /// Aggregate fleet-level accounting for the run.
    pub utilization: FleetUtilization,
}

impl ScheduleReport {
    /// Jobs that ended [`JobStatus::Quarantined`].
    pub fn quarantined(&self) -> usize {
        self.outcomes.iter().filter(|o| o.status == JobStatus::Quarantined).count()
    }

    /// True when *every* job was quarantined — the only condition under
    /// which `sgc serve` exits nonzero.
    pub fn all_failed(&self) -> bool {
        !self.outcomes.is_empty() && self.quarantined() == self.outcomes.len()
    }
}

impl ScheduleReport {
    /// Full structured dump: per-job [`RunReport`]s (see
    /// [`RunReport::to_json`]), executed swaps, and the utilization
    /// summary.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("reports", Json::Arr(self.reports.iter().map(|r| r.to_json()).collect()))
            .set("swaps", Json::Arr(self.swaps.iter().map(|s| s.to_json()).collect()))
            .set("outcomes", Json::Arr(self.outcomes.iter().map(|o| o.to_json()).collect()))
            .set("utilization", self.utilization.to_json());
        o
    }
}

/// Handles into an attached [`Obs`] bundle. Registered once (the
/// allocating step); the per-round hooks then record through these
/// handles with pure atomics and ring writes — never touching the
/// registry on the hot path.
struct SchedObs {
    obs: Arc<Obs>,
    /// Per-job round-latency summaries, indexed by job id; registered
    /// at run start once the job count is known.
    job_latency: Vec<Histogram>,
    rounds: Counter,
    arrivals: Counter,
    deaths: Counter,
    swaps: Counter,
    replacements: Counter,
    retries: Counter,
    degraded: Counter,
    quarantines: Counter,
    submitted: Counter,
    rejected: Counter,
    preempted: Counter,
    queue_depth: Gauge,
    adm_queue: Gauge,
    makespan: Gauge,
    gain: Gauge,
}

/// One admitted job's scheduling state.
struct Slot {
    /// `None` once the run completed and was consumed into `report`.
    session: Option<SgcSession>,
    plan: RoundPlan,
    /// Placement map: logical worker `i` runs on physical
    /// `place[i]`. Seeded from the policy's rotation at run start;
    /// individual entries migrate onto live spares when their physical
    /// worker retires (elastic membership).
    place: Vec<usize>,
    /// Inverse map for event routing, sized to the cluster capacity:
    /// `inv[p]` is the logical id hosted on physical `p`, or
    /// `usize::MAX` when `p` is not in this job's placement. Rebuilt at
    /// every round start.
    inv: Vec<usize>,
    /// Round currently (or last) submitted, as the cluster knows it
    /// (`round_base + plan.round`).
    round: u64,
    /// Cluster-visible rounds consumed by earlier swap segments: keeps
    /// `(job, round)` keys unique across hot-swaps.
    round_base: u64,
    /// The job's current scheme (replaced on hot-swap).
    scheme: SchemeConfig,
    /// Session parameters as admitted; post-swap sessions reuse them
    /// with the job count rebased to the remaining work.
    session_cfg: SessionConfig,
    /// Paper-jobs the job was admitted with.
    jobs_total: usize,
    /// Paper-jobs owned by already-finished swap segments.
    assigned_base: usize,
    /// Reports of already-finished swap segments, in execution order
    /// (empty until the first hot-swap).
    segments: Vec<RunReport>,
    /// Paper-jobs each finished segment owned ([`merge_segments`] caps).
    segment_assigned: Vec<usize>,
    /// Cluster time the current round was submitted.
    submit_s: f64,
    /// A round is open and awaiting events.
    open: bool,
    /// Physical workers reported unable to serve the *current* round
    /// (`WorkerDead` events for `slot.round`; reset every round —
    /// backends re-report per submission).
    dead: Vec<bool>,
    // --- failure domain (see [`FailurePolicy`]) ---
    /// Re-queue attempts consumed so far.
    retries: u32,
    /// `Some(t)`: the job is parked until cluster clock `t`, when a
    /// fresh session restarts its remaining paper-jobs.
    retry_at_s: Option<f64>,
    /// Future segments run degraded ([`WaitPolicy::NeverWait`]).
    degraded: bool,
    /// Rounds committed while degraded.
    degraded_rounds: u64,
    /// The job exhausted its retry budget and was retired.
    failed: bool,
    report: Option<RunReport>,
    // --- serving loop (see [`JobScheduler::serve`]) ---
    /// Admission priority: higher runs first, ties broken by job id
    /// (always 0 under [`JobScheduler::run`]).
    priority: u8,
    /// Submitter-chosen name, echoed in journals and reports.
    name: String,
    /// Accepted but not yet activated (or re-queued by preemption); a
    /// queued slot holds no session and consumes no fleet capacity.
    queued: bool,
    /// Marked for eviction: the current segment finishes its already-
    /// assigned jobs ([`SgcSession::finish_after_assigned`]), banks its
    /// ledger, and the slot returns to the queue.
    preempt: bool,
    /// Cluster clock when the job was first activated (None until then;
    /// `run` stamps every slot with the run's start).
    admit_s: Option<f64>,
    /// Cluster clock when the job finished (report or quarantine).
    finish_s: Option<f64>,
}

/// Multiplexes `N` admitted [`SgcSession`] jobs over one shared
/// [`EventCluster`]. See the [module docs](self) for the event pump.
pub struct JobScheduler<'c> {
    cluster: &'c mut dyn EventCluster,
    policy: Box<dyn PlacementPolicy>,
    slots: Vec<Slot>,
    ran: bool,
    /// Live roster, indexed by physical worker id. Seeded all-live at
    /// run start; maintained by `WorkerJoined`/`WorkerRetired` events
    /// (grows when an elastic backend admits a fresh id).
    live: Vec<bool>,
    // --- reused scratch (the pump allocates nothing per event batch) ---
    events: Vec<ClusterEvent>,
    loads: Vec<f64>,
    state: Vec<bool>,
    pending: Vec<usize>,
    /// Per-job failure-domain policy (retry/degrade/quarantine).
    failure: FailurePolicy,
    /// Adaptive control plane, when enabled (see [`crate::adapt`]).
    adapt: Option<AdaptiveController>,
    /// Observability handles, when attached (see [`crate::obs`]).
    obs: Option<SchedObs>,
    /// The gradient data plane, when real-gradient jobs are admitted
    /// (see [`Self::set_dataplane`]).
    dp: Option<SharedDataPlane>,
    /// Hot-swaps executed so far, in execution order.
    swaps: Vec<SchemeSwapped>,
    // --- utilization counters ---
    done_events: u64,
    dead_events: u64,
    joined_events: u64,
    retired_events: u64,
    replacements: u64,
    rounds_closed: usize,
    /// Active jobs banked and re-queued by the serving loop's balancer.
    preemptions: u64,
    /// Submissions offered to [`Self::serve`] (accepted or rejected).
    submitted_total: u64,
    /// Submissions load-shed by admission control.
    rejected_total: u64,
}

impl<'c> JobScheduler<'c> {
    /// Scheduler with the default [`RoundRobinPlacement`].
    pub fn new(cluster: &'c mut dyn EventCluster) -> Self {
        Self::with_policy(cluster, Box::new(RoundRobinPlacement))
    }

    /// Scheduler with an explicit placement policy.
    pub fn with_policy(
        cluster: &'c mut dyn EventCluster,
        policy: Box<dyn PlacementPolicy>,
    ) -> Self {
        JobScheduler {
            cluster,
            policy,
            slots: Vec::new(),
            ran: false,
            live: Vec::new(),
            events: Vec::new(),
            loads: Vec::new(),
            state: Vec::new(),
            pending: Vec::new(),
            failure: FailurePolicy::default(),
            adapt: None,
            obs: None,
            dp: None,
            swaps: Vec::new(),
            done_events: 0,
            dead_events: 0,
            joined_events: 0,
            retired_events: 0,
            replacements: 0,
            rounds_closed: 0,
            preemptions: 0,
            submitted_total: 0,
            rejected_total: 0,
        }
    }

    /// Enable the adaptive control plane: profile worker delays from the
    /// event stream, re-fit scheme parameters in the background, and
    /// hot-swap jobs at job boundaries when a re-fit clears the swap
    /// policy (see [`crate::adapt`]). Call before [`run`](Self::run);
    /// without it the scheduler behaves exactly as before.
    pub fn set_adaptive(&mut self, cfg: AdaptiveConfig) {
        self.adapt = Some(AdaptiveController::new(cfg));
    }

    /// The adaptive controller, when adaptation is enabled (inspection).
    pub fn adaptive(&self) -> Option<&AdaptiveController> {
        self.adapt.as_ref()
    }

    /// Replace the default [`FailurePolicy`] (retry budget, backoff
    /// shape, degrade escalation). Call before [`run`](Self::run).
    pub fn set_failure_policy(&mut self, policy: FailurePolicy) {
        self.failure = policy;
    }

    /// Attach the gradient data plane (see [`crate::grad`]): every round
    /// start of a job the plane was configured for stages the round's
    /// wire work units — with the GC coefficients resolved master-side
    /// and the parameter version pinned — *before* the cluster fan-out,
    /// so a fleet backend finds the entry when it ships assignments.
    /// Jobs the plane does not know keep the synthetic path untouched.
    /// Share the same handle with the fleet master and the
    /// [`GradPump`](crate::grad::GradPump) observer.
    pub fn set_dataplane(&mut self, dp: SharedDataPlane) {
        self.dp = Some(dp);
    }

    /// Attach an observability bundle (see [`crate::obs`]): per-job
    /// round-latency histograms, fleet-level counters/gauges, and
    /// journaled round spans (assign → per-worker arrival → μ-cut →
    /// close → decode). The hooks are read-only — an instrumented run
    /// produces a byte-identical [`ScheduleReport`] (pinned by
    /// `tests/obs.rs`) — and allocation-free per round in steady state
    /// (pinned by `tests/alloc.rs`). Call before [`run`](Self::run);
    /// the bundle is shared with the adaptive controller when one is
    /// configured.
    pub fn set_obs(&mut self, obs: Arc<Obs>) {
        let m = &obs.metrics;
        let rounds = m.counter("sgc_rounds_closed_total", "", "Rounds committed across all jobs");
        let arrivals = m.counter("sgc_worker_done_total", "", "WorkerDone events absorbed");
        let deaths = m.counter("sgc_worker_dead_total", "", "WorkerDead events absorbed");
        let swaps = m.counter("sgc_scheme_swaps_total", "", "Adaptive hot-swaps executed");
        let replacements = m.counter(
            "sgc_replacements_total",
            "",
            "Logical slots migrated off retired workers onto live spares",
        );
        let retries = m.counter(
            "sgc_job_retries_total",
            "",
            "Job attempts truncated and re-queued by the failure domains",
        );
        let degraded = m.counter(
            "sgc_degraded_rounds_total",
            "",
            "Rounds committed under degraded (never-wait) decode",
        );
        let quarantines = m.counter(
            "sgc_jobs_quarantined_total",
            "",
            "Jobs retired after exhausting their retry budget",
        );
        let submitted = m.counter(
            "sgc_jobs_submitted_total",
            "",
            "Submissions offered to the serving loop (accepted or not)",
        );
        let rejected = m.counter(
            "sgc_jobs_rejected_total",
            "",
            "Submissions load-shed by admission control",
        );
        let preempted = m.counter(
            "sgc_jobs_preempted_total",
            "",
            "Active jobs banked and re-queued to shed load on a shrunken fleet",
        );
        let queue_depth = m.gauge("sgc_jobs_unfinished", "", "Admitted jobs still running");
        let adm_queue = m.gauge(
            "sgc_admission_queue_depth",
            "",
            "Jobs accepted but not yet activated by the serving loop",
        );
        let makespan =
            m.gauge("sgc_fleet_makespan_seconds", "", "Cluster-clock span of the last run");
        let gain = m.gauge(
            "sgc_fleet_multiplexing_gain",
            "",
            "Session seconds packed per shared-fleet second",
        );
        self.obs = Some(SchedObs {
            obs,
            job_latency: Vec::new(),
            rounds,
            arrivals,
            deaths,
            swaps,
            replacements,
            retries,
            degraded,
            quarantines,
            submitted,
            rejected,
            preempted,
            queue_depth,
            adm_queue,
            makespan,
            gain,
        });
    }

    /// Admit one job; returns its [`JobId`] (also its index in
    /// [`ScheduleReport::reports`]). All jobs must be admitted before
    /// [`run`](Self::run). The scheme's `n` may be *smaller* than the
    /// cluster's capacity: the surplus workers are spares, available to
    /// elastic re-placement.
    pub fn admit(&mut self, spec: &JobSpec) -> crate::Result<JobId> {
        anyhow::ensure!(!self.ran, "JobScheduler::admit after run");
        self.admit_slot(spec)
    }

    /// [`admit`](Self::admit) without the `admit`-before-`run` guard:
    /// the serving loop ([`Self::serve`]) admits dynamically while the
    /// pump is live, so its slots join mid-flight (queued until
    /// activation).
    fn admit_slot(&mut self, spec: &JobSpec) -> crate::Result<JobId> {
        let session = SgcSession::new(&spec.scheme, spec.session.clone());
        let n = self.cluster.n();
        anyhow::ensure!(
            session.n() <= n,
            "cluster has {n} workers but scheme {} expects n = {}",
            spec.scheme.label(),
            session.n()
        );
        let job = self.slots.len();
        self.slots.push(Slot {
            session: Some(session),
            plan: RoundPlan::default(),
            place: Vec::new(),
            inv: Vec::new(),
            round: 0,
            round_base: 0,
            scheme: spec.scheme.clone(),
            session_cfg: spec.session.clone(),
            jobs_total: spec.session.jobs,
            assigned_base: 0,
            segments: Vec::new(),
            segment_assigned: Vec::new(),
            submit_s: 0.0,
            open: false,
            dead: vec![false; n],
            retries: 0,
            retry_at_s: None,
            degraded: false,
            degraded_rounds: 0,
            failed: false,
            report: None,
            priority: 0,
            name: format!("job-{job}"),
            queued: false,
            preempt: false,
            admit_s: None,
            finish_s: None,
        });
        Ok(job)
    }

    /// Number of admitted jobs.
    pub fn jobs(&self) -> usize {
        self.slots.len()
    }

    /// Run every admitted job to completion.
    pub fn run(&mut self) -> crate::Result<ScheduleReport> {
        self.run_observed(&mut NoopObserver)
    }

    /// Run with per-round [`RoundObserver`] hooks.
    pub fn run_observed(
        &mut self,
        obs: &mut dyn RoundObserver,
    ) -> crate::Result<ScheduleReport> {
        anyhow::ensure!(!self.ran, "JobScheduler::run called twice");
        anyhow::ensure!(!self.slots.is_empty(), "no jobs admitted");
        self.ran = true;
        let n = self.cluster.n();
        let jobs = self.slots.len();
        // every slot known at run start is live; membership events
        // maintain the roster from here on
        self.live.clear();
        self.live.resize(n, true);
        for (j, slot) in self.slots.iter_mut().enumerate() {
            let offset = self.policy.offset(j, n, jobs) % n.max(1);
            let sn = slot.session.as_ref().expect("unstarted job").n();
            slot.place = (0..sn).map(|i| (i + offset) % n).collect();
        }
        let start_s = self.cluster.now_s();
        // all jobs are co-admitted on this path; the busy-span union in
        // build_report then degenerates to the plain makespan
        for slot in &mut self.slots {
            slot.admit_s.get_or_insert(start_s);
        }

        // Register per-job series and journal admissions now that the
        // job count is final. Registration is the allocating step; the
        // per-round hooks below only touch the returned handles.
        if let Some(so) = &mut self.obs {
            so.job_latency.clear();
            for j in 0..jobs {
                so.job_latency.push(so.obs.metrics.histogram(
                    "sgc_round_latency_seconds",
                    &format!("job=\"{j}\""),
                    "Per-job protocol round latency",
                ));
                so.obs.journal.record(start_s, EventKind::JobAdmit, j as i64, -1, -1, 0.0);
            }
            so.queue_depth.set(jobs as f64);
            so.obs.journal.record(start_s, EventKind::QueueDepth, -1, -1, -1, jobs as f64);
        }
        // share the bundle with the adaptive controller, whichever of
        // set_obs / set_adaptive was called first
        if let (Some(ad), Some(so)) = (self.adapt.as_mut(), self.obs.as_ref()) {
            ad.set_obs(so.obs.clone());
        }

        // Open round 1 of every job, in job-id order (determinism: the
        // backend's RNG draws follow submission order).
        for j in 0..jobs {
            self.start_round(j, obs)?;
        }

        let mut stalls = 0u32;
        while self.slots.iter().any(|s| s.report.is_none()) {
            // Sleep horizon: the earliest still-future μ-cutoff across
            // open jobs. Jobs whose cutoff already passed are waiting for
            // a specific arrival — only an event can help them, so they
            // contribute no horizon.
            let pre = self.cluster.now_s();
            let mut wake = f64::INFINITY;
            for slot in &self.slots {
                // parked jobs wake at their scheduled retry instant
                if let Some(t) = slot.retry_at_s {
                    if t > pre && t < wake {
                        wake = t;
                    }
                    continue;
                }
                if !slot.open {
                    continue;
                }
                if let Some(h) = slot.session.as_ref().expect("open slot").deadline_hint()
                {
                    let t = slot.submit_s + h;
                    if t > pre && t < wake {
                        wake = t;
                    }
                }
            }

            let batch = self.cluster.poll(wake);
            self.events.clear();
            self.events.extend_from_slice(batch);
            // Judgment instant: captured BEFORE the co-timed drain below,
            // so on a wall-clock backend any arrival stamped at or before
            // `now` is either already in this batch or gets absorbed by
            // that drain — a result that beat the μ-cutoff is never cut
            // just because it sat unprocessed in the channel (the
            // try_close_round contract; the deleted fleet loop kept the
            // same order).
            let now = self.cluster.now_s();
            // Drain events up to the judgment instant before judging any
            // round — unconditionally, so (a) *how* a backend batches its
            // deliveries (one event per call, ties split, everything at
            // once) can never reorder the job-id-ordered close/resubmit
            // sequence below, and (b) on a wall-clock backend an arrival
            // stamped before `now` that raced past the first poll's drain
            // is absorbed before its worker can be cut at the cutoff.
            loop {
                let more = self.cluster.poll(now);
                if more.is_empty() {
                    break;
                }
                self.events.extend_from_slice(more);
            }
            self.absorb_events()?;
            let closed_before = self.rounds_closed;
            for j in 0..jobs {
                self.try_advance(j, now, obs)?;
            }

            // Progress guard: a simulated backend that can neither
            // deliver events nor advance time while jobs are open means
            // the run is deadlocked — fail loudly instead of spinning.
            let progressed = !self.events.is_empty()
                || self.rounds_closed > closed_before
                || self.cluster.now_s() > pre;
            stalls = if progressed { 0 } else { stalls + 1 };
            anyhow::ensure!(
                stalls < 1000,
                "scheduler made no progress with {} jobs unfinished (deadlocked backend?)",
                self.slots.iter().filter(|s| s.report.is_none()).count()
            );
        }

        Ok(self.build_report(start_s, n))
    }

    /// Fold the finished slots into a [`ScheduleReport`] — the shared
    /// tail of [`run_observed`](Self::run_observed) and
    /// [`serve`](Self::serve). Every slot must hold a report.
    fn build_report(&mut self, start_s: f64, workers: usize) -> ScheduleReport {
        let end_s = self.cluster.now_s();
        let makespan = (end_s - start_s).max(0.0);
        let jobs = self.slots.len();
        let reports: Vec<RunReport> = self
            .slots
            .iter_mut()
            .map(|s| s.report.take().expect("all jobs finished"))
            .collect();
        let total_session_s: f64 = reports.iter().map(|r| r.total_runtime_s).sum();
        // Busy span: the union of per-job `[admission, finish]` windows,
        // so idle gaps between admission waves don't deflate the gain.
        // Under `run` every window starts at `start_s` and the last
        // finish is the clock the pump exited on, so this equals the
        // plain makespan and the gain formula is unchanged there.
        let mut windows: Vec<(f64, f64)> = self
            .slots
            .iter()
            .map(|s| (s.admit_s.unwrap_or(start_s), s.finish_s.unwrap_or(end_s)))
            .collect();
        let busy_span = union_span(&mut windows);
        // Per-job failure-domain outcomes: what each job's state machine
        // ended on, and how approximate its report is.
        let outcomes: Vec<JobOutcome> = self
            .slots
            .iter()
            .enumerate()
            .map(|(j, s)| {
                let rep = &reports[j];
                let reported = rep.job_completion_s.len();
                let undecoded =
                    rep.job_completion_s.iter().filter(|t| !t.is_finite()).count();
                let failed_jobs = s.jobs_total.saturating_sub(reported) + undecoded;
                let status = if s.failed {
                    JobStatus::Quarantined
                } else if failed_jobs > 0 {
                    JobStatus::Degraded
                } else {
                    JobStatus::Completed
                };
                JobOutcome {
                    job: j,
                    status,
                    retries: s.retries,
                    degraded_rounds: s.degraded_rounds,
                    completed_jobs: s.jobs_total - failed_jobs.min(s.jobs_total),
                    failed_jobs,
                    error_bound: failed_jobs as f64 / s.jobs_total.max(1) as f64,
                }
            })
            .collect();
        let swaps = std::mem::take(&mut self.swaps);
        let (refit_candidates, profile_staleness) = self
            .adapt
            .as_ref()
            .map(|ad| (ad.candidates_evaluated(), ad.profile_staleness()))
            .unwrap_or((0, 0));
        let utilization = FleetUtilization {
            workers,
            jobs,
            makespan_s: makespan,
            total_session_s,
            rounds: self.rounds_closed,
            worker_done_events: self.done_events,
            worker_dead_events: self.dead_events,
            worker_joined_events: self.joined_events,
            worker_retired_events: self.retired_events,
            replacements: self.replacements,
            job_retries: outcomes.iter().map(|o| u64::from(o.retries)).sum(),
            degraded_rounds: outcomes.iter().map(|o| o.degraded_rounds).sum(),
            jobs_degraded: outcomes.iter().filter(|o| o.status == JobStatus::Degraded).count(),
            jobs_quarantined: outcomes
                .iter()
                .filter(|o| o.status == JobStatus::Quarantined)
                .count(),
            scheme_swaps: swaps.len() as u64,
            refit_candidates,
            profile_staleness,
            busy_span_s: busy_span,
            multiplexing_gain: if busy_span > 0.0 { total_session_s / busy_span } else { 0.0 },
            preemptions: self.preemptions,
            jobs_rejected: self.rejected_total,
            placement: self.policy.label(),
        };
        if let Some(so) = &self.obs {
            so.makespan.set(utilization.makespan_s);
            so.gain.set(utilization.multiplexing_gain);
            so.queue_depth.set(0.0);
        }
        ScheduleReport { reports, swaps, outcomes, utilization }
    }

    /// Route one absorbed event batch into the owning sessions.
    fn absorb_events(&mut self) -> crate::Result<()> {
        let events = std::mem::take(&mut self.events);
        let result = self.route_events(&events);
        self.events = events;
        result
    }

    fn route_events(&mut self, events: &[ClusterEvent]) -> crate::Result<()> {
        for &ev in events {
            match ev {
                // Death flags are strictly per (job, round): backends
                // re-stage WorkerDead for every submission a worker owes,
                // and a stale event from an earlier round must neither
                // kill nor resurrect a worker for the *current* one (a
                // worker that was dead when this round was assigned can
                // never fill it, however alive it is now).
                ClusterEvent::WorkerDone { job, round, worker, finish_s } => {
                    self.done_events += 1;
                    if let Some(so) = &self.obs {
                        so.arrivals.inc();
                    }
                    let Some(slot) = self.slots.get_mut(job) else { continue };
                    if slot.open && round == slot.round {
                        // physical → logical through this round's
                        // placement; a worker outside the job's placed
                        // set (a spare serving a zero-load assignment)
                        // carries no protocol meaning
                        let logical = slot.inv.get(worker).copied().unwrap_or(usize::MAX);
                        if logical != usize::MAX {
                            if let Some(d) = slot.dead.get_mut(worker) {
                                *d = false;
                            }
                            slot.session
                                .as_mut()
                                .expect("open slot")
                                .submit(logical, finish_s);
                            if let Some(ad) = self.adapt.as_mut() {
                                ad.observe_done(job, round, logical, finish_s);
                            }
                            if let Some(so) = &self.obs {
                                // the arrival's wall instant is the
                                // round origin plus the service time
                                so.obs.journal.record(
                                    slot.submit_s + finish_s,
                                    EventKind::WorkerArrive,
                                    job as i64,
                                    round as i64,
                                    logical as i64,
                                    finish_s,
                                );
                            }
                        }
                    }
                }
                ClusterEvent::WorkerDead { job, round, worker } => {
                    self.dead_events += 1;
                    if let Some(so) = &self.obs {
                        so.deaths.inc();
                    }
                    if let Some(slot) = self.slots.get_mut(job) {
                        if slot.open && round == slot.round {
                            if let Some(d) = slot.dead.get_mut(worker) {
                                *d = true;
                            }
                        }
                    }
                }
                ClusterEvent::RoundTimeout { job, round } => {
                    // Failure domain: the backend gave up on this round.
                    // Truncate *this* job at its last decoded paper-job
                    // and re-queue it; every other job keeps running. A
                    // stale timeout (closed round, retried or quarantined
                    // job) routes nowhere — `slot.round` only ever grows.
                    let hit = self
                        .slots
                        .get(job)
                        .is_some_and(|s| s.open && round == s.round);
                    if hit {
                        let now = self.cluster.now_s();
                        self.fail_attempt(job, now);
                    }
                }
                // membership events maintain the live roster; placement
                // reacts at the next round start (replace_dead_slots)
                ClusterEvent::WorkerJoined { worker } => {
                    self.joined_events += 1;
                    if worker >= self.live.len() {
                        self.live.resize(worker + 1, false);
                    }
                    self.live[worker] = true;
                }
                ClusterEvent::WorkerRetired { worker } => {
                    self.retired_events += 1;
                    if let Some(l) = self.live.get_mut(worker) {
                        *l = false;
                    }
                }
            }
        }
        Ok(())
    }

    /// Try to close job `j`'s open round at judgment instant `now` and,
    /// if it closed, start the next one (or finish the job).
    fn try_advance(
        &mut self,
        j: usize,
        now: f64,
        obs: &mut dyn RoundObserver,
    ) -> crate::Result<()> {
        let slot = &mut self.slots[j];
        // A parked job restarts once the cluster clock reaches its
        // backoff deadline (the pump's wake horizon includes it).
        if let Some(t) = slot.retry_at_s {
            if now >= t {
                return self.restart_job(j, obs);
            }
            return Ok(());
        }
        if !slot.open {
            return Ok(());
        }
        let round = slot.round;
        let session = slot.session.as_mut().expect("open slot");
        let now_rel = (now - slot.submit_s).max(0.0);
        // O(1) gating per event batch; the pending *list* is only
        // materialized on the rare hopeless-wait paths below.
        let pending = session.pending_count();
        let hint = session.deadline_hint();
        let closable = pending == 0 || hint.is_some_and(|h| now_rel >= h);
        // A wait on workers that are all permanently dead can never end
        // (mirrors the old fleet loop); checked wherever a wait could
        // otherwise spin until the round timeout. Logical ids map through
        // this round's placement.
        let all_pending_dead = |pending_buf: &[usize], place: &[usize], dead: &[bool]| {
            !pending_buf.is_empty()
                && pending_buf
                    .iter()
                    .all(|&lw| dead.get(place[lw]).copied().unwrap_or(true))
        };
        if !closable {
            // κ unknown means *nobody* has reported; if every awaited
            // worker is dead, no arrival can ever establish a cutoff.
            if hint.is_none() && pending > 0 {
                session.pending_workers_into(&mut self.pending);
                if all_pending_dead(&self.pending, &slot.place, &slot.dead) {
                    // no arrival can ever establish a cutoff: fail this
                    // attempt (retry/degrade/quarantine), not the run
                    self.fail_attempt(j, now);
                }
            }
            return Ok(());
        }
        let events = session.try_close_round(now_rel);
        if matches!(events.first(), Some(SessionEvent::WaitingFor { .. })) {
            // The wait-out policy needs an arrival that has not come; if
            // every awaited worker is permanently dead the wait is
            // hopeless — fail the attempt (retry/degrade/quarantine)
            // instead of the whole run.
            session.pending_workers_into(&mut self.pending);
            if all_pending_dead(&self.pending, &slot.place, &slot.dead) {
                self.fail_attempt(j, now);
            }
            return Ok(());
        }
        self.rounds_closed += 1;
        obs.round_closed(j, session, &slot.plan, &events)?;
        slot.open = false;
        if slot.degraded {
            slot.degraded_rounds += 1;
        }
        // Journal the commit: the μ-cut decision (κ, detected
        // stragglers), the round span end, and any paper-jobs that
        // became decodable — all read from the committed RoundRecord,
        // never re-derived.
        if let Some(so) = &self.obs {
            if let Some(rec) = slot.session.as_ref().expect("closed slot").last_round() {
                so.rounds.inc();
                if let Some(h) = so.job_latency.get(j) {
                    h.record(rec.duration_s);
                }
                let (jid, rid) = (j as i64, round as i64);
                so.obs.journal.record(
                    now,
                    EventKind::CutDecision,
                    jid,
                    rid,
                    rec.detected_stragglers as i64,
                    rec.kappa_s,
                );
                so.obs.journal.record(
                    now,
                    EventKind::RoundClose,
                    jid,
                    rid,
                    rec.waited_out as i64,
                    rec.duration_s,
                );
                if slot.degraded {
                    so.degraded.inc();
                    so.obs.journal.record(
                        now,
                        EventKind::DegradedRound,
                        jid,
                        rid,
                        -1,
                        rec.duration_s,
                    );
                }
                // Real-gradient jobs additionally journal the data-plane
                // decode event, so operators can line gradient
                // reconstruction up against the protocol-level decode.
                let grad_job = self.dp.as_ref().is_some_and(|dp| {
                    dp.lock().expect("data plane lock poisoned").is_grad_job(j as u32)
                });
                for ev in &events {
                    if let SessionEvent::JobDecoded { job, .. } = ev {
                        so.obs.journal.record(now, EventKind::JobDecode, jid, *job as i64, -1, 0.0);
                        if grad_job {
                            so.obs.journal.record(
                                now,
                                EventKind::GradientDecoded,
                                jid,
                                *job as i64,
                                -1,
                                0.0,
                            );
                        }
                    }
                }
            }
        }
        // Adaptive step (no-op without `set_adaptive`): fold the closed
        // round into the profile, tick the background re-fit, and — once
        // a swap is staged — truncate the incumbent session so it drains
        // its decode tail toward the swap boundary.
        if self.adapt.is_some() {
            self.adaptive_close(j, now);
        }
        // A preemption mark drains the session exactly like a staged
        // swap: finish what is assigned, then bank and re-queue in
        // finish_segment. Re-asserted at every close (idempotent).
        if self.slots[j].preempt {
            self.slots[j]
                .session
                .as_mut()
                .expect("closed slot")
                .finish_after_assigned();
        }
        let slot = &mut self.slots[j];
        if slot.session.as_ref().expect("closed slot").is_complete() {
            let finished = slot.session.take().expect("closed slot");
            let assigned = finished.assigned_jobs();
            let segment = finished.into_report();
            self.finish_segment(j, assigned, segment, now, obs)?;
        } else {
            self.start_round(j, obs)?;
        }
        Ok(())
    }

    /// Post-close adaptive hook for job `j` (see [`crate::adapt`]).
    /// Folding, re-fit ticking and swap staging all happen here, between
    /// rounds — the swap itself executes in `finish_segment` once the
    /// truncated session completes its decode tail.
    fn adaptive_close(&mut self, j: usize, now: f64) {
        let round = self.slots[j].round;
        let ad = self.adapt.as_mut().expect("adaptive_close without a controller");
        ad.round_closed(j, round, &self.slots[j].scheme, now);
        if ad.pending_swap(j).is_some() {
            // Idempotent: every close while draining re-asserts the cap.
            self.slots[j]
                .session
                .as_mut()
                .expect("closed slot")
                .finish_after_assigned();
        }
    }

    /// Deterministic capped exponential backoff for job `j`'s
    /// `retry`-th re-queue: `base · 2^(retry-1)` capped, scaled by a
    /// jitter in `[0.5, 1.0)` drawn from a PCG stream keyed on
    /// `(jitter_seed, job, retry)` — identically-configured runs park
    /// and resume identically.
    fn backoff_s(&self, job: usize, retry: u32) -> f64 {
        let p = &self.failure;
        let exp = f64::from(1u32 << (retry.saturating_sub(1)).min(20));
        let raw = (p.backoff_base_s * exp).min(p.backoff_cap_s);
        let mut rng = Pcg32::new(p.jitter_seed ^ job as u64, u64::from(retry));
        raw * (0.5 + 0.5 * rng.f64())
    }

    /// Can the live roster still conform to job `j`'s scheme? `false`
    /// once fewer than `n - tolerance` placed workers are live — the
    /// straggler pattern then exceeds the code's budget every round and
    /// exact decode is impossible until membership recovers.
    fn roster_below_tolerance(&self, j: usize) -> bool {
        let slot = &self.slots[j];
        let n = slot.place.len();
        let live = slot.place.iter().filter(|&&p| self.live.get(p).copied().unwrap_or(false));
        // count spares available for re-placement as live capacity
        let spares = (0..self.live.len())
            .filter(|&p| self.live[p] && !slot.place.contains(&p))
            .count();
        let usable = live.count() + spares.min(n);
        usable.min(n) + slot.scheme.per_round_tolerance() < n
    }

    /// Fail job `j`'s current attempt: truncate at the last decoded
    /// paper-job (the open round is dropped — only committed rounds
    /// reach the report), bank the segment, and either park the job for
    /// a backoff-delayed retry or quarantine it once the retry budget
    /// is spent. Other jobs are untouched — this is the failure-domain
    /// boundary.
    fn fail_attempt(&mut self, j: usize, now: f64) {
        let slot = &mut self.slots[j];
        let session = slot.session.take().expect("failing a job with no session");
        slot.open = false;
        let decoded = session.decoded_prefix();
        let segment = session.into_report();
        // Rebase cluster round keys past the aborted round: stale events
        // from this attempt can never reach the fresh session.
        slot.round_base = slot.round;
        slot.assigned_base += decoded;
        slot.segments.push(segment);
        slot.segment_assigned.push(decoded);
        if let Some(ad) = self.adapt.as_mut() {
            // a swap staged against the aborted segment is stale
            let _ = ad.take_swap(j);
        }
        let slot = &mut self.slots[j];
        if slot.retries >= self.failure.max_retries {
            slot.failed = true;
            slot.report = Some(merge_segments(&slot.segments, &slot.segment_assigned));
            if let Some(so) = &self.obs {
                so.quarantines.inc();
                so.obs.journal.record(
                    now,
                    EventKind::JobQuarantine,
                    j as i64,
                    slot.round as i64,
                    -1,
                    f64::from(slot.retries),
                );
            }
            self.note_job_finished(j, now);
            return;
        }
        slot.retries += 1;
        let retries = slot.retries;
        let wait = self.backoff_s(j, retries);
        let escalate = retries > self.failure.degrade_after || self.roster_below_tolerance(j);
        let slot = &mut self.slots[j];
        slot.retry_at_s = Some(now + wait);
        if escalate {
            slot.degraded = true;
        }
        if let Some(so) = &self.obs {
            so.retries.inc();
            so.obs.journal.record(
                now,
                EventKind::JobRetry,
                j as i64,
                slot.round as i64,
                -1,
                wait,
            );
        }
    }

    /// A parked job's backoff elapsed: restart its remaining paper-jobs
    /// in a fresh session — degraded attempts run
    /// [`WaitPolicy::NeverWait`] (approximate decode, never blocks on a
    /// shrunken roster).
    fn restart_job(&mut self, j: usize, obs: &mut dyn RoundObserver) -> crate::Result<()> {
        let slot = &mut self.slots[j];
        slot.retry_at_s = None;
        let remaining = slot.jobs_total.saturating_sub(slot.assigned_base);
        if remaining == 0 {
            // the aborted round sat past the last decode: nothing left
            slot.report = Some(merge_segments(&slot.segments, &slot.segment_assigned));
            let now = self.cluster.now_s();
            self.note_job_finished(j, now);
            return Ok(());
        }
        // a roster that shrank below tolerance while parked escalates too
        let escalate = self.roster_below_tolerance(j);
        let slot = &mut self.slots[j];
        if escalate {
            slot.degraded = true;
        }
        let mut cfg = slot.session_cfg.clone();
        cfg.jobs = remaining;
        if slot.degraded {
            cfg.wait_policy = WaitPolicy::NeverWait;
        }
        slot.session = Some(SgcSession::new(&slot.scheme, cfg));
        self.start_round(j, obs)
    }

    /// A session ran to completion (possibly truncated toward a swap):
    /// either execute the staged hot-swap — fresh session, re-fitted
    /// scheme, remaining paper-jobs — or produce the job's final report,
    /// merging swap segments when any exist.
    fn finish_segment(
        &mut self,
        j: usize,
        assigned: usize,
        segment: RunReport,
        now: f64,
        obs: &mut dyn RoundObserver,
    ) -> crate::Result<()> {
        let done = self.slots[j].assigned_base + assigned;
        let remaining = self.slots[j].jobs_total.saturating_sub(done);
        // Preemption wins over a staged swap: bank the drained segment
        // and return the job to the queue; the balancer re-activates it
        // (with a fresh session over the remaining work) once capacity
        // recovers. A preempted job that happens to have nothing left
        // just finishes normally below.
        if self.slots[j].preempt && remaining > 0 {
            if let Some(ad) = self.adapt.as_mut() {
                // the fleet the swap was fitted against is gone
                let _ = ad.take_swap(j);
            }
            let slot = &mut self.slots[j];
            slot.preempt = false;
            slot.queued = true;
            slot.round_base = slot.round;
            slot.assigned_base = done;
            slot.segments.push(segment);
            slot.segment_assigned.push(assigned);
            slot.session = None;
            slot.place.clear();
            self.preemptions += 1;
            if let Some(so) = &self.obs {
                so.preempted.inc();
                so.obs.journal.record(
                    now,
                    EventKind::JobPreempt,
                    j as i64,
                    self.slots[j].round as i64,
                    -1,
                    assigned as f64,
                );
            }
            return Ok(());
        }
        self.slots[j].preempt = false;
        let swap = match self.adapt.as_mut() {
            Some(ad) if remaining > 0 => ad.take_swap(j),
            Some(ad) => {
                // completed naturally while a swap was pending: there is
                // nothing left to migrate — drop the stale decision
                let _ = ad.take_swap(j);
                None
            }
            None => None,
        };
        let slot = &mut self.slots[j];
        match swap {
            Some((to, gain)) => {
                debug_assert_eq!(to.n, slot.scheme.n, "re-fit candidates preserve n");
                self.swaps.push(SchemeSwapped {
                    job: j,
                    at_round: slot.round,
                    from: slot.scheme.label(),
                    to: to.label(),
                    predicted_gain: gain,
                    at_s: now,
                });
                if let Some(so) = &self.obs {
                    so.swaps.inc();
                    so.obs.journal.record(
                        now,
                        EventKind::SchemeSwap,
                        j as i64,
                        slot.round as i64,
                        -1,
                        gain,
                    );
                }
                slot.round_base = slot.round;
                slot.assigned_base = done;
                slot.segments.push(segment);
                slot.segment_assigned.push(assigned);
                slot.scheme = to;
                let mut cfg = slot.session_cfg.clone();
                cfg.jobs = remaining;
                let fresh = SgcSession::new(&slot.scheme, cfg);
                slot.session = Some(fresh);
                self.start_round(j, obs)
            }
            None if slot.segments.is_empty() => {
                // never swapped: the plain single-session path — the
                // report is byte-identical to a non-adaptive run
                slot.report = Some(segment);
                self.note_job_finished(j, now);
                Ok(())
            }
            None => {
                slot.segments.push(segment);
                slot.segment_assigned.push(assigned);
                slot.report = Some(merge_segments(&slot.segments, &slot.segment_assigned));
                self.note_job_finished(j, now);
                Ok(())
            }
        }
    }

    /// Stamp a job's finish instant (for the busy-span union), journal
    /// its completion, and refresh the queue-depth gauge.
    fn note_job_finished(&mut self, j: usize, now: f64) {
        self.slots[j].finish_s = Some(now);
        if let Some(so) = &self.obs {
            let depth = self.slots.iter().filter(|s| s.report.is_none()).count();
            so.obs.journal.record(now, EventKind::JobFinish, j as i64, -1, -1, 0.0);
            so.queue_depth.set(depth as f64);
            so.obs.journal.record(now, EventKind::QueueDepth, -1, -1, -1, depth as f64);
        }
    }

    /// Re-place logical workers of job `j` whose physical host left the
    /// live roster onto live spares not already used by the job (elastic
    /// membership). With no spare available the mapping is kept: the
    /// backend keeps reporting the ghost dead per submission and the
    /// μ-rule cuts it — exactly the pre-elastic behaviour.
    fn replace_dead_slots(&mut self, j: usize) {
        let slot = &mut self.slots[j];
        for logical in 0..slot.place.len() {
            let p = slot.place[logical];
            if self.live.get(p).copied().unwrap_or(false) {
                continue;
            }
            // With adaptation on, prefer the historically fastest spare
            // (profile-driven re-placement); otherwise — and for spares
            // the profile never observed — first-fit by id.
            let spare = match self.adapt.as_ref() {
                Some(ad) => ad.prefer_spare(&self.live, &slot.place),
                None => (0..self.live.len())
                    .find(|&c| self.live[c] && !slot.place.contains(&c)),
            };
            if let Some(s) = spare {
                slot.place[logical] = s;
                self.replacements += 1;
                if let Some(so) = &self.obs {
                    so.replacements.inc();
                    so.obs.journal.record(
                        self.cluster.now_s(),
                        EventKind::Replacement,
                        j as i64,
                        -1,
                        s as i64,
                        p as f64,
                    );
                }
            }
        }
    }

    /// Begin job `j`'s next round and fan its tasks out on the cluster.
    fn start_round(&mut self, j: usize, obs: &mut dyn RoundObserver) -> crate::Result<()> {
        let cap = self.cluster.n();
        // an elastic backend may have grown its slot space; workers the
        // scheduler was never told joined stay non-live
        if self.live.len() < cap {
            self.live.resize(cap, false);
        }
        self.replace_dead_slots(j);
        {
            let slot = &mut self.slots[j];
            let session = slot.session.as_mut().expect("job still running");
            session.begin_round_into(&mut slot.plan);
            obs.round_started(j, session, &slot.plan)?;
            slot.round = slot.round_base + slot.plan.round as u64;
            slot.open = true;
            // fresh round, fresh death flags (see `route_events`): the
            // backend's `submit` re-reports workers unusable *for this
            // round* before any of its events can matter
            slot.dead.clear();
            slot.dead.resize(cap, false);
            // placement: logical worker i → physical place[i]; workers
            // outside the placement (spares, retired slots) are marked
            // UNPLACED so backends skip them entirely — a scheme's
            // genuine zero-load no-op assignments stay 0.0
            self.loads.clear();
            self.loads.resize(cap, UNPLACED);
            for (logical, &load) in slot.plan.loads.iter().enumerate() {
                self.loads[slot.place[logical]] = load;
            }
            // inverse map for event routing (physical → logical)
            slot.inv.clear();
            slot.inv.resize(cap, usize::MAX);
            for (logical, &p) in slot.place.iter().enumerate() {
                slot.inv[p] = logical;
            }
            // Stage the gradient-data-plane round BEFORE the cluster
            // fan-out: a fleet backend resolves its GradAssign frames
            // from this entry inside `submit`. No-op for jobs the plane
            // was never configured for.
            if let Some(dp) = &self.dp {
                dp.lock().expect("data plane lock poisoned").stage_round(
                    j as u32,
                    slot.round,
                    session.scheme(),
                    &slot.plan,
                    &slot.place,
                    cap,
                );
            }
            if let Some(ad) = self.adapt.as_mut() {
                ad.register_round(j, slot.round, &slot.place, &slot.plan.loads);
            }
        }
        let job_round = self.slots[j].round;
        self.cluster.submit(j, job_round, &self.loads);
        // Stamp the round origin AFTER the fan-out: a wall-clock backend
        // stamps its own origin at the start of `submit`, so reading the
        // clock here can only *understate* the elapsed round time — the
        // μ-cutoff never fires early by the Assign-write duration.
        // Simulated clocks do not move inside `submit`, so this is exact.
        self.slots[j].submit_s = self.cluster.now_s();
        if let Some(so) = &self.obs {
            // round span start, stamped with the same origin the μ-rule
            // measures arrivals against
            so.obs.journal.record(
                self.slots[j].submit_s,
                EventKind::RoundAssign,
                j as i64,
                job_round as i64,
                -1,
                0.0,
            );
        }
        // Ground truth (simulators know it): un-permute into logical ids
        // so the report's true pattern is placement-agnostic.
        if let Some(state) = self.cluster.true_state(j, job_round) {
            self.state.clear();
            self.state.resize(self.slots[j].place.len(), false);
            for (logical, &p) in self.slots[j].place.iter().enumerate() {
                self.state[logical] = state.get(p).copied().unwrap_or(false);
            }
            self.slots[j]
                .session
                .as_mut()
                .expect("job still running")
                .record_true_state(&self.state);
        }
        Ok(())
    }
}

/// Drive one session over an event backend: a single-job
/// [`JobScheduler`] run. This is the event-native sibling of
/// [`crate::session::drive`] — identical reports on identically-seeded
/// backends (`tests/properties.rs` pins byte equality).
pub fn drive_events(
    scheme_cfg: &SchemeConfig,
    cfg: &SessionConfig,
    cluster: &mut dyn EventCluster,
) -> crate::Result<RunReport> {
    let mut sched = JobScheduler::new(cluster);
    sched.admit(&JobSpec { scheme: scheme_cfg.clone(), session: cfg.clone() })?;
    let mut out = sched.run()?;
    Ok(out.reports.remove(0))
}

/// Total length of the union of half-open intervals `[start, end)`,
/// sorted and merged in place. Non-positive intervals contribute
/// nothing. The [`FleetUtilization::busy_span_s`] primitive: overlap is
/// counted once, gaps between admission waves not at all.
pub(crate) fn union_span(windows: &mut Vec<(f64, f64)>) -> f64 {
    windows.retain(|w| w.1 > w.0);
    windows.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
    let mut total = 0.0;
    let mut cur: Option<(f64, f64)> = None;
    for &(s, e) in windows.iter() {
        match cur {
            Some((cs, ce)) if s <= ce => cur = Some((cs, ce.max(e))),
            Some((cs, ce)) => {
                total += ce - cs;
                cur = Some((s, e));
            }
            None => cur = Some((s, e)),
        }
    }
    if let Some((cs, ce)) = cur {
        total += ce - cs;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{LatencyParams, SimCluster};
    use crate::straggler::models::NoStragglers;
    use crate::straggler::GilbertElliot;

    fn quiet(n: usize, seed: u64) -> SimCluster {
        SimCluster::new(n, LatencyParams::default(), Box::new(NoStragglers { n }), seed)
    }

    fn spec(n: usize, s: usize, jobs: usize) -> JobSpec {
        JobSpec {
            scheme: SchemeConfig::gc(n, s),
            session: SessionConfig { jobs, ..Default::default() },
        }
    }

    #[test]
    fn two_jobs_share_one_quiet_cluster() {
        let n = 8;
        let mut sim = quiet(n, 3);
        let mut sched = JobScheduler::new(&mut sim);
        sched.admit(&spec(n, 1, 6)).unwrap();
        sched.admit(&spec(n, 2, 4)).unwrap();
        let out = sched.run().unwrap();
        assert_eq!(out.reports.len(), 2);
        assert_eq!(out.reports[0].rounds.len(), 6);
        assert_eq!(out.reports[1].rounds.len(), 4);
        for rep in &out.reports {
            assert_eq!(rep.deadline_violations, 0);
            assert!(rep.job_completion_s.iter().all(|t| t.is_finite()));
        }
        let u = &out.utilization;
        assert_eq!((u.jobs, u.workers), (2, n));
        assert_eq!(u.rounds, 10);
        assert_eq!(u.worker_done_events, 10 * n as u64);
        assert!(u.makespan_s > 0.0);
        assert!(u.total_session_s > 0.0);
        assert!(!format!("{u}").is_empty());
    }

    #[test]
    fn straggling_cluster_still_completes_every_job() {
        let n = 12;
        let mut sim =
            SimCluster::from_gilbert_elliot(n, GilbertElliot::new(n, 0.06, 0.6, 7), 19);
        let mut sched =
            JobScheduler::with_policy(&mut sim, Box::new(DisjointPlacement));
        for _ in 0..3 {
            sched.admit(&spec(n, 2, 8)).unwrap();
        }
        let out = sched.run().unwrap();
        assert_eq!(out.reports.len(), 3);
        for rep in &out.reports {
            assert_eq!(rep.deadline_violations, 0, "{}", rep.scheme);
            assert_eq!(rep.rounds.len(), 8);
            assert!(rep.job_completion_s.iter().all(|t| t.is_finite()));
        }
        assert_eq!(out.utilization.placement, "disjoint");
    }

    #[test]
    fn placement_policies_are_deterministic_and_spread_jobs() {
        let n = 16;
        let rr = RoundRobinPlacement;
        let dj = DisjointPlacement;
        for j in 0..4 {
            assert_eq!(rr.offset(j, n, 4), j);
            assert_eq!(dj.offset(j, n, 4), j * 4);
        }
        // single job always anchors at worker 0 (equivalence with the
        // single-session drivers depends on this)
        assert_eq!(rr.offset(0, n, 1), 0);
        assert_eq!(dj.offset(0, n, 1), 0);
        // more jobs than workers still places validly
        assert!(dj.offset(5, 4, 8) < 4);
    }

    /// Scripted backend: worker `dead_worker` never serves — every
    /// submission stages a `WorkerDead` for it (plus a bogus stale-round
    /// `WorkerDone` that a correct scheduler must ignore); everyone else
    /// finishes ~1s after submission.
    struct DeadWorkerCluster {
        n: usize,
        dead_worker: usize,
        clock: f64,
        staged: Vec<ClusterEvent>,
        buf: Vec<ClusterEvent>,
    }

    impl DeadWorkerCluster {
        fn new(n: usize, dead_worker: usize) -> Self {
            DeadWorkerCluster { n, dead_worker, clock: 0.0, staged: Vec::new(), buf: Vec::new() }
        }
    }

    impl EventCluster for DeadWorkerCluster {
        fn n(&self) -> usize {
            self.n
        }

        fn now_s(&self) -> f64 {
            self.clock
        }

        fn submit(&mut self, job: JobId, round: u64, loads: &[f64]) {
            assert_eq!(loads.len(), self.n);
            for worker in 0..self.n {
                if worker == self.dead_worker {
                    self.staged.push(ClusterEvent::WorkerDead { job, round, worker });
                    // resurrection bait: a stale result for a round this
                    // job is not running — must not clear the death flag
                    self.staged.push(ClusterEvent::WorkerDone {
                        job,
                        round: round + 1000,
                        worker,
                        finish_s: 0.5,
                    });
                } else {
                    self.staged.push(ClusterEvent::WorkerDone {
                        job,
                        round,
                        worker,
                        finish_s: 1.0 + worker as f64 * 0.01,
                    });
                }
            }
        }

        fn poll(&mut self, until_s: f64) -> &[ClusterEvent] {
            self.buf.clear();
            if self.staged.is_empty() {
                if until_s.is_finite() && until_s > self.clock {
                    self.clock = until_s;
                }
            } else {
                self.clock += 0.5;
                std::mem::swap(&mut self.buf, &mut self.staged);
            }
            &self.buf
        }

        fn true_state(&self, _job: JobId, _round: u64) -> Option<&[bool]> {
            None
        }
    }

    #[test]
    fn dead_worker_is_cut_by_the_mu_rule_and_the_run_completes() {
        // GC(s=1) tolerates the permanently-dead worker every round: the
        // μ-rule cuts it at the (1+μ)·κ cutoff and every job decodes.
        let mut cluster = DeadWorkerCluster::new(3, 2);
        let rep = drive_events(
            &SchemeConfig::gc(3, 1),
            &SessionConfig { jobs: 5, ..Default::default() },
            &mut cluster,
        )
        .unwrap();
        assert_eq!(rep.rounds.len(), 5);
        assert_eq!(rep.deadline_violations, 0);
        assert!(rep.job_completion_s.iter().all(|t| t.is_finite()));
        assert!(rep.rounds.iter().all(|r| r.detected_stragglers == 1));
    }

    #[test]
    fn waitall_on_a_dead_worker_degrades_instead_of_failing() {
        // The uncoded scheme must wait for everyone and worker 2 can
        // never report. Pre-failure-domain schedulers errored out of the
        // whole run here; now the job is retried, escalated to degraded
        // (never-wait) decode, and the run completes with an explicit
        // error bound — the stale-round resurrection bait still must not
        // mask the death.
        let mut cluster = DeadWorkerCluster::new(3, 2);
        let mut sched = JobScheduler::new(&mut cluster);
        sched
            .admit(&JobSpec {
                scheme: SchemeConfig::uncoded(3),
                session: SessionConfig { jobs: 2, ..Default::default() },
            })
            .unwrap();
        let out = sched.run().unwrap();
        let o = &out.outcomes[0];
        assert_eq!(o.status, JobStatus::Degraded);
        assert_eq!(o.retries, 2, "one same-policy retry, then degraded");
        assert_eq!(o.failed_jobs, 2, "nothing the dead worker held can decode");
        assert!((o.error_bound - 1.0).abs() < 1e-12);
        assert!(o.degraded_rounds > 0, "degraded rounds are accounted");
        assert_eq!(out.utilization.job_retries, 2);
        assert_eq!(out.utilization.jobs_degraded, 1);
        assert_eq!(out.utilization.jobs_quarantined, 0);
        assert!(!out.all_failed(), "a degraded job is not a failed job");
        // the degraded report carries NaN (undecoded) entries, not lies
        assert!(out.reports[0].job_completion_s.iter().all(|t| !t.is_finite()));
    }

    /// Scripted backend that dooms exactly one job: every submission for
    /// `victim` stages `WorkerDead` for all its placed workers (so no
    /// μ-cutoff can ever be established), while other jobs' submissions
    /// complete ~1s later. Pins the failure-domain boundary.
    struct OneJobDoomed {
        n: usize,
        victim: JobId,
        clock: f64,
        staged: Vec<ClusterEvent>,
        buf: Vec<ClusterEvent>,
    }

    impl OneJobDoomed {
        fn new(n: usize, victim: JobId) -> Self {
            OneJobDoomed { n, victim, clock: 0.0, staged: Vec::new(), buf: Vec::new() }
        }
    }

    impl EventCluster for OneJobDoomed {
        fn n(&self) -> usize {
            self.n
        }

        fn now_s(&self) -> f64 {
            self.clock
        }

        fn submit(&mut self, job: JobId, round: u64, loads: &[f64]) {
            for (worker, &load) in loads.iter().enumerate() {
                if load < 0.0 {
                    continue; // unplaced spare
                }
                if job == self.victim {
                    self.staged.push(ClusterEvent::WorkerDead { job, round, worker });
                } else {
                    self.staged.push(ClusterEvent::WorkerDone {
                        job,
                        round,
                        worker,
                        finish_s: 1.0 + worker as f64 * 0.01,
                    });
                }
            }
        }

        fn poll(&mut self, until_s: f64) -> &[ClusterEvent] {
            self.buf.clear();
            if self.staged.is_empty() {
                if until_s.is_finite() && until_s > self.clock {
                    self.clock = until_s;
                }
            } else {
                self.clock += 0.5;
                std::mem::swap(&mut self.buf, &mut self.staged);
            }
            &self.buf
        }

        fn true_state(&self, _job: JobId, _round: u64) -> Option<&[bool]> {
            None
        }
    }

    #[test]
    fn hopeless_job_is_quarantined_while_the_other_completes() {
        let mut cluster = OneJobDoomed::new(4, 1);
        let out = {
            let mut sched = JobScheduler::new(&mut cluster);
            sched.admit(&spec(4, 1, 3)).unwrap();
            sched.admit(&spec(4, 1, 3)).unwrap();
            sched.run().unwrap()
        };
        // the healthy job is untouched by its neighbour's failure domain
        let healthy = &out.reports[0];
        assert_eq!(healthy.rounds.len(), 3);
        assert_eq!(healthy.deadline_violations, 0);
        assert!(healthy.job_completion_s.iter().all(|t| t.is_finite()));
        assert_eq!(out.outcomes[0].status, JobStatus::Completed);
        assert_eq!(out.outcomes[0].retries, 0);
        // the doomed job burned its retry budget and was quarantined
        let o = &out.outcomes[1];
        assert_eq!(o.status, JobStatus::Quarantined);
        assert_eq!(o.retries, FailurePolicy::default().max_retries);
        assert_eq!(o.completed_jobs, 0);
        assert_eq!(o.failed_jobs, 3);
        assert!((o.error_bound - 1.0).abs() < 1e-12);
        assert_eq!(out.utilization.jobs_quarantined, 1);
        assert_eq!(out.quarantined(), 1);
        assert!(!out.all_failed(), "one healthy job keeps the fleet green");
    }

    /// Scripted backend whose first submission times out (no completions
    /// ever arrive for it); every later submission is healthy. Pins the
    /// `RoundTimeout → retry → complete` path.
    struct FirstRoundTimesOut {
        n: usize,
        submissions: usize,
        clock: f64,
        staged: Vec<ClusterEvent>,
        buf: Vec<ClusterEvent>,
    }

    impl FirstRoundTimesOut {
        fn new(n: usize) -> Self {
            FirstRoundTimesOut { n, submissions: 0, clock: 0.0, staged: Vec::new(), buf: Vec::new() }
        }
    }

    impl EventCluster for FirstRoundTimesOut {
        fn n(&self) -> usize {
            self.n
        }

        fn now_s(&self) -> f64 {
            self.clock
        }

        fn submit(&mut self, job: JobId, round: u64, loads: &[f64]) {
            assert_eq!(loads.len(), self.n);
            self.submissions += 1;
            if self.submissions == 1 {
                self.staged.push(ClusterEvent::RoundTimeout { job, round });
            } else {
                for worker in 0..self.n {
                    self.staged.push(ClusterEvent::WorkerDone {
                        job,
                        round,
                        worker,
                        finish_s: 1.0 + worker as f64 * 0.01,
                    });
                }
            }
        }

        fn poll(&mut self, until_s: f64) -> &[ClusterEvent] {
            self.buf.clear();
            if self.staged.is_empty() {
                if until_s.is_finite() && until_s > self.clock {
                    self.clock = until_s;
                }
            } else {
                self.clock += 0.5;
                std::mem::swap(&mut self.buf, &mut self.staged);
            }
            &self.buf
        }

        fn true_state(&self, _job: JobId, _round: u64) -> Option<&[bool]> {
            None
        }
    }

    #[test]
    fn round_timeout_retries_the_job_and_it_completes_exactly() {
        let mut cluster = FirstRoundTimesOut::new(4);
        let out = {
            let mut sched = JobScheduler::new(&mut cluster);
            sched.admit(&spec(4, 1, 3)).unwrap();
            sched.run().unwrap()
        };
        let o = &out.outcomes[0];
        assert_eq!(o.status, JobStatus::Completed, "retry recovered everything");
        assert_eq!(o.retries, 1);
        assert_eq!(o.failed_jobs, 0);
        assert_eq!(o.error_bound, 0.0);
        assert_eq!(out.utilization.job_retries, 1);
        assert_eq!(out.utilization.jobs_degraded, 0);
        let rep = &out.reports[0];
        assert_eq!(rep.job_completion_s.len(), 3);
        assert!(rep.job_completion_s.iter().all(|t| t.is_finite()));
    }

    #[test]
    fn stale_events_for_a_quarantined_job_are_ignored() {
        // After job 1 is quarantined its aborted submissions may still
        // owe RoundTimeout / WorkerDead / WorkerDone events; delivering
        // them must neither crash nor perturb the surviving jobs
        // (regression for the fail-fast bail this module used to have).
        struct LateGhostEvents {
            inner: OneJobDoomed,
            ghost_spam: bool,
        }
        impl EventCluster for LateGhostEvents {
            fn n(&self) -> usize {
                self.inner.n()
            }
            fn now_s(&self) -> f64 {
                self.inner.now_s()
            }
            fn submit(&mut self, job: JobId, round: u64, loads: &[f64]) {
                self.inner.submit(job, round, loads);
                if self.ghost_spam {
                    // stale events keyed to the victim's long-aborted
                    // first attempt, re-delivered on every submission
                    self.inner.staged.push(ClusterEvent::RoundTimeout { job: 1, round: 1 });
                    self.inner.staged.push(ClusterEvent::WorkerDead {
                        job: 1,
                        round: 1,
                        worker: 0,
                    });
                    self.inner.staged.push(ClusterEvent::WorkerDone {
                        job: 1,
                        round: 1,
                        worker: 1,
                        finish_s: 0.1,
                    });
                }
                self.ghost_spam = true;
            }
            fn poll(&mut self, until_s: f64) -> &[ClusterEvent] {
                self.inner.poll(until_s)
            }
            fn true_state(&self, job: JobId, round: u64) -> Option<&[bool]> {
                self.inner.true_state(job, round)
            }
        }
        let mut plain = OneJobDoomed::new(4, 1);
        let baseline = {
            let mut sched = JobScheduler::new(&mut plain);
            sched.admit(&spec(4, 1, 3)).unwrap();
            sched.admit(&spec(4, 1, 3)).unwrap();
            sched.run().unwrap()
        };
        let mut noisy = LateGhostEvents { inner: OneJobDoomed::new(4, 1), ghost_spam: false };
        let spammed = {
            let mut sched = JobScheduler::new(&mut noisy);
            sched.admit(&spec(4, 1, 3)).unwrap();
            sched.admit(&spec(4, 1, 3)).unwrap();
            sched.run().unwrap()
        };
        // the healthy job's report is byte-identical despite the spam
        assert_eq!(
            format!("{:?}", baseline.reports[0]),
            format!("{:?}", spammed.reports[0])
        );
        assert_eq!(spammed.outcomes[1].status, JobStatus::Quarantined);
    }

    #[test]
    fn backoff_is_deterministic_capped_and_jittered() {
        let mut sim = quiet(4, 1);
        let sched = JobScheduler::new(&mut sim);
        let base = sched.failure.backoff_base_s;
        let cap = sched.failure.backoff_cap_s;
        for job in 0..3 {
            for retry in 1..=8u32 {
                let a = sched.backoff_s(job, retry);
                let b = sched.backoff_s(job, retry);
                assert_eq!(a, b, "jitter must be deterministic");
                let raw = (base * f64::from(1u32 << (retry - 1))).min(cap);
                assert!(a >= raw * 0.5 && a < raw, "jitter stays in [raw/2, raw)");
            }
        }
        // distinct (job, retry) keys draw distinct jitter
        assert_ne!(sched.backoff_s(0, 1), sched.backoff_s(1, 1));
    }

    #[test]
    fn admit_rejects_a_size_mismatch() {
        let mut sim = quiet(4, 1);
        let mut sched = JobScheduler::new(&mut sim);
        let err = sched.admit(&spec(8, 1, 2)).unwrap_err();
        assert!(err.to_string().contains("expects n = 8"), "{err}");
    }

    /// Scripted elastic backend: capacity 4, a 3-worker job. Worker 2
    /// retires together with round 1's completions; worker 3 is a live
    /// spare. Fully deterministic — no clocks, no RNG.
    struct ElasticScripted {
        clock: f64,
        submissions: usize,
        live: Vec<bool>,
        staged: Vec<ClusterEvent>,
        buf: Vec<ClusterEvent>,
        loads_seen: Vec<Vec<f64>>,
    }

    impl ElasticScripted {
        fn new() -> Self {
            ElasticScripted {
                clock: 0.0,
                submissions: 0,
                live: vec![true; 4],
                staged: Vec::new(),
                buf: Vec::new(),
                loads_seen: Vec::new(),
            }
        }
    }

    impl EventCluster for ElasticScripted {
        fn n(&self) -> usize {
            4
        }

        fn now_s(&self) -> f64 {
            self.clock
        }

        fn submit(&mut self, job: JobId, round: u64, loads: &[f64]) {
            assert_eq!(loads.len(), 4);
            self.submissions += 1;
            self.loads_seen.push(loads.to_vec());
            for (worker, &load) in loads.iter().enumerate() {
                if load <= 0.0 {
                    continue; // spare or retired slot: not part of the job
                }
                if self.live[worker] {
                    self.staged.push(ClusterEvent::WorkerDone {
                        job,
                        round,
                        worker,
                        finish_s: 1.0 + worker as f64 * 0.01,
                    });
                } else {
                    self.staged.push(ClusterEvent::WorkerDead { job, round, worker });
                }
            }
            if self.submissions == 1 {
                // worker 2 dies alongside round 1's completions
                self.live[2] = false;
                self.staged.push(ClusterEvent::WorkerRetired { worker: 2 });
            }
        }

        fn poll(&mut self, until_s: f64) -> &[ClusterEvent] {
            self.buf.clear();
            if self.staged.is_empty() {
                if until_s.is_finite() && until_s > self.clock {
                    self.clock = until_s;
                }
            } else {
                self.clock += 0.5;
                std::mem::swap(&mut self.buf, &mut self.staged);
            }
            &self.buf
        }

        fn true_state(&self, _job: JobId, _round: u64) -> Option<&[bool]> {
            None
        }
    }

    #[test]
    fn retired_worker_is_replaced_by_a_live_spare() {
        let mut cluster = ElasticScripted::new();
        let out = {
            let mut sched = JobScheduler::new(&mut cluster);
            sched.admit(&spec(3, 1, 3)).unwrap();
            sched.run().unwrap()
        };
        let rep = &out.reports[0];
        assert_eq!(rep.rounds.len(), 3);
        assert_eq!(rep.deadline_violations, 0);
        assert!(rep.job_completion_s.iter().all(|t| t.is_finite()));
        // round 1 ran on workers 0..2 (worker 3 an unplaced spare)
        assert!(cluster.loads_seen[0][2] > 0.0);
        assert_eq!(cluster.loads_seen[0][3], UNPLACED);
        // rounds 2+ migrated the retired worker 2's slot onto spare 3
        for round_loads in &cluster.loads_seen[1..] {
            assert_eq!(round_loads[2], UNPLACED, "retired worker still loaded");
            assert!(round_loads[3] > 0.0, "spare not used");
        }
        assert_eq!(out.utilization.worker_retired_events, 1);
        assert_eq!(out.utilization.replacements, 1);
        // no straggler cut was ever needed: the dead worker never hosted
        // a task after its retirement was observed
        assert!(rep.rounds.iter().all(|r| r.detected_stragglers == 0));
    }

    #[test]
    fn observer_sees_every_round_boundary() {
        struct Counter {
            started: usize,
            closed: usize,
            decoded: usize,
        }
        impl RoundObserver for Counter {
            fn round_started(
                &mut self,
                _job: JobId,
                _session: &SgcSession,
                plan: &RoundPlan,
            ) -> crate::Result<()> {
                assert!(plan.round > 0);
                self.started += 1;
                Ok(())
            }
            fn round_closed(
                &mut self,
                _job: JobId,
                _session: &SgcSession,
                _plan: &RoundPlan,
                events: &[SessionEvent],
            ) -> crate::Result<()> {
                assert!(matches!(events.first(), Some(SessionEvent::RoundClosed { .. })));
                self.closed += 1;
                self.decoded += events
                    .iter()
                    .filter(|e| matches!(e, SessionEvent::JobDecoded { .. }))
                    .count();
                Ok(())
            }
        }
        let n = 6;
        let mut sim = quiet(n, 9);
        let mut sched = JobScheduler::new(&mut sim);
        sched.admit(&spec(n, 1, 5)).unwrap();
        sched.admit(&spec(n, 1, 5)).unwrap();
        let mut counter = Counter { started: 0, closed: 0, decoded: 0 };
        let out = sched.run_observed(&mut counter).unwrap();
        assert_eq!(counter.started, 10);
        assert_eq!(counter.closed, 10);
        assert_eq!(counter.decoded, 10, "every job of every session decodes");
        assert_eq!(out.utilization.rounds, 10);
    }

    #[test]
    fn union_span_merges_overlaps_and_skips_gaps() {
        // disjoint: lengths add
        let mut w = vec![(0.0, 1.0), (2.0, 3.5)];
        assert!((union_span(&mut w) - 2.5).abs() < 1e-12);
        // overlapping: counted once
        let mut w = vec![(0.0, 2.0), (1.0, 3.0)];
        assert!((union_span(&mut w) - 3.0).abs() < 1e-12);
        // contained: inner window adds nothing
        let mut w = vec![(0.0, 4.0), (1.0, 2.0)];
        assert!((union_span(&mut w) - 4.0).abs() < 1e-12);
        // touching endpoints merge (half-open adjacency)
        let mut w = vec![(1.0, 2.0), (0.0, 1.0)];
        assert!((union_span(&mut w) - 2.0).abs() < 1e-12);
        // empty / degenerate windows contribute nothing
        let mut w = vec![(1.0, 1.0), (3.0, 2.0)];
        assert_eq!(union_span(&mut w), 0.0);
        let mut w: Vec<(f64, f64)> = Vec::new();
        assert_eq!(union_span(&mut w), 0.0);
    }

    #[test]
    fn utilization_is_admission_time_aware() {
        // Two identical same-seed single-job runs, executed back-to-back
        // on one cluster clock: the second job is "admitted" long after
        // the first finished. A wall-clock gain (total_session_s over
        // the full makespan) would count the idle gap between them; the
        // busy-span union must not.
        let n = 6;
        let mut sim = quiet(n, 11);
        let r1 = {
            let mut sched = JobScheduler::new(&mut sim);
            sched.admit(&spec(n, 1, 4)).unwrap();
            sched.run().unwrap()
        };
        let u1 = &r1.utilization;
        // co-admitted path: busy span IS the makespan, gain unchanged
        assert!((u1.busy_span_s - u1.makespan_s).abs() < 1e-9);
        assert!(
            (u1.multiplexing_gain - u1.total_session_s / u1.makespan_s).abs() < 1e-9,
            "single-wave gain must equal the legacy formula"
        );
        assert_eq!((u1.preemptions, u1.jobs_rejected), (0, 0));
        // the JSON face carries the new fields
        let js = u1.to_json().to_string();
        assert!(js.contains("busy_span_s"), "{js}");
        assert!(js.contains("jobs_rejected"), "{js}");

        // Pin the corrected formula itself: windows with a gap between
        // admission waves yield gain = Σsession / union, not Σ/makespan.
        let mut windows = vec![(0.0, 10.0), (50.0, 60.0)];
        let busy = union_span(&mut windows);
        assert!((busy - 20.0).abs() < 1e-12);
        let total_session_s = 18.0;
        let wall_makespan = 60.0;
        let corrected = total_session_s / busy;
        let deflated = total_session_s / wall_makespan;
        assert!(corrected > deflated * 2.5, "gap no longer deflates the gain");
    }
}
