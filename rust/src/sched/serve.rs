//! Always-on serving loop: dynamic admission, priorities, preemption,
//! and backpressure over one long-lived [`JobScheduler`].
//!
//! [`JobScheduler::run`] freezes the job set up front — the paper's
//! batch shape. This module lifts that restriction into
//! [`JobScheduler::serve`]: an [`AdmissionSource`] feeds submissions
//! into the live event pump, each is admitted (queued) or load-shed
//! with a [`Rejected`](AdmissionVerdict::Rejected) verdict, queued jobs
//! activate highest-priority-first while the fleet has headroom, and
//! when membership shrinks below aggregate demand the lowest-priority
//! active jobs are *preempted* — drained after their already-assigned
//! paper-jobs (the [`SgcSession::finish_after_assigned`] machinery the
//! failure domains and adaptive hot-swap already rely on), banked as a
//! ledger segment, and returned to the queue for re-activation once
//! capacity recovers.
//!
//! Two sources ship:
//!
//! * [`ScriptedSource`] — deterministic in-process arrivals keyed on
//!   cluster time or closed-round counts (soak/property tests, chaos
//!   `adm@rR:K` bursts).
//! * [`QueueSource`] — drains a [`SharedControl`] queue the fleet
//!   master fills from `Submit` wire frames on its control socket, and
//!   pushes verdicts back for the reactor to answer with
//!   `Accepted`/`Rejected` frames.
//!
//! The loop stays event-driven: its wake horizon is the minimum of the
//! jobs' μ-cutoffs, parked retries, the source's next timed arrival,
//! and the optional serve deadline — a fleet backend still sleeps in
//! one `poll(2)` and is woken early by control-socket traffic, so an
//! idle serving loop burns no CPU.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use super::*;

/// One submission offered to the serving loop.
#[derive(Debug, Clone)]
pub struct SubmitRequest {
    /// Source-chosen correlation id, echoed in the verdict
    /// ([`AdmissionSource::notify`]); the fleet master keys reply
    /// connections on it.
    pub token: u64,
    /// Submitter-chosen display name (journals, reports).
    pub name: String,
    /// Admission priority: higher activates first; ties break toward
    /// the older submission.
    pub priority: u8,
    /// The parsed job, or the parse error. Carrying the `Err` through
    /// the loop (instead of dropping it source-side) keeps every
    /// rejection in the same counters and journal.
    pub spec: Result<JobSpec, String>,
}

/// The serving loop's answer to one [`SubmitRequest`].
#[derive(Debug, Clone, PartialEq)]
pub enum AdmissionVerdict {
    /// Admitted: the job's scheduler id and the admission-queue depth
    /// right after it joined.
    Accepted { job: JobId, queue_depth: usize },
    /// Load-shed (queue full, bad spec, oversized scheme, shutdown).
    Rejected { reason: String },
}

/// Where [`JobScheduler::serve`] gets its submissions.
pub trait AdmissionSource {
    /// Append every submission due at cluster clock `now_s` with
    /// `rounds_closed` total rounds committed. The loop passes
    /// `u64::MAX` when no further round can ever close, so
    /// rounds-keyed arrivals cannot deadlock an idle fleet.
    fn poll_requests(&mut self, now_s: f64, rounds_closed: u64, out: &mut Vec<SubmitRequest>);

    /// Earliest *time-keyed* arrival still pending (a wake horizon), if
    /// any. Rounds-keyed and externally-fed arrivals return `None`.
    fn next_arrival_s(&self, now_s: f64) -> Option<f64>;

    /// No further submission will ever arrive.
    fn exhausted(&self) -> bool;

    /// Deliver the verdict for the request submitted with `token`.
    fn notify(&mut self, token: u64, verdict: AdmissionVerdict);
}

/// When a scripted arrival fires.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalAt {
    /// At cluster clock `t` (seconds).
    Time(f64),
    /// Once `k` rounds have been committed across all jobs.
    RoundsClosed(u64),
}

/// Deterministic in-process [`AdmissionSource`] for tests and sim
/// drivers: arrivals fire on cluster time or closed-round counts, in
/// insertion order within a tick, and every verdict is logged for
/// assertion.
#[derive(Default)]
pub struct ScriptedSource {
    pending: VecDeque<(ArrivalAt, SubmitRequest)>,
    next_token: u64,
    /// Every verdict delivered, in delivery order.
    pub verdicts: Vec<(u64, AdmissionVerdict)>,
}

impl ScriptedSource {
    pub fn new() -> Self {
        Self::default()
    }

    /// Stage `spec` to arrive at `at`; returns the assigned token.
    pub fn submit_at(
        &mut self,
        at: ArrivalAt,
        name: &str,
        priority: u8,
        spec: JobSpec,
    ) -> u64 {
        let token = self.next_token;
        self.next_token += 1;
        self.pending.push_back((
            at,
            SubmitRequest { token, name: name.into(), priority, spec: Ok(spec) },
        ));
        token
    }

    /// Stage a deliberately malformed submission (exercises the
    /// rejection path end to end).
    pub fn submit_bad_at(&mut self, at: ArrivalAt, name: &str, error: &str) -> u64 {
        let token = self.next_token;
        self.next_token += 1;
        self.pending.push_back((
            at,
            SubmitRequest {
                token,
                name: name.into(),
                priority: 0,
                spec: Err(error.into()),
            },
        ));
        token
    }

    /// Stage one burst per `adm@rR:K` fault in `plan`: `K` copies of
    /// `mk(round, i)` arriving once `R` rounds have closed — the chaos
    /// harness's hook into the serving loop.
    pub fn stage_bursts<F>(&mut self, plan: &crate::chaos::ResolvedPlan, mut mk: F)
    where
        F: FnMut(u64, usize) -> (String, u8, JobSpec),
    {
        for (round, count) in plan.admission_faults() {
            for i in 0..count {
                let (name, priority, spec) = mk(round, i);
                self.submit_at(ArrivalAt::RoundsClosed(round), &name, priority, spec);
            }
        }
    }

    /// Verdicts that accepted, in delivery order.
    pub fn accepted(&self) -> usize {
        self.verdicts
            .iter()
            .filter(|(_, v)| matches!(v, AdmissionVerdict::Accepted { .. }))
            .count()
    }

    /// Verdicts that rejected, in delivery order.
    pub fn rejected(&self) -> usize {
        self.verdicts.len() - self.accepted()
    }
}

impl AdmissionSource for ScriptedSource {
    fn poll_requests(&mut self, now_s: f64, rounds_closed: u64, out: &mut Vec<SubmitRequest>) {
        let mut i = 0;
        while i < self.pending.len() {
            let due = match self.pending[i].0 {
                ArrivalAt::Time(t) => t <= now_s,
                ArrivalAt::RoundsClosed(r) => r <= rounds_closed,
            };
            if due {
                let (_, req) = self.pending.remove(i).expect("index in range");
                out.push(req);
            } else {
                i += 1;
            }
        }
    }

    fn next_arrival_s(&self, _now_s: f64) -> Option<f64> {
        self.pending
            .iter()
            .filter_map(|(at, _)| match at {
                ArrivalAt::Time(t) => Some(*t),
                ArrivalAt::RoundsClosed(_) => None,
            })
            .fold(None, |acc: Option<f64>, t| {
                Some(acc.map_or(t, |a| a.min(t)))
            })
    }

    fn exhausted(&self) -> bool {
        self.pending.is_empty()
    }

    fn notify(&mut self, token: u64, verdict: AdmissionVerdict) {
        self.verdicts.push((token, verdict));
    }
}

/// One raw submission as the control socket received it (unparsed: the
/// reactor thread never touches scheme code).
#[derive(Debug, Clone)]
pub struct RawSubmit {
    /// Reactor-assigned token identifying the submitting connection.
    pub token: u64,
    pub name: String,
    /// Scheme spec string, parsed by [`QueueSource`] against the
    /// cluster's worker count (e.g. `gc:2`, `srsgc:2,4,1`).
    pub scheme: String,
    /// Paper-jobs for the session; `0` means "template default".
    pub session_jobs: u32,
    pub priority: u8,
}

/// A verdict queued for the reactor to ship back on the submitting
/// connection.
#[derive(Debug, Clone, PartialEq)]
pub enum RawVerdict {
    Accepted { job: u32, queue_depth: u32 },
    Rejected { reason: String },
}

/// The master ↔ serving-loop handoff queue behind the control socket:
/// the reactor pushes [`RawSubmit`]s in, [`QueueSource`] drains them,
/// and verdicts flow back the other way.
#[derive(Default)]
pub struct ControlQueue {
    pub incoming: VecDeque<RawSubmit>,
    pub verdicts: VecDeque<(u64, RawVerdict)>,
    /// Set on shutdown: no further submission will arrive, letting the
    /// serving loop's exit condition fire.
    pub closed: bool,
}

/// Shared handle on a [`ControlQueue`].
pub type SharedControl = Arc<Mutex<ControlQueue>>;

impl ControlQueue {
    pub fn shared() -> SharedControl {
        Arc::new(Mutex::new(ControlQueue::default()))
    }
}

/// [`AdmissionSource`] over a [`SharedControl`] queue: parses each raw
/// submission against the cluster's worker count and a template
/// [`SessionConfig`], and routes verdicts back for the reactor to
/// answer on the wire.
pub struct QueueSource {
    control: SharedControl,
    /// Worker count schemes are parsed against.
    n: usize,
    /// Session defaults (μ, wait policy, …); `session_jobs` overrides
    /// the job count when non-zero.
    template: SessionConfig,
}

impl QueueSource {
    pub fn new(control: SharedControl, n: usize, template: SessionConfig) -> Self {
        QueueSource { control, n, template }
    }
}

impl AdmissionSource for QueueSource {
    fn poll_requests(&mut self, _now_s: f64, _rounds_closed: u64, out: &mut Vec<SubmitRequest>) {
        let mut q = self.control.lock().expect("control queue lock poisoned");
        while let Some(raw) = q.incoming.pop_front() {
            let spec = SchemeConfig::parse(self.n, &raw.scheme)
                .map(|scheme| {
                    let mut session = self.template.clone();
                    if raw.session_jobs > 0 {
                        session.jobs = raw.session_jobs as usize;
                    }
                    JobSpec { scheme, session }
                })
                .map_err(|e| e.to_string());
            out.push(SubmitRequest {
                token: raw.token,
                name: raw.name,
                priority: raw.priority,
                spec,
            });
        }
    }

    fn next_arrival_s(&self, _now_s: f64) -> Option<f64> {
        None
    }

    fn exhausted(&self) -> bool {
        let q = self.control.lock().expect("control queue lock poisoned");
        q.closed && q.incoming.is_empty()
    }

    fn notify(&mut self, token: u64, verdict: AdmissionVerdict) {
        let raw = match verdict {
            AdmissionVerdict::Accepted { job, queue_depth } => RawVerdict::Accepted {
                job: job as u32,
                queue_depth: queue_depth as u32,
            },
            AdmissionVerdict::Rejected { reason } => RawVerdict::Rejected { reason },
        };
        self.control
            .lock()
            .expect("control queue lock poisoned")
            .verdicts
            .push_back((token, raw));
    }
}

/// Admission-control knobs for [`JobScheduler::serve`].
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Accepted-but-not-activated jobs the loop will hold before
    /// load-shedding (`Rejected { "queue full …" }`).
    pub max_queue: usize,
    /// Jobs multiplexed concurrently at most.
    pub max_active: usize,
    /// Capacity budget as a multiple of the live worker count:
    /// aggregate active demand (Σ scheme `n`) above
    /// `oversub × live` triggers preemption; activation stops at it.
    pub oversub: f64,
    /// Stop accepting after this many seconds on the cluster clock;
    /// already-accepted jobs still run to completion. `None` serves
    /// until the source is exhausted.
    pub serve_for_s: Option<f64>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { max_queue: 64, max_active: 8, oversub: 4.0, serve_for_s: None }
    }
}

impl<'c> JobScheduler<'c> {
    /// Serve jobs from `source` until it is exhausted (or the
    /// [`ServeConfig::serve_for_s`] deadline passes) *and* every
    /// accepted job has finished. Jobs admitted via
    /// [`admit`](Self::admit) before the call join the queue like any
    /// dynamic submission (priority 0).
    ///
    /// The event pump is [`run_observed`](Self::run_observed)'s, with
    /// three extra phases per iteration: drain the source (accept or
    /// load-shed each request), rebalance (mark preemptions when live
    /// membership no longer covers aggregate demand; activate queued
    /// jobs highest-priority-first into the headroom), and an exit
    /// check. Identically-seeded backends and scripts produce
    /// byte-identical reports.
    pub fn serve(
        &mut self,
        source: &mut dyn AdmissionSource,
        cfg: &ServeConfig,
        obs: &mut dyn RoundObserver,
    ) -> crate::Result<ScheduleReport> {
        anyhow::ensure!(!self.ran, "JobScheduler::serve after run");
        self.ran = true;
        let n = self.cluster.n();
        self.live.resize(n, true);
        let start_s = self.cluster.now_s();
        let deadline = cfg.serve_for_s.map(|d| start_s + d);

        for slot in &mut self.slots {
            slot.queued = true;
        }
        if let Some(so) = &mut self.obs {
            so.job_latency.clear();
            for j in 0..self.slots.len() {
                let pri = f64::from(self.slots[j].priority);
                so.job_latency.push(so.obs.metrics.histogram(
                    "sgc_round_latency_seconds",
                    &format!("job=\"{j}\""),
                    "Per-job protocol round latency",
                ));
                so.obs.journal.record(start_s, EventKind::JobAdmit, j as i64, -1, -1, pri);
            }
        }
        if let (Some(ad), Some(so)) = (self.adapt.as_mut(), self.obs.as_ref()) {
            ad.set_obs(so.obs.clone());
        }

        let mut requests: Vec<SubmitRequest> = Vec::new();
        let mut stalls = 0u32;
        loop {
            let pre = self.cluster.now_s();

            // Admission. When nothing is active and no timed arrival is
            // coming, no further round can ever close — rounds-keyed
            // arrivals are released unconditionally so a later wave
            // cannot deadlock a quiet fleet.
            let idle = !self.slots.iter().any(|s| s.report.is_none() && !s.queued);
            let rounds_key = if idle && source.next_arrival_s(pre).is_none() {
                u64::MAX
            } else {
                self.rounds_closed as u64
            };
            requests.clear();
            source.poll_requests(pre, rounds_key, &mut requests);
            for req in requests.drain(..) {
                self.admit_request(req, cfg, deadline, pre, source);
            }

            self.rebalance(cfg, pre, obs)?;

            let all_done = self.slots.iter().all(|s| s.report.is_some());
            let source_done = source.exhausted() || deadline.is_some_and(|d| pre >= d);
            if all_done && source_done {
                break;
            }

            // Wake horizon: earliest μ-cutoff, parked retry, timed
            // arrival, or the serve deadline — whichever comes first.
            let mut wake = f64::INFINITY;
            for slot in &self.slots {
                if let Some(t) = slot.retry_at_s {
                    if t > pre && t < wake {
                        wake = t;
                    }
                    continue;
                }
                if !slot.open {
                    continue;
                }
                if let Some(h) = slot.session.as_ref().expect("open slot").deadline_hint() {
                    let t = slot.submit_s + h;
                    if t > pre && t < wake {
                        wake = t;
                    }
                }
            }
            if let Some(t) = source.next_arrival_s(pre) {
                if t > pre && t < wake {
                    wake = t;
                }
            }
            if let Some(d) = deadline {
                if d > pre && d < wake {
                    wake = d;
                }
            }

            // Pump: poll, co-timed drain, absorb, advance — identical
            // to the batch loop (order pins determinism).
            let batch = self.cluster.poll(wake);
            self.events.clear();
            self.events.extend_from_slice(batch);
            let now = self.cluster.now_s();
            loop {
                let more = self.cluster.poll(now);
                if more.is_empty() {
                    break;
                }
                self.events.extend_from_slice(more);
            }
            self.absorb_events()?;
            let closed_before = self.rounds_closed;
            for j in 0..self.slots.len() {
                self.try_advance(j, now, obs)?;
            }

            let progressed = !self.events.is_empty()
                || self.rounds_closed > closed_before
                || self.cluster.now_s() > pre;
            stalls = if progressed { 0 } else { stalls + 1 };
            anyhow::ensure!(
                stalls < 1000,
                "serving loop made no progress with {} jobs unfinished (deadlocked backend?)",
                self.slots.iter().filter(|s| s.report.is_none()).count()
            );
        }

        // One zero-horizon turn so a fleet backend can flush the last
        // admission verdicts before the clock freezes into the report.
        let now = self.cluster.now_s();
        let _ = self.cluster.poll(now);
        Ok(self.build_report(start_s, n))
    }

    /// Accept (queue) or load-shed one submission, feed the counters
    /// and journal, and deliver the verdict.
    fn admit_request(
        &mut self,
        req: SubmitRequest,
        cfg: &ServeConfig,
        deadline: Option<f64>,
        now: f64,
        source: &mut dyn AdmissionSource,
    ) {
        self.submitted_total += 1;
        if let Some(so) = &self.obs {
            so.submitted.inc();
            so.obs.journal.record(
                now,
                EventKind::JobSubmit,
                -1,
                -1,
                -1,
                f64::from(req.priority),
            );
        }
        let queued = self.slots.iter().filter(|s| s.queued).count();
        let outcome: Result<JobId, String> = if deadline.is_some_and(|d| now >= d) {
            Err("shutting down".into())
        } else if queued >= cfg.max_queue {
            Err(format!("queue full (max {})", cfg.max_queue))
        } else {
            match &req.spec {
                Err(e) => Err(format!("bad spec: {e}")),
                Ok(spec) => self.admit_slot(spec).map_err(|e| e.to_string()),
            }
        };
        match outcome {
            Ok(job) => {
                let slot = &mut self.slots[job];
                slot.priority = req.priority;
                slot.name = req.name;
                slot.queued = true;
                let depth = queued + 1;
                if let Some(so) = &mut self.obs {
                    so.job_latency.push(so.obs.metrics.histogram(
                        "sgc_round_latency_seconds",
                        &format!("job=\"{job}\""),
                        "Per-job protocol round latency",
                    ));
                    so.obs.journal.record(
                        now,
                        EventKind::JobAdmit,
                        job as i64,
                        -1,
                        -1,
                        f64::from(req.priority),
                    );
                    so.adm_queue.set(depth as f64);
                    let unfinished =
                        self.slots.iter().filter(|s| s.report.is_none()).count();
                    so.queue_depth.set(unfinished as f64);
                }
                source.notify(req.token, AdmissionVerdict::Accepted { job, queue_depth: depth });
            }
            Err(reason) => {
                self.rejected_total += 1;
                if let Some(so) = &self.obs {
                    so.rejected.inc();
                    so.obs.journal.record(
                        now,
                        EventKind::JobReject,
                        -1,
                        -1,
                        -1,
                        f64::from(req.priority),
                    );
                }
                source.notify(req.token, AdmissionVerdict::Rejected { reason });
            }
        }
    }

    /// One balance pass: shed load low-priority-first when the live
    /// roster no longer covers aggregate demand, then activate queued
    /// jobs highest-priority-first into the remaining headroom.
    fn rebalance(
        &mut self,
        cfg: &ServeConfig,
        now: f64,
        obs: &mut dyn RoundObserver,
    ) -> crate::Result<()> {
        let live_workers = self.live.iter().filter(|&&l| l).count().max(1);
        let budget = cfg.oversub * live_workers as f64;
        let active: Vec<usize> = (0..self.slots.len())
            .filter(|&j| {
                let s = &self.slots[j];
                !s.queued && s.report.is_none()
            })
            .collect();
        let mut demand: f64 = active.iter().map(|&j| self.slots[j].scheme.n as f64).sum();

        // Preemption marks: lowest priority first, youngest id first on
        // ties, always keeping at least one job unmarked. The marked
        // session is truncated at each round close and banks + re-queues
        // in finish_segment.
        if demand > budget && active.len() > 1 {
            let mut victims = active.clone();
            victims.sort_by(|&a, &b| {
                self.slots[a]
                    .priority
                    .cmp(&self.slots[b].priority)
                    .then(b.cmp(&a))
            });
            let mut unmarked = active.iter().filter(|&&j| !self.slots[j].preempt).count();
            for &j in &victims {
                if demand <= budget || unmarked <= 1 {
                    break;
                }
                let s = &mut self.slots[j];
                // parked slots hold no session to drain; their retry
                // path already re-fits them to the shrunken roster
                if s.preempt || s.session.is_none() {
                    continue;
                }
                s.preempt = true;
                unmarked -= 1;
                demand -= s.scheme.n as f64;
            }
        }

        // Activation: an idle fleet always takes one job; beyond that,
        // only while aggregate demand stays within the budget.
        loop {
            let active_count = self
                .slots
                .iter()
                .filter(|s| !s.queued && s.report.is_none())
                .count();
            if active_count >= cfg.max_active {
                break;
            }
            let mut best: Option<usize> = None;
            for j in 0..self.slots.len() {
                if !self.slots[j].queued {
                    continue;
                }
                let better = match best {
                    None => true,
                    Some(b) => {
                        let (s, sb) = (&self.slots[j], &self.slots[b]);
                        (s.priority, std::cmp::Reverse(j)) > (sb.priority, std::cmp::Reverse(b))
                    }
                };
                if better {
                    best = Some(j);
                }
            }
            let Some(j) = best else { break };
            let need = self.slots[j].scheme.n as f64;
            if active_count > 0 && demand + need > budget {
                break;
            }
            self.activate(j, now, obs)?;
            demand += need;
        }
        if let Some(so) = &self.obs {
            let queued = self.slots.iter().filter(|s| s.queued).count();
            so.adm_queue.set(queued as f64);
        }
        Ok(())
    }

    /// Take job `j` off the queue and open its first round: fresh
    /// session over the remaining paper-jobs when none is banked
    /// (first activation, or resume after preemption/retry), placement
    /// re-derived against the *current* roster when empty.
    fn activate(&mut self, j: usize, now: f64, obs: &mut dyn RoundObserver) -> crate::Result<()> {
        let n = self.cluster.n();
        let jobs = self.slots.len();
        let resumed = {
            let slot = &mut self.slots[j];
            slot.queued = false;
            slot.admit_s.get_or_insert(now);
            let resumed = !slot.segments.is_empty();
            if slot.session.is_none() {
                let remaining = slot.jobs_total.saturating_sub(slot.assigned_base);
                let mut scfg = slot.session_cfg.clone();
                scfg.jobs = remaining.max(1);
                if slot.degraded {
                    scfg.wait_policy = WaitPolicy::NeverWait;
                }
                slot.session = Some(SgcSession::new(&slot.scheme, scfg));
            }
            resumed
        };
        if self.slots[j].place.is_empty() {
            let offset = self.policy.offset(j, n, jobs) % n.max(1);
            let sn = self.slots[j].session.as_ref().expect("session just ensured").n();
            self.slots[j].place = (0..sn).map(|i| (i + offset) % n).collect();
        }
        if resumed {
            if let Some(so) = &self.obs {
                so.obs.journal.record(now, EventKind::JobResume, j as i64, -1, -1, 0.0);
            }
        }
        self.start_round(j, obs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::ChaosPlan;
    use crate::cluster::{LatencyParams, SimCluster};
    use crate::straggler::models::NoStragglers;

    fn quiet(n: usize, seed: u64) -> SimCluster {
        SimCluster::new(n, LatencyParams::default(), Box::new(NoStragglers { n }), seed)
    }

    fn spec(n: usize, s: usize, jobs: usize) -> JobSpec {
        JobSpec {
            scheme: SchemeConfig::gc(n, s),
            session: SessionConfig { jobs, ..Default::default() },
        }
    }

    fn serve_quiet(seed: u64) -> (ScheduleReport, ScriptedSource) {
        let n = 8;
        let mut sim = quiet(n, seed);
        let mut src = ScriptedSource::new();
        // wave 1 at t=0, wave 2 long after wave 1 drained: two disjoint
        // admission waves over one live loop
        src.submit_at(ArrivalAt::Time(0.0), "w1-a", 1, spec(n, 1, 3));
        src.submit_at(ArrivalAt::Time(0.0), "w1-b", 0, spec(n, 1, 3));
        src.submit_at(ArrivalAt::Time(5_000.0), "w2-a", 2, spec(n, 2, 4));
        src.submit_at(ArrivalAt::Time(5_000.0), "w2-b", 0, spec(n, 1, 2));
        let mut sched = JobScheduler::new(&mut sim);
        let out = sched
            .serve(&mut src, &ServeConfig::default(), &mut NoopObserver)
            .unwrap();
        (out, src)
    }

    #[test]
    fn serve_survives_two_disjoint_admission_waves() {
        let (out, src) = serve_quiet(42);
        assert_eq!(out.reports.len(), 4);
        assert_eq!(src.accepted(), 4);
        assert_eq!(src.rejected(), 0);
        for o in &out.outcomes {
            assert_eq!(o.status, JobStatus::Completed, "job {}", o.job);
        }
        let u = &out.utilization;
        assert_eq!((u.jobs, u.jobs_rejected, u.preemptions), (4, 0, 0));
        // the idle gap between waves is excluded from the busy span …
        assert!(
            u.busy_span_s < u.makespan_s - 1_000.0,
            "busy {} vs makespan {}",
            u.busy_span_s,
            u.makespan_s
        );
        // … so the gain reflects real multiplexing, not wall idle time
        assert!(u.multiplexing_gain > u.total_session_s / u.makespan_s);
    }

    #[test]
    fn serve_is_deterministic_for_a_fixed_seed() {
        let (a, _) = serve_quiet(9);
        let (b, _) = serve_quiet(9);
        assert_eq!(format!("{:?}", a.reports), format!("{:?}", b.reports));
        assert_eq!(format!("{:?}", a.outcomes), format!("{:?}", b.outcomes));
    }

    #[test]
    fn backpressure_sheds_load_beyond_max_queue() {
        let n = 6;
        let mut sim = quiet(n, 3);
        let mut src = ScriptedSource::new();
        for i in 0..4 {
            src.submit_at(ArrivalAt::Time(0.0), &format!("j{i}"), 0, spec(n, 1, 2));
        }
        src.submit_bad_at(ArrivalAt::Time(0.0), "broken", "no such scheme");
        let cfg = ServeConfig { max_queue: 1, ..Default::default() };
        let mut sched = JobScheduler::new(&mut sim);
        let out = sched.serve(&mut src, &cfg, &mut NoopObserver).unwrap();
        // one request fills the queue; the rest of the co-timed burst is
        // shed, and the malformed one rejects regardless
        assert_eq!(src.accepted(), 1);
        assert_eq!(src.rejected(), 4);
        assert_eq!(out.utilization.jobs_rejected, 4);
        assert!(src.verdicts.iter().any(|(_, v)| matches!(
            v,
            AdmissionVerdict::Rejected { reason } if reason.contains("queue full (max 1)")
        )));
        assert!(src.verdicts.iter().any(|(_, v)| matches!(
            v,
            AdmissionVerdict::Rejected { reason } if reason.contains("bad spec")
        )));
        assert_eq!(out.reports.len(), 1);
        assert_eq!(out.outcomes[0].status, JobStatus::Completed);
    }

    #[test]
    fn shrink_preempts_the_low_priority_job_then_resumes_it() {
        let n = 8;
        let mut sim = quiet(n, 17);
        // retire 4 of 8 workers at the 4th submission: the fleet drops
        // below the aggregate demand of two co-active n=8 jobs
        sim.set_chaos(ChaosPlan::parse("shrink@r4:4", 5).unwrap().resolve(n));
        let mut src = ScriptedSource::new();
        src.submit_at(ArrivalAt::Time(0.0), "hi", 9, spec(n, 4, 6));
        src.submit_at(ArrivalAt::Time(0.0), "lo", 1, spec(n, 4, 6));
        let cfg = ServeConfig { oversub: 2.0, ..Default::default() };
        let mut sched = JobScheduler::new(&mut sim);
        let out = sched.serve(&mut src, &cfg, &mut NoopObserver).unwrap();
        assert_eq!(src.accepted(), 2);
        assert!(out.utilization.preemptions >= 1, "{}", out.utilization);
        // the preempted job resumed and finished its full ledger
        assert_eq!(out.reports.len(), 2);
        for (o, rep) in out.outcomes.iter().zip(&out.reports) {
            assert_eq!(o.status, JobStatus::Completed, "job {}", o.job);
            assert_eq!(rep.job_completion_s.len(), 6);
            assert!(rep.job_completion_s.iter().all(|t| t.is_finite()));
        }
    }

    #[test]
    fn chaos_bursts_feed_the_scripted_source() {
        let n = 6;
        let mut sim = quiet(n, 23);
        let plan = ChaosPlan::parse("adm@r2:3", 1).unwrap().resolve(n);
        let mut src = ScriptedSource::new();
        src.submit_at(ArrivalAt::Time(0.0), "seed", 0, spec(n, 1, 3));
        src.stage_bursts(&plan, |round, i| {
            (format!("burst-r{round}-{i}"), 1, spec(n, 1, 2))
        });
        let mut sched = JobScheduler::new(&mut sim);
        let out = sched
            .serve(&mut src, &ServeConfig::default(), &mut NoopObserver)
            .unwrap();
        assert_eq!(out.reports.len(), 4, "seed job + 3-job burst");
        assert_eq!(src.accepted(), 4);
        for o in &out.outcomes {
            assert_eq!(o.status, JobStatus::Completed);
        }
    }

    #[test]
    fn queue_source_parses_and_answers_on_the_control_queue() {
        let n = 6;
        let control = ControlQueue::shared();
        {
            let mut q = control.lock().unwrap();
            q.incoming.push_back(RawSubmit {
                token: 7,
                name: "wire-a".into(),
                scheme: "gc:1".into(),
                session_jobs: 2,
                priority: 3,
            });
            q.incoming.push_back(RawSubmit {
                token: 8,
                name: "wire-bad".into(),
                scheme: "nonsense".into(),
                session_jobs: 0,
                priority: 0,
            });
            q.closed = true;
        }
        let template = SessionConfig { jobs: 5, ..Default::default() };
        let mut src = QueueSource::new(control.clone(), n, template);
        let mut sim = quiet(n, 4);
        let mut sched = JobScheduler::new(&mut sim);
        let out = sched
            .serve(&mut src, &ServeConfig::default(), &mut NoopObserver)
            .unwrap();
        assert_eq!(out.reports.len(), 1);
        assert_eq!(out.reports[0].job_completion_s.len(), 2, "session_jobs override");
        assert_eq!(out.utilization.jobs_rejected, 1);
        let q = control.lock().unwrap();
        let verdicts: Vec<_> = q.verdicts.iter().cloned().collect();
        assert_eq!(verdicts.len(), 2);
        assert_eq!(verdicts[0], (7, RawVerdict::Accepted { job: 0, queue_depth: 1 }));
        assert!(matches!(
            &verdicts[1],
            (8, RawVerdict::Rejected { reason }) if reason.contains("bad spec")
        ));
    }

    #[test]
    fn serve_for_deadline_rejects_late_submissions_but_drains_accepted() {
        let n = 6;
        let mut sim = quiet(n, 31);
        let mut src = ScriptedSource::new();
        src.submit_at(ArrivalAt::Time(0.0), "early", 0, spec(n, 1, 3));
        // lands exactly on the deadline: drained on the shutdown tick
        // and shed with the shutting-down verdict
        src.submit_at(ArrivalAt::Time(1_000.0), "late", 5, spec(n, 1, 3));
        let cfg = ServeConfig { serve_for_s: Some(1_000.0), ..Default::default() };
        let mut sched = JobScheduler::new(&mut sim);
        let out = sched.serve(&mut src, &cfg, &mut NoopObserver).unwrap();
        assert_eq!(src.accepted(), 1);
        assert_eq!(src.rejected(), 1);
        assert!(src.verdicts.iter().any(|(_, v)| matches!(
            v,
            AdmissionVerdict::Rejected { reason } if reason.contains("shutting down")
        )));
        assert_eq!(out.reports.len(), 1);
        assert_eq!(out.outcomes[0].status, JobStatus::Completed);
        assert_eq!(out.reports[0].job_completion_s.len(), 3);
    }
}
