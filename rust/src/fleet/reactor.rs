//! Minimal readiness layer for the single-threaded fleet master: a
//! hand-rolled `poll(2)` binding (keeping the crate's zero-heavy-deps
//! posture — no `mio`, no `libc` crate) plus [`Connection`], a
//! non-blocking TCP stream with partial-frame read buffering and a
//! pending-write buffer.
//!
//! The master builds one fd set per reactor turn — the listener, every
//! worker socket, every pre-`Hello` pending connection — and sleeps in
//! a single `poll(2)` call whose timeout is the *exact* distance to the
//! next deadline (the caller's μ-cutoff horizon, a heartbeat reap, a
//! round timeout, a handshake expiry). One readable socket wakes it;
//! nothing in the loop sleeps a fixed slice. See `rust/DESIGN.md`
//! §Reactor for the wakeup math.

use super::wire::{Frame, FrameBuffer};
use std::io::{self, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::os::raw::c_ulong;
use std::os::unix::io::{AsRawFd, RawFd};
use std::time::Duration;

/// `poll(2)` readable-interest / readiness flag (`POLLIN`).
pub const POLLIN: i16 = 0x001;
/// `poll(2)` writable-interest / readiness flag (`POLLOUT`).
pub const POLLOUT: i16 = 0x004;
/// `poll(2)` error readiness flag (`POLLERR`, output only).
pub const POLLERR: i16 = 0x008;
/// `poll(2)` hangup readiness flag (`POLLHUP`, output only).
pub const POLLHUP: i16 = 0x010;
/// `poll(2)` invalid-fd flag (`POLLNVAL`, output only).
pub const POLLNVAL: i16 = 0x020;

/// One entry of a `poll(2)` fd set — layout-compatible with the C
/// `struct pollfd` (fd, then two shorts), which is identical on every
/// Unix this crate targets.
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct PollFd {
    /// File descriptor to watch (negative entries are ignored by the
    /// kernel, which is how a slot is masked out without re-indexing).
    pub fd: RawFd,
    /// Requested events (`POLLIN` / `POLLOUT`).
    pub events: i16,
    /// Kernel-reported readiness, valid after [`poll_fds`] returns.
    pub revents: i16,
}

impl PollFd {
    /// Watch `fd` for `events`.
    pub fn new(fd: RawFd, events: i16) -> Self {
        PollFd { fd, events, revents: 0 }
    }

    /// Any readiness at all, including error/hangup conditions (which
    /// the kernel reports even when not requested).
    pub fn ready(&self) -> bool {
        self.revents != 0
    }

    /// Readable (or in an error/hangup state that a read will surface).
    pub fn readable(&self) -> bool {
        self.revents & (POLLIN | POLLERR | POLLHUP | POLLNVAL) != 0
    }

    /// Writable.
    pub fn writable(&self) -> bool {
        self.revents & (POLLOUT | POLLERR | POLLHUP | POLLNVAL) != 0
    }
}

extern "C" {
    /// `int poll(struct pollfd *fds, nfds_t nfds, int timeout)` from the
    /// platform C library (always linked by Rust's std on Unix).
    fn poll(fds: *mut PollFd, nfds: c_ulong, timeout: i32) -> i32;
}

/// Block until at least one fd in `fds` is ready or `timeout` elapses
/// (`None` = wait indefinitely). Returns the number of ready entries
/// (0 = timed out). With an empty `fds`, this is a precise sleep.
///
/// The timeout is rounded *up* to the next millisecond, so the call
/// never wakes before the requested deadline (the property the μ-cutoff
/// exactness test pins); `EINTR` retries with the same timeout.
pub fn poll_fds(fds: &mut [PollFd], timeout: Option<Duration>) -> io::Result<usize> {
    let timeout_ms: i32 = match timeout {
        None => -1,
        Some(d) => {
            let ms = (d.as_secs_f64() * 1000.0).ceil();
            if ms >= i32::MAX as f64 {
                i32::MAX
            } else {
                ms as i32
            }
        }
    };
    loop {
        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as c_ulong, timeout_ms) };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = io::Error::last_os_error();
        if err.kind() != io::ErrorKind::Interrupted {
            return Err(err);
        }
    }
}

/// One non-blocking connection owned by the reactor: the TCP stream, a
/// [`FrameBuffer`] assembling inbound frames across partial reads, and
/// an outbound byte buffer flushed on writability.
///
/// All methods are edge-tolerant: they do as much work as the socket
/// allows and never block. A fatal condition (EOF, I/O error, or an
/// unframeable byte stream) latches [`is_dead`](Self::is_dead); the
/// owner decides what that means for the worker.
pub struct Connection {
    stream: TcpStream,
    rbuf: FrameBuffer,
    wbuf: Vec<u8>,
    /// Consumed prefix of `wbuf` (compacted on the next queue).
    wpos: usize,
    dead: bool,
    /// Bytes read off the socket since the last [`take_io`](Self::take_io).
    bytes_in: u64,
    /// Bytes written to the socket since the last [`take_io`](Self::take_io).
    bytes_out: u64,
}

impl Connection {
    /// Take ownership of an accepted stream and switch it to
    /// non-blocking mode.
    pub fn new(stream: TcpStream) -> io::Result<Self> {
        stream.set_nonblocking(true)?;
        stream.set_nodelay(true).ok();
        Ok(Connection {
            stream,
            rbuf: FrameBuffer::new(),
            wbuf: Vec::new(),
            wpos: 0,
            dead: false,
            bytes_in: 0,
            bytes_out: 0,
        })
    }

    /// Raw fd for the reactor's poll set.
    pub fn fd(&self) -> RawFd {
        self.stream.as_raw_fd()
    }

    /// Events this connection currently wants from `poll(2)`.
    pub fn interest(&self) -> i16 {
        if self.wants_write() {
            POLLIN | POLLOUT
        } else {
            POLLIN
        }
    }

    /// Outbound bytes are queued and unsent.
    pub fn wants_write(&self) -> bool {
        self.wpos < self.wbuf.len()
    }

    /// The connection hit EOF, a fatal I/O error, or a framing error.
    pub fn is_dead(&self) -> bool {
        self.dead
    }

    /// Drain everything the socket currently has into the frame buffer.
    /// Returns `false` once the connection is dead.
    pub fn fill(&mut self) -> bool {
        if self.dead {
            return false;
        }
        let mut tmp = [0u8; 16 * 1024];
        loop {
            match self.stream.read(&mut tmp) {
                Ok(0) => {
                    self.dead = true;
                    return false;
                }
                Ok(k) => {
                    self.bytes_in += k as u64;
                    self.rbuf.feed(&tmp[..k]);
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return true,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => {
                    self.dead = true;
                    return false;
                }
            }
        }
    }

    /// Next complete inbound frame, if one is buffered. A framing error
    /// kills the connection (the byte stream can no longer be trusted).
    pub fn next_frame(&mut self) -> Option<Frame> {
        match self.try_next_frame() {
            Ok(f) => f,
            Err(e) => {
                crate::log_warn!("fleet master: unframeable peer ({e}); dropping connection");
                self.dead = true;
                None
            }
        }
    }

    /// Like [`next_frame`](Self::next_frame), but surfaces the framing
    /// error instead of latching the connection dead — the handshake
    /// compat gate uses this to answer a wrong-version peer with a
    /// structured [`Frame::Error`] before closing. After an `Err` the
    /// caller must stop reading (the byte stream can no longer be
    /// trusted); writes still work so a farewell frame can go out.
    pub fn try_next_frame(&mut self) -> Result<Option<Frame>, super::wire::WireError> {
        self.rbuf.next_frame()
    }

    /// Queue `frame` and opportunistically flush. Returns `false` once
    /// the connection is dead (the frame is then lost, like a write to a
    /// gone socket always was).
    pub fn send(&mut self, frame: &Frame) -> bool {
        if self.dead {
            return false;
        }
        if self.wpos > 0 {
            self.wbuf.drain(..self.wpos);
            self.wpos = 0;
        }
        self.wbuf.extend_from_slice(&frame.encode());
        self.flush()
    }

    /// Write as much queued output as the socket accepts right now.
    /// Returns `false` once the connection is dead.
    pub fn flush(&mut self) -> bool {
        if self.dead {
            return false;
        }
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => {
                    self.dead = true;
                    return false;
                }
                Ok(k) => {
                    self.bytes_out += k as u64;
                    self.wpos += k;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return true,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => {
                    self.dead = true;
                    return false;
                }
            }
        }
        true
    }

    /// Half-close both directions (best-effort; idempotent).
    pub fn shutdown(&self) {
        let _ = self.stream.shutdown(Shutdown::Both);
    }

    /// Harvest and reset the byte counters accumulated since the last
    /// call: `(bytes_in, bytes_out)`. The observability layer sums these
    /// across connections each reactor turn.
    pub fn take_io(&mut self) -> (u64, u64) {
        let io = (self.bytes_in, self.bytes_out);
        self.bytes_in = 0;
        self.bytes_out = 0;
        io
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn poll_timeout_is_a_precise_sleep_with_no_fds() {
        let t = std::time::Instant::now();
        let n = poll_fds(&mut [], Some(Duration::from_millis(40))).unwrap();
        assert_eq!(n, 0);
        let elapsed = t.elapsed();
        assert!(elapsed >= Duration::from_millis(40), "woke early: {elapsed:?}");
        assert!(elapsed < Duration::from_millis(200), "woke far too late: {elapsed:?}");
    }

    #[test]
    fn poll_wakes_on_readability() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        let mut fds = [PollFd::new(server.as_raw_fd(), POLLIN)];
        // nothing to read yet: times out
        assert_eq!(poll_fds(&mut fds, Some(Duration::from_millis(10))).unwrap(), 0);
        // a write from the peer wakes the poll well before the timeout
        (&client).write_all(b"x").unwrap();
        let t = std::time::Instant::now();
        let n = poll_fds(&mut fds, Some(Duration::from_secs(5))).unwrap();
        assert_eq!(n, 1);
        assert!(fds[0].readable());
        assert!(t.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn connection_round_trips_frames_nonblocking() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        let mut conn = Connection::new(server).unwrap();

        // peer sends two frames back to back
        let f1 = Frame::Hello { worker_id: 3 };
        let f2 = Frame::Heartbeat { worker_id: 3, round: 9 };
        super::super::wire::write_frame(&mut (&client), &f1).unwrap();
        super::super::wire::write_frame(&mut (&client), &f2).unwrap();
        // wait for readability, then drain
        let mut fds = [PollFd::new(conn.fd(), POLLIN)];
        poll_fds(&mut fds, Some(Duration::from_secs(5))).unwrap();
        assert!(conn.fill());
        assert_eq!(conn.next_frame(), Some(f1));
        assert_eq!(conn.next_frame(), Some(f2));
        assert_eq!(conn.next_frame(), None);
        assert!(!conn.is_dead());

        // outbound path: send lands on the peer intact
        assert!(conn.send(&Frame::Shutdown));
        let got = super::super::wire::read_frame(&mut (&client)).unwrap();
        assert_eq!(got, Frame::Shutdown);

        // peer hangs up → fill reports death
        drop(client);
        let mut fds = [PollFd::new(conn.fd(), POLLIN)];
        poll_fds(&mut fds, Some(Duration::from_secs(5))).unwrap();
        assert!(!conn.fill());
        assert!(conn.is_dead());
    }
}
