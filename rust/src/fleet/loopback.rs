//! In-process loopback fleet: a master plus `n` worker threads over
//! localhost TCP — the full wire protocol, streaming arrivals and
//! wall-clock μ-rule with zero external processes. Backs the fleet
//! integration tests, the CI smoke job and `sgc run --fleet N`.

use super::master::FleetCluster;
use super::worker::{run_worker, ChaosConfig, WorkerConfig, WorkerStats};
use std::thread::JoinHandle;
use std::time::Duration;

/// A running loopback fleet. Dropping it shuts the workers down; call
/// [`shutdown`](Self::shutdown) to also collect their stats.
pub struct LoopbackFleet {
    /// The master handle (drive it via [`super::drive_fleet`], a
    /// multi-job [`JobScheduler`](crate::sched::JobScheduler), or — for
    /// blocking callers — a [`SyncAdapter`](crate::cluster::SyncAdapter)).
    pub cluster: FleetCluster,
    workers: Vec<JoinHandle<crate::Result<WorkerStats>>>,
}

impl LoopbackFleet {
    /// Spin up `n` workers on localhost with the given chaos injection
    /// (`None` = always healthy) and accept them all.
    pub fn spawn(n: usize, chaos: Option<ChaosConfig>) -> crate::Result<Self> {
        Self::spawn_with(n, move |id, addr| {
            WorkerConfig::loopback(id, addr.to_string(), chaos)
        })
    }

    /// Full-control variant: `make_config(id, master_addr)` builds each
    /// worker's configuration.
    pub fn spawn_with(
        n: usize,
        make_config: impl Fn(u32, &str) -> WorkerConfig,
    ) -> crate::Result<Self> {
        let mut workers = Vec::with_capacity(n);
        let cluster = FleetCluster::listen_ephemeral(n, Duration::from_secs(10), |addr| {
            for id in 0..n as u32 {
                let cfg = make_config(id, addr);
                let handle = std::thread::Builder::new()
                    .name(format!("sgc-fleet-worker-{id}"))
                    .spawn(move || run_worker(cfg))
                    .expect("spawn loopback worker");
                workers.push(handle);
            }
        })?;
        Ok(LoopbackFleet { cluster, workers })
    }

    /// Start one more worker thread against this fleet's master — the
    /// elastic-membership late-join path. The worker connects, claims
    /// `cfg.id` via `Hello`, and is admitted into the live roster the
    /// next time the master's reactor runs (staging
    /// [`ClusterEvent::WorkerJoined`](crate::cluster::ClusterEvent));
    /// a fresh id grows the fleet, a retired id re-joins it. The thread
    /// is tracked like the initial workers and joined by
    /// [`shutdown`](Self::shutdown).
    ///
    /// Call this *before* handing the cluster to a scheduler: admission
    /// itself happens mid-run, inside the master's event loop.
    pub fn join_worker(&mut self, mut cfg: WorkerConfig) {
        cfg.master = self.cluster.addr().to_string();
        let id = cfg.id;
        let handle = std::thread::Builder::new()
            .name(format!("sgc-fleet-worker-{id}"))
            .spawn(move || run_worker(cfg))
            .expect("spawn loopback joiner");
        self.workers.push(handle);
    }

    /// Send `Shutdown` to all workers and join them.
    pub fn shutdown(mut self) -> crate::Result<Vec<WorkerStats>> {
        self.cluster.shutdown();
        self.workers
            .drain(..)
            .map(|h| h.join().map_err(|_| anyhow::anyhow!("worker thread panicked"))?)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, SyncAdapter};
    use crate::coding::SchemeConfig;
    use crate::fleet::drive_fleet;
    use crate::session::SessionConfig;

    #[test]
    fn quiet_loopback_round_trip() {
        let mut fleet = LoopbackFleet::spawn(3, None).unwrap();
        // blocking bridge over the event API: wait for all three results
        let sample = SyncAdapter::new(&mut fleet.cluster).sample_round(&[0.05, 0.05, 0.05]);
        assert_eq!(sample.finish.len(), 3);
        // quiet workers: all times near base + α·load ≈ 24 ms, none wild
        for &f in &sample.finish {
            assert!((0.01..1.0).contains(&f), "finish {f}");
        }
        let stats = fleet.shutdown().unwrap();
        assert!(stats.iter().all(|s| s.rounds_served == 1), "{stats:?}");
    }

    #[test]
    fn fleet_run_completes_and_traces() {
        let n = 4;
        let chaos = Some(ChaosConfig::default_fit(17));
        let mut fleet = LoopbackFleet::spawn(n, chaos).unwrap();
        let scheme = SchemeConfig::gc(n, 1);
        let cfg = SessionConfig { jobs: 6, ..Default::default() };
        let run = drive_fleet(&scheme, &cfg, &mut fleet.cluster).unwrap();
        assert_eq!(run.report.rounds.len(), 6);
        assert_eq!(run.report.deadline_violations, 0);
        assert!(run.report.total_runtime_s > 0.0);
        assert_eq!(run.trace.n, n);
        assert_eq!(run.trace.rounds(), 6);
        // the trace matrix is complete and strictly positive
        assert!(run
            .trace
            .rounds
            .iter()
            .all(|r| r.finish.iter().all(|&f| f > 0.0 && f.is_finite())));
        fleet.shutdown().unwrap();
    }

    #[test]
    fn mismatched_fleet_size_is_an_error_not_a_panic() {
        let mut fleet = LoopbackFleet::spawn(2, None).unwrap();
        let scheme = SchemeConfig::gc(4, 1); // expects 4 workers
        let cfg = SessionConfig { jobs: 2, ..Default::default() };
        let err = drive_fleet(&scheme, &cfg, &mut fleet.cluster).unwrap_err();
        assert!(err.to_string().contains("expects n = 4"), "{err}");
        fleet.shutdown().unwrap();
    }
}
