//! Worker runtime: serve task assignments from a fleet master over TCP.
//!
//! A worker connects, claims its slot with a `Hello`, then loops: on
//! `Assign` it executes a synthetic minitask whose duration scales with
//! the assigned normalized load (exactly the latency law the simulator
//! uses, so fleet and sim runs live on the same time axis up to a scale
//! factor), sends a `Result`, and keeps heartbeating from a side thread
//! so the master can tell "slow" from "dead". `Shutdown` ends the loop.
//!
//! **Chaos injection.** Real Lambda fleets straggle on their own; a
//! loopback fleet on one machine does not. [`ChaosConfig`] recreates the
//! paper's observed behaviour deterministically: each worker owns a
//! Gilbert–Elliot state machine seeded from `(seed, worker_id)` and, in
//! slow rounds, stretches its minitask by a Pareto-tailed multiplier with
//! within-burst decay — the same process as
//! [`cluster::LatencyParams`](crate::cluster::LatencyParams), so a seeded
//! live run is reproducible straggler-for-straggler.

use super::wire::{
    read_frame, tensor_slices, write_frame, Frame, GradUnit, TensorAssembly, WireError,
};
use crate::chaos::{FaultKind, WorkerFault};
use crate::cluster::latency::decayed_uplift;
use crate::grad::dataplane::ChunkData;
use crate::grad::mlp;
use crate::runtime::ModelDims;
use crate::straggler::models::ge_step;
use crate::util::rng::Pcg32;
use std::collections::{BTreeSet, HashMap};
use std::io::BufReader;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Deterministic straggler injection for one worker.
#[derive(Clone, Copy, Debug)]
pub struct ChaosConfig {
    /// Fleet-wide seed; each worker derives its stream from
    /// `(seed, worker_id)`.
    pub seed: u64,
    /// Gilbert–Elliot entry probability (normal → straggler).
    pub p_enter: f64,
    /// Gilbert–Elliot exit probability (straggler → normal).
    pub p_exit: f64,
    /// Minimum slowdown multiplier while straggling (> 1 + μ so the
    /// μ-rule can see it).
    pub slow_scale: f64,
    /// Pareto shape of the slowdown tail.
    pub slow_shape: f64,
    /// Within-burst severity decay per consecutive slow round.
    pub decay: f64,
    /// Probability of an extra one-round straggle even while the
    /// Gilbert–Elliot state is healthy (an independently drawn transient
    /// contention spike per worker — not correlated across the fleet).
    pub p_burst: f64,
}

impl ChaosConfig {
    /// Fig.-1-flavoured defaults: ~5% straggling cells, short bursts,
    /// 2–4× slowdowns.
    pub fn default_fit(seed: u64) -> Self {
        ChaosConfig {
            seed,
            p_enter: 0.037,
            p_exit: 0.7,
            slow_scale: 2.4,
            slow_shape: 6.5,
            decay: 0.68,
            p_burst: 0.01,
        }
    }
}

/// Per-worker chaos state machine (deterministic given config + id).
struct ChaosState {
    cfg: ChaosConfig,
    rng: Pcg32,
    straggling: bool,
    burst_age: usize,
}

impl ChaosState {
    fn new(cfg: ChaosConfig, worker_id: u32) -> Self {
        // worker-id-keyed stream: chaos is independent per worker and
        // independent of how rounds interleave across workers.
        let rng = Pcg32::new(cfg.seed ^ 0x0f1ee7, 0x40_000 + worker_id as u64);
        ChaosState { cfg, rng, straggling: false, burst_age: 0 }
    }

    /// Advance one round; returns the execution-time multiplier (1.0 when
    /// healthy).
    fn next_multiplier(&mut self) -> f64 {
        self.straggling =
            ge_step(self.straggling, self.cfg.p_enter, self.cfg.p_exit, &mut self.rng);
        let burst = self.rng.chance(self.cfg.p_burst);
        if self.straggling || burst {
            let raw = self.rng.pareto(self.cfg.slow_scale, self.cfg.slow_shape);
            let mult = decayed_uplift(raw, self.cfg.decay, self.burst_age);
            self.burst_age += 1;
            mult
        } else {
            self.burst_age = 0;
            1.0
        }
    }
}

/// Worker runtime configuration.
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    /// Slot id (unique per fleet; a late joiner picks the next free id,
    /// a reconnecting worker reclaims its old one).
    pub id: u32,
    /// Master address, e.g. `127.0.0.1:7070`.
    pub master: String,
    /// Seeded straggler injection; `None` = always healthy.
    pub chaos: Option<ChaosConfig>,
    /// Fixed per-round overhead of the minitask (seconds).
    pub base_s: f64,
    /// Seconds of minitask work per unit of normalized load (the fleet's
    /// α, mirroring `LatencyParams::alpha_s_per_load`).
    pub alpha_s: f64,
    /// Heartbeat period.
    pub heartbeat: Duration,
    /// Keep retrying the initial TCP connect for this long (a late
    /// joiner or a reconnecting worker may race the master's listener).
    /// `Duration::ZERO` = a single attempt.
    pub connect_retry: Duration,
    /// Fault injection for membership tests: after serving this many
    /// rounds, crash — drop the connection with no `Shutdown` handshake,
    /// exactly like a worker process dying mid-fleet. `None` = never.
    pub fail_after_rounds: Option<usize>,
    /// Scripted chaos fault (see [`crate::chaos`]): crash, silent hang,
    /// byzantine corruption or socket-drop-and-reconnect, acted out at
    /// the scripted assignment ordinal. Populated from
    /// [`ResolvedPlan::worker_fault`](crate::chaos::ResolvedPlan::worker_fault)
    /// by `sgc serve --chaos`. `None` = healthy.
    pub fault: Option<WorkerFault>,
}

impl WorkerConfig {
    /// Loopback-friendly defaults: ~25 ms quiet rounds at typical loads,
    /// so tests and CI smoke runs finish in seconds.
    pub fn loopback(id: u32, master: String, chaos: Option<ChaosConfig>) -> Self {
        WorkerConfig {
            id,
            master,
            chaos,
            base_s: 0.02,
            alpha_s: 0.08,
            heartbeat: Duration::from_millis(50),
            connect_retry: Duration::from_secs(5),
            fail_after_rounds: None,
            fault: None,
        }
    }
}

/// What a worker did before shutdown.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct WorkerStats {
    /// Assignments executed (results sent).
    pub rounds_served: usize,
    /// Rounds in which chaos injection stretched the minitask.
    pub chaos_rounds: usize,
}

/// Dial the master until `deadline`, with capped exponential backoff
/// and deterministic per-worker jitter between attempts: attempt `k`
/// sleeps `min(10ms · 2ᵏ, 500ms) · (0.5 + 0.5·u)`, where `u` comes from
/// a [`Pcg32`] stream keyed on the worker id — a herd of restarting
/// workers spreads its redials out instead of hammering the listener in
/// lockstep. Used by both the initial connect and mid-run reconnects.
fn connect_with_backoff(cfg: &WorkerConfig, deadline: Instant) -> crate::Result<TcpStream> {
    let mut rng = Pcg32::new(0x5e7_bacf ^ u64::from(cfg.id), 0xd1a1);
    let mut attempt = 0u32;
    loop {
        match TcpStream::connect(&cfg.master) {
            Ok(s) => return Ok(s),
            Err(e) => {
                let now = Instant::now();
                if now >= deadline {
                    return Err(anyhow::anyhow!(
                        "worker {}: connect {}: {e}",
                        cfg.id,
                        cfg.master
                    ));
                }
                let base = Duration::from_millis(10u64 << attempt.min(6));
                let jittered = base.min(Duration::from_millis(500)).mul_f64(0.5 + 0.5 * rng.f64());
                std::thread::sleep(jittered.min(deadline - now));
                attempt += 1;
            }
        }
    }
}

/// Gradient data-plane state for one job, cached across rounds *and*
/// redials: a reconnecting worker keeps its partitions and only fetches
/// what the master re-ships.
struct GradJob {
    dims: ModelDims,
    /// Latest fully assembled `(version, tensors)` parameter broadcast.
    params: Option<(u32, Vec<Vec<f32>>)>,
    /// Cached partitions keyed by chunk id.
    chunks: HashMap<u32, ChunkData>,
    /// In-flight partition reassembly: chunk id → (rows, assembly).
    part_asm: HashMap<u32, (u32, TensorAssembly)>,
    /// In-flight parameter reassembly: (version, assembly).
    params_asm: Option<(u32, TensorAssembly)>,
}

impl GradJob {
    fn new(dims: ModelDims) -> Self {
        GradJob {
            dims,
            params: None,
            chunks: HashMap::new(),
            part_asm: HashMap::new(),
            params_asm: None,
        }
    }
}

/// Compute the framed payload for a `GradAssign`: per distinct chunk one
/// real forward/backward pass, then per wire unit either the raw chunk
/// gradient or the coded combination with the master-resolved
/// coefficients, concatenated in unit order (`param_count` floats each).
///
/// `None` — stay silent, let the straggler path absorb it — when the
/// worker cannot answer faithfully: params missing or at a different
/// version than the assignment pins, or a partition not yet cached.
fn compute_grad_units(gj: &GradJob, version: u32, units: &[GradUnit]) -> Option<Vec<f32>> {
    let (v, params) = gj.params.as_ref()?;
    if *v != version {
        return None;
    }
    let pc = gj.dims.param_count();
    let mut wanted: BTreeSet<u32> = BTreeSet::new();
    for u in units {
        match u {
            GradUnit::Plain { chunk, .. } => {
                wanted.insert(*chunk);
            }
            GradUnit::Coded { terms, .. } => {
                for &(c, _) in terms {
                    wanted.insert(c);
                }
            }
        }
    }
    let mut grads: HashMap<u32, Vec<f32>> = HashMap::new();
    for &c in &wanted {
        let ch = gj.chunks.get(&c)?;
        let (_, g) = mlp::grad_chunk(&gj.dims, params, &ch.x, &ch.y, &ch.w);
        grads.insert(c, mlp::flatten(&g));
    }
    let mut out = Vec::with_capacity(pc * units.len());
    for u in units {
        match u {
            GradUnit::Plain { chunk, .. } => out.extend_from_slice(&grads[chunk]),
            GradUnit::Coded { terms, .. } => {
                let mut ell = vec![0.0f32; pc];
                for &(c, coeff) in terms {
                    for (e, &x) in ell.iter_mut().zip(&grads[&c]) {
                        *e += coeff as f32 * x;
                    }
                }
                out.extend_from_slice(&ell);
            }
        }
    }
    Some(out)
}

/// Why one TCP session of the worker loop ended.
enum SessionEnd {
    /// Terminal: clean `Shutdown`, master EOF mid-run, or a scripted
    /// crash/hang fault ran its course. The worker exits.
    Done,
    /// Scripted reconnect fault: drop the socket, stay away for
    /// `away_s`, then redial and rejoin.
    Redial {
        away_s: f64,
    },
}

/// Run the worker loop until the master sends `Shutdown` or disconnects.
///
/// Connects (initially and after a scripted reconnect fault) with
/// capped exponential backoff until [`WorkerConfig::connect_retry`]
/// elapses, so a worker started moments before its master — or
/// re-joining an elastic fleet — does not fail spuriously.
///
/// Scripted faults ([`WorkerConfig::fault`]) always end in `Ok`: a
/// chaos run's planned deaths are not errors the harness should
/// propagate.
pub fn run_worker(cfg: WorkerConfig) -> crate::Result<WorkerStats> {
    let mut fault = cfg.fault;
    let mut chaos = cfg.chaos.map(|c| ChaosState::new(c, cfg.id));
    let mut stats = WorkerStats::default();
    // Gradient data-plane cache, deliberately outside the session loop:
    // partitions survive a scripted reconnect, and the master re-ships
    // only what the rejoined connection reports missing.
    let mut grad: HashMap<u32, GradJob> = HashMap::new();
    let mut deadline = Instant::now() + cfg.connect_retry;
    let mut initial = true;
    loop {
        match serve_session(&cfg, initial, &mut fault, &mut chaos, &mut stats, &mut grad, deadline)?
        {
            SessionEnd::Done => return Ok(stats),
            SessionEnd::Redial { away_s } => {
                std::thread::sleep(Duration::from_secs_f64(away_s.max(0.0)));
                // fresh retry budget, same capped-backoff dial policy
                deadline = Instant::now() + cfg.connect_retry;
                initial = false;
            }
        }
    }
}

/// One TCP session: connect, `Hello`, serve assignments (with heartbeat
/// side thread) until shutdown, disconnect, or a scripted fault acts.
fn serve_session(
    cfg: &WorkerConfig,
    initial: bool,
    fault: &mut Option<WorkerFault>,
    chaos: &mut Option<ChaosState>,
    stats: &mut WorkerStats,
    grad: &mut HashMap<u32, GradJob>,
    connect_deadline: Instant,
) -> crate::Result<SessionEnd> {
    let stream = match connect_with_backoff(cfg, connect_deadline) {
        Ok(s) => s,
        // A redial that finds no master is a clean exit, not an error:
        // the fleet may simply have finished and shut down while this
        // worker was acting out its scripted away window.
        Err(_) if !initial => return Ok(SessionEnd::Done),
        Err(e) => return Err(e),
    };
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let writer = Arc::new(Mutex::new(stream));
    write_frame(&mut *writer.lock().unwrap(), &Frame::Hello { worker_id: cfg.id })?;

    // Heartbeat side thread: liveness, not progress — it keeps beating
    // while a long minitask runs, which is exactly what lets the master
    // distinguish a straggler (cut it) from a corpse (error out).
    let stop = Arc::new(AtomicBool::new(false));
    let current_round = Arc::new(AtomicU32::new(0));
    let hb = {
        let writer = Arc::clone(&writer);
        let stop = Arc::clone(&stop);
        let round = Arc::clone(&current_round);
        let period = cfg.heartbeat;
        let id = cfg.id;
        std::thread::Builder::new()
            .name(format!("sgc-fleet-hb-{id}"))
            .spawn(move || {
                while !stop.load(Ordering::Acquire) {
                    std::thread::sleep(period);
                    let frame =
                        Frame::Heartbeat { worker_id: id, round: round.load(Ordering::Acquire) };
                    if write_frame(&mut *writer.lock().unwrap(), &frame).is_err() {
                        break; // master gone; main loop will notice too
                    }
                }
            })
            .expect("spawn heartbeat thread")
    };

    let result = loop {
        match read_frame(&mut reader) {
            Ok(Frame::Assign { round, work_units, chunks }) => {
                // A scripted fault past its threshold acts on *receipt*
                // of the next assignment — the in-flight round is what
                // the fault strands, exactly like a process dying with
                // work on its plate.
                if let Some(f) = *fault {
                    if stats.rounds_served as u64 >= f.at_round {
                        match f.kind {
                            FaultKind::Crash => {
                                // dropped socket, no Shutdown handshake
                                break Ok(SessionEnd::Done);
                            }
                            FaultKind::Hang => {
                                // silent: stop results *and* heartbeats
                                // but hold the socket open until the
                                // master reaps us and hangs up
                                stop.store(true, Ordering::Release);
                                while read_frame(&mut reader).is_ok() {}
                                break Ok(SessionEnd::Done);
                            }
                            FaultKind::Reconnect => {
                                *fault = None; // one-shot
                                break Ok(SessionEnd::Redial { away_s: f.away_s });
                            }
                            // byzantine corrupts the gradient payload
                            // (see the GradAssign arm); master-side
                            // kinds never reach a worker
                            _ => {}
                        }
                    }
                }
                current_round.store(round, Ordering::Release);
                let mult = chaos.as_mut().map_or(1.0, |c| c.next_multiplier());
                if mult > 1.0 {
                    stats.chaos_rounds += 1;
                }
                let started = Instant::now();
                let checksum = execute_minitask(
                    &chunks,
                    (cfg.base_s + cfg.alpha_s * work_units) * mult,
                );
                stats.rounds_served += 1;
                let frame = Frame::Result {
                    worker_id: cfg.id,
                    round,
                    compute_s: started.elapsed().as_secs_f64(),
                    checksum,
                };
                if let Err(e) = write_frame(&mut *writer.lock().unwrap(), &frame) {
                    break Err(anyhow::anyhow!("worker {}: send result: {e}", cfg.id));
                }
                // fault injection: crash after this many served rounds —
                // no Shutdown handshake, just a dropped socket, exactly
                // like a worker process dying (membership tests)
                if cfg.fail_after_rounds.is_some_and(|k| stats.rounds_served >= k) {
                    break Ok(SessionEnd::Done);
                }
            }
            Ok(Frame::Shutdown) => break Ok(SessionEnd::Done),
            // The master refuses the session deliberately (version
            // mismatch, bad handshake): surface its reason instead of
            // the generic "closed before assigning work".
            Ok(Frame::Error { code, msg }) => {
                break Err(anyhow::anyhow!(
                    "worker {}: master refused the session (code {code}): {msg}",
                    cfg.id
                ))
            }
            Ok(Frame::JobSpec { job, input, classes, hidden1, hidden2 }) => {
                let dims = ModelDims {
                    input: input as usize,
                    classes: classes as usize,
                    hidden1: hidden1 as usize,
                    hidden2: hidden2 as usize,
                    // batch sharding is the master's concern; the worker
                    // only ever sees materialised partitions
                    chunk: 0,
                };
                grad.entry(job).or_insert_with(|| GradJob::new(dims));
            }
            Ok(Frame::Partition { job, chunk, rows, off, total, data }) => {
                let Some(gj) = grad.get_mut(&job) else { continue };
                if off == 0 {
                    // a re-ship always restarts the assembly — a stale
                    // half-built partition from before a redial must
                    // not poison the fresh copy
                    gj.part_asm.insert(chunk, (rows, TensorAssembly::new(total)));
                }
                let Some((_, asm)) = gj.part_asm.get_mut(&chunk) else { continue };
                match asm.accept(off, &data) {
                    Ok(false) => {}
                    Ok(true) => {
                        let (rows, asm) =
                            gj.part_asm.remove(&chunk).expect("assembly just completed");
                        match ChunkData::from_flat(&gj.dims, rows as usize, &asm.take()) {
                            Some(cd) => {
                                gj.chunks.insert(chunk, cd);
                            }
                            None => eprintln!(
                                "worker {}: job {job} chunk {chunk}: partition shape \
                                 mismatch; dropped",
                                cfg.id
                            ),
                        }
                    }
                    Err(e) => {
                        gj.part_asm.remove(&chunk);
                        eprintln!(
                            "worker {}: job {job} chunk {chunk}: bad partition slice \
                             ({e}); dropped",
                            cfg.id
                        );
                    }
                }
            }
            Ok(Frame::Params { job, version, off, total, data }) => {
                let Some(gj) = grad.get_mut(&job) else { continue };
                if off == 0 {
                    gj.params_asm = Some((version, TensorAssembly::new(total)));
                }
                let Some((v, asm)) = gj.params_asm.as_mut() else { continue };
                if *v != version {
                    continue; // slice of an abandoned broadcast
                }
                match asm.accept(off, &data) {
                    Ok(false) => {}
                    Ok(true) => {
                        let (v, asm) = gj.params_asm.take().expect("assembly just completed");
                        match mlp::unflatten(&gj.dims, &asm.take()) {
                            Some(p) => gj.params = Some((v, p)),
                            None => eprintln!(
                                "worker {}: job {job}: params v{v} length mismatch; \
                                 dropped",
                                cfg.id
                            ),
                        }
                    }
                    Err(e) => {
                        gj.params_asm = None;
                        eprintln!(
                            "worker {}: job {job}: bad params slice ({e}); dropped",
                            cfg.id
                        );
                    }
                }
            }
            Ok(Frame::GradAssign { job, round, param_version, work_units, units }) => {
                // same scripted-fault gate as the synthetic path: a
                // fault past its threshold acts on receipt
                if let Some(f) = *fault {
                    if stats.rounds_served as u64 >= f.at_round {
                        match f.kind {
                            FaultKind::Crash => break Ok(SessionEnd::Done),
                            FaultKind::Hang => {
                                stop.store(true, Ordering::Release);
                                while read_frame(&mut reader).is_ok() {}
                                break Ok(SessionEnd::Done);
                            }
                            FaultKind::Reconnect => {
                                *fault = None; // one-shot
                                break Ok(SessionEnd::Redial { away_s: f.away_s });
                            }
                            // byzantine corrupts the payload below
                            _ => {}
                        }
                    }
                }
                current_round.store(round, Ordering::Release);
                let mult = chaos.as_mut().map_or(1.0, |c| c.next_multiplier());
                if mult > 1.0 {
                    stats.chaos_rounds += 1;
                }
                let started = Instant::now();
                let payload =
                    grad.get(&job).and_then(|gj| compute_grad_units(gj, param_version, &units));
                // Chaos stretches *real* compute: hold the worker until
                // the modelled duration elapses, gradient math included,
                // so fleet and sim stay on the same time axis.
                let target = (cfg.base_s + cfg.alpha_s * work_units) * mult;
                let elapsed = started.elapsed().as_secs_f64();
                if target > elapsed {
                    std::thread::sleep(Duration::from_secs_f64(target - elapsed));
                }
                let Some(mut payload) = payload else {
                    // missing chunks, or params absent / at the wrong
                    // version: answering would poison the decode, so
                    // stay silent and let the straggler machinery
                    // absorb the gap
                    eprintln!(
                        "worker {}: job {job} round {round}: cannot serve param \
                         v{param_version}; staying silent",
                        cfg.id
                    );
                    continue;
                };
                if let Some(f) = *fault {
                    if f.kind == FaultKind::Byzantine && stats.rounds_served as u64 >= f.at_round
                    {
                        // scripted corruption: a well-formed, plausible
                        // payload with every sign flipped — only the
                        // code's redundancy can catch it. The fault stays
                        // armed (every later round lies too): a single
                        // flipped round can slip through when a decode
                        // closes with no spare responder, but a liar that
                        // keeps lying is caught the first time any group
                        // decodes with redundancy — and then the master
                        // audits, flags and retires us for good.
                        for v in payload.iter_mut() {
                            *v = -*v;
                        }
                    }
                }
                stats.rounds_served += 1;
                let compute_s = started.elapsed().as_secs_f64();
                let total = payload.len() as u32;
                let mut send_err = None;
                for (off, slice) in tensor_slices(&payload) {
                    let frame = Frame::GradResult {
                        worker_id: cfg.id,
                        job,
                        round,
                        param_version,
                        compute_s,
                        off,
                        total,
                        data: slice.to_vec(),
                    };
                    if let Err(e) = write_frame(&mut *writer.lock().unwrap(), &frame) {
                        send_err = Some(e);
                        break;
                    }
                }
                if let Some(e) = send_err {
                    break Err(anyhow::anyhow!("worker {}: send gradient: {e}", cfg.id));
                }
                if cfg.fail_after_rounds.is_some_and(|k| stats.rounds_served >= k) {
                    break Ok(SessionEnd::Done);
                }
            }
            Ok(other) => {
                break Err(anyhow::anyhow!("worker {}: unexpected frame {other:?}", cfg.id))
            }
            // EOF before the first assignment means the master rejected
            // this worker (duplicate/out-of-range id, or the fleet was
            // already full) — that must not look like a clean run. After
            // a scripted reconnect (`!initial`) the same EOF just means
            // the fleet wound down first.
            Err(WireError::Closed) if stats.rounds_served == 0 && initial => {
                break Err(anyhow::anyhow!(
                    "worker {}: master closed the connection before assigning any \
                     work (rejected handshake?)",
                    cfg.id
                ))
            }
            Err(WireError::Closed) => break Ok(SessionEnd::Done), // master hung up mid-run
            Err(e) => break Err(anyhow::anyhow!("worker {}: read: {e}", cfg.id)),
        }
    };
    stop.store(true, Ordering::Release);
    let _ = hb.join();
    result
}

/// FNV-1a fold of the assigned chunk ids: the minitask's "result". The
/// master recomputes this from the chunks it assigned and rejects
/// results that disagree (a worker that skipped the work, or a corrupted
/// assignment).
pub(crate) fn chunk_checksum(chunks: &[u32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &c in chunks {
        h ^= c as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The synthetic workload: compute the chunk checksum (stands in for
/// "compute the partial gradient over these chunks"), then hold the
/// worker busy for the modelled duration.
fn execute_minitask(chunks: &[u32], duration_s: f64) -> u64 {
    let h = chunk_checksum(chunks);
    std::thread::sleep(Duration::from_secs_f64(duration_s.max(0.0)));
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_is_deterministic_per_worker() {
        let cfg = ChaosConfig::default_fit(42);
        let seq = |id: u32| {
            let mut c = ChaosState::new(cfg, id);
            (0..200).map(|_| c.next_multiplier()).collect::<Vec<_>>()
        };
        assert_eq!(seq(3), seq(3), "same worker, same stream");
        assert_ne!(seq(3), seq(4), "distinct workers diverge");
    }

    #[test]
    fn chaos_matches_fig1_scale() {
        let cfg = ChaosConfig::default_fit(7);
        let mut slow_cells = 0usize;
        let rounds = 400;
        let workers = 32;
        for id in 0..workers {
            let mut c = ChaosState::new(cfg, id);
            for _ in 0..rounds {
                if c.next_multiplier() > 1.0 {
                    slow_cells += 1;
                }
            }
        }
        let frac = slow_cells as f64 / (rounds * workers as usize) as f64;
        assert!((0.02..0.12).contains(&frac), "straggle fraction {frac}");
    }

    #[test]
    fn chaos_slowdowns_clear_the_mu_cutoff() {
        // μ = 1 ⇒ a fresh straggler's multiplier must exceed 2.
        let cfg = ChaosConfig::default_fit(11);
        let mut c = ChaosState::new(cfg, 0);
        let mut fresh = Vec::new();
        let mut was_slow = false;
        for _ in 0..2000 {
            let m = c.next_multiplier();
            if m > 1.0 && !was_slow {
                fresh.push(m);
            }
            was_slow = m > 1.0;
        }
        assert!(!fresh.is_empty());
        let ok = fresh.iter().filter(|&&m| m > 2.0).count() as f64 / fresh.len() as f64;
        assert!(ok > 0.95, "fresh straggler multipliers must clear 2×: {ok}");
    }

    #[test]
    fn minitask_checksum_depends_on_chunks() {
        let a = execute_minitask(&[1, 2, 3], 0.0);
        let b = execute_minitask(&[1, 2, 4], 0.0);
        let c = execute_minitask(&[1, 2, 3], 0.0);
        assert_eq!(a, c);
        assert_ne!(a, b);
    }

    #[test]
    fn minitask_holds_for_duration() {
        let t = Instant::now();
        execute_minitask(&[], 0.03);
        assert!(t.elapsed() >= Duration::from_millis(28));
    }
}
